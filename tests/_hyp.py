"""Optional-``hypothesis`` shim for the property tests.

When ``hypothesis`` is installed the real ``given``/``settings``/``st``
are re-exported unchanged. When it is missing (it is an optional extra,
not a tier-1 dependency) a tiny deterministic fallback runs each property
test over ``max_examples`` seeded random draws instead of skipping it —
less adversarial than hypothesis (no shrinking, no edge-case bias) but
the invariants still get exercised.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised implicitly by which path CI installs
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    from types import SimpleNamespace

    import numpy as np

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _tuples(*strategies):
        return _Strategy(lambda rng: tuple(s.example(rng) for s in strategies))

    def _lists(elements, min_size=0, max_size=10):
        return _Strategy(
            lambda rng: [
                elements.example(rng)
                for _ in range(int(rng.integers(min_size, max_size + 1)))
            ]
        )

    def _sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))])

    def _floats(min_value, max_value):
        return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    st = SimpleNamespace(
        integers=_integers,
        tuples=_tuples,
        lists=_lists,
        sampled_from=_sampled_from,
        floats=_floats,
    )

    def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            # No functools.wraps: the wrapper must present a zero-arg
            # signature or pytest asks for the drawn params as fixtures.
            def wrapper():
                rng = np.random.default_rng(0xE7A5)
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    fn(*(s.example(rng) for s in strategies))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
