"""End-to-end pipeline behaviour: kernels path == jnp path, multi-node
shard_map array, and the latency-stage structure from paper Table III."""
import numpy as np
import pytest

from repro.core.events import batch_from_arrays
from repro.core.pipeline import PipelineConfig, make_process_window, run_recording
from repro.data.synthetic import make_recording


@pytest.fixture(scope="module")
def recording():
    return make_recording(seed=3, duration_s=0.4, n_rsos=2)


def test_kernel_path_equals_jnp_path(recording):
    n = min(len(recording), 250)
    b = batch_from_arrays(
        recording.x[:n], recording.y[:n], recording.t[:n], recording.p[:n]
    )
    c1, m1 = make_process_window(PipelineConfig(use_kernels=False))(b)
    c2, m2 = make_process_window(PipelineConfig(use_kernels=True))(b)
    np.testing.assert_array_equal(np.asarray(c1.count), np.asarray(c2.count))
    np.testing.assert_allclose(
        np.asarray(c1.centroid_x), np.asarray(c2.centroid_x), rtol=1e-5
    )
    np.testing.assert_allclose(
        np.asarray(m1["shannon_entropy"]), np.asarray(m2["shannon_entropy"]),
        rtol=1e-4, atol=1e-5,
    )


def test_run_recording_produces_windows_and_tracks(recording):
    results = run_recording(recording, PipelineConfig(), with_tracking=True)
    assert len(results) >= 15
    assert all(r.tracks is not None for r in results)
    n_det = sum(int(r.clusters.num_valid()) for r in results)
    assert n_det > 10


def test_multi_node_array_shard_map(subproc):
    """ARACHNID scaling: the same pipeline over a 'node' mesh axis — one
    shard per camera (paper Sec. V-E)."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.events import EventBatch
from repro.core.grid_clustering import GridConfig, grid_cluster
from repro.launch.mesh import make_mesh, shard_map

nodes, windows, cap = 4, 8, 256
mesh = make_mesh((nodes,), ("node",))
rng = np.random.default_rng(0)
leaves = [
    rng.integers(0, 640, (nodes, windows, cap)).astype(np.int32),
    rng.integers(0, 480, (nodes, windows, cap)).astype(np.int32),
    np.zeros((nodes, windows, cap), np.int32),
    np.zeros((nodes, windows, cap), np.int32),
    np.ones((nodes, windows, cap), bool),
]
batch = EventBatch(*[jax.device_put(jnp.asarray(a), NamedSharding(mesh, P("node"))) for a in leaves])
grid = GridConfig(min_events=1, max_clusters=1200)  # keep every non-empty cell

def node_fn(b):
    b = jax.tree.map(lambda a: a[0], b)  # shard-local: drop the node dim
    out = jax.vmap(lambda eb: grid_cluster(eb, grid).count)(b)
    return out[None]  # re-add for out_specs P("node")

fn = jax.jit(shard_map(
    node_fn, mesh=mesh,
    in_specs=(jax.tree.map(lambda _: P("node"), batch),), out_specs=P("node")))
counts = np.asarray(fn(batch))
assert counts.shape == (nodes, windows, grid.max_clusters)
assert counts.sum() == nodes * windows * cap  # every event in a cell
print("ARRAY OK")
""", device_count=4)
    assert "ARRAY OK" in out


def test_stage_latency_breakdown(recording):
    """Table III structure: measure per-stage host latencies for one
    batch; every stage must be bounded and the pipeline total < 62 ms
    budget per window at CPU scale for the paper's batch size."""
    import time

    from repro.core import metrics as M
    from repro.core.events import persistent_event_filter, roi_filter
    from repro.core.grid_clustering import (
        GridConfig,
        cell_histogram,
        clusters_from_histogram,
    )

    n = min(len(recording), 250)
    b = batch_from_arrays(
        recording.x[:n], recording.y[:n], recording.t[:n], recording.p[:n]
    )
    cfg = GridConfig()
    # warm up the jits via one full pass
    proc = make_process_window(PipelineConfig())
    proc(b)

    stages = {}
    t0 = time.perf_counter()
    bb = roi_filter(b)
    bb = persistent_event_filter(bb)
    stages["conditioning"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    hist = cell_histogram(bb, cfg)
    stages["quantize+accumulate"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    clusters = clusters_from_histogram(*hist, cfg)
    stages["threshold+centroid"] = time.perf_counter() - t0
    t0 = time.perf_counter()
    frame = M.reconstruct_frame(bb)
    M.cluster_metrics(frame, clusters)
    stages["metrics"] = time.perf_counter() - t0
    assert all(v < 5.0 for v in stages.values()), stages
