"""Frame-free event-space core: equivalence surfaces (ISSUE 2).

* pairwise ``persistent_event_filter`` == sensor-histogram oracle,
* sort-based ``coincidence_counts`` == naive pairwise reference,
* out-of-bounds coordinates are masked, never wrapped onto another row,
* ``cluster_metrics_events`` (frame-free) bit-identical to the
  frame-based ``cluster_metrics_frame`` oracle, including edge-clamped
  centroids and zero-valid windows,
* the event-space scan driver bit-identical to the frame scan driver.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.core import metrics as M
from repro.core.events import (
    EventBatch,
    batch_from_arrays,
    coincidence_counts,
    persistent_event_filter,
    persistent_event_filter_hist,
)
from repro.core.grid_clustering import GridConfig, cell_histogram, grid_cluster
from repro.core.pipeline import PipelineConfig, run_recording_scan
from repro.data.synthetic import make_recording

RNG = np.random.default_rng(7)


def _random_batch(seed, n=200, capacity=256, spread=640):
    rng = np.random.default_rng(seed)
    # Cluster events around a few hot spots so patches overlap and some
    # pixels repeat (coincidence counts > 1).
    centers = rng.integers(30, 600, (4, 2))
    pick = rng.integers(0, 4, n)
    x = np.clip(centers[pick, 0] + rng.integers(-20, 21, n), 0, spread - 1)
    y = np.clip(centers[pick, 1] + rng.integers(-20, 21, n), 0, 479)
    batch = batch_from_arrays(x, y, np.arange(n), rng.integers(0, 2, n), capacity)
    # Random validity holes exercise masked events.
    valid = np.asarray(batch.valid) & (rng.random(capacity) > 0.1)
    return batch._replace(valid=jnp.asarray(valid))


# ---------------------------------------------------------------------------
# persistent_event_filter: pairwise vs histogram oracle
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(
    st.integers(0, 10_000),
    st.integers(1, 250),
    st.sampled_from([1, 2, 8, 12]),
)
def test_persistent_filter_pairwise_matches_hist(seed, n, max_repeats):
    rng = np.random.default_rng(seed)
    # Narrow coordinate range to force hot pixels.
    x = rng.integers(0, 30, n)
    y = rng.integers(0, 30, n)
    batch = batch_from_arrays(x, y, np.arange(n), np.zeros(n))
    valid = np.asarray(batch.valid) & (rng.random(batch.capacity) > 0.2)
    batch = batch._replace(valid=jnp.asarray(valid))
    a = persistent_event_filter(batch, max_repeats)
    b = persistent_event_filter_hist(batch, max_repeats)
    np.testing.assert_array_equal(np.asarray(a.valid), np.asarray(b.valid))


def test_persistent_filter_large_capacity_sort_path():
    # Capacities past the pairwise cutoff route through the sort-based
    # coincidence count; the keep mask must still match the oracle.
    n, cap = 1500, 2048
    rng = np.random.default_rng(3)
    x = rng.integers(0, 40, n)
    y = rng.integers(0, 40, n)
    batch = batch_from_arrays(x, y, np.arange(n), np.zeros(n), capacity=cap)
    valid = np.asarray(batch.valid) & (rng.random(cap) > 0.2)
    batch = batch._replace(valid=jnp.asarray(valid))
    a = persistent_event_filter(batch, max_repeats=4)
    b = persistent_event_filter_hist(batch, max_repeats=4)
    np.testing.assert_array_equal(np.asarray(a.valid), np.asarray(b.valid))


def test_persistent_filter_removes_hot_pixel():
    x = np.array([5] * 10 + [100, 101, 102])
    y = np.array([5] * 10 + [100, 100, 100])
    batch = batch_from_arrays(x, y, np.arange(13), np.zeros(13), capacity=16)
    out = persistent_event_filter(batch, max_repeats=8)
    v = np.asarray(out.valid)
    assert not v[:10].any()  # hot pixel gone
    assert v[10:13].all()  # isolated events kept


# ---------------------------------------------------------------------------
# coincidence_counts
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 256))
def test_coincidence_counts_match_pairwise(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(0, 25, n), jnp.int32)
    y = jnp.asarray(rng.integers(0, 25, n), jnp.int32)
    w = jnp.asarray(rng.random(n) > 0.3)
    c, leader = coincidence_counts(x, y, w)
    same = (x[:, None] == x[None, :]) & (y[:, None] == y[None, :])
    c_ref = np.asarray(jnp.sum(same & w[None, :], axis=-1))
    cn, ln, wn = np.asarray(c), np.asarray(leader), np.asarray(w)
    np.testing.assert_array_equal(cn[wn], c_ref[wn])
    # Exactly one leader per occupied pixel, and leaders are weighted.
    assert not ln[~wn].any()
    keys = np.asarray(y) * 640 + np.asarray(x)
    assert ln.sum() == len(np.unique(keys[wn]))
    for k in np.unique(keys[wn]):
        assert ln[wn & (keys == k)].sum() == 1


def test_coincidence_counts_all_invalid():
    x = jnp.zeros(8, jnp.int32)
    c, leader = coincidence_counts(x, x, jnp.zeros(8, bool))
    assert not np.asarray(leader).any()


# ---------------------------------------------------------------------------
# Out-of-bounds coordinates are masked, not wrapped
# ---------------------------------------------------------------------------

def test_reconstruct_frame_masks_out_of_bounds():
    # x = width would previously clip the flat index onto the next row.
    batch = batch_from_arrays(
        np.array([640, 10, -1]), np.array([10, 470, 5]),
        np.arange(3), np.zeros(3), capacity=4,
    )
    img = M.accumulate_image(batch)
    assert float(img.sum()) == 1.0  # only the in-bounds event lands
    assert float(img[470, 10]) == 1.0
    assert float(img[11, 0]) == 0.0  # no wraparound onto row 11


def test_cell_histogram_masks_out_of_bounds():
    cfg = GridConfig()
    batch = batch_from_arrays(
        np.array([640, 655, 100]), np.array([0, 479, 100]),
        np.arange(3), np.zeros(3), capacity=4,
    )
    count, sx, sy, st_ = cell_histogram(batch, cfg)
    assert int(np.asarray(count).sum()) == 1
    # The in-bounds event is in cell (6, 6).
    assert int(np.asarray(count)[6 * cfg.grid_w + 6]) == 1


def test_cluster_accum_kernel_masks_out_of_bounds():
    from repro.kernels import ops as kops

    cfg = GridConfig()
    x = jnp.asarray([640, 100], jnp.int32)
    y = jnp.asarray([0, 100], jnp.int32)
    count, *_ = kops.cluster_accum(
        x, y, jnp.zeros(2), jnp.ones(2, bool),
        cell_size=cfg.cell_size, grid_w=cfg.grid_w, grid_h=cfg.grid_h,
        width=cfg.width, height=cfg.height,
    )
    assert int(np.asarray(count).sum()) == 1


# ---------------------------------------------------------------------------
# Frame-free metrics == frame-based oracle, bit for bit
# ---------------------------------------------------------------------------

def _assert_metrics_identical(batch, clusters):
    a = M.cluster_metrics_frame(batch, clusters)
    b = M.cluster_metrics_events(batch, clusters)
    assert set(a) == set(M.METRIC_NAMES)
    for k in a:
        np.testing.assert_array_equal(
            np.asarray(a[k]), np.asarray(b[k]), err_msg=k
        )


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_event_metrics_bit_identical_random(seed):
    batch = _random_batch(seed)
    clusters = grid_cluster(batch, GridConfig(min_events=2))
    _assert_metrics_identical(batch, clusters)


def test_event_metrics_bit_identical_edge_clamped():
    # Events hugging every sensor corner -> centroids clamp to the border.
    pts = []
    for cx, cy in [(1, 1), (638, 1), (1, 478), (638, 477)]:
        pts += [(cx + dx, cy) for dx in (-1, 0, 1)] * 2
    pts = np.array(pts)
    batch = batch_from_arrays(
        pts[:, 0], pts[:, 1], np.arange(len(pts)), np.zeros(len(pts))
    )
    clusters = grid_cluster(batch, GridConfig(min_events=2))
    assert int(clusters.num_valid()) >= 4
    _assert_metrics_identical(batch, clusters)


def test_event_metrics_bit_identical_zero_valid():
    batch = _random_batch(5)
    batch = batch._replace(valid=jnp.zeros_like(batch.valid))
    clusters = grid_cluster(batch, GridConfig())
    assert int(clusters.num_valid()) == 0
    _assert_metrics_identical(batch, clusters)
    mets = M.cluster_metrics_events(batch, clusters)
    assert all(float(np.abs(np.asarray(v)).max()) == 0.0 for v in mets.values())


def test_event_metrics_bit_identical_after_hot_filter():
    batch = persistent_event_filter(_random_batch(6), max_repeats=2)
    clusters = grid_cluster(batch, GridConfig(min_events=2))
    _assert_metrics_identical(batch, clusters)


def test_count_patches_match_frame_slices():
    batch = _random_batch(8)
    clusters = grid_cluster(batch, GridConfig(min_events=2))
    img = M.accumulate_image(batch)
    patches = M.cluster_count_patches(batch, clusters)
    for k in range(patches.shape[0]):
        ref = M.extract_window(
            img, clusters.centroid_x[k], clusters.centroid_y[k]
        )
        np.testing.assert_array_equal(np.asarray(patches[k]), np.asarray(ref))


def test_exact_core_close_to_legacy_metrics():
    """The refactored shared core agrees with the legacy frame metrics to
    float tolerance (same math, replayable summation forms)."""
    batch = _random_batch(9)
    clusters = grid_cluster(batch, GridConfig(min_events=2))
    legacy = M.cluster_metrics(M.reconstruct_frame(batch), clusters)
    exact = M.cluster_metrics_frame(batch, clusters)
    for k in M.METRIC_NAMES:
        np.testing.assert_allclose(
            np.asarray(legacy[k]), np.asarray(exact[k]),
            rtol=1e-4, atol=1e-4, err_msg=k,
        )


# ---------------------------------------------------------------------------
# Pipeline-level: scan drivers agree across metrics_impl
# ---------------------------------------------------------------------------

def test_scan_event_impl_matches_frame_impl():
    rec = make_recording(seed=11, duration_s=0.4, n_rsos=2)
    cfg = PipelineConfig()  # metrics_impl="event"
    a = run_recording_scan(rec, cfg)
    b = run_recording_scan(rec, dataclasses.replace(cfg, metrics_impl="frame"))
    for k in a.metrics:
        np.testing.assert_array_equal(
            np.asarray(a.metrics[k]), np.asarray(b.metrics[k]), err_msg=k
        )
    for f in a.final_tracks._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a.final_tracks, f)),
            np.asarray(getattr(b.final_tracks, f)),
            err_msg=f,
        )


def test_scan_event_impl_invariant_to_chunk():
    rec = make_recording(seed=11, duration_s=0.3, n_rsos=1)
    base = run_recording_scan(rec, PipelineConfig(scan_chunk=16))
    for chunk in (1, 3, 64):
        out = run_recording_scan(rec, PipelineConfig(scan_chunk=chunk))
        for k in base.metrics:
            np.testing.assert_array_equal(
                np.asarray(base.metrics[k]), np.asarray(out.metrics[k]),
                err_msg=f"chunk={chunk} {k}",
            )
