"""DetectionScore zero-division edges and merge_candidates degenerate
inputs (empty list / single element)."""
import numpy as np

from repro.core.pipeline import (
    Candidates,
    DetectionScore,
    merge_candidates,
    score_threshold,
)


def test_detection_score_all_zero_is_defined():
    s = DetectionScore()
    assert s.accuracy == 0.0
    assert s.precision == 0.0
    assert s.recall == 0.0


def test_precision_zero_division_no_positives():
    # Detector fired nothing: precision denominator tp + fp == 0.
    s = DetectionScore(tp=0, fp=0, fn=7, tn=3)
    assert s.precision == 0.0
    assert s.recall == 0.0
    assert s.accuracy == 3 / 10


def test_recall_zero_division_no_truth():
    # No true objects at all: recall denominator tp + fn == 0.
    s = DetectionScore(tp=0, fp=4, fn=0, tn=6)
    assert s.recall == 0.0
    assert s.precision == 0.0
    assert s.accuracy == 0.6


def test_perfect_scores():
    s = DetectionScore(tp=5, fp=0, fn=0, tn=5)
    assert s.precision == 1.0
    assert s.recall == 1.0
    assert s.accuracy == 1.0


def test_merge_candidates_empty_list():
    merged = merge_candidates([])
    assert merged.counts.shape == (0,) and merged.counts.dtype == np.int32
    assert merged.is_rso.shape == (0,) and merged.is_rso.dtype == np.bool_
    assert merged.object_best.shape == (0,)
    s = score_threshold(merged, 5)
    assert (s.tp, s.fp, s.fn, s.tn) == (0, 0, 0, 0)
    assert s.accuracy == 0.0  # not a ZeroDivisionError


def test_merge_candidates_single_element_is_identity():
    cand = Candidates(
        counts=np.array([3, 7, 12], np.int32),
        is_rso=np.array([False, True, True]),
        object_best=np.array([7, 12], np.int32),
    )
    merged = merge_candidates([cand])
    np.testing.assert_array_equal(merged.counts, cand.counts)
    np.testing.assert_array_equal(merged.is_rso, cand.is_rso)
    np.testing.assert_array_equal(merged.object_best, cand.object_best)
    s = score_threshold(merged, 5)
    assert (s.tp, s.fp, s.fn, s.tn) == (2, 0, 0, 1)


def test_merge_candidates_concatenates_in_order():
    a = Candidates(
        np.array([1], np.int32), np.array([True]), np.array([1], np.int32)
    )
    b = Candidates(
        np.array([9, 2], np.int32), np.array([False, True]),
        np.array([], np.int32),
    )
    merged = merge_candidates([a, b])
    np.testing.assert_array_equal(merged.counts, [1, 9, 2])
    np.testing.assert_array_equal(merged.is_rso, [True, False, True])
    np.testing.assert_array_equal(merged.object_best, [1])
