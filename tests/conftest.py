import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = REPO / "src"


def run_in_subprocess(code: str, device_count: int = 1, timeout: int = 600) -> str:
    """Run a snippet with a forced XLA host device count (kept out of this
    process so the main test session sees exactly 1 device)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={device_count}"
    env["PYTHONPATH"] = str(SRC)
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        )
    return proc.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_in_subprocess
