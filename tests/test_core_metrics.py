"""Cluster quality metrics (paper Sec. III-E) unit tests."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics as M
from repro.core.events import batch_from_arrays
from repro.core.grid_clustering import GridConfig, grid_cluster

RNG = np.random.default_rng(3)


def test_shannon_entropy_bounds():
    flat = jnp.zeros((48, 48))
    assert float(M.shannon_entropy(flat)) == pytest.approx(0.0, abs=1e-6)
    # maximal histogram spread: one pixel per bin level
    vals = jnp.asarray(np.linspace(0, 0.999, 48 * 48).reshape(48, 48), jnp.float32)
    h = float(M.shannon_entropy(vals))
    assert h == pytest.approx(np.log2(M.HIST_BINS), abs=0.01)


def test_renyi_le_shannon():
    patch = jnp.asarray(RNG.random((48, 48)), jnp.float32)
    assert float(M.renyi_entropy(patch)) <= float(M.shannon_entropy(patch)) + 1e-6


def test_local_contrast():
    patch = jnp.asarray(RNG.random((48, 48)), jnp.float32)
    assert float(M.local_contrast(patch)) == pytest.approx(float(jnp.std(patch)), rel=1e-6)


def test_edge_density_detects_edge():
    patch = np.zeros((48, 48), np.float32)
    patch[:, 24:] = 1.0  # vertical edge
    d = float(M.edge_density(jnp.asarray(patch)))
    assert 0.02 < d < 0.2
    assert float(M.edge_density(jnp.zeros((48, 48)))) == 0.0


def test_extract_window_clamps_at_borders():
    frame = jnp.asarray(RNG.random((480, 640)), jnp.float32)
    w = M.extract_window(frame, jnp.asarray(2), jnp.asarray(470))
    assert w.shape == (48, 48)
    np.testing.assert_allclose(np.asarray(w), np.asarray(frame[432:480, 0:48]))


def test_cluster_metrics_structure_and_validity():
    pts = np.array([[100, 100]] * 8 + [[300, 300]] * 2)
    batch = batch_from_arrays(pts[:, 0], pts[:, 1], np.arange(10), np.zeros(10))
    clusters = grid_cluster(batch, GridConfig(min_events=5))
    frame = M.reconstruct_frame(batch)
    mets = M.cluster_metrics(frame, clusters)
    assert set(mets) == set(M.METRIC_NAMES)
    valid = np.asarray(clusters.valid)
    ec = np.asarray(mets["event_count"])
    assert ec[valid].max() == 8
    assert (ec[~valid] == 0).all()  # invalid slots zeroed


def test_correlation_matrix_properties():
    x = RNG.normal(size=(200, 6)).astype(np.float32)
    x[:, 1] = x[:, 0] * 2 + 0.01 * RNG.normal(size=200)  # strongly correlated
    c = np.asarray(M.correlation_matrix(jnp.asarray(x)))
    assert c.shape == (6, 6)
    np.testing.assert_allclose(np.diag(c), 1.0, atol=1e-4)
    np.testing.assert_allclose(c, c.T, atol=1e-5)
    assert c[0, 1] > 0.95


def test_rso_entropy_exceeds_star_entropy():
    """Fig. 5's separation: moving streaks have richer structure than
    static points in the reconstructed frame."""
    n = 60
    # streak: events along a 30-px line; star: all on one pixel w/ jitter.
    xs = np.linspace(200, 230, n) + RNG.normal(0, 0.6, n)
    ys = np.full(n, 240) + RNG.normal(0, 0.6, n)
    sx = np.full(n, 400) + RNG.normal(0, 0.6, n)
    sy = np.full(n, 120) + RNG.normal(0, 0.6, n)
    batch = batch_from_arrays(
        np.concatenate([xs, sx]).astype(int),
        np.concatenate([ys, sy]).astype(int),
        np.arange(2 * n), np.zeros(2 * n),
    )
    frame = M.reconstruct_frame(batch)
    h_rso = float(M.shannon_entropy(M.extract_window(frame, 215.0, 240.0)))
    h_star = float(M.shannon_entropy(M.extract_window(frame, 400.0, 120.0)))
    assert h_rso > h_star
