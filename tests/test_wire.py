"""Compressed event-wire format (DESIGN.md Sec. 16; ISSUE 10).

Four layers of differential coverage over the ragged ingest path:

* word-level properties — ``pack_words``/``unpack_words`` roundtrip
  composed with 16-bit masking vs a numpy oracle, over negative coords,
  boundary values, and OOB sentinels (hypothesis via ``_hyp``);
* wire-level — ``pack_wire`` + ``unpack_wire`` reconstruct the dense
  ``pack_bounds`` planes bit-for-bit, including events that take the
  exact int32 spill lane, with the jnp route and the Pallas
  ``event_unpack`` kernel route agreeing; ``spill=False`` raises instead
  of wrapping;
* engine-level — fleet and streaming drivers produce bit-identical
  per-session outputs under ``wire="ragged"`` vs ``wire="dense"`` for
  randomized chunking, idle sensors, and spill-forcing windows;
* service-level — ``DetectionService`` differential at pipeline depths
  1 and 3 under attach/detach churn, on the float, fixed-point, and
  megakernel datapaths, plus the wire-stats compression accounting.
"""
import dataclasses

import jax
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from test_serve_service import FakeClock, _spaced_stream

from repro.core.events import (
    SPILL_QUANTUM,
    SPILL_SENTINEL,
    WIRE_QUANTUM,
    BatcherConfig,
    dense_wire_bytes,
    pack_bounds,
    pack_bounds_into,
    pack_wire,
    pack_words,
    ragged_wire_bytes,
    spill_pad,
    unpack_wire,
    unpack_words,
    wire_pad,
)
from repro.core.pipeline import FleetPipeline, PipelineConfig, StreamingPipeline
from repro.core.pipeline.config import BatcherConfig as _BatcherAlias  # noqa: F401
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.serve import AdmissionConfig, DetectionService
from repro.serve.chaos import compare_outputs, concat_outputs

CONFIG = PipelineConfig()
FIXED = dataclasses.replace(CONFIG, numerics="fixed")
MEGA = dataclasses.replace(CONFIG, numerics="fixed", metrics_impl="megakernel")

# Values that stress the 16-bit lanes: in-range, both boundaries, just
# past, negative, and the full-word sentinel.
EDGE_COORDS = [0, 1, 255, 0xFFFF, 0x10000, -1, -0x8000, 0x7FFFFFFF, -0x80000000]


# ---------------------------------------------------------------------------
# Word-level properties: pack_words / unpack_words.
# ---------------------------------------------------------------------------

def _mask16(v: np.ndarray) -> np.ndarray:
    """Numpy oracle: the int32 value a packed 16-bit lane reconstructs."""
    return (np.asarray(v).astype(np.int64) & 0xFFFF).astype(np.int32)


@settings(max_examples=60, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(-(2**31), 2**31 - 1), st.integers(-(2**31), 2**31 - 1)
        ),
        min_size=1,
        max_size=64,
    )
)
def test_pack_unpack_words_roundtrip_masked(pairs):
    """unpack(pack(x, y)) == (x & 0xFFFF, y & 0xFFFF) as int32, for ANY
    int32 input — the packed word keeps exactly the low 16 bits."""
    x = np.array([a for a, _ in pairs], np.int64)
    y = np.array([b for _, b in pairs], np.int64)
    ux, uy = unpack_words(pack_words(jax.numpy.asarray(x), jax.numpy.asarray(y)))
    np.testing.assert_array_equal(np.asarray(ux), _mask16(x))
    np.testing.assert_array_equal(np.asarray(uy), _mask16(y))


def test_pack_unpack_words_edge_values():
    """Boundary sweep: every (x, y) pair from the edge set roundtrips to
    its masked value, and in-range values roundtrip exactly."""
    xs, ys = np.meshgrid(EDGE_COORDS, EDGE_COORDS)
    x, y = xs.ravel(), ys.ravel()
    ux, uy = unpack_words(pack_words(jax.numpy.asarray(x), jax.numpy.asarray(y)))
    np.testing.assert_array_equal(np.asarray(ux), _mask16(x))
    np.testing.assert_array_equal(np.asarray(uy), _mask16(y))
    inr = (x >= 0) & (x <= 0xFFFF) & (y >= 0) & (y <= 0xFFFF)
    np.testing.assert_array_equal(np.asarray(ux)[inr], x[inr])
    np.testing.assert_array_equal(np.asarray(uy)[inr], y[inr])


def test_pack_words_oob_sentinel():
    """The all-ones word (the coincidence sort's invalid-key sentinel)
    unpacks to (0xFFFF, 0xFFFF) — and only (x,y)=(0xFFFF,0xFFFF) packs
    to it, so sentinel keys can never collide with in-ROI pixels."""
    w = np.asarray(pack_words(
        jax.numpy.asarray([0xFFFF]), jax.numpy.asarray([0xFFFF])
    ))
    assert w[0] == np.uint32(0xFFFFFFFF)
    x, y = unpack_words(jax.numpy.asarray([np.uint32(0xFFFFFFFF)]))
    assert (int(x[0]), int(y[0])) == (0xFFFF, 0xFFFF)


@settings(max_examples=40, deadline=None)
@given(
    st.lists(st.integers(0, 2**32 - 1), min_size=1, max_size=48),
)
def test_event_unpack_kernel_matches_ref_and_jnp(words):
    """The Pallas event_unpack route (interpret on CPU) equals both the
    jnp ref oracle and unpack_words, for arbitrary 32-bit words at
    arbitrary (padded) lengths."""
    w = jax.numpy.asarray(np.array(words, np.uint32))
    kx, ky = kops.event_unpack_call(w)
    rx, ry = kref.event_unpack_ref(w)
    jx, jy = unpack_words(w)
    np.testing.assert_array_equal(np.asarray(kx), np.asarray(rx))
    np.testing.assert_array_equal(np.asarray(ky), np.asarray(ry))
    np.testing.assert_array_equal(np.asarray(kx), np.asarray(jx))
    np.testing.assert_array_equal(np.asarray(ky), np.asarray(jy))


# ---------------------------------------------------------------------------
# Wire-level: pack_wire / unpack_wire vs the dense planes.
# ---------------------------------------------------------------------------

def _window_stream(seed, n=700, span_us=120_000, garbage=False):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 640, n).astype(np.int64)
    y = rng.integers(0, 480, n).astype(np.int64)
    t = np.sort(rng.integers(0, span_us, n))
    p = rng.integers(0, 2, n).astype(np.int64)
    if garbage:
        # Values a real sensor never emits — the spill lane's job.
        x[5], y[9], p[13], x[17] = -3, 70_000, 7, 2**33 + 11
    return x, y, t, p


def _bounds3(t, batcher):
    from repro.core.events import dual_threshold_bounds

    return [(s, e, int(t[s])) for s, e in dual_threshold_bounds(t, batcher)]


@pytest.mark.parametrize("garbage", [False, True])
@pytest.mark.parametrize("kernel_route", [False, True])
def test_wire_roundtrip_matches_dense_planes(garbage, kernel_route):
    batcher = BatcherConfig()
    x, y, t, p = _window_stream(3, garbage=garbage)
    bounds3 = _bounds3(t, batcher)
    wire, starts, stops, t_start, overflow = pack_wire(
        x, y, t, p, bounds3, batcher.capacity
    )
    impl = kops.event_unpack_call if kernel_route else None
    packed, valid = unpack_wire(*wire, batcher.capacity, unpack_impl=impl)
    dense = pack_bounds(x, y, t, p, bounds3, batcher.capacity)
    np.testing.assert_array_equal(np.asarray(packed[0, 0]), np.asarray(dense.batch.x))
    np.testing.assert_array_equal(np.asarray(packed[1, 0]), np.asarray(dense.batch.y))
    np.testing.assert_array_equal(np.asarray(packed[2, 0]), np.asarray(dense.batch.t))
    np.testing.assert_array_equal(np.asarray(packed[3, 0]), np.asarray(dense.batch.p))
    np.testing.assert_array_equal(np.asarray(valid[0]), np.asarray(dense.batch.valid))
    np.testing.assert_array_equal(starts, dense.starts)
    np.testing.assert_array_equal(stops, dense.stops)
    np.testing.assert_array_equal(t_start, dense.t_start_us)
    np.testing.assert_array_equal(overflow, dense.overflow)
    spill = wire[4]
    if garbage:
        assert spill.shape[1] >= 4  # the injected events took the lane
    else:
        assert spill.shape[1] == 0


def test_wire_capacity_truncation_matches_dense():
    """Windows longer than capacity truncate identically on both layouts
    (same kept prefix, same overflow counts)."""
    batcher = BatcherConfig(capacity=32, size_threshold=200)
    x, y, t, p = _window_stream(7, n=500, span_us=50_000)
    bounds3 = _bounds3(t, batcher)
    wire, starts, stops, t_start, overflow = pack_wire(
        x, y, t, p, bounds3, batcher.capacity
    )
    packed, valid = unpack_wire(*wire, batcher.capacity)
    dense = pack_bounds(x, y, t, p, bounds3, batcher.capacity)
    assert overflow.sum() > 0  # the case actually triggers
    np.testing.assert_array_equal(overflow, dense.overflow)
    for lane, ref in zip(packed, (dense.batch.x, dense.batch.y, dense.batch.t, dense.batch.p)):
        np.testing.assert_array_equal(np.asarray(lane[0]), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(valid[0]), np.asarray(dense.batch.valid))


def test_wire_overflow_guard_raises_without_spill():
    """With the spill lane disabled, an event the packed lanes cannot
    hold exactly raises (never silently wraps)."""
    batcher = BatcherConfig()
    x, y, t, p = _window_stream(3, garbage=True)
    with pytest.raises(ValueError, match="spill lane is disabled"):
        pack_wire(x, y, t, p, _bounds3(t, batcher), batcher.capacity, spill=False)
    # Wide window-relative deltas (dt > 0xFFFF) are also caught.
    t2 = np.array([0, 1, 200_000, 200_001], np.int64)
    z = np.zeros(4, np.int64)
    with pytest.raises(ValueError, match="spill lane is disabled"):
        pack_wire(z, z, t2, z, [(0, 4, 0)], 8, spill=False)


def test_pack_bounds_into_ragged_requires_out_and_capacity():
    z = np.zeros(4, np.int64)
    with pytest.raises(TypeError, match="out= wire tuple"):
        pack_bounds_into(z, z, z, z, [(0, 4, 0)], layout="ragged")
    words = np.zeros(WIRE_QUANTUM, np.uint32)
    dt = np.zeros(WIRE_QUANTUM, np.uint16)
    pb = np.zeros(WIRE_QUANTUM, np.uint8)
    off = np.zeros(2, np.int32)
    with pytest.raises(TypeError, match="capacity"):
        pack_bounds_into(
            z, z, z, z, [(0, 4, 0)], out=(words, dt, pb, off), layout="ragged"
        )
    with pytest.raises(ValueError, match="unknown pack layout"):
        pack_bounds_into(z, z, z, z, [(0, 4, 0)], layout="csr")


def test_wire_pad_and_byte_accounting():
    assert wire_pad(0) == WIRE_QUANTUM
    assert wire_pad(1) == WIRE_QUANTUM
    assert wire_pad(WIRE_QUANTUM) == WIRE_QUANTUM
    assert wire_pad(WIRE_QUANTUM + 1) == 2 * WIRE_QUANTUM
    assert WIRE_QUANTUM % 32 == 0  # the polarity bitplane stays integral
    assert spill_pad(0) == 0
    assert spill_pad(1) == SPILL_QUANTUM
    # Ragged wins by construction at full occupancy, slot for slot:
    # 6.125 B/slot vs 17 B/slot, before padding.
    s, w, cap = 8, 1, 256
    n = s * w * cap
    assert ragged_wire_bytes(wire_pad(n), s, w, 0) < dense_wire_bytes(s, w, cap)
    assert SPILL_SENTINEL == np.int32(2**31 - 1)


# ---------------------------------------------------------------------------
# Engine-level: fleet and streaming dense-vs-ragged differentials.
# ---------------------------------------------------------------------------

def _chunk_stream(stream, cuts):
    x, y, t, p = stream
    out, prev = [], 0
    for c in list(cuts) + [len(t)]:
        out.append((x[prev:c], y[prev:c], t[prev:c], p[prev:c]))
        prev = c
    return out


def _assert_results_equal(got, want, label):
    bad = compare_outputs(concat_outputs(got), concat_outputs(want), label)
    assert not bad, bad


def _run_fleet(config, wire, rounds, n_sensors):
    fp = FleetPipeline(config, n_sensors=n_sensors, wire=wire)
    res = [fp.feed(r) for r in rounds] + [fp.flush()]
    return fp, res


def test_fleet_ragged_bitwise_equals_dense():
    """Multi-sensor fleet, randomized per-sensor chunk cuts, one idle
    sensor per round: ragged == dense on every surface."""
    rng = np.random.default_rng(11)
    n_sensors, n_rounds = 3, 5
    streams = [_spaced_stream(seed=30 + s, n=1200) for s in range(n_sensors)]
    per_sensor = [
        _chunk_stream(streams[s], sorted(rng.integers(1, 1200, n_rounds - 1)))
        for s in range(n_sensors)
    ]
    rounds = [
        [per_sensor[s][r] if (r + s) % 4 else None for s in range(n_sensors)]
        for r in range(n_rounds)
    ]
    _, dense = _run_fleet(CONFIG, "dense", rounds, n_sensors)
    fp, ragged = _run_fleet(CONFIG, "ragged", rounds, n_sensors)
    for s in range(n_sensors):
        _assert_results_equal(
            [r.sensor(s) for r in ragged],
            [r.sensor(s) for r in dense],
            f"fleet/sensor{s}",
        )
    assert fp.wire_stats.rounds > 0
    assert fp.wire_stats.compression > 1.0
    assert fp.wire_stats.wire_bytes < fp.wire_stats.dense_bytes


def test_fleet_ragged_spill_path_bitwise_equals_dense():
    """Sparse events under a 200 ms time threshold produce window-relative
    deltas past the 16-bit lane — the spill lane carries them and the
    outputs stay bit-identical (stats confirm the lane was exercised)."""
    config = dataclasses.replace(
        CONFIG, batcher=BatcherConfig(time_threshold_us=200_000)
    )
    rng = np.random.default_rng(5)
    n = 400
    stream = (
        rng.integers(0, 640, n).astype(np.int64),
        rng.integers(0, 480, n).astype(np.int64),
        np.sort(rng.integers(0, 2_000_000, n)),
        rng.integers(0, 2, n).astype(np.int64),
    )
    rounds = [[c] for c in _chunk_stream(stream, [120, 260])]
    _, dense = _run_fleet(config, "dense", rounds, 1)
    fp, ragged = _run_fleet(config, "ragged", rounds, 1)
    _assert_results_equal(
        [r.sensor(0) for r in ragged], [r.sensor(0) for r in dense], "spill"
    )
    assert fp.wire_stats.spilled > 0


def test_streaming_ragged_bitwise_equals_dense():
    x, y, t, p = _spaced_stream(seed=77, n=1500)
    cuts = [0, 333, 700, 701, 1100]
    dense_sp = StreamingPipeline(CONFIG, wire="dense")
    ragged_sp = StreamingPipeline(CONFIG, wire="ragged")
    got, want = [], []
    for c in _chunk_stream((x, y, t, p), cuts):
        want.append(dense_sp.feed(*c))
        got.append(ragged_sp.feed(*c))
    want.append(dense_sp.flush())
    got.append(ragged_sp.flush())
    _assert_results_equal(got, want, "stream")
    assert ragged_sp.wire_stats.compression > 1.0
    assert dense_sp.wire_stats.compression == 1.0


def test_wire_mode_validated():
    with pytest.raises(ValueError, match="unknown wire mode"):
        FleetPipeline(CONFIG, wire="csr")
    with pytest.raises(ValueError, match="unknown wire mode"):
        StreamingPipeline(CONFIG, wire="packed")


# ---------------------------------------------------------------------------
# Service-level: churny differential at pipeline depths 1 and 3.
# ---------------------------------------------------------------------------

def _drive_service(config, wire, depth, n_rounds=8, chunk=100):
    """Seeded churn schedule: attach ramp, random chunk sizes, a detach,
    slot recycling. Returns per-session output part lists."""
    svc = DetectionService(
        config,
        tiers=(2, 4),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        clock=FakeClock(),
        max_inflight_rounds=depth,
        wire=wire,
    )
    rng = np.random.default_rng(0xC0FFEE)
    streams, parts, live = {}, {}, []

    def attach():
        sid = svc.attach()
        streams[sid] = {
            "data": _spaced_stream(seed=900 + sid, n=n_rounds * 2 * chunk),
            "pos": 0,
        }
        parts[sid] = []
        live.append(sid)

    def collect(served):
        for f in served:
            parts[f.sid].append(f.result)

    attach()
    attach()
    for r in range(n_rounds):
        if r == 2:
            attach()  # tier promotion territory on round 3
        if r == 5:
            sid = live.pop(0)
            parts[sid].append(svc.detach(sid))
            attach()  # recycled slot
        for sid in live:
            rec = streams[sid]
            n = int(rng.integers(40, 2 * chunk))
            x, y, t, p = rec["data"]
            pos = rec["pos"]
            collect(svc.feed(sid, x[pos:pos + n], y[pos:pos + n],
                             t[pos:pos + n], p[pos:pos + n]))
            rec["pos"] = pos + n
        collect(svc.pump(force=True))
    for sid in list(live):
        parts[sid].append(svc.detach(sid))
    return svc, parts


@pytest.mark.parametrize("depth", [1, 3])
def test_service_ragged_bitwise_equals_dense(depth):
    _, want = _drive_service(CONFIG, "dense", depth)
    svc, got = _drive_service(CONFIG, "ragged", depth)
    assert set(got) == set(want)
    for sid in want:
        _assert_results_equal(got[sid], want[sid], f"svc-d{depth}/s{sid}")
    assert svc.wire_stats.compression > 1.0


@pytest.mark.parametrize("config", [FIXED, MEGA], ids=["fixed", "megakernel"])
def test_service_ragged_equals_dense_fixed_routes(config):
    """The compressed wire is numerics-agnostic: the fixed-point and
    fused-megakernel datapaths see identical reconstructed planes (small
    shapes — the megakernel runs in interpret mode on CPU)."""
    cfg = dataclasses.replace(
        config, batcher=BatcherConfig(size_threshold=50, capacity=64)
    )
    _, want = _drive_service(cfg, "dense", 1, n_rounds=3, chunk=50)
    _, got = _drive_service(cfg, "ragged", 1, n_rounds=3, chunk=50)
    for sid in want:
        _assert_results_equal(got[sid], want[sid], f"svc-fixed/s{sid}")


def test_service_wire_stats_accounting():
    svc, _ = _drive_service(CONFIG, "ragged", 1, n_rounds=3)
    stats = svc.wire_stats
    assert stats.rounds > 0 and stats.events > 0
    assert stats.wire_bytes_per_round > 0
    # Dense-equivalent accounting uses the same round shapes, so the
    # ratio is bounded below by the per-slot byte ratio at the padding
    # floor and above by 17 / 6.125 times the inverse occupancy.
    assert 0 < stats.compression
    assert stats.dense_bytes >= stats.wire_bytes or stats.compression < 1.0
