"""Scenario simulator: every family produces labeled, evaluable
recordings; family-specific statistics hold; the fleet evaluation path
scores the scenario suite identically to the offline scan path."""
import functools

import numpy as np
import pytest

from repro.core.pipeline import (
    PipelineConfig,
    collect_candidates,
    collect_candidates_fleet,
    collect_candidates_many,
    score_threshold,
    threshold_sweep,
    track_table,
)
from repro.data.synthetic import (
    KIND_NOISE,
    KIND_RSO,
    KIND_STAR,
    SCENARIO_FAMILIES,
    RSOSpec,
    Scenario,
    make_fleet_recordings,
    make_scenario,
    make_scenario_suite,
)

DUR = 0.6  # seconds; short but > several tumble/jitter periods


@functools.lru_cache(maxsize=None)
def _family(fam: str, seed: int = 11):
    import dataclasses

    sc = dataclasses.replace(SCENARIO_FAMILIES[fam], duration_s=DUR)
    return make_scenario(sc, seed=seed)


def test_scenario_registry_is_diverse():
    # >= 5 new families beyond the paper's linear-crossing regime.
    assert len(SCENARIO_FAMILIES) >= 6
    assert "crossing" in SCENARIO_FAMILIES  # the baseline regime stays


@pytest.mark.parametrize("fam", sorted(SCENARIO_FAMILIES))
def test_scenario_recording_is_labeled_and_sorted(fam):
    rec = _family(fam)
    assert len(rec) > 0
    assert np.all(np.diff(rec.t) >= 0)
    assert rec.kind.shape == rec.t.shape == rec.obj.shape
    assert set(np.unique(rec.kind)) <= {KIND_NOISE, KIND_STAR, KIND_RSO}
    # Per-event ground truth: every RSO event names a real track row.
    rso_objs = rec.obj[rec.kind == KIND_RSO]
    assert rso_objs.size > 0
    assert rso_objs.min() >= 0
    assert rso_objs.max() < track_table(rec.rso_tracks).shape[0]
    # Noise carries no object id.
    assert np.all(rec.obj[rec.kind == KIND_NOISE] == -1)
    # RSO events sit within the gate of their ground-truth trajectory
    # (PSF + pointing jitter + integer truncation stay below ~6 px).
    for r in range(rec.rso_tracks.shape[0]):
        sel = (rec.kind == KIND_RSO) & (rec.obj == r)
        px, py = rec.rso_position(r, rec.t[sel])
        d = np.hypot(px - rec.x[sel], py - rec.y[sel])
        assert np.percentile(d, 95) < 8.0, fam


@pytest.mark.parametrize("fam", sorted(SCENARIO_FAMILIES))
def test_scenario_families_are_exercised_by_evaluation(fam):
    """Every family flows through the full evaluation suite and produces
    a meaningful confusion matrix (candidates on both sides)."""
    rec = _family(fam)
    score = score_threshold(collect_candidates(rec), 5)
    total = score.tp + score.fp + score.fn + score.tn
    assert total > 0
    # Every family keeps some separability signal: true positives exist...
    assert score.tp > 0, (fam, score)
    # ...and so do correctly rejected star/noise candidates.
    assert score.tn > 0, (fam, score)


def test_detectable_families_keep_high_recall():
    # Dense movers (linear, slow GEO, curved) must stay detectable at the
    # paper's min_events=5; degraded-regime families (tumbling troughs,
    # bursts) are allowed to dip but not vanish. hot_columns is the
    # designed failure regime — stuck columns collapse the size-cut
    # windows so the per-window hot-pixel filter stops firing and both
    # recall and precision crater; the floor only pins that the true
    # objects don't disappear entirely.
    for fam, floor in [
        ("crossing", 0.85), ("geo_slow", 0.85), ("ballistic", 0.85),
        ("jitter", 0.85), ("tumbling", 0.6), ("noise_burst", 0.6),
        ("hot_columns", 0.1),
    ]:
        score = score_threshold(collect_candidates(_family(fam)), 5)
        assert score.recall >= floor, (fam, score)
    # The stress is real: hot columns destroy precision.
    hot = score_threshold(collect_candidates(_family("hot_columns")), 5)
    assert hot.precision < 0.5


def test_ballistic_tracks_are_quadratic():
    rec = _family("ballistic")
    tracks = track_table(rec.rso_tracks)
    assert tracks.shape[-1] == 6
    assert np.any(np.hypot(tracks[:, 4], tracks[:, 5]) > 1.0)
    # rso_position honors the acceleration columns.
    x0, y0, vx, vy, ax, ay = tracks[0]
    t_us = np.array([0.0, 5e5, 1e6])
    px, py = rec.rso_position(0, t_us)
    ts = t_us * 1e-6
    np.testing.assert_allclose(px, x0 + vx * ts + 0.5 * ax * ts * ts)
    np.testing.assert_allclose(py, y0 + vy * ts + 0.5 * ay * ts * ts)


def test_tumbling_modulates_event_rate():
    rec_t = _family("tumbling")
    rec_c = _family("crossing")

    def cv(rec):  # per-50ms-bin coefficient of variation of RSO arrivals
        t = rec.t[(rec.kind == KIND_RSO) & (rec.obj == 0)]
        bins = np.histogram(t, bins=np.arange(0, rec.duration_us, 50_000))[0]
        return bins.std() / max(bins.mean(), 1e-9)

    # Sinusoidal thinning makes arrivals much burstier than Poisson.
    assert cv(rec_t) > 2.0 * cv(rec_c)


def test_hot_columns_concentrate_on_few_pixels():
    rec = _family("hot_columns")
    noise = rec.kind == KIND_NOISE
    cols, counts = np.unique(rec.x[noise], return_counts=True)
    top3 = counts[np.argsort(counts)][-3:].sum()
    # The three stuck columns dominate the background events.
    assert top3 > 0.5 * noise.sum()


def test_noise_burst_is_temporally_localized():
    rec = _family("noise_burst")
    t = rec.t[rec.kind == KIND_NOISE]
    bins = np.histogram(t, bins=np.arange(0, rec.duration_us, 10_000))[0]
    assert bins.max() > 5 * np.median(bins)


def test_pointing_jitter_moves_the_frame():
    import dataclasses

    sc = dataclasses.replace(SCENARIO_FAMILIES["jitter"], duration_s=DUR)
    still = dataclasses.replace(sc, jitter_px=0.0)
    a = make_scenario(sc, seed=5)
    b = make_scenario(still, seed=5)
    # Same seed, same events drawn — only the apparent positions wobble.
    assert len(a) == len(b)
    np.testing.assert_array_equal(a.t, b.t)
    moved = np.abs(a.x - b.x) + np.abs(a.y - b.y)
    assert (moved > 0).mean() > 0.5


def test_scenario_suite_and_sweep_run_end_to_end():
    suite = make_scenario_suite(duration_s=0.35)
    assert len(suite) == len(SCENARIO_FAMILIES)
    sweep = threshold_sweep(suite, thresholds=(2, 5, 8))
    assert set(sweep) == {2, 5, 8}
    assert all(s.tp + s.fp + s.fn + s.tn > 0 for s in sweep.values())


def test_fleet_evaluation_equals_scan_on_scenarios():
    suite = make_scenario_suite(
        families=("crossing", "ballistic", "tumbling", "geo_slow"),
        duration_s=0.35,
    )
    for a, b in zip(
        collect_candidates_many(suite), collect_candidates_fleet(suite)
    ):
        np.testing.assert_array_equal(a.counts, b.counts)
        np.testing.assert_array_equal(a.is_rso, b.is_rso)
        np.testing.assert_array_equal(a.object_best, b.object_best)
    sweep_scan = threshold_sweep(suite, thresholds=(5,), driver="scan")
    sweep_fleet = threshold_sweep(suite, thresholds=(5,), driver="fleet")
    assert sweep_scan[5] == sweep_fleet[5]


def test_fleet_recordings_are_scenario_diverse():
    recs = make_fleet_recordings(4, seed0=3, duration_s=0.25)
    assert len(recs) == 4
    assert len({r.name.split("-", 1)[1] for r in recs}) == 4  # distinct families
    for r in recs:
        assert np.all(np.diff(r.t) >= 0)


def test_composed_scenario():
    # Stressors compose in one sky: tumbling + hot columns + jitter.
    sc = Scenario(
        name="kitchen-sink",
        rsos=(RSOSpec(tumble_hz=4.0),),
        hot_columns=1,
        jitter_px=1.5,
        duration_s=0.3,
    )
    rec = make_scenario(sc, seed=2)
    score = score_threshold(collect_candidates(rec), 5)
    assert score.tp + score.fn > 0
