"""``tracking._greedy_assign`` edge cases pinned against a numpy oracle:
cost ties, all-gated rows, and MAX_TRACKS saturation (more confirmed
clusters than tracker slots)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

import jax.numpy as jnp

from repro.core.grid_clustering import Clusters
from repro.core.tracking import (
    MAX_TRACKS,
    TrackerConfig,
    _greedy_assign,
    init_tracks,
    tracker_step,
)


def _greedy_assign_np(cost: np.ndarray, gate: float) -> np.ndarray:
    """Reference with the scan's exact semantics: rows in track order,
    ``argmin`` breaking ties toward the lowest detection index, each
    detection used at most once, unassigned rows -1."""
    t, k = cost.shape
    assigned = np.zeros(k, bool)
    out = np.full(t, -1, np.int32)
    for ti in range(t):
        row = np.where(assigned, np.inf, cost[ti])
        j = int(np.argmin(row))
        if row[j] <= gate:
            assigned[j] = True
            out[ti] = j
    return out


def _assert_matches_oracle(cost: np.ndarray, gate: float):
    got = np.asarray(_greedy_assign(jnp.asarray(cost, jnp.float32), gate))
    np.testing.assert_array_equal(got, _greedy_assign_np(cost, gate))
    # Structural invariants, independent of the oracle.
    used = got[got >= 0]
    assert len(np.unique(used)) == len(used)  # each detection at most once
    for ti, j in enumerate(got):
        if j >= 0:
            assert cost[ti, j] <= gate


def test_exact_cost_ties_break_toward_lowest_detection_index():
    # Both tracks see identical costs on detections 1 and 2: track 0 must
    # take detection 1 (lowest index among the minima), track 1 then takes
    # detection 2 (its minimum is consumed).
    cost = np.array([
        [9.0, 2.0, 2.0, 8.0],
        [9.0, 2.0, 2.0, 8.0],
    ])
    got = np.asarray(_greedy_assign(jnp.asarray(cost, jnp.float32), 10.0))
    np.testing.assert_array_equal(got, [1, 2])
    _assert_matches_oracle(cost, 10.0)


def test_tied_rows_compete_in_track_order():
    # One shared best detection: the lower-index track wins it; the loser
    # falls back to its next-best — taken when inside the gate, -1 when out.
    cost = np.array([
        [1.0, 5.0],
        [1.0, 3.0],
    ])
    got = np.asarray(_greedy_assign(jnp.asarray(cost, jnp.float32), 4.0))
    np.testing.assert_array_equal(got, [0, 1])  # 3.0 <= gate: fallback taken
    _assert_matches_oracle(cost, 4.0)
    cost2 = np.array([
        [1.0, 5.0],
        [1.0, 5.0],
    ])
    got2 = np.asarray(_greedy_assign(jnp.asarray(cost2, jnp.float32), 4.0))
    np.testing.assert_array_equal(got2, [0, -1])  # 5.0 > gate: loser unmatched
    _assert_matches_oracle(cost2, 4.0)


def test_all_gated_rows_get_minus_one():
    cost = np.full((3, 2), 100.0)
    got = np.asarray(_greedy_assign(jnp.asarray(cost, jnp.float32), 24.0))
    np.testing.assert_array_equal(got, [-1, -1, -1])
    _assert_matches_oracle(cost, 24.0)


def test_all_inf_rows_inactive_tracks_never_assign():
    # tracker_step masks inactive tracks / invalid detections to inf;
    # an all-inf row must come out -1, not detection 0.
    cost = np.full((2, 3), np.inf)
    cost[1, 1] = 3.0
    got = np.asarray(_greedy_assign(jnp.asarray(cost, jnp.float32), 24.0))
    np.testing.assert_array_equal(got, [-1, 1])
    _assert_matches_oracle(cost, 24.0)


def test_exactly_at_gate_is_assigned():
    cost = np.array([[24.0]])
    got = np.asarray(_greedy_assign(jnp.asarray(cost, jnp.float32), 24.0))
    np.testing.assert_array_equal(got, [0])  # gate is inclusive
    _assert_matches_oracle(cost, 24.0)


def test_more_tracks_than_detections_and_vice_versa():
    _assert_matches_oracle(np.array([[1.0], [2.0], [0.5]]), 10.0)  # T > K
    _assert_matches_oracle(np.array([[3.0, 1.0, 2.0, 0.1]]), 10.0)  # K > T


@settings(max_examples=30, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_greedy_assign_matches_numpy_oracle_randomized(seed):
    rng = np.random.default_rng(seed)
    t = int(rng.integers(1, MAX_TRACKS + 1))
    k = int(rng.integers(1, 33))
    # Quantized costs force frequent exact ties; scatter some infs in.
    cost = rng.integers(0, 6, size=(t, k)).astype(np.float64)
    cost[rng.random((t, k)) < 0.2] = np.inf
    _assert_matches_oracle(cost, gate=3.0)


def _clusters_at(xs: np.ndarray, ys: np.ndarray, k: int) -> Clusters:
    n = len(xs)
    pad = k - n
    f = lambda a: jnp.asarray(np.pad(np.asarray(a, np.float32), (0, pad)))
    i = lambda a: jnp.asarray(np.pad(np.asarray(a, np.int32), (0, pad)))
    valid = jnp.asarray(np.pad(np.ones(n, bool), (0, pad)))
    zero = np.zeros(n)
    return Clusters(
        centroid_x=f(xs), centroid_y=f(ys), centroid_t=f(zero),
        count=i(np.full(n, 9)), cell_x=i(zero), cell_y=i(zero), valid=valid,
    )


def test_max_tracks_saturation_spawns_lowest_index_detections():
    """More confirmed clusters than tracker slots: every slot fills, the
    overflow detections are dropped, and the spawned slots take the
    detections in index order (rank-pairing is deterministic)."""
    config = TrackerConfig()
    k = MAX_TRACKS + 8  # 24 detections into 16 slots
    xs = 30.0 + 25.0 * np.arange(k)  # > gate apart: no cross-association
    ys = np.full(k, 50.0)
    clusters = _clusters_at(xs, ys, k)
    entropy = jnp.zeros((k,), jnp.float32)
    state, assign = tracker_step(init_tracks(config), clusters, entropy, config)
    assert int(state.active.sum()) == MAX_TRACKS  # saturated, not overflowed
    np.testing.assert_array_equal(np.asarray(assign), np.full(MAX_TRACKS, -1))
    # Slots take detections 0..MAX_TRACKS-1 in order; the rest are dropped.
    np.testing.assert_array_equal(
        np.asarray(state.x), xs[:MAX_TRACKS].astype(np.float32)
    )
    np.testing.assert_array_equal(np.asarray(state.hits), np.ones(MAX_TRACKS))

    # A second window at the same spots: every slot associates (all slots
    # busy), and the 8 unclaimed detections still cannot spawn.
    state2, assign2 = tracker_step(state, clusters, entropy, config)
    assert int(state2.active.sum()) == MAX_TRACKS
    np.testing.assert_array_equal(np.asarray(assign2), np.arange(MAX_TRACKS))
    np.testing.assert_array_equal(np.asarray(state2.hits), np.full(MAX_TRACKS, 2))


def test_saturated_tracker_frees_slot_on_miss_then_respawns():
    config = TrackerConfig(max_misses=0)  # one miss kills a track
    k = MAX_TRACKS
    xs = 30.0 + 25.0 * np.arange(k)
    ys = np.full(k, 50.0)
    entropy = jnp.zeros((k,), jnp.float32)
    state, _ = tracker_step(
        init_tracks(config), _clusters_at(xs, ys, k), entropy, config
    )
    assert int(state.active.sum()) == MAX_TRACKS
    # Next window: detection 0 vanishes -> slot 0 misses once and dies,
    # and a brand-new detection far away claims the freed slot.
    xs2 = np.concatenate([xs[1:], [600.0]])
    ys2 = np.full(k, 50.0)
    state2, _ = tracker_step(state, _clusters_at(xs2, ys2, k), entropy, config)
    assert int(state2.active.sum()) == MAX_TRACKS
    assert float(state2.x[0]) == pytest.approx(600.0)  # respawned slot
    assert int(state2.hits[0]) == 1
