"""Pipelined ingest (DESIGN.md Sec. 14): async rounds are bit-identical
to the synchronous path, at the fleet layer and through the service.

The async round API must be *invisible* in the outputs: ``feed_async``
at any pipeline depth, with staging-buffer reuse, hot-row host gathers,
mid-flight quarantine, and deferral backpressure, returns exactly what
the synchronous ``feed`` path returns for the same chunks. These tests
pin that, plus the exactness of the new backpressure accounting and the
one-compile-per-tier discipline in pipelined mode.
"""
import numpy as np
import pytest

from test_serve_service import FakeClock, _service_recordings, _spaced_stream

from repro.core.events import pack_bounds, pack_bounds_into
from repro.core.pipeline import (
    FleetPipeline,
    PendingRound,
    PipelineConfig,
    StreamingPipeline,
)
from repro.core.pipeline.config import BatcherConfig
from repro.data.evas import iter_chunks
from repro.serve import AdmissionConfig, DetectionService
from repro.serve.chaos import compare_outputs, concat_outputs
from repro.serve.faults import FaultConfig


def _fleet_rounds(seed: int, n_sensors: int, n_rounds: int, chunk: int = 250):
    """Per-round chunk lists for a fleet: ``rounds[r][s]`` is sensor s's
    (x, y, t, p) chunk for round r."""
    streams = [
        _spaced_stream(seed=seed + s, n=n_rounds * chunk)
        for s in range(n_sensors)
    ]
    return [
        [tuple(a[r * chunk:(r + 1) * chunk] for a in s) for s in streams]
        for r in range(n_rounds)
    ]


def _sensor_parts(results, n_sensors: int):
    """Split fleet results into per-sensor ScanResult part lists."""
    return {
        s: [res.sensor(s) for res in results] for s in range(n_sensors)
    }


def _assert_fleet_runs_equal(results_a, results_b, n_sensors: int, label: str):
    pa = _sensor_parts(results_a, n_sensors)
    pb = _sensor_parts(results_b, n_sensors)
    for s in range(n_sensors):
        bad = compare_outputs(
            concat_outputs(pa[s]), concat_outputs(pb[s]), f"{label}/sensor{s}"
        )
        assert not bad, bad


# ---------------------------------------------------------------------------
# Fleet layer: feed_async vs feed.
# ---------------------------------------------------------------------------

def test_feed_async_bitwise_equals_feed():
    """Six rounds dispatched without ever synchronizing (all PendingRound
    handles held past the staging depth, so every staging set is reused
    while its earlier rounds are still unconsumed), materialized newest
    first, equal the synchronous path bitwise."""
    config = PipelineConfig()
    n_sensors, rounds = 3, _fleet_rounds(seed=70, n_sensors=3, n_rounds=6)

    fp_sync = FleetPipeline(config, n_sensors=n_sensors)
    sync_results = [fp_sync.feed(r) for r in rounds] + [fp_sync.flush()]

    fp_async = FleetPipeline(config, n_sensors=n_sensors, staging_depth=2)
    pending = [fp_async.feed_async(r) for r in rounds]
    pending.append(fp_async.feed_async([None] * n_sensors, final=True))
    # Materialize in reverse dispatch order: if staging reuse or the
    # bookkeeping rows aliased live buffers, the oldest rounds would be
    # the corrupted ones.
    for pr in reversed(pending):
        pr.wait()
    async_results = [pr.result() for pr in pending]

    _assert_fleet_runs_equal(sync_results, async_results, n_sensors, "async")


def test_pending_round_api():
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=2)
    rounds = _fleet_rounds(seed=90, n_sensors=2, n_rounds=1)
    pr = fp.feed_async(rounds[0])
    assert isinstance(pr, PendingRound)
    # Host-side bookkeeping never blocks: window counts are computed at
    # dispatch from the cursor walk, not from device outputs.
    assert pr.n_windows.shape == (2,)
    assert pr.total_windows == int(pr.n_windows.sum()) > 0
    res = pr.wait()
    assert pr.ready()
    assert pr.result() is res
    assert res.sensor(0).num_windows == int(pr.n_windows[0])


def test_feed_async_validation_raises_at_dispatch():
    """A bad chunk raises at the feed_async call (not at materialization)
    and leaves the fleet re-feedable — same contract as feed."""
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=2)
    rounds = _fleet_rounds(seed=95, n_sensors=2, n_rounds=2)
    x, y, t, p = rounds[0][0]
    with pytest.raises(ValueError):
        fp.feed_async([(x, y, t[::-1].copy(), p), rounds[0][1]])
    # Untouched: the same chunks feed fine afterwards and match a clean run.
    got = [fp.feed_async(r).wait() for r in rounds] + [fp.flush()]
    ref_fp = FleetPipeline(config, n_sensors=2)
    want = [ref_fp.feed(r) for r in rounds] + [ref_fp.flush()]
    _assert_fleet_runs_equal(want, got, 2, "post-raise")


def test_interleaved_sync_async_rounds():
    """feed / feed_async interleave freely on one pipeline (the sync path
    is just an awaited round)."""
    config = PipelineConfig()
    n_sensors, rounds = 2, _fleet_rounds(seed=80, n_sensors=2, n_rounds=4)
    fp_ref = FleetPipeline(config, n_sensors=n_sensors)
    want = [fp_ref.feed(r) for r in rounds] + [fp_ref.flush()]

    fp = FleetPipeline(config, n_sensors=n_sensors)
    got = [
        fp.feed(rounds[0]),
        fp.feed_async(rounds[1]).wait(),
        fp.feed_async(rounds[2]).result(),  # never explicitly awaited
        fp.feed(rounds[3]),
        fp.flush(),
    ]
    _assert_fleet_runs_equal(want, got, n_sensors, "interleaved")


def test_hot_row_gather_matches_dedicated_stream():
    """A sparse pool (1 active slot of 8) takes the hot-row gather path in
    _host_view and still returns the dedicated-pipeline outputs bitwise."""
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=8)
    sp = StreamingPipeline(config)
    x, y, t, p = _spaced_stream(seed=77, n=500)
    chunks = [None] * 8
    chunks[5] = (x, y, t, p)
    res = fp.feed(chunks)
    want = sp.feed(x, y, t, p)
    got = res.sensor(5)
    assert res._hot_rows == {5: 0}  # gather path taken, slot remapped
    bad = compare_outputs(
        concat_outputs([got]), concat_outputs([want]), "hot-gather"
    )
    assert not bad, bad
    # Idle slots still answer (empty results), through the remap default.
    assert res.sensor(0).num_windows == 0


def test_pack_bounds_into_out_matches_positional():
    x, y, t, p = _spaced_stream(seed=99, n=700)
    bounds = [(0, 250, int(t[0])), (250, 500, int(t[250])), (500, 700, int(t[500]))]
    cap = 256
    we = pack_bounds(x, y, t, p, bounds, cap)
    planes = tuple(np.zeros((4, cap), np.int32) for _ in range(4))
    bv = np.zeros((4, cap), bool)
    starts, stops, t_start, overflow = pack_bounds_into(
        x, y, t, p, bounds, out=planes + (bv,)
    )
    for got, want in zip(planes, (we.batch.x, we.batch.y, we.batch.t, we.batch.p)):
        np.testing.assert_array_equal(got[:3], np.asarray(want))
    np.testing.assert_array_equal(bv[:3], np.asarray(we.batch.valid))
    np.testing.assert_array_equal(starts, we.starts)
    np.testing.assert_array_equal(stops, we.stops)
    np.testing.assert_array_equal(t_start, we.t_start_us)
    np.testing.assert_array_equal(overflow, we.overflow)
    with pytest.raises(TypeError):
        pack_bounds_into(x, y, t, p, bounds)  # planes required
    with pytest.raises(TypeError):
        pack_bounds_into(
            x, y, t, p, bounds, *(planes + (bv,)), out=planes + (bv,)
        )


# ---------------------------------------------------------------------------
# Service layer: depth-N vs depth-1 bit-identity under churn.
# ---------------------------------------------------------------------------

def _drive_service(depth: int, seed: int):
    """One seeded churn/chunking schedule through a service at the given
    pipeline depth; returns {session key: concatenated output surfaces}.

    Every schedule decision draws only from the seeded rng and counters
    that evolve identically across depths (never from round outputs), so
    two depths replay byte-identical feed sequences.
    """
    rng = np.random.default_rng(seed)
    recs = _service_recordings()
    config = PipelineConfig()
    clock = FakeClock()
    svc = DetectionService(
        config, tiers=(2, 4),
        admission=AdmissionConfig(max_delay_s=0.02, max_items=600),
        clock=clock, max_inflight_rounds=depth,
    )
    live: dict[int, dict] = {}   # sid -> {rec index, cursor}
    parts: dict[int, list] = {}
    keys: dict[int, tuple] = {}  # sid -> replay-stable identity
    spawned = 0

    def collect(served):
        for fd in served:
            parts[fd.sid].append(fd.result)

    for _ in range(40):
        clock.now += 0.01
        if live and rng.random() < 0.15:           # churn: detach one
            sid = list(live)[int(rng.integers(len(live)))]
            parts[sid].append(svc.detach(sid))
            del live[sid]
        if len(live) < 4 and rng.random() < 0.5:   # churn: attach one
            sid = svc.attach()
            live[sid] = {"rec": spawned % len(recs), "pos": 0}
            keys[sid] = (spawned,)
            parts[sid] = []
            spawned += 1
        for sid, st in live.items():               # randomized chunking
            rec = recs[st["rec"]]
            n = int(rng.integers(0, 400))
            lo, hi = st["pos"], min(st["pos"] + n, len(rec.t))
            if hi > lo:
                collect(svc.feed(
                    sid, rec.x[lo:hi], rec.y[lo:hi], rec.t[lo:hi], rec.p[lo:hi]
                ))
                st["pos"] = hi
        if rng.random() < 0.3:
            collect(svc.pump(force=True))
        else:
            collect(svc.pump())
    for sid in list(live):
        parts[sid].append(svc.detach(sid))
    svc.drain()
    assert svc.inflight_rounds == 0
    return {keys[sid]: concat_outputs(p) for sid, p in parts.items()}


@pytest.mark.parametrize("seed", [0, 7])
def test_service_depth_bit_identity_randomized_churn(seed):
    """The same randomized churn + chunking schedule, replayed at depth 1
    (synchronous) and depth 3 (pipelined), is bitwise identical session
    by session."""
    ref = _drive_service(depth=1, seed=seed)
    got = _drive_service(depth=3, seed=seed)
    assert got.keys() == ref.keys()
    for key in ref:
        bad = compare_outputs(got[key], ref[key], f"session{key}")
        assert not bad, bad


def test_quarantine_with_rounds_in_flight():
    """A validation fault that quarantines its session while dispatched
    rounds are still executing neither corrupts the pending rounds nor
    perturbs the healthy session, whose outputs stay bit-identical to a
    fault-free reference."""
    config = PipelineConfig()
    rec = _service_recordings()[0]
    bad_stream = _spaced_stream(seed=60, n=2000)

    def run(with_fault: bool):
        clock = FakeClock()
        svc = DetectionService(
            config, tiers=(2,),
            admission=AdmissionConfig(max_delay_s=1e9, max_items=250),
            faults=FaultConfig(on_validation_error="quarantine"),
            clock=clock, max_inflight_rounds=3,
        )
        healthy = svc.attach("healthy")
        bad = svc.attach("bad")
        parts = {healthy: [], bad: []}

        def collect(served):
            for fd in served:
                parts[fd.sid].append(fd.result)

        pos = 0
        for r in range(8):
            clock.now += 0.01
            lo, hi = pos, min(pos + 300, len(rec.t))
            collect(svc.feed(
                healthy, rec.x[lo:hi], rec.y[lo:hi], rec.t[lo:hi], rec.p[lo:hi]
            ))
            pos = hi
            bx, by, bt, bp = (a[r * 200:(r + 1) * 200] for a in bad_stream)
            if with_fault and r == 4:
                assert svc.inflight_rounds >= 1  # fault lands mid-flight
                collect(svc.feed(bad, bx, by, bt[::-1].copy(), bp))
                assert svc.session(bad).state == "quarantined"
            elif svc.session(bad).state == "live":
                collect(svc.feed(bad, bx, by, bt, bp))
        parts[healthy].append(svc.detach(healthy))
        svc.drain()
        return concat_outputs(parts[healthy])

    bad = compare_outputs(run(True), run(False), "healthy")
    assert not bad, bad


def test_deferred_round_accounting_exact(monkeypatch):
    """With the pipeline artificially held full (PendingRound.ready
    forced False), admission-triggered rounds defer: counters increment
    exactly, queues stay intact, offered == events + shed stays exact,
    and force/drain still make progress by applying backpressure."""
    config = PipelineConfig()
    clock = FakeClock()
    svc = DetectionService(
        config, tiers=(2,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=100),
        clock=clock, max_inflight_rounds=2,
    )
    sid = svc.attach()
    x, y, t, p = _spaced_stream(seed=55, n=1000)

    def feed_slice(i):
        lo = i * 100
        return svc.feed(sid, x[lo:lo + 100], y[lo:lo + 100],
                        t[lo:lo + 100], p[lo:lo + 100])

    feed_slice(0)  # round 1 dispatched
    feed_slice(1)  # round 2 dispatched: pipeline now full
    assert svc.inflight_rounds == 2 and svc.deferred_rounds == 0

    monkeypatch.setattr(PendingRound, "ready", lambda self: False)
    feed_slice(2)  # admission fires, pipeline "full" -> deferred
    feed_slice(3)  # deferred again
    sess = svc.session(sid)
    assert svc.deferred_rounds == 2
    assert sess.stats.deferred_rounds == 2
    assert sess.queued_events == 200          # queue untouched by deferral
    assert svc.inflight_rounds == 2           # nothing dispatched
    st = sess.stats
    assert st.offered_events == st.events + st.shed_events == 400

    monkeypatch.undo()
    done = svc.pump()  # oldest round is actually ready -> dispatches now
    assert svc.deferred_rounds == 2           # no new deferrals
    assert sess.queued_events == 0
    svc.drain()
    assert svc.inflight_rounds == 0
    st = sess.stats
    assert st.offered_events == st.events + st.shed_events == 400
    assert st.steps == 3 and st.shed_events == 0


def test_force_pump_applies_backpressure_not_deferral(monkeypatch):
    """pump(force=True) never defers: it retires the oldest round (real
    backpressure) and dispatches."""
    config = PipelineConfig()
    clock = FakeClock()
    svc = DetectionService(
        config, tiers=(2,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=100),
        clock=clock, max_inflight_rounds=2,
    )
    sid = svc.attach()
    x, y, t, p = _spaced_stream(seed=56, n=600)
    for i in range(2):
        svc.feed(sid, x[i * 100:(i + 1) * 100], y[i * 100:(i + 1) * 100],
                 t[i * 100:(i + 1) * 100], p[i * 100:(i + 1) * 100])
    assert svc.inflight_rounds == 2
    monkeypatch.setattr(PendingRound, "ready", lambda self: False)
    # Queue more data, then force: dispatch must happen despite ready()
    # lying, because force retires (blocks on) the oldest round.
    svc.feed(sid, x[200:300], y[200:300], t[200:300], p[200:300])
    svc.pump(force=True)
    assert svc.session(sid).queued_events == 0
    assert svc.deferred_rounds == 1  # only the non-forced feed deferred
    monkeypatch.undo()
    svc.drain()


def test_pipelined_churn_compiles_one_fleet_step_per_tier():
    """The compile-discipline contract survives pipelining: a churn
    workload at depth 3 traces exactly one fleet step per capacity tier
    (staging buffers and pending rounds never enter compiled shapes)."""
    from repro.core.pipeline import fleet as fleet_mod

    # A config no other test jits (capacity 192), so every compile in
    # this workload shows up in STEP_TRACES.
    config = PipelineConfig(
        batcher=BatcherConfig(size_threshold=100, capacity=192)
    )
    svc = DetectionService(
        config, tiers=(2, 4),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        clock=FakeClock(), max_inflight_rounds=3,
    )
    streams = {}

    def feed_round(sids):
        for sid in sids:
            x, y, t, p = streams[sid]["data"]
            pos = streams[sid]["pos"]
            svc.feed(sid, x[pos:pos + 100], y[pos:pos + 100],
                     t[pos:pos + 100], p[pos:pos + 100])
            streams[sid]["pos"] = pos + 100
        svc.pump(force=True)

    def attach():
        sid = svc.attach()
        streams[sid] = {"data": _spaced_stream(seed=30 + sid, n=2000), "pos": 0}
        return sid

    fleet_mod.STEP_TRACES.clear()
    live = []
    for target in (1, 2, 3, 4):
        while len(live) < target:
            live.append(attach())
        feed_round(live)
    while live:
        svc.detach(live.pop())
    live = [attach(), attach()]
    feed_round(live)
    svc.drain()

    traces = [tr for tr in fleet_mod.STEP_TRACES if tr[2] == 192]
    per_tier = {}
    for s, *_ in traces:
        per_tier[s] = per_tier.get(s, 0) + 1
    assert per_tier == {2: 1, 4: 1}, traces


def test_served_feed_is_lazy():
    """ServedFeed defers materialization: num_windows answers from host
    bookkeeping, result synchronizes once and caches."""
    config = PipelineConfig()
    clock = FakeClock()
    svc = DetectionService(
        config, tiers=(2,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=250),
        clock=clock, max_inflight_rounds=2,
    )
    sid = svc.attach()
    x, y, t, p = _spaced_stream(seed=57, n=250)
    done = svc.feed(sid, x, y, t, p)
    assert len(done) == 1
    fd = done[0]
    assert fd._result is None          # nothing materialized yet
    assert fd.num_windows == 1         # host-side count, still lazy
    assert fd._result is None
    res = fd.result
    assert fd.result is res            # cached
    assert res.num_windows == 1
    svc.drain()
