"""Grid clustering, event conditioning, and baseline algorithms."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.core.baselines import dbscan, dbscan_centroids, kmeans
from repro.core.events import (
    BatcherConfig,
    batch_from_arrays,
    dual_threshold_batches,
    pack_words,
    persistent_event_filter,
    roi_filter,
    unpack_words,
)
from repro.core.grid_clustering import (
    GridConfig,
    form_clusters,
    grid_cluster,
    merge_adjacent,
    quantize,
    quantize_packed,
)

RNG = np.random.default_rng(7)


def _batch(xy, capacity=256):
    xy = np.asarray(xy)
    n = len(xy)
    return batch_from_arrays(
        xy[:, 0], xy[:, 1], np.arange(n), np.zeros(n, np.int32), capacity
    )


# ---------------------------------------------------------------------------
# packing / quantization
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.integers(0, 65535), st.integers(0, 65535))
def test_pack_unpack_roundtrip(x, y):
    w = pack_words(jnp.asarray([x]), jnp.asarray([y]))
    xx, yy = unpack_words(w)
    assert int(xx[0]) == x and int(yy[0]) == y


def test_quantize_matches_division():
    x = jnp.asarray(RNG.integers(0, 640, 500), jnp.int32)
    y = jnp.asarray(RNG.integers(0, 480, 500), jnp.int32)
    for cs in (16, 10, 32):
        cx, cy = quantize(x, y, cs)
        np.testing.assert_array_equal(np.asarray(cx), np.asarray(x) // cs)
        np.testing.assert_array_equal(np.asarray(cy), np.asarray(y) // cs)


def test_quantize_packed_wire_identity():
    x = RNG.integers(0, 640, 100)
    y = RNG.integers(0, 480, 100)
    w = pack_words(jnp.asarray(x), jnp.asarray(y))
    out = quantize_packed(w, 16)
    cx, cy = unpack_words(out)
    np.testing.assert_array_equal(np.asarray(cx), x // 16)
    np.testing.assert_array_equal(np.asarray(cy), y // 16)


# ---------------------------------------------------------------------------
# clustering
# ---------------------------------------------------------------------------

def test_cluster_single_blob():
    pts = RNG.normal(0, 2.0, (40, 2)) + np.array([200, 100])
    clusters = grid_cluster(_batch(pts.astype(int)))
    assert int(clusters.num_valid()) >= 1
    k = int(np.argmax(np.asarray(clusters.count)))
    assert abs(float(clusters.centroid_x[k]) - 200) < 16
    assert abs(float(clusters.centroid_y[k]) - 100) < 16


def test_min_events_threshold():
    # 3 events in one cell, 7 in another: only the 7 survives min_events=5.
    pts = [[5, 5]] * 3 + [[100, 100]] * 7
    clusters = grid_cluster(_batch(pts), GridConfig(min_events=5))
    assert int(clusters.num_valid()) == 1
    assert int(np.asarray(clusters.count).max()) == 7


def test_centroid_within_cell():
    pts = [[37, 53]] * 6
    clusters = grid_cluster(_batch(pts))
    k = int(np.argmax(np.asarray(clusters.count)))
    assert float(clusters.centroid_x[k]) == pytest.approx(37.0)
    assert float(clusters.centroid_y[k]) == pytest.approx(53.0)
    assert int(clusters.cell_x[k]) == 37 // 16
    assert int(clusters.cell_y[k]) == 53 // 16


@settings(max_examples=25, deadline=None)
@given(
    st.lists(
        st.tuples(st.integers(0, 639), st.integers(0, 479)),
        min_size=1, max_size=200,
    )
)
def test_cluster_count_conservation(points):
    """Sum of per-cell counts equals number of valid events (O(n) single
    pass loses nothing)."""
    clusters = form_clusters(_batch(points), GridConfig(min_events=1, max_clusters=1200))
    # every event lands in exactly one cell
    assert int(np.asarray(clusters.count).sum()) == len(points)


def test_merge_adjacent_combines_straddling_object():
    # Object straddles the x=16 cell boundary.
    pts = [[14, 8]] * 5 + [[18, 8]] * 4
    cfg = GridConfig(min_events=4)
    clusters = form_clusters(_batch(pts), cfg)
    assert int(clusters.num_valid()) == 2
    merged = merge_adjacent(clusters, cfg)
    assert int(merged.num_valid()) == 1
    k = int(np.argmax(np.asarray(merged.count)))
    assert int(merged.count[k]) == 9
    expect_x = (14 * 5 + 18 * 4) / 9
    assert float(merged.centroid_x[k]) == pytest.approx(expect_x, abs=0.01)


# ---------------------------------------------------------------------------
# conditioning
# ---------------------------------------------------------------------------

def test_roi_filter():
    b = _batch([[10, 10], [300, 200], [600, 430]])
    out = roi_filter(b)  # default ROI [20,20,580,420]
    assert np.asarray(out.valid)[:3].tolist() == [False, True, False]


def test_persistent_event_filter_drops_hot_pixel():
    pts = [[50, 50]] * 20 + [[100, 100]] * 3
    out = persistent_event_filter(_batch(pts), max_repeats=8)
    v = np.asarray(out.valid)
    assert not v[:20].any()
    assert v[20:23].all()


def test_dual_threshold_batcher_size_and_time():
    # 1000 events in 1 us steps -> size threshold (250) fires first.
    t = np.arange(1000)
    x = np.zeros(1000, np.int32)
    batches = list(dual_threshold_batches(x, x, t, x))
    assert all(int(b.count()) <= 250 for b, _ in batches)
    assert int(batches[0][0].count()) == 250
    # 100 events spread over 100 ms -> time threshold (20 ms) fires first.
    t = np.arange(0, 100_000, 1000)
    x = np.zeros(100, np.int32)
    batches = list(dual_threshold_batches(x, x, t, x))
    for b, sl in batches:
        tt = t[sl]
        assert tt[-1] - tt[0] < 20_000


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 500_000), min_size=1, max_size=400))
def test_batcher_covers_stream_once(times):
    """Every event lands in exactly one batch, in order."""
    t = np.sort(np.asarray(times, np.int64))
    n = len(t)
    x = np.zeros(n, np.int32)
    cfg = BatcherConfig()
    seen = []
    for b, sl in dual_threshold_batches(x, x, t, x, cfg):
        seen.extend(range(sl.start, sl.stop))
        assert int(b.count()) == min(sl.stop - sl.start, cfg.capacity)
    assert seen == list(range(n))


# ---------------------------------------------------------------------------
# baselines (paper Table I)
# ---------------------------------------------------------------------------

def _three_blobs(n_per=20):
    blobs = [(100, 100), (300, 200), (500, 400)]
    pts = np.concatenate(
        [RNG.normal(0, 2, (n_per, 2)) + np.array(c) for c in blobs]
    )
    return pts.astype(int), blobs


def test_kmeans_recovers_blobs():
    pts, blobs = _three_blobs()
    res = kmeans(_batch(pts), k=3, iters=20)
    cents = np.asarray(res.centroids)
    for bx, by in blobs:
        d = np.hypot(cents[:, 0] - bx, cents[:, 1] - by).min()
        assert d < 10, (cents, blobs)


def test_dbscan_recovers_blobs_and_noise():
    pts, blobs = _three_blobs()
    noise = np.array([[50, 400], [600, 50]])
    allpts = np.concatenate([pts, noise])
    res = dbscan(_batch(allpts, capacity=128), eps=8.0, min_pts=5)
    labels = np.asarray(res.labels)[: len(allpts)]
    assert int(res.n_clusters) == 3
    # noise points unlabeled
    assert (labels[-2:] == -1).all()
    cents, counts = dbscan_centroids(_batch(allpts, capacity=128), res)
    cents = np.asarray(cents)
    for bx, by in blobs:
        d = np.hypot(cents[:, 0] - bx, cents[:, 1] - by)
        assert d.min() < 6


def test_grid_agrees_with_dbscan_on_separated_blobs():
    pts, blobs = _three_blobs()
    g = grid_cluster(_batch(pts), GridConfig(min_events=5))
    d = dbscan(_batch(pts, capacity=128), eps=8.0, min_pts=5)
    # same number of objects found (grid may split cell-straddlers; merge)
    merged = merge_adjacent(g, GridConfig(min_events=5))
    assert int(d.n_clusters) == 3
    assert 3 <= int(merged.num_valid()) <= 4
