"""Fixed-point datapath vs float golden model vs fused megakernel (ISSUE 6).

Three-way differential layer over the per-window stage chain:

* float golden vs staged fixed (``numerics="fixed"``): pins the exact
  claims of DESIGN.md Sec. 12 — bit-identical conditioning, cluster
  counts/cells/validity, patch origins, and the shannon/renyi/
  local-contrast/event-count metrics; bounded centroid quantization
  (<= 2**-8 px) and bounded differential-entropy / edge-density shifts;
* staged fixed vs fused Pallas megakernel: bit-identical on EVERY
  surface (cluster fields, all six metrics, tracker state) — the shared
  float epilogue makes this structural, these tests keep it true;
* primitive helpers (round_div_half_even, isqrt) vs exact oracles.

Windows cover randomized clustered scenes plus the adversarial shapes:
empty, single-event, all-same-pixel (hot filter), capacity-saturated,
out-of-bounds coordinates, and ROI-boundary straddlers.
"""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.core import metrics as M
from repro.core.events import batch_from_arrays
from repro.core.fixed_point import (
    CENTROID_ONE,
    fixed_window_stage,
    isqrt,
    make_fixed_process_window,
    round_div_half_even,
)
from repro.core.pipeline import (
    PipelineConfig,
    init_tracks,
    make_process_window,
    run_recording_scan,
)
from repro.data.synthetic import make_recording
from repro.kernels import ops as kops
from repro.kernels import ref as kref

CONFIG = PipelineConfig()
FIXED = dataclasses.replace(CONFIG, numerics="fixed")
MEGA = dataclasses.replace(CONFIG, numerics="fixed", metrics_impl="megakernel")

# Exact-claim metrics (identical integers -> identical float expressions)
# vs bounded-claim metrics (DESIGN.md Sec. 12 bounds).
EXACT_METRICS = ("shannon_entropy", "renyi_entropy", "local_contrast", "event_count")
CENTROID_TOL = 2.0**-8  # UQ10.8 quantization
DIFF_ENTROPY_TOL = 0.05  # integer floor-sqrt first moment (measured ~0.024)
EDGE_DENSITY_TOL = 8.0 / (M.WINDOW * M.WINDOW)  # threshold-straddling pixels


def _random_batch(seed, n=160, capacity=128):
    rng = np.random.default_rng(seed)
    centers = rng.integers(40, 580, (4, 2))
    pick = rng.integers(0, 4, n)
    x = np.clip(centers[pick, 0] + rng.integers(-12, 13, n), 0, 639)
    y = np.clip(centers[pick, 1] % 440 + rng.integers(-12, 13, n), 0, 479)
    t = np.sort(rng.integers(0, 20_000, n))
    batch = batch_from_arrays(x, y, t, rng.integers(0, 2, n), capacity)
    valid = np.asarray(batch.valid) & (rng.random(capacity) > 0.1)
    return batch._replace(valid=jnp.asarray(valid))


def _adversarial_batches(capacity=128):
    """Named edge-shape windows for the differential sweep."""
    rng = np.random.default_rng(0xF1)
    out = {}

    empty = _random_batch(1, capacity=capacity)
    out["empty"] = empty._replace(valid=jnp.zeros_like(empty.valid))

    out["single_event"] = batch_from_arrays(
        np.array([300]), np.array([200]), np.array([5]), np.array([1]), capacity
    )

    # Every event on one pixel: the hot-pixel filter must kill the lot.
    n = 40
    out["all_same_pixel"] = batch_from_arrays(
        np.full(n, 321), np.full(n, 234), np.arange(n), np.zeros(n), capacity
    )

    # Saturated: every slot valid, clustered tight (coincidences > 1).
    x = 100 + rng.integers(0, 25, capacity)
    y = 100 + rng.integers(0, 25, capacity)
    out["capacity_saturated"] = batch_from_arrays(
        x, y, np.sort(rng.integers(0, 9_000, capacity)), np.zeros(capacity), capacity
    )

    # Out-of-bounds coordinates mixed with a real cluster: must be
    # masked, never wrapped onto another cell/patch row.
    x = np.concatenate([640 + rng.integers(0, 50, 30), 200 + rng.integers(0, 10, 50)])
    y = np.concatenate([rng.integers(500, 600, 30), 300 + rng.integers(0, 10, 50)])
    out["out_of_bounds"] = batch_from_arrays(
        x, y, np.arange(80), np.zeros(80), capacity
    )

    # Straddling the ROI edge (x0=20): half the cluster is cut away.
    x = 14 + rng.integers(0, 12, 90)
    y = 200 + rng.integers(0, 12, 90)
    out["roi_boundary"] = batch_from_arrays(
        x, y, np.arange(90), np.zeros(90), capacity
    )
    return out


def _stack(batches):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *batches)


# ---------------------------------------------------------------------------
# Primitive oracles.
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_round_div_half_even_matches_float_round(seed):
    rng = np.random.default_rng(seed)
    num = rng.integers(0, 2**26, 256)
    den = rng.integers(1, 257, 256)
    got = round_div_half_even(
        jnp.asarray(num, jnp.int32), jnp.asarray(den, jnp.int32)
    )
    # Host-side float64 oracle: the quotient is < 2**26 so the division
    # is correctly rounded and .5 boundaries are representable — np.round
    # is exact round-half-even here.
    want = np.round(num.astype(np.float64) / den.astype(np.float64))
    np.testing.assert_array_equal(np.asarray(got), want.astype(np.int64))


def test_round_div_half_even_ties_to_even():
    # Exact .5 boundaries round to the even quotient, like jnp.round.
    num = jnp.asarray([1, 3, 5, 7, 250 * 2 + 1], jnp.int32)
    den = jnp.asarray([2, 2, 2, 2, 2], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(round_div_half_even(num, den)), [0, 2, 2, 4, 250]
    )


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000))
def test_isqrt_matches_math_isqrt(seed):
    rng = np.random.default_rng(seed)
    v = rng.integers(0, 2**26, 256)
    got = np.asarray(isqrt(jnp.asarray(v, jnp.int32)))
    want = np.array([math.isqrt(int(u)) for u in v])
    np.testing.assert_array_equal(got, want)


def test_isqrt_perfect_square_edges():
    v = jnp.asarray([0, 1, 2, 3, 4, 255, 256, 257, 2**26 - 1], jnp.int32)
    want = [math.isqrt(int(u)) for u in np.asarray(v)]
    np.testing.assert_array_equal(np.asarray(isqrt(v)), want)


# ---------------------------------------------------------------------------
# Config validation.
# ---------------------------------------------------------------------------

def test_fixed_config_rejects_float_only_knobs():
    for bad in (
        dataclasses.replace(FIXED, merge_neighbors=True),
        dataclasses.replace(FIXED, use_kernels=True),
        dataclasses.replace(FIXED, metrics_impl="frame"),
        dataclasses.replace(FIXED, metrics_impl="kernel"),
    ):
        with pytest.raises(ValueError):
            make_fixed_process_window(bad)
    with pytest.raises(ValueError):
        make_process_window(dataclasses.replace(CONFIG, numerics="fp8"))


# ---------------------------------------------------------------------------
# Float golden vs staged fixed: the Sec. 12 claims.
# ---------------------------------------------------------------------------

def _assert_fixed_matches_float(batch):
    clusters_f, mets_f = make_process_window(CONFIG)(batch)
    clusters_x, mets_x = make_process_window(FIXED)(batch)

    # Bit-identical cluster structure.
    for field in ("count", "cell_x", "cell_y", "valid"):
        np.testing.assert_array_equal(
            np.asarray(getattr(clusters_x, field)),
            np.asarray(getattr(clusters_f, field)),
            err_msg=field,
        )
    # Centroids: Q10.8 quantization bound (invalid slots share -1.0).
    for field in ("centroid_x", "centroid_y", "centroid_t"):
        np.testing.assert_allclose(
            np.asarray(getattr(clusters_x, field)),
            np.asarray(getattr(clusters_f, field)),
            atol=CENTROID_TOL, rtol=0, err_msg=field,
        )
    # Patch origins: exact integer division == round(float centroid).
    fc, _ = jax.jit(lambda b: fixed_window_stage(FIXED, b))(batch)
    gx0, gy0 = M.window_origin(
        clusters_f.centroid_x, clusters_f.centroid_y,
        CONFIG.grid.width, CONFIG.grid.height, M.WINDOW,
    )
    valid = np.asarray(clusters_f.valid)
    np.testing.assert_array_equal(np.asarray(fc.x0)[valid], np.asarray(gx0)[valid])
    np.testing.assert_array_equal(np.asarray(fc.y0)[valid], np.asarray(gy0)[valid])

    for name in EXACT_METRICS:
        np.testing.assert_array_equal(
            np.asarray(mets_x[name]), np.asarray(mets_f[name]), err_msg=name
        )
    np.testing.assert_allclose(
        np.asarray(mets_x["edge_density"]), np.asarray(mets_f["edge_density"]),
        atol=EDGE_DENSITY_TOL, rtol=0,
    )
    np.testing.assert_allclose(
        np.asarray(mets_x["differential_entropy"]),
        np.asarray(mets_f["differential_entropy"]),
        atol=DIFF_ENTROPY_TOL, rtol=0,
    )


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000))
def test_fixed_matches_float_random_windows(seed):
    _assert_fixed_matches_float(_random_batch(seed))


@pytest.mark.parametrize("name", sorted(_adversarial_batches()))
def test_fixed_matches_float_adversarial(name):
    _assert_fixed_matches_float(_adversarial_batches()[name])


def test_all_same_pixel_yields_no_clusters():
    # The hot-pixel filter must kill a 40-repeat pixel in BOTH numerics.
    batch = _adversarial_batches()["all_same_pixel"]
    for config in (CONFIG, FIXED, MEGA):
        clusters, mets = make_process_window(config)(batch)
        assert not np.asarray(clusters.valid).any(), config.numerics
        assert np.asarray(mets["event_count"]).sum() == 0.0


# ---------------------------------------------------------------------------
# Staged fixed vs fused megakernel: total bit-identity.
# ---------------------------------------------------------------------------

def _assert_mega_matches_staged(stacked):
    fc_k, mets_k = jax.jit(
        lambda s: kops.window_pipeline_call(s, MEGA)
    )(stacked)
    fc_r, mets_r = jax.jit(
        lambda s: kref.window_pipeline_ref(s, FIXED)
    )(stacked)
    for field in fc_k._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(fc_k, field)),
            np.asarray(getattr(fc_r, field)),
            err_msg=field,
        )
    for name in M.METRIC_NAMES:
        got = np.asarray(mets_k[name]).view(np.int32)
        want = np.asarray(mets_r[name]).view(np.int32)
        np.testing.assert_array_equal(got, want, err_msg=name)


def test_megakernel_bit_identical_random_windows():
    _assert_mega_matches_staged(_stack([_random_batch(s) for s in range(3)]))


def test_megakernel_bit_identical_adversarial_windows():
    _assert_mega_matches_staged(_stack(list(_adversarial_batches().values())))


def test_megakernel_process_window_matches_staged():
    batch = _random_batch(11)
    cl_s, mets_s = make_process_window(FIXED)(batch)
    cl_m, mets_m = make_process_window(MEGA)(batch)
    for field in cl_s._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(cl_m, field)), np.asarray(getattr(cl_s, field))
        )
    for name in M.METRIC_NAMES:
        np.testing.assert_array_equal(
            np.asarray(mets_m[name]).view(np.int32),
            np.asarray(mets_s[name]).view(np.int32),
            err_msg=name,
        )


# ---------------------------------------------------------------------------
# Scan drivers: whole-recording differential, tracker included.
# ---------------------------------------------------------------------------

def test_fixed_scan_matches_float_scan_bounds():
    rec = make_recording(seed=3, duration_s=0.3)
    res_f = run_recording_scan(rec, CONFIG)
    res_x = run_recording_scan(rec, FIXED)
    np.testing.assert_array_equal(
        np.asarray(res_x.clusters.valid), np.asarray(res_f.clusters.valid)
    )
    np.testing.assert_array_equal(
        np.asarray(res_x.clusters.count), np.asarray(res_f.clusters.count)
    )
    for name in EXACT_METRICS:
        np.testing.assert_array_equal(
            np.asarray(res_x.metrics[name]), np.asarray(res_f.metrics[name]),
            err_msg=name,
        )
    np.testing.assert_allclose(
        np.asarray(res_x.clusters.centroid_x),
        np.asarray(res_f.clusters.centroid_x),
        atol=CENTROID_TOL, rtol=0,
    )
    np.testing.assert_allclose(
        np.asarray(res_x.metrics["differential_entropy"]),
        np.asarray(res_f.metrics["differential_entropy"]),
        atol=DIFF_ENTROPY_TOL, rtol=0,
    )


def test_mega_scan_bit_identical_to_staged_scan():
    rec = make_recording(seed=3, duration_s=0.2)
    res_s = run_recording_scan(rec, FIXED)
    res_m = run_recording_scan(rec, MEGA)
    for field in res_s.clusters._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(res_m.clusters, field)),
            np.asarray(getattr(res_s.clusters, field)),
            err_msg=field,
        )
    for name in M.METRIC_NAMES:
        np.testing.assert_array_equal(
            np.asarray(res_m.metrics[name]).view(np.int32),
            np.asarray(res_s.metrics[name]).view(np.int32),
            err_msg=name,
        )
    # Tracker consumed identical inputs -> identical final state.
    for leaf_m, leaf_s in zip(
        jax.tree_util.tree_leaves(res_m.final_tracks),
        jax.tree_util.tree_leaves(res_s.final_tracks),
    ):
        np.testing.assert_array_equal(np.asarray(leaf_m), np.asarray(leaf_s))
