"""EVAS interchange format round-trip, deterministic suite ordering,
chunked replay (iter_chunks), + synthetic suite integrity."""
import dataclasses

import numpy as np
import pytest

from repro.data.evas import (
    iter_chunks,
    load_recording,
    load_validation_suite,
    save_recording,
)
from repro.data.synthetic import KIND_RSO, make_recording, make_validation_suite


def test_evas_roundtrip(tmp_path):
    rec = make_recording(seed=5, duration_s=0.3, n_rsos=2)
    f = tmp_path / "rec0.npz"
    save_recording(rec, f)
    back = load_recording(f)
    np.testing.assert_array_equal(rec.x, back.x)
    np.testing.assert_array_equal(rec.t, back.t)
    np.testing.assert_array_equal(rec.kind, back.kind)
    np.testing.assert_allclose(rec.rso_tracks, back.rso_tracks)
    assert back.duration_us == rec.duration_us


def test_load_suite_prefers_files(tmp_path):
    rec = make_recording(seed=1, duration_s=0.2)
    save_recording(rec, tmp_path / "a.npz")
    suite = load_validation_suite(tmp_path)
    assert len(suite) == 1 and len(suite[0]) == len(rec)


def test_load_suite_order_is_name_sorted_not_creation_order(tmp_path):
    """Suite ordering decides sweep-output ordering; it must be the sorted
    file names, independent of directory insertion order (glob reflects
    filesystem order on some platforms)."""
    base = make_recording(seed=2, duration_s=0.2)
    for stem in ("bravo", "alpha", "delta", "charlie"):  # scrambled creation
        save_recording(dataclasses.replace(base, name=stem), tmp_path / f"{stem}.npz")
    suite = load_validation_suite(tmp_path)
    assert [r.name for r in suite] == ["alpha", "bravo", "charlie", "delta"]


# ---------------------------------------------------------------------------
# Chunked replay (iter_chunks): the live-client feed shape.
# ---------------------------------------------------------------------------

def test_iter_chunks_concatenation_reproduces_recording_exactly():
    rec = make_recording(seed=7, duration_s=0.3, n_rsos=1)
    chunks = list(iter_chunks(rec, chunk_us=20_000))
    for field, i in (("x", 0), ("y", 1), ("t", 2), ("p", 3)):
        cat = np.concatenate([c[i] for c in chunks])
        np.testing.assert_array_equal(cat, getattr(rec, field), err_msg=field)


def test_iter_chunks_boundaries_are_event_time_strides():
    rec = make_recording(seed=8, duration_s=0.25)
    chunk_us = 20_000
    t0 = int(rec.t[0])
    chunks = list(iter_chunks(rec, chunk_us=chunk_us))
    for i, (_, _, t, _) in enumerate(chunks):
        lo = t0 + i * chunk_us
        if len(t):
            assert lo <= int(t[0]) and int(t[-1]) < lo + chunk_us, i
    # Strides are anchored at the first event and cover through the last.
    assert len(chunks) == (int(rec.t[-1]) - t0) // chunk_us + 1


def test_iter_chunks_yields_empty_chunks_for_dead_strides():
    # A 50 ms silence inside a stream: the quiet strides still come out
    # (as empty arrays), keeping chunk index aligned with wall time.
    t = np.array([0, 1_000, 70_000, 71_000], np.int64)
    z = np.zeros(4, np.int32)
    rec = make_recording(seed=0, duration_s=0.01)
    rec = dataclasses.replace(
        rec, x=z, y=z, t=t, p=z, kind=z, obj=z, duration_us=71_000
    )
    sizes = [len(c[2]) for c in iter_chunks(rec, chunk_us=20_000)]
    assert sizes == [2, 0, 0, 2]


def test_iter_chunks_rejects_bad_chunk_us():
    rec = make_recording(seed=0, duration_s=0.01)
    with pytest.raises(ValueError, match="chunk_us"):
        next(iter_chunks(rec, chunk_us=0))


def test_iter_chunks_feeds_streaming_pipeline_to_scan_identity():
    # The advertised use: chunked replay into the streaming engine equals
    # the offline scan bit-for-bit.
    from repro.core.pipeline import (
        PipelineConfig,
        StreamingPipeline,
        run_recording_scan,
    )

    rec = make_recording(seed=9, duration_s=0.2, n_rsos=1)
    config = PipelineConfig()
    sp = StreamingPipeline(config)
    parts = [sp.feed_chunk(c) for c in iter_chunks(rec)] + [sp.flush()]
    scan = run_recording_scan(rec, config)
    assert sum(p.num_windows for p in parts) == scan.num_windows
    np.testing.assert_array_equal(
        np.concatenate([np.asarray(p.clusters.count) for p in parts]),
        np.asarray(scan.clusters.count),
    )


def test_synthetic_suite_structure():
    suite = make_validation_suite(n_recordings=2, duration_s=0.3)
    assert len(suite) == 6  # 2 recordings x 3 lens configs
    for rec in suite:
        assert (np.diff(rec.t) >= 0).all()  # time-sorted
        assert (rec.kind == KIND_RSO).sum() > 0
        assert rec.x.min() >= 0 and rec.x.max() < 640
        assert rec.y.min() >= 0 and rec.y.max() < 480


def test_recording_determinism():
    a = make_recording(seed=42, duration_s=0.2)
    b = make_recording(seed=42, duration_s=0.2)
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.t, b.t)
