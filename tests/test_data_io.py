"""EVAS interchange format round-trip + synthetic suite integrity."""
import numpy as np

from repro.data.evas import load_recording, load_validation_suite, save_recording
from repro.data.synthetic import KIND_RSO, make_recording, make_validation_suite


def test_evas_roundtrip(tmp_path):
    rec = make_recording(seed=5, duration_s=0.3, n_rsos=2)
    f = tmp_path / "rec0.npz"
    save_recording(rec, f)
    back = load_recording(f)
    np.testing.assert_array_equal(rec.x, back.x)
    np.testing.assert_array_equal(rec.t, back.t)
    np.testing.assert_array_equal(rec.kind, back.kind)
    np.testing.assert_allclose(rec.rso_tracks, back.rso_tracks)
    assert back.duration_us == rec.duration_us


def test_load_suite_prefers_files(tmp_path):
    rec = make_recording(seed=1, duration_s=0.2)
    save_recording(rec, tmp_path / "a.npz")
    suite = load_validation_suite(tmp_path)
    assert len(suite) == 1 and len(suite[0]) == len(rec)


def test_synthetic_suite_structure():
    suite = make_validation_suite(n_recordings=2, duration_s=0.3)
    assert len(suite) == 6  # 2 recordings x 3 lens configs
    for rec in suite:
        assert (np.diff(rec.t) >= 0).all()  # time-sorted
        assert (rec.kind == KIND_RSO).sum() > 0
        assert rec.x.min() >= 0 and rec.x.max() < 640
        assert rec.y.min() >= 0 and rec.y.max() < 480


def test_recording_determinism():
    a = make_recording(seed=42, duration_s=0.2)
    b = make_recording(seed=42, duration_s=0.2)
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.t, b.t)
