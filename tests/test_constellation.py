"""ConstellationService tests (DESIGN.md Sec. 15).

Placement/rebalance planner behavior, bit-identity of healthy sessions
under randomized multi-shard churn (migrations, rebalances, whole-shard
rescue), the compressed cross-shard exchange's quantization bounds, and
the shard chaos harness — plus the multi-device shard-mesh path in a
subprocess.
"""
import jax
import numpy as np
import pytest

from repro.core.events import BatcherConfig
from repro.core.pipeline import PipelineConfig
from repro.core.pipeline.fleet import FleetPipeline
from repro.core.pipeline.stream import StreamingPipeline
from repro.serve.batcher import AdmissionConfig
from repro.serve.chaos import (
    _FakeClock,
    _FlakyFleet,
    _Stream,
    compare_outputs,
    concat_outputs,
)
from repro.serve.chaos_shards import (
    SHARD_FAULT_TAXONOMY,
    ShardChaosConfig,
    ShardChaosHarness,
)
from repro.serve.constellation import (
    ConstellationService,
    CrossShardExchange,
    partition_devices,
)
from repro.serve.faults import FaultConfig

CONFIG = PipelineConfig(
    batcher=BatcherConfig(time_threshold_us=2_000, size_threshold=40, capacity=64)
)
# Manual pump only: admission never fires on its own, so rounds land
# exactly where the test dispatches them.
MANUAL = AdmissionConfig(max_delay_s=1e9, max_items=1 << 30)


def _make(n_shards=2, **kw):
    kw.setdefault("tiers", (2, 4, 8))
    kw.setdefault("admission", MANUAL)
    kw.setdefault("clock", _FakeClock())
    kw.setdefault("sleep", lambda s: None)
    return ConstellationService(CONFIG, n_shards=n_shards, **kw)


def _drain_all(cs, gids):
    # Forced pumps clear the service queues; the batcher remainder
    # inside each slot carry (also counted by backlog()) only leaves at
    # detach, so loop on queued events, not backlog.
    out = []
    while any(cs.session(g).queued_events for g in gids):
        out += cs.pump(force=True)
    cs.drain()
    return out


def _reference(chunks):
    ref = StreamingPipeline(CONFIG)
    return [ref.feed(*c) for c in chunks] + [ref.flush()]


# ---------------------------------------------------------------------------
# Device partitioning and placement.
# ---------------------------------------------------------------------------


def test_partition_devices():
    # Balanced contiguous split when devices cover the shards.
    assert partition_devices(range(10), 3) == [
        (0, 1, 2, 3),
        (4, 5, 6),
        (7, 8, 9),
    ]
    assert partition_devices(range(4), 4) == [(0,), (1,), (2,), (3,)]
    # Round-robin sharing when shards outnumber devices.
    assert partition_devices(range(2), 5) == [(0,), (1,), (0,), (1,), (0,)]
    with pytest.raises(ValueError, match="n_shards"):
        partition_devices(range(2), 0)
    with pytest.raises(ValueError, match="at least one device"):
        partition_devices([], 2)


def test_attach_routes_least_loaded():
    cs = _make()
    gids = [cs.attach() for _ in range(4)]
    # Alternating placement (ties broken by shard index).
    assert [cs.shard_of(g) for g in gids] == [0, 1, 0, 1]
    assert cs.loads == [2, 2]
    cs.detach(gids[0])
    assert cs.loads == [1, 2]
    # The freed capacity attracts the next attach.
    assert cs.shard_of(cs.attach()) == 0
    assert cs.n_sessions == 4
    assert cs.capacity == sum(sh.service.capacity for sh in cs._shards)


def test_routing_errors():
    cs = _make()
    gid = cs.attach()
    with pytest.raises(KeyError, match="unknown session"):
        cs.feed(999, *_Stream(0).next(8))
    cs.detach(gid)
    with pytest.raises(RuntimeError, match=f"session {gid} is"):
        cs.feed(gid, *_Stream(0).next(8))
    with pytest.raises(RuntimeError, match="live; detach first"):
        cs.forget(cs.attach())
    cs.forget(gid)
    with pytest.raises(KeyError):
        cs.shard_of(gid)
    cs.forget(gid)  # idempotent on unknown/forgotten ids


# ---------------------------------------------------------------------------
# Bit-identity under churn.
# ---------------------------------------------------------------------------


def test_bit_identity_under_randomized_churn():
    """5 sensors over 2 shards, 10 rounds with random migrations and
    rebalance sweeps interleaved: every session's concatenated output is
    bit-identical to a dedicated StreamingPipeline fed the same chunks."""
    rng = np.random.default_rng(3)
    cs = _make()
    gids = [cs.attach() for _ in range(5)]
    streams = {g: _Stream(100 + g) for g in gids}
    fed = {g: [] for g in gids}
    parts = {g: [] for g in gids}

    def collect(served):
        for f in served:
            parts[f.gid].append(f.result)

    for rnd in range(10):
        for g in gids:
            # Ragged but few distinct sizes: chunking still varies per
            # sensor/round without a fresh XLA compile per feed shape.
            chunk = streams[g].next(int(rng.choice([60, 100, 140])))
            fed[g].append(chunk)
            collect(cs.feed(g, *chunk))
        collect(cs.pump(force=True))
        if rng.random() < 0.5:
            g = int(rng.choice(gids))
            cs.migrate(g, 1 - cs.shard_of(g))  # always a real move
        if rng.random() < 0.3:
            cs.rebalance()
    collect(_drain_all(cs, gids))
    assert cs.migrations >= 2  # the schedule actually churned
    for g in gids:
        parts[g].append(cs.detach(g))
        want = _reference(fed[g])
        assert (
            compare_outputs(
                concat_outputs(parts[g]), concat_outputs(want), f"gid {g}"
            )
            == []
        )
    # Exchange saw the rounds and compressed them.
    st = cs.exchange.stats
    assert st["rounds"] > 0 and st["compression_ratio"] > 3.0


def test_explicit_migrate_keeps_gid_and_stats():
    cs = _make()
    g0, g1 = cs.attach(), cs.attach()
    s = _Stream(7)
    cs.feed(g0, *s.next(100))
    cs.pump(force=True)
    events_before = cs.session(g0).stats.events
    assert events_before > 0
    src = cs.shard_of(g0)
    cs.migrate(g0, 1 - src)
    assert cs.shard_of(g0) == 1 - src
    assert cs.migrations == 1
    assert cs.session(g0).stats.events == events_before  # record travels
    cs.migrate(g0, 1 - src)  # same-shard move is a no-op
    assert cs.migrations == 1
    assert cs.loads == [1, 1] or cs.loads == [0, 2]
    stats = cs.stats()
    assert stats["migrations"] == 1 and len(stats["shards"]) == 2
    cs.detach(g0), cs.detach(g1)


def test_rebalance_moves_youngest_to_least_loaded():
    cs = _make(auto_rebalance=False, rebalance_margin=1)
    gids = [cs.attach() for _ in range(6)]
    # Pile everyone onto shard 0.
    for g in gids:
        if cs.shard_of(g) != 0:
            cs.migrate(g, 0)
    assert cs.loads == [6, 0]
    moves = cs.rebalance()
    assert moves == 3 and cs.loads == [3, 3]
    assert cs.rebalances == 1
    assert cs.rebalance() == 0  # already within margin


# ---------------------------------------------------------------------------
# Whole-shard rescue.
# ---------------------------------------------------------------------------


def test_shard_stall_rescue_bit_identity():
    """A whole-shard stall (every fleet dispatch failing) triggers the
    rescue after the configured degraded streak: the shard is marked
    down, its sessions re-migrate and keep streaming bit-identically."""
    cs = _make(
        faults=FaultConfig(degrade_on_step_failure=True, max_step_retries=0),
        rescue_after_degraded_rounds=2,
    )
    gids = [cs.attach() for _ in range(4)]
    streams = {g: _Stream(200 + g) for g in gids}
    fed = {g: [] for g in gids}
    parts = {g: [] for g in gids}

    def feed_round():
        for g in gids:
            chunk = streams[g].next(90)
            fed[g].append(chunk)
            for f in cs.feed(g, *chunk):
                parts[f.gid].append(f.result)
        for f in cs.pump(force=True):
            parts[f.gid].append(f.result)

    feed_round()  # healthy warm-up round
    stalled = _FlakyFleet(cs.shard(0).service._fleet)
    stalled.fail_next = 10**9
    cs.shard(0).service._fleet = stalled
    victims = [g for g in gids if cs.shard_of(g) == 0]
    for _ in range(3):
        feed_round()
    assert cs.rescues == 1 and cs.down_shards == [0]
    assert cs.loads[0] == 0 and cs.loads[1] == 4
    assert all(cs.shard_of(g) == 1 for g in victims)
    assert cs.n_sessions == 4  # moved, not lost
    for _ in range(2):
        feed_round()
    for f in _drain_all(cs, gids):
        parts[f.gid].append(f.result)
    for g in gids:
        parts[g].append(cs.detach(g))
        want = _reference(fed[g])
        assert (
            compare_outputs(
                concat_outputs(parts[g]), concat_outputs(want), f"gid {g}"
            )
            == []
        )
    # Revival re-admits the shard for new placements.
    stalled.fail_next = 0
    cs.revive_shard(0)
    assert cs.shard_of(cs.attach()) == 0


def test_rescue_refuses_when_no_survivor():
    cs = _make()
    assert cs.rescue_shard(1) == 0  # nothing to move; shard 1 downed
    with pytest.raises(RuntimeError, match="no other shard is up"):
        cs.rescue_shard(0)  # would strand any stream with nowhere to go
    cs.shard(0).down = True
    with pytest.raises(RuntimeError, match="every shard is down"):
        cs.attach()
    cs.revive_shard(0)
    assert cs.shard_of(cs.attach()) == 0


# ---------------------------------------------------------------------------
# Compressed cross-shard exchange.
# ---------------------------------------------------------------------------


def _rounds(n_sensors, n_rounds, seed=11):
    """Real PendingRounds from a fleet fed dense enough to close windows."""
    fleet = FleetPipeline(CONFIG, n_sensors=n_sensors, uniform_fast_path=False)
    streams = [_Stream(seed + i, dt_us=60) for i in range(n_sensors)]
    out = []
    for _ in range(n_rounds):
        rnd = fleet.feed_async([s.next(120) for s in streams])
        rnd.wait()
        out.append(rnd)
    return out


def test_exchange_int8_ef_bounds_and_telescoping():
    rounds = _rounds(2, 6)
    ex = CrossShardExchange(1, "int8_ef")
    oracle = CrossShardExchange(1, "exact")
    sum_exact = sum_pub = None
    for rnd in rounds:
        exact = np.asarray(CrossShardExchange.summary_plane(rnd))
        ef_prev = ex.error_feedback(0)
        ef_prev = np.zeros_like(exact) if ef_prev is None else ef_prev
        ex.push_round(0, rnd)
        oracle.push_round(0, rnd)
        # Exact mode is the uncompressed oracle, bit-identical.
        assert np.array_equal(oracle.latest(0), exact)
        deq = ex.latest(0)
        scale = ex.last_scale(0)
        # Per-round bound: symmetric int8 round-to-nearest of the
        # EF-corrected plane never errs by more than half a step.
        assert np.all(np.abs(deq - (exact + ef_prev)) <= scale / 2 + 1e-5)
        sum_exact = exact if sum_exact is None else sum_exact + exact
        sum_pub = deq if sum_pub is None else sum_pub + deq
    # Telescoping: published sums == exact sums - final residual, so a
    # running cross-shard accumulation is exact up to one round's error.
    np.testing.assert_allclose(
        sum_pub, sum_exact - ex.error_feedback(0), rtol=1e-5, atol=1e-3
    )
    assert ex.columns == oracle.columns
    assert ex.columns[:2] == ("windows", "clusters")
    assert ex.stats["compression_ratio"] > 3.0
    assert ex.wire_bytes < oracle.wire_bytes


def test_exchange_ef_survives_tier_resize():
    """Growing the slot pool mid-stream resizes the plane; surviving
    rows keep their EF residual (the bound holds with the padded EF)."""
    ex = CrossShardExchange(1, "int8_ef")
    small = _rounds(2, 2, seed=21)
    big = _rounds(4, 1, seed=22)
    for rnd in small:
        ex.push_round(0, rnd)
    ef_prev = ex.error_feedback(0)
    assert ef_prev.shape[0] == 2
    exact = np.asarray(CrossShardExchange.summary_plane(big[0]))
    padded = np.zeros_like(exact)
    padded[:2] = ef_prev
    ex.push_round(0, big[0])
    assert np.all(
        np.abs(ex.latest(0) - (exact + padded)) <= ex.last_scale(0) / 2 + 1e-5
    )


def test_exchange_off_and_validation():
    ex = CrossShardExchange(2, "off")
    for rnd in _rounds(1, 1):
        ex.push_round(0, rnd)
    assert ex.latest(0) is None and ex.rounds == 0 and ex.view() == {}
    with pytest.raises(ValueError, match="exchange mode"):
        CrossShardExchange(2, "zstd")
    with pytest.raises(ValueError, match="exchange mode"):
        _make(exchange="gzip")


# ---------------------------------------------------------------------------
# Shard chaos harness.
# ---------------------------------------------------------------------------


def test_shard_chaos_smoke():
    cfg = ShardChaosConfig(
        n_sensors=4,
        n_faulty=1,
        n_rounds=24,
        seed=3,
        faults=("stall", "burst", "migrate", "rebalance", "shard_stall"),
    )
    rep = ShardChaosHarness(cfg).run()
    assert rep.bit_identical, rep.mismatches
    assert rep.lost_sessions == 0
    assert rep.escaped_errors == []
    assert rep.rescues >= 1
    assert all(rep.fired.get(k, 0) >= 1 for k in cfg.faults), rep.fired
    assert rep.exchange["compression_ratio"] > 3.0


def test_shard_chaos_config_validation():
    with pytest.raises(ValueError, match=">= 2 shards"):
        ShardChaosConfig(n_shards=1)
    with pytest.raises(ValueError, match="unknown faults"):
        ShardChaosConfig(faults=("meteor",))
    with pytest.raises(ValueError, match="shard_stall_rounds"):
        ShardChaosConfig(shard_stall_rounds=2, rescue_after_degraded_rounds=2)
    assert set(SHARD_FAULT_TAXONOMY) > {"migrate", "rebalance", "shard_stall"}


# ---------------------------------------------------------------------------
# Multi-device shard meshes.
# ---------------------------------------------------------------------------


def test_constellation_multidevice(subproc):
    """4 devices, 2 shards: each shard gets a 2-device sensor mesh, and
    a session migrated across the meshes stays bit-identical."""
    out = subproc(
        """
import sys
sys.path.insert(0, "tests")
import jax
import numpy as np
assert jax.device_count() == 4
from test_constellation import CONFIG, MANUAL, _drain_all, _reference
from repro.serve.chaos import _FakeClock, _Stream, compare_outputs, concat_outputs
from repro.serve.constellation import ConstellationService

cs = ConstellationService(
    CONFIG, n_shards=2, tiers=(2, 4), admission=MANUAL,
    clock=_FakeClock(), sleep=lambda s: None,
)
assert [len(cs.shard(i).devices) for i in range(2)] == [2, 2]
assert all(cs.shard(i).mesh is not None for i in range(2))
assert cs.shard(0).devices != cs.shard(1).devices

gids = [cs.attach() for i in range(2)]
streams = {g: _Stream(400 + g) for g in gids}
fed = {g: [] for g in gids}
parts = {g: [] for g in gids}
for rnd in range(4):
    for g in gids:
        chunk = streams[g].next(90)
        fed[g].append(chunk)
        for f in cs.feed(g, *chunk):
            parts[f.gid].append(f.result)
    for f in cs.pump(force=True):
        parts[f.gid].append(f.result)
    if rnd == 1:
        cs.migrate(gids[0], 1 - cs.shard_of(gids[0]))
for f in _drain_all(cs, gids):
    parts[f.gid].append(f.result)
for g in gids:
    parts[g].append(cs.detach(g))
    bad = compare_outputs(
        concat_outputs(parts[g]), concat_outputs(_reference(fed[g])), str(g)
    )
    assert bad == [], bad
assert cs.migrations == 1
assert cs.exchange.stats["compression_ratio"] > 3.0
print("multidevice constellation bit-identical")
""",
        device_count=4,
    )
    assert "multidevice constellation bit-identical" in out
