"""Sharding rules, HLO cost analysis, and a small-mesh dry-run integration
test (the full 512-device dry-run runs via launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (
    SERVE_RULES,
    TRAIN_RULES,
    MULTIPOD_TRAIN_RULES,
    partition_params,
)
from repro.launch.hlo_analysis import (
    Analyzer,
    _parse_shape,
    _shape_bytes,
    analyze,
    parse_module,
)

MOCK_HLO = """\
HloModule test

%wrapped_add (p0: f32[8,8], p1: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  %p1 = f32[8,8]{1,0} parameter(1)
  ROOT %add.1 = f32[8,8]{1,0} add(%p0, %p1)
}

%body (arg: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
  %arg = (s32[], f32[8,16]) parameter(0)
  %iv = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %w = f32[16,16]{1,0} constant({...})
  %dot.1 = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ag = f32[8,16]{1,0} all-gather(%dot.1), channel_id=1, replica_groups=[4]<=[4], dimensions={1}
  %one = s32[] constant(1)
  %next = s32[] add(%iv, %one)
  ROOT %tup = (s32[], f32[8,16]) tuple(%next, %ag)
}

%cond (arg: (s32[], f32[8,16])) -> pred[] {
  %arg = (s32[], f32[8,16]) parameter(0)
  %iv = s32[] get-tuple-element(%arg), index=0
  %lim = s32[] constant(5)
  ROOT %lt = pred[] compare(%iv, %lim), direction=LT
}

ENTRY %main (a: f32[8,16], b: f32[8,8]) -> f32[8,16] {
  %a = f32[8,16]{1,0} parameter(0)
  %b = f32[8,8]{1,0} parameter(1)
  %zero = s32[] constant(0)
  %t = (s32[], f32[8,16]) tuple(%zero, %a)
  %loop = (s32[], f32[8,16]) while(%t), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%loop), index=1
}
"""


def test_parse_shapes():
    assert _parse_shape("f32[8,16]{1,0}") == [("f32", [8, 16])]
    assert _parse_shape("(s32[], f32[2,3])") == [("s32", []), ("f32", [2, 3])]
    assert _shape_bytes([("bf16", [4, 4])]) == 32
    assert _shape_bytes([("s32", [])]) == 4


def test_analyzer_loop_multiplier():
    comps = parse_module(MOCK_HLO)
    assert set(comps) >= {"body", "cond", "main"}
    out = analyze(MOCK_HLO)
    # dot: 2*8*16*16 = 4096 flops x 5 trips = 20480
    assert out["flops"] == 4096 * 5
    # all-gather result 8*16*4 = 512B x 5 trips
    assert out["coll_breakdown"]["all-gather"] == 512 * 5


def test_analyzer_on_real_compiled_module():
    def f(w, x):
        def body(c, _):
            return jnp.tanh(c @ w), None
        c, _ = jax.lax.scan(body, x, None, length=9)
        return c.sum()

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((64, 64), jnp.float32),
        jax.ShapeDtypeStruct((8, 64), jnp.float32),
    ).compile()
    out = analyze(compiled.as_text())
    expect = 2 * 8 * 64 * 64 * 9
    assert out["flops"] == pytest.approx(expect, rel=0.01)


# ---------------------------------------------------------------------------
# partition specs
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape, names):
        self.axis_names = names
        import numpy as _np

        self.devices = _np.empty(shape)


def test_partition_params_rules():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    params = {
        "embed": jax.ShapeDtypeStruct((50304, 2560), jnp.float32),
        "cycles": {"blk0": {
            "inner": {"wq": jax.ShapeDtypeStruct((16, 2560, 2560), jnp.float32)},
            "moe": {"wi_gate": jax.ShapeDtypeStruct((16, 64, 2048, 1408), jnp.float32)},
            "norm1": jax.ShapeDtypeStruct((16, 2560), jnp.float32),
        }},
    }
    specs = partition_params(params, TRAIN_RULES, mesh)
    assert specs["embed"] == P("model", "data")
    # stacked scan dim -> leading None
    assert specs["cycles"]["blk0"]["inner"]["wq"] == P(None, "data", "model")
    # moe: experts over ep(model), fsdp on d
    assert specs["cycles"]["blk0"]["moe"]["wi_gate"] == P(None, "model", "data", None)
    assert specs["cycles"]["blk0"]["norm1"] == P()


def test_partition_divisibility_fallback():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    params = {"embed": jax.ShapeDtypeStruct((73448, 2560), jnp.float32)}
    specs = partition_params(params, TRAIN_RULES, mesh)
    # 73448 % 16 != 0 -> vocab dim replicated, d still sharded
    assert specs["embed"] == P(None, "data")


def test_serve_rules_no_fsdp():
    mesh = _FakeMesh((16, 16), ("data", "model"))
    params = {"wq": jax.ShapeDtypeStruct((2048, 2048), jnp.float32)}
    assert partition_params(params, SERVE_RULES, mesh)["wq"] == P(None, "model")
    assert partition_params(params, TRAIN_RULES, mesh)["wq"] == P("data", "model")


# ---------------------------------------------------------------------------
# small-mesh dry-run integration (8 fake devices in a subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape_kind", ["train", "decode"])
def test_dryrun_small_mesh(subproc, shape_kind):
    out = subproc(f"""
import dataclasses, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import get_config
from repro.distributed import sharding as S
from repro.launch.mesh import make_mesh, use_mesh
from repro.launch.dryrun import _batch_sharding, _cache_sharding
from repro.models.transformer import init_params, init_cache, decode_step
from repro.train.optimizer import init_opt_state
from repro.train.train_step import TrainConfig, make_train_step
from functools import partial

cfg = dataclasses.replace(
    get_config("llama3.2-1b"), n_layers=4, d_model=256, n_heads=8,
    n_kv_heads=4, d_ff=512, vocab=1024, head_dim=32,
)
mesh = make_mesh((4, 2), ("data", "model"))
rules = S.TRAIN_RULES
params_sds = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
pspec = S.partition_params(params_sds, rules, mesh)
pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)

kind = {shape_kind!r}
with use_mesh(mesh):
    if kind == "train":
        batch = {{
            "tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32),
            "labels": jax.ShapeDtypeStruct((8, 64), jnp.int32),
        }}
        bshard = _batch_sharding(mesh, rules, batch)
        opt_sds = jax.eval_shape(init_opt_state, params_sds)
        oshard = {{"step": NamedSharding(mesh, P()), "mu": pshard, "nu": pshard}}
        fn = make_train_step(cfg, TrainConfig())
        compiled = jax.jit(fn, in_shardings=(pshard, oshard, bshard)).lower(
            params_sds, opt_sds, batch).compile()
    else:
        cache_sds = jax.eval_shape(lambda: init_cache(cfg, 8, 128))
        cshard = _cache_sharding(mesh, S.SERVE_RULES, cache_sds)
        pshard = jax.tree.map(
            lambda s: NamedSharding(mesh, s),
            S.partition_params(params_sds, S.SERVE_RULES, mesh))
        inp = {{"tokens": jax.ShapeDtypeStruct((8, 1), jnp.int32)}}
        ishard = _batch_sharding(mesh, S.SERVE_RULES, inp)
        fn = partial(decode_step, cfg=cfg)
        compiled = jax.jit(
            fn, in_shardings=(pshard, ishard, cshard, NamedSharding(mesh, P())),
        ).lower(params_sds, inp, cache_sds, jax.ShapeDtypeStruct((), jnp.int32)).compile()
mem = compiled.memory_analysis()
assert mem is not None
print("DRYRUN-{shape_kind} OK")
""", device_count=8)
    assert f"DRYRUN-{shape_kind} OK" in out


def test_full_dryrun_results_are_green():
    """If the full-scale dry-run has produced results, none may be failed."""
    import json
    from pathlib import Path

    res = Path(__file__).resolve().parent.parent / "benchmarks" / "dryrun_results"
    files = list(res.glob("*.json"))
    if not files:
        pytest.skip("full dry-run not yet executed")
    bad = []
    for f in files:
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            bad.append((f.name, rec.get("error")))
    assert not bad, bad
