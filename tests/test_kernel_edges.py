"""Edge-shape differential suite for the Pallas kernels (ISSUE 6
satellite).

``tests/test_kernels.py`` sweeps nominal shapes; this file pins the
degenerate windows a live sensor actually produces, kernel vs
``kernels/ref.py`` (or the metrics oracle) on every one:

* zero-event (all-invalid) windows,
* single-event windows,
* capacity-saturated windows (every slot valid, heavy coincidences),
* all-invalid PADDING carrying garbage/out-of-bounds coordinates that
  must never leak into a cell, patch, or metric.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import metrics as M
from repro.core.events import batch_from_arrays
from repro.core.grid_clustering import GridConfig, grid_cluster
from repro.kernels import ops, ref

RNG = np.random.default_rng(0xED6E)


# ---------------------------------------------------------------------------
# grid_quantize: single word, tile-boundary sizes, max coordinates.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 2, 1023, 1024, 1025])
def test_grid_quantize_tile_boundaries(n):
    x = RNG.integers(0, 640, n).astype(np.uint32)
    y = RNG.integers(0, 480, n).astype(np.uint32)
    words = jnp.asarray((y << 16) | x)
    np.testing.assert_array_equal(
        np.asarray(ops.grid_quantize_packed(words, 16)),
        np.asarray(ref.grid_quantize_packed_ref(words, 16)),
    )


def test_grid_quantize_extreme_coordinates():
    # Full 16-bit coordinate range: no overflow into the other half-word.
    words = jnp.asarray(
        [0, 0xFFFF, 0xFFFF_0000, 0xFFFF_FFFF, (479 << 16) | 639], jnp.uint32
    )
    np.testing.assert_array_equal(
        np.asarray(ops.grid_quantize_packed(words, 16)),
        np.asarray(ref.grid_quantize_packed_ref(words, 16)),
    )


# ---------------------------------------------------------------------------
# cluster_accum: zero-event / single-event / saturated / garbage padding.
# ---------------------------------------------------------------------------

def _accum_case(x, y, t, v):
    args = (
        jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32),
        jnp.asarray(t, jnp.float32), jnp.asarray(v, bool),
    )
    kw = dict(cell_size=16, grid_w=40, grid_h=30)
    out = ops.cluster_accum(*args, **kw)
    exp = ref.cluster_accum_ref(*args, **kw)
    for a, b, name in zip(out, exp, ("count", "sx", "sy", "st")):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-3, err_msg=name
        )
    return out


def test_cluster_accum_zero_event_window():
    n = 256
    out = _accum_case(
        RNG.integers(0, 640, n), RNG.integers(0, 480, n),
        np.zeros(n), np.zeros(n, bool),
    )
    for surf in out:
        assert float(np.abs(np.asarray(surf)).max()) == 0.0


def test_cluster_accum_single_event_window():
    count, sx, sy, st = _accum_case(
        np.array([321]), np.array([234]), np.array([77.0]), np.array([True])
    )
    flat = (234 // 16) * 40 + (321 // 16)
    count = np.asarray(count)
    assert count.sum() == 1 and count[flat] == 1
    assert float(np.asarray(sx)[flat]) == 321.0
    assert float(np.asarray(st)[flat]) == 77.0


def test_cluster_accum_saturated_one_cell():
    # Every event valid and landing in ONE cell: the accumulator sees the
    # full capacity worth of adds without loss.
    n = 1024
    x = 320 + RNG.integers(0, 16, n)
    y = 240 + RNG.integers(0, 16, n)
    count, *_ = _accum_case(x, y, np.ones(n), np.ones(n, bool))
    count = np.asarray(count)
    assert count.sum() == n
    assert count.max() == n  # all in the (320//16, 240//16) cell


def test_cluster_accum_garbage_padding_masked():
    # Invalid slots carry hostile coordinates (negative, beyond-sensor):
    # they must not scatter anywhere, matching the ref's masking.
    n = 128
    x = np.concatenate([200 + RNG.integers(0, 10, n // 2),
                        RNG.integers(-5000, 5000, n // 2)])
    y = np.concatenate([100 + RNG.integers(0, 10, n // 2),
                        RNG.integers(-5000, 5000, n // 2)])
    v = np.concatenate([np.ones(n // 2, bool), np.zeros(n // 2, bool)])
    count, *_ = _accum_case(x, y, np.ones(n), v)
    assert int(np.asarray(count).sum()) == n // 2


# ---------------------------------------------------------------------------
# window_entropy: corner-clipped centers, single hot pixel, empty frame.
# ---------------------------------------------------------------------------

def test_window_entropy_corner_centers():
    frame = jnp.asarray(RNG.random((480, 640)), jnp.float32)
    cx = jnp.asarray([0, 639, 0, 639, 320], jnp.int32)
    cy = jnp.asarray([0, 0, 479, 479, 240], jnp.int32)
    np.testing.assert_allclose(
        np.asarray(ops.window_entropy(frame, cx, cy)),
        np.asarray(ref.window_entropy_ref(frame, cx, cy)),
        rtol=1e-4, atol=1e-5,
    )


def test_window_entropy_single_hot_pixel():
    frame = jnp.zeros((480, 640), jnp.float32).at[240, 320].set(1.0)
    cx = jnp.asarray([320], jnp.int32)
    cy = jnp.asarray([240], jnp.int32)
    out = np.asarray(ops.window_entropy(frame, cx, cy))
    exp = np.asarray(ref.window_entropy_ref(frame, cx, cy))
    np.testing.assert_allclose(out, exp, rtol=1e-4, atol=1e-5)
    assert out[0, 0] > 0.0  # one bright pixel -> nonzero shannon


def test_window_entropy_empty_frame_all_corners():
    frame = jnp.zeros((480, 640), jnp.float32)
    cx = jnp.asarray([0, 639], jnp.int32)
    cy = jnp.asarray([479, 0], jnp.int32)
    out = np.asarray(ops.window_entropy(frame, cx, cy))
    np.testing.assert_allclose(out[0], 0.0, atol=1e-5)  # shannon
    np.testing.assert_allclose(out[2], 0.0, atol=1e-6)  # contrast


# ---------------------------------------------------------------------------
# patch_metrics: degenerate windows vs the event-space oracle.
# ---------------------------------------------------------------------------

def _metrics_case(batch, grid=GridConfig(min_events=1)):
    clusters = grid_cluster(batch, grid)
    out = jax.jit(
        lambda b, c: ops.patch_metrics_call(b, c, width=640, height=480)
    )(batch, clusters)
    exp = M.cluster_metrics_events(batch, clusters)
    for k in M.METRIC_NAMES:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(exp[k]),
            rtol=1e-5, atol=1e-5, err_msg=k,
        )
    return clusters, out


def test_patch_metrics_single_event_window():
    batch = batch_from_arrays(
        np.array([300]), np.array([200]), np.array([5]), np.array([1]), 128
    )
    clusters, out = _metrics_case(batch)
    valid = np.asarray(clusters.valid)
    assert valid.sum() == 1
    np.testing.assert_allclose(
        np.asarray(out["event_count"])[valid], [1.0], atol=0
    )


def test_patch_metrics_capacity_saturated_window():
    n = 256
    x = 100 + RNG.integers(0, 20, n)
    y = 100 + RNG.integers(0, 20, n)
    batch = batch_from_arrays(x, y, np.arange(n), np.zeros(n), n)
    assert bool(np.asarray(batch.valid).all())
    _metrics_case(batch, GridConfig(min_events=2))


def test_patch_metrics_padding_coordinates_do_not_leak():
    # Two identical windows except the invalid tail's coordinates: one
    # zeroed, one garbage landing INSIDE the live patch. Metrics must
    # be bit-identical — padding never reaches a patch or histogram.
    n, cap = 90, 256
    x = 200 + RNG.integers(0, 12, n)
    y = 300 + RNG.integers(0, 12, n)
    clean = batch_from_arrays(x, y, np.arange(n), np.zeros(n), cap)
    gx = np.concatenate([x, 200 + RNG.integers(0, 12, cap - n)])
    gy = np.concatenate([y, 300 + RNG.integers(0, 12, cap - n)])
    dirty = clean._replace(
        x=jnp.asarray(gx, jnp.int32), y=jnp.asarray(gy, jnp.int32)
    )
    clusters = grid_cluster(clean, GridConfig(min_events=2))
    out_c = ops.patch_metrics_call(clean, clusters, width=640, height=480)
    out_d = ops.patch_metrics_call(dirty, clusters, width=640, height=480)
    for k in M.METRIC_NAMES:
        np.testing.assert_array_equal(
            np.asarray(out_c[k]), np.asarray(out_d[k]), err_msg=k
        )
