"""Device-resident scanned pipeline: pad_windows invariants and
scan-vs-loop equivalence (bit-for-bit, both histogram paths)."""
import numpy as np
import pytest

from repro.core.events import (
    BatcherConfig,
    dual_threshold_batches,
    pad_windows,
    window_batches,
)
from repro.core.pipeline import (
    PipelineConfig,
    run_many_scan,
    run_recording,
    run_recording_scan,
)
from repro.data.synthetic import Recording, make_recording, make_validation_suite


@pytest.fixture(scope="module")
def recording():
    return make_recording(seed=3, duration_s=0.4, n_rsos=2)


@pytest.fixture(scope="module")
def suite():
    # One recording per lens configuration, short for test speed.
    return make_validation_suite(n_recordings=1, duration_s=0.4)


def _empty_recording() -> Recording:
    z = np.zeros(0, np.int32)
    return Recording(
        x=z, y=z, t=np.zeros(0, np.int64), p=z, kind=z, obj=z,
        rso_tracks=np.zeros((0, 4)), duration_us=0, name="empty",
    )


# ---------------------------------------------------------------------------
# pad_windows
# ---------------------------------------------------------------------------

def test_pad_windows_matches_batcher_windows(recording):
    cfg = BatcherConfig()
    windowed = pad_windows(recording.x, recording.y, recording.t, recording.p, cfg)
    batches = list(
        dual_threshold_batches(recording.x, recording.y, recording.t, recording.p, cfg)
    )
    assert windowed.num_windows == len(batches)
    for w, (batch, sl) in enumerate(batches):
        assert windowed.starts[w] == sl.start
        assert windowed.stops[w] == sl.stop
        assert windowed.t_start_us[w] == recording.t[sl.start]
        for field in batch._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(windowed.batch, field)[w]),
                np.asarray(getattr(batch, field)),
                err_msg=field,
            )


def test_pad_windows_events_conserved(recording):
    cfg = BatcherConfig()
    windowed = pad_windows(recording.x, recording.y, recording.t, recording.p, cfg)
    # Dual-threshold windows close at <= size_threshold <= capacity events,
    # so no window truncates and every event lands in exactly one row.
    assert int(np.asarray(windowed.batch.valid).sum()) == len(recording)
    # Slices partition the stream in order.
    assert windowed.starts[0] == 0
    assert windowed.stops[-1] == len(recording)
    np.testing.assert_array_equal(windowed.starts[1:], windowed.stops[:-1])


def test_pad_windows_last_partial_window():
    # 260 events, 1 us apart: windows of 250 then a partial 10-event window.
    n = 260
    t = np.arange(n, dtype=np.int64)
    z = np.zeros(n, np.int32)
    windowed = pad_windows(z, z, t, z, BatcherConfig())
    assert windowed.num_windows == 2
    valid = np.asarray(windowed.batch.valid)
    assert int(valid[0].sum()) == 250
    assert int(valid[1].sum()) == 10
    # Relative timestamps restart at each window's first event.
    bt = np.asarray(windowed.batch.t)
    assert bt[1, 0] == 0 and bt[1, 9] == 9


def test_pad_windows_stride_policy_matches_window_batches(recording):
    cap = 512
    cfg = BatcherConfig(capacity=cap)
    windowed = pad_windows(
        recording.x, recording.y, recording.t, recording.p, cfg, policy="stride"
    )
    batches = list(
        window_batches(
            recording.x, recording.y, recording.t, recording.p, capacity=cap
        )
    )
    assert windowed.num_windows == len(batches)
    for w, (batch, _) in enumerate(batches):
        for field in batch._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(windowed.batch, field)[w]),
                np.asarray(getattr(batch, field)),
                err_msg=field,
            )


def test_pad_windows_stride_truncates_at_capacity():
    # 100 events in one 20 ms stride window but capacity 16 -> truncated row.
    n = 100
    t = np.arange(n, dtype=np.int64) * 100
    z = np.zeros(n, np.int32)
    windowed = pad_windows(z, z, t, z, BatcherConfig(capacity=16), policy="stride")
    assert windowed.num_windows == 1
    assert int(np.asarray(windowed.batch.valid).sum()) == 16


def test_pad_windows_empty_stream():
    z = np.zeros(0, np.int32)
    windowed = pad_windows(z, z, np.zeros(0, np.int64), z, BatcherConfig())
    assert windowed.num_windows == 0
    assert windowed.batch.x.shape == (0, BatcherConfig().capacity)


def test_pad_windows_rejects_unknown_policy():
    z = np.zeros(1, np.int32)
    with pytest.raises(ValueError):
        pad_windows(z, z, np.zeros(1, np.int64), z, policy="nope")


# ---------------------------------------------------------------------------
# scan vs loop equivalence
# ---------------------------------------------------------------------------

def _assert_scan_equals_loop(rec, config):
    loop = run_recording(rec, config, with_tracking=True)
    scan = run_recording_scan(rec, config, with_tracking=True)
    assert scan.num_windows == len(loop)
    for a, b in zip(loop, scan.window_results()):
        assert a.t_start_us == b.t_start_us
        for field in a.clusters._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a.clusters, field)),
                np.asarray(getattr(b.clusters, field)),
                err_msg=f"clusters.{field}",
            )
        for key in a.metrics:
            np.testing.assert_array_equal(
                a.metrics[key], b.metrics[key], err_msg=f"metrics[{key}]"
            )
        for field in a.tracks._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(a.tracks, field)),
                np.asarray(getattr(b.tracks, field)),
                err_msg=f"tracks.{field}",
            )
    for field in scan.final_tracks._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(loop[-1].tracks, field)),
            np.asarray(getattr(scan.final_tracks, field)),
            err_msg=f"final_tracks.{field}",
        )


def test_scan_equals_loop_jnp_path(suite):
    for rec in suite:
        _assert_scan_equals_loop(rec, PipelineConfig(use_kernels=False))


def test_scan_equals_loop_kernel_path(suite):
    # Pallas path; interpret=True is selected automatically off-TPU.
    _assert_scan_equals_loop(suite[0], PipelineConfig(use_kernels=True))


def test_scan_without_tracking(recording):
    scan = run_recording_scan(recording, PipelineConfig(), with_tracking=False)
    assert scan.tracks is None and scan.final_tracks is None
    loop = run_recording(recording, PipelineConfig(), with_tracking=False)
    for a, b in zip(loop, scan.window_results()):
        np.testing.assert_array_equal(
            np.asarray(a.clusters.count), np.asarray(b.clusters.count)
        )


def test_scan_empty_recording():
    scan = run_recording_scan(_empty_recording(), PipelineConfig())
    assert scan.num_windows == 0
    assert scan.clusters.count.shape[0] == 0
    assert scan.window_results() == []


def test_run_many_scan_matches_per_recording():
    # Different durations -> different window counts, so the pad-to-W_max
    # path and the padded-tail tracker semantics are exercised.
    recs = [
        make_recording(seed=1, duration_s=0.6, n_rsos=2),
        make_recording(seed=2, duration_s=0.3, n_rsos=1),
    ]
    config = PipelineConfig()
    assert (
        run_recording_scan(recs[0], config).num_windows
        != run_recording_scan(recs[1], config).num_windows
    )
    many = run_many_scan(recs, config)
    assert len(many) == len(recs)
    for res, rec in zip(many, recs):
        single = run_recording_scan(rec, config)
        assert res.num_windows == single.num_windows
        for field in res.clusters._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(res.clusters, field)),
                np.asarray(getattr(single.clusters, field)),
                err_msg=f"clusters.{field}",
            )
        for field in res.final_tracks._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(res.final_tracks, field)),
                np.asarray(getattr(single.final_tracks, field)),
                err_msg=f"final_tracks.{field}",
            )


def test_run_many_scan_empty_list():
    assert run_many_scan([], PipelineConfig()) == []


def test_scan_reuses_precomputed_windows(recording):
    config = PipelineConfig()
    windowed = pad_windows(
        recording.x, recording.y, recording.t, recording.p, config.batcher
    )
    a = run_recording_scan(recording, config, windows=windowed)
    b = run_recording_scan(recording, config)
    np.testing.assert_array_equal(
        np.asarray(a.clusters.count), np.asarray(b.clusters.count)
    )
