"""Per-architecture smoke tests (reduced configs) + component unit tests
+ decode-vs-teacher-forcing consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LM_SHAPES, applicable_shapes, get_config, list_archs
from repro.models import attention as A
from repro.models import moe as MOE
from repro.models.common import apply_mrope, apply_rope
from repro.models.transformer import (
    decode_step,
    forward_train,
    init_cache,
    init_params,
    prefill,
)

ARCHS = list_archs()


def reduce_cfg(cfg):
    plen = len(cfg.block_pattern)
    return dataclasses.replace(
        cfg,
        n_layers=max(2 * plen if plen > 1 else 2, plen),
        d_model=128, n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads > 1 else 1,
        d_ff=256 if cfg.d_ff else 0, vocab=512,
        head_dim=32 if cfg.head_dim else 0,
        n_experts=4 if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        q_lora_rank=32 if cfg.q_lora_rank else 0,
        kv_lora_rank=16 if cfg.kv_lora_rank else 0,
        qk_nope_dim=16 if cfg.qk_nope_dim else 0,
        qk_rope_dim=8 if cfg.qk_rope_dim else 0,
        v_head_dim=16 if cfg.v_head_dim else 0,
        local_window=8, lru_width=128 if cfg.lru_width else 0,
        mrope_sections=(4, 6, 6) if cfg.mrope_sections else None,
        dtype="float32",
    )


def make_inputs(cfg, b, s, key, with_labels=False):
    inputs = {}
    if cfg.frontend:
        inputs["embeds"] = jax.random.normal(key, (b, s, cfg.d_model), jnp.float32)
    else:
        inputs["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    if cfg.pos_kind == "mrope":
        inputs["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)
        ).astype(jnp.int32)
    if with_labels:
        inputs["labels"] = jax.random.randint(key, (b, s), 0, cfg.vocab)
    return inputs


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    """Required per-arch smoke test: reduced config, one forward, shapes
    + no NaNs."""
    cfg = reduce_cfg(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    b, s = 2, 16
    logits, aux = forward_train(params, make_inputs(cfg, b, s, key), cfg, remat=False)
    assert logits.shape == (b, s, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Required per-arch smoke test: one train step on CPU, finite loss."""
    from repro.train.optimizer import OptConfig, init_opt_state
    from repro.train.train_step import TrainConfig, make_train_step

    cfg = reduce_cfg(get_config(arch))
    key = jax.random.PRNGKey(1)
    params = init_params(key, cfg)
    opt = init_opt_state(params)
    step = make_train_step(cfg, TrainConfig(remat=False, opt=OptConfig(lr=1e-3)))
    batch = make_inputs(cfg, 2, 16, key, with_labels=True)
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(opt2["step"]) == 1
    # parameters actually moved
    delta = sum(
        float(jnp.abs(a - b).sum())
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", ["llama3.2-1b", "minicpm3-4b", "recurrentgemma-9b", "xlstm-350m", "moonshot-v1-16b-a3b"])
def test_decode_matches_teacher_forcing(arch):
    cfg = reduce_cfg(get_config(arch))
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # no drops
    key = jax.random.PRNGKey(2)
    params = init_params(key, cfg)
    b, s = 2, 12
    full = make_inputs(cfg, b, s + 2, key)
    ref_logits, _ = forward_train(params, full, cfg, remat=False)
    pre = {k: (v[:, :, :s] if k == "mrope_positions" else v[:, :s]) for k, v in full.items()}
    lp, cache = prefill(params, pre, cfg, cache_len=s + 2)
    np.testing.assert_allclose(
        np.asarray(lp), np.asarray(ref_logits[:, s - 1]), rtol=1e-4, atol=1e-4
    )
    for i in range(2):
        if cfg.frontend:
            stepin = {"embeds": full["embeds"][:, s + i : s + i + 1]}
        else:
            stepin = {"tokens": full["tokens"][:, s + i : s + i + 1]}
        ld, cache = decode_step(params, stepin, cache, jnp.int32(s + i), cfg)
        np.testing.assert_allclose(
            np.asarray(ld), np.asarray(ref_logits[:, s + i]), rtol=1e-3, atol=2e-3
        )


def test_paged_decode_matches_teacher_forcing():
    """HC1's paged decode path (hot ring page + online-softmax merge)
    must be bit-consistent with the dense path, including page wrap."""
    import repro.models.transformer as T

    cfg = reduce_cfg(get_config("llama3.2-1b"))
    key = jax.random.PRNGKey(3)
    params = init_params(key, cfg)
    s = 8
    toks = jax.random.randint(key, (2, s + 6), 0, cfg.vocab)
    ref, _ = forward_train(params, {"tokens": toks}, cfg, remat=False)
    old = T.PAGED_DECODE
    T.PAGED_DECODE = 4  # tiny page -> exercises wrap-around
    try:
        paged_tmpl = jax.eval_shape(lambda: init_cache(cfg, 2, s + 6))
    finally:
        T.PAGED_DECODE = old
    _, cache0 = prefill(params, {"tokens": toks[:, :s]}, cfg, cache_len=s + 6)

    def graft(tmpl, real):
        out = {}
        for k_, v_ in tmpl.items():
            if isinstance(v_, dict):
                out[k_] = graft(v_, real.get(k_, {}))
            elif k_ in real:
                out[k_] = real[k_]
            else:
                fill = -1 if "pos" in k_ else 0
                out[k_] = jnp.full(v_.shape, fill, v_.dtype)
        return out

    from repro.models.attention import flush_page

    cache = graft(paged_tmpl, cache0)
    for i in range(6):
        if i > 0 and i % 4 == 0:  # page full: the serving loop flushes
            cache["cycles"] = jax.vmap(flush_page)(cache["cycles"]["blk0"])
            cache["cycles"] = {"blk0": cache["cycles"]}
        ld, cache = decode_step(
            params, {"tokens": toks[:, s + i : s + i + 1]}, cache,
            jnp.int32(s + i), cfg,
        )
        np.testing.assert_allclose(
            np.asarray(ld), np.asarray(ref[:, s + i]), rtol=1e-3, atol=2e-3,
            err_msg=f"step {i}",
        )
    # flush clears the page and lands positions in the main cache
    blk = jax.tree.map(lambda a: a[0], cache["cycles"]["blk0"])
    flushed = flush_page(blk)
    assert int(jnp.sum(flushed["page_pos"] >= 0)) == 0
    got = set(int(p) for p in np.asarray(flushed["pos"]) if p >= 0)
    assert {s, s + 1, s + 2, s + 3, s + 4, s + 5} <= got


def test_applicable_shapes_rules():
    for arch in ARCHS:
        cfg = get_config(arch)
        shapes = applicable_shapes(cfg)
        assert {"train_4k", "prefill_32k", "decode_32k"} <= set(shapes)
        assert ("long_500k" in shapes) == cfg.subquadratic
    assert get_config("recurrentgemma-9b").subquadratic
    assert get_config("xlstm-350m").subquadratic
    assert not get_config("deepseek-67b").subquadratic


def test_total_cells_count():
    cells = sum(len(applicable_shapes(get_config(a))) for a in ARCHS)
    assert cells == 3 * 10 + 2  # 32 runnable of the 40 assigned (8 skips)


# ---------------------------------------------------------------------------
# components
# ---------------------------------------------------------------------------

def test_rope_preserves_norm_and_relativity():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 2, 32))
    pos = jnp.arange(8)[None]
    y = apply_rope(x, pos)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-5,
    )
    # relative property: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 32))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 32))
    def dot_at(p, d):
        qq = apply_rope(q, jnp.asarray([[p]]))
        kk = apply_rope(k, jnp.asarray([[p + d]]))
        return float(jnp.sum(qq * kk))
    assert dot_at(0, 3) == pytest.approx(dot_at(17, 3), rel=1e-4)


def test_mrope_text_equals_rope():
    """With t=h=w positions, M-RoPE must reduce to standard RoPE."""
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 6, 2, 32))
    pos = jnp.arange(6)[None].repeat(2, 0)
    mpos = jnp.broadcast_to(pos[None], (3, 2, 6))
    a = apply_rope(x, pos)
    b = apply_mrope(x, mpos, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_flash_attention_matches_naive():
    key = jax.random.PRNGKey(0)
    b, s, kv, g, d = 2, 37, 2, 3, 16
    q = jax.random.normal(key, (b, s, kv, g, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, kv, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, kv, d))
    pos = jnp.arange(s)
    out = A.flash_attention(q, k, v, pos, pos, causal=True, q_chunk=8, kv_chunk=16)
    # naive reference
    scores = jnp.einsum("bqkgd,btkd->bkgqt", q, k) / np.sqrt(d)
    mask = pos[None, :] <= pos[:, None]
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    ref = jnp.einsum("bkgqt,btkd->bqkgd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_flash_attention_window():
    key = jax.random.PRNGKey(0)
    b, s, d = 1, 24, 8
    q = jax.random.normal(key, (b, s, 1, 1, d))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, s, 1, d))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, 1, d))
    pos = jnp.arange(s)
    out = A.flash_attention(q, k, v, pos, pos, causal=True, window=4, q_chunk=8, kv_chunk=8)
    scores = jnp.einsum("bqkgd,btkd->bkgqt", q, k) / np.sqrt(d)
    mask = (pos[None, :] <= pos[:, None]) & (pos[None, :] > pos[:, None] - 4)
    scores = jnp.where(mask[None, None, None], scores, -1e30)
    ref = jnp.einsum("bkgqt,btkd->bqkgd", jax.nn.softmax(scores, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_moe_router_normalized_and_balanced_loss():
    key = jax.random.PRNGKey(0)
    params = MOE.moe_init(key, 32, 64, 8)
    x = jax.random.normal(key, (2, 16, 32))
    out = MOE.moe_apply(params, x, n_experts=8, top_k=2, capacity_factor=8.0)
    assert out.y.shape == x.shape
    assert np.isfinite(np.asarray(out.y)).all()
    # aux loss >= 1 (equality at perfect balance) and finite
    assert 0.5 < float(out.aux_loss) < 8.0


def test_moe_capacity_drops_tokens():
    key = jax.random.PRNGKey(0)
    params = MOE.moe_init(key, 16, 32, 4)
    x = jax.random.normal(key, (1, 64, 16))
    full = MOE.moe_apply(params, x, n_experts=4, top_k=2, capacity_factor=8.0)
    tight = MOE.moe_apply(params, x, n_experts=4, top_k=2, capacity_factor=0.25)
    # tight capacity must change (drop) some outputs
    assert float(jnp.abs(full.y - tight.y).max()) > 1e-6
