"""Dual-threshold admission primitive: fake-clock semantics, weights,
prefix-pop rule, and the LM batcher as its thin client."""
import pytest

from repro.serve.batcher import AdmissionConfig, DualThresholdAdmitter, drain


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now


def test_empty_admitter_not_ready():
    adm = DualThresholdAdmitter(AdmissionConfig(0.02, 4), FakeClock())
    assert not adm.ready()
    assert adm.oldest_age_s() == 0.0
    assert adm.pop() == [] and adm.pop_all() == []


def test_time_threshold_fires_on_oldest_item():
    clock = FakeClock()
    adm = DualThresholdAdmitter(AdmissionConfig(0.02, 100), clock)
    adm.submit("a")
    clock.now = 0.015
    adm.submit("b")
    assert not adm.ready()  # oldest is 15 ms old
    clock.now = 0.020
    assert adm.ready()  # oldest hits exactly max_delay
    assert adm.oldest_age_s() == pytest.approx(0.020)
    assert adm.pop_all() == ["a", "b"]
    assert not adm.ready()  # drained


def test_size_threshold_counts_weight_not_entries():
    clock = FakeClock()
    adm = DualThresholdAdmitter(AdmissionConfig(10.0, 250), clock)
    adm.submit("chunk1", weight=200)
    assert not adm.ready()
    adm.submit("chunk2", weight=50)  # total weight hits 250
    assert adm.ready()
    assert adm.pending_weight == 250


def test_pop_takes_longest_prefix_within_weight():
    adm = DualThresholdAdmitter(AdmissionConfig(10.0, 4), FakeClock())
    for item, w in [("a", 2), ("b", 2), ("c", 1)]:
        adm.submit(item, weight=w)
    assert adm.pop() == ["a", "b"]  # 2 + 2 fits; + c would exceed
    assert adm.items == ["c"]
    assert adm.pending_weight == 1


def test_pop_never_wedges_on_overweight_head():
    adm = DualThresholdAdmitter(AdmissionConfig(10.0, 4), FakeClock())
    adm.submit("huge", weight=100)
    adm.submit("next", weight=1)
    assert adm.pop() == ["huge"]  # at least one item always comes out
    assert adm.items == ["next"]


def test_drain_helper_respects_ready_and_force():
    clock = FakeClock()
    adm = DualThresholdAdmitter(AdmissionConfig(0.02, 100), clock)
    adm.submit("a")
    assert drain(adm) == []  # not ready, not forced
    assert drain(adm, force=True) == ["a"]
    adm.submit("b")
    clock.now = 1.0
    assert drain(adm) == ["b"]  # time threshold fired


def test_config_and_weight_validation():
    with pytest.raises(ValueError, match="max_items"):
        AdmissionConfig(0.02, 0)
    with pytest.raises(ValueError, match="max_delay_s"):
        AdmissionConfig(-1.0, 8)
    adm = DualThresholdAdmitter(AdmissionConfig(), FakeClock())
    with pytest.raises(ValueError, match="weight"):
        adm.submit("a", weight=-1)


def test_lm_batcher_is_thin_client_of_admitter():
    # The historical LM API — Request.arrival_s stamping, .queue view,
    # pop_batch at max_batch — now rides the generic admitter.
    from repro.serve.lm import DualThresholdBatcher, EngineConfig, Request

    clock = FakeClock()
    b = DualThresholdBatcher(
        EngineConfig(max_delay_s=0.02, max_batch=3), clock=clock
    )
    clock.now = 0.5
    r = Request(rid=0, tokens=[1])
    b.submit(r)
    assert r.arrival_s == 0.5
    assert not b.ready()
    for i in range(1, 4):
        b.submit(Request(rid=i, tokens=[1]))
    assert b.ready()  # 4 >= max_batch
    batch = b.pop_batch()
    assert [r.rid for r in batch] == [0, 1, 2]  # max_batch prefix
    assert [r.rid for r in b.queue] == [3]


def test_discard_removes_item_entries_and_weight():
    clock = FakeClock()
    adm = DualThresholdAdmitter(AdmissionConfig(0.02, 100), clock)
    adm.submit("a", weight=30)
    adm.submit("b", weight=10)
    adm.submit("a", weight=20)
    assert adm.discard("a") == 2
    assert adm.items == ["b"] and adm.pending_weight == 10
    # The dead entries no longer age toward the time threshold.
    clock.now = 1.0
    adm2 = DualThresholdAdmitter(AdmissionConfig(0.02, 100), clock)
    adm2.submit("stale")
    clock.now = 2.0
    adm2.discard("stale")
    adm2.submit("fresh")
    assert not adm2.ready()  # only the fresh entry's age counts
    assert adm.discard("missing") == 0


# ---------------------------------------------------------------------------
# Edge cases + restate (out-of-band weight changes).
# ---------------------------------------------------------------------------

def test_oldest_age_resets_when_drained_empty():
    clock = FakeClock()
    adm = DualThresholdAdmitter(AdmissionConfig(0.02, 100), clock)
    adm.submit("a")
    clock.now = 1.0
    adm.discard("a")
    assert adm.oldest_age_s() == 0.0 and not adm.ready()


def test_pop_includes_entry_exactly_at_weight_boundary():
    adm = DualThresholdAdmitter(AdmissionConfig(10.0, 4), FakeClock())
    adm.submit("a", weight=2)
    adm.submit("b", weight=2)  # 2 + 2 == max_items exactly: both fit
    adm.submit("c", weight=1)
    assert adm.pop() == ["a", "b"]
    assert adm.items == ["c"]


def test_single_oversized_submit_is_ready_immediately():
    adm = DualThresholdAdmitter(AdmissionConfig(10.0, 250), FakeClock())
    adm.submit("flood", weight=300)  # one chunk over the whole budget
    assert adm.ready()
    assert adm.pop() == ["flood"]
    assert adm.pending_weight == 0 and not adm.ready()


def test_restate_replaces_entries_with_one_exact_weight():
    adm = DualThresholdAdmitter(AdmissionConfig(10.0, 100), FakeClock())
    adm.submit("a", weight=30)
    adm.submit("b", weight=10)
    adm.submit("a", weight=20)
    adm.restate("a", 12)  # e.g. the session's queue budget shed 38 events
    assert adm.pending_weight == 22
    assert sorted(adm.items) == ["a", "b"]
    assert adm.items.count("a") == 1


def test_restate_keeps_oldest_arrival_for_time_threshold():
    clock = FakeClock()
    adm = DualThresholdAdmitter(AdmissionConfig(0.02, 10_000), clock)
    adm.submit("a", weight=50)
    clock.now = 0.010
    adm.restate("a", 30)
    clock.now = 0.021  # 21 ms after the ORIGINAL arrival
    assert adm.ready()  # the shed did not reset a's latency clock


def test_restate_zero_weight_clears_and_fresh_item_stamps_now():
    clock = FakeClock()
    adm = DualThresholdAdmitter(AdmissionConfig(0.02, 100), clock)
    adm.submit("a", weight=5)
    adm.restate("a", 0)
    assert adm.items == [] and adm.pending_weight == 0
    clock.now = 1.0
    adm.restate("b", 7)  # no prior entries: stamped at the current clock
    assert adm.items == ["b"] and adm.pending_weight == 7
    assert adm.oldest_age_s() == 0.0
    with pytest.raises(ValueError, match="weight"):
        adm.restate("b", -1)


def test_restate_inserts_in_arrival_order():
    clock = FakeClock()
    adm = DualThresholdAdmitter(AdmissionConfig(10.0, 3), clock)
    adm.submit("a", weight=1)
    clock.now = 0.01
    adm.submit("b", weight=1)
    clock.now = 0.02
    adm.submit("c", weight=1)
    adm.restate("b", 1)  # re-stated entry keeps its slot in the order
    assert adm.items == ["a", "b", "c"]
    assert adm.pop() == ["a", "b", "c"]
