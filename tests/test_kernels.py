"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes/dtypes, plus hypothesis property tests (deterministic
fallback when hypothesis isn't installed; see tests/_hyp.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.kernels import ops, ref

RNG = np.random.default_rng(42)


def _coords(n, w=640, h=480):
    return RNG.integers(0, w, n), RNG.integers(0, h, n)


# ---------------------------------------------------------------------------
# grid_quantize
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [1, 5, 250, 1024, 2500])
@pytest.mark.parametrize("cell_size", [16, 32, 10, 7])
def test_grid_quantize_matches_ref(n, cell_size):
    x, y = _coords(n)
    words = jnp.asarray((y.astype(np.uint32) << 16) | x.astype(np.uint32))
    out = ops.grid_quantize_packed(words, cell_size)
    expect = ref.grid_quantize_packed_ref(words, cell_size)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))


def test_grid_quantize_wire_format():
    # x in low 16 bits, y in high 16 bits; output mirrors (paper Sec IV-B).
    words = jnp.asarray([(7 << 16) | 33], jnp.uint32)  # y=7, x=33
    out = int(ops.grid_quantize_packed(words, 16)[0])
    assert out & 0xFFFF == 33 // 16
    assert out >> 16 == 7 // 16


@settings(max_examples=30, deadline=None)
@given(
    st.lists(st.tuples(st.integers(0, 639), st.integers(0, 479)),
             min_size=1, max_size=300),
    st.sampled_from([8, 16, 20, 64]),
)
def test_grid_quantize_property(coords, cell_size):
    x = np.array([c[0] for c in coords], np.uint32)
    y = np.array([c[1] for c in coords], np.uint32)
    words = jnp.asarray((y << 16) | x)
    out = np.asarray(ops.grid_quantize_packed(words, cell_size))
    assert ((out & 0xFFFF) == x // cell_size).all()
    assert ((out >> 16) == y // cell_size).all()


# ---------------------------------------------------------------------------
# cluster_accum (fused quantize+aggregate)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [250, 256, 700, 1500])
@pytest.mark.parametrize("cell_size,grid_w,grid_h", [(16, 40, 30), (32, 20, 15)])
def test_cluster_accum_matches_ref(n, cell_size, grid_w, grid_h):
    x, y = _coords(n)
    t = RNG.uniform(0, 20000, n).astype(np.float32)
    v = RNG.random(n) > 0.15
    args = (jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32),
            jnp.asarray(t), jnp.asarray(v))
    kw = dict(cell_size=cell_size, grid_w=grid_w, grid_h=grid_h)
    out = ops.cluster_accum(*args, **kw)
    exp = ref.cluster_accum_ref(*args, **kw)
    for a, b, name in zip(out, exp, ("count", "sx", "sy", "st")):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-3, err_msg=name
        )


def test_cluster_accum_total_count_conserved():
    x, y = _coords(1000)
    v = RNG.random(1000) > 0.5
    count, *_ = ops.cluster_accum(
        jnp.asarray(x, jnp.int32), jnp.asarray(y, jnp.int32),
        jnp.zeros(1000, jnp.float32), jnp.asarray(v),
        cell_size=16, grid_w=40, grid_h=30,
    )
    assert int(np.asarray(count).sum()) == int(v.sum())


# ---------------------------------------------------------------------------
# window_entropy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 4, 17])
def test_window_entropy_matches_ref(k):
    frame = jnp.asarray(RNG.random((480, 640)), jnp.float32)
    cx = jnp.asarray(RNG.integers(0, 640, k), jnp.int32)
    cy = jnp.asarray(RNG.integers(0, 480, k), jnp.int32)
    out = ops.window_entropy(frame, cx, cy)
    exp = ref.window_entropy_ref(frame, cx, cy)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), rtol=1e-4, atol=1e-5)


def test_window_entropy_constant_patch_is_zero():
    frame = jnp.zeros((480, 640), jnp.float32)
    out = np.asarray(ops.window_entropy(frame, jnp.asarray([100]), jnp.asarray([100])))
    assert out[0, 0] == pytest.approx(0.0, abs=1e-5)  # shannon
    assert out[2, 0] == pytest.approx(0.0, abs=1e-6)  # contrast


# ---------------------------------------------------------------------------
# patch_metrics (fused event->patch + six cluster metrics)
# ---------------------------------------------------------------------------

def _metrics_inputs(seed, n=180, capacity=256):
    from repro.core.events import batch_from_arrays
    from repro.core.grid_clustering import GridConfig, grid_cluster

    rng = np.random.default_rng(seed)
    centers = rng.integers(40, 580, (3, 2))
    pick = rng.integers(0, 3, n)
    x = np.clip(centers[pick, 0] + rng.integers(-15, 16, n), 0, 639)
    y = np.clip(centers[pick, 1] + rng.integers(-15, 16, n), 0, 479)
    batch = batch_from_arrays(x, y, np.arange(n), np.zeros(n), capacity)
    clusters = grid_cluster(batch, GridConfig(min_events=2))
    return batch, clusters


@pytest.mark.parametrize("seed", [0, 1, 5])
def test_patch_metrics_matches_event_path(seed):
    from repro.core import metrics as M

    batch, clusters = _metrics_inputs(seed)
    out = jax.jit(
        lambda b, c: ops.patch_metrics_call(b, c, width=640, height=480)
    )(batch, clusters)
    ref = M.cluster_metrics_events(batch, clusters)
    assert set(out) == set(M.METRIC_NAMES)
    for k in M.METRIC_NAMES:
        np.testing.assert_allclose(
            np.asarray(out[k]), np.asarray(ref[k]),
            rtol=1e-5, atol=1e-5, err_msg=k,
        )


def test_patch_metrics_zero_valid_window():
    from repro.core import metrics as M

    batch, clusters = _metrics_inputs(2)
    batch = batch._replace(valid=jnp.zeros_like(batch.valid))
    from repro.core.grid_clustering import GridConfig, grid_cluster

    clusters = grid_cluster(batch, GridConfig())
    out = ops.patch_metrics_call(batch, clusters, width=640, height=480)
    for k in M.METRIC_NAMES:
        assert float(np.abs(np.asarray(out[k])).max()) == 0.0, k
