"""Fleet engine: per-sensor bit-identity with N independent streaming
pipelines (and hence with the scan driver) under arbitrary feed
interleavings — idle sensors, chunks splitting windows, a sensor
mid-tag-rollover — plus atomic feed validation and sensor-sharded
carries."""
import functools

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from test_streaming import _assert_stream_equals_scan

from repro.core.pipeline import (
    FleetPipeline,
    PipelineConfig,
    StreamingPipeline,
    run_recording_scan,
    tier_capacity,
)


@functools.lru_cache(maxsize=None)
def _fleet_recordings(n: int = 4, duration_s: float = 0.3):
    from repro.data.synthetic import make_recording

    return tuple(
        make_recording(seed=20 + s, duration_s=duration_s, n_rsos=1 + s % 2)
        for s in range(n)
    )


def _interleave(fp: FleetPipeline, recs, cuts_per_sensor, idle=()):
    """Feed every sensor its recording split at per-sensor cut indices.

    ``cuts_per_sensor[s]`` is a list of event indices; feeds are aligned
    round-robin (feed i takes sensor s from its previous cut to cut i),
    ``idle`` marks (feed, sensor) pairs fed ``None`` that round (their
    chunk shifts to the next feed). Ends with a flush. Returns per-sensor
    lists of ScanResults.
    """
    s_count = len(recs)
    n_feeds = max(len(c) for c in cuts_per_sensor) + 1
    prev = [0] * s_count
    parts = [[] for _ in range(s_count)]
    for i in range(n_feeds):
        chunks = []
        for s, rec in enumerate(recs):
            if (i, s) in idle and i < n_feeds - 1:
                chunks.append(None)
                continue
            cut = (
                len(rec)
                if i >= len(cuts_per_sensor[s])
                else min(max(cuts_per_sensor[s][i], prev[s]), len(rec))
            )
            if i == n_feeds - 1:
                cut = len(rec)
            chunks.append(
                (rec.x[prev[s]:cut], rec.y[prev[s]:cut],
                 rec.t[prev[s]:cut], rec.p[prev[s]:cut])
            )
            prev[s] = cut
        out = fp.feed(chunks)
        for s in range(s_count):
            parts[s].append(out.sensor(s))
    tail = fp.flush()
    for s in range(s_count):
        parts[s].append(tail.sensor(s))
    return parts


def test_fleet_single_feed_equals_scan_per_sensor():
    recs = _fleet_recordings()
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=len(recs))
    parts = _interleave(fp, recs, [[] for _ in recs])
    for s, rec in enumerate(recs):
        scan = run_recording_scan(rec, config)
        _assert_stream_equals_scan(parts[s], scan)


@settings(max_examples=4, deadline=None)
@given(st.lists(st.integers(0, 10_000_000), min_size=4, max_size=12))
def test_fleet_random_interleaving_bit_identical(raw):
    recs = _fleet_recordings()
    config = PipelineConfig()
    # Derive per-sensor cut lists and idle rounds from the random draw, so
    # sensors close different window counts per feed (ragged padding) and
    # some sensors skip rounds entirely.
    cuts = [
        sorted(c % (len(recs[s]) + 1) for j, c in enumerate(raw) if j % 4 == s)
        for s in range(len(recs))
    ]
    idle = {(raw[0] % 3, raw[1] % len(recs)), (raw[-1] % 3, raw[-2] % len(recs))}
    fp = FleetPipeline(config, n_sensors=len(recs))
    parts = _interleave(fp, recs, cuts, idle=idle)
    for s, rec in enumerate(recs):
        scan = run_recording_scan(rec, config)
        _assert_stream_equals_scan(parts[s], scan)


def test_fleet_matches_independent_streams_feed_by_feed():
    recs = _fleet_recordings()
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=len(recs))
    sps = [StreamingPipeline(config) for _ in recs]
    thirds = [[len(r) // 3, 2 * len(r) // 3] for r in recs]
    prev = [0] * len(recs)
    for i in range(3):
        chunks = []
        for s, rec in enumerate(recs):
            cut = len(rec) if i == 2 else thirds[s][i]
            chunks.append(
                (rec.x[prev[s]:cut], rec.y[prev[s]:cut],
                 rec.t[prev[s]:cut], rec.p[prev[s]:cut])
            )
            prev[s] = cut
        out = fp.feed(chunks)
        for s in range(len(recs)):
            ref = sps[s].feed(*chunks[s])
            got = out.sensor(s)
            assert got.num_windows == ref.num_windows
            for field in ref.clusters._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(got.clusters, field)),
                    np.asarray(getattr(ref.clusters, field)),
                    err_msg=f"feed {i} sensor {s} clusters.{field}",
                )
            for field in ref.final_tracks._fields:
                np.testing.assert_array_equal(
                    np.asarray(getattr(got.final_tracks, field)),
                    np.asarray(getattr(ref.final_tracks, field)),
                    err_msg=f"feed {i} sensor {s} final_tracks.{field}",
                )
    fo, so = fp.flush(), [sp.flush() for sp in sps]
    for s in range(len(recs)):
        np.testing.assert_array_equal(
            np.asarray(fo.sensor(s).clusters.count),
            np.asarray(so[s].clusters.count),
        )


def test_fleet_sensor_mid_tag_rollover_keeps_identity():
    recs = _fleet_recordings()
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=len(recs))
    fp._tag_limit = 4  # force per-sensor atlas re-zeroing every few windows
    cuts = [list(range(0, len(r), max(len(r) // 6, 1))) for r in recs]
    parts = _interleave(fp, recs, cuts)
    assert any(c.next_tag <= 4 for c in fp.state.cursors)
    for s, rec in enumerate(recs):
        scan = run_recording_scan(rec, config)
        _assert_stream_equals_scan(parts[s], scan)


def test_fleet_without_tracking():
    recs = _fleet_recordings()[:2]
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=2, with_tracking=False)
    parts = _interleave(fp, recs, [[len(r) // 2] for r in recs])
    for s, rec in enumerate(recs):
        scan = run_recording_scan(rec, config, with_tracking=False)
        assert all(p.tracks is None and p.final_tracks is None for p in parts[s])
        _assert_stream_equals_scan(parts[s], scan, with_tracking=False)


def test_fleet_feed_rejects_bad_chunk_atomically():
    recs = _fleet_recordings()[:2]
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=2)
    r0, r1 = recs
    bad_t = r1.t[:10][::-1].copy()  # unsorted within the chunk
    with pytest.raises(ValueError, match="sensor 1"):
        fp.feed([
            (r0.x[:10], r0.y[:10], r0.t[:10], r0.p[:10]),
            (r1.x[:10], r1.y[:10], bad_t, r1.p[:10]),
        ])
    # NO sensor absorbed anything — the whole feed was rejected.
    assert all(c.pending_count == 0 for c in fp.state.cursors)
    parts = _interleave(fp, recs, [[len(r) // 2] for r in recs])
    for s, rec in enumerate(recs):
        _assert_stream_equals_scan(parts[s], run_recording_scan(rec, config))


def test_fleet_feed_rejects_regressing_feed_boundary():
    recs = _fleet_recordings()[:2]
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=2)
    half = [len(r) // 2 for r in recs]
    fp.feed([
        (r.x[:h], r.y[:h], r.t[:h], r.p[:h]) for r, h in zip(recs, half)
    ])
    with pytest.raises(ValueError, match="monotonically non-decreasing"):
        fp.feed([
            (recs[0].x[:5], recs[0].y[:5], recs[0].t[:5], recs[0].p[:5]),
            None,
        ])


def test_fleet_feed_wrong_chunk_count():
    fp = FleetPipeline(PipelineConfig(), n_sensors=3)
    with pytest.raises(ValueError, match="3 per-sensor chunks"):
        fp.feed([None, None])


def test_fleet_empty_feed_closes_nothing():
    recs = _fleet_recordings()[:2]
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=2)
    out = fp.feed([None, None])
    assert out.total_windows == 0
    assert all(out.sensor(s).num_windows == 0 for s in range(2))
    # Tiny chunks that cannot close a window stay pending per sensor.
    out = fp.feed([
        (r.x[:3], r.y[:3], r.t[:3], r.p[:3]) for r in recs
    ])
    assert out.total_windows == 0
    assert [c.pending_count for c in fp.state.cursors] == [3, 3]


def test_fleet_state_sensor_count_mismatch():
    fp = FleetPipeline(PipelineConfig(), n_sensors=2)
    with pytest.raises(ValueError, match="2 sensors"):
        FleetPipeline(PipelineConfig(), n_sensors=3, state=fp.state)


def test_fleet_sensor_sharded_carries(subproc):
    """4 sensors over a 4-device 'sensor' mesh: carry leaves are sharded
    over the sensor axis and outputs stay bit-identical to the unsharded
    fleet."""
    out = subproc(
        """
import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.pipeline import FleetPipeline, PipelineConfig
from repro.data.synthetic import make_recording
from repro.launch.mesh import make_mesh

assert jax.device_count() == 4
mesh = make_mesh((4,), ("sensor",))
config = PipelineConfig()
recs = [make_recording(seed=20 + s, duration_s=0.2, n_rsos=1) for s in range(4)]
chunks = [(r.x, r.y, r.t, r.p) for r in recs]

plain = FleetPipeline(config, n_sensors=4)
sharded = FleetPipeline(config, n_sensors=4, mesh=mesh)
spec = sharded.state.atlas.sharding.spec
assert "sensor" in str(spec), spec

a = plain.feed(chunks)
b = sharded.feed(chunks)
np.testing.assert_array_equal(
    np.asarray(a.clusters.count), np.asarray(b.clusters.count)
)
for field in a.final_tracks._fields:
    np.testing.assert_array_equal(
        np.asarray(getattr(a.final_tracks, field)),
        np.asarray(getattr(b.final_tracks, field)),
        err_msg=field,
    )
ta, tb = plain.flush(), sharded.flush()
np.testing.assert_array_equal(
    np.asarray(ta.clusters.count), np.asarray(tb.clusters.count)
)
print("SHARDED-FLEET-OK")
""",
        device_count=4,
    )
    assert "SHARDED-FLEET-OK" in out


# ---------------------------------------------------------------------------
# Slot pool: grow (tier promotion), reset (slot recycling), per-slot flush.
# ---------------------------------------------------------------------------

def _feed_whole(fp, slot, rec):
    """Feed a whole recording into one slot in two chunks; return parts."""
    half = len(rec) // 2
    parts = []
    for lo, hi in ((0, half), (half, len(rec))):
        chunks = [None] * fp.n_sensors
        chunks[slot] = (rec.x[lo:hi], rec.y[lo:hi], rec.t[lo:hi], rec.p[lo:hi])
        parts.append(fp.feed(chunks).sensor(slot))
    parts.append(fp.flush_slots([slot]).sensor(slot))
    return parts


def test_tier_capacity_schedule():
    assert [tier_capacity(n, (4, 8, 16)) for n in (1, 4, 5, 8, 9, 16)] == \
        [4, 4, 8, 8, 16, 16]
    assert tier_capacity(17, (4, 8, 16)) == 32  # doubles past the last tier
    assert tier_capacity(33, (4, 8, 16)) == 64
    with pytest.raises(ValueError, match="at least one"):
        tier_capacity(0)


def test_fleet_grow_preserves_live_sensor_identity():
    recs = _fleet_recordings()
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=2)
    # Half-feed sensors 0/1, promote the pool mid-stream, then finish them
    # while two new sensors stream on the freshly grown slots.
    half = [len(r) // 2 for r in recs[:2]]
    first = fp.feed([
        (r.x[:h], r.y[:h], r.t[:h], r.p[:h]) for r, h in zip(recs, half)
    ])
    parts = {s: [first.sensor(s)] for s in range(2)}
    fp.grow(4)
    assert fp.n_sensors == 4 and len(fp.state.cursors) == 4
    assert fp.state.atlas.shape[0] == 4
    second = fp.feed([
        (recs[0].x[half[0]:], recs[0].y[half[0]:],
         recs[0].t[half[0]:], recs[0].p[half[0]:]),
        (recs[1].x[half[1]:], recs[1].y[half[1]:],
         recs[1].t[half[1]:], recs[1].p[half[1]:]),
        (recs[2].x, recs[2].y, recs[2].t, recs[2].p),
        (recs[3].x, recs[3].y, recs[3].t, recs[3].p),
    ])
    tail = fp.flush()
    for s in range(4):
        if s >= 2:
            parts[s] = [second.sensor(s), tail.sensor(s)]
        else:
            parts[s] += [second.sensor(s), tail.sensor(s)]
        _assert_stream_equals_scan(parts[s], run_recording_scan(recs[s], config))


def test_fleet_grow_rejects_shrink_and_is_noop_at_size():
    fp = FleetPipeline(PipelineConfig(), n_sensors=2)
    with pytest.raises(ValueError, match="shrink"):
        fp.grow(1)
    fp.grow(2)  # no-op
    assert fp.n_sensors == 2


def test_fleet_reset_slots_recycles_bit_identically():
    recs = _fleet_recordings()
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=2)
    # First tenant streams to completion on slot 0 (slot 1 idles along).
    parts_a = _feed_whole(fp, 0, recs[0])
    _assert_stream_equals_scan(parts_a, run_recording_scan(recs[0], config))
    # Recycle slot 0; the second tenant restarts from t=0 — without the
    # reset its timestamps would regress and its atlas would be stale.
    fp.reset_slots([0])
    assert fp.state.cursors[0].next_tag == 0
    parts_b = _feed_whole(fp, 0, recs[1])
    _assert_stream_equals_scan(parts_b, run_recording_scan(recs[1], config))


def test_fleet_flush_slots_leaves_other_remainders_pending():
    recs = _fleet_recordings()[:2]
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=2)
    half = [len(r) // 2 for r in recs]
    first = fp.feed([
        (r.x[:h], r.y[:h], r.t[:h], r.p[:h]) for r, h in zip(recs, half)
    ])
    pending_1 = fp.state.cursors[1].pending_count
    assert pending_1 > 0
    tail0 = fp.flush_slots([0])
    # Slot 0's trailing window closed; slot 1's remainder is untouched and
    # its stream continues bit-identically.
    assert tail0.n_windows[0] == 1 and tail0.n_windows[1] == 0
    assert fp.state.cursors[0].pending_count == 0
    assert fp.state.cursors[1].pending_count == pending_1
    second = fp.feed([
        None,
        (recs[1].x[half[1]:], recs[1].y[half[1]:],
         recs[1].t[half[1]:], recs[1].p[half[1]:]),
    ])
    tail1 = fp.flush_slots([1])
    _assert_stream_equals_scan(
        [first.sensor(1), second.sensor(1), tail1.sensor(1)],
        run_recording_scan(recs[1], config),
    )


def test_fleet_final_mask_shape_validated():
    fp = FleetPipeline(PipelineConfig(), n_sensors=2)
    with pytest.raises(ValueError, match="final mask"):
        fp.feed([None, None], final=np.zeros(3, bool))


def test_fleet_grow_resharding(subproc):
    """Tier promotion on a 4-device sensor mesh: a 2-slot carry cannot
    shard over 4 devices (replicated), but after growing to 4 the carry
    is sensor-sharded — and outputs match the unsharded fleet."""
    out = subproc(
        """
import jax
import numpy as np

from repro.core.pipeline import FleetPipeline, PipelineConfig
from repro.data.synthetic import make_recording
from repro.launch.mesh import make_mesh

assert jax.device_count() == 4
mesh = make_mesh((4,), ("sensor",))
config = PipelineConfig()
recs = [make_recording(seed=20 + s, duration_s=0.15, n_rsos=1) for s in range(4)]
chunks = [(r.x, r.y, r.t, r.p) for r in recs]

plain = FleetPipeline(config, n_sensors=2)
sharded = FleetPipeline(config, n_sensors=2, mesh=mesh)
assert "sensor" not in str(sharded.state.atlas.sharding.spec)  # 2 % 4 != 0
for fp in (plain, sharded):
    fp.feed(chunks[:2])
    fp.grow(4)
spec = sharded.state.atlas.sharding.spec
assert "sensor" in str(spec), spec
a = plain.feed([None, None, chunks[2], chunks[3]])
b = sharded.feed([None, None, chunks[2], chunks[3]])
np.testing.assert_array_equal(
    np.asarray(a.clusters.count), np.asarray(b.clusters.count)
)
ta, tb = plain.flush(), sharded.flush()
np.testing.assert_array_equal(
    np.asarray(ta.clusters.count), np.asarray(tb.clusters.count)
)
for field in ta.final_tracks._fields:
    np.testing.assert_array_equal(
        np.asarray(getattr(ta.final_tracks, field)),
        np.asarray(getattr(tb.final_tracks, field)),
        err_msg=field,
    )
print("GROW-RESHARD-OK")
""",
        device_count=4,
    )
    assert "GROW-RESHARD-OK" in out


def test_fleet_shrink_preserves_surviving_slots():
    """Demote a 4-slot pool to 2 mid-stream: the surviving slots' carries
    are untouched and their streams finish bit-identical to the scan."""
    recs = _fleet_recordings()[:2]
    config = PipelineConfig()
    fp = FleetPipeline(config, n_sensors=4)
    half = [len(r) // 2 for r in recs]
    first = fp.feed([
        (recs[0].x[:half[0]], recs[0].y[:half[0]],
         recs[0].t[:half[0]], recs[0].p[:half[0]]),
        (recs[1].x[:half[1]], recs[1].y[:half[1]],
         recs[1].t[:half[1]], recs[1].p[:half[1]]),
        None,
        None,
    ])
    fp.shrink(2, occupied=(0, 1))
    assert fp.n_sensors == 2 and len(fp.state.cursors) == 2
    assert fp.state.atlas.shape[0] == 2
    second = fp.feed([
        (recs[0].x[half[0]:], recs[0].y[half[0]:],
         recs[0].t[half[0]:], recs[0].p[half[0]:]),
        (recs[1].x[half[1]:], recs[1].y[half[1]:],
         recs[1].t[half[1]:], recs[1].p[half[1]:]),
    ])
    tail = fp.flush()
    for s in range(2):
        _assert_stream_equals_scan(
            [first.sensor(s), second.sensor(s), tail.sensor(s)],
            run_recording_scan(recs[s], config),
        )


def test_fleet_shrink_validation():
    fp = FleetPipeline(PipelineConfig(), n_sensors=4)
    with pytest.raises(ValueError, match="at least one"):
        fp.shrink(0)
    with pytest.raises(ValueError, match="use grow"):
        fp.shrink(8)
    with pytest.raises(ValueError, match=r"occupied slots \[3\]"):
        fp.shrink(2, occupied=(0, 3))
    fp.shrink(4)  # no-op at current size
    assert fp.n_sensors == 4
    fp.shrink(2, occupied=(0, 1))
    assert fp.n_sensors == 2
    fp.grow(4)  # and back up: the inverse round-trips
    assert fp.n_sensors == 4
