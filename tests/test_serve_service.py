"""DetectionService: session lifecycle over the slot-pooled fleet.

Pins the two service-layer contracts from DESIGN.md Sec. 11:

* **Bit-identity under churn** — for arbitrary interleavings of attach /
  feed / idle / detach (including detach-then-reattach reusing a slot
  and capacity-tier promotion mid-stream), every session's concatenated
  results equal a dedicated ``StreamingPipeline`` / scan run of the same
  chunks.
* **Compile discipline** — a churn workload cycling 1 -> max sessions
  compiles at most one fleet step per capacity tier (slot occupancy
  never appears in a compiled shape).
"""
import dataclasses
import functools

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from test_streaming import _assert_stream_equals_scan

from repro.core.events import BatcherConfig
from repro.core.pipeline import PipelineConfig, run_recording_scan
from repro.data.evas import iter_chunks
from repro.serve import AdmissionConfig, DetectionService


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now


@functools.lru_cache(maxsize=None)
def _service_recordings(n: int = 4, duration_s: float = 0.25):
    from repro.data.synthetic import make_recording

    return tuple(
        make_recording(seed=40 + s, duration_s=duration_s, n_rsos=1 + s % 2)
        for s in range(n)
    )


def _prefix(rec, n: int):
    """The recording's first ``n`` events (what a partial session saw)."""
    return dataclasses.replace(
        rec, x=rec.x[:n], y=rec.y[:n], t=rec.t[:n], p=rec.p[:n],
        kind=rec.kind[:n], obj=rec.obj[:n],
    )


def _spaced_stream(seed: int, n: int, dt_us: int = 100):
    """Synthetic evenly-spaced stream: every 100-event slice spans well
    under 20 ms, so feeds in exact ``size_threshold`` slices close exactly
    one window each (shape-deterministic for compile-count tests)."""
    rng = np.random.default_rng(seed)
    return (
        rng.integers(40, 560, n).astype(np.int64),
        rng.integers(40, 400, n).astype(np.int64),
        (np.arange(n, dtype=np.int64) + 1) * dt_us,
        rng.integers(0, 2, n).astype(np.int64),
    )


def _collect(served, parts):
    for fd in served:
        parts[fd.sid].append(fd.result)


# ---------------------------------------------------------------------------
# Bit-identity.
# ---------------------------------------------------------------------------

def test_service_sessions_bit_identical_to_scan():
    """Three sessions (forcing one tier promotion) fed live-cadence chunks
    concatenate to exactly the scan driver's outputs."""
    recs = _service_recordings()[:3]
    config = PipelineConfig()
    svc = DetectionService(config, tiers=(2, 4), clock=FakeClock())
    sids = [svc.attach(f"s{i}") for i in range(3)]
    assert svc.capacity == 4 and svc.promotions == 1
    parts = {sid: [] for sid in sids}
    chunk_lists = [list(iter_chunks(r)) for r in recs]
    for j in range(max(len(c) for c in chunk_lists)):
        for i, cl in enumerate(chunk_lists):
            if j < len(cl):
                _collect(svc.feed(sids[i], *cl[j]), parts)
        _collect(svc.pump(force=True), parts)
    for i, sid in enumerate(sids):
        parts[sid].append(svc.detach(sid))
    for i, rec in enumerate(recs):
        _assert_stream_equals_scan(
            parts[sids[i]], run_recording_scan(rec, config)
        )


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_service_random_churn_bit_identical(seed):
    """Randomized attach / feed / idle / detach schedule — including slot
    recycling and mid-stream tier promotion — against per-session scan
    references over exactly the events each session fed."""
    rng = np.random.default_rng(seed)
    recs = _service_recordings()
    config = PipelineConfig()
    clock = FakeClock()
    svc = DetectionService(
        config, tiers=(2, 4),
        admission=AdmissionConfig(max_delay_s=0.02, max_items=600),
        clock=clock,
    )
    live: dict[int, dict] = {}
    finished: list[tuple[int, dict]] = []
    parts: dict[int, list] = {}

    def detach(sid):
        parts[sid].append(svc.detach(sid))
        finished.append((sid, live.pop(sid)))

    for _ in range(40):
        clock.now += 0.004
        op = int(rng.integers(0, 10))
        if op < 3 and len(live) < 4:
            sid = svc.attach()
            live[sid] = {"rec": recs[int(rng.integers(len(recs)))], "pos": 0}
            parts[sid] = []
        elif op < 8 and live:
            sid = int(rng.choice(sorted(live)))
            s = live[sid]
            if s["pos"] < len(s["rec"]):
                cut = min(s["pos"] + int(rng.integers(1, 1200)), len(s["rec"]))
                r = s["rec"]
                _collect(
                    svc.feed(
                        sid,
                        r.x[s["pos"]:cut], r.y[s["pos"]:cut],
                        r.t[s["pos"]:cut], r.p[s["pos"]:cut],
                    ),
                    parts,
                )
                s["pos"] = cut
        elif op < 9:
            _collect(svc.pump(force=True), parts)
        elif live:
            detach(int(rng.choice(sorted(live))))
    for sid in sorted(live):
        detach(sid)

    for sid, s in finished:
        n = s["pos"]
        if n == 0:
            assert sum(p.num_windows for p in parts[sid]) == 0
            continue
        scan = run_recording_scan(_prefix(s["rec"], n), config)
        _assert_stream_equals_scan(parts[sid], scan)


def test_service_slot_recycling_and_promotion_bookkeeping():
    svc = DetectionService(
        PipelineConfig(), tiers=(2, 4), clock=FakeClock()
    )
    a, b = svc.attach("a"), svc.attach("b")
    assert svc.capacity == 2 and svc.promotions == 0
    c = svc.attach("c")  # pool full -> tier promotion
    assert svc.capacity == 4 and svc.promotions == 1
    slot_b = svc.session(b).slot
    svc.detach(b)
    assert svc.session(b).state == "detached"
    d = svc.attach("d")  # lowest free slot is b's old one
    assert svc.session(d).slot == slot_b
    assert svc.n_sessions == 3
    # Detached sessions are closed to traffic; unknown sids are errors.
    with pytest.raises(RuntimeError, match="detached"):
        svc.feed(b, *_spaced_stream(0, 10))
    with pytest.raises(KeyError, match="unknown session"):
        svc.feed(12345, *_spaced_stream(0, 10))
    for sid in (a, c, d):
        svc.detach(sid)
    assert svc.n_sessions == 0


# ---------------------------------------------------------------------------
# Compile discipline.
# ---------------------------------------------------------------------------

def test_service_churn_compiles_one_fleet_step_per_tier():
    """Cycling 1 -> 4 sessions over tiers (2, 4) — with detach-and-reattach
    churn at the end — traces exactly ONE fleet-step compile per capacity
    tier: slot occupancy is never part of a compiled shape."""
    from repro.core.pipeline import fleet as fleet_mod

    # A config no other test jits, so the step cache starts cold and
    # every compile shows up in STEP_TRACES.
    config = PipelineConfig(
        batcher=BatcherConfig(size_threshold=100, capacity=128)
    )
    svc = DetectionService(
        config, tiers=(2, 4),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        clock=FakeClock(),
    )
    streams = {}

    def feed_round(sids):
        for sid in sids:
            x, y, t, p = streams[sid]["data"]
            pos = streams[sid]["pos"]
            svc.feed(sid, x[pos:pos + 100], y[pos:pos + 100],
                     t[pos:pos + 100], p[pos:pos + 100])
            streams[sid]["pos"] = pos + 100
        svc.pump(force=True)

    def attach():
        sid = svc.attach()
        streams[sid] = {"data": _spaced_stream(seed=50 + sid, n=2000), "pos": 0}
        return sid

    fleet_mod.STEP_TRACES.clear()
    live = []
    for target in (1, 2, 3, 4):  # churn up: 1 -> max sessions
        while len(live) < target:
            live.append(attach())
        feed_round(live)
    while live:  # churn down: exact-window feeds leave no remainder, so
        svc.detach(live.pop())  # detach flushes close nothing (no step)
    live = [attach(), attach()]  # recycled slots at the promoted tier
    feed_round(live)

    traces = [tr for tr in fleet_mod.STEP_TRACES if tr[2] == 128]
    assert all(w == 1 for (_, w, _, _) in traces), traces
    assert all(u is False for (*_, u) in traces), traces
    per_tier = {}
    for s, *_ in traces:
        per_tier[s] = per_tier.get(s, 0) + 1
    assert per_tier == {2: 1, 4: 1}, traces


# ---------------------------------------------------------------------------
# Admission, validation, accounting.
# ---------------------------------------------------------------------------

def test_service_admission_micro_batches_sessions():
    clock = FakeClock()
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=0.02, max_items=300),
        clock=clock,
    )
    s0, s1 = svc.attach(), svc.attach()
    d0, d1 = _spaced_stream(1, 400), _spaced_stream(2, 400)
    assert svc.feed(s0, *[a[:150] for a in d0]) == []  # 150 < 300, fresh
    clock.now += 0.010
    assert svc.feed(s1, *[a[:100] for a in d1]) == []  # 250 < 300, 10 ms
    clock.now += 0.011  # oldest chunk is now 21 ms > max_delay
    served = svc.feed(s0, *[a[150:151] for a in d0])
    assert {fd.sid for fd in served} == {s0, s1}  # one step served both
    assert svc.session(s0).stats.steps == 1
    assert svc.session(s1).stats.steps == 1


def test_service_feed_rejects_bad_chunk_atomically():
    recs = _service_recordings()
    config = PipelineConfig()
    svc = DetectionService(config, tiers=(2,), clock=FakeClock())
    sid = svc.attach()
    rec = recs[0]
    bad_t = rec.t[:20][::-1].copy()
    with pytest.raises(ValueError, match=f"session {sid}"):
        svc.feed(sid, rec.x[:20], rec.y[:20], bad_t, rec.p[:20])
    # Nothing was queued — the session (and the fleet) never saw the chunk.
    assert svc.backlog(sid) == 0
    assert svc.session(sid).stats.feeds == 0
    parts = []
    for chunk in iter_chunks(rec):
        _collect(svc.feed(sid, *chunk), {sid: parts})
        _collect(svc.pump(force=True), {sid: parts})
    parts.append(svc.detach(sid))
    _assert_stream_equals_scan(parts, run_recording_scan(rec, config))


def test_service_monotone_enforced_across_session_feeds():
    svc = DetectionService(PipelineConfig(), tiers=(2,), clock=FakeClock())
    sid = svc.attach()
    x, y, t, p = _spaced_stream(3, 200)
    svc.feed(sid, x[:100], y[:100], t[:100], p[:100])
    with pytest.raises(ValueError, match="monotonically non-decreasing"):
        svc.feed(sid, x[:10], y[:10], t[:10], p[:10])  # regresses in time


def test_service_latency_and_backlog_accounting():
    clock = FakeClock()
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        clock=clock,
    )
    sid = svc.attach("cam")
    x, y, t, p = _spaced_stream(4, 300)
    svc.feed(sid, x[:50], y[:50], t[:50], p[:50])
    assert svc.backlog(sid) == 50  # queued service-side
    clock.now += 0.005
    served = svc.pump(force=True)
    assert len(served) == 1 and served[0].latency_ms == pytest.approx(5.0)
    # 50 events cannot close a window; they sit in the slot's batcher
    # remainder now — still this session's backlog.
    assert served[0].result.num_windows == 0
    assert svc.backlog(sid) == 50
    stats = svc.session(sid).stats
    assert stats.feeds == 1 and stats.events == 50 and stats.steps == 1
    assert stats.latency_percentile(50) == pytest.approx(5.0)
    svc.detach(sid)
    assert svc.backlog(sid) == 0  # remainder flushed with the tail

    # Empty chunks are heartbeats: accepted, never queued, never stepped.
    sid2 = svc.attach()
    assert svc.feed(sid2, *[np.zeros(0, np.int64)] * 4) == []
    assert svc.session(sid2).stats.feeds == 0
    assert svc.pump(force=True) == []


def test_service_rejects_bad_tiers():
    with pytest.raises(ValueError, match="tiers"):
        DetectionService(PipelineConfig(), tiers=(4, 2))
    with pytest.raises(ValueError, match="tiers"):
        DetectionService(PipelineConfig(), tiers=())


def test_detach_discards_stale_admission_entries():
    """A detached session's queued-chunk entries must not keep aging in
    the admitter — otherwise the next session's first feed fires the time
    threshold spuriously instead of micro-batching its own window."""
    clock = FakeClock()
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=0.02, max_items=10_000),
        clock=clock,
    )
    a = svc.attach()
    svc.feed(a, *_spaced_stream(10, 100))  # queued, admission not fired
    clock.now += 0.005
    svc.detach(a)  # consumes the chunk out of band
    clock.now += 0.05  # a's dead entry would now be 55 ms old
    b = svc.attach()
    assert svc.feed(b, *_spaced_stream(11, 50)) == []  # b batches normally
    assert svc.session(b).stats.steps == 0


def test_forget_evicts_detached_records_only():
    svc = DetectionService(PipelineConfig(), tiers=(2,), clock=FakeClock())
    a, b = svc.attach("a"), svc.attach("b")
    svc.detach(a)
    assert svc.detached_sessions == [a]
    with pytest.raises(RuntimeError, match="detach first"):
        svc.forget(b)
    svc.forget(a)
    assert svc.detached_sessions == []
    with pytest.raises(KeyError):
        svc.session(a)
    svc.forget(12345)  # unknown sid: no-op
    svc.detach(b)


def test_latency_samples_are_bounded():
    from repro.serve.sessions import MAX_LATENCY_SAMPLES, SessionStats

    stats = SessionStats()
    for i in range(MAX_LATENCY_SAMPLES + 100):
        stats.record_latency(float(i))
    assert len(stats.latency_ms) == MAX_LATENCY_SAMPLES
    assert stats.latency_ms[0] == 100.0  # oldest samples dropped
    assert stats.latency_percentile(100) == float(MAX_LATENCY_SAMPLES + 99)


# ---------------------------------------------------------------------------
# Fault tolerance (DESIGN.md Sec. 13). The end-to-end chaos gate lives in
# test_chaos.py; these pin each degraded mode in isolation.
# ---------------------------------------------------------------------------

from repro.serve import FaultConfig  # noqa: E402


class _FlakyFleet:
    """Fleet wrapper whose dispatch raises the next ``fail`` times (the
    service's retry loop wraps ``feed_async``; ``feed`` is intercepted
    too so direct-fleet callers fail the same way)."""

    def __init__(self, fleet, fail: int):
        self._fleet = fleet
        self.fail = fail

    def __getattr__(self, name):
        return getattr(self._fleet, name)

    def _maybe_fail(self):
        if self.fail > 0:
            self.fail -= 1
            raise RuntimeError("boom")

    def feed(self, *args, **kwargs):
        self._maybe_fail()
        return self._fleet.feed(*args, **kwargs)

    def feed_async(self, *args, **kwargs):
        self._maybe_fail()
        return self._fleet.feed_async(*args, **kwargs)


def test_fault_config_validation():
    for kw in (
        {"on_validation_error": "panic"},
        {"shed_policy": "newest"},
        {"queue_budget_events": 0},
        {"heartbeat_timeout_s": 0.0},
        {"max_step_retries": -1},
        {"retry_backoff_s": -0.1},
        {"straggler_factor": 1.0},
    ):
        with pytest.raises(ValueError):
            FaultConfig(**kw)


def test_quarantine_on_validation_fault():
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        faults=FaultConfig(on_validation_error="quarantine"),
        clock=FakeClock(),
    )
    a = svc.attach("suspect")
    slot_a = svc.session(a).slot
    x, y, t, p = _spaced_stream(20, 200)
    svc.feed(a, x[:100], y[:100], t[:100], p[:100])
    assert svc.feed(a, x[:10], y[:10], t[:10], p[:10]) == []  # regresses
    sess = svc.session(a)
    assert sess.state == "quarantined"
    assert svc.quarantines == 1
    assert svc.quarantined_sessions == [a]
    assert sess.queued_events == 0  # suspect queue dropped
    assert sess.stats.validation_failures == 1
    assert [e.kind for e in sess.errors] == ["validation"]
    assert svc.errors == sess.errors
    with pytest.raises(RuntimeError, match="quarantined"):
        svc.feed(a, x[:1], y[:1], t[:1], p[:1])
    b = svc.attach("next-tenant")  # the slot was recycled
    assert svc.session(b).slot == slot_a
    svc.forget(a)  # quarantined records can be forgotten
    assert svc.quarantined_sessions == []


def test_quarantine_isolates_other_sessions():
    """A garbage-coordinate quarantine on one session never perturbs a
    concurrently streaming one — its outputs still equal the scan."""
    rec = _service_recordings()[0]
    config = PipelineConfig()
    svc = DetectionService(
        config, tiers=(2,),
        faults=FaultConfig(on_validation_error="quarantine"),
        clock=FakeClock(),
    )
    good, bad = svc.attach("good"), svc.attach("bad")
    parts = {good: [], bad: []}
    bx, by, bt, bp = _spaced_stream(21, 100)
    chunks = list(iter_chunks(rec))
    for j, chunk in enumerate(chunks):
        _collect(svc.feed(good, *chunk), parts)
        if j == 1:
            garbage = bx + (np.int64(1) << 31)
            assert svc.feed(bad, garbage, by, bt, bp) == []
            assert svc.session(bad).state == "quarantined"
        _collect(svc.pump(force=True), parts)
    parts[good].append(svc.detach(good))
    _assert_stream_equals_scan(parts[good], run_recording_scan(rec, config))


def test_heartbeat_eviction_flushes_and_recycles():
    clock = FakeClock()
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        faults=FaultConfig(heartbeat_timeout_s=0.05),
        clock=clock,
    )
    a, b = svc.attach("alive"), svc.attach("silent")
    xa, ya, ta, pa = _spaced_stream(22, 300)
    xb, yb, tb, pb = _spaced_stream(23, 300)
    svc.feed(a, xa[:100], ya[:100], ta[:100], pa[:100])
    svc.feed(b, xb[:100], yb[:100], tb[:100], pb[:100])
    clock.now += 0.03
    svc.feed(a, xa[100:200], ya[100:200], ta[100:200], pa[100:200])  # beat
    assert svc.session(b).state == "live"  # 30 ms silent: still inside
    clock.now += 0.03
    svc.feed(a, xa[200:], ya[200:], ta[200:], pa[200:])  # sweeps b out
    sess_b = svc.session(b)
    assert sess_b.state == "evicted"
    assert svc.evictions == 1 and svc.evicted_sessions == [b]
    assert sess_b.tail_result is not None  # queued events flushed to a tail
    assert sess_b.tail_result.num_windows >= 1
    assert [e.kind for e in sess_b.errors] == ["evicted"]
    with pytest.raises(RuntimeError, match="evicted"):
        svc.feed(b, xb[:1], yb[:1], tb[:1], pb[:1])
    c = svc.attach("next")  # slot recycled
    assert svc.session(c).slot == 1
    svc.forget(b)
    assert svc.evicted_sessions == []
    # The survivor's stream is intact: detach flushes its remainder.
    assert svc.detach(a).num_windows >= 0


def test_eviction_demotes_capacity_tier():
    clock = FakeClock()
    svc = DetectionService(
        PipelineConfig(), tiers=(2, 4),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        faults=FaultConfig(heartbeat_timeout_s=0.05),
        clock=clock,
    )
    a, b = svc.attach(), svc.attach()
    c = svc.attach()  # promotes to capacity 4, slot 2
    assert svc.capacity == 4 and svc.session(c).slot == 2
    x, y, t, p = _spaced_stream(24, 300)
    for sid in (a, b, c):
        svc.feed(sid, x[:100], y[:100], t[:100], p[:100])
    clock.now += 0.03
    for sid in (a, b):
        svc.feed(sid, x[100:200], y[100:200], t[100:200], p[100:200])
    clock.now += 0.03  # c is now 60 ms silent; a and b only 30
    svc.pump(force=True)
    assert svc.session(c).state == "evicted"
    assert svc.capacity == 2 and svc.demotions == 1  # tail slot freed
    # Survivors keep streaming at the demoted tier.
    for sid in (a, b):
        svc.feed(sid, x[200:], y[200:], t[200:], p[200:])
        assert svc.detach(sid) is not None


def test_queue_budget_reject_sheds_whole_chunk():
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        faults=FaultConfig(queue_budget_events=100, shed_policy="reject"),
        clock=FakeClock(),
    )
    sid = svc.attach()
    x, y, t, p = _spaced_stream(25, 240)
    assert svc.feed(sid, x[:80], y[:80], t[:80], p[:80]) == []
    assert svc.feed(sid, x[80:160], y[80:160], t[80:160], p[80:160]) == []
    st_ = svc.session(sid).stats
    assert st_.offered_events == 160 and st_.events == 80
    assert st_.shed_events == 80 and st_.shed_chunks == 1
    assert st_.offered_events == st_.events + st_.shed_events  # exact
    assert svc.session(sid).queued_events == 80  # oldest data kept
    assert svc._admit.pending_weight == 80  # admitter re-stated exactly
    svc.pump(force=True)  # queue drains; the stream has a gap, which the
    # pipeline tolerates: later chunks still validate against true last_t
    assert svc.feed(sid, x[160:], y[160:], t[160:], p[160:]) == []
    assert svc.session(sid).stats.events == 160


def test_queue_budget_drop_oldest_keeps_newest():
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        faults=FaultConfig(
            queue_budget_events=100, shed_policy="drop_oldest"
        ),
        clock=FakeClock(),
    )
    sid = svc.attach()
    x, y, t, p = _spaced_stream(26, 160)
    svc.feed(sid, x[:80], y[:80], t[:80], p[:80])
    svc.feed(sid, x[80:], y[80:], t[80:], p[80:])  # evicts the older 80
    sess = svc.session(sid)
    assert sess.queued_events == 80
    assert sess.stats.shed_events == 80 and sess.stats.shed_chunks == 1
    assert sess.stats.events == 80  # net of the un-counted shed chunk
    assert sess.stats.offered_events == 160
    assert svc._admit.pending_weight == 80
    # A single over-budget chunk keeps only its newest `budget` events.
    svc2 = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        faults=FaultConfig(
            queue_budget_events=100, shed_policy="drop_oldest"
        ),
        clock=FakeClock(),
    )
    sid2 = svc2.attach()
    x2, y2, t2, p2 = _spaced_stream(27, 150)
    svc2.feed(sid2, x2, y2, t2, p2)
    sess2 = svc2.session(sid2)
    assert sess2.queued_events == 100
    assert sess2.stats.shed_events == 50
    assert sess2.stats.offered_events == 150


def test_step_retry_heals_transient_failure():
    sleeps = []
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        faults=FaultConfig(max_step_retries=2, retry_backoff_s=0.01),
        clock=FakeClock(),
        sleep=sleeps.append,
    )
    sid = svc.attach()
    svc._fleet = _FlakyFleet(svc._fleet, fail=1)
    svc.feed(sid, *_spaced_stream(28, 100))
    served = svc.pump(force=True)
    assert len(served) == 1 and served[0].sid == sid
    assert svc.step_retries == 1 and svc.degraded_rounds == 0
    assert sleeps == [0.01]  # exponential base, first attempt


def test_degraded_round_restores_chunks_bit_identically():
    sleeps = []
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        faults=FaultConfig(
            max_step_retries=2, retry_backoff_s=0.01,
            degrade_on_step_failure=True,
        ),
        clock=FakeClock(),
        sleep=sleeps.append,
    )
    sid = svc.attach()
    chunk = _spaced_stream(29, 100)
    svc.feed(sid, *chunk)
    svc._fleet = _FlakyFleet(svc._fleet, fail=3)  # all attempts fail
    assert svc.pump(force=True) == []  # degraded, not raised
    sess = svc.session(sid)
    assert svc.degraded_rounds == 1 and sess.stats.degraded_rounds == 1
    assert svc.step_retries == 2
    assert sleeps == [0.01, 0.02]  # exponential backoff between attempts
    assert sess.queued_events == 100  # chunk restored, nothing lost
    assert sess.state == "live"
    assert [e.kind for e in sess.errors] == ["degraded_round"]
    served = svc.pump(force=True)  # fleet healed: same chunk re-fed
    assert len(served) == 1
    # The re-fed round equals a never-faulted service run bitwise.
    from repro.serve.chaos import compare_outputs, concat_outputs

    ref = DetectionService(PipelineConfig(), tiers=(2,), clock=FakeClock())
    rid = ref.attach()
    ref.feed(rid, *chunk)
    ref_served = ref.pump(force=True)
    assert compare_outputs(
        concat_outputs([served[0].result, svc.detach(sid)]),
        concat_outputs([ref_served[0].result, ref.detach(rid)]),
        "degraded",
    ) == []


def test_strict_step_failure_raises_after_retries():
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        faults=FaultConfig(max_step_retries=1),  # strict: no degrade
        clock=FakeClock(),
    )
    sid = svc.attach()
    svc.feed(sid, *_spaced_stream(30, 100))
    svc._fleet = _FlakyFleet(svc._fleet, fail=2)
    with pytest.raises(RuntimeError, match="boom"):
        svc.pump(force=True)
    assert svc.step_retries == 1


def test_degraded_detach_is_retryable():
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        faults=FaultConfig(
            max_step_retries=0, degrade_on_step_failure=True
        ),
        clock=FakeClock(),
    )
    sid = svc.attach()
    svc.feed(sid, *_spaced_stream(31, 100))
    svc._fleet = _FlakyFleet(svc._fleet, fail=1)
    with pytest.raises(RuntimeError, match="retry the detach"):
        svc.detach(sid)
    sess = svc.session(sid)
    assert sess.state == "live" and sess.queued_events == 100
    assert svc.detach(sid) is not None  # healed: retry succeeds
    assert sess.state == "detached"


def test_straggler_flagging_filters_to_live_sessions():
    svc = DetectionService(
        PipelineConfig(), tiers=(4,),
        faults=FaultConfig(straggler_factor=2.0, straggler_alpha=1.0),
        clock=FakeClock(),
    )
    a, b, c = svc.attach(), svc.attach(), svc.attach()
    for _ in range(3):
        svc._health.note_latency(a, 5.0)
        svc._health.note_latency(b, 5.0)
        svc._health.note_latency(c, 50.0)  # 10x the fleet median
    assert svc.stragglers() == [c]
    svc.detach(c)  # departed sessions stop weighing on the fleet
    assert svc.stragglers() == []


def test_double_detach_and_closed_session_lifecycle():
    svc = DetectionService(PipelineConfig(), tiers=(2,), clock=FakeClock())
    a = svc.attach("once")
    svc.detach(a)
    with pytest.raises(RuntimeError, match="detached"):
        svc.detach(a)  # double detach is an error, not a silent no-op
    with pytest.raises(RuntimeError, match="detached"):
        svc.feed(a, *_spaced_stream(32, 10))
    assert svc.detached_sessions == [a]
    assert svc.session(a).stats is not None  # record stays readable
    svc.forget(a)
    with pytest.raises(KeyError):
        svc.session(a)
    svc.forget(a)  # idempotent on unknown sids
