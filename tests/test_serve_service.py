"""DetectionService: session lifecycle over the slot-pooled fleet.

Pins the two service-layer contracts from DESIGN.md Sec. 11:

* **Bit-identity under churn** — for arbitrary interleavings of attach /
  feed / idle / detach (including detach-then-reattach reusing a slot
  and capacity-tier promotion mid-stream), every session's concatenated
  results equal a dedicated ``StreamingPipeline`` / scan run of the same
  chunks.
* **Compile discipline** — a churn workload cycling 1 -> max sessions
  compiles at most one fleet step per capacity tier (slot occupancy
  never appears in a compiled shape).
"""
import dataclasses
import functools

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from test_streaming import _assert_stream_equals_scan

from repro.core.events import BatcherConfig
from repro.core.pipeline import PipelineConfig, run_recording_scan
from repro.data.evas import iter_chunks
from repro.serve import AdmissionConfig, DetectionService


class FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now


@functools.lru_cache(maxsize=None)
def _service_recordings(n: int = 4, duration_s: float = 0.25):
    from repro.data.synthetic import make_recording

    return tuple(
        make_recording(seed=40 + s, duration_s=duration_s, n_rsos=1 + s % 2)
        for s in range(n)
    )


def _prefix(rec, n: int):
    """The recording's first ``n`` events (what a partial session saw)."""
    return dataclasses.replace(
        rec, x=rec.x[:n], y=rec.y[:n], t=rec.t[:n], p=rec.p[:n],
        kind=rec.kind[:n], obj=rec.obj[:n],
    )


def _spaced_stream(seed: int, n: int, dt_us: int = 100):
    """Synthetic evenly-spaced stream: every 100-event slice spans well
    under 20 ms, so feeds in exact ``size_threshold`` slices close exactly
    one window each (shape-deterministic for compile-count tests)."""
    rng = np.random.default_rng(seed)
    return (
        rng.integers(40, 560, n).astype(np.int64),
        rng.integers(40, 400, n).astype(np.int64),
        (np.arange(n, dtype=np.int64) + 1) * dt_us,
        rng.integers(0, 2, n).astype(np.int64),
    )


def _collect(served, parts):
    for fd in served:
        parts[fd.sid].append(fd.result)


# ---------------------------------------------------------------------------
# Bit-identity.
# ---------------------------------------------------------------------------

def test_service_sessions_bit_identical_to_scan():
    """Three sessions (forcing one tier promotion) fed live-cadence chunks
    concatenate to exactly the scan driver's outputs."""
    recs = _service_recordings()[:3]
    config = PipelineConfig()
    svc = DetectionService(config, tiers=(2, 4), clock=FakeClock())
    sids = [svc.attach(f"s{i}") for i in range(3)]
    assert svc.capacity == 4 and svc.promotions == 1
    parts = {sid: [] for sid in sids}
    chunk_lists = [list(iter_chunks(r)) for r in recs]
    for j in range(max(len(c) for c in chunk_lists)):
        for i, cl in enumerate(chunk_lists):
            if j < len(cl):
                _collect(svc.feed(sids[i], *cl[j]), parts)
        _collect(svc.pump(force=True), parts)
    for i, sid in enumerate(sids):
        parts[sid].append(svc.detach(sid))
    for i, rec in enumerate(recs):
        _assert_stream_equals_scan(
            parts[sids[i]], run_recording_scan(rec, config)
        )


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_service_random_churn_bit_identical(seed):
    """Randomized attach / feed / idle / detach schedule — including slot
    recycling and mid-stream tier promotion — against per-session scan
    references over exactly the events each session fed."""
    rng = np.random.default_rng(seed)
    recs = _service_recordings()
    config = PipelineConfig()
    clock = FakeClock()
    svc = DetectionService(
        config, tiers=(2, 4),
        admission=AdmissionConfig(max_delay_s=0.02, max_items=600),
        clock=clock,
    )
    live: dict[int, dict] = {}
    finished: list[tuple[int, dict]] = []
    parts: dict[int, list] = {}

    def detach(sid):
        parts[sid].append(svc.detach(sid))
        finished.append((sid, live.pop(sid)))

    for _ in range(40):
        clock.now += 0.004
        op = int(rng.integers(0, 10))
        if op < 3 and len(live) < 4:
            sid = svc.attach()
            live[sid] = {"rec": recs[int(rng.integers(len(recs)))], "pos": 0}
            parts[sid] = []
        elif op < 8 and live:
            sid = int(rng.choice(sorted(live)))
            s = live[sid]
            if s["pos"] < len(s["rec"]):
                cut = min(s["pos"] + int(rng.integers(1, 1200)), len(s["rec"]))
                r = s["rec"]
                _collect(
                    svc.feed(
                        sid,
                        r.x[s["pos"]:cut], r.y[s["pos"]:cut],
                        r.t[s["pos"]:cut], r.p[s["pos"]:cut],
                    ),
                    parts,
                )
                s["pos"] = cut
        elif op < 9:
            _collect(svc.pump(force=True), parts)
        elif live:
            detach(int(rng.choice(sorted(live))))
    for sid in sorted(live):
        detach(sid)

    for sid, s in finished:
        n = s["pos"]
        if n == 0:
            assert sum(p.num_windows for p in parts[sid]) == 0
            continue
        scan = run_recording_scan(_prefix(s["rec"], n), config)
        _assert_stream_equals_scan(parts[sid], scan)


def test_service_slot_recycling_and_promotion_bookkeeping():
    svc = DetectionService(
        PipelineConfig(), tiers=(2, 4), clock=FakeClock()
    )
    a, b = svc.attach("a"), svc.attach("b")
    assert svc.capacity == 2 and svc.promotions == 0
    c = svc.attach("c")  # pool full -> tier promotion
    assert svc.capacity == 4 and svc.promotions == 1
    slot_b = svc.session(b).slot
    svc.detach(b)
    assert svc.session(b).state == "detached"
    d = svc.attach("d")  # lowest free slot is b's old one
    assert svc.session(d).slot == slot_b
    assert svc.n_sessions == 3
    # Detached sessions are closed to traffic; unknown sids are errors.
    with pytest.raises(RuntimeError, match="detached"):
        svc.feed(b, *_spaced_stream(0, 10))
    with pytest.raises(KeyError, match="unknown session"):
        svc.feed(12345, *_spaced_stream(0, 10))
    for sid in (a, c, d):
        svc.detach(sid)
    assert svc.n_sessions == 0


# ---------------------------------------------------------------------------
# Compile discipline.
# ---------------------------------------------------------------------------

def test_service_churn_compiles_one_fleet_step_per_tier():
    """Cycling 1 -> 4 sessions over tiers (2, 4) — with detach-and-reattach
    churn at the end — traces exactly ONE fleet-step compile per capacity
    tier: slot occupancy is never part of a compiled shape."""
    from repro.core.pipeline import fleet as fleet_mod

    # A config no other test jits, so the step cache starts cold and
    # every compile shows up in STEP_TRACES.
    config = PipelineConfig(
        batcher=BatcherConfig(size_threshold=100, capacity=128)
    )
    svc = DetectionService(
        config, tiers=(2, 4),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        clock=FakeClock(),
    )
    streams = {}

    def feed_round(sids):
        for sid in sids:
            x, y, t, p = streams[sid]["data"]
            pos = streams[sid]["pos"]
            svc.feed(sid, x[pos:pos + 100], y[pos:pos + 100],
                     t[pos:pos + 100], p[pos:pos + 100])
            streams[sid]["pos"] = pos + 100
        svc.pump(force=True)

    def attach():
        sid = svc.attach()
        streams[sid] = {"data": _spaced_stream(seed=50 + sid, n=2000), "pos": 0}
        return sid

    fleet_mod.STEP_TRACES.clear()
    live = []
    for target in (1, 2, 3, 4):  # churn up: 1 -> max sessions
        while len(live) < target:
            live.append(attach())
        feed_round(live)
    while live:  # churn down: exact-window feeds leave no remainder, so
        svc.detach(live.pop())  # detach flushes close nothing (no step)
    live = [attach(), attach()]  # recycled slots at the promoted tier
    feed_round(live)

    traces = [tr for tr in fleet_mod.STEP_TRACES if tr[2] == 128]
    assert all(w == 1 for (_, w, _, _) in traces), traces
    assert all(u is False for (*_, u) in traces), traces
    per_tier = {}
    for s, *_ in traces:
        per_tier[s] = per_tier.get(s, 0) + 1
    assert per_tier == {2: 1, 4: 1}, traces


# ---------------------------------------------------------------------------
# Admission, validation, accounting.
# ---------------------------------------------------------------------------

def test_service_admission_micro_batches_sessions():
    clock = FakeClock()
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=0.02, max_items=300),
        clock=clock,
    )
    s0, s1 = svc.attach(), svc.attach()
    d0, d1 = _spaced_stream(1, 400), _spaced_stream(2, 400)
    assert svc.feed(s0, *[a[:150] for a in d0]) == []  # 150 < 300, fresh
    clock.now += 0.010
    assert svc.feed(s1, *[a[:100] for a in d1]) == []  # 250 < 300, 10 ms
    clock.now += 0.011  # oldest chunk is now 21 ms > max_delay
    served = svc.feed(s0, *[a[150:151] for a in d0])
    assert {fd.sid for fd in served} == {s0, s1}  # one step served both
    assert svc.session(s0).stats.steps == 1
    assert svc.session(s1).stats.steps == 1


def test_service_feed_rejects_bad_chunk_atomically():
    recs = _service_recordings()
    config = PipelineConfig()
    svc = DetectionService(config, tiers=(2,), clock=FakeClock())
    sid = svc.attach()
    rec = recs[0]
    bad_t = rec.t[:20][::-1].copy()
    with pytest.raises(ValueError, match=f"session {sid}"):
        svc.feed(sid, rec.x[:20], rec.y[:20], bad_t, rec.p[:20])
    # Nothing was queued — the session (and the fleet) never saw the chunk.
    assert svc.backlog(sid) == 0
    assert svc.session(sid).stats.feeds == 0
    parts = []
    for chunk in iter_chunks(rec):
        _collect(svc.feed(sid, *chunk), {sid: parts})
        _collect(svc.pump(force=True), {sid: parts})
    parts.append(svc.detach(sid))
    _assert_stream_equals_scan(parts, run_recording_scan(rec, config))


def test_service_monotone_enforced_across_session_feeds():
    svc = DetectionService(PipelineConfig(), tiers=(2,), clock=FakeClock())
    sid = svc.attach()
    x, y, t, p = _spaced_stream(3, 200)
    svc.feed(sid, x[:100], y[:100], t[:100], p[:100])
    with pytest.raises(ValueError, match="monotonically non-decreasing"):
        svc.feed(sid, x[:10], y[:10], t[:10], p[:10])  # regresses in time


def test_service_latency_and_backlog_accounting():
    clock = FakeClock()
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        clock=clock,
    )
    sid = svc.attach("cam")
    x, y, t, p = _spaced_stream(4, 300)
    svc.feed(sid, x[:50], y[:50], t[:50], p[:50])
    assert svc.backlog(sid) == 50  # queued service-side
    clock.now += 0.005
    served = svc.pump(force=True)
    assert len(served) == 1 and served[0].latency_ms == pytest.approx(5.0)
    # 50 events cannot close a window; they sit in the slot's batcher
    # remainder now — still this session's backlog.
    assert served[0].result.num_windows == 0
    assert svc.backlog(sid) == 50
    stats = svc.session(sid).stats
    assert stats.feeds == 1 and stats.events == 50 and stats.steps == 1
    assert stats.latency_percentile(50) == pytest.approx(5.0)
    svc.detach(sid)
    assert svc.backlog(sid) == 0  # remainder flushed with the tail

    # Empty chunks are heartbeats: accepted, never queued, never stepped.
    sid2 = svc.attach()
    assert svc.feed(sid2, *[np.zeros(0, np.int64)] * 4) == []
    assert svc.session(sid2).stats.feeds == 0
    assert svc.pump(force=True) == []


def test_service_rejects_bad_tiers():
    with pytest.raises(ValueError, match="tiers"):
        DetectionService(PipelineConfig(), tiers=(4, 2))
    with pytest.raises(ValueError, match="tiers"):
        DetectionService(PipelineConfig(), tiers=())


def test_detach_discards_stale_admission_entries():
    """A detached session's queued-chunk entries must not keep aging in
    the admitter — otherwise the next session's first feed fires the time
    threshold spuriously instead of micro-batching its own window."""
    clock = FakeClock()
    svc = DetectionService(
        PipelineConfig(), tiers=(2,),
        admission=AdmissionConfig(max_delay_s=0.02, max_items=10_000),
        clock=clock,
    )
    a = svc.attach()
    svc.feed(a, *_spaced_stream(10, 100))  # queued, admission not fired
    clock.now += 0.005
    svc.detach(a)  # consumes the chunk out of band
    clock.now += 0.05  # a's dead entry would now be 55 ms old
    b = svc.attach()
    assert svc.feed(b, *_spaced_stream(11, 50)) == []  # b batches normally
    assert svc.session(b).stats.steps == 0


def test_forget_evicts_detached_records_only():
    svc = DetectionService(PipelineConfig(), tiers=(2,), clock=FakeClock())
    a, b = svc.attach("a"), svc.attach("b")
    svc.detach(a)
    assert svc.detached_sessions == [a]
    with pytest.raises(RuntimeError, match="detach first"):
        svc.forget(b)
    svc.forget(a)
    assert svc.detached_sessions == []
    with pytest.raises(KeyError):
        svc.session(a)
    svc.forget(12345)  # unknown sid: no-op
    svc.detach(b)


def test_latency_samples_are_bounded():
    from repro.serve.sessions import MAX_LATENCY_SAMPLES, SessionStats

    stats = SessionStats()
    for i in range(MAX_LATENCY_SAMPLES + 100):
        stats.record_latency(float(i))
    assert len(stats.latency_ms) == MAX_LATENCY_SAMPLES
    assert stats.latency_ms[0] == 100.0  # oldest samples dropped
    assert stats.latency_percentile(100) == float(MAX_LATENCY_SAMPLES + 99)
