"""Smoke tests for the benchmark entry points (ISSUE 6 satellite).

The benchmarks are release tooling, not tier-1 hot paths, so regressions
there historically surfaced only when someone cut a BENCH json. These
tests import the modules the way ``benchmarks.run`` does and pin:

* ``table5_scaling.bench`` on a single node count produces a well-formed
  non-FAILED row (the subprocess snippet still runs),
* ``roofline_report.window_report`` emits the float/fixed/megakernel
  rows with sane magnitudes, and its ``bench()`` degrades to the
  ``roofline/missing`` row when no dryrun records exist,
* the ``benchmarks.run`` aggregator survives a gated bench that writes
  no ``BENCH_*.json`` (ERROR row + exit 1) and rejects unknown keys.
"""
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:  # `import benchmarks.<mod>` package imports
    sys.path.insert(0, str(REPO))

from benchmarks import roofline_report, run  # noqa: E402


def test_table5_bench_single_node():
    from benchmarks import table5_scaling

    rows = table5_scaling.bench(node_counts=(1,))
    assert len(rows) == 1
    name, us, derived = rows[0]
    assert name == "table5/nodes1"
    assert derived != "FAILED"
    assert us > 0.0
    assert "efficiency1.00" in derived  # single node defines the baseline


@pytest.fixture(scope="module")
def window_report():
    return roofline_report.window_report(n_windows=2, capacity=128)


def test_window_report_rows(window_report):
    rows = window_report["rows"]
    assert set(rows) == {"float_staged", "fixed_staged", "megakernel_model"}
    for name, r in rows.items():
        assert r["flops"] > 0 and r["bytes"] > 0, name
    # The whole point of the fused launch: one launch, HBM traffic far
    # below either staged path.
    assert rows["megakernel_model"]["launches"] == 1.0
    assert window_report["mega_over_fixed_bytes"] <= 0.01
    assert rows["megakernel_model"]["bytes"] < rows["float_staged"]["bytes"]


def test_window_markdown_table(window_report):
    table = roofline_report.window_markdown_table(window_report)
    for needle in ("float_staged", "fixed_staged", "megakernel_model",
                   "mega/fixed bytes"):
        assert needle in table


def test_roofline_bench_missing_records(monkeypatch, tmp_path, window_report):
    monkeypatch.setattr(roofline_report, "RESULTS", tmp_path)
    monkeypatch.setattr(
        roofline_report, "window_report", lambda **kw: window_report
    )
    rows = roofline_report.bench()
    names = [r[0] for r in rows]
    assert "roofline/missing" in names  # graceful no-dryrun fallback
    assert any(n.startswith("roofline/window/") for n in names)


def test_run_aggregator_missing_bench_json(monkeypatch, capsys):
    # A gated bench whose subprocess dies before writing its json must
    # produce the ERROR summary row and a nonzero aggregator exit.
    monkeypatch.setattr(
        run, "BENCHES",
        {"ghost": ("does_not_exist_bench.py", "BENCH_ghost_missing.json")},
    )
    monkeypatch.setattr(sys, "argv", ["run.py", "ghost"])
    with pytest.raises(SystemExit) as exc:
        run.main()
    assert exc.value.code == 1
    out = capsys.readouterr().out
    assert "ERROR (no BENCH json)" in out


def test_run_aggregator_rejects_unknown_key(monkeypatch):
    monkeypatch.setattr(sys, "argv", ["run.py", "bogus_key"])
    with pytest.raises(SystemExit) as exc:
        run.main()
    assert "bogus_key" in str(exc.value.code)
