"""Property-based tests for the stream merge point (ISSUE 6 satellite).

``validate_monotone`` / ``monotone_merge`` guard every streaming driver:
a chunk must be internally non-decreasing and must not start before the
stream's newest absorbed timestamp, or the dual-threshold batcher would
silently mis-window events. These properties sweep randomized chunk
splits of a sorted stream (always accepted, merge == concatenation),
equal-timestamp runs (ties are legal everywhere), empty chunks, and
randomized corruptions (always rejected, pending buffer untouched),
against plain numpy oracles.
"""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.core.events import monotone_merge, validate_monotone


def _sorted_stream(rng, n, tie_heavy=False):
    # tie_heavy draws from a tiny alphabet so long equal-t runs appear.
    steps = rng.integers(0, 3 if tie_heavy else 50, n)
    t = np.cumsum(steps) + int(rng.integers(0, 1000))
    x = rng.integers(0, 640, n)
    y = rng.integers(0, 480, n)
    p = rng.integers(0, 2, n)
    return x, y, t, p


def _split(rng, n, n_chunks):
    """Random split of range(n) into n_chunks contiguous (possibly empty)
    chunks."""
    cuts = np.sort(rng.integers(0, n + 1, n_chunks - 1))
    return np.split(np.arange(n), cuts)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 300), st.integers(1, 8))
def test_merge_of_sorted_splits_reassembles_stream(seed, n, n_chunks):
    rng = np.random.default_rng(seed)
    x, y, t, p = _sorted_stream(rng, n, tie_heavy=bool(seed % 2))
    pending = tuple(np.empty(0, np.int64) for _ in range(4))
    last_t = None
    for idx in _split(rng, n, n_chunks):
        pending = monotone_merge(pending, x[idx], y[idx], t[idx], p[idx], last_t)
        if len(idx):
            last_t = int(t[idx[-1]])
    for got, want in zip(pending, (x, y, t, p)):
        np.testing.assert_array_equal(got, want.astype(np.int64))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(2, 200))
def test_out_of_order_chunk_rejected_and_pending_untouched(seed, n):
    rng = np.random.default_rng(seed)
    x, y, t, p = _sorted_stream(rng, n)
    # Corrupt one interior position so t is strictly decreasing there.
    i = int(rng.integers(1, n))
    t = t.copy()
    t[i] = t[i - 1] - 1 - int(rng.integers(0, 100))
    assert np.any(t[1:] < t[:-1])  # numpy oracle agrees it's unsorted
    pending = tuple(np.arange(3, dtype=np.int64) for _ in range(4))
    with pytest.raises(ValueError, match="non-decreasing"):
        monotone_merge(pending, x, y, t, p)
    for buf in pending:  # no partial absorption
        np.testing.assert_array_equal(buf, np.arange(3))


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 200), st.integers(1, 500))
def test_chunk_before_last_t_rejected(seed, n, gap):
    rng = np.random.default_rng(seed)
    x, y, t, p = _sorted_stream(rng, n)
    last_t = int(t[0]) + gap
    if int(t[0]) >= last_t:
        return
    pending = tuple(np.empty(0, np.int64) for _ in range(4))
    with pytest.raises(ValueError, match="before the"):
        monotone_merge(pending, x, y, t, p, last_t)


def test_empty_chunk_always_accepted():
    empty = np.empty(0, np.int64)
    validate_monotone(empty)  # no last_t
    validate_monotone(empty, last_t=10**9)  # empty can't precede anything
    pending = tuple(np.arange(5, dtype=np.int64) for _ in range(4))
    merged = monotone_merge(pending, empty, empty, empty, empty, last_t=123)
    for got, want in zip(merged, pending):
        np.testing.assert_array_equal(got, want)


def test_equal_timestamp_runs_accepted_across_boundaries():
    # A run of identical timestamps may straddle a chunk boundary: the
    # next chunk starts AT last_t, which is legal (non-decreasing).
    t = np.full(10, 42, np.int64)
    validate_monotone(t, last_t=42)
    pending = tuple(np.empty(0, np.int64) for _ in range(4))
    z = np.zeros(10, np.int64)
    merged = monotone_merge(pending, z, z, t, z, last_t=42)
    np.testing.assert_array_equal(merged[2], t)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 300))
def test_validate_matches_numpy_oracle(seed, n):
    # validate_monotone accepts iff numpy says sorted AND t[0] >= last_t.
    rng = np.random.default_rng(seed)
    t = rng.integers(0, 60, n).astype(np.int64)  # usually unsorted
    if seed % 3 == 0:
        t = np.sort(t)
    last_t = int(rng.integers(0, 60))
    ok = bool(np.all(t[1:] >= t[:-1])) and int(t[0]) >= last_t
    if ok:
        validate_monotone(t, last_t)
    else:
        with pytest.raises(ValueError):
            validate_monotone(t, last_t)
