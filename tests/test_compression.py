"""Unit suite for repro.distributed.compression vs numpy oracles.

The compression module is the wire layer the constellation-scale item
builds on (ROADMAP: compressed cross-shard result exchange), so its
numerics are pinned here before anything depends on them:

* quantize/dequantize roundtrips against a plain-numpy oracle, with the
  analytic error bound (|x - deq| <= scale/2 inside the clip range);
* empty tensors and dtype edges (float16 / bfloat16 / scalar / int32);
* error feedback: one EF step's corrected gradient + residual exactly
  reconstructs the input, and the residual shrinks the next step's bias;
* the collectives (compressed_psum_int8, dp_grad_sync_int8,
  ring_allreduce_int8) under ``jax.vmap(axis_name=...)`` — the
  single-device stand-in for a mesh axis — against the fp32 mean.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.distributed.compression import (
    compressed_psum_int8,
    dequantize_int8,
    dp_grad_sync_int8,
    ef_int8_roundtrip,
    quantize_int8,
    ring_allreduce_int8,
)


def _np_quantize(x: np.ndarray) -> tuple[np.ndarray, float]:
    """Numpy oracle of the symmetric per-tensor int8 quantizer."""
    amax = np.max(np.abs(x)) if x.size else 0.0
    scale = max(amax, 1e-12) / 127.0
    q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Quantize / dequantize.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(64,), (7, 5), (1,), (3, 1, 4)])
def test_quantize_matches_numpy_oracle(shape):
    x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
    q, scale = quantize_int8(jnp.asarray(x))
    oq, oscale = _np_quantize(x)
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q), oq)
    assert float(scale) == pytest.approx(oscale, rel=1e-6)


def test_roundtrip_error_bound():
    """|x - dequantize(quantize(x))| <= scale/2 everywhere (symmetric
    rounding; amax maps exactly to +-127 so nothing clips)."""
    x = np.random.default_rng(1).normal(size=4096).astype(np.float32) * 3.0
    q, scale = quantize_int8(jnp.asarray(x))
    deq = np.asarray(dequantize_int8(q, scale))
    assert np.max(np.abs(x - deq)) <= float(scale) / 2 + 1e-7


def test_roundtrip_exact_on_grid():
    """Values already on the quantization grid survive bit-exactly."""
    scale = 0.5
    x = (np.arange(-127, 128, dtype=np.float32)) * scale
    q, s = quantize_int8(jnp.asarray(x))
    np.testing.assert_allclose(
        np.asarray(dequantize_int8(q, s)), x, rtol=0, atol=1e-6
    )


def test_quantize_zero_tensor():
    q, scale = quantize_int8(jnp.zeros(16))
    np.testing.assert_array_equal(np.asarray(q), np.zeros(16, np.int8))
    assert float(scale) > 0  # 1e-12 floor, never a 0/0
    np.testing.assert_array_equal(
        np.asarray(dequantize_int8(q, scale)), np.zeros(16, np.float32)
    )


def test_quantize_empty_tensor():
    """Zero-size gradient leaves are legal; jnp.max over them is not."""
    q, scale = quantize_int8(jnp.zeros((0,)))
    assert q.shape == (0,) and q.dtype == jnp.int8
    deq = dequantize_int8(q, scale)
    assert deq.shape == (0,) and deq.dtype == jnp.float32
    q2, _ = quantize_int8(jnp.zeros((3, 0, 5)))
    assert q2.shape == (3, 0, 5)


@pytest.mark.parametrize("dtype", [jnp.float16, jnp.bfloat16, jnp.float32])
def test_quantize_dtype_edges(dtype):
    x = jnp.asarray([-1.0, -0.25, 0.0, 0.5, 1.0], dtype)
    q, scale = quantize_int8(x)
    assert q.dtype == jnp.int8
    deq = np.asarray(dequantize_int8(q, scale), np.float32)
    np.testing.assert_allclose(
        deq, np.asarray(x, np.float32), atol=float(scale) / 2 + 1e-3
    )


def test_quantize_scalar_and_int_input():
    q, scale = quantize_int8(jnp.asarray(2.5))
    assert np.asarray(q) == 127  # amax maps to full scale
    assert float(dequantize_int8(q, scale)) == pytest.approx(2.5, rel=1e-6)
    qi, si = quantize_int8(jnp.asarray([-3, 0, 7], jnp.int32))
    np.testing.assert_array_equal(np.asarray(qi), [-54, 0, 127])


def test_quantize_under_jit():
    x = jnp.linspace(-1, 1, 33)
    q_eager, s_eager = quantize_int8(x)
    q_jit, s_jit = jax.jit(quantize_int8)(x)
    np.testing.assert_array_equal(np.asarray(q_eager), np.asarray(q_jit))
    assert float(s_eager) == pytest.approx(float(s_jit), rel=1e-7)


# ---------------------------------------------------------------------------
# Error feedback.
# ---------------------------------------------------------------------------


def test_ef_roundtrip_reconstructs_input():
    """corrected == deq + residual exactly: g + ef = deq + new_ef."""
    rng = np.random.default_rng(2)
    grads = {
        "w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=4).astype(np.float32)),
    }
    out, state = ef_int8_roundtrip(grads, {})
    for k in grads:
        lhs = np.asarray(grads[k])
        rhs = np.asarray(out[k]) + np.asarray(state["ef"][k])
        np.testing.assert_allclose(lhs, rhs, atol=1e-6)


def test_ef_residual_bounded_and_carried():
    rng = np.random.default_rng(3)
    g = {"w": jnp.asarray(rng.normal(size=256).astype(np.float32))}
    out1, state1 = ef_int8_roundtrip(g, {})
    _, scale = quantize_int8(g["w"])
    assert np.max(np.abs(np.asarray(state1["ef"]["w"]))) <= float(scale)
    # Second step carries the residual: the EF buffer changes.
    out2, state2 = ef_int8_roundtrip(g, state1)
    assert not np.array_equal(
        np.asarray(state1["ef"]["w"]), np.asarray(state2["ef"]["w"])
    )
    # Averaged over the two steps, EF keeps the mean error at one
    # quantization step of the truth (the EF-SGD unbiasedness argument).
    mean_out = (np.asarray(out1["w"]) + np.asarray(out2["w"])) / 2
    assert np.max(np.abs(mean_out - np.asarray(g["w"]))) <= float(scale)


# ---------------------------------------------------------------------------
# Collectives under vmap(axis_name=...) — the single-device mesh axis.
# ---------------------------------------------------------------------------

N_SHARDS = 4


def _shards(seed: int, shape) -> np.ndarray:
    return (
        np.random.default_rng(seed)
        .normal(size=(N_SHARDS,) + shape)
        .astype(np.float32)
    )


def test_compressed_psum_matches_fp32_mean():
    x = _shards(4, (128,))
    out = jax.vmap(
        lambda v: compressed_psum_int8(v, "shard"), axis_name="shard"
    )(jnp.asarray(x))
    want = x.mean(axis=0)
    # Every shard sees the same reduced tensor, within quantization error
    # of the true mean (max per-shard scale bounds the per-term error).
    scales = np.abs(x).max(axis=1) / 127.0
    tol = scales.max() + 1e-6
    for s in range(N_SHARDS):
        np.testing.assert_allclose(np.asarray(out[s]), want, atol=tol)
    assert np.asarray(out).std(axis=0).max() < 1e-7  # shards agree exactly


def test_dp_grad_sync_tree():
    tree = {
        "w": jnp.asarray(_shards(5, (16, 3))),
        "b": jnp.asarray(_shards(6, (3,))),
    }
    out = jax.vmap(
        lambda g: dp_grad_sync_int8(g, "shard"), axis_name="shard"
    )(tree)
    for k, v in tree.items():
        want = np.asarray(v).mean(axis=0)
        tol = np.abs(np.asarray(v)).max() / 127.0 + 1e-6
        np.testing.assert_allclose(np.asarray(out[k][0]), want, atol=tol)


@pytest.mark.parametrize("n", [64, 63, 1])  # 63, 1: padding path
def test_ring_allreduce_matches_psum_mean(n):
    x = _shards(7, (n,))
    out = jax.vmap(
        lambda v: ring_allreduce_int8(v, "shard", N_SHARDS),
        axis_name="shard",
    )(jnp.asarray(x))
    want = x.mean(axis=0)
    tol = np.abs(x).max() / 127.0 * 1.5 + 1e-6  # int16 partial sums, one scale
    for s in range(N_SHARDS):
        assert np.asarray(out[s]).shape == (n,)
        np.testing.assert_allclose(np.asarray(out[s]), want, atol=tol)


def test_ring_allreduce_axis_size_one_is_identity():
    x = jnp.asarray(np.random.default_rng(8).normal(size=10), jnp.float32)
    out = jax.vmap(
        lambda v: ring_allreduce_int8(v, "shard", 1), axis_name="shard"
    )(x[None])
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(x))


def test_ring_allreduce_preserves_shape_2d():
    x = _shards(9, (5, 7))
    out = jax.vmap(
        lambda v: ring_allreduce_int8(v, "shard", N_SHARDS),
        axis_name="shard",
    )(jnp.asarray(x))
    assert np.asarray(out).shape == (N_SHARDS, 5, 7)
    tol = np.abs(x).max() / 127.0 * 1.5 + 1e-6
    np.testing.assert_allclose(np.asarray(out[0]), x.mean(axis=0), atol=tol)
