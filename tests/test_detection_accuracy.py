"""Paper-claims validation: accuracy vs min_events (Fig. 10b, Table IV).

The paper reports 97% accuracy at the min_events = 5 operating point,
with the threshold sweep peaking there. The synthetic EVAS-like suite
reproduces the regime; we assert the same qualitative curve and a >= 95%
peak in the 4-6 threshold neighbourhood.
"""
import numpy as np
import pytest

from repro.core.pipeline import PipelineConfig, evaluate_detection, threshold_sweep
from repro.core.tracking import confirmed
from repro.data.synthetic import make_recording


@pytest.fixture(scope="module")
def sweep():
    recs = [
        make_recording(seed=s, duration_s=1.0, n_rsos=1 + (s % 3))
        for s in (1, 2, 3)
    ] + [make_recording(seed=11, duration_s=1.0, n_rsos=1, lens="telephoto"),
         make_recording(seed=21, duration_s=1.0, n_rsos=2, lens="wide")]
    return threshold_sweep(recs, thresholds=(2, 3, 4, 5, 6, 8, 10))


def test_accuracy_at_paper_threshold(sweep):
    acc5 = sweep[5].accuracy
    assert acc5 >= 0.95, f"accuracy@5 = {acc5:.3f}"


def test_curve_peaks_near_five(sweep):
    accs = {t: s.accuracy for t, s in sweep.items()}
    best = max(accs, key=accs.get)
    assert best in (4, 5, 6), accs
    # both flanks strictly worse than the peak region
    assert accs[2] < accs[best] - 0.05
    assert accs[10] < accs[best]


def test_precision_monotone_in_threshold(sweep):
    precs = [sweep[t].precision for t in (2, 3, 4, 5, 6)]
    assert all(b >= a - 1e-9 for a, b in zip(precs, precs[1:])), precs


def test_single_recording_detection():
    rec = make_recording(seed=5, duration_s=0.6, n_rsos=2)
    score = evaluate_detection(rec)
    assert score.accuracy > 0.9
    assert score.tp > 10


def test_tracking_confirms_rsos_not_noise():
    from repro.core.pipeline import run_recording

    rec = make_recording(seed=9, duration_s=1.0, n_rsos=2)
    cfg = PipelineConfig()
    results = run_recording(rec, cfg, with_tracking=True)
    final = results[-1].tracks
    n_conf = int(np.asarray(confirmed(final, cfg.tracker)).sum())
    assert 1 <= n_conf <= 4  # 2 objects; allow a transient ghost or merge
