"""Import-surface contracts for the serving package.

``repro.serve`` exposes the detection stack eagerly and the LM engine
lazily (PEP 562), and the legacy ``repro.serve.engine`` shim warns.
Both run in a subprocess so this test controls exactly which modules
are already imported.
"""
import subprocess
import sys


def _run(code: str) -> str:
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env={"PYTHONPATH": "src", "JAX_PLATFORMS": "cpu", "PATH": "/usr/bin:/bin"},
        cwd=".",
    )
    assert proc.returncode == 0, proc.stderr
    return proc.stdout


def test_serve_import_is_lm_lazy():
    out = _run(
        """
import sys
import repro.serve as s
assert "repro.serve.lm" not in sys.modules, "LM client imported eagerly"
# The detection-serving surface is eager...
s.DetectionService, s.ConstellationService, s.ShardChaosHarness
# ...and the LM names still resolve (lazily) with a stable dir().
assert "ServingEngine" in dir(s)
s.DualThresholdBatcher, s.EngineConfig, s.Request, s.ServingEngine
assert "repro.serve.lm" in sys.modules
try:
    s.NoSuchName
except AttributeError as e:
    assert "NoSuchName" in str(e)
else:
    raise AssertionError("missing attribute did not raise")
print("lazy ok")
"""
    )
    assert "lazy ok" in out


def test_engine_shim_warns_deprecated():
    out = _run(
        """
import warnings
with warnings.catch_warnings(record=True) as caught:
    warnings.simplefilter("always")
    import repro.serve.engine as engine
msgs = [str(w.message) for w in caught
        if issubclass(w.category, DeprecationWarning)]
assert any("repro.serve.lm" in m for m in msgs), msgs
# The shim still re-exports the moved names.
engine.DualThresholdBatcher, engine.ServingEngine
print("shim warns")
"""
    )
    assert "shim warns" in out
