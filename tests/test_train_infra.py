"""Optimizer, checkpointing, fault tolerance, compression, serving."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.distributed.compression import (
    dequantize_int8,
    ef_int8_roundtrip,
    quantize_int8,
)
from repro.distributed.fault_tolerance import (
    ElasticRunner,
    FailureEvent,
    HeartbeatMonitor,
    StragglerTracker,
)
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import (
    OptConfig,
    adamw_update,
    clip_by_global_norm,
    init_opt_state,
    schedule,
)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_matches_manual_scalar():
    cfg = OptConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                    warmup_steps=0, total_steps=10**9, clip_norm=0.0)
    p = {"w": jnp.asarray(2.0)}
    g = {"w": jnp.asarray(0.5)}
    state = init_opt_state(p)
    new_p, state, _ = adamw_update(g, state, p, cfg)
    # manual: mu=0.05, nu=0.0025; mhat=0.5, vhat=0.25 -> upd = 0.5/0.5 = 1
    lr0 = float(schedule(jnp.asarray(1), cfg))
    assert float(new_p["w"]) == pytest.approx(2.0 - lr0 * 1.0, rel=1e-5)


def test_schedule_warmup_and_decay():
    cfg = OptConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(schedule(jnp.asarray(s), cfg)) for s in range(0, 101, 5)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1e-3, rel=0.01)
    assert lrs[-1] == pytest.approx(1e-4, rel=0.05)  # min_lr_ratio


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 3.0), "b": jnp.full((4,), 4.0)}  # norm = 10
    clipped, norm = clip_by_global_norm(g, 5.0)
    assert float(norm) == pytest.approx(10.0, rel=1e-5)
    new_norm = float(
        jnp.sqrt(sum(jnp.sum(x * x) for x in jax.tree.leaves(clipped)))
    )
    assert new_norm == pytest.approx(5.0, rel=1e-5)


def test_training_reduces_loss():
    from repro.launch.train import train

    _, log = train(arch="llama3.2-1b", preset="tiny", steps=30, batch=8,
                   seq=64, lr=3e-3, log_every=29)
    assert log[-1]["loss"] < log[0]["loss"] - 0.1
    assert np.isfinite(log[-1]["loss"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "params": {"w": jax.random.normal(k, (8, 8)), "b": jnp.zeros((8,))},
        "opt": {"step": jnp.asarray(3), "mu": {"w": jnp.ones((8, 8))}},
    }


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2)
    state = _state()
    mgr.save(7, state, meta={"note": "test"})
    step, restored = mgr.restore(jax.tree.map(jnp.zeros_like, state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_gc(tmp_path):
    mgr = CheckpointManager(tmp_path, keep_n=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _state(s))
    mgr.wait()
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]  # keep_n=2
    assert mgr.latest_step() == 4


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore({"w": jnp.zeros((5,))})


def test_checkpoint_elastic_restore_resharded(tmp_path, subproc):
    """Checkpoint written on 1 device restores onto an 8-device mesh with
    different sharding — the elastic-scaling path."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(2, {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)})
    out = subproc(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.train.checkpoint import CheckpointManager
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
mgr = CheckpointManager({str(tmp_path)!r})
step, state = mgr.restore(
    {{"w": jnp.zeros((8, 8))}},
    shardings={{"w": NamedSharding(mesh, P("data", "model"))}},
)
assert step == 2
np.testing.assert_allclose(np.asarray(state["w"]).ravel(), np.arange(64))
print("SHARDS", len(state["w"].sharding.device_set))
""", device_count=8)
    assert "SHARDS 8" in out


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_heartbeat_monitor():
    t = [0.0]
    mon = HeartbeatMonitor(["n0", "n1"], timeout_s=10, clock=lambda: t[0])
    t[0] = 5.0
    mon.beat("n0")
    t[0] = 12.0
    assert mon.failed_nodes() == ["n1"]
    assert mon.healthy_nodes() == ["n0"]


def test_straggler_tracker():
    tr = StragglerTracker(factor=2.0)
    for _ in range(10):
        for n in ("a", "b", "c"):
            tr.record(n, 1.0)
        tr.record("slow", 5.0)
    assert tr.stragglers() == ["slow"]


def test_elastic_runner_recovers_from_failure(tmp_path):
    """Simulated node loss at step 7: runner rebuilds 'mesh', restores the
    step-5 checkpoint, and finishes all 12 steps."""
    ckpt = CheckpointManager(tmp_path, keep_n=3)
    fail_once = {"armed": True}

    def failure_hook(step):
        if step == 7 and fail_once["armed"]:
            fail_once["armed"] = False
            return FailureEvent(step, "node_lost", "simulated")
        return None

    def step_fn(state, batch):
        new = {"x": state["x"] + batch}
        return new, {"loss": float(batch), "x": float(new["x"])}

    runner = ElasticRunner(
        mesh_factory=lambda n_failures: f"mesh<{8 - n_failures}>",
        make_state=lambda mesh: {"x": jnp.asarray(0.0)},
        step_fn=step_fn,
        ckpt=ckpt,
        ckpt_every=5,
        failure_hook=failure_hook,
    )
    batches = [jnp.asarray(1.0)] * 12
    state, log = runner.run(batches)
    assert runner.restarts == 1
    assert [e.kind for e in runner.events] == ["node_lost"]
    # all 12 batches contributed exactly once in the final lineage:
    # steps 0..5 checkpointed, replay 6..11 => x == 12
    assert float(state["x"]) == 12.0


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_int8_quantization_error_bound():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)).astype(np.float32))
    q, s = quantize_int8(x)
    err = float(jnp.abs(dequantize_int8(q, s) - x).max())
    assert err <= float(s) / 2 + 1e-7


def test_error_feedback_preserves_signal():
    """With EF, the *sum* of compressed grads over steps tracks the true
    sum (bias-free compression)."""
    rng = np.random.default_rng(1)
    g_true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * 1e-3)
    opt_state = {}
    total = jnp.zeros((64,))
    for _ in range(50):
        g_c, opt_state = ef_int8_roundtrip({"g": g_true}, opt_state)
        total = total + g_c["g"]
    np.testing.assert_allclose(
        np.asarray(total), np.asarray(g_true * 50), rtol=0.02, atol=1e-4
    )


def test_ring_allreduce_int8_matches_mean(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_mesh, shard_map
from repro.distributed.compression import ring_allreduce_int8
mesh = make_mesh((4,), ("dp",))
x = np.random.default_rng(0).normal(size=(4, 128)).astype(np.float32)
fn = shard_map(
    partial(ring_allreduce_int8, axis_name="dp", axis_size=4),
    mesh=mesh, in_specs=P("dp"), out_specs=P("dp"),
)
out = np.asarray(fn(jnp.asarray(x)))
expect = x.mean(0, keepdims=True)
for r in range(4):
    np.testing.assert_allclose(out[r], expect[0], atol=2 * np.abs(x).max() / 127)
print("RING OK")
""", device_count=4)
    assert "RING OK" in out


# ---------------------------------------------------------------------------
# serving engine (dual-threshold batching = the paper's policy)
# ---------------------------------------------------------------------------

def test_dual_threshold_batcher_semantics():
    from repro.serve.lm import DualThresholdBatcher, EngineConfig, Request

    t = [0.0]
    b = DualThresholdBatcher(
        EngineConfig(max_delay_s=0.02, max_batch=4), clock=lambda: t[0]
    )
    for i in range(3):
        b.submit(Request(rid=i, tokens=[1]))
    assert not b.ready()  # 3 < 4 and no time elapsed
    t[0] = 0.025
    assert b.ready()  # time threshold fired
    assert len(b.pop_batch()) == 3
    for i in range(5):
        b.submit(Request(rid=i, tokens=[1]))
    assert b.ready()  # size threshold fired immediately
    assert len(b.pop_batch()) == 4
    assert len(b.queue) == 1


def test_serving_engine_generates():
    from repro.launch.serve import serve_demo

    stats = serve_demo(arch="llama3.2-1b", n_requests=6, prompt_len=8,
                       max_new=4, max_batch=3)
    assert stats["requests"] == 6
    assert stats["tokens_generated"] == 24


def test_heartbeat_register_forget_roster():
    t = [0.0]
    mon = HeartbeatMonitor(timeout_s=10, clock=lambda: t[0])
    assert mon.nodes == []
    mon.register("a")
    t[0] = 2.0
    mon.register("b")
    assert "a" in mon and "ghost" not in mon
    assert mon.nodes == ["a", "b"]
    assert mon.last_beat_s("a") == 0.0 and mon.last_beat_s("b") == 2.0
    with pytest.raises(ValueError, match="already registered"):
        mon.register("a")
    with pytest.raises(KeyError, match="unregistered"):
        mon.beat("ghost")  # a typo'd id must not create a phantom node
    mon.forget("a")
    assert "a" not in mon
    with pytest.raises(KeyError):
        mon.forget("a")
    t[0] = 20.0
    assert mon.failed_nodes() == ["b"]  # forgotten nodes never count


def test_straggler_fleet_median_even_count_unbiased():
    tr = StragglerTracker()
    for i, v in enumerate([1.0, 2.0, 3.0, 4.0]):
        tr.record(f"n{i}", v)
    # Mean of the two middle EMAs — the upper-middle element alone (3.0)
    # would inflate the straggler threshold by 20% here.
    assert tr.fleet_median() == pytest.approx(2.5)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.floats(0.1, 1e3), min_size=1, max_size=25))
def test_straggler_fleet_median_matches_numpy_oracle(vals):
    tr = StragglerTracker()
    for i, v in enumerate(vals):
        tr.record(i, v)  # first record seeds the EMA at the value itself
    assert tr.fleet_median() == pytest.approx(float(np.median(vals)))


def test_straggler_forget_and_ema_accessor():
    tr = StragglerTracker()
    tr.record("a", 1.0)
    tr.record("b", 100.0)
    assert tr.ema("b") == pytest.approx(100.0)
    assert tr.ema("ghost") is None
    tr.forget("b")
    assert tr.fleet_median() == pytest.approx(1.0)
    tr.forget("ghost")  # no-op, departed nodes may be forgotten twice
    assert tr.stragglers() == []
    assert StragglerTracker().fleet_median() == 0.0
