"""Carry re-migration property tests (DESIGN.md Sec. 15).

Pins the invariant the constellation planner leans on: ANY sequence of
``grow_fleet_carry`` / ``shrink_fleet_carry`` tier moves, slot
permutations, and cross-pool slot migrations — across two pools with
*different* meshes — leaves every surviving slot's carry bit-identical.
A numpy mirror executes the same bookkeeping as the oracle, and every
step is checked leaf-by-leaf against it.

The fleet-level export/import primitive gets the same treatment with
real stream state: a mid-stream slot hop between two pools of different
capacities (and meshes) must resume bit-identically to a dedicated
``StreamingPipeline``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.core.events import BatcherConfig
from repro.core.pipeline import PipelineConfig
from repro.core.pipeline.fleet import FleetPipeline
from repro.core.pipeline.stream import StreamingPipeline
from repro.distributed.sharding import (
    grow_fleet_carry,
    shard_fleet_carry,
    shrink_fleet_carry,
)
from repro.launch.mesh import make_mesh
from repro.serve.chaos import compare_outputs, concat_outputs

CONFIG = PipelineConfig(
    batcher=BatcherConfig(time_threshold_us=2_000, size_threshold=40, capacity=64)
)


# ---------------------------------------------------------------------------
# Pure-carry sequences against a numpy oracle.
# ---------------------------------------------------------------------------


def _random_carry(rng, cap: int):
    """Synthetic stacked carry: an atlas-like int32 leaf plus a mixed
    tracker pytree, all with the sensor dim leading."""
    return (
        rng.integers(-(10**6), 10**6, (cap, 5, 7)).astype(np.int32),
        {
            "pos": rng.normal(size=(cap, 3)).astype(np.float32),
            "age": rng.integers(0, 9, (cap, 4, 2)).astype(np.int32),
        },
    )


class _Pool:
    """One slot pool: a device carry, its numpy mirror, its mesh, and
    which slots are occupied (non-zero)."""

    def __init__(self, rng, cap: int, mesh):
        self.mesh = mesh
        self.mirror = _random_carry(rng, cap)
        self.carry = shard_fleet_carry(
            jax.tree.map(jnp.asarray, self.mirror), mesh
        )
        self.occupied = set(range(cap))

    @property
    def cap(self) -> int:
        return jax.tree.leaves(self.carry)[0].shape[0]

    def check(self, label: str) -> None:
        got = jax.tree.leaves(jax.tree.map(np.asarray, self.carry))
        want = jax.tree.leaves(self.mirror)
        for i, (g, w) in enumerate(zip(got, want)):
            assert g.shape == w.shape, f"{label}[{i}]: {g.shape} vs {w.shape}"
            assert np.array_equal(g, w), (
                f"{label}[{i}]: {int((g != w).sum())}/{g.size} differ"
            )

    def grow(self, new_cap: int) -> None:
        self.carry = grow_fleet_carry(self.carry, new_cap, self.mesh)
        self.mirror = jax.tree.map(
            lambda a: np.concatenate(
                [a, np.zeros((new_cap - a.shape[0],) + a.shape[1:], a.dtype)]
            ),
            self.mirror,
        )

    def shrink(self, new_cap: int) -> None:
        assert all(s < new_cap for s in self.occupied)
        self.carry = shrink_fleet_carry(self.carry, new_cap, self.mesh)
        self.mirror = jax.tree.map(lambda a: a[:new_cap].copy(), self.mirror)

    def permute(self, perm: np.ndarray) -> None:
        """Randomized slot permutation (the planner may place anywhere)."""
        self.carry = shard_fleet_carry(
            jax.tree.map(lambda a: a[jnp.asarray(perm)], self.carry), self.mesh
        )
        self.mirror = jax.tree.map(lambda a: a[perm].copy(), self.mirror)
        inv = {int(old): new for new, old in enumerate(perm)}
        self.occupied = {inv[s] for s in self.occupied}


def _migrate(src: _Pool, s_slot: int, dst: _Pool, d_slot: int) -> None:
    """Move one slot's carry between pools (the constellation hop):
    written into the destination, zeroed at the source."""
    row = jax.tree.map(lambda a: np.asarray(a[s_slot]), src.carry)
    dst.carry = shard_fleet_carry(
        jax.tree.map(
            lambda a, r: a.at[d_slot].set(jnp.asarray(r)), dst.carry, row
        ),
        dst.mesh,
    )
    src.carry = shard_fleet_carry(
        jax.tree.map(
            lambda a: a.at[s_slot].set(jnp.zeros_like(a[s_slot])), src.carry
        ),
        src.mesh,
    )
    dst.mirror = jax.tree.map(
        lambda a, r: _np_set(a, d_slot, r), dst.mirror, row
    )
    src.mirror = jax.tree.map(
        lambda a: _np_set(a, s_slot, np.zeros_like(a[s_slot])), src.mirror
    )
    src.occupied.discard(s_slot)
    dst.occupied.add(d_slot)


def _np_set(a: np.ndarray, slot: int, row: np.ndarray) -> np.ndarray:
    out = a.copy()
    out[slot] = row
    return out


def run_sequence(seed: int, mesh_a, mesh_b, steps: int = 18) -> int:
    """Random grow -> migrate -> shrink -> permute sequence over two
    pools with different meshes; every step is oracle-checked. Returns
    the number of migrations performed (callers assert coverage)."""
    rng = np.random.default_rng(seed)
    pools = [_Pool(rng, 4, mesh_a), _Pool(rng, 4, mesh_b)]
    migrations = 0
    for step in range(steps):
        op = rng.choice(["grow", "shrink", "migrate", "permute"])
        p = pools[int(rng.integers(2))]
        if op == "grow" and p.cap < 16:
            p.grow(int(p.cap * 2))
        elif op == "shrink":
            top = max(p.occupied, default=-1)
            new_cap = max(top + 1, p.cap // 2, 1)
            if new_cap < p.cap:
                p.shrink(new_cap)
        elif op == "migrate":
            src, dst = (
                (pools[0], pools[1]) if rng.integers(2) else (pools[1], pools[0])
            )
            free = sorted(set(range(dst.cap)) - dst.occupied)
            if src.occupied and not free:
                dst.grow(int(dst.cap * 2))
                free = sorted(set(range(dst.cap)) - dst.occupied)
            if src.occupied and free:
                s_slot = int(rng.permutation(sorted(src.occupied))[0])
                d_slot = int(rng.permutation(free)[0])
                _migrate(src, s_slot, dst, d_slot)
                migrations += 1
        elif op == "permute":
            p.permute(rng.permutation(p.cap))
        for i, pool in enumerate(pools):
            pool.check(f"seed {seed} step {step} ({op}) pool {i}")
    return migrations


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_grow_migrate_shrink_oracle(seed):
    """Two pools, two meshes (unsharded vs a 1-device sensor mesh):
    any op sequence stays bit-identical to the numpy oracle."""
    mesh_b = make_mesh((1,), ("sensor",))
    run_sequence(seed, None, mesh_b)


def test_grow_migrate_shrink_covers_migration():
    """At least one seed in the deterministic sweep actually migrates
    (guards the property against silently testing nothing)."""
    mesh_b = make_mesh((1,), ("sensor",))
    assert sum(run_sequence(s, None, mesh_b) for s in range(3)) >= 3


def test_grow_migrate_shrink_four_devices(subproc):
    """Same oracle property across a 4-device and a 2-device sensor
    mesh — re-sharding on every hop, slots crossing device boundaries."""
    out = subproc(
        """
import sys
sys.path.insert(0, "tests")
import jax
assert jax.device_count() == 4
from repro.launch.mesh import make_mesh
from test_carry_migration import run_sequence
mesh_a = make_mesh((4,), ("sensor",))
mesh_b = make_mesh((2,), ("sensor",))
total = sum(run_sequence(seed, mesh_a, mesh_b, steps=12) for seed in range(3))
assert total >= 2, total
print("oracle-identical across meshes; migrations", total)
""",
        device_count=4,
    )
    assert "oracle-identical across meshes" in out


# ---------------------------------------------------------------------------
# Fleet-level export/import with real stream state.
# ---------------------------------------------------------------------------


def _chunks(seed: int, n_chunks: int, n: int = 90, dt_us: int = 40):
    rng = np.random.default_rng(seed)
    pos = 0
    out = []
    for _ in range(n_chunks):
        t = (np.arange(n, dtype=np.int64) + pos + 1) * dt_us
        pos += n
        out.append((
            rng.integers(0, 600, n).astype(np.int64),
            rng.integers(0, 440, n).astype(np.int64),
            t,
            rng.integers(0, 2, n).astype(np.int64),
        ))
    return out


@pytest.mark.parametrize("seed", [0, 1])
def test_fleet_export_import_mid_stream(seed):
    """A stream fed through pool A, hopped mid-stream into a different-
    capacity pool B (B under a 1-device sensor mesh), finished there:
    concatenated outputs bit-identical to a dedicated StreamingPipeline."""
    chunks = _chunks(seed, 6)
    a = FleetPipeline(CONFIG, n_sensors=2, uniform_fast_path=False)
    b = FleetPipeline(
        CONFIG,
        n_sensors=4,
        uniform_fast_path=False,
        mesh=make_mesh((1,), ("sensor",)),
    )
    slot_a, slot_b = 1, 3
    parts = []
    for c in chunks[:3]:
        feed = [None] * a.n_sensors
        feed[slot_a] = c
        parts.append(a.feed(feed).sensor(slot_a))
    carry = a.export_slot(slot_a)
    a.reset_slots([slot_a])
    b.import_slot(slot_b, carry)
    for c in chunks[3:]:
        feed = [None] * b.n_sensors
        feed[slot_b] = c
        parts.append(b.feed(feed).sensor(slot_b))
    parts.append(b.flush_slots([slot_b]).sensor(slot_b))

    ref = StreamingPipeline(CONFIG)
    want = [ref.feed(*c) for c in chunks] + [ref.flush()]
    assert compare_outputs(
        concat_outputs(parts), concat_outputs(want), "hop"
    ) == []


def test_fleet_import_refuses_mismatched_carry():
    """A carry exported under a different PipelineConfig is refused
    atomically (shape check before any mutation)."""
    # Capacity above the grid width widens the atlas (see atlas_shape),
    # so this config is genuinely shape-incompatible with CONFIG.
    other = PipelineConfig(
        batcher=BatcherConfig(
            time_threshold_us=2_000, size_threshold=40, capacity=4096
        )
    )
    a = FleetPipeline(CONFIG, n_sensors=2, uniform_fast_path=False)
    b = FleetPipeline(other, n_sensors=2, uniform_fast_path=False)
    carry = a.export_slot(0)
    before = jax.tree.map(np.asarray, (b.state.atlas, b.state.tracks))
    with pytest.raises(ValueError, match="atlas shape"):
        b.import_slot(0, carry)
    after = jax.tree.map(np.asarray, (b.state.atlas, b.state.tracks))
    for g, w in zip(jax.tree.leaves(after), jax.tree.leaves(before)):
        assert np.array_equal(g, w)
    with pytest.raises(IndexError, match="out of range"):
        a.import_slot(7, carry)
    with pytest.raises(IndexError, match="out of range"):
        a.export_slot(7)
