"""Streaming engine: bit-identity with the scan driver under arbitrary
chunkings (including chunks that split a dual-threshold window), batcher
remainder semantics, tracker chaining, tag-epoch rollover, and
overflow accounting."""
import functools

import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or deterministic fallback

from repro.core.events import (
    BatcherConfig,
    dual_threshold_bounds,
    dual_threshold_closed_bounds,
    pad_windows,
)
from repro.core.pipeline import (
    PipelineConfig,
    StreamingPipeline,
    run_recording_scan,
)
from repro.core.tracking import track_recording
import jax


@functools.lru_cache(maxsize=None)
def _recording(seed: int = 3, duration_s: float = 0.35, n_rsos: int = 2):
    from repro.data.synthetic import make_recording

    return make_recording(seed=seed, duration_s=duration_s, n_rsos=n_rsos)


def _feed_chunks(sp: StreamingPipeline, rec, cuts: list[int]):
    """Feed a recording split at the given event indices; flush at the end."""
    parts = []
    prev = 0
    for c in sorted(cuts) + [len(rec)]:
        c = min(max(c, prev), len(rec))
        parts.append(sp.feed(rec.x[prev:c], rec.y[prev:c], rec.t[prev:c], rec.p[prev:c]))
        prev = c
    parts.append(sp.flush())
    return parts


def _assert_stream_equals_scan(parts, scan, with_tracking=True):
    assert sum(p.num_windows for p in parts) == scan.num_windows
    t_start = np.concatenate([p.t_start_us for p in parts])
    np.testing.assert_array_equal(t_start, scan.t_start_us)
    starts = np.concatenate([p.windows.starts for p in parts])
    stops = np.concatenate([p.windows.stops for p in parts])
    np.testing.assert_array_equal(starts, scan.windows.starts)
    np.testing.assert_array_equal(stops, scan.windows.stops)
    for field in scan.clusters._fields:
        cat = np.concatenate(
            [np.asarray(getattr(p.clusters, field)) for p in parts]
        )
        np.testing.assert_array_equal(
            cat, np.asarray(getattr(scan.clusters, field)),
            err_msg=f"clusters.{field}",
        )
    for key in scan.metrics:
        cat = np.concatenate([np.asarray(p.metrics[key]) for p in parts])
        np.testing.assert_array_equal(
            cat, np.asarray(scan.metrics[key]), err_msg=f"metrics[{key}]"
        )
    if with_tracking:
        for field in scan.tracks._fields:
            cat = np.concatenate(
                [np.asarray(getattr(p.tracks, field)) for p in parts]
            )
            np.testing.assert_array_equal(
                cat, np.asarray(getattr(scan.tracks, field)),
                err_msg=f"tracks.{field}",
            )
        for field in scan.final_tracks._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(parts[-1].final_tracks, field)),
                np.asarray(getattr(scan.final_tracks, field)),
                err_msg=f"final_tracks.{field}",
            )


# ---------------------------------------------------------------------------
# Incremental windower (batcher remainder semantics).
# ---------------------------------------------------------------------------

def test_closed_bounds_are_prefix_of_full_bounds():
    rec = _recording()
    cfg = BatcherConfig()
    full = dual_threshold_bounds(rec.t, cfg)
    for cut in (1, 7, len(rec) // 3, len(rec) - 1, len(rec)):
        closed, consumed = dual_threshold_closed_bounds(rec.t[:cut], cfg)
        assert closed == full[: len(closed)]
        assert consumed == (closed[-1][1] if closed else 0)
        # Whatever stays pending is exactly the un-emitted suffix.
        assert consumed <= cut


def test_closed_bounds_hold_back_open_window():
    # 10 events all within 1 ms: neither the 20 ms nor the 250-event cut
    # can prove the window closed — nothing is emitted.
    t = np.arange(10, dtype=np.int64) * 100
    closed, consumed = dual_threshold_closed_bounds(t, BatcherConfig())
    assert closed == [] and consumed == 0
    # An event past the time threshold closes it.
    t2 = np.concatenate([t, [30_000]])
    closed, consumed = dual_threshold_closed_bounds(t2, BatcherConfig())
    assert closed == [(0, 10)] and consumed == 10


def test_closed_bounds_size_cut_closes_without_later_event():
    # Exactly size_threshold events inside the time window: size cut binds.
    n = BatcherConfig().size_threshold
    t = np.linspace(0, 1000, n).astype(np.int64)
    closed, consumed = dual_threshold_closed_bounds(t, BatcherConfig())
    assert closed == [(0, n)] and consumed == n


# ---------------------------------------------------------------------------
# Stream == scan bit-identity.
# ---------------------------------------------------------------------------

def test_single_feed_plus_flush_equals_scan():
    rec = _recording()
    config = PipelineConfig()
    scan = run_recording_scan(rec, config)
    sp = StreamingPipeline(config)
    parts = _feed_chunks(sp, rec, [])
    _assert_stream_equals_scan(parts, scan)


@settings(max_examples=8, deadline=None)
@given(st.lists(st.integers(0, 10_000_000), min_size=1, max_size=6))
def test_chunked_feed_bit_identical_to_scan(raw_cuts):
    rec = _recording()
    config = PipelineConfig()
    scan = run_recording_scan(rec, config)
    cuts = [c % (len(rec) + 1) for c in raw_cuts]
    sp = StreamingPipeline(config)
    parts = _feed_chunks(sp, rec, cuts)
    _assert_stream_equals_scan(parts, scan)


def test_chunk_splitting_every_window_boundary_neighbourhood():
    # Deliberately adversarial: cut one event past each window boundary,
    # so every window is split across two feeds.
    rec = _recording()
    config = PipelineConfig()
    scan = run_recording_scan(rec, config)
    cuts = [int(s) + 1 for s in scan.windows.starts[1:]]
    sp = StreamingPipeline(config)
    parts = _feed_chunks(sp, rec, cuts)
    _assert_stream_equals_scan(parts, scan)


@pytest.mark.parametrize("impl", ["frame", "event"])
def test_stream_matches_scan_across_metrics_impls(impl):
    rec = _recording(seed=6, duration_s=0.25, n_rsos=1)
    config = PipelineConfig(metrics_impl=impl)
    scan = run_recording_scan(rec, config)
    sp = StreamingPipeline(config)
    parts = _feed_chunks(sp, rec, [len(rec) // 3, 2 * len(rec) // 3])
    _assert_stream_equals_scan(parts, scan)


def test_stream_without_tracking():
    rec = _recording()
    config = PipelineConfig()
    scan = run_recording_scan(rec, config, with_tracking=False)
    sp = StreamingPipeline(config, with_tracking=False)
    parts = _feed_chunks(sp, rec, [len(rec) // 2])
    assert all(p.tracks is None and p.final_tracks is None for p in parts)
    _assert_stream_equals_scan(parts, scan, with_tracking=False)


def test_feed_that_closes_no_window_returns_empty_result():
    rec = _recording()
    config = PipelineConfig()
    sp = StreamingPipeline(config)
    res = sp.feed(rec.x[:3], rec.y[:3], rec.t[:3], rec.p[:3])
    assert res.num_windows == 0
    assert res.clusters.count.shape[0] == 0
    assert res.window_results() == []
    assert sp.state.pending_count == 3
    # The held-back events still come out right once the stream continues.
    rest = sp.feed(rec.x[3:], rec.y[3:], rec.t[3:], rec.p[3:])
    scan = run_recording_scan(rec, config)
    _assert_stream_equals_scan([res, rest, sp.flush()], scan)


def test_tag_epoch_rollover_keeps_identity():
    rec = _recording()
    config = PipelineConfig()
    scan = run_recording_scan(rec, config)
    sp = StreamingPipeline(config)
    sp._tag_limit = 4  # force atlas re-zeroing every few windows
    parts = _feed_chunks(sp, rec, list(range(0, len(rec), len(rec) // 5)))
    assert sp.state.next_tag <= 4
    _assert_stream_equals_scan(parts, scan)


def test_feed_larger_than_tag_epoch_refuses_without_wedging():
    # A single feed closing more windows than one tag epoch can address
    # must error (silent int32 tag wrap would alias stale atlas pixels)
    # WITHOUT absorbing the chunk — the stream stays usable and the same
    # events can be re-fed in smaller pieces.
    rec = _recording()
    config = PipelineConfig()
    sp = StreamingPipeline(config)
    sp._tag_limit = 2
    with pytest.raises(ValueError, match="tag epoch"):
        sp.feed(rec.x, rec.y, rec.t, rec.p)
    assert sp.state.pending_count == 0  # chunk rejected, not buffered
    scan = run_recording_scan(rec, config)
    parts = _feed_chunks(sp, rec, list(range(0, len(rec), len(rec) // 10)))
    _assert_stream_equals_scan(parts, scan)


def test_stream_state_resumes_in_new_pipeline():
    rec = _recording()
    config = PipelineConfig()
    scan = run_recording_scan(rec, config)
    half = len(rec) // 2
    sp1 = StreamingPipeline(config)
    first = sp1.feed(rec.x[:half], rec.y[:half], rec.t[:half], rec.p[:half])
    # Hand the carry to a brand-new pipeline object (e.g. after a restart).
    sp2 = StreamingPipeline(config, state=sp1.state)
    rest = sp2.feed(rec.x[half:], rec.y[half:], rec.t[half:], rec.p[half:])
    _assert_stream_equals_scan([first, rest, sp2.flush()], scan)


# ---------------------------------------------------------------------------
# Feed monotonicity validation (no silent mis-windowing).
# ---------------------------------------------------------------------------

def test_feed_rejects_unsorted_chunk():
    rec = _recording()
    sp = StreamingPipeline(PipelineConfig())
    t_bad = rec.t[:20][::-1].copy()
    with pytest.raises(ValueError, match="not non-decreasing"):
        sp.feed(rec.x[:20], rec.y[:20], t_bad, rec.p[:20])
    # The chunk was not absorbed; the stream stays usable.
    assert sp.state.pending_count == 0
    parts = _feed_chunks(sp, rec, [len(rec) // 2])
    _assert_stream_equals_scan(parts, run_recording_scan(rec, PipelineConfig()))


def test_feed_rejects_timestamps_regressing_across_feeds():
    rec = _recording()
    sp = StreamingPipeline(PipelineConfig())
    half = len(rec) // 2
    sp.feed(rec.x[:half], rec.y[:half], rec.t[:half], rec.p[:half])
    # Re-feeding earlier events would mis-window silently without the check:
    # the already-processed prefix cannot be re-windowed.
    with pytest.raises(ValueError, match="monotonically non-decreasing"):
        sp.feed(rec.x[:10], rec.y[:10], rec.t[:10], rec.p[:10])
    # Regression applies even when the remainder is empty but earlier
    # feeds consumed later timestamps.
    rest = sp.feed(rec.x[half:], rec.y[half:], rec.t[half:], rec.p[half:])
    assert rest.num_windows > 0
    with pytest.raises(ValueError, match="monotonically non-decreasing"):
        sp.feed(rec.x[:1], rec.y[:1], rec.t[:1], rec.p[:1])


def test_feed_accepts_equal_boundary_timestamps():
    # Non-decreasing means ties are legal, both within and across feeds.
    t = np.array([0, 0, 5, 5], np.int64)
    z = np.zeros(4, np.int32)
    sp = StreamingPipeline(PipelineConfig())
    sp.feed(z, z, t, z)
    sp.feed(z, z, np.full(4, 5, np.int64), z)  # t[0] == last absorbed t
    assert sp.state.pending_count == 8


# ---------------------------------------------------------------------------
# Tracker chaining across segment boundaries (track_recording init=...).
# ---------------------------------------------------------------------------

def test_track_recording_chains_across_boundaries():
    rec = _recording()
    config = PipelineConfig()
    scan = run_recording_scan(rec, config)
    ent = scan.metrics["shannon_entropy"]
    full_final, full_states = track_recording(scan.clusters, ent, config.tracker)
    half = scan.num_windows // 2
    head = jax.tree.map(lambda a: a[:half], scan.clusters)
    tail = jax.tree.map(lambda a: a[half:], scan.clusters)
    f1, s1 = track_recording(head, ent[:half], config.tracker)
    f2, s2 = track_recording(tail, ent[half:], config.tracker, init=f1)
    for field in full_final._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(f2, field)),
            np.asarray(getattr(full_final, field)),
            err_msg=f"final.{field}",
        )
        cat = np.concatenate(
            [np.asarray(getattr(s1, field)), np.asarray(getattr(s2, field))]
        )
        np.testing.assert_array_equal(
            cat, np.asarray(getattr(full_states, field)), err_msg=field
        )


# ---------------------------------------------------------------------------
# Overflow accounting (no more silent event loss).
# ---------------------------------------------------------------------------

def test_pad_windows_dual_policy_has_zero_overflow():
    rec = _recording()
    windowed = pad_windows(rec.x, rec.y, rec.t, rec.p, BatcherConfig())
    assert windowed.overflow is not None
    np.testing.assert_array_equal(
        windowed.overflow, np.zeros(windowed.num_windows, np.int64)
    )


def test_pad_windows_stride_policy_records_overflow():
    # 100 events in one 20 ms stride window, capacity 16 -> 84 dropped.
    n = 100
    t = np.arange(n, dtype=np.int64) * 100
    z = np.zeros(n, np.int32)
    windowed = pad_windows(z, z, t, z, BatcherConfig(capacity=16), policy="stride")
    np.testing.assert_array_equal(windowed.overflow, [84])
    assert int(np.asarray(windowed.batch.valid).sum()) == 16


def test_dual_policy_overflow_when_capacity_below_size_threshold():
    # Degenerate config (capacity < size_threshold): dual windows truncate,
    # and both the offline and the streaming windower must say so.
    cfg = BatcherConfig(size_threshold=8, capacity=4)
    config = PipelineConfig(batcher=cfg)
    n = 64
    t = np.arange(n, dtype=np.int64)  # 1 us apart: all size-cut windows
    z = np.zeros(n, np.int32)
    windowed = pad_windows(z, z, t, z, cfg)
    np.testing.assert_array_equal(
        windowed.overflow, np.full(windowed.num_windows, 4)
    )
    sp = StreamingPipeline(config)
    res = sp.feed(z, z, t, z)
    np.testing.assert_array_equal(
        res.windows.overflow, np.full(res.num_windows, 4)
    )
    # Truncation is applied identically, so stream == scan still holds.
    from repro.data.synthetic import Recording

    rec = Recording(
        x=z, y=z, t=t, p=z, kind=z, obj=z,
        rso_tracks=np.zeros((0, 4)), duration_us=int(t[-1]), name="trunc",
    )
    scan = run_recording_scan(rec, config)
    tail = sp.flush()
    _assert_stream_equals_scan([res, tail], scan)
