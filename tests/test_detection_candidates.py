"""Candidate collection / threshold scoring: device-vs-numpy-vs-loop
equivalence, batched (O(1)-dispatch) sweeps, and edge cases (empty
stream, zero RSOs, truncation)."""
import numpy as np
import pytest

from repro.core.pipeline import (
    Candidates,
    PipelineConfig,
    collect_candidates,
    collect_candidates_loop,
    collect_candidates_many,
    collect_candidates_numpy,
    merge_candidates,
    score_threshold,
    threshold_sweep,
)
from repro.data.synthetic import Recording, make_recording, make_validation_suite


@pytest.fixture(scope="module")
def recording():
    return make_recording(seed=5, duration_s=0.4, n_rsos=2)


def _empty_recording() -> Recording:
    z = np.zeros(0, np.int32)
    return Recording(
        x=z, y=z, t=np.zeros(0, np.int64), p=z, kind=z, obj=z,
        rso_tracks=np.zeros((0, 4)), duration_us=0, name="empty",
    )


def _assert_candidates_equal(a: Candidates, b: Candidates):
    np.testing.assert_array_equal(a.counts, b.counts)
    np.testing.assert_array_equal(a.is_rso, b.is_rso)
    np.testing.assert_array_equal(a.object_best, b.object_best)


def test_vectorized_matches_loop(recording):
    cfg = PipelineConfig()
    _assert_candidates_equal(
        collect_candidates(recording, cfg), collect_candidates_loop(recording, cfg)
    )


def test_device_matches_numpy_oracle_on_suite():
    cfg = PipelineConfig()
    for rec in make_validation_suite(n_recordings=1, duration_s=0.4):
        _assert_candidates_equal(
            collect_candidates(rec, cfg), collect_candidates_numpy(rec, cfg)
        )


def test_collect_candidates_many_matches_single():
    cfg = PipelineConfig()
    recs = [
        make_recording(seed=1, duration_s=0.5, n_rsos=2),
        make_recording(seed=2, duration_s=0.3, n_rsos=1),  # fewer windows/RSOs
        make_recording(seed=4, duration_s=0.3, n_rsos=0),  # no RSOs at all
    ]
    many = collect_candidates_many(recs, cfg)
    assert len(many) == len(recs)
    for m, rec in zip(many, recs):
        _assert_candidates_equal(m, collect_candidates(rec, cfg))
    # Per-recording max_samples truncation applies inside the batch too.
    many_cap = collect_candidates_many(recs, cfg, max_samples=9)
    for m, rec in zip(many_cap, recs):
        _assert_candidates_equal(m, collect_candidates(rec, cfg, max_samples=9))


def test_collect_candidates_many_empty_list():
    assert collect_candidates_many([], PipelineConfig()) == []


def test_threshold_sweep_matches_numpy_oracle_scores():
    cfg = PipelineConfig()
    recs = make_validation_suite(n_recordings=1, duration_s=0.4)
    sweep = threshold_sweep(recs, thresholds=(2, 4, 5, 8), config=cfg)
    oracle = merge_candidates([collect_candidates_numpy(r, cfg) for r in recs])
    for thr, score in sweep.items():
        ref = score_threshold(oracle, thr)
        assert (score.tp, score.fp, score.fn, score.tn) == (
            ref.tp, ref.fp, ref.fn, ref.tn
        ), thr


def test_threshold_sweep_uses_batched_scan(monkeypatch):
    # The sweep must go through the vmapped many-recording path: disable
    # the single-recording scan and it still works.
    import repro.core.pipeline.scan as scan_mod

    def _forbidden(*a, **k):
        raise AssertionError("threshold_sweep fell back to per-recording scans")

    monkeypatch.setattr(scan_mod, "make_scan_fn", _forbidden)
    recs = [make_recording(seed=1, duration_s=0.3, n_rsos=1)]
    sweep = threshold_sweep(recs, thresholds=(5,))
    assert sweep[5].tp + sweep[5].fn > 0


def test_vectorized_matches_loop_with_max_samples(recording):
    cfg = PipelineConfig()
    for max_samples in (0, 7, 40):
        a = collect_candidates(recording, cfg, max_samples=max_samples)
        b = collect_candidates_loop(recording, cfg, max_samples=max_samples)
        assert len(a.counts) == min(max_samples, len(collect_candidates(recording, cfg).counts))
        _assert_candidates_equal(a, b)


def test_empty_recording_yields_empty_candidates():
    cand = collect_candidates(_empty_recording(), PipelineConfig())
    assert cand.counts.shape == (0,)
    assert cand.is_rso.shape == (0,)
    assert cand.object_best.shape == (0,)
    score = score_threshold(cand, 5)
    assert (score.tp, score.fp, score.fn, score.tn) == (0, 0, 0, 0)
    assert score.accuracy == 0.0
    assert score.precision == 0.0 and score.recall == 0.0


def test_zero_rso_recording_has_no_fn_inflation():
    rec = make_recording(seed=4, duration_s=0.3, n_rsos=0)
    assert rec.rso_tracks.shape == (0, 4)
    cand = collect_candidates(rec, PipelineConfig())
    # Stars/noise still produce candidates, but none match an RSO and no
    # phantom object-level misses appear at any threshold.
    assert len(cand.counts) > 0
    assert not cand.is_rso.any()
    assert cand.object_best.shape == (0,)
    for thr in (2, 5, 10):
        assert score_threshold(cand, thr).fn == 0
    assert score_threshold(cand, 5).tp == 0


def test_max_samples_truncation_cap(recording):
    full = collect_candidates(recording, PipelineConfig())
    cap = len(full.counts) // 2
    truncated = collect_candidates(recording, PipelineConfig(), max_samples=cap)
    assert len(truncated.counts) == cap
    # Truncation keeps the window-major prefix of the full candidate list.
    np.testing.assert_array_equal(truncated.counts, full.counts[:cap])
    np.testing.assert_array_equal(truncated.is_rso, full.is_rso[:cap])


def test_merge_candidates_empty_list():
    merged = merge_candidates([])
    assert merged.counts.shape == (0,)
    assert merged.is_rso.shape == (0,)
    assert merged.object_best.shape == (0,)
    assert score_threshold(merged, 5).accuracy == 0.0


def test_merge_candidates_concatenates(recording):
    cand = collect_candidates(recording, PipelineConfig())
    merged = merge_candidates([cand, cand])
    assert len(merged.counts) == 2 * len(cand.counts)
    s1, s2 = score_threshold(cand, 5), score_threshold(merged, 5)
    assert (s2.tp, s2.fp, s2.fn, s2.tn) == (2 * s1.tp, 2 * s1.fp, 2 * s1.fn, 2 * s1.tn)


def test_score_threshold_known_values():
    cand = Candidates(
        counts=np.array([1, 4, 5, 9], np.int32),
        is_rso=np.array([False, True, True, False]),
        object_best=np.array([4, 9], np.int32),
    )
    s = score_threshold(cand, 5)
    assert s.tp == 1  # count 5 RSO passes
    assert s.fp == 1  # count 9 non-RSO passes
    assert s.fn == 1  # object_best 4 below threshold
    assert s.tn == 1  # count 1 non-RSO rejected
    assert s.accuracy == pytest.approx(0.5)
