"""Chaos-injection harness: the fault-tolerance acceptance gate.

Runs the seeded fault schedule — every entry of the taxonomy — against a
fault-tolerant DetectionService and pins the two invariants of DESIGN.md
Sec. 13:

* no injected fault ever raises out of ``feed`` / ``pump`` (each leaves
  a structured SessionError instead), and
* every *healthy* session's outputs are bit-identical to a fault-free
  reference run of the same feeds — fault isolation, measured bitwise.

Everything here is deterministic: fake clock, seeded schedule, seeded
payloads. The latency soak over the same harness lives in
``benchmarks/chaos_soak.py``.
"""
import numpy as np
import pytest

from repro.serve.chaos import (
    FAULT_TAXONOMY,
    ChaosConfig,
    ChaosHarness,
    compare_outputs,
)

SMALL = ChaosConfig(n_sensors=5, n_faulty=2, n_rounds=32, tiers=(4, 8), seed=3)


@pytest.fixture(scope="module")
def report():
    """One full-taxonomy run shared by the invariant tests."""
    return ChaosHarness(SMALL).run()


def test_every_fault_fires_at_least_once(report):
    assert set(report.fired) == set(FAULT_TAXONOMY)
    missing = [k for k, n in report.fired.items() if n < 1]
    assert not missing, f"faults never injected: {missing}"


def test_no_fault_escapes_the_service(report):
    assert report.escaped_errors == []


def test_healthy_sessions_bit_identical_under_faults(report):
    assert report.healthy_windows > 0  # the comparison is not vacuous
    assert report.bit_identical, report.mismatches


def test_shed_accounting_is_exact(report):
    shed = report.shed
    assert shed["exact"]
    assert shed["offered"] == shed["accepted"] + shed["shed"]
    assert shed["shed"] > 0  # the burst fault actually exercised the budget


def test_faults_leave_structured_error_records(report):
    kinds = {e.kind for e in report.errors}
    assert "validation" in kinds  # non_monotone / duplicate / garbage_coords
    assert "evicted" in kinds  # stall -> heartbeat eviction
    n_validation = sum(e.kind == "validation" for e in report.errors)
    assert n_validation == report.quarantines
    assert all(e.message for e in report.errors)
    assert all(e.sid >= 0 and e.time_s >= 0.0 for e in report.errors)


def test_quarantine_eviction_and_retry_paths_all_taken(report):
    assert report.quarantines >= 1
    assert report.evictions >= 1
    assert report.step_retries + report.degraded_rounds >= 1


def test_schedule_is_deterministic_per_seed():
    assert ChaosHarness(SMALL).schedule() == ChaosHarness(SMALL).schedule()
    other = ChaosHarness(
        ChaosConfig(
            n_sensors=5, n_faulty=2, n_rounds=32, tiers=(4, 8), seed=4
        )
    ).schedule()
    assert other != ChaosHarness(SMALL).schedule()


def test_degraded_rounds_recover_bit_identically():
    """A schedule of only step_exception faults drives both variants —
    heal-within-retries and retry-exhausted degraded rounds — and the
    restored-and-refed chunks still match the fault-free run bitwise."""
    cfg = ChaosConfig(
        n_sensors=4,
        n_faulty=1,
        n_rounds=24,
        tiers=(4,),
        seed=11,
        faults=("step_exception",),
    )
    rep = ChaosHarness(cfg).run()
    assert rep.fired["step_exception"] >= 2
    assert rep.step_retries >= 1
    assert rep.degraded_rounds >= 1
    assert rep.escaped_errors == []
    assert rep.bit_identical, rep.mismatches
    assert any(e.kind == "degraded_round" for e in rep.errors)


def test_eviction_churn_interleaved_with_live_feeds():
    """Heartbeat evictions and attach/detach churn interleave with live
    feeds on the other slots: every stall window ends in an eviction
    whose flush + slot recycle never perturbs the healthy streams."""
    cfg = ChaosConfig(
        n_sensors=5,
        n_faulty=2,
        n_rounds=36,
        tiers=(4, 8),
        seed=5,
        faults=("stall", "churn"),
    )
    rep = ChaosHarness(cfg).run()
    assert rep.fired["stall"] >= 1 and rep.fired["churn"] >= 1
    assert rep.evictions >= 1
    assert rep.escaped_errors == []
    assert rep.healthy_windows > 0
    assert rep.bit_identical, rep.mismatches
    assert any(e.kind == "evicted" for e in rep.errors)


def test_chaos_config_validation():
    with pytest.raises(ValueError, match="n_faulty"):
        ChaosConfig(n_sensors=3, n_faulty=3)
    with pytest.raises(ValueError, match="unknown faults"):
        ChaosConfig(faults=("non_monotone", "gremlins"))
    with pytest.raises(ValueError, match="stall_rounds"):
        ChaosConfig(heartbeat_rounds=4, stall_rounds=5)
    with pytest.raises(ValueError, match="queue budget"):
        ChaosConfig(chunk_events=900, queue_budget_events=800)


def test_compare_outputs_flags_real_differences():
    a = [np.arange(6).reshape(2, 3), np.ones(4)]
    assert compare_outputs(a, [x.copy() for x in a], "s") == []
    b = [np.arange(6).reshape(2, 3), np.zeros(4)]
    bad = compare_outputs(a, b, "s")
    assert len(bad) == 1 and "4/4 elements differ" in bad[0]
    assert compare_outputs(a, a[:1], "s") == ["s: 2 surfaces vs 1"]
    c = [np.arange(6).reshape(3, 2), np.ones(4)]
    assert "shape" in compare_outputs(a, c, "s")[0]
