"""Fleet quickstart: a multi-sensor constellation behind one jitted step.

Builds a scenario-diverse 4-sensor sky (a crossing pair, a GEO
slow-mover, a tumbling RSO, and a ballistic arc — each sensor with its
own pointing jitter), then streams all four through ONE
``FleetPipeline``: every ``feed`` takes one 20 ms chunk per sensor and
drives the whole fleet through a single vmapped/jitted step with
per-sensor carries (batcher remainder, tagged event atlas, tracker
state) riding along between rounds. Per-sensor results are bit-identical
to running four independent ``StreamingPipeline`` objects — the fleet
just pays one dispatch instead of four.

  PYTHONPATH=src python examples/fleet_quickstart.py
"""
import dataclasses
import time

import numpy as np

from repro.core.pipeline import FleetPipeline, PipelineConfig
from repro.core.tracking import confirmed
from repro.data.evas import iter_chunks
from repro.data.synthetic import SCENARIO_FAMILIES, make_fleet_recordings

CHUNK_US = 20_000  # feed 20 ms per sensor per round
FAMILIES = ("crossing", "geo_slow", "tumbling", "ballistic")


def main() -> None:
    print(f"Generating a {len(FAMILIES)}-sensor scenario-diverse sky (2 s)...")
    recs = [
        dataclasses.replace(
            make_fleet_recordings(
                1, scenario=SCENARIO_FAMILIES[fam], seed0=31 * s, duration_s=2.0
            )[0],
            name=f"sensor{s}-{fam}",
        )
        for s, fam in enumerate(FAMILIES)
    ]
    for rec in recs:
        print(f"  {rec.name:<22} {len(rec):>7,} events")

    # Slice every sensor's stream into 20 ms rounds (None = exhausted).
    per_sensor = [list(iter_chunks(r, CHUNK_US)) for r in recs]
    n_rounds = max(len(c) for c in per_sensor)

    cfg = PipelineConfig()  # paper defaults: 16px cells, min_events=5
    fleet = FleetPipeline(cfg, n_sensors=len(recs), with_tracking=True)

    windows = 0
    detections = 0
    latencies = []
    for i in range(n_rounds):
        chunks = [c[i] if i < len(c) else None for c in per_sensor]
        t0 = time.perf_counter()
        out = fleet.feed(chunks)  # ONE step for the whole fleet
        n_det = (
            int(np.asarray(out.clusters.valid).sum())
            if out.clusters is not None else 0
        )
        latencies.append((time.perf_counter() - t0) * 1e3)
        windows += out.total_windows
        detections += n_det
    tail = fleet.flush()  # close every sensor's trailing window
    windows += tail.total_windows

    print(
        f"Processed {windows} windows across {len(recs)} sensors "
        f"in {len(latencies)} fleet feeds."
    )
    print(f"Clusters passing min_events=5: {detections}")
    lat = np.asarray(latencies[3:])  # skip jit warmup rounds
    print(
        f"Steady-state fleet feed latency: p50={np.percentile(lat, 50):.1f} ms "
        f"p99={np.percentile(lat, 99):.1f} ms (paper budget: 62 ms)"
    )

    final = fleet.state.tracks  # leaves (S, T): stacked per-sensor carries
    for s, rec in enumerate(recs):
        conf = np.asarray(confirmed(
            type(final)(*(np.asarray(leaf[s]) for leaf in final)), cfg.tracker
        ))
        ids = np.flatnonzero(conf)
        line = ", ".join(
            f"({float(final.x[s, i]):5.0f},{float(final.y[s, i]):5.0f}) "
            f"hits={int(final.hits[s, i])}"
            for i in ids
        ) or "none"
        print(f"  sensor {s} ({rec.name}): {len(ids)} confirmed tracks: {line}")


if __name__ == "__main__":
    main()
