"""Streaming quickstart: detect orbiting objects from a live-style feed.

Replays a synthetic EVAS-like recording through the streaming engine in
20 ms chunks — the cadence of a live event camera — instead of handing
the whole file to the offline driver. Each ``feed`` call windows the
incoming events with the paper's dual-threshold policy, runs ONE jit'd
step over the windows that closed, and returns their clusters, quality
metrics, and tracker state; the dual-threshold remainder, persistent
event atlas, and tracker carry ride along in ``StreamingPipeline.state``
between calls, so the results are bit-identical to
``run_recording_scan`` over the same events no matter how the stream is
chunked.

  PYTHONPATH=src python examples/stream_quickstart.py
"""
import time

import numpy as np

from repro.core.events import stride_bounds
from repro.core.pipeline import PipelineConfig, StreamingPipeline
from repro.core.tracking import confirmed
from repro.data.synthetic import make_recording

CHUNK_US = 20_000  # feed 20 ms of events at a time


def main() -> None:
    print("Generating a 2 s synthetic EVAS-like recording (2 RSOs)...")
    rec = make_recording(seed=7, duration_s=2.0, n_rsos=2, lens="standard")
    print(f"  {len(rec):,} events")

    cfg = PipelineConfig()  # paper defaults: 16px cells, min_events=5
    sp = StreamingPipeline(cfg, with_tracking=True)

    n_windows = 0
    n_detections = 0
    latencies = []
    for lo, hi, _ in stride_bounds(rec.t, CHUNK_US):
        t0 = time.perf_counter()
        res = sp.feed(rec.x[lo:hi], rec.y[lo:hi], rec.t[lo:hi], rec.p[lo:hi])
        n_det = int(np.asarray(res.clusters.valid).sum())  # syncs the step
        latencies.append((time.perf_counter() - t0) * 1e3)
        n_windows += res.num_windows
        n_detections += n_det
    tail = sp.flush()  # close the trailing partial window
    n_windows += tail.num_windows
    n_detections += int(np.asarray(tail.clusters.valid).sum())

    print(f"Processed {n_windows} windows from {len(latencies)} chunked feeds.")
    print(f"Clusters passing min_events=5: {n_detections}")
    lat = np.asarray(latencies[3:])  # skip jit warmup feeds
    print(
        f"Steady-state per-chunk latency: p50={np.percentile(lat, 50):.1f} ms "
        f"p99={np.percentile(lat, 99):.1f} ms (paper budget: 62 ms)"
    )

    final = sp.state.tracks
    conf = np.asarray(confirmed(final, cfg.tracker))
    print(f"Confirmed tracks: {int(conf.sum())}")
    for i in np.flatnonzero(conf):
        print(
            f"  track {i}: pos=({float(final.x[i]):6.1f},{float(final.y[i]):6.1f}) "
            f"vel=({float(final.vx[i]):+5.2f},{float(final.vy[i]):+5.2f}) px/win "
            f"hits={int(final.hits[i])} entropy={float(final.entropy[i]):.2f}"
        )


if __name__ == "__main__":
    main()
