"""Constellation quickstart: sensor sessions sharded over service shards.

A surveillance-network scenario: six ground stations stream into a
2-shard :class:`~repro.serve.constellation.ConstellationService`. The
planner places each new station on the least-loaded shard; every round
each up shard dispatches its own pipelined fleet step (rounds interleave
across shards) and publishes an int8+error-feedback compressed summary
plane to its peers through the cross-shard exchange. Mid-run one
station is migrated by hand — its slot carry is the entire stream
state, so the stream resumes bit-identically on the new shard — and a
simulated whole-shard outage is rescued: the stalled shard's sessions
re-migrate to the survivor, no stream lost, and the shard is revived
once "repaired".

  PYTHONPATH=src python examples/constellation_quickstart.py
"""
import dataclasses

from repro.core.pipeline import PipelineConfig
from repro.data.evas import iter_chunks
from repro.data.synthetic import SCENARIO_FAMILIES, make_fleet_recordings
from repro.serve import ConstellationService, FaultConfig
from repro.serve.chaos import _FlakyFleet

CHUNK_US = 20_000  # live cadence: one 20 ms chunk per sensor per round
FAMILIES = ("crossing", "geo_slow", "tumbling", "ballistic", "jitter")


def _recording(idx: int):
    fam = FAMILIES[idx % len(FAMILIES)]
    rec = make_fleet_recordings(
        1, scenario=SCENARIO_FAMILIES[fam], seed0=31 * idx, duration_s=1.0
    )[0]
    return dataclasses.replace(rec, name=f"station{idx}-{fam}")


def main() -> None:
    config = PipelineConfig()  # paper defaults: 16px cells, 20 ms / 250 ev
    cs = ConstellationService(
        config,
        n_shards=2,
        tiers=(4, 8),
        faults=FaultConfig(degrade_on_step_failure=True, max_step_retries=0),
        rescue_after_degraded_rounds=2,
    )
    print(
        f"constellation up: {cs.n_shards} shards, "
        f"{cs.capacity} slots total, exchange={cs.exchange.mode}"
    )

    feeds, windows = {}, 0
    for i in range(6):
        rec = _recording(i)
        gid = cs.attach(rec.name)
        feeds[gid] = iter_chunks(rec, CHUNK_US)
        print(f"  + {rec.name} -> gid {gid} on shard {cs.shard_of(gid)}")
    print(f"placement: loads {cs.loads}")

    def round_(rnd: int) -> int:
        served = []
        for gid, it in list(feeds.items()):
            chunk = next(it, None)
            if chunk is None:
                continue
            served += cs.feed(gid, *chunk)
        served += cs.pump(force=True)
        return sum(f.num_windows for f in served)

    for rnd in range(10):
        windows += round_(rnd)

    mover = next(iter(feeds))
    cs.migrate(mover, 1 - cs.shard_of(mover))
    print(
        f"migrated gid {mover} to shard {cs.shard_of(mover)} "
        f"(stream state = slot carry; resumes bit-identically)"
    )

    # Simulate a whole-shard outage: every fleet dispatch on shard 0
    # fails until "repaired". Two degraded rounds trip the rescue.
    stalled = _FlakyFleet(cs.shard(0).service._fleet)
    stalled.fail_next = 10**9
    cs.shard(0).service._fleet = stalled
    for rnd in range(4):
        windows += round_(rnd)
    print(
        f"shard 0 stalled -> rescued: down={cs.down_shards}, "
        f"loads {cs.loads}, sessions lost: {6 - cs.n_sessions}"
    )
    stalled.fail_next = 0
    cs.revive_shard(0)
    print(f"shard 0 repaired and revived: down={cs.down_shards}")

    for rnd in range(6):
        windows += round_(rnd)
    for gid in list(feeds):
        cs.detach(gid)

    st = cs.stats()
    ex = st["exchange"]
    print(
        f"done: {windows} windows, {st['migrations']} migrations "
        f"({st['rescues']} rescue), exchange {ex['rounds']} rounds at "
        f"{ex['compression_ratio']:.2f}x compression "
        f"({ex['wire_bytes']:,} vs {ex['exact_bytes']:,} bytes)"
    )


if __name__ == "__main__":
    main()
