"""Serve detections: dynamic sensor sessions over the slot-pooled fleet.

A ground-station scenario: sensors come and go while the service keeps
one slot-pooled fleet step hot. Three sensors attach up front (the pool
opens at the 4-slot tier); mid-run two more stations join — the fifth
attach promotes the pool to the 8-slot tier with carry migration, live
sessions unaffected — and one of the originals drops out, its slot
zeroed and recycled. Chunks are micro-batched under the paper's
dual-threshold admission policy (20 ms / 250 events, Sec. III-A), so
however many sessions are live, each round costs ONE vmapped fleet
dispatch. Every session's outputs are bit-identical to a dedicated
single-sensor ``StreamingPipeline`` fed the same chunks.

  PYTHONPATH=src python examples/serve_detections.py
"""
import dataclasses

import numpy as np

from repro.core.pipeline import PipelineConfig
from repro.core.tracking import confirmed
from repro.data.evas import iter_chunks
from repro.data.synthetic import SCENARIO_FAMILIES, make_fleet_recordings
from repro.serve import DetectionService

CHUNK_US = 20_000  # live cadence: one 20 ms chunk per sensor per round
FAMILIES = ("crossing", "geo_slow", "tumbling", "ballistic", "jitter")


def _recording(idx: int):
    fam = FAMILIES[idx % len(FAMILIES)]
    rec = make_fleet_recordings(
        1, scenario=SCENARIO_FAMILIES[fam], seed0=17 * idx, duration_s=1.5
    )[0]
    return dataclasses.replace(rec, name=f"station{idx}-{fam}")


def main() -> None:
    config = PipelineConfig()  # paper defaults: 16px cells, 20 ms / 250 ev
    svc = DetectionService(config, tiers=(4, 8, 16))
    print(f"DetectionService up: tier capacity {svc.capacity} slots")

    feeds: dict[int, object] = {}  # sid -> chunk iterator (live cadence)
    recs: dict[int, object] = {}

    def join(idx: int) -> int:
        rec = _recording(idx)
        sid = svc.attach(rec.name)
        feeds[sid] = iter_chunks(rec, CHUNK_US)
        recs[sid] = rec
        print(
            f"  + {rec.name} attached as session {sid} "
            f"(slot {svc.session(sid).slot}, pool {svc.capacity} slots, "
            f"{len(rec):,} events)"
        )
        return sid

    first = [join(i) for i in range(3)]
    windows = dets = 0
    for rnd in range(110):
        if rnd == 25:  # two stations join mid-run -> tier promotion at #5
            join(3), join(4)
            print(f"    (pool promoted: capacity {svc.capacity}, "
                  f"promotions {svc.promotions})")
        if rnd == 40:  # one original drops out; its slot is recycled
            tail = svc.detach(first[0])
            windows += tail.num_windows
            st = svc.session(first[0]).stats
            print(
                f"  - session {first[0]} detached: {st.windows} windows, "
                f"p50 service latency {st.latency_percentile(50):.1f} ms"
            )
        for sid, chunks in list(feeds.items()):
            if svc.session(sid).state != "live":
                continue
            chunk = next(chunks, None)  # each session streams its own clock
            if chunk is not None:
                for fd in svc.feed(sid, *chunk):  # admission may fire
                    windows += fd.result.num_windows
                    dets += int(np.asarray(fd.result.clusters.valid).sum())
        for fd in svc.pump(force=True):  # drain the round deterministically
            windows += fd.result.num_windows
            dets += int(np.asarray(fd.result.clusters.valid).sum())

    print(f"\nProcessed {windows} windows, {dets} detections.")
    print("(early sessions' p99 includes the one-off cold-compile rounds; "
          "benchmarks/serve_latency.py gates the warmed steady state)")
    for sid in sorted(recs):
        sess = svc.session(sid)
        if sess.state == "live":
            final = svc.detach(sid)
            n_conf = int(np.asarray(confirmed(final.final_tracks, config.tracker)).sum())
        else:
            n_conf = 0
        st = sess.stats
        print(
            f"  {sess.name:<22} {st.events:>8,} events  {st.windows:>4} windows  "
            f"p99 latency {st.latency_percentile(99):6.1f} ms  "
            f"confirmed tracks at detach: {n_conf}"
        )


if __name__ == "__main__":
    main()
