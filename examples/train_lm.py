"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

Uses the framework's full training path — model zoo config, AdamW +
cosine schedule, train_step with z-loss, async checkpointing — on the
synthetic Markov token stream. Loss drops from ~ln(V) toward the chain's
conditional entropy.

  PYTHONPATH=src python examples/train_lm.py            # ~100M, 300 steps
  PYTHONPATH=src python examples/train_lm.py --fast     # tiny smoke run
"""
import argparse

from repro.launch.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="tiny config, 40 steps")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()
    if args.fast:
        params, log = train(
            arch="llama3.2-1b", preset="tiny", steps=40, batch=8, seq=64,
            ckpt_dir=args.ckpt_dir,
        )
    else:
        params, log = train(
            arch="llama3.2-1b", preset="small100m", steps=300, batch=8,
            seq=256, lr=1e-3, ckpt_dir=args.ckpt_dir, log_every=20,
        )
    first, last = log[0], log[-1]
    drop = first["loss"] - last["loss"]
    print(f"\nloss {first['loss']:.3f} -> {last['loss']:.3f} (drop {drop:.3f})")
    assert drop > 0.05, "training failed to reduce loss"


if __name__ == "__main__":
    main()
