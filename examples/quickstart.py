"""Quickstart: detect orbiting objects in a synthetic night-sky recording.

Runs the paper's full pipeline — dual-threshold event batching, grid
quantization (the FPGA IP core as a Pallas kernel / jnp), cluster
formation with min_events=5, entropy metrics, and tracking — and prints
the detections with their quality metrics.

Uses the device-resident scan driver (``run_recording_scan``): the whole
recording is windowed on host once, then conditioning -> clustering ->
metrics -> tracking run as a single compiled ``lax.scan`` with one
device dispatch. When events arrive as a live stream instead of a
recorded file, feed them incrementally to ``StreamingPipeline`` — see
``examples/stream_quickstart.py`` — for bit-identical results per
closed window.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core.pipeline import PipelineConfig, run_recording_scan, evaluate_detection
from repro.core.tracking import confirmed
from repro.data.synthetic import make_recording

def main() -> None:
    print("Generating a 2 s synthetic EVAS-like recording (2 RSOs)...")
    rec = make_recording(seed=7, duration_s=2.0, n_rsos=2, lens="standard")
    print(f"  {len(rec):,} events "
          f"({np.sum(rec.kind == 2):,} RSO / {np.sum(rec.kind == 1):,} star "
          f"/ {np.sum(rec.kind == 0):,} noise)")

    cfg = PipelineConfig()  # paper defaults: 16px cells, min_events=5
    result = run_recording_scan(rec, cfg, with_tracking=True)
    print(f"Processed {result.num_windows} windows "
          f"(20 ms / 250-event batches, one compiled scan).")

    n_det = int(np.asarray(result.clusters.valid).sum())
    print(f"Clusters passing min_events=5: {n_det}")

    final = result.final_tracks
    conf = np.asarray(confirmed(final, cfg.tracker))
    print(f"Confirmed tracks: {int(conf.sum())}")
    for i in np.flatnonzero(conf):
        print(
            f"  track {i}: pos=({float(final.x[i]):6.1f},{float(final.y[i]):6.1f}) "
            f"vel=({float(final.vx[i]):+5.2f},{float(final.vy[i]):+5.2f}) px/win "
            f"hits={int(final.hits[i])} entropy={float(final.entropy[i]):.2f}"
        )

    score = evaluate_detection(rec, cfg)
    print(
        f"Detection accuracy vs ground truth: {100 * score.accuracy:.1f}% "
        f"(tp={score.tp} fp={score.fp} fn={score.fn} tn={score.tn})"
    )


if __name__ == "__main__":
    main()
