"""ARACHNID-style multi-camera array as SPMD (paper Sec. V-D/V-E).

Each event camera pairs with one processing node; the paper scales 1->8
nodes with linear throughput and invariant latency (Table V). Here the
node axis is a JAX mesh axis: `shard_map` runs the SAME per-node pipeline
on every shard — one device = one EBC-FPGA node.

  PYTHONPATH=src python examples/multi_node_array.py --nodes 8
(requires XLA_FLAGS=--xla_force_host_platform_device_count=8; the script
sets it before importing jax.)
"""
import argparse
import os
import sys

N_NODES = 8
if "--nodes" in sys.argv:
    N_NODES = int(sys.argv[sys.argv.index("--nodes") + 1])
os.environ.setdefault(
    "XLA_FLAGS", f"--xla_force_host_platform_device_count={N_NODES}"
)

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.core.events import EventBatch  # noqa: E402
from repro.core.grid_clustering import GridConfig, grid_cluster  # noqa: E402
from repro.data.synthetic import make_recording  # noqa: E402
from repro.launch.mesh import make_mesh, shard_map  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--nodes", type=int, default=N_NODES)
    ap.add_argument("--windows", type=int, default=64)
    args = ap.parse_args()
    nodes = min(args.nodes, jax.device_count())
    mesh = make_mesh((nodes,), ("node",))
    grid = GridConfig()

    # One synthetic recording per camera node, stacked: (nodes, W, E).
    print(f"Simulating {nodes} camera nodes x {args.windows} windows...")
    cap = 256
    batches = []
    for n in range(nodes):
        rec = make_recording(seed=100 + n, duration_s=args.windows * 0.02, n_rsos=1 + n % 3)
        from repro.core.events import window_batches
        xs = np.zeros((args.windows, cap), np.int32)
        ys = np.zeros((args.windows, cap), np.int32)
        ts = np.zeros((args.windows, cap), np.int32)
        ps = np.zeros((args.windows, cap), np.int32)
        vs = np.zeros((args.windows, cap), bool)
        for w, (b, _) in enumerate(window_batches(rec.x, rec.y, rec.t, rec.p, capacity=cap)):
            if w >= args.windows:
                break
            xs[w], ys[w], ts[w], ps[w], vs[w] = (
                np.asarray(b.x), np.asarray(b.y), np.asarray(b.t),
                np.asarray(b.p), np.asarray(b.valid),
            )
        batches.append((xs, ys, ts, ps, vs))
    stacked = EventBatch(*[
        jnp.asarray(np.stack([b[i] for b in batches])) for i in range(5)
    ])  # each leaf: (nodes, W, E)

    sharding = NamedSharding(mesh, P("node"))
    stacked = jax.tree.map(lambda x: jax.device_put(x, sharding), stacked)

    @jax.jit
    def per_node_pipeline(batch: EventBatch):
        # vmap over windows inside each node shard; shard_map over nodes.
        def node_fn(b):
            b = jax.tree.map(lambda a: a[0], b)  # shard-local node dim
            out = jax.vmap(lambda eb: grid_cluster(eb, grid).count)(b)
            return out[None]

        return shard_map(
            node_fn, mesh=mesh,
            in_specs=(jax.tree.map(lambda _: P("node"), batch),),
            out_specs=P("node"),
        )(batch)

    counts = per_node_pipeline(stacked)
    counts.block_until_ready()
    t0 = time.time()
    counts = per_node_pipeline(stacked)
    counts.block_until_ready()
    dt = time.time() - t0
    ev_total = int(np.asarray(stacked.valid).sum())
    print(f"nodes={nodes} windows={args.windows} events={ev_total:,}")
    print(f"aggregate throughput: {ev_total / dt / 1e6:.2f} MEv/s "
          f"({dt * 1e3:.1f} ms for the array)")
    k = np.asarray(counts)
    print(f"clusters >= {grid.min_events} events: {(k >= grid.min_events).sum()} across array")


if __name__ == "__main__":
    main()
