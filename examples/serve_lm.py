"""Serve a small LM with batched requests under the paper's admission
policy (close a batch at 20 ms OR max_batch requests — Sec. III-A of the
paper, transplanted to LLM serving).

  PYTHONPATH=src python examples/serve_lm.py
"""
from repro.launch.serve import serve_demo


def main() -> None:
    stats = serve_demo(arch="llama3.2-1b", n_requests=24, max_batch=8)
    print("serving stats (dual-threshold batching, 20 ms / 8 requests):")
    for k, v in stats.items():
        print(f"  {k}: {v}")
    assert stats["requests"] == 24
    assert stats["tokens_generated"] > 0


if __name__ == "__main__":
    main()
