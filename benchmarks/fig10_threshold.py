"""Paper Fig. 10b: detection accuracy vs min_events threshold. The curve
must peak at ~5 with ~97% accuracy."""
from __future__ import annotations

from repro.core.pipeline import PipelineConfig, threshold_sweep
from repro.data.synthetic import make_recording


def bench() -> list[tuple[str, float, str]]:
    recs = [
        make_recording(seed=s, duration_s=1.0, n_rsos=1 + (s % 3))
        for s in (1, 2, 3)
    ] + [make_recording(seed=11, duration_s=1.0, n_rsos=1, lens="telephoto"),
         make_recording(seed=21, duration_s=1.0, n_rsos=2, lens="wide")]
    sweep = threshold_sweep(recs, thresholds=(2, 3, 4, 5, 6, 8, 10),
                            config=PipelineConfig())
    rows = []
    best = max(sweep, key=lambda t: sweep[t].accuracy)
    for t, s in sweep.items():
        mark = "_OPT" if t == best else ""
        rows.append(
            (f"fig10/min_events_{t}", 0.0,
             f"acc{100 * s.accuracy:.1f}pct_tp{s.tp}_fp{s.fp}_fn{s.fn}{mark}")
        )
    return rows
