"""Constellation scaling curve: sensors vs aggregate events/s and p99.

The scale-out bench for the sharded serving layer (DESIGN.md Sec. 15).
For each sensor count in SENSORS a :class:`ConstellationService` with
SHARDS shards runs N_ROUNDS live-cadence beats (every sensor feeds one
LEVEL-event chunk spanning CHUNK_US of sensor time, then one forced
pump dispatches every shard's round; compressed cross-shard exchange
stays on), reporting aggregate sustained events/s and per-round
p50/p99. A second single-shard run at RATIO_SENSORS sensors measures
what sharding itself buys at equal sensor count.

Gates (exit code 1 on failure, BENCH_NO_FAIL=1 to disable):

* **monotone scaling** — aggregate events/s strictly non-decreasing
  from 8 up through MONOTONE_MIN_SENSORS (>= 128): batching more
  sensors through the vmapped shard steps must amortize, not thrash.
  Host-bounded like the p99 gate: only points up to GATE_MAX_SENSORS
  are gated (a 1-core host is oversubscribed past ~32 live sensors and
  its aggregate legitimately dips); the reference multi-core host gates
  the full 8 -> 128 curve. The json records the applied bound.
* **p99 budget** — per-round p99 <= BUDGET_MS (the paper's 62 ms) at
  every point that fits the host: sensor counts up to GATE_MAX_SENSORS,
  which defaults to 32 x host_cores (one core drives ~32 live sensors
  inside the budget on the CPU backend; larger points are still
  measured and recorded, tracked from dedicated hardware).
* **shard speedup** — SHARDS-shard aggregate >= target x the 1-shard
  aggregate at RATIO_SENSORS sensors. The 2x target requires shards to
  actually run concurrently: a multi-device mesh (one device slice per
  shard) plus enough host cores to drive them. On a single-device or
  single-core host the shards time-slice one device, so the gate
  degrades to a documented no-regression floor (0.85x — the shard
  layer may not cost more than 15% overhead even where it cannot win),
  same convention as the ingest bench. BENCH_GATE_SHARDS overrides
  either; the json records applied and multi-device targets.
* **multi-shard chaos** — the shard chaos harness
  (:mod:`repro.serve.chaos_shards`, whole-shard stall included) must
  leave healthy outputs bit-identical with no session lost (CHAOS=0
  skips, e.g. when the suite already ran it).

Results land in BENCH_constellation.json at the repo root with the
uniform ``bench`` block the ``benchmarks.run`` aggregator consumes.

  PYTHONPATH=src python benchmarks/constellation_scaling.py
  SENSORS=8,32,128,512 SHARDS=2 LEVEL=250 N_ROUNDS=12 ...  (CI knobs)
"""
import gc
import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np
from _common import git_commit

from repro.core.pipeline import PipelineConfig
from repro.core.pipeline.fleet import tier_capacity
from repro.serve.batcher import AdmissionConfig
from repro.serve.constellation import ConstellationService

SENSORS = tuple(
    int(v) for v in os.environ.get("SENSORS", "8,32,128,512").split(",")
)
SHARDS = int(os.environ.get("SHARDS", "2"))
LEVEL = int(os.environ.get("LEVEL", "250"))  # events/sensor/round (1 window)
N_ROUNDS = int(os.environ.get("N_ROUNDS", "12"))
N_WARMUP = int(os.environ.get("N_WARMUP", "3"))
CHUNK_US = int(os.environ.get("CHUNK_US", "20000"))  # live-cadence beat
BUDGET_MS = float(os.environ.get("BUDGET_MS", "62"))
RATIO_SENSORS = int(os.environ.get("RATIO_SENSORS", "32"))
MONOTONE_MIN_SENSORS = int(os.environ.get("MONOTONE_MIN_SENSORS", "128"))
EXCHANGE = os.environ.get("EXCHANGE", "int8_ef")
SHARD_TARGET_MULTIDEVICE = 2.0
SHARD_FLOOR_SHARED_DEVICE = 0.85
REPO_ROOT = Path(__file__).resolve().parent.parent


def _stream(seed: int, n: int, dt_us: int):
    rng = np.random.default_rng(seed)
    return (
        rng.integers(40, 560, n).astype(np.int64),
        rng.integers(40, 400, n).astype(np.int64),
        (np.arange(n, dtype=np.int64) + 1) * dt_us,
        rng.integers(0, 2, n).astype(np.int64),
    )


def _replay(n_sensors: int, n_shards: int):
    """One (sensor count, shard count) point: aggregate sustained
    events/s over N_ROUNDS forced-pump beats, per-round times, and the
    constellation's exchange stats. Each shard's slot pool is sized to
    its share up front (one tier, one compile per shard shape)."""
    per_shard = tier_capacity(max(1, -(-n_sensors // n_shards)))
    cs = ConstellationService(
        PipelineConfig(),
        n_shards=n_shards,
        tiers=(per_shard,),
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        exchange=EXCHANGE,
    )
    total = (N_WARMUP + N_ROUNDS) * LEVEL
    dt_us = max(1, CHUNK_US // LEVEL)
    streams = [_stream(7 * s + 1, total, dt_us) for s in range(n_sensors)]
    gids = [cs.attach(f"c{s}") for s in range(n_sensors)]
    served = []

    def beat(rnd):
        lo, hi = rnd * LEVEL, (rnd + 1) * LEVEL
        for s, gid in enumerate(gids):
            x, y, t, p = streams[s]
            served.extend(cs.feed(gid, x[lo:hi], y[lo:hi], t[lo:hi], p[lo:hi]))
        served.extend(cs.pump(force=True))

    for rnd in range(N_WARMUP):  # compiles each shard's (S, W) step shape
        beat(rnd)
    cs.drain()
    served.clear()

    times = []
    t_all = time.perf_counter()
    for rnd in range(N_WARMUP, N_WARMUP + N_ROUNDS):
        t0 = time.perf_counter()
        beat(rnd)
        times.append((time.perf_counter() - t0) * 1e3)
    # The drain is in the measured window: in-flight rounds may not hide
    # their cost outside the sustained-throughput accounting.
    cs.drain()
    wall_s = time.perf_counter() - t_all
    windows = sum(fd.num_windows for fd in served)
    aggregate = N_ROUNDS * LEVEL * n_sensors / wall_s
    exchange = cs.exchange.stats
    for gid in gids:
        cs.detach(gid)
    del cs
    gc.collect()
    return times, aggregate, windows, exchange


def _point(n_sensors: int, n_shards: int) -> dict:
    times, aggregate, windows, exchange = _replay(n_sensors, n_shards)
    arr = np.asarray(times)
    return {
        "sensors": n_sensors,
        "shards": n_shards,
        "offered_events_s": round(n_sensors * LEVEL / (CHUNK_US / 1e6), 1),
        "aggregate_events_s": round(aggregate, 1),
        "p50_ms": round(float(np.percentile(arr, 50)), 3),
        "p99_ms": round(float(np.percentile(arr, 99)), 3),
        "windows": windows,
        "exchange_wire_bytes": exchange["wire_bytes"],
        "exchange_ratio": round(exchange["compression_ratio"], 3),
    }


def _run_chaos() -> dict:
    from repro.serve.chaos_shards import ShardChaosConfig, ShardChaosHarness

    rep = ShardChaosHarness(ShardChaosConfig(seed=7)).run()
    return {
        "bit_identical": rep.bit_identical,
        "lost_sessions": rep.lost_sessions,
        "escaped_errors": len(rep.escaped_errors),
        "rescues": rep.rescues,
        "migrations": rep.migrations,
        "fired": rep.fired,
    }


def main() -> None:
    host_cores = os.cpu_count() or 1
    n_devices = len(jax.devices())
    gate_max_sensors = int(
        os.environ.get("GATE_MAX_SENSORS", str(32 * host_cores))
    )
    multi = n_devices >= SHARDS and host_cores >= 2 * SHARDS
    shard_target = (
        SHARD_TARGET_MULTIDEVICE if multi else SHARD_FLOOR_SHARED_DEVICE
    )
    shard_target = float(os.environ.get("BENCH_GATE_SHARDS", shard_target))
    print(
        f"backend={jax.default_backend()}  devices={n_devices}  "
        f"host_cores={host_cores}  shards={SHARDS}  sensors={SENSORS}  "
        f"level={LEVEL} ev/sensor/round  rounds={N_ROUNDS}"
    )

    gc.collect()
    points = [_point(n, SHARDS) for n in SENSORS]
    single = _point(RATIO_SENSORS, 1)
    paired = next(p for p in points if p["sensors"] == RATIO_SENSORS)
    shard_ratio = paired["aggregate_events_s"] / single["aggregate_events_s"]

    print(f"\n{'sensors':>8} {'offered/s':>12} {'aggregate/s':>12} "
          f"{'p50 ms':>8} {'p99 ms':>8} {'xchg':>6}")
    for p in points:
        print(
            f"{p['sensors']:>8} {p['offered_events_s']:>12,.0f} "
            f"{p['aggregate_events_s']:>12,.0f} {p['p50_ms']:>8.2f} "
            f"{p['p99_ms']:>8.2f} {p['exchange_ratio']:>6.2f}"
        )
    print(
        f"1-shard @ {RATIO_SENSORS}: {single['aggregate_events_s']:,.0f} ev/s"
        f"  ->  {SHARDS}-shard ratio {shard_ratio:.2f}x"
    )

    # Gate 1: monotone aggregate throughput from 8 up through
    # MONOTONE_MIN_SENSORS — bounded, like the p99 gate, to the points
    # that fit the host. On a 1-core CPU host the 128-sensor point is
    # oversubscribed by construction and its aggregate legitimately
    # dips; the reference multi-core host gates the full 8 -> 128 curve.
    monotone_bound = min(MONOTONE_MIN_SENSORS, gate_max_sensors)
    curve = [p for p in points if p["sensors"] <= monotone_bound]
    steps = [
        b["aggregate_events_s"] / a["aggregate_events_s"]
        for a, b in zip(curve, curve[1:])
    ]
    monotone_min = min(steps) if steps else 1.0
    gate_monotone = monotone_min >= 1.0

    # Gate 2: p99 within the paper budget at every point that fits.
    gated_points = [p for p in points if p["sensors"] <= gate_max_sensors]
    worst_p99 = max((p["p99_ms"] for p in gated_points), default=0.0)
    gate_p99 = worst_p99 <= BUDGET_MS

    # Gate 3: sharding speedup at equal sensor count.
    gate_shards = shard_ratio >= shard_target

    # Gate 4: multi-shard chaos (whole-shard stall included).
    chaos = None
    gate_chaos = True
    if os.environ.get("CHAOS", "1") != "0":
        chaos = _run_chaos()
        gate_chaos = (
            chaos["bit_identical"]
            and chaos["lost_sessions"] == 0
            and chaos["escaped_errors"] == 0
            and chaos["rescues"] >= 1
        )

    print(
        f"\nmonotone 8->{monotone_bound} (target {MONOTONE_MIN_SENSORS}, "
        f"host-bounded): min step ratio {monotone_min:.3f} >= 1.0 "
        f"({'PASS' if gate_monotone else 'FAIL'})"
    )
    print(
        f"p99 <= {BUDGET_MS} ms at sensors <= {gate_max_sensors}: worst "
        f"{worst_p99:.2f} ms ({'PASS' if gate_p99 else 'FAIL'})"
    )
    print(
        f"{SHARDS}-shard vs 1-shard @ {RATIO_SENSORS}: {shard_ratio:.2f}x >= "
        f"{shard_target}x ({'PASS' if gate_shards else 'FAIL'}; "
        f"multi-device target {SHARD_TARGET_MULTIDEVICE}x, "
        f"{n_devices} device(s) / {host_cores} core(s) here)"
    )
    if chaos is not None:
        print(
            f"shard chaos: bit_identical={chaos['bit_identical']} "
            f"lost={chaos['lost_sessions']} rescues={chaos['rescues']} "
            f"({'PASS' if gate_chaos else 'FAIL'})"
        )

    ref = gated_points[-1] if gated_points else points[0]
    payload = {
        "backend": jax.default_backend(),
        "commit": git_commit(),
        "host_cores": host_cores,
        "n_devices": n_devices,
        "shards": SHARDS,
        "level_events_per_sensor": LEVEL,
        "n_rounds": N_ROUNDS,
        "chunk_us": CHUNK_US,
        "exchange": EXCHANGE,
        "points": points,
        "single_shard": single,
        "shard_ratio": round(shard_ratio, 3),
        "shard_target_applied": shard_target,
        "shard_target_multidevice": SHARD_TARGET_MULTIDEVICE,
        "gate_max_sensors": gate_max_sensors,
        "monotone_bound_applied": monotone_bound,
        "chaos": chaos,
        "bench": {
            "name": "constellation_scaling",
            "p50_ms": ref["p50_ms"],
            "p99_ms": ref["p99_ms"],
            "gates": [
                {
                    "name": "aggregate_monotone_to_128",
                    "value": round(monotone_min, 3),
                    "threshold": 1.0,
                    "op": ">=",
                    "pass": gate_monotone,
                },
                {
                    "name": "p99_within_budget_fitting_points",
                    "value": round(worst_p99, 3),
                    "threshold": BUDGET_MS,
                    "op": "<=",
                    "pass": gate_p99,
                },
                {
                    "name": "shard_speedup_equal_sensors",
                    "value": round(shard_ratio, 3),
                    "threshold": shard_target,
                    "op": ">=",
                    "pass": gate_shards,
                },
                {
                    "name": "shard_chaos_bit_identical",
                    "value": 1.0 if gate_chaos else 0.0,
                    "threshold": 1.0,
                    "op": ">=",
                    "pass": gate_chaos,
                },
            ],
        },
    }
    out_path = REPO_ROOT / "BENCH_constellation.json"
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out_path}")

    if os.environ.get("BENCH_NO_FAIL"):
        return
    if not (gate_monotone and gate_p99 and gate_shards and gate_chaos):
        sys.exit(1)


if __name__ == "__main__":
    main()
