"""Paper Table I: clustering-algorithm comparison (grid vs K-Means vs
DBSCAN) — measured throughput + complexity scaling on identical batches."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks._common import time_fn
from repro.core.baselines import dbscan, kmeans
from repro.core.events import batch_from_arrays
from repro.core.grid_clustering import GridConfig, grid_cluster


def _batch(n: int, seed: int = 0, capacity: int | None = None):
    rng = np.random.default_rng(seed)
    return batch_from_arrays(
        rng.integers(0, 640, n), rng.integers(0, 480, n),
        np.arange(n), rng.integers(0, 2, n),
        capacity or n,
    )


def bench() -> list[tuple[str, float, str]]:
    rows = []
    grid_fn = jax.jit(lambda b: grid_cluster(b, GridConfig()))
    for n in (64, 128, 256, 512, 1024):
        b = _batch(n)
        us_grid = time_fn(grid_fn, b)
        rows.append(
            (f"table1/grid_n{n}", us_grid, f"{n / us_grid:.2f}Mev_s")
        )
    for n in (64, 128, 256, 512):
        b = _batch(n)
        us_km = time_fn(lambda bb: kmeans(bb, k=8, iters=16), b)
        rows.append((f"table1/kmeans_n{n}", us_km, f"{n / us_km:.2f}Mev_s"))
        us_db = time_fn(lambda bb: dbscan(bb, eps=8.0, min_pts=5), b)
        rows.append((f"table1/dbscan_n{n}", us_db, f"{n / us_db:.2f}Mev_s"))
    # complexity scaling exponents (log-log slope between n=128 and n=512)
    def slope(prefix):
        t = {int(r[0].split("_n")[1]): r[1] for r in rows if r[0].startswith(prefix)}
        return np.log(t[512] / t[128]) / np.log(4)

    rows.append(("table1/slope_grid", 0.0, f"O(n^{slope('table1/grid'):.2f})"))
    rows.append(("table1/slope_kmeans", 0.0, f"O(n^{slope('table1/kmeans'):.2f})"))
    rows.append(("table1/slope_dbscan", 0.0, f"O(n^{slope('table1/dbscan'):.2f})"))
    return rows
