"""Paper Figs. 5-8: entropy statistics of detected clusters.

Fig 5: Shannon entropy separation RSO vs star clusters.
Fig 6: events-per-cluster distribution around the min_events=5 box.
Fig 7: metric correlation matrix (entropy ~ count ~ contrast).
Fig 8: temporal entropy stability of a tracked RSO vs noise.
"""
from __future__ import annotations

import numpy as np

from repro.core import metrics as M
from repro.core.pipeline import PipelineConfig, run_recording
from repro.data.synthetic import make_recording


def bench() -> list[tuple[str, float, str]]:
    rec = make_recording(seed=4, duration_s=1.5, n_rsos=2)
    cfg = PipelineConfig()
    results = run_recording(rec, cfg, with_tracking=True)

    rso_h, star_h, counts, mats = [], [], [], []
    for res in results:
        valid = np.asarray(res.clusters.valid)
        if not valid.any():
            continue
        cx = np.asarray(res.clusters.centroid_x)
        cy = np.asarray(res.clusters.centroid_y)
        ct = np.asarray(res.clusters.centroid_t)
        h = res.metrics["shannon_entropy"]
        counts.extend(np.asarray(res.clusters.count)[valid].tolist())
        mats.append(M.metric_matrix(
            {k: np.asarray(v) for k, v in res.metrics.items()}
        )[valid])
        for k in np.flatnonzero(valid):
            t_ev = res.t_start_us + float(ct[k])
            is_rso = False
            for r in range(rec.rso_tracks.shape[0]):
                px, py = rec.rso_position(r, np.array([t_ev]))
                if np.hypot(px[0] - cx[k], py[0] - cy[k]) <= 14:
                    is_rso = True
            (rso_h if is_rso else star_h).append(float(h[k]))

    rows = []
    rows.append(("fig5/rso_entropy", 0.0,
                 f"mean{np.mean(rso_h):.3f}_std{np.std(rso_h):.3f}_n{len(rso_h)}"))
    rows.append(("fig5/star_entropy", 0.0,
                 f"mean{np.mean(star_h):.3f}_std{np.std(star_h):.3f}_n{len(star_h)}"))
    rows.append(("fig5/separation", 0.0,
                 f"rso_gt_star_{np.mean(rso_h) > np.mean(star_h)}"))

    counts = np.asarray(counts)
    rows.append(("fig6/events_per_cluster", 0.0,
                 f"median{np.median(counts):.0f}_p90_{np.percentile(counts, 90):.0f}"
                 f"_in5to20_{np.mean((counts >= 5) & (counts <= 20)):.2f}"))

    mat = np.concatenate(mats)
    corr = np.asarray(M.correlation_matrix(mat))
    names = M.METRIC_NAMES
    i_h, i_cnt, i_con = names.index("shannon_entropy"), names.index("event_count"), names.index("local_contrast")
    rows.append(("fig7/corr_entropy_count", 0.0, f"{corr[i_h, i_cnt]:.2f}"))
    rows.append(("fig7/corr_entropy_contrast", 0.0, f"{corr[i_h, i_con]:.2f}"))

    # Fig 8: entropy EMA stability of confirmed tracks across 50 windows.
    ent_series = [
        np.asarray(r.tracks.entropy)[np.asarray(r.tracks.active)]
        for r in results[-50:] if r.tracks is not None
    ]
    flat = [e.mean() for e in ent_series if len(e)]
    rows.append(("fig8/track_entropy_stability", 0.0,
                 f"std{np.std(flat):.4f}_over{len(flat)}windows"))
    return rows
