"""Detection-service feed latency under session churn.

The service layer's pitch is dynamic membership at serving speed:
sensors attach, stream, and detach against ONE slot-pooled fleet step,
with micro-batched admission — so the paper's 62 ms deterministic-latency
budget has to hold *while the session set is changing*, not just for a
frozen fleet. This benchmark replays a churning ground-station scenario:

* a scenario-diverse session pool (rate-balanced families, per-sensor
  pointing jitter) feeding 20 ms live-cadence chunks via
  ``iter_chunks`` — the same wire shape a live EBC client sends;
* churn: the pool starts at CHURN_START sessions, grows one session
  every ATTACH_EVERY rounds up to N_SESSIONS (crossing a capacity-tier
  promotion on the way), and from then on cycles detach-oldest +
  attach-replacement every CHURN_EVERY rounds — so slot zeroing,
  recycling, and carry migration all sit on the measured path;
* per-round latency = wall time of (every live session's ``feed`` +
  one forced ``pump`` + blocking on the round's results): the full
  service cost of a fleet-wide feed round, which is also each session's
  per-feed service latency since every queued chunk is served in that
  round's single step.

Methodology matches the fleet bench: one cold pass warms every compiled
shape (at most one fleet-step compile per capacity tier — reported from
the step-trace hook), then N_PASSES steady-state passes with GC off,
combined by per-round minimum (the least-noise estimator documented in
benchmarks/fleet_throughput.py).

Gates (exit code 1 on failure, BENCH_NO_FAIL=1 to disable):

* steady-state per-feed p99 <= BUDGET_MS (62 ms paper budget), churn on.

Results land in BENCH_serve.json at the repo root with the uniform
``bench`` block the ``benchmarks.run`` aggregator consumes.

  PYTHONPATH=src python benchmarks/serve_latency.py
  N_SESSIONS=8 DURATION_S=2 CHUNK_US=20000 BUDGET_MS=62 ...  (CI knobs)
"""
import dataclasses
import gc
import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np
from _common import git_commit

from repro.core.pipeline import FleetPipeline, PipelineConfig
from repro.core.pipeline import fleet as fleet_mod
from repro.data.evas import iter_chunks
from repro.data.synthetic import SCENARIO_FAMILIES, make_fleet_recordings
from repro.serve import AdmissionConfig, DetectionService

N_SESSIONS = int(os.environ.get("N_SESSIONS", "8"))
DURATION_S = float(os.environ.get("DURATION_S", "2.0"))
CHUNK_US = int(os.environ.get("CHUNK_US", "20000"))
BUDGET_MS = float(os.environ.get("BUDGET_MS", "62"))
N_PASSES = int(os.environ.get("N_PASSES", "5"))
# Default rounds stay under DURATION_S / CHUNK_US so no session exhausts
# its replay mid-schedule (exhausted sessions idle until churned out).
N_ROUNDS = int(os.environ.get("N_ROUNDS", "96"))
REPO_ROOT = Path(__file__).resolve().parent.parent

TIERS = (4, 8, 16, 32)
CHURN_START = min(4, N_SESSIONS)
ATTACH_EVERY = 8  # rounds between ramp-up attaches
CHURN_EVERY = 12  # rounds between detach+replace cycles at full strength

BALANCED_FAMILIES = ("crossing", "geo_slow", "tumbling", "ballistic", "jitter")


def _recording(idx: int):
    fam = BALANCED_FAMILIES[idx % len(BALANCED_FAMILIES)]
    rec = make_fleet_recordings(
        1, scenario=SCENARIO_FAMILIES[fam],
        seed0=101 * idx, duration_s=DURATION_S,
    )[0]
    return dataclasses.replace(rec, name=f"station{idx}-{fam}")


def _replay(recordings):
    """One full churn schedule; returns (per-round ms, stats dict)."""
    # The paper's 250-event size cut is per sensor; fleet-wide admission
    # weight scales with the session count, otherwise the size threshold
    # fires several times inside every 20 ms round and the micro-batch
    # degenerates to per-sensor steps.
    svc = DetectionService(
        PipelineConfig(), tiers=TIERS,
        admission=AdmissionConfig(
            max_delay_s=CHUNK_US / 1e6, max_items=250 * N_SESSIONS
        ),
    )
    next_rec = iter(recordings)
    live: dict[int, object] = {}  # sid -> chunk iterator
    order: list[int] = []  # attach order (detach the oldest)
    events = windows = dets = attaches = detaches = 0

    def attach():
        nonlocal attaches
        rec = next(next_rec)
        sid = svc.attach(rec.name)
        live[sid] = iter_chunks(rec, CHUNK_US)
        order.append(sid)
        attaches += 1

    def consume(served):
        nonlocal windows, dets
        for fd in served:
            windows += fd.result.num_windows
            if fd.result.num_windows:
                dets += int(np.asarray(fd.result.clusters.valid).sum())

    for _ in range(CHURN_START):
        attach()
    times = []
    for rnd in range(N_ROUNDS):
        # Churn runs INSIDE the timed window: the detach flush step, slot
        # zeroing, and tier promotion are service work the latency gate
        # must cover, not background it.
        t0 = time.perf_counter()
        if len(live) < N_SESSIONS and rnd % ATTACH_EVERY == ATTACH_EVERY - 1:
            attach()
        elif len(live) == N_SESSIONS and rnd % CHURN_EVERY == CHURN_EVERY - 1:
            oldest = order.pop(0)
            del live[oldest]
            windows += svc.detach(oldest).num_windows
            detaches += 1
            attach()
        results = []
        for sid, chunks in live.items():
            chunk = next(chunks, None)
            if chunk is None:
                continue  # stream exhausted: idles until churned out
            events += len(chunk[2])
            results.extend(svc.feed(sid, *chunk))
        results.extend(svc.pump(force=True))
        jax.block_until_ready([fd.result.metrics for fd in results])
        times.append((time.perf_counter() - t0) * 1e3)
        consume(results)
    for sid in list(live):
        windows += svc.detach(sid).num_windows
    return times, {
        "events": events, "windows": windows, "detections": dets,
        "attaches": attaches, "detaches": detaches + len(order),
        "promotions": svc.promotions,
    }


def _host_view_bench(slots: int = 32, hot: int = 2, iters: int = 30):
    """Micro-bench the sparse host copy-back (FleetResult._host_view).

    A churny service pool is mostly idle slots: with ``hot`` of ``slots``
    sensors closing windows, the hot-row gather path moves only the
    valid-window rows to host instead of the full (S, W, ...) stacked
    leaves. Each iteration feeds one live-cadence round, waits for the
    device step (so only the copy-back is on the clock), then times the
    full stacked copy vs the gather path on the same round's buffers.
    Returns per-variant median ms.
    """
    fp = FleetPipeline(PipelineConfig(), n_sensors=slots,
                       uniform_fast_path=False)
    rng = np.random.default_rng(11)
    n = 250
    pos = 0
    full_ms, gather_ms = [], []
    for it in range(iters + 1):
        chunks = [None] * slots
        for s in range(hot):
            t = (np.arange(n, dtype=np.int64) + 1 + pos) * 80
            chunks[s] = (
                rng.integers(40, 560, n).astype(np.int64),
                rng.integers(40, 400, n).astype(np.int64),
                t,
                rng.integers(0, 2, n).astype(np.int64),
            )
        pos += n
        res = fp.feed_async(chunks).wait()
        stacked = (res.clusters, res.metrics, res.tracks, res.final_tracks)
        t0 = time.perf_counter()
        jax.tree.map(np.asarray, stacked)
        t1 = time.perf_counter()
        res._host_view()
        t2 = time.perf_counter()
        if it:  # first iteration carries the compile/warmup
            full_ms.append((t1 - t0) * 1e3)
            gather_ms.append((t2 - t1) * 1e3)
        assert res._hot_rows is not None  # the gather path was exercised
    return {
        "slots": slots,
        "hot_slots": hot,
        "full_copy_ms": round(float(np.median(full_ms)), 4),
        "gather_ms": round(float(np.median(gather_ms)), 4),
        "speedup": round(float(np.median(full_ms) / np.median(gather_ms)), 2),
    }


def main() -> None:
    # Enough distinct recordings for the whole churn schedule, per pass.
    n_recs = CHURN_START + N_SESSIONS + N_ROUNDS // CHURN_EVERY + 2
    recordings = [_recording(i) for i in range(n_recs)]
    print(
        f"backend={jax.default_backend()}  sessions<= {N_SESSIONS}  "
        f"tiers={TIERS[:2]}...  rounds={N_ROUNDS} x {CHUNK_US / 1e3:.0f} ms  "
        f"budget={BUDGET_MS} ms"
    )

    # Cold pass: compiles every step shape (at most one per capacity tier).
    fleet_mod.STEP_TRACES.clear()
    t0 = time.perf_counter()
    _, stats = _replay(recordings)
    cold_s = time.perf_counter() - t0
    compiles = sorted({(s, w) for (s, w, _, _) in fleet_mod.STEP_TRACES})
    tiers_hit = sorted({s for s, _ in compiles})

    gc.collect()
    gc.disable()
    try:
        passes = [_replay(recordings)[0] for _ in range(N_PASSES)]
    finally:
        gc.enable()
    arr = np.minimum.reduce([np.asarray(p) for p in passes])
    p50, p95, p99 = (float(np.percentile(arr, q)) for q in (50, 95, 99))
    peak = float(arr.max())

    print(
        f"churn per pass: {stats['attaches']} attaches, "
        f"{stats['detaches']} detaches, {stats['promotions']} tier "
        f"promotions; {stats['events']:,} events, {stats['windows']} windows"
    )
    print(f"cold pass (incl. compiles): {cold_s:.2f} s")
    print(
        f"fleet-step compiles: {len(compiles)} shapes {compiles} over "
        f"capacity tiers {tiers_hit} (compile budget: <= 1 per tier per "
        f"window count)"
    )
    print(
        f"steady-state per-feed service latency (churn on): "
        f"p50={p50:.2f} ms  p95={p95:.2f} ms  p99={p99:.2f} ms  "
        f"max={peak:.2f} ms"
    )
    gate_p99 = p99 <= BUDGET_MS
    print(
        f"p99 vs paper budget: {p99:.2f} ms <= {BUDGET_MS} ms "
        f"({'PASS' if gate_p99 else 'FAIL'})"
    )

    hv = _host_view_bench()
    print(
        f"host copy-back, {hv['hot_slots']}/{hv['slots']} slots hot: "
        f"full {hv['full_copy_ms']:.3f} ms vs hot-row gather "
        f"{hv['gather_ms']:.3f} ms ({hv['speedup']:.2f}x)"
    )

    payload = {
        "backend": jax.default_backend(),
        "commit": git_commit(),
        "n_sessions": N_SESSIONS,
        "tiers": list(TIERS),
        "duration_s": DURATION_S,
        "chunk_us": CHUNK_US,
        "n_rounds": N_ROUNDS,
        "budget_ms": BUDGET_MS,
        "cold_pass_s": round(cold_s, 3),
        "churn": {
            "attaches": stats["attaches"],
            "detaches": stats["detaches"],
            "tier_promotions": stats["promotions"],
        },
        "fleet_step_compiles": [list(c) for c in compiles],
        "n_events_per_pass": stats["events"],
        "n_windows_per_pass": stats["windows"],
        "latency_ms": {
            "p50": round(p50, 3),
            "p95": round(p95, 3),
            "p99": round(p99, 3),
            "max": round(peak, 3),
        },
        "n_passes": N_PASSES,
        "host_view_sparse": hv,
        "bench": {
            "name": "serve_latency",
            "p50_ms": round(p50, 3),
            "p99_ms": round(p99, 3),
            "gates": [
                {
                    "name": "feed_p99_within_budget_with_churn",
                    "value": round(p99, 3),
                    "threshold": BUDGET_MS,
                    "op": "<=",
                    "pass": gate_p99,
                },
            ],
        },
    }
    out_path = REPO_ROOT / "BENCH_serve.json"
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out_path}")

    if os.environ.get("BENCH_NO_FAIL"):
        return
    if not gate_p99:
        sys.exit(1)


if __name__ == "__main__":
    main()
