"""Paper Table V: multi-EBC scaling (1/2/4/8 nodes) through the real
:class:`FleetPipeline` — the full ingest path (host windowing, packed
transfer, vmapped cluster+track step), not a bare ``grid_cluster`` jit.

One mesh-axis shard per camera node via the pipeline's ``mesh=``
support; each node carries ``PER_NODE`` sensors (weak scaling, the
paper's deployment shape: more ground stations, same per-station load).
Runs in subprocesses so each node count gets its own
``--xla_force_host_platform_device_count``.
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"

_SNIPPET = """
import time
import numpy as np
from repro.core.pipeline import FleetPipeline, PipelineConfig
from repro.launch.mesh import make_mesh

nodes, per_node, chunk, rounds = {nodes}, 4, 250, 6
s = nodes * per_node
mesh = make_mesh((nodes,), ("sensor",)) if nodes > 1 else None
fp = FleetPipeline(PipelineConfig(), n_sensors=s, mesh=mesh)

def stream(seed, n):
    rng = np.random.default_rng(seed)
    return (
        rng.integers(40, 560, n).astype(np.int64),
        rng.integers(40, 400, n).astype(np.int64),
        (np.arange(n, dtype=np.int64) + 1) * 80,
        rng.integers(0, 2, n).astype(np.int64),
    )

streams = [stream(i, chunk * (rounds + 1)) for i in range(s)]
def feed_round(r):
    return fp.feed([
        tuple(a[r * chunk:(r + 1) * chunk] for a in st) for st in streams
    ])

feed_round(0).block_until_ready()  # compile + warm the (S, W) step shape
times, windows = [], 0
for r in range(1, rounds + 1):
    t0 = time.perf_counter()
    out = feed_round(r).block_until_ready()
    times.append(time.perf_counter() - t0)
    windows += out.total_windows
dt = sorted(times)[len(times) // 2]
print(f"RESULT,{{s * chunk / dt / 1e6:.3f}},{{dt / max(windows / rounds, 1) * 1e3:.3f}}")
"""


def bench(
    node_counts: tuple[int, ...] = (1, 2, 4, 8)
) -> list[tuple[str, float, str]]:
    """One row per node count; smoke callers pass ``node_counts=(1,)``."""
    rows = []
    base = None
    for nodes in node_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={nodes}"
        env["PYTHONPATH"] = str(SRC)
        out = subprocess.run(
            [sys.executable, "-c", _SNIPPET.format(nodes=nodes)],
            capture_output=True, text=True, timeout=600, env=env,
        )
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT,")]
        if not line:
            rows.append((f"table5/nodes{nodes}", 0.0, "FAILED"))
            continue
        mev_s, ms_per_window = line[0].split(",")[1:]
        if base is None:
            base = float(mev_s)
        # All N virtual nodes share ONE physical core here, so the paper's
        # linear-scaling claim shows up as CONSTANT aggregate throughput
        # (contention-free weak scaling): efficiency = agg / (1x agg).
        rows.append(
            (f"table5/nodes{nodes}", float(ms_per_window) * 1e3,
             f"{mev_s}MEv_s_aggregate_1core_efficiency{float(mev_s) / base:.2f}")
        )
    return rows
