"""Paper Table V: multi-EBC scaling (1/2/4/8 nodes). One mesh-axis shard
per camera node via shard_map; reports aggregate throughput and per-node
latency invariance. Runs in subprocesses so each config gets its own
device count."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

SRC = Path(__file__).resolve().parent.parent / "src"

_SNIPPET = """
import time
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.events import EventBatch
from repro.core.grid_clustering import GridConfig, grid_cluster
from repro.launch.mesh import make_mesh, shard_map

nodes, windows, cap = {nodes}, 32, 256
mesh = make_mesh((nodes,), ("node",))
rng = np.random.default_rng(0)
leaves = [
    rng.integers(0, 640, (nodes, windows, cap)).astype(np.int32),
    rng.integers(0, 480, (nodes, windows, cap)).astype(np.int32),
    np.zeros((nodes, windows, cap), np.int32),
    np.zeros((nodes, windows, cap), np.int32),
    np.ones((nodes, windows, cap), bool),
]
batch = EventBatch(*[jax.device_put(jnp.asarray(a), NamedSharding(mesh, P("node"))) for a in leaves])
grid = GridConfig()

def node_fn(b):
    b = jax.tree.map(lambda a: a[0], b)
    return jax.vmap(lambda eb: grid_cluster(eb, grid).count)(b)[None]

fn = jax.jit(shard_map(node_fn, mesh=mesh,
    in_specs=(jax.tree.map(lambda _: P("node"), batch),), out_specs=P("node")))
fn(batch).block_until_ready()
times = []
for _ in range(5):
    t0 = time.perf_counter()
    fn(batch).block_until_ready()
    times.append(time.perf_counter() - t0)
dt = sorted(times)[2]
ev = nodes * windows * cap
print(f"RESULT,{{ev / dt / 1e6:.3f}},{{dt / windows * 1e3:.3f}}")
"""


def bench(
    node_counts: tuple[int, ...] = (1, 2, 4, 8)
) -> list[tuple[str, float, str]]:
    """One row per node count; smoke callers pass ``node_counts=(1,)``."""
    rows = []
    base = None
    for nodes in node_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={nodes}"
        env["PYTHONPATH"] = str(SRC)
        out = subprocess.run(
            [sys.executable, "-c", _SNIPPET.format(nodes=nodes)],
            capture_output=True, text=True, timeout=600, env=env,
        )
        line = [l for l in out.stdout.splitlines() if l.startswith("RESULT,")]
        if not line:
            rows.append((f"table5/nodes{nodes}", 0.0, "FAILED"))
            continue
        mev_s, ms_per_window = line[0].split(",")[1:]
        if base is None:
            base = float(mev_s)
        # All N virtual nodes share ONE physical core here, so the paper's
        # linear-scaling claim shows up as CONSTANT aggregate throughput
        # (contention-free weak scaling): efficiency = agg / (1x agg).
        rows.append(
            (f"table5/nodes{nodes}", float(ms_per_window) * 1e3,
             f"{mev_s}MEv_s_aggregate_1core_efficiency{float(mev_s) / base:.2f}")
        )
    return rows
