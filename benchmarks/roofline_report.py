"""Aggregate dry-run JSON artifacts into the EXPERIMENTS.md roofline
table, and report HLO bytes/flops for the per-window stage chain
(float vs fixed vs fused megakernel). Also exposes the baseline rows as
benchmark CSV.

The window report is the "before/after" evidence for the megakernel PR:
``launch.hlo_analysis.analyze`` over the jit-compiled staged float and
staged fixed window-batch steps (real post-optimization HLO counts), plus
an analytic cost model for the fused Pallas kernel — interpret-mode
Pallas shows up in HLO as an opaque custom call, so its bytes/flops are
derived from the kernel's block shapes instead (one (W, E) pass, VMEM-
resident intermediates, one (CL_ROWS + K, LANE) output block per
window). ``benchmarks/scan_throughput.py`` embeds these numbers next to
the measured megakernel speedup gate in ``BENCH_scan.json``.
"""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "dryrun_results"


def load_records(mesh: str | None = None, variant: str | None = "") -> list[dict]:
    """variant="" -> baselines only; None -> everything."""
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        if variant is not None and r.get("variant", "") != variant:
            continue
        recs.append(r)
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def markdown_table(mesh: str = "single", variant: str | None = "") -> str:
    rows = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| useful/HLO | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(mesh, variant):
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED: {r.get('error','')[:40]} |")
            continue
        t = r["roofline"]
        peak = r.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute_s'])} "
            f"| {fmt_s(t['t_memory_s'])} | {fmt_s(t['t_collective_s'])} "
            f"| {t['bottleneck']} | {ratio:.2f} | {peak:.1f} |"
            if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute_s'])} "
            f"| {fmt_s(t['t_memory_s'])} | {fmt_s(t['t_collective_s'])} "
            f"| {t['bottleneck']} | - | {peak:.1f} |"
        )
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# Per-window stage-chain report: float vs fixed vs fused megakernel.
# ---------------------------------------------------------------------------

def _compile_window_step(config, n_windows: int, capacity: int):
    """Jit-compile the (un-tracked) window-batch step for HLO analysis."""
    import jax
    import jax.numpy as jnp

    from repro.core.events import EventBatch
    from repro.core.pipeline.scan import _fresh_carry_core
    from repro.core.tracking import init_tracks

    core = _fresh_carry_core(config, with_tracking=False)
    stacked = EventBatch(
        x=jnp.zeros((n_windows, capacity), jnp.int32),
        y=jnp.zeros((n_windows, capacity), jnp.int32),
        t=jnp.zeros((n_windows, capacity), jnp.int32),
        p=jnp.zeros((n_windows, capacity), jnp.int32),
        valid=jnp.zeros((n_windows, capacity), bool),
    )
    return jax.jit(core).lower(stacked, init_tracks(config.tracker)).compile()


def _megakernel_cost_model(
    config, n_windows: int, capacity: int
) -> dict[str, float]:
    """Analytic bytes/flops for the fused Pallas window kernel.

    Interpret-mode Pallas appears in HLO as an opaque custom call, so the
    fused step's roofline terms come from its block shapes instead: per
    grid step (= per window), the pairwise (E, E) conditioning block,
    the (E, C) cell one-hot matmul, K per-cluster (E, patch^2) +
    (E, bins) matmuls and the Sobel stencil. HBM traffic is just the
    event arrays in and the two packed output blocks out — every
    intermediate lives in VMEM, which is the point of fusing.
    """
    from repro.core import metrics as M
    from repro.kernels import window_pipeline as wp

    e = capacity
    grid = config.grid
    k = grid.max_clusters
    c_pad = -(-grid.n_cells // wp.LANE) * wp.LANE
    npix = M.WINDOW * M.WINDOW
    bins = M.HIST_BINS
    per_window_flops = (
        5 * e * e  # same-pixel compares, hot counts, coincidence, leaders
        + 2 * 4 * e * c_pad  # 4-stat cell one-hot matmul
        + k * 4 * c_pad  # top-K (max, first-index, mask) passes
        + k * (3 * e * npix + 2 * e * bins + 20 * npix)  # per-cluster stage
    )
    hbm_bytes = n_windows * (
        4 * e * 4  # x, y, t, valid int32 in
        + (wp.CL_ROWS + k) * wp.LANE * 4  # cluster + surface blocks out
    )
    return {
        "flops": float(n_windows * per_window_flops),
        "bytes": float(hbm_bytes),
        "launches": 1.0,
    }


def window_report(n_windows: int = 8, capacity: int = 256) -> dict:
    """Bytes/flops for the per-window stage chain, before/after fusing.

    Rows: the staged float path and the staged fixed path (both measured
    from jit-compiled post-optimization HLO via ``launch.hlo_analysis`` —
    "traffic" there is inter-fusion operand+result bytes, the HLO proxy
    for HBM round-trips between launches), and the fused megakernel
    (analytic model, HBM-only by construction: intermediates never leave
    VMEM — see :func:`_megakernel_cost_model`). All figures cover one
    ``n_windows``-window batch step at the given capacity.
    """
    from repro.core.pipeline.config import PipelineConfig
    from repro.launch.roofline import extract_terms

    report: dict = {"n_windows": n_windows, "capacity": capacity, "rows": {}}
    for name, config in (
        ("float_staged", PipelineConfig()),
        ("fixed_staged", PipelineConfig(numerics="fixed")),
    ):
        terms = extract_terms(
            _compile_window_step(config, n_windows, capacity), n_devices=1
        )
        report["rows"][name] = {
            "flops": terms.flops,
            "bytes": terms.hbm_bytes,
            "launches": float(n_windows),  # one logical step per window
        }
    report["rows"]["megakernel_model"] = _megakernel_cost_model(
        PipelineConfig(numerics="fixed", metrics_impl="megakernel"),
        n_windows, capacity,
    )
    fl = report["rows"]["float_staged"]
    fx = report["rows"]["fixed_staged"]
    mk = report["rows"]["megakernel_model"]
    report["fixed_over_float_bytes"] = fx["bytes"] / max(fl["bytes"], 1.0)
    report["fixed_over_float_flops"] = fx["flops"] / max(fl["flops"], 1.0)
    report["mega_over_fixed_bytes"] = mk["bytes"] / max(fx["bytes"], 1.0)
    return report


def window_markdown_table(report: dict | None = None) -> str:
    report = window_report() if report is None else report
    rows = [
        f"Per-window stage chain, W={report['n_windows']} x "
        f"E={report['capacity']} batch step:",
        "",
        "| path | MFLOPs | traffic MB | launches |",
        "|---|---|---|---|",
    ]
    for name, r in report["rows"].items():
        rows.append(
            f"| {name} | {r['flops'] / 1e6:.2f} | {r['bytes'] / 1e6:.2f} "
            f"| {r['launches']:.0f} |"
        )
    rows.append("")
    rows.append(
        f"fixed/float bytes: {report['fixed_over_float_bytes']:.2f}x, "
        f"fixed/float flops: {report['fixed_over_float_flops']:.2f}x, "
        f"mega/fixed bytes: {report['mega_over_fixed_bytes']:.3f}x"
    )
    return "\n".join(rows)


def bench() -> list[tuple[str, float, str]]:
    rows = []
    wr = window_report(n_windows=4, capacity=256)
    for name, r in wr["rows"].items():
        rows.append(
            (f"roofline/window/{name}", 0.0,
             f"mflops{r['flops'] / 1e6:.2f}_mb{r['bytes'] / 1e6:.2f}")
        )
    rows.append(
        ("roofline/window/mega_over_fixed_bytes",
         wr["mega_over_fixed_bytes"], "hbm_traffic_ratio")
    )
    recs = load_records("single")
    if not recs:
        return rows + [("roofline/missing", 0.0, "run launch.dryrun first")]
    n_ok = sum(r["ok"] for r in recs)
    rows.append(("roofline/cells_single_pod", 0.0, f"{n_ok}of{len(recs)}_ok"))
    multi = load_records("multi")
    rows.append(
        ("roofline/cells_multi_pod", 0.0,
         f"{sum(r['ok'] for r in multi)}of{len(multi)}_ok")
    )
    for r in recs:
        if not r.get("ok"):
            continue
        t = r["roofline"]
        bound = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"])
        frac = t["t_compute_s"] / bound if bound else 0.0
        rows.append(
            (f"roofline/{r['arch']}/{r['shape']}", bound * 1e6,
             f"{t['bottleneck']}_computefrac{frac:.2f}")
        )
    return rows


if __name__ == "__main__":
    print(window_markdown_table())
    print()
    if load_records("single"):
        print(markdown_table())
    else:
        print("(no dryrun_results yet — run launch.dryrun for the mesh table)")
