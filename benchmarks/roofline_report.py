"""Aggregate dry-run JSON artifacts into the EXPERIMENTS.md roofline
table. Also exposes the baseline rows as benchmark CSV."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).resolve().parent / "dryrun_results"


def load_records(mesh: str | None = None, variant: str | None = "") -> list[dict]:
    """variant="" -> baselines only; None -> everything."""
    recs = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if mesh and r.get("mesh") != mesh:
            continue
        if variant is not None and r.get("variant", "") != variant:
            continue
        recs.append(r)
    return recs


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def markdown_table(mesh: str = "single", variant: str | None = "") -> str:
    rows = [
        "| arch | shape | t_compute | t_memory | t_collective | bottleneck "
        "| useful/HLO | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in load_records(mesh, variant):
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAILED: {r.get('error','')[:40]} |")
            continue
        t = r["roofline"]
        peak = r.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9
        ratio = r.get("useful_flops_ratio")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute_s'])} "
            f"| {fmt_s(t['t_memory_s'])} | {fmt_s(t['t_collective_s'])} "
            f"| {t['bottleneck']} | {ratio:.2f} | {peak:.1f} |"
            if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {fmt_s(t['t_compute_s'])} "
            f"| {fmt_s(t['t_memory_s'])} | {fmt_s(t['t_collective_s'])} "
            f"| {t['bottleneck']} | - | {peak:.1f} |"
        )
    return "\n".join(rows)


def bench() -> list[tuple[str, float, str]]:
    rows = []
    recs = load_records("single")
    if not recs:
        return [("roofline/missing", 0.0, "run launch.dryrun first")]
    n_ok = sum(r["ok"] for r in recs)
    rows.append(("roofline/cells_single_pod", 0.0, f"{n_ok}of{len(recs)}_ok"))
    multi = load_records("multi")
    rows.append(
        ("roofline/cells_multi_pod", 0.0,
         f"{sum(r['ok'] for r in multi)}of{len(multi)}_ok")
    )
    for r in recs:
        if not r.get("ok"):
            continue
        t = r["roofline"]
        bound = max(t["t_compute_s"], t["t_memory_s"], t["t_collective_s"])
        frac = t["t_compute_s"] / bound if bound else 0.0
        rows.append(
            (f"roofline/{r['arch']}/{r['shape']}", bound * 1e6,
             f"{t['bottleneck']}_computefrac{frac:.2f}")
        )
    return rows
