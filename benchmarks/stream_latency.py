"""Per-chunk streaming step latency vs the paper's 62 ms budget.

The paper's headline claim is deterministic processing latencies below
62 ms on a live event-camera feed. This benchmark replays a synthetic
recording through ``StreamingPipeline.feed`` in fixed event-time chunks
(default 20 ms — approximately one dual-threshold window per feed, the
live-sensor cadence) and measures the wall time of every feed call:
host windowing + one jit'd donated-carry step + device sync.

A first pass over the identical chunk sequence warms the jit cache (one
compile per distinct windows-per-feed count), so the timed pass measures
the steady state the latency claim is about; cold-start compile time is
reported separately. p50/p95/p99/max land in BENCH_stream.json at the
repo root, and the exit code enforces p99 <= budget (set BENCH_NO_FAIL=1
to disable).

  PYTHONPATH=src python benchmarks/stream_latency.py
  DURATION_S=2 CHUNK_US=20000 BUDGET_MS=62 ...   (CI smoke knobs)
"""
import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np
from _common import git_commit

from repro.core.events import stride_bounds
from repro.core.pipeline import PipelineConfig, StreamingPipeline
from repro.data.synthetic import make_recording

DURATION_S = float(os.environ.get("DURATION_S", "3.0"))
CHUNK_US = int(os.environ.get("CHUNK_US", "20000"))
BUDGET_MS = float(os.environ.get("BUDGET_MS", "62"))
REPO_ROOT = Path(__file__).resolve().parent.parent


def _chunks(rec):
    """Event-index boundaries of fixed CHUNK_US event-time slices.

    ``stride_bounds`` anchors at the first event and covers through the
    last one, including timestamps landing exactly on a slice edge.
    """
    return [(lo, hi) for lo, hi, _ in stride_bounds(rec.t, CHUNK_US)]


def _replay(rec, chunks, config) -> tuple[list[float], int]:
    """Feed every chunk once; per-feed wall times (ms) + windows closed."""
    sp = StreamingPipeline(config)
    times: list[float] = []
    windows = 0
    for lo, hi in chunks:
        t0 = time.perf_counter()
        res = sp.feed(rec.x[lo:hi], rec.y[lo:hi], rec.t[lo:hi], rec.p[lo:hi])
        jax.block_until_ready((res.clusters, res.metrics, res.tracks))
        times.append((time.perf_counter() - t0) * 1e3)
        windows += res.num_windows
    res = sp.flush()
    jax.block_until_ready((res.clusters, res.metrics, res.tracks))
    windows += res.num_windows
    return times, windows


def main() -> None:
    config = PipelineConfig()  # paper defaults: 16px cells, 20 ms / 250 ev
    rec = make_recording(seed=0, duration_s=DURATION_S, n_rsos=2)
    chunks = _chunks(rec)
    print(
        f"backend={jax.default_backend()}  events={len(rec):,}  "
        f"chunks={len(chunks)} x {CHUNK_US / 1e3:.0f} ms  budget={BUDGET_MS} ms"
    )

    # Cold pass: compiles one step per distinct windows-per-feed shape.
    t0 = time.perf_counter()
    cold_times, n_windows = _replay(rec, chunks, config)
    cold_s = time.perf_counter() - t0

    # Steady-state pass: identical chunk sequence, fully warm jit cache.
    times, _ = _replay(rec, chunks, config)
    arr = np.asarray(times)
    p50, p95, p99 = (float(np.percentile(arr, q)) for q in (50, 95, 99))
    peak = float(arr.max())

    print(f"windows processed: {n_windows}  feeds: {len(arr)}")
    print(f"cold pass (incl. compiles): {cold_s:.2f} s")
    print(
        f"steady-state per-feed latency: p50={p50:.2f} ms  p95={p95:.2f} ms  "
        f"p99={p99:.2f} ms  max={peak:.2f} ms"
    )
    ok = p99 <= BUDGET_MS
    print(
        f"p99 vs paper budget: {p99:.2f} ms <= {BUDGET_MS} ms "
        f"({'PASS' if ok else 'FAIL'})"
    )

    payload = {
        "backend": jax.default_backend(),
        "commit": git_commit(),
        "duration_s": DURATION_S,
        "chunk_us": CHUNK_US,
        "n_feeds": len(arr),
        "n_windows": n_windows,
        "budget_ms": BUDGET_MS,
        "cold_pass_s": round(cold_s, 3),
        "latency_ms": {
            "p50": round(p50, 3),
            "p95": round(p95, 3),
            "p99": round(p99, 3),
            "max": round(peak, 3),
        },
        "bench": {
            "name": "stream_latency",
            "p50_ms": round(p50, 3),
            "p99_ms": round(p99, 3),
            "gates": [
                {
                    "name": "feed_p99_within_budget",
                    "value": round(p99, 3),
                    "threshold": BUDGET_MS,
                    "op": "<=",
                    "pass": ok,
                },
            ],
        },
    }
    out_path = REPO_ROOT / "BENCH_stream.json"
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out_path}")

    if not ok and not os.environ.get("BENCH_NO_FAIL"):
        sys.exit(1)


if __name__ == "__main__":
    main()
