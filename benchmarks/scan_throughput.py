"""Loop vs scan vs vmapped-scan throughput, frame vs event metrics paths.

The loop driver is now memoized per config (ISSUE 3), so its steady-state
row measures pure per-window dispatch/host-sync/batching overhead — the
"loop (cold, re-jit)" row clears the caches first to keep the historical
as-shipped baseline (trace+compile included, the ISSUE 1 acceptance
line). The scanned driver pays one dispatch per recording. On top of
that dispatch story, the per-window core itself has two implementations
(ISSUE 2): the frame-based oracle that scatters a sensor-sized
accumulation image per window, and the frame-free event-space path
(O(events + K*patch^2) per window) that is bit-identical and must clear
>= 3x on the pre-windowed scan row. A per-stage breakdown (conditioning
/ histogram / metrics / tracking) attributes the win.

Results also land in BENCH_scan.json at the repo root so the perf
trajectory is tracked across PRs. Acceptance gates (exit code 1 on
failure, set BENCH_NO_FAIL=1 to disable):

* scan end-to-end >= 3x over the cold (re-jit) loop (ISSUE 1 line)
* event-space pre-windowed scan >= 3x over the frame path (ISSUE 2 line)
* fused fixed-point megakernel (ONE Pallas launch per window batch) vs
  the staged per-stage-kernel float path (two launches per window) on
  the same pre-windowed batch (ISSUE 6 line): >= 1x where launches are
  real (compiled TPU), a 0.5x regression floor under the CPU Pallas
  interpreter, plus a backend-independent HBM-traffic gate (<= 0.01x of
  the staged path) from the benchmarks/roofline_report.py window report,
  embedded alongside the measured ratio

  PYTHONPATH=src python benchmarks/scan_throughput.py
  N_WINDOWS=16 MEGA_WINDOWS=8 BENCH_GATE_EVENT=0 BENCH_GATE_MEGA=0
  ... (CI smoke knobs)
"""
import dataclasses
import json
import os
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np
from _common import git_commit, time_fn

from repro.core import metrics as M
from repro.core.events import pad_windows
from repro.core.pipeline import (
    PipelineConfig,
    _cluster,
    _condition,
    _histogram_fn,
    _tracker_fn,
    init_tracks,
    make_process_window,
    make_scan_fn,
    run_many_scan,
    run_recording,
    run_recording_scan,
    tracker_step,
)
from repro.data.synthetic import Recording, make_recording

N_WINDOWS = int(os.environ.get("N_WINDOWS", "64"))
# The megakernel rows use a smaller window count: interpret-mode Pallas
# (CPU) unrolls the (W,) grid at trace time, so compile cost scales with W.
MEGA_WINDOWS = int(os.environ.get("MEGA_WINDOWS", "8"))
N_SENSORS = int(os.environ.get("N_SENSORS", "4"))
REPO_ROOT = Path(__file__).resolve().parent.parent


def _recording_with_windows(n_windows: int, seed: int = 0) -> Recording:
    """A synthetic recording truncated to exactly n_windows dual-threshold
    windows."""
    rec = make_recording(seed=seed, duration_s=3.0, n_rsos=2)
    config = PipelineConfig()
    windowed = pad_windows(rec.x, rec.y, rec.t, rec.p, config.batcher)
    if windowed.num_windows < n_windows:
        raise SystemExit(
            f"recording too short: {windowed.num_windows} < {n_windows} windows"
        )
    cut = int(windowed.stops[n_windows - 1])
    return Recording(
        x=rec.x[:cut], y=rec.y[:cut], t=rec.t[:cut], p=rec.p[:cut],
        kind=rec.kind[:cut], obj=rec.obj[:cut], rso_tracks=rec.rso_tracks,
        duration_us=int(rec.t[cut - 1]), name=f"{rec.name}-{n_windows}w",
    )


def _stage_breakdown(
    config: PipelineConfig, us_event: float, stacked
) -> dict[str, float]:
    """Per-stage wall times (ms) over the stacked windows: cumulative scans
    over prefixes of the frame-path window core, reported as deltas, plus
    the event-space metrics stage for the head-to-head."""
    hist_fn = _histogram_fn(config)
    grid = config.grid

    def scan_upto(stage):
        @jax.jit
        def run(b):
            def step(carry, batch):
                batch = _condition(config, batch)
                if stage == "conditioning":
                    return carry, batch.valid.sum()
                clusters = _cluster(config, hist_fn, batch)
                if stage == "histogram":
                    return carry, clusters.count.sum()
                mets = M.cluster_metrics_frame(batch, clusters, grid.width, grid.height)
                if stage == "metrics":
                    return carry, mets["shannon_entropy"].sum()
                carry, _ = tracker_step(
                    carry, clusters, mets["shannon_entropy"], config.tracker
                )
                return carry, mets["shannon_entropy"].sum()

            return jax.lax.scan(step, init_tracks(config.tracker), b)

        return run

    out: dict[str, float] = {}
    prev = 0.0
    for stage in ("conditioning", "histogram", "metrics", "tracking"):
        fn = scan_upto(stage)
        us = time_fn(lambda: fn(stacked), iters=5)
        out[stage] = max((us - prev) / 1e3, 0.0)  # deltas; clamp timer noise
        prev = us

    # Event-space metrics stage: the measured event scan row minus the
    # shared conditioning+histogram+tracking prefix cost.
    shared = out["conditioning"] + out["histogram"] + out["tracking"]
    out["metrics (event)"] = max(us_event / 1e3 - shared, 0.0)
    return out


def main() -> None:
    config = PipelineConfig()  # metrics_impl="event" default
    config_frame = dataclasses.replace(config, metrics_impl="frame")
    rec = _recording_with_windows(N_WINDOWS)
    n_events = len(rec)
    print(
        f"backend={jax.default_backend()}  windows={N_WINDOWS}  "
        f"events={n_events:,}  sensors(vmap)={N_SENSORS}"
    )

    # Cold loop: clear the per-config caches so every call re-traces and
    # re-compiles — the historical "as shipped" baseline the ISSUE 1
    # acceptance line is defined against.
    def cold_loop():
        make_process_window.cache_clear()
        _tracker_fn.cache_clear()
        return run_recording(rec, config, with_tracking=True)

    us_loop = time_fn(cold_loop, warmup=1, iters=3)

    # Steady-state loop: make_process_window / _tracker_fn are memoized
    # per config, so a warm run_recording measures pure per-window
    # dispatch / host-sync / batching overhead.
    us_steady = time_fn(
        lambda: run_recording(rec, config, with_tracking=True), iters=5
    )

    # Scanned driver, end to end: host windowing + one compiled scan.
    us_scan = time_fn(
        lambda: run_recording_scan(rec, config, with_tracking=True).clusters.count,
        iters=5,
    )

    # Device-only scan: windows prebuilt, pure compiled time — the
    # frame-path oracle vs the frame-free event path head to head.
    # Samples are interleaved (alternating order) and the speedup is the
    # median of per-pair ratios, so slowly-varying host load hits both
    # rows of a pair equally and the ratio stays meaningful on shared
    # machines.
    import time as _time

    windowed = pad_windows(rec.x, rec.y, rec.t, rec.p, config.batcher)
    init = init_tracks(config.tracker)
    scan_event = make_scan_fn(config, True)
    scan_frame = make_scan_fn(config_frame, True)

    def _once(fn) -> float:
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(windowed.batch, init))
        return (_time.perf_counter() - t0) * 1e6

    for fn in (scan_event, scan_frame):
        jax.block_until_ready(fn(windowed.batch, init))  # compile warmup
    samples_e: list[float] = []
    samples_f: list[float] = []
    for i in range(16):
        if i % 2:
            samples_e.append(_once(scan_event))
            samples_f.append(_once(scan_frame))
        else:
            samples_f.append(_once(scan_frame))
            samples_e.append(_once(scan_event))
    us_device_event = sorted(samples_e)[len(samples_e) // 2]
    us_device_frame = sorted(samples_f)[len(samples_f) // 2]
    pair_ratios = sorted(f / e for f, e in zip(samples_f, samples_e))
    ratio_event_over_frame = pair_ratios[len(pair_ratios) // 2]
    # Gate on the min/min ratio: the minimum is the classic least-noise
    # wall-time estimator (timeit-style), and scheduler/GC jitter on small
    # shared boxes lands almost entirely in the right tail.
    ratio_event_over_frame_best = min(samples_f) / min(samples_e)

    # Fused fixed-point megakernel (ONE Pallas launch per window batch)
    # vs the staged per-stage-kernel float path (two interpret-mode
    # launches per window), same pre-windowed batch, same interleaved
    # paired sampling as above.
    config_mega = dataclasses.replace(
        config, numerics="fixed", metrics_impl="megakernel"
    )
    config_kpath = dataclasses.replace(
        config, use_kernels=True, metrics_impl="kernel"
    )
    batch_mega = jax.tree_util.tree_map(
        lambda a: a[:MEGA_WINDOWS], windowed.batch
    )
    scan_mega = make_scan_fn(config_mega, True)
    scan_kpath = make_scan_fn(config_kpath, True)

    def _once_b(fn) -> float:
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(batch_mega, init))
        return (_time.perf_counter() - t0) * 1e6

    for fn in (scan_mega, scan_kpath):
        jax.block_until_ready(fn(batch_mega, init))  # compile warmup
    samples_m: list[float] = []
    samples_k: list[float] = []
    for i in range(8):
        if i % 2:
            samples_m.append(_once_b(scan_mega))
            samples_k.append(_once_b(scan_kpath))
        else:
            samples_k.append(_once_b(scan_kpath))
            samples_m.append(_once_b(scan_mega))
    us_mega = sorted(samples_m)[len(samples_m) // 2]
    us_kpath = sorted(samples_k)[len(samples_k) // 2]
    mega_pair_ratios = sorted(k / m for k, m in zip(samples_k, samples_m))
    ratio_mega = mega_pair_ratios[len(mega_pair_ratios) // 2]
    ratio_mega_best = min(samples_k) / min(samples_m)

    # Vmapped scan across N_SENSORS recordings (one dispatch total).
    recs = [_recording_with_windows(N_WINDOWS, seed=s) for s in range(N_SENSORS)]
    us_vmap = time_fn(
        lambda: run_many_scan(recs, config)[-1].clusters.count, iters=5
    )

    stages = _stage_breakdown(config_frame, us_device_event, windowed.batch)

    rows: dict[str, dict[str, float]] = {}

    def report(name: str, us: float, windows: int, events: int) -> None:
        rows[name] = {
            "ms": round(us / 1e3, 3),
            "windows_per_sec": round(windows / (us * 1e-6), 1),
            "events_per_sec": round(events / (us * 1e-6), 1),
        }
        print(
            f"{name:<28} {us / 1e3:9.2f} ms   "
            f"{windows / (us * 1e-6):12,.0f} win/s   "
            f"{events / (us * 1e-6):14,.0f} ev/s"
        )

    print(f"{'driver':<28} {'wall':>12}   {'windows/sec':>12}   {'events/sec':>14}")
    report("loop (cold, re-jit)", us_loop, N_WINDOWS, n_events)
    report("loop (steady-state)", us_steady, N_WINDOWS, n_events)
    report("scan (end-to-end)", us_scan, N_WINDOWS, n_events)
    report("scan (pre-windowed, frame)", us_device_frame, N_WINDOWS, n_events)
    report("scan (pre-windowed, event)", us_device_event, N_WINDOWS, n_events)
    n_events_mega = int(np.asarray(batch_mega.valid).sum())
    report("staged kernels (float)", us_kpath, MEGA_WINDOWS, n_events_mega)
    report("megakernel (fixed)", us_mega, MEGA_WINDOWS, n_events_mega)
    report(
        f"vmap scan x{N_SENSORS}",
        us_vmap,
        N_SENSORS * N_WINDOWS,
        sum(len(r) for r in recs),
    )

    print("\nper-stage breakdown (frame-path scan body, ms over all windows):")
    for stage, ms in stages.items():
        print(f"  {stage:<18} {ms:8.2f} ms")

    speedup_scan = us_loop / us_scan
    speedup_event = ratio_event_over_frame
    gate_scan = speedup_scan >= 3.0
    gate_event = ratio_event_over_frame_best >= 3.0
    # Off TPU both contenders run under the Pallas interpreter, which
    # charges per grid point per op — the fused kernel's larger body pays
    # more interpretation than its one-launch saving returns, so the CPU
    # floor is a 0.5x regression guard; the >= 1x claim is gated where
    # launches are real (compiled TPU). The deterministic fusion evidence
    # (HBM traffic gate below) holds on every backend.
    mega_threshold = 1.0 if jax.default_backend() == "tpu" else 0.5
    gate_mega = ratio_mega_best >= mega_threshold
    print(
        f"\nscan end-to-end speedup over loop: {speedup_scan:.1f}x "
        f"({'PASS' if gate_scan else 'FAIL'} >= 3x acceptance)"
    )
    print(
        f"event-space speedup over frame path (pre-windowed): "
        f"{ratio_event_over_frame_best:.1f}x best, "
        f"{speedup_event:.1f}x paired-median "
        f"({'PASS' if gate_event else 'FAIL'} >= 3x best acceptance)"
    )
    print(
        f"megakernel speedup over staged kernel path "
        f"({MEGA_WINDOWS} windows): {ratio_mega_best:.1f}x best, "
        f"{ratio_mega:.1f}x paired-median "
        f"({'PASS' if gate_mega else 'FAIL'} >= {mega_threshold}x best "
        f"acceptance on this backend)"
    )

    # Roofline bytes/flops delta for the fused launch (ISSUE 6 evidence;
    # the measured ratio above pairs with this analytic/HLO comparison).
    import roofline_report

    wr = roofline_report.window_report(n_windows=4, capacity=256)
    gate_traffic = wr["mega_over_fixed_bytes"] <= 0.01
    print()
    print(roofline_report.window_markdown_table(wr))
    print(
        f"megakernel HBM traffic vs staged fixed: "
        f"{wr['mega_over_fixed_bytes']:.4f}x "
        f"({'PASS' if gate_traffic else 'FAIL'} <= 0.01x acceptance)"
    )

    payload = {
        "backend": jax.default_backend(),
        "commit": git_commit(),
        "n_windows": N_WINDOWS,
        "n_events": n_events,
        "rows": rows,
        "stages_ms": {k: round(v, 3) for k, v in stages.items()},
        "speedups": {
            "scan_end_to_end_over_loop": round(speedup_scan, 2),
            "event_over_frame_prewindowed": round(speedup_event, 2),
            "event_over_frame_prewindowed_best": round(
                ratio_event_over_frame_best, 2
            ),
            "megakernel_over_staged_kernels": round(ratio_mega, 2),
            "megakernel_over_staged_kernels_best": round(ratio_mega_best, 2),
        },
        "mega_windows": MEGA_WINDOWS,
        "roofline_window": wr,
        # Uniform block consumed by the benchmarks.run aggregator; the
        # percentiles are over the pre-windowed event-scan samples (the
        # steady-state compiled dispatch this bench is really about).
        "bench": {
            "name": "scan_throughput",
            "p50_ms": round(us_device_event / 1e3, 3),
            "p99_ms": round(
                float(np.percentile(np.asarray(samples_e), 99)) / 1e3, 3
            ),
            "gates": [
                {
                    "name": "scan_end_to_end_over_loop",
                    "value": round(speedup_scan, 2),
                    "threshold": 3.0,
                    "op": ">=",
                    "pass": gate_scan,
                },
                {
                    "name": "event_over_frame_prewindowed_best",
                    "value": round(ratio_event_over_frame_best, 2),
                    "threshold": 3.0,
                    "op": ">=",
                    "pass": gate_event,
                },
                {
                    "name": "megakernel_over_staged_kernels_best",
                    "value": round(ratio_mega_best, 2),
                    "threshold": mega_threshold,
                    "op": ">=",
                    "pass": gate_mega,
                },
                {
                    "name": "megakernel_hbm_traffic_over_staged",
                    "value": round(wr["mega_over_fixed_bytes"], 4),
                    "threshold": 0.01,
                    "op": "<=",
                    "pass": gate_traffic,
                },
            ],
        },
    }
    out_path = REPO_ROOT / "BENCH_scan.json"
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"\nwrote {out_path}")

    if os.environ.get("BENCH_NO_FAIL"):
        return
    gates = [gate_scan]
    if os.environ.get("BENCH_GATE_EVENT", "1") != "0":
        gates.append(gate_event)
    if os.environ.get("BENCH_GATE_MEGA", "1") != "0":
        gates.append(gate_mega)
        gates.append(gate_traffic)
    if not all(gates):
        sys.exit(1)


if __name__ == "__main__":
    main()
