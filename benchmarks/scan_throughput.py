"""Loop vs scan vs vmapped-scan throughput (the dispatch-overhead story).

The legacy driver pays a fresh trace+compile per recording plus one jit
dispatch, host sync, and per-window host batching/transfer for every
window; the scanned driver is memoized per config and pays one dispatch
per recording. Both are measured as the public APIs ship; a steady-state
loop row (process_window compiled once, held by the caller) isolates the
per-window dispatch + host-sync cost from the re-jit cost. On a
64-window synthetic recording the scan driver must clear >= 3x
windows/sec over the legacy loop on CPU (ISSUE 1 acceptance); on
accelerators the gap widens further.

  PYTHONPATH=src python benchmarks/scan_throughput.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np
from _common import time_fn

from repro.core.events import pad_windows
from repro.core.pipeline import (
    PipelineConfig,
    init_tracks,
    make_scan_fn,
    run_many_scan,
    run_recording,
    run_recording_scan,
)
from repro.data.synthetic import Recording, make_recording

N_WINDOWS = 64
N_SENSORS = 4


def _recording_with_windows(n_windows: int, seed: int = 0) -> Recording:
    """A synthetic recording truncated to exactly n_windows dual-threshold
    windows."""
    rec = make_recording(seed=seed, duration_s=3.0, n_rsos=2)
    config = PipelineConfig()
    windowed = pad_windows(rec.x, rec.y, rec.t, rec.p, config.batcher)
    if windowed.num_windows < n_windows:
        raise SystemExit(
            f"recording too short: {windowed.num_windows} < {n_windows} windows"
        )
    cut = int(windowed.stops[n_windows - 1])
    return Recording(
        x=rec.x[:cut], y=rec.y[:cut], t=rec.t[:cut], p=rec.p[:cut],
        kind=rec.kind[:cut], obj=rec.obj[:cut], rso_tracks=rec.rso_tracks,
        duration_us=int(rec.t[cut - 1]), name=f"{rec.name}-{n_windows}w",
    )


def main() -> None:
    config = PipelineConfig()
    rec = _recording_with_windows(N_WINDOWS)
    n_events = len(rec)
    print(
        f"backend={jax.default_backend()}  windows={N_WINDOWS}  "
        f"events={n_events:,}  sensors(vmap)={N_SENSORS}"
    )

    # Legacy host loop as shipped: re-traces per call, one dispatch/window.
    us_loop = time_fn(
        lambda: run_recording(rec, config, with_tracking=True), warmup=1, iters=3
    )

    # Steady-state loop: caller holds the compiled window fn + tracker fn,
    # paying only the per-window dispatch / host-sync / batching cost.
    import functools

    from repro.core.events import dual_threshold_batches
    from repro.core.pipeline import make_process_window, tracker_step

    process_window = make_process_window(config)
    tracker_fn = jax.jit(functools.partial(tracker_step, config=config.tracker))

    def steady_loop():
        state = init_tracks(config.tracker)
        out = []
        for batch, sl in dual_threshold_batches(
            rec.x, rec.y, rec.t, rec.p, config.batcher
        ):
            clusters, mets = process_window(batch)
            state, _ = tracker_fn(state, clusters, mets["shannon_entropy"])
            out.append(
                (clusters, {k: np.asarray(v) for k, v in mets.items()}, state)
            )
        return out

    us_steady = time_fn(steady_loop, iters=5)

    # Scanned driver, end to end: host windowing + one compiled scan.
    us_scan = time_fn(
        lambda: run_recording_scan(rec, config, with_tracking=True).clusters.count,
        iters=5,
    )

    # Device-only scan: windows prebuilt, pure compiled time.
    windowed = pad_windows(rec.x, rec.y, rec.t, rec.p, config.batcher)
    scan_fn = make_scan_fn(config, True)
    init = init_tracks(config.tracker)
    us_device = time_fn(lambda: scan_fn(windowed.batch, init), iters=10)

    # Vmapped scan across N_SENSORS recordings (one dispatch total).
    recs = [_recording_with_windows(N_WINDOWS, seed=s) for s in range(N_SENSORS)]
    us_vmap = time_fn(
        lambda: run_many_scan(recs, config)[-1].clusters.count, iters=5
    )

    def report(name: str, us: float, windows: int, events: int) -> None:
        print(
            f"{name:<28} {us / 1e3:9.2f} ms   "
            f"{windows / (us * 1e-6):12,.0f} win/s   "
            f"{events / (us * 1e-6):14,.0f} ev/s"
        )

    print(f"{'driver':<28} {'wall':>12}   {'windows/sec':>12}   {'events/sec':>14}")
    report("loop (as shipped)", us_loop, N_WINDOWS, n_events)
    report("loop (steady-state)", us_steady, N_WINDOWS, n_events)
    report("scan (end-to-end)", us_scan, N_WINDOWS, n_events)
    report("scan (pre-windowed)", us_device, N_WINDOWS, n_events)
    report(
        f"vmap scan x{N_SENSORS}",
        us_vmap,
        N_SENSORS * N_WINDOWS,
        sum(len(r) for r in recs),
    )
    speedup = us_loop / us_scan
    print(f"scan end-to-end speedup over loop: {speedup:.1f}x "
          f"({'PASS' if speedup >= 3.0 else 'FAIL'} >= 3x acceptance)")


if __name__ == "__main__":
    main()
