"""Ingest saturation: offered-load sweep, pipelined vs synchronous.

PR 7 made the service fault-tolerant; this bench measures what the
pipelined ingest path (DESIGN.md Sec. 14) buys at saturation. A fixed
fleet of N_SESSIONS sensors offers rising per-round event loads (one
"round" = one 20 ms live-cadence beat: every session feeds one chunk,
then one forced pump dispatches the fleet step). Each load level runs
twice over identical streams:

* **sync** — ``max_inflight_rounds=1``: every round is awaited before
  the next feed (the pre-pipelining behaviour, bit-identical outputs);
* **pipelined** — ``max_inflight_rounds=DEPTH``: host packing of round
  N+1 overlaps device compute of rounds N.. (double-buffered staging),
  results consumed lazily, ``drain()`` inside the timed region so the
  tail is never hidden.

Per level and mode the bench reports offered vs **sustained** events/s
(total events / wall time) and per-round p50/p99. The **knee** is the
highest level a mode still sustains >= KNEE_FRACTION x offered — the
service's live-cadence capacity.

Gates (exit code 1 on failure, BENCH_NO_FAIL=1 to disable):

* pipelined knee per-round p99 <= BUDGET_MS (62 ms paper budget);
* pipelined peak sustained >= RATIO x sync peak sustained. Pipelining
  moves host packing off the critical path but conserves total work, so
  the 1.3x target needs a second core for the XLA worker thread to run
  on; on a single-core host the gate degrades to a documented
  no-regression floor (0.95x), same convention as the relaxed CI gates
  in ci.yml ("tracked from dedicated hardware"). BENCH_GATE_RATIO
  overrides either. The json records both the applied and the
  multi-core target so dashboards can track the real number;
* knee wire compression >= WIRE_TARGET (2.0x): host->device bytes on
  the default ragged wire (DESIGN.md Sec. 16) vs the dense-equivalent
  cost of the same rounds, measured at the knee's occupancy. The floor
  is intentionally below the ~2.8x the 250-events-per-256-slot steady
  state delivers: degenerate rounds (all-full windows plus quantum
  padding, or near-empty rounds dominated by the WIRE_QUANTUM floor)
  compress less, and the gate must hold at whatever occupancy the knee
  lands on. BENCH_GATE_WIRE overrides.

Results land in BENCH_ingest.json at the repo root with the uniform
``bench`` block the ``benchmarks.run`` aggregator consumes.

  PYTHONPATH=src python benchmarks/serve_saturation.py
  N_SESSIONS=8 LEVELS=250,500,1000 DEPTH=3 BUDGET_MS=62 ...  (CI knobs)
"""
import gc
import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np
from _common import git_commit

from repro.core.pipeline import PipelineConfig
from repro.serve import AdmissionConfig, DetectionService

N_SESSIONS = int(os.environ.get("N_SESSIONS", "8"))
N_ROUNDS = int(os.environ.get("N_ROUNDS", "40"))
N_WARMUP = int(os.environ.get("N_WARMUP", "4"))
CHUNK_US = int(os.environ.get("CHUNK_US", "20000"))  # live-cadence round
BUDGET_MS = float(os.environ.get("BUDGET_MS", "62"))
DEPTH = int(os.environ.get("DEPTH", "3"))  # pipelined max_inflight_rounds
KNEE_FRACTION = float(os.environ.get("KNEE_FRACTION", "0.95"))
# Events per sensor per round. 250 is the paper's size cut (one window
# per sensor per round); higher levels close 2/4/8 windows per round.
LEVELS = tuple(
    int(v) for v in os.environ.get("LEVELS", "125,250,500,1000").split(",")
)
RATIO_TARGET_MULTICORE = 1.3
RATIO_FLOOR_1CORE = 0.95
WIRE_TARGET = float(os.environ.get("BENCH_GATE_WIRE", "2.0"))
REPO_ROOT = Path(__file__).resolve().parent.parent

TIERS = (N_SESSIONS,)


def _stream(seed: int, n: int, dt_us: int):
    rng = np.random.default_rng(seed)
    return (
        rng.integers(40, 560, n).astype(np.int64),
        rng.integers(40, 400, n).astype(np.int64),
        (np.arange(n, dtype=np.int64) + 1) * dt_us,
        rng.integers(0, 2, n).astype(np.int64),
    )


def _replay(level: int, depth: int):
    """One offered-load level at one pipeline depth.

    Returns (per-round ms, sustained events/s, windows). Event
    timestamps are spaced so each round's chunk spans exactly CHUNK_US
    of sensor time — the offered load is level * N_SESSIONS events per
    20 ms beat, fed as fast as the service absorbs them (no pacing:
    sustained >= offered means the service keeps up with live cadence).
    """
    svc = DetectionService(
        PipelineConfig(), tiers=TIERS,
        admission=AdmissionConfig(max_delay_s=1e9, max_items=1 << 30),
        max_inflight_rounds=depth,
    )
    total = (N_WARMUP + N_ROUNDS) * level
    dt_us = max(1, CHUNK_US // level)
    streams = [_stream(7 * s + 1, total, dt_us) for s in range(N_SESSIONS)]
    sids = [svc.attach(f"sat{s}") for s in range(N_SESSIONS)]
    served = []

    def beat(rnd):
        lo, hi = rnd * level, (rnd + 1) * level
        for s, sid in enumerate(sids):
            x, y, t, p = streams[s]
            served.extend(svc.feed(sid, x[lo:hi], y[lo:hi], t[lo:hi], p[lo:hi]))
        served.extend(svc.pump(force=True))

    for rnd in range(N_WARMUP):  # compiles this level's (S, W) step shape
        beat(rnd)
    svc.drain()
    served.clear()

    ws = svc.wire_stats
    w0 = (ws.rounds, ws.wire_bytes, ws.dense_bytes)
    times = []
    t_all = time.perf_counter()
    for rnd in range(N_WARMUP, N_WARMUP + N_ROUNDS):
        t0 = time.perf_counter()
        beat(rnd)
        times.append((time.perf_counter() - t0) * 1e3)
    # The drain is part of the measured window: pipelining may not defer
    # the tail's cost outside the sustained-throughput accounting.
    svc.drain()
    wall_s = time.perf_counter() - t_all
    # Timed-region wire accounting (warmup rounds excluded).
    d_rounds = max(1, ws.rounds - w0[0])
    wire = {
        "wire_bytes_per_round": round((ws.wire_bytes - w0[1]) / d_rounds, 1),
        "dense_bytes_per_round": round((ws.dense_bytes - w0[2]) / d_rounds, 1),
        "wire_ratio": round(
            (ws.dense_bytes - w0[2]) / max(1, ws.wire_bytes - w0[1]), 3
        ),
    }
    windows = sum(fd.num_windows for fd in served)
    sustained = N_ROUNDS * level * N_SESSIONS / wall_s
    for sid in sids:
        svc.detach(sid)
    return times, sustained, windows, wire


def _sweep(depth: int):
    rows = []
    gc.collect()
    gc.disable()
    try:
        for level in LEVELS:
            times, sustained, windows, wire = _replay(level, depth)
            offered = level * N_SESSIONS / (CHUNK_US / 1e6)
            arr = np.asarray(times)
            rows.append({
                "level_events_per_sensor": level,
                "offered_events_s": round(offered, 1),
                "sustained_events_s": round(sustained, 1),
                "utilization": round(sustained / offered, 3),
                "p50_ms": round(float(np.percentile(arr, 50)), 3),
                "p99_ms": round(float(np.percentile(arr, 99)), 3),
                "windows": windows,
                **wire,
            })
    finally:
        gc.enable()
    return rows


def _knee(rows):
    """Highest level still sustaining >= KNEE_FRACTION x offered; falls
    back to the first level (everything saturated) so the p99 gate always
    has a defined operating point."""
    passing = [r for r in rows if r["utilization"] >= KNEE_FRACTION]
    return passing[-1] if passing else rows[0]


def main() -> None:
    host_cores = os.cpu_count() or 1
    ratio_target = RATIO_TARGET_MULTICORE if host_cores >= 2 else RATIO_FLOOR_1CORE
    ratio_target = float(os.environ.get("BENCH_GATE_RATIO", ratio_target))
    print(
        f"backend={jax.default_backend()}  host_cores={host_cores}  "
        f"sessions={N_SESSIONS}  levels={LEVELS} ev/sensor/round  "
        f"rounds={N_ROUNDS}  depth={DEPTH}"
    )

    sync_rows = _sweep(depth=1)
    pipe_rows = _sweep(depth=DEPTH)

    print(f"\n{'level':>6} {'offered/s':>11} {'sync/s':>11} {'pipe/s':>11} "
          f"{'ratio':>6} {'sync p99':>9} {'pipe p99':>9}")
    for sr, pr in zip(sync_rows, pipe_rows):
        print(
            f"{sr['level_events_per_sensor']:>6} "
            f"{sr['offered_events_s']:>11,.0f} "
            f"{sr['sustained_events_s']:>11,.0f} "
            f"{pr['sustained_events_s']:>11,.0f} "
            f"{pr['sustained_events_s'] / sr['sustained_events_s']:>6.2f} "
            f"{sr['p99_ms']:>9.2f} {pr['p99_ms']:>9.2f}"
        )

    knee = _knee(pipe_rows)
    sync_peak = max(r["sustained_events_s"] for r in sync_rows)
    pipe_peak = max(r["sustained_events_s"] for r in pipe_rows)
    ratio = pipe_peak / sync_peak

    gate_p99 = knee["p99_ms"] <= BUDGET_MS
    gate_ratio = ratio >= ratio_target
    gate_wire = knee["wire_ratio"] >= WIRE_TARGET
    print(
        f"\nknee (pipelined): {knee['level_events_per_sensor']} ev/sensor/"
        f"round = {knee['offered_events_s']:,.0f} ev/s offered, sustained "
        f"{knee['sustained_events_s']:,.0f} ev/s, p99 {knee['p99_ms']:.2f} ms"
    )
    print(
        f"knee p99 vs paper budget: {knee['p99_ms']:.2f} ms <= {BUDGET_MS} ms "
        f"({'PASS' if gate_p99 else 'FAIL'})"
    )
    print(
        f"pipelined/sync peak sustained: {pipe_peak:,.0f} / {sync_peak:,.0f} "
        f"= {ratio:.2f}x >= {ratio_target}x "
        f"({'PASS' if gate_ratio else 'FAIL'}; multi-core target "
        f"{RATIO_TARGET_MULTICORE}x, {host_cores} core(s) here)"
    )
    print(
        f"knee wire compression: {knee['wire_ratio']:.2f}x >= {WIRE_TARGET}x "
        f"({'PASS' if gate_wire else 'FAIL'}; "
        f"{knee['wire_bytes_per_round']:,.0f} B/round ragged vs "
        f"{knee['dense_bytes_per_round']:,.0f} B/round dense-equivalent)"
    )

    payload = {
        "backend": jax.default_backend(),
        "commit": git_commit(),
        "host_cores": host_cores,
        "n_sessions": N_SESSIONS,
        "n_rounds": N_ROUNDS,
        "chunk_us": CHUNK_US,
        "depth": DEPTH,
        "levels": list(LEVELS),
        "knee_fraction": KNEE_FRACTION,
        "sync": sync_rows,
        "pipelined": pipe_rows,
        "knee": knee,
        "sustained_ratio": round(ratio, 3),
        "ratio_target_applied": ratio_target,
        "ratio_target_multicore": RATIO_TARGET_MULTICORE,
        "wire_target": WIRE_TARGET,
        "bench": {
            "name": "serve_saturation",
            "p50_ms": knee["p50_ms"],
            "p99_ms": knee["p99_ms"],
            "bytes_per_round": knee["wire_bytes_per_round"],
            "gates": [
                {
                    "name": "knee_p99_within_budget",
                    "value": knee["p99_ms"],
                    "threshold": BUDGET_MS,
                    "op": "<=",
                    "pass": gate_p99,
                },
                {
                    "name": "pipelined_sustained_vs_sync",
                    "value": round(ratio, 3),
                    "threshold": ratio_target,
                    "op": ">=",
                    "pass": gate_ratio,
                },
                {
                    "name": "wire_compression",
                    "value": knee["wire_ratio"],
                    "threshold": WIRE_TARGET,
                    "op": ">=",
                    "pass": gate_wire,
                },
            ],
        },
    }
    out_path = REPO_ROOT / "BENCH_ingest.json"
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out_path}")

    if os.environ.get("BENCH_NO_FAIL"):
        return
    if not (gate_p99 and gate_ratio and gate_wire):
        sys.exit(1)


if __name__ == "__main__":
    main()
