"""Paper Table IV: system specs — detection accuracy (sampled detections
vs ground truth, the paper's 97% protocol), end-to-end throughput, and
the TPU roofline for the quantization kernel (the II=1 / 200 MEv/s
analogue)."""
from __future__ import annotations

import time

import numpy as np

from repro.core.pipeline import PipelineConfig, merge_candidates, collect_candidates, score_threshold
from repro.core.pipeline import run_recording
from repro.data.synthetic import make_recording
from repro.launch.mesh import HBM_BW


def bench() -> list[tuple[str, float, str]]:
    rows = []
    recs = [
        make_recording(seed=s, duration_s=1.0, n_rsos=1 + s % 3) for s in (1, 2)
    ] + [make_recording(seed=11, duration_s=1.0, n_rsos=1, lens="telephoto"),
         make_recording(seed=21, duration_s=1.0, n_rsos=2, lens="wide")]
    cfg = PipelineConfig()

    # Accuracy at the paper's operating point, >= 1000 sampled detections.
    cand = merge_candidates([collect_candidates(r, cfg) for r in recs])
    score = score_threshold(cand, 5)
    n_samples = score.tp + score.fp + score.fn + score.tn
    rows.append(
        ("table4/detection_accuracy", 0.0,
         f"{100 * score.accuracy:.1f}pct_n{n_samples}_paper97")
    )
    rows.append(
        ("table4/precision_recall", 0.0,
         f"p{100 * score.precision:.1f}_r{100 * score.recall:.1f}")
    )

    # End-to-end throughput (events/s through the full pipeline).
    rec = recs[0]
    t0 = time.perf_counter()
    run_recording(rec, cfg, with_tracking=True)
    dt = time.perf_counter() - t0
    rows.append(
        ("table4/pipeline_throughput", dt / max(len(rec), 1) * 1e6,
         f"{len(rec) / dt / 1e3:.0f}kEv_s_cpu")
    )

    # Quantize-kernel roofline on the TPU target: 4B in + 4B out per event
    # at HBM bandwidth (the stream is too light to be compute-bound).
    ev_per_s = HBM_BW / 8.0
    rows.append(
        ("table4/quantize_kernel_roofline", 0.0,
         f"{ev_per_s / 1e9:.0f}GEv_s_vs_paper_0.2GEv_s")
    )
    # Config constants carried from the paper.
    rows.append(("table4/grid_size", 0.0, "16x16_cells"))
    rows.append(("table4/min_events", 0.0, "5"))
    rows.append(("table4/batch", 0.0, "250ev_20ms"))
    return rows
