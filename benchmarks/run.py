"""Benchmark aggregator: paper tables/figures + the gated perf benches.

Two kinds of entries share this single entrypoint:

* **table/figure modules** (``table1`` .. ``roofline``) — imported and
  run in-process, printing ``name,us_per_call,derived`` CSV rows (the
  paper-reproduction numbers).
* **gated benches** (``scan`` / ``stream`` / ``fleet``) — run as
  subprocesses writing ``BENCH_<name>.json`` at the repo root. Every
  payload carries a uniform ``bench`` block — ``{name, p50_ms, p99_ms,
  gates:[{name, value, threshold, op, pass}]}`` — which this aggregator
  collects into one summary table. Benches that account wire traffic
  also report ``bytes_per_round``; the summary prints it as a column
  and shows ``WARN`` (never an error) for payloads missing the field. Each bench's own exit code is the
  gate authority (env knobs like ``BENCH_NO_FAIL`` /
  ``BENCH_GATE_SPEEDUP`` / ``BENCH_GATE_EVENT`` pass through and mean
  the same thing here as when a bench is run directly); the aggregator
  exits nonzero iff any subprocess did.

Select subsets by key::

  PYTHONPATH=src python -m benchmarks.run table1 fig10   # paper tables
  PYTHONPATH=src python -m benchmarks.run scan stream fleet serve
  PYTHONPATH=src python -m benchmarks.run                # everything
"""
from __future__ import annotations

import json
import subprocess
import sys
import time
from pathlib import Path

from benchmarks._common import emit

REPO_ROOT = Path(__file__).resolve().parent.parent

MODULES = {
    "table1": "benchmarks.table1_algorithms",
    "table3": "benchmarks.table3_latency",
    "table4": "benchmarks.table4_system",
    "table5": "benchmarks.table5_scaling",
    "fig10": "benchmarks.fig10_threshold",
    "fig5_8": "benchmarks.fig5_8_entropy",
    "roofline": "benchmarks.roofline_report",
}

# Gated benches: script + the BENCH_*.json it writes (uniform `bench`
# block inside). Registered here so one command runs the whole gate set.
BENCHES = {
    "scan": ("scan_throughput.py", "BENCH_scan.json"),
    "stream": ("stream_latency.py", "BENCH_stream.json"),
    "fleet": ("fleet_throughput.py", "BENCH_fleet.json"),
    "serve": ("serve_latency.py", "BENCH_serve.json"),
    "ingest": ("serve_saturation.py", "BENCH_ingest.json"),
    "chaos": ("chaos_soak.py", "BENCH_chaos.json"),
    "constellation": ("constellation_scaling.py", "BENCH_constellation.json"),
}


def _run_module(key: str) -> None:
    t0 = time.time()
    mod = __import__(MODULES[key], fromlist=["bench"])
    try:
        rows = mod.bench()
    except Exception as e:  # noqa: BLE001
        rows = [(f"{key}/ERROR", 0.0, f"{type(e).__name__}_{e}")]
    emit(rows)
    print(f"# {key} done in {time.time() - t0:.1f}s", file=sys.stderr)


def _run_bench(key: str) -> tuple[dict | None, bool]:
    """Run one gated bench as a subprocess.

    Returns ``(bench block, ok)``: the bench's own exit code decides
    ``ok`` (so its gate knobs behave identically under the aggregator),
    and the block is parsed from the written json when available.
    """
    script, json_name = BENCHES[key]
    t0 = time.time()
    proc = subprocess.run(
        [sys.executable, str(Path(__file__).parent / script)], cwd=REPO_ROOT
    )
    print(f"# {key} done in {time.time() - t0:.1f}s", file=sys.stderr)
    path = REPO_ROOT / json_name
    block = json.loads(path.read_text()).get("bench") if path.exists() else None
    return block, proc.returncode == 0


def main() -> None:
    selected = sys.argv[1:] or [*MODULES, *BENCHES]
    unknown = [k for k in selected if k not in MODULES and k not in BENCHES]
    if unknown:
        sys.exit(f"unknown benchmark keys: {unknown}; "
                 f"choose from {[*MODULES, *BENCHES]}")

    if any(k in MODULES for k in selected):
        print("name,us_per_call,derived")
    summaries: list[tuple[str, dict | None, bool]] = []
    for key in selected:
        if key in MODULES:
            _run_module(key)
        else:
            block, ok = _run_bench(key)
            summaries.append((key, block, ok))

    if not summaries:
        return
    print(f"\n{'bench':<18} {'p50 ms':>9} {'p99 ms':>9} {'bytes/round':>12}  gates")
    failed = False
    for key, block, ok in summaries:
        failed |= not ok
        if block is None:
            print(f"{key:<18} {'-':>9} {'-':>9} {'-':>12}  ERROR (no BENCH json)")
            continue
        bpr = block.get("bytes_per_round")
        if bpr is None:
            # Older BENCH json predating the wire-format accounting: the
            # column is advisory, so a missing field warns but never fails.
            bpr_col = "WARN"
        else:
            bpr_col = f"{bpr:.0f}"
        gates = "; ".join(
            f"{g['name']} {g['value']} {g['op']} {g['threshold']} "
            f"[{'PASS' if g['pass'] else 'FAIL'}]"
            for g in block.get("gates", [])
        )
        print(
            f"{block['name']:<18} {block['p50_ms']:>9} {block['p99_ms']:>9} "
            f"{bpr_col:>12}  {gates}{'' if ok else '  << exit 1'}"
        )
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
