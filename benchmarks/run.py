"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Select subsets with
``python -m benchmarks.run table1 fig10``.
"""
from __future__ import annotations

import sys
import time

from benchmarks._common import emit

MODULES = {
    "table1": "benchmarks.table1_algorithms",
    "table3": "benchmarks.table3_latency",
    "table4": "benchmarks.table4_system",
    "table5": "benchmarks.table5_scaling",
    "fig10": "benchmarks.fig10_threshold",
    "fig5_8": "benchmarks.fig5_8_entropy",
    "roofline": "benchmarks.roofline_report",
}


def main() -> None:
    selected = sys.argv[1:] or list(MODULES)
    print("name,us_per_call,derived")
    for key in selected:
        mod_name = MODULES[key]
        t0 = time.time()
        mod = __import__(mod_name, fromlist=["bench"])
        try:
            rows = mod.bench()
        except Exception as e:  # noqa: BLE001
            rows = [(f"{key}/ERROR", 0.0, f"{type(e).__name__}_{e}")]
        emit(rows)
        print(f"# {key} done in {time.time() - t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
