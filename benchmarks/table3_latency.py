"""Paper Table III: latency breakdown per processing stage (250-event
batch). Stages mirror the paper's pipeline; 'fused kernel' shows the
beyond-paper quantize+aggregate fusion (paper Sec. VI projects < 30 ms
from exactly this offload)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks._common import time_fn
from repro.core import metrics as M
from repro.core.events import batch_from_arrays, persistent_event_filter, roi_filter
from repro.core.grid_clustering import (
    GridConfig,
    cell_histogram,
    clusters_from_histogram,
)
from repro.core.pipeline import PipelineConfig, make_process_window
from repro.core.tracking import TrackerConfig, init_tracks, tracker_step
from repro.data.synthetic import make_recording
from repro.kernels import ops as kops


def bench() -> list[tuple[str, float, str]]:
    rec = make_recording(seed=1, duration_s=0.2)
    n = 250
    b = batch_from_arrays(rec.x[:n], rec.y[:n], rec.t[:n], rec.p[:n])
    cfg = GridConfig()
    rows = []

    cond = jax.jit(lambda bb: persistent_event_filter(roi_filter(bb)))
    rows.append(("table3/conditioning", time_fn(cond, b), "roi+hotpixel"))

    bb = cond(b)
    quant = jax.jit(lambda e: cell_histogram(e, cfg))
    rows.append(("table3/quantize_accumulate_jnp", time_fn(quant, bb), "xla"))

    fused = lambda e: kops.cluster_accum(
        e.x, e.y, e.t, e.valid, cell_size=cfg.cell_size,
        grid_w=cfg.grid_w, grid_h=cfg.grid_h,
    )
    rows.append(
        ("table3/quantize_accumulate_kernel", time_fn(fused, bb),
         "pallas_interpret")
    )

    hist = quant(bb)
    form = jax.jit(lambda h: clusters_from_histogram(*h, cfg))
    rows.append(("table3/threshold_centroid", time_fn(form, hist), "topk"))

    clusters = form(hist)
    met = jax.jit(lambda e, c: M.cluster_metrics(M.reconstruct_frame(e), c))
    rows.append(("table3/metrics_48x48", time_fn(met, bb, clusters), "6metrics"))

    mets = met(bb, clusters)
    tcfg = TrackerConfig()
    st = init_tracks(tcfg)
    track = jax.jit(lambda s, c, e: tracker_step(s, c, e, tcfg)[0])
    rows.append(
        ("table3/tracking", time_fn(track, st, clusters, mets["shannon_entropy"]),
         "alpha_beta")
    )

    whole = make_process_window(PipelineConfig())
    us = time_fn(whole, b)
    rows.append(("table3/total_window", us, f"{'<62ms' if us < 62000 else '>62ms'}"))
    return rows
