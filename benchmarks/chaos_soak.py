"""Chaos soak: serving latency + correctness gates with faults ACTIVE.

The fault-tolerance layer's pitch (DESIGN.md Sec. 13) is that degraded
mode costs nothing it didn't promise: with the full fault taxonomy
firing — corrupt chunks, silent sensors, overload bursts, attach/detach
churn, injected device-step failures — the service must neither crash
nor slow past the paper's 62 ms deterministic-latency budget, and every
*healthy* sensor's outputs must stay bit-identical to a fault-free run.

This bench runs the seeded :class:`~repro.serve.chaos.ChaosHarness`
(deterministic schedule, fake service clock — wall time is measured
around each faulted round, which includes quarantine flushes, eviction
steps, tier demotions, and retry loops on the serving path).

Methodology matches the serve bench: one cold pass warms every compiled
shape, then N_PASSES passes with GC off, combined by per-round minimum.
The correctness gates are evaluated on the (deterministic) report.

Gates (exit code 1 on failure, BENCH_NO_FAIL=1 to disable):

* zero faults escape ``feed``/``pump`` (no-crash invariant);
* every taxonomy entry actually fired (the soak is not vacuous);
* healthy-sensor outputs bit-identical to the fault-free reference;
* shed accounting exact: offered == accepted + shed;
* per-round p99 <= BUDGET_MS (62 ms paper budget), faults active.

Results land in BENCH_chaos.json at the repo root with the uniform
``bench`` block the ``benchmarks.run`` aggregator consumes.

  PYTHONPATH=src python benchmarks/chaos_soak.py
  N_SENSORS=6 N_ROUNDS=48 BUDGET_MS=62 N_PASSES=3 ...   (CI knobs)
"""
import gc
import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np
from _common import git_commit

from repro.serve.chaos import ChaosConfig, ChaosHarness

N_SENSORS = int(os.environ.get("N_SENSORS", "6"))
N_FAULTY = int(os.environ.get("N_FAULTY", "2"))
N_ROUNDS = int(os.environ.get("N_ROUNDS", "48"))
SEED = int(os.environ.get("SEED", "0"))
BUDGET_MS = float(os.environ.get("BUDGET_MS", "62"))
N_PASSES = int(os.environ.get("N_PASSES", "3"))
REPO_ROOT = Path(__file__).resolve().parent.parent


def main() -> None:
    cfg = ChaosConfig(
        n_sensors=N_SENSORS, n_faulty=N_FAULTY, n_rounds=N_ROUNDS, seed=SEED
    )
    harness = ChaosHarness(cfg)
    print(
        f"backend={jax.default_backend()}  sensors={N_SENSORS} "
        f"({N_FAULTY} faulty)  rounds={N_ROUNDS}  seed={SEED}  "
        f"faults={len(cfg.faults)}  budget={BUDGET_MS} ms"
    )

    t0 = time.perf_counter()
    harness.run()  # cold pass: warms every compiled shape
    cold_s = time.perf_counter() - t0

    gc.collect()
    gc.disable()
    try:
        reports = [harness.run() for _ in range(N_PASSES)]
    finally:
        gc.enable()
    rep = reports[-1]  # the report is deterministic; any pass's will do
    arr = np.minimum.reduce([np.asarray(r.round_times_ms) for r in reports])
    p50, p95, p99 = (float(np.percentile(arr, q)) for q in (50, 95, 99))
    peak = float(arr.max())

    print(
        f"fired: {rep.fired}\n"
        f"quarantines={rep.quarantines}  evictions={rep.evictions}  "
        f"degraded_rounds={rep.degraded_rounds}  "
        f"step_retries={rep.step_retries}  demotions={rep.demotions}"
    )
    print(
        f"shed accounting: offered={rep.shed['offered']:,} = "
        f"accepted {rep.shed['accepted']:,} + shed {rep.shed['shed']:,} "
        f"({'exact' if rep.shed['exact'] else 'INEXACT'})"
    )
    print(f"cold pass (incl. compiles): {cold_s:.2f} s")
    print(
        f"faulted-round latency: p50={p50:.2f} ms  p95={p95:.2f} ms  "
        f"p99={p99:.2f} ms  max={peak:.2f} ms"
    )

    min_fired = min(rep.fired.values())
    gates = [
        {
            "name": "no_fault_escapes_service",
            "value": len(rep.escaped_errors),
            "threshold": 0,
            "op": "<=",
            "pass": not rep.escaped_errors,
        },
        {
            "name": "every_fault_kind_fired",
            "value": min_fired,
            "threshold": 1,
            "op": ">=",
            "pass": min_fired >= 1,
        },
        {
            "name": "healthy_outputs_bit_identical",
            "value": int(rep.bit_identical),
            "threshold": 1,
            "op": ">=",
            "pass": rep.bit_identical,
        },
        {
            "name": "shed_accounting_exact",
            "value": int(rep.shed["exact"]),
            "threshold": 1,
            "op": ">=",
            "pass": bool(rep.shed["exact"]),
        },
        {
            "name": "round_p99_within_budget_with_faults",
            "value": round(p99, 3),
            "threshold": BUDGET_MS,
            "op": "<=",
            "pass": p99 <= BUDGET_MS,
        },
    ]
    for g in gates:
        print(
            f"gate {g['name']}: {g['value']} {g['op']} {g['threshold']} "
            f"({'PASS' if g['pass'] else 'FAIL'})"
        )
    if rep.mismatches:
        print("bit-identity mismatches:")
        for m in rep.mismatches[:10]:
            print(f"  {m}")
    for e in rep.escaped_errors[:10]:
        print(f"escaped: {e}")

    payload = {
        "backend": jax.default_backend(),
        "commit": git_commit(),
        "n_sensors": N_SENSORS,
        "n_faulty": N_FAULTY,
        "n_rounds": N_ROUNDS,
        "seed": SEED,
        "faults": list(cfg.faults),
        "budget_ms": BUDGET_MS,
        "n_passes": N_PASSES,
        "cold_pass_s": round(cold_s, 3),
        "fired": rep.fired,
        "quarantines": rep.quarantines,
        "evictions": rep.evictions,
        "degraded_rounds": rep.degraded_rounds,
        "step_retries": rep.step_retries,
        "demotions": rep.demotions,
        "healthy_windows": rep.healthy_windows,
        "shed": rep.shed,
        "n_error_records": len(rep.errors),
        "latency_ms": {
            "p50": round(p50, 3),
            "p95": round(p95, 3),
            "p99": round(p99, 3),
            "max": round(peak, 3),
        },
        "bench": {
            "name": "chaos_soak",
            "p50_ms": round(p50, 3),
            "p99_ms": round(p99, 3),
            "gates": gates,
        },
    }
    out_path = REPO_ROOT / "BENCH_chaos.json"
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out_path}")

    if os.environ.get("BENCH_NO_FAIL"):
        return
    if not all(g["pass"] for g in gates):
        sys.exit(1)


if __name__ == "__main__":
    main()
