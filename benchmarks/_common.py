"""Shared benchmark utilities."""
from __future__ import annotations

import subprocess
import time
from pathlib import Path
from typing import Callable

import jax

REPO_ROOT = Path(__file__).resolve().parent.parent


def git_commit() -> str:
    """Short hash of HEAD, or "unknown" outside a usable git checkout."""
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=REPO_ROOT, capture_output=True, text=True, check=True,
        ).stdout.strip()
    except Exception:
        return "unknown"


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall time per call in microseconds (blocking on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(rows: list[tuple[str, float, str]]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
