"""Fleet feed latency + aggregate throughput vs sequential pipelines.

The fleet engine's pitch is serving-shaped: N live sensors behind ONE
vmapped/jitted step, so a constellation pays one dispatch per feed round
instead of one per sensor. This benchmark builds a scenario-diverse
N-sensor sky (cycling the rate-balanced family presets, each sensor with
independent pointing jitter), chunks every sensor's stream into fixed
event-time slices (default 20 ms, the live cadence), and replays the
same round sequence two ways:

* **fleet** — one :class:`FleetPipeline` fed all sensors per round; the
  wall time of each ``feed`` call is the whole fleet's per-round latency
  (host windowing for every sensor + one donated-carry vmapped step +
  consuming the round's detections), which is also each sensor's feed
  latency since all sensors' windows close inside that one call.
* **sequential** — N independent :class:`StreamingPipeline` objects fed
  one after another in the same round order: the N-dispatches-per-round
  baseline a naive multi-sensor deployment runs on the same host.

Methodology notes:

* Both replays consume their results the way the quickstarts do — the
  per-feed detection count is read back to host — so the comparison
  covers end-to-end serving cost, not just device residency.
* Both replays run once cold (warming every jit shape: one compile per
  distinct fleet window count), then three steady-state passes with GC
  disabled. Per-round wall times are recorded for BOTH sides and the
  passes are combined by per-round minimum before summing — the classic
  least-noise wall-clock estimator (the same rule the scan bench gates
  on), applied symmetrically. This matters on shared hosts: the
  reference runner exhibits a ~10 Hz external scheduler stall (~20 ms,
  visible as a drifting periodic spike in *both* replays) that a single
  pass sum absorbs ~15-25% of; the stall indices drift between passes,
  so the per-round min converges to the quiet-host sustained rate. The
  raw best-pass sums are reported alongside for transparency.
* The sensor mix cycles the *rate-balanced* scenario families so every
  sensor closes about one window per 20 ms round. A sensor with 10x the
  event rate of its neighbours (e.g. the full ``hot_columns`` stressor)
  pads every other sensor to its window count each feed and the fleet
  loses its dispatch-amortization edge by design; that ragged regime is
  pinned by the bit-identity tests, while this bench measures the
  steady co-observing regime the throughput claim is about.

Gates (exit code 1 on failure, BENCH_NO_FAIL=1 to disable):

* steady-state fleet per-feed p99 <= BUDGET_MS (62 ms paper budget)
* aggregate event throughput >= 3x the sequential baseline
  (BENCH_GATE_SPEEDUP=0 to skip on noisy shared runners)

Results land in BENCH_fleet.json at the repo root with the uniform
``bench`` block (name / p50_ms / p99_ms / gates) the ``benchmarks.run``
aggregator consumes.

  PYTHONPATH=src python benchmarks/fleet_throughput.py
  N_SENSORS=8 DURATION_S=2 CHUNK_US=20000 BUDGET_MS=62 ...  (CI knobs)
"""
import gc
import json
import os
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import numpy as np
from _common import git_commit

from repro.core.pipeline import FleetPipeline, PipelineConfig, StreamingPipeline
from repro.data.evas import iter_chunks
from repro.data.synthetic import SCENARIO_FAMILIES, make_fleet_recordings

N_SENSORS = int(os.environ.get("N_SENSORS", "8"))
DURATION_S = float(os.environ.get("DURATION_S", "3.0"))
CHUNK_US = int(os.environ.get("CHUNK_US", "20000"))
BUDGET_MS = float(os.environ.get("BUDGET_MS", "62"))
N_PASSES = int(os.environ.get("N_PASSES", "5"))
REPO_ROOT = Path(__file__).resolve().parent.parent

# Rate-balanced family subset: comparable events/s per sensor (see
# module docstring for why the 10x-rate stressors sit this one out).
BALANCED_FAMILIES = ("crossing", "geo_slow", "tumbling", "ballistic", "jitter")


def _recordings():
    recs = []
    for s in range(N_SENSORS):
        fam = BALANCED_FAMILIES[s % len(BALANCED_FAMILIES)]
        recs.extend(
            make_fleet_recordings(
                1, scenario=SCENARIO_FAMILIES[fam],
                seed0=101 * s, duration_s=DURATION_S,
            )
        )
    return recs


def _rounds(recs):
    """Per-round chunk tuples: ``rounds[i][s]`` is sensor s's i-th slice
    (or None once that sensor's stream is exhausted)."""
    per_sensor = [list(iter_chunks(r, CHUNK_US)) for r in recs]
    n_rounds = max(len(c) for c in per_sensor)
    return [
        [c[i] if i < len(c) else None for c in per_sensor]
        for i in range(n_rounds)
    ]


def _replay_fleet(rounds, config):
    """One fleet feed per round; (per-feed ms, windows, detections)."""
    fp = FleetPipeline(config, n_sensors=N_SENSORS)
    times, windows, dets = [], 0, 0
    for chunks in rounds:
        t0 = time.perf_counter()
        out = fp.feed(chunks)
        if out.clusters is not None:  # consume: this round's detections
            dets += int(np.asarray(out.clusters.valid).sum())
        jax.block_until_ready((out.metrics, out.tracks))
        times.append((time.perf_counter() - t0) * 1e3)
        windows += out.total_windows
    tail = fp.flush()
    if tail.clusters is not None:
        dets += int(np.asarray(tail.clusters.valid).sum())
    jax.block_until_ready((tail.metrics, tail.tracks))
    windows += tail.total_windows
    return times, windows, dets


def _replay_sequential(rounds, config):
    """N independent single-sensor pipelines, fed back to back in the
    same round order; (per-round ms, windows, detections)."""
    pipes = [StreamingPipeline(config) for _ in range(N_SENSORS)]
    times, windows, dets = [], 0, 0
    for chunks in rounds:
        t0 = time.perf_counter()
        for sp, chunk in zip(pipes, chunks):
            if chunk is None:
                continue
            res = sp.feed(*chunk)
            dets += int(np.asarray(res.clusters.valid).sum())
            jax.block_until_ready((res.metrics, res.tracks))
            windows += res.num_windows
        times.append((time.perf_counter() - t0) * 1e3)
    for sp in pipes:
        res = sp.flush()
        dets += int(np.asarray(res.clusters.valid).sum())
        jax.block_until_ready((res.metrics, res.tracks))
        windows += res.num_windows
    return times, windows, dets


def main() -> None:
    config = PipelineConfig()  # paper defaults: 16px cells, 20 ms / 250 ev
    recs = _recordings()
    rounds = _rounds(recs)
    n_events = sum(len(r) for r in recs)
    print(
        f"backend={jax.default_backend()}  sensors={N_SENSORS}  "
        f"events={n_events:,}  rounds={len(rounds)} x {CHUNK_US / 1e3:.0f} ms  "
        f"budget={BUDGET_MS} ms"
    )
    for r in recs:
        print(f"  {r.name:<24} {len(r):>8,} events")

    # Cold pass: compiles one fleet step per distinct window count.
    t0 = time.perf_counter()
    _, n_windows, n_dets = _replay_fleet(rounds, config)
    cold_s = time.perf_counter() - t0
    _replay_sequential(rounds, config)  # warm the single-sensor shapes

    # Steady-state passes over the identical round sequence, GC off.
    gc.collect()
    gc.disable()
    try:
        fleet_passes = [_replay_fleet(rounds, config)[0] for _ in range(N_PASSES)]
        seq_results = [_replay_sequential(rounds, config) for _ in range(N_PASSES)]
    finally:
        gc.enable()
    # Per-round minimum across passes (symmetric least-noise combiner —
    # see module docstring), plus the raw best single pass.
    arr = np.minimum.reduce([np.asarray(p) for p in fleet_passes])
    seq_arr = np.minimum.reduce([np.asarray(r[0]) for r in seq_results])
    fleet_s = float(arr.sum()) / 1e3
    seq_s = float(seq_arr.sum()) / 1e3
    fleet_best_pass_s = min(sum(p) for p in fleet_passes) / 1e3
    seq_best_pass_s = min(sum(r[0]) for r in seq_results) / 1e3
    _, seq_windows, seq_dets = seq_results[0]

    p50, p95, p99 = (float(np.percentile(arr, q)) for q in (50, 95, 99))
    peak = float(arr.max())
    fleet_evs = n_events / fleet_s
    seq_evs = n_events / seq_s
    speedup = seq_s / fleet_s

    assert seq_windows == n_windows and seq_dets == n_dets, "drivers diverged"
    print(f"windows processed: {n_windows}  detections: {n_dets}")
    print(f"cold pass (incl. compiles): {cold_s:.2f} s")
    print(
        f"steady-state fleet per-feed latency ({N_SENSORS} sensors/feed): "
        f"p50={p50:.2f} ms  p95={p95:.2f} ms  p99={p99:.2f} ms  max={peak:.2f} ms"
    )
    print(
        f"aggregate throughput (per-round min over {N_PASSES} passes): "
        f"fleet {fleet_evs:,.0f} ev/s in {fleet_s:.2f} s vs "
        f"sequential {seq_evs:,.0f} ev/s in {seq_s:.2f} s"
    )
    print(
        f"  (raw best single pass: fleet {fleet_best_pass_s:.2f} s, "
        f"sequential {seq_best_pass_s:.2f} s)"
    )
    gate_p99 = p99 <= BUDGET_MS
    gate_speedup = speedup >= 3.0
    print(
        f"p99 vs paper budget: {p99:.2f} ms <= {BUDGET_MS} ms "
        f"({'PASS' if gate_p99 else 'FAIL'})"
    )
    print(
        f"fleet over sequential: {speedup:.2f}x "
        f"({'PASS' if gate_speedup else 'FAIL'} >= 3x acceptance)"
    )

    payload = {
        "backend": jax.default_backend(),
        "commit": git_commit(),
        "n_sensors": N_SENSORS,
        "duration_s": DURATION_S,
        "chunk_us": CHUNK_US,
        "n_events": n_events,
        "n_rounds": len(rounds),
        "n_windows": n_windows,
        "n_detections": n_dets,
        "budget_ms": BUDGET_MS,
        "cold_pass_s": round(cold_s, 3),
        "latency_ms": {
            "p50": round(p50, 3),
            "p95": round(p95, 3),
            "p99": round(p99, 3),
            "max": round(peak, 3),
        },
        "throughput": {
            "fleet_events_per_sec": round(fleet_evs, 1),
            "sequential_events_per_sec": round(seq_evs, 1),
            "fleet_wall_s": round(fleet_s, 3),
            "sequential_wall_s": round(seq_s, 3),
            "fleet_best_pass_s": round(fleet_best_pass_s, 3),
            "sequential_best_pass_s": round(seq_best_pass_s, 3),
            "n_passes": N_PASSES,
            "speedup": round(speedup, 2),
        },
        "bench": {
            "name": "fleet_throughput",
            "p50_ms": round(p50, 3),
            "p99_ms": round(p99, 3),
            "gates": [
                {
                    "name": "feed_p99_within_budget",
                    "value": round(p99, 3),
                    "threshold": BUDGET_MS,
                    "op": "<=",
                    "pass": gate_p99,
                },
                {
                    "name": "fleet_speedup_over_sequential",
                    "value": round(speedup, 2),
                    "threshold": 3.0,
                    "op": ">=",
                    "pass": gate_speedup,
                },
            ],
        },
    }
    out_path = REPO_ROOT / "BENCH_fleet.json"
    out_path.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"wrote {out_path}")

    if os.environ.get("BENCH_NO_FAIL"):
        return
    gates = [gate_p99]
    if os.environ.get("BENCH_GATE_SPEEDUP", "1") != "0":
        gates.append(gate_speedup)
    if not all(gates):
        sys.exit(1)


if __name__ == "__main__":
    main()
