"""Distribution substrate: sharding rules, collectives, fault tolerance."""
from repro.distributed.sharding import (  # noqa: F401
    ShardingRules,
    TRAIN_RULES,
    SERVE_RULES,
    hint,
    partition_params,
    batch_spec,
)
