"""Parameter/activation sharding rules (DP / FSDP / TP / EP).

Specs are derived per-leaf from the parameter's *name* (right-aligned
against the leaf shape so scan-stacking extra leading dims works
transparently) with divisibility checks against the concrete mesh: a dim
that does not divide by its axis size falls back to replication rather
than failing to lower. This keeps one rule set valid across all 10
architectures (40-head MLA, 12-head VLM, 4-head xLSTM, ...).

Axis semantics:
  dp   — batch data parallelism (('pod','data') on the multi-pod mesh)
  fsdp — weight/optimizer sharding over the data axis (ZeRO-3 style)
  tp   — tensor parallelism over the model axis; also hosts EP (experts)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    dp: tuple[str, ...] = ("data",)
    fsdp: str | None = "data"
    tp: str | None = "model"
    ep: str | None = "model"
    # Pure expert parallelism: shard expert weights ONLY over ep. The
    # default additionally FSDPs the contracting d_model dim, which makes
    # every expert einsum a partial-sum all-reduce of the (E, C, ff)
    # dispatch tensor (EXPERIMENTS.md §Perf HC2).
    moe_ep_only: bool = False


TRAIN_RULES = ShardingRules()
MULTIPOD_TRAIN_RULES = ShardingRules(dp=("pod", "data"))
SERVE_RULES = ShardingRules(fsdp=None)
MULTIPOD_SERVE_RULES = ShardingRules(dp=("pod", "data"), fsdp=None)
# 2D tensor parallelism for tiny-batch serving (long-context decode with
# global_batch=1 leaves the data axis idle — fold it into TP).
SERVE_2D_RULES = ShardingRules(fsdp=None, tp=("model", "data"))
MULTIPOD_SERVE_2D_RULES = ShardingRules(
    dp=("pod",), fsdp=None, tp=("model", "data")
)


# Right-aligned axis-role specs per parameter name. Roles: 'fsdp', 'tp',
# 'ep', None. Names not listed replicate.
_BASE: dict[str, tuple] = {
    # embeddings / heads
    "embed": ("tp", "fsdp"),
    "lm_head": ("fsdp", "tp"),
    # attention
    "wq": ("fsdp", "tp"),
    "wk": ("fsdp", "tp"),
    "wv": ("fsdp", "tp"),
    "wo": ("tp", "fsdp"),
    # MLA
    "w_dq": ("fsdp", "tp"),
    "w_uq": ("fsdp", "tp"),
    "w_dkv": ("fsdp", "tp"),
    "w_uk": ("fsdp", "tp"),
    "w_uv": ("fsdp", "tp"),
    "w_kr": ("fsdp", None),
    # FFN
    "wi_gate": ("fsdp", "tp"),
    "wi_up": ("fsdp", "tp"),
    "w_down": ("tp", "fsdp"),
    # router
    "router": ("fsdp", None),
    # RG-LRU
    "w_gate_branch": ("fsdp", "tp"),
    "w_main": ("fsdp", "tp"),
    "w_input_gate": ("fsdp", "tp"),
    "w_rec_gate": ("fsdp", "tp"),
    "w_out": ("tp", "fsdp"),
    "conv_w": (None, "tp"),
    "conv_b": ("tp",),
    "log_lambda": ("tp",),
    # xLSTM
    "w_up": ("fsdp", "tp"),
    "w_up_gate": ("fsdp", "tp"),
    "w_igate": ("fsdp", None),
    "w_fgate": ("fsdp", None),
    "w_gates": ("fsdp", "tp"),
    "r_gates": (None, None, "tp"),
    "skip_scale": ("tp",),
}

# Names whose leaves live under a 'moe' subtree get an extra leading expert
# dim sharded over ep.
_MOE_BASE: dict[str, tuple] = {
    "wi_gate": ("ep", "fsdp", None),
    "wi_up": ("ep", "fsdp", None),
    "wo": ("ep", None, "fsdp"),
}

_MOE_BASE_EP_ONLY: dict[str, tuple] = {
    "wi_gate": ("ep", None, None),
    "wi_up": ("ep", None, None),
    "wo": ("ep", None, None),
}


def _role_to_axis(role, rules: ShardingRules):
    if role is None:
        return None
    return getattr(rules, role)


def _resolve(roles: tuple, shape: tuple[int, ...], rules: ShardingRules, axis_sizes: dict[str, int]) -> P:
    """Right-align roles against shape; drop non-dividing axes. Axis
    entries may be tuples (multi-axis sharding, e.g. 2D TP for serving)."""
    ndim = len(shape)
    spec: list = [None] * ndim
    for i, role in enumerate(roles):
        dim = ndim - len(roles) + i
        if dim < 0:
            continue
        axis = _role_to_axis(role, rules)
        if axis is None:
            continue
        parts = axis if isinstance(axis, tuple) else (axis,)
        present = tuple(a for a in parts if a in axis_sizes)
        if not present:
            continue
        size = 1
        for a in present:
            size *= axis_sizes[a]
        if shape[dim] % size != 0:
            continue
        spec[dim] = present if len(present) > 1 else present[0]
    return P(*spec)


def partition_params(
    params: Any, rules: ShardingRules, mesh: Mesh | None = None
) -> Any:
    """PartitionSpec tree for a parameter pytree (works on ShapeDtypeStructs)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh is not None else {}

    def leaf_spec(path, leaf):
        names = [
            p.key for p in path if isinstance(p, jax.tree_util.DictKey)
        ]
        name = names[-1] if names else ""
        in_moe = "moe" in names[:-1]
        moe_table = _MOE_BASE_EP_ONLY if rules.moe_ep_only else _MOE_BASE
        table = moe_table if (in_moe and name in moe_table) else _BASE
        roles = table.get(name)
        if roles is None:
            return P()
        return _resolve(roles, leaf.shape, rules, axis_sizes)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


def batch_spec(rules: ShardingRules, extra_dims: int = 1) -> P:
    """Spec for (B, ...) inputs: batch over dp axes, rest replicated."""
    dp = rules.dp if len(rules.dp) > 1 else rules.dp[0]
    return P(dp, *([None] * extra_dims))


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# ---------------------------------------------------------------------------
# Fleet (multi-sensor streaming) carry sharding.
# ---------------------------------------------------------------------------

# The streaming fleet engine stacks per-sensor carries (event atlas,
# tracker state) along a leading sensor dim and drives them through one
# vmapped step. Sensors are embarrassingly parallel — no cross-sensor
# collective anywhere in the step — so the whole carry shards 1:1 over a
# dedicated mesh axis and each device serves S / axis_size sensors.
SENSOR_AXIS = "sensor"


def shard_fleet_carry(tree: Any, mesh: Mesh | None) -> Any:
    """Place a stacked fleet carry pytree on ``mesh``, sensor-sharded.

    Every leaf has the sensor dim leading; leaves whose sensor count does
    not divide the axis (or meshes without a ``sensor`` axis) fall back
    to replication, mirroring :func:`partition_params`' divisibility
    rule. With ``mesh=None`` this is the identity, so the fleet engine
    runs unchanged on a single host.
    """
    if mesh is None or SENSOR_AXIS not in mesh.axis_names:
        return tree
    size = dict(zip(mesh.axis_names, mesh.devices.shape))[SENSOR_AXIS]

    def place(leaf):
        ok = getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] % size == 0
        return jax.device_put(
            leaf, NamedSharding(mesh, P(SENSOR_AXIS) if ok else P())
        )

    return jax.tree.map(place, tree)


def grow_fleet_carry(tree: Any, new_size: int, mesh: Mesh | None) -> Any:
    """Migrate a stacked fleet carry into a larger slot pool.

    Every leaf is zero-padded along the leading sensor dim to
    ``new_size`` (zeroed slots are exactly the fresh-sensor initial
    state) and the grown pytree is re-placed with
    :func:`shard_fleet_carry`, so a capacity-tier promotion keeps the
    carry sharded over the ``sensor`` axis — including the case where
    the old capacity did not divide the axis but the new one does.
    """

    def pad(leaf):
        extra = new_size - leaf.shape[0]
        if extra < 0:
            raise ValueError(
                f"fleet carry has {leaf.shape[0]} slots, cannot shrink to "
                f"{new_size}"
            )
        if extra == 0:
            return leaf
        return jnp.concatenate(
            [leaf, jnp.zeros((extra,) + leaf.shape[1:], leaf.dtype)], axis=0
        )

    return shard_fleet_carry(jax.tree.map(pad, tree), mesh)


def shrink_fleet_carry(tree: Any, new_size: int, mesh: Mesh | None) -> Any:
    """Migrate a stacked fleet carry into a *smaller* slot pool.

    The inverse of :func:`grow_fleet_carry`, for capacity-tier demotion
    after evictions shrink the live set: every leaf keeps its first
    ``new_size`` slots verbatim (the caller guarantees the dropped tail
    slots are free, i.e. already zeroed) and the sliced pytree is
    re-placed with :func:`shard_fleet_carry` so the demoted carry keeps
    sharding over the ``sensor`` axis.
    """
    if new_size < 1:
        raise ValueError(f"need at least one slot, got {new_size}")

    def cut(leaf):
        if leaf.shape[0] < new_size:
            raise ValueError(
                f"fleet carry has {leaf.shape[0]} slots, cannot take "
                f"{new_size}"
            )
        return leaf[:new_size]

    return shard_fleet_carry(jax.tree.map(cut, tree), mesh)


def hint_fleet(tree: Any) -> Any:
    """Sensor-axis sharding hint over every leaf of a stacked fleet pytree
    (identity without an active mesh; see :func:`hint`)."""
    return jax.tree.map(lambda a: hint(a, SENSOR_AXIS), tree)


def hint_wire(packed: jax.Array, valid: jax.Array, offsets: jax.Array):
    """Sensor-axis hints for the ragged-wire decoder surfaces.

    The 1-D wire streams (words/dt/pol/spill) are occupancy-ordered, not
    sensor-partitioned, so they stay replicated; the CSR ``offsets``
    (S, W+1) and the reconstructed dense ``packed`` (4, S, W, cap) /
    ``valid`` (S, W, cap) planes carry the sensor dim and shard over the
    ``sensor`` mesh axis like every other fleet carry leaf — the gather
    that builds them is then partitioned per device's sensor slice.
    Identity without an active mesh, like :func:`hint`.
    """
    return (
        hint(packed, None, SENSOR_AXIS),
        hint(valid, SENSOR_AXIS),
        hint(offsets, SENSOR_AXIS),
    )


# ---------------------------------------------------------------------------
# Activation sharding hints (no-ops without a mesh context).
# ---------------------------------------------------------------------------

def _current_axis_sizes() -> dict[str, int] | None:
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        return None
    if mesh is None or not getattr(mesh, "axis_names", ()):
        return None
    return dict(zip(mesh.axis_names, mesh.axis_sizes))


def hint(x: jax.Array, *axes) -> jax.Array:
    """with_sharding_constraint that degrades to identity when axes are
    absent from the active mesh or do not divide the dim."""
    sizes = _current_axis_sizes()
    if sizes is None:
        return x
    spec: list = []
    for dim, a in enumerate(axes):
        if a is None:
            spec.append(None)
            continue
        parts = a if isinstance(a, tuple) else (a,)
        present = tuple(p for p in parts if p in sizes)
        total = 1
        for p in present:
            total *= sizes[p]
        if present and x.shape[dim] % total == 0:
            spec.append(present if len(present) > 1 else present[0])
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
