"""Fault tolerance and straggler mitigation for long-running jobs.

Components, scoped the way a 1000-node deployment needs them:

* :class:`HeartbeatMonitor` — tracks per-node liveness; a node missing
  ``timeout_s`` of heartbeats is declared failed. In a multi-host run the
  transport is the cluster coordinator; here the transport is injected so
  tests simulate failures deterministically.
* :class:`StragglerTracker` — EMA of per-step wall time with an outlier
  rule (step > factor x EMA = straggler); the runner consults it to
  re-dispatch or exclude nodes.
* :class:`ElasticRunner` — the restart loop: run steps, checkpoint every
  ``ckpt_every``, and on failure rebuild the mesh from surviving devices
  and restore the latest checkpoint onto the NEW mesh (elastic re-shard,
  see ``train.checkpoint``). Training resumes within one checkpoint
  interval of the failure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax


class HeartbeatMonitor:
    """Per-node liveness with an explicit membership roster.

    Membership is explicit — :meth:`register` / :meth:`forget` — and
    :meth:`beat` raises ``KeyError`` for an unregistered id: a typo'd
    node (or sensor) id must surface as an error, not silently create a
    phantom healthy node that the failure detector then vouches for.
    Node ids are any hashable (host names for training jobs, session
    ids for the detection service).
    """

    def __init__(self, node_ids=(), timeout_s: float = 60.0, clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last: dict[Any, float] = {n: now for n in node_ids}

    def __contains__(self, node_id) -> bool:
        return node_id in self._last

    @property
    def nodes(self) -> list:
        """Registered node ids, registration-ordered."""
        return list(self._last)

    def register(self, node_id) -> None:
        """Add a node, its heartbeat stamped now. Re-registering a live
        id raises — two owners of one id is a bookkeeping bug."""
        if node_id in self._last:
            raise ValueError(f"node {node_id!r} is already registered")
        self._last[node_id] = self._clock()

    def forget(self, node_id) -> None:
        """Remove a node from the roster (``KeyError`` if unknown), so a
        departed node stops counting as failed forever."""
        del self._last[node_id]

    def beat(self, node_id) -> None:
        if node_id not in self._last:
            raise KeyError(
                f"heartbeat from unregistered node {node_id!r}; register() it"
            )
        self._last[node_id] = self._clock()

    def last_beat_s(self, node_id) -> float:
        """Clock time of the node's most recent beat (KeyError if unknown)."""
        return self._last[node_id]

    def failed_nodes(self) -> list:
        now = self._clock()
        return [n for n, t in self._last.items() if now - t > self.timeout_s]

    def healthy_nodes(self) -> list:
        now = self._clock()
        return [n for n, t in self._last.items() if now - t <= self.timeout_s]


class StragglerTracker:
    """EMA-based straggler detection over per-node step times."""

    def __init__(self, factor: float = 2.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self._ema: dict[Any, float] = {}

    def record(self, node_id, step_time_s: float) -> None:
        prev = self._ema.get(node_id, step_time_s)
        self._ema[node_id] = (1 - self.alpha) * prev + self.alpha * step_time_s

    def forget(self, node_id) -> None:
        """Drop a node's EMA (no-op if never recorded) so departed nodes
        stop weighing on the fleet median."""
        self._ema.pop(node_id, None)

    def ema(self, node_id) -> float | None:
        return self._ema.get(node_id)

    def fleet_median(self) -> float:
        """True median of the per-node EMAs: for an even count the mean
        of the two middle elements (the upper-middle element alone biases
        high, inflating the straggler threshold)."""
        if not self._ema:
            return 0.0
        vals = sorted(self._ema.values())
        n = len(vals)
        mid = vals[n // 2]
        if n % 2 == 0:
            mid = (vals[n // 2 - 1] + mid) / 2.0
        return mid

    def stragglers(self) -> list:
        med = self.fleet_median()
        if med == 0.0:
            return []
        return [n for n, t in self._ema.items() if t > self.factor * med]


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str  # "node_lost" | "preemption" | "nan_loss"
    detail: str = ""


class ElasticRunner:
    """Checkpoint/restart training loop with elastic mesh rebuilding.

    ``make_state(mesh)`` builds (or restores) sharded train state for a
    mesh; ``step_fn(state, batch) -> state, metrics`` runs one step;
    ``mesh_factory(n_failures)`` returns the (possibly shrunken) mesh
    after each failure. Failures are raised by ``failure_hook`` (tests) or
    detected via non-finite loss.
    """

    def __init__(
        self,
        mesh_factory: Callable[[int], Any],
        make_state: Callable[[Any], Any],
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        ckpt,
        ckpt_every: int = 10,
        failure_hook: Callable[[int], FailureEvent | None] | None = None,
    ):
        self.mesh_factory = mesh_factory
        self.make_state = make_state
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.failure_hook = failure_hook
        self.events: list[FailureEvent] = []
        self.restarts = 0

    def run(self, batches: list[Any], start_step: int = 0) -> tuple[Any, list[dict]]:
        mesh = self.mesh_factory(self.restarts)
        state = self.make_state(mesh)
        latest = self.ckpt.latest_step()
        step = start_step
        if latest is not None:
            step, state = self.ckpt.restore(state)
            step += 1
        metrics_log: list[dict] = []
        i = step
        while i < len(batches):
            if self.failure_hook is not None:
                ev = self.failure_hook(i)
                if ev is not None:
                    # Simulated node loss: rebuild mesh, restore, resume.
                    self.events.append(ev)
                    self.restarts += 1
                    mesh = self.mesh_factory(self.restarts)
                    state = self.make_state(mesh)
                    latest = self.ckpt.latest_step()
                    if latest is not None:
                        resume, state = self.ckpt.restore(state)
                        i = resume + 1
                    else:
                        i = 0
                    continue
            state, metrics = self.step_fn(state, batches[i])
            loss = float(metrics.get("loss", 0.0))
            if loss != loss:  # NaN — restore from last good checkpoint
                self.events.append(FailureEvent(i, "nan_loss"))
                latest = self.ckpt.latest_step()
                if latest is None:
                    raise RuntimeError("NaN loss before first checkpoint")
                resume, state = self.ckpt.restore(state)
                i = resume + 1
                continue
            metrics_log.append(dict(metrics, step=i))
            if i % self.ckpt_every == 0:
                self.ckpt.save_async(i, state)
            i += 1
        self.ckpt.wait()
        return state, metrics_log
