"""Fault tolerance and straggler mitigation for long-running jobs.

Components, scoped the way a 1000-node deployment needs them:

* :class:`HeartbeatMonitor` — tracks per-node liveness; a node missing
  ``timeout_s`` of heartbeats is declared failed. In a multi-host run the
  transport is the cluster coordinator; here the transport is injected so
  tests simulate failures deterministically.
* :class:`StragglerTracker` — EMA of per-step wall time with an outlier
  rule (step > factor x EMA = straggler); the runner consults it to
  re-dispatch or exclude nodes.
* :class:`ElasticRunner` — the restart loop: run steps, checkpoint every
  ``ckpt_every``, and on failure rebuild the mesh from surviving devices
  and restore the latest checkpoint onto the NEW mesh (elastic re-shard,
  see ``train.checkpoint``). Training resumes within one checkpoint
  interval of the failure.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax


class HeartbeatMonitor:
    def __init__(self, node_ids: list[str], timeout_s: float = 60.0, clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        now = clock()
        self._last: dict[str, float] = {n: now for n in node_ids}

    def beat(self, node_id: str) -> None:
        self._last[node_id] = self._clock()

    def failed_nodes(self) -> list[str]:
        now = self._clock()
        return [n for n, t in self._last.items() if now - t > self.timeout_s]

    def healthy_nodes(self) -> list[str]:
        now = self._clock()
        return [n for n, t in self._last.items() if now - t <= self.timeout_s]


class StragglerTracker:
    """EMA-based straggler detection over per-node step times."""

    def __init__(self, factor: float = 2.0, alpha: float = 0.2):
        self.factor = factor
        self.alpha = alpha
        self._ema: dict[str, float] = {}

    def record(self, node_id: str, step_time_s: float) -> None:
        prev = self._ema.get(node_id, step_time_s)
        self._ema[node_id] = (1 - self.alpha) * prev + self.alpha * step_time_s

    def fleet_median(self) -> float:
        if not self._ema:
            return 0.0
        vals = sorted(self._ema.values())
        return vals[len(vals) // 2]

    def stragglers(self) -> list[str]:
        med = self.fleet_median()
        if med == 0.0:
            return []
        return [n for n, t in self._ema.items() if t > self.factor * med]


@dataclasses.dataclass
class FailureEvent:
    step: int
    kind: str  # "node_lost" | "preemption" | "nan_loss"
    detail: str = ""


class ElasticRunner:
    """Checkpoint/restart training loop with elastic mesh rebuilding.

    ``make_state(mesh)`` builds (or restores) sharded train state for a
    mesh; ``step_fn(state, batch) -> state, metrics`` runs one step;
    ``mesh_factory(n_failures)`` returns the (possibly shrunken) mesh
    after each failure. Failures are raised by ``failure_hook`` (tests) or
    detected via non-finite loss.
    """

    def __init__(
        self,
        mesh_factory: Callable[[int], Any],
        make_state: Callable[[Any], Any],
        step_fn: Callable[[Any, Any], tuple[Any, dict]],
        ckpt,
        ckpt_every: int = 10,
        failure_hook: Callable[[int], FailureEvent | None] | None = None,
    ):
        self.mesh_factory = mesh_factory
        self.make_state = make_state
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.failure_hook = failure_hook
        self.events: list[FailureEvent] = []
        self.restarts = 0

    def run(self, batches: list[Any], start_step: int = 0) -> tuple[Any, list[dict]]:
        mesh = self.mesh_factory(self.restarts)
        state = self.make_state(mesh)
        latest = self.ckpt.latest_step()
        step = start_step
        if latest is not None:
            step, state = self.ckpt.restore(state)
            step += 1
        metrics_log: list[dict] = []
        i = step
        while i < len(batches):
            if self.failure_hook is not None:
                ev = self.failure_hook(i)
                if ev is not None:
                    # Simulated node loss: rebuild mesh, restore, resume.
                    self.events.append(ev)
                    self.restarts += 1
                    mesh = self.mesh_factory(self.restarts)
                    state = self.make_state(mesh)
                    latest = self.ckpt.latest_step()
                    if latest is not None:
                        resume, state = self.ckpt.restore(state)
                        i = resume + 1
                    else:
                        i = 0
                    continue
            state, metrics = self.step_fn(state, batches[i])
            loss = float(metrics.get("loss", 0.0))
            if loss != loss:  # NaN — restore from last good checkpoint
                self.events.append(FailureEvent(i, "nan_loss"))
                latest = self.ckpt.latest_step()
                if latest is None:
                    raise RuntimeError("NaN loss before first checkpoint")
                resume, state = self.ckpt.restore(state)
                i = resume + 1
                continue
            metrics_log.append(dict(metrics, step=i))
            if i % self.ckpt_every == 0:
                self.ckpt.save_async(i, state)
            i += 1
        self.ckpt.wait()
        return state, metrics_log
