"""Gradient compression: int8 quantization with error feedback, and an
explicit compressed all-reduce for manual-collective (shard_map) data
parallelism.

Under pjit/auto-SPMD the gradient sync collectives are inserted by the
partitioner at fp32, so quantization alone does not shrink wire bytes.
``compressed_psum_int8`` is the shard_map building block that DOES: it
reduces int8 payloads across the axis (4x fewer link bytes) and corrects
the quantization error locally with an error-feedback buffer, which keeps
SGD convergence (Karimireddy et al. 2019 EF-SGD argument).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8. Returns (q, scale).

    An empty tensor (a zero-size gradient leaf, legal in a pytree)
    quantizes to an empty int8 payload with unit scale — ``jnp.max``
    over zero elements is undefined, so it is never reached.
    """
    if x.size == 0:
        return x.astype(jnp.int8), jnp.ones((), jnp.float32)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_int8_roundtrip(grads: Any, opt_state: dict) -> tuple[Any, dict]:
    """Quantize-dequantize each gradient leaf with error feedback.

    The EF buffer is carried inside opt_state under 'ef'. Returns the
    corrected (compressed-fidelity) gradients and updated state.
    """
    ef = opt_state.get("ef")
    if ef is None:
        ef = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def leaf(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        deq = dequantize_int8(q, s)
        return deq, corrected - deq

    out = jax.tree.map(leaf, grads, ef)
    new_grads = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_grads, dict(opt_state, ef=new_ef)


def compressed_psum_int8(x: jax.Array, axis_name: str) -> jax.Array:
    """All-reduce-mean with int8 payload inside shard_map.

    Wire bytes: int8 tensor + fp32 scale (vs fp32 tensor) => ~4x less.
    """
    q, scale = quantize_int8(x)
    # Per-shard scales must agree before the integer sum: align every
    # shard to the global max scale (one scalar pmax), then psum int8
    # payloads widened to int32 against overflow.
    max_scale = jax.lax.pmax(scale, axis_name)
    rescale = scale / max_scale
    q_aligned = jnp.round(q.astype(jnp.float32) * rescale).astype(jnp.int8)
    q_sum = jax.lax.psum(q_aligned.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    return q_sum.astype(jnp.float32) * max_scale / n


def dp_grad_sync_int8(grads: Any, axis_name: str) -> Any:
    """Apply compressed all-reduce-mean to every gradient leaf."""
    return jax.tree.map(lambda g: compressed_psum_int8(g, axis_name), grads)


def ring_allreduce_int8(x: jax.Array, axis_name: str, axis_size: int) -> jax.Array:
    """Bandwidth-optimal ring all-reduce with quantized wire payloads.

    Classic two-phase ring (reduce-scatter then all-gather) built from
    ``lax.ppermute``; every hop moves 1/N of the tensor as int16 (partial
    sums of int8-quantized values), so wire bytes are
    2 * (N-1)/N * |x| * 2B vs 4B for the fp32 all-reduce XLA would insert
    — a 2x link-bandwidth saving visible in the lowered HLO
    (collective-permute operand dtypes), 4x with per-hop requantization.
    Scales are pre-aligned with one scalar pmax.
    """
    if axis_size == 1:
        return x
    orig_shape = x.shape
    n = x.size
    pad = (-n) % axis_size
    flat = jnp.pad(x.reshape(-1).astype(jnp.float32), (0, pad))
    chunks = flat.reshape(axis_size, -1)

    q, scale = quantize_int8(chunks)
    max_scale = jax.lax.pmax(scale, axis_name)
    q = jnp.round(chunks / max_scale).clip(-127, 127).astype(jnp.int8)

    idx = jax.lax.axis_index(axis_name)
    perm = [(j, (j + 1) % axis_size) for j in range(axis_size)]

    # Phase 1: reduce-scatter. Payloads carry partial sums, which exceed
    # int8 range after accumulation — widen to int16 on the wire (2x
    # smaller than fp32; pure-int8 would need per-hop requantization).
    acc16 = q.astype(jnp.int16)

    def rs_step16(i, carry):
        acc, = carry
        chunk_id = (idx - i) % axis_size
        payload = jnp.take(acc, chunk_id, axis=0)
        recv = jax.lax.ppermute(payload, axis_name, perm)
        recv_id = (idx - i - 1) % axis_size
        acc = acc.at[recv_id].set(acc[recv_id] + recv)
        return (acc,)

    (acc16,) = jax.lax.fori_loop(0, axis_size - 1, rs_step16, (acc16,))

    # Phase 2: all-gather the owned (fully reduced) chunks, int16 payloads.
    owned_id = (idx + 1) % axis_size
    gathered = jnp.zeros_like(acc16)
    own = jnp.take(acc16, owned_id, axis=0)
    gathered = gathered.at[owned_id].set(own)

    def ag_step(i, carry):
        gathered, payload, pid = carry
        recv = jax.lax.ppermute(payload, axis_name, perm)
        new_pid = (pid - 1) % axis_size
        gathered = gathered.at[new_pid].set(recv)
        return gathered, recv, new_pid

    gathered, _, _ = jax.lax.fori_loop(
        0, axis_size - 1, ag_step, (gathered, own, owned_id)
    )
    out = gathered.astype(jnp.float32) * max_scale / axis_size
    return out.reshape(-1)[: n].reshape(orig_shape)
