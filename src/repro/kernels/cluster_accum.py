"""Fused quantize + cluster-accumulate Pallas kernel (beyond-paper).

The paper's Discussion (Sec. VI) proposes offloading aggregation and
centroid calculation to the FPGA fabric to cut total latency below 30 ms.
This kernel realizes that fusion on TPU: one pass over the event stream
produces, per grid cell, the event count and the coordinate/time sums the
centroid calculation needs — the client-side stage collapses to one
division.

TPU mapping: per event tile we build a one-hot cell-assignment matrix and
accumulate the four statistics with a single (4, TILE) @ (TILE, CELLS)
matmul — scatter-add re-expressed as MXU work, which is the TPU-idiomatic
replacement for the FPGA's BRAM read-modify-write loop (DESIGN.md Sec. 2).

Accumulators live in the output VMEM block across grid steps (constant
index_map), initialized at step 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EVENT_TILE = 256  # events per grid step
LANE = 128


def _kernel(x_ref, y_ref, t_ref, valid_ref, out_ref, *, cell_size: int, grid_w: int, n_cells_padded: int, width: int, height: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    x = x_ref[...].astype(jnp.int32)  # (1, TILE)
    y = y_ref[...].astype(jnp.int32)
    t = t_ref[...].astype(jnp.float32)
    v = valid_ref[...].astype(jnp.float32)
    # Sensor-bounds mask mirrors core.grid_clustering.cell_histogram:
    # out-of-range events are dropped, never wrapped into another cell.
    inb = (x >= 0) & (x < width) & (y >= 0) & (y < height)
    v = v * inb.astype(jnp.float32)

    if cell_size & (cell_size - 1) == 0:
        shift = cell_size.bit_length() - 1
        cx = x >> shift
        cy = y >> shift
    else:
        cx = x // cell_size
        cy = y // cell_size
    flat = cy * grid_w + cx  # (1, TILE)
    flat = jnp.clip(flat, 0, n_cells_padded - 1)

    # One-hot (TILE, CELLS) via iota comparison; masked by validity.
    cells_iota = jax.lax.broadcasted_iota(jnp.int32, (EVENT_TILE, n_cells_padded), 1)
    onehot = (flat.reshape(EVENT_TILE, 1) == cells_iota).astype(jnp.float32)
    onehot = onehot * v.reshape(EVENT_TILE, 1)

    # Stats stacked: rows = [count, sum_x, sum_y, sum_t] -> (4, TILE).
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    stats = jnp.concatenate(
        [jnp.ones_like(xf), xf * v, yf * v, t * v], axis=0
    )  # (4, TILE); count row masked via onehot already
    acc = jnp.dot(stats, onehot, preferred_element_type=jnp.float32)  # (4, CELLS)
    out_ref[...] += acc


def cluster_accum(
    x: jax.Array,
    y: jax.Array,
    t: jax.Array,
    valid: jax.Array,
    *,
    cell_size: int,
    grid_w: int,
    grid_h: int,
    width: int | None = None,
    height: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Fused histogram/centroid accumulation over an event batch.

    Inputs are (N,) arrays with N a multiple of EVENT_TILE (ops.py pads).
    Returns (count int32, sum_x, sum_y, sum_t float32), each (grid_w*grid_h,).
    ``width``/``height`` bound the valid sensor area (default: the full
    grid extent), matching the core path's out-of-range masking.
    """
    n = x.shape[0]
    if n % EVENT_TILE:
        raise ValueError(f"N ({n}) must be a multiple of {EVENT_TILE}")
    width = grid_w * cell_size if width is None else width
    height = grid_h * cell_size if height is None else height
    n_cells = grid_w * grid_h
    n_cells_padded = -(-n_cells // LANE) * LANE
    grid = (n // EVENT_TILE,)

    def reshape_in(a, dtype):
        return a.astype(dtype).reshape(1, n)

    out = pl.pallas_call(
        lambda xr, yr, tr, vr, o: _kernel(
            xr, yr, tr, vr, o,
            cell_size=cell_size, grid_w=grid_w, n_cells_padded=n_cells_padded,
            width=width, height=height,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, EVENT_TILE), lambda i: (0, i)),
            pl.BlockSpec((1, EVENT_TILE), lambda i: (0, i)),
            pl.BlockSpec((1, EVENT_TILE), lambda i: (0, i)),
            pl.BlockSpec((1, EVENT_TILE), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((4, n_cells_padded), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((4, n_cells_padded), jnp.float32),
        interpret=interpret,
    )(
        reshape_in(x, jnp.int32),
        reshape_in(y, jnp.int32),
        reshape_in(t, jnp.float32),
        reshape_in(valid, jnp.float32),
    )
    count = out[0, :n_cells].astype(jnp.int32)
    return count, out[1, :n_cells], out[2, :n_cells], out[3, :n_cells]
