"""Pure-jnp oracles for every Pallas kernel (the correctness references).

Each function mirrors the corresponding kernel's contract exactly; tests
sweep shapes/dtypes and assert allclose between kernel (interpret=True on
CPU) and these references.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def grid_quantize_packed_ref(words: jax.Array, cell_size: int = 16) -> jax.Array:
    """Oracle for kernels.grid_quantize.grid_quantize_packed."""
    w = words.astype(jnp.uint32)
    x = w & jnp.uint32(0xFFFF)
    y = w >> jnp.uint32(16)
    cx = x // jnp.uint32(cell_size)
    cy = y // jnp.uint32(cell_size)
    return (cy << jnp.uint32(16)) | cx


def event_unpack_ref(words: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Oracle for kernels.event_unpack.event_unpack (any shape)."""
    w = words.astype(jnp.uint32)
    x = (w & jnp.uint32(0xFFFF)).astype(jnp.int32)
    y = (w >> jnp.uint32(16)).astype(jnp.int32)
    return x, y


def cluster_accum_ref(
    x: jax.Array,
    y: jax.Array,
    t: jax.Array,
    valid: jax.Array,
    *,
    cell_size: int,
    grid_w: int,
    grid_h: int,
    width: int | None = None,
    height: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Oracle for kernels.cluster_accum.cluster_accum."""
    width = grid_w * cell_size if width is None else width
    height = grid_h * cell_size if height is None else height
    n_cells = grid_w * grid_h
    xi = x.astype(jnp.int32)
    yi = y.astype(jnp.int32)
    cx = xi // cell_size
    cy = yi // cell_size
    flat = jnp.clip(cy * grid_w + cx, 0, n_cells - 1)
    inb = (xi >= 0) & (xi < width) & (yi >= 0) & (yi < height)
    valid = valid & inb
    v = valid.astype(jnp.float32)
    vi = valid.astype(jnp.int32)
    count = jnp.zeros((n_cells,), jnp.int32).at[flat].add(vi)
    sum_x = jnp.zeros((n_cells,), jnp.float32).at[flat].add(v * x.astype(jnp.float32))
    sum_y = jnp.zeros((n_cells,), jnp.float32).at[flat].add(v * y.astype(jnp.float32))
    sum_t = jnp.zeros((n_cells,), jnp.float32).at[flat].add(v * t.astype(jnp.float32))
    return count, sum_x, sum_y, sum_t


def window_pipeline_ref(stacked, config):
    """Oracle for kernels.window_pipeline: the staged fixed-point path
    scanned over the window axis.

    ``stacked`` is an EventBatch with (W, E) leaves; returns
    ``(FixedClusters, metrics)`` with (W, K) leaves — the identical
    contract as ``ops.window_pipeline_call``, via one jnp stage at a
    time instead of the fused kernel.
    """
    from repro.core.fixed_point import fixed_window_stage

    def step(carry, batch):
        fc, mets = fixed_window_stage(config, batch)
        return carry, (fc, mets)

    _, (fc, mets) = jax.lax.scan(step, 0, stacked)
    return fc, mets


def window_entropy_ref(
    frame: jax.Array,
    cx: jax.Array,
    cy: jax.Array,
    *,
    window: int = 48,
    bins: int = 32,
) -> jax.Array:
    """Oracle for kernels.window_entropy.window_entropy. Returns (3, K)."""
    h, w = frame.shape

    def one(cx_i, cy_i):
        x0 = jnp.clip(cx_i - window // 2, 0, w - window)
        y0 = jnp.clip(cy_i - window // 2, 0, h - window)
        patch = jax.lax.dynamic_slice(frame, (y0, x0), (window, window))
        flat = patch.reshape(-1)
        idx = jnp.clip((flat * bins).astype(jnp.int32), 0, bins - 1)
        counts = jnp.zeros((bins,), jnp.float32).at[idx].add(1.0)
        p = counts / jnp.maximum(counts.sum(), 1.0)
        shannon = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-12)), 0.0))
        renyi = -jnp.log2(jnp.maximum(jnp.sum(p * p), 1e-12))
        contrast = jnp.std(flat)
        return jnp.stack([shannon, renyi, contrast])

    return jax.vmap(one)(cx.astype(jnp.int32), cy.astype(jnp.int32)).T
