"""Pallas TPU kernel for the event-wire word unpack (DESIGN.md Sec. 16).

The inverse of the paper's Sec. IV-B packing stage, run device-side on
the compressed ragged ingest wire: each 32-bit word carries
``x = bits[15:0]`` and ``y = bits[31:16]``; the kernel splits a VMEM
tile of words into two int32 coordinate planes with one shift and one
mask per lane. Mirrors :mod:`repro.kernels.grid_quantize`'s layout —
8x128 VPU tiles of packed words — and like every kernel here it runs
compiled on TPU and interpreted elsewhere (``ops.py`` picks).

Zero-extension contract: lane values land in [0, 0xFFFF], so the int32
planes are exactly the values :func:`repro.core.events.unpack_words`
produces — the decoder's bit-identity rests on the two routes agreeing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-native tile: 8 sublanes x 128 lanes of 32-bit words.
BLOCK_ROWS = 8
BLOCK_COLS = 128


def _kernel(words_ref, x_ref, y_ref):
    w = words_ref[...].astype(jnp.uint32)
    x_ref[...] = (w & jnp.uint32(0xFFFF)).astype(jnp.int32)
    y_ref[...] = (w >> jnp.uint32(16)).astype(jnp.int32)


def event_unpack(
    words: jax.Array, *, interpret: bool = False
) -> tuple[jax.Array, jax.Array]:
    """Unpack a 2D array of packed 32-bit event words into (x, y) planes.

    ``words``: (R, 128) uint32 with R a multiple of 8 (``ops.py`` pads
    arbitrary 1-D wire streams into this layout). Returns two int32
    arrays of the same shape.
    """
    if words.ndim != 2 or words.shape[1] != BLOCK_COLS:
        raise ValueError(f"expected (R, {BLOCK_COLS}) layout, got {words.shape}")
    rows = words.shape[0]
    if rows % BLOCK_ROWS:
        raise ValueError(f"rows ({rows}) must be a multiple of {BLOCK_ROWS}")
    grid = (rows // BLOCK_ROWS,)
    spec = pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec],
        out_specs=(spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct(words.shape, jnp.int32),
            jax.ShapeDtypeStruct(words.shape, jnp.int32),
        ),
        interpret=interpret,
    )(words)
