"""Public jit'd wrappers around the Pallas kernels.

Handles stream padding/layout so callers pass natural 1-D event arrays,
and selects interpret mode automatically: compiled on TPU, interpreted
(kernel body executed in Python by the Pallas interpreter) on CPU so the
same code path is testable everywhere.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import cluster_accum as _ca
from repro.kernels import event_unpack as _eu
from repro.kernels import grid_quantize as _gq
from repro.kernels import patch_metrics as _pm
from repro.kernels import window_entropy as _we
from repro.kernels import window_pipeline as _wp


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(a: jax.Array, n: int, fill=0) -> jax.Array:
    pad = n - a.shape[0]
    if pad == 0:
        return a
    return jnp.concatenate([a, jnp.full((pad,), fill, a.dtype)])


@partial(jax.jit, static_argnames=("cell_size", "interpret"))
def grid_quantize_packed(
    words: jax.Array, cell_size: int = 16, interpret: bool | None = None
) -> jax.Array:
    """Quantize a 1-D stream of packed 32-bit event words (paper IP core).

    Pads to the kernel's (8, 128) tile, runs the Pallas kernel, and returns
    the first N packed cell words.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n = words.shape[0]
    tile = _gq.BLOCK_ROWS * _gq.BLOCK_COLS
    n_pad = -(-n // tile) * tile
    padded = _pad_to(words.astype(jnp.uint32), n_pad)
    out = _gq.grid_quantize_packed(
        padded.reshape(-1, _gq.BLOCK_COLS), cell_size, interpret=interpret
    )
    return out.reshape(-1)[:n]


def event_unpack_call(
    words: jax.Array, interpret: bool | None = None
) -> tuple[jax.Array, jax.Array]:
    """Trace-time event-word unpack for the ragged ingest decoder.

    Takes a 1-D uint32 wire stream of any length, pads to the kernel's
    (8, 128) tile, and returns the first N (x, y) int32 coordinates —
    the same values :func:`repro.core.events.unpack_words` yields. No
    jit wrapper: every shape is static at trace time, so this is safe
    inside the enclosing wire-decoder jit without nesting a dispatch
    boundary.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n = words.shape[0]
    tile = _eu.BLOCK_ROWS * _eu.BLOCK_COLS
    n_pad = -(-n // tile) * tile
    padded = _pad_to(words.astype(jnp.uint32), n_pad)
    x, y = _eu.event_unpack(
        padded.reshape(-1, _eu.BLOCK_COLS), interpret=interpret
    )
    return x.reshape(-1)[:n], y.reshape(-1)[:n]


def cluster_accum_call(
    x: jax.Array,
    y: jax.Array,
    t: jax.Array,
    valid: jax.Array,
    *,
    cell_size: int,
    grid_w: int,
    grid_h: int,
    width: int | None = None,
    height: int | None = None,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Trace-time fused quantize + per-cell count/centroid accumulation.

    No jit wrapper: all shapes (event count, pad amount, grid) are static
    at trace time, so this is safe to call inside an enclosing ``jax.jit``
    or a ``lax.scan`` body (the scanned pipeline path) without nesting a
    dispatch boundary per window.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n = x.shape[0]
    n_pad = -(-n // _ca.EVENT_TILE) * _ca.EVENT_TILE
    return _ca.cluster_accum(
        _pad_to(x.astype(jnp.int32), n_pad),
        _pad_to(y.astype(jnp.int32), n_pad),
        _pad_to(t.astype(jnp.float32), n_pad),
        _pad_to(valid.astype(jnp.float32), n_pad),
        cell_size=cell_size,
        grid_w=grid_w,
        grid_h=grid_h,
        width=width,
        height=height,
        interpret=interpret,
    )


cluster_accum = jax.jit(
    cluster_accum_call,
    static_argnames=("cell_size", "grid_w", "grid_h", "width", "height", "interpret"),
)
cluster_accum.__doc__ = (
    "Jit'd entry point for host callers; see :func:`cluster_accum_call`."
)


def patch_metrics_call(
    batch,
    clusters,
    *,
    width: int = 640,
    height: int = 480,
    window: int | None = None,
    bins: int | None = None,
    interpret: bool | None = None,
) -> dict:
    """Trace-time fused event->patch scatter + six cluster metrics.

    Event-space preprocessing (coincidence counts, leaders, the frame
    normalizer, patch origins) runs as jnp ops that fuse into the caller's
    jit; the per-cluster patch accumulation, histogram, Sobel, and metric
    math run in the Pallas kernel. Like :func:`cluster_accum_call` this is
    safe inside an enclosing jit or scan body. Returns the metric dict
    keyed by ``repro.core.metrics.METRIC_NAMES``.
    """
    from repro.core import metrics as M

    interpret = _default_interpret() if interpret is None else interpret
    window = M.WINDOW if window is None else window
    bins = M.HIST_BINS if bins is None else bins
    c, leader, w, norm = M.event_normalizer(batch, width, height)
    x0, y0 = M.window_origin(
        clusters.centroid_x, clusters.centroid_y, width, height, window
    )
    e = batch.x.shape[0]
    n_pad = -(-e // _pm.LANE) * _pm.LANE
    out = _pm.patch_metrics(
        _pad_to(batch.x.astype(jnp.int32), n_pad),
        _pad_to(batch.y.astype(jnp.int32), n_pad),
        _pad_to(w.astype(jnp.float32), n_pad),
        _pad_to(c.astype(jnp.float32), n_pad),
        _pad_to(leader.astype(jnp.float32), n_pad),
        x0,
        y0,
        clusters.count,
        clusters.valid,
        norm,
        window=window,
        bins=bins,
        interpret=interpret,
    )
    return {name: out[:, i] for i, name in enumerate(M.METRIC_NAMES)}


def window_pipeline_call(
    stacked,
    config,
    *,
    window: int | None = None,
    bins: int | None = None,
    interpret: bool | None = None,
):
    """Trace-time fused per-window fixed-point pipeline (the megakernel).

    ``stacked`` is an EventBatch with (W, E) leaves (a window batch, as
    produced by ``pad_windows``); ``config`` a PipelineConfig. ONE kernel
    launch covers conditioning, clustering, and metrics for every window
    in the batch — versus two interpret-mode launches *per window* on the
    staged kernel path (``use_kernels`` + ``metrics_impl="kernel"``).
    The kernel covers the integer datapath; the float metric epilogue is
    the SAME vmapped ``fixed_point.fixed_metric_epilogue`` the staged
    path runs, applied here to the kernel's integer surfaces — that
    shared final stage is what makes fused-vs-staged bit-identity
    structural. Like the other ``*_call`` entry points this is safe
    inside an enclosing jit. Returns ``(FixedClusters, metrics)`` with
    (W, K) leaves; metrics keyed by ``repro.core.metrics.METRIC_NAMES``.
    """
    from functools import partial as _partial

    from repro.core import metrics as M
    from repro.core.fixed_point import FixedClusters, fixed_metric_epilogue

    interpret = _default_interpret() if interpret is None else interpret
    window = M.WINDOW if window is None else window
    bins = M.HIST_BINS if bins is None else bins
    e = stacked.x.shape[-1]
    e_pad = -(-e // _wp.LANE) * _wp.LANE

    def pad_ev(a, fill=0):
        if e_pad == e:
            return a
        pad_width = [(0, 0)] * (a.ndim - 1) + [(0, e_pad - e)]
        return jnp.pad(a, pad_width, constant_values=fill)

    grid = config.grid
    k = grid.max_clusters
    cl, surf = _wp.window_pipeline(
        pad_ev(stacked.x.astype(jnp.int32)),
        pad_ev(stacked.y.astype(jnp.int32)),
        pad_ev(stacked.t.astype(jnp.int32)),
        pad_ev(stacked.valid.astype(jnp.int32)),
        roi=tuple(config.roi),
        hot_pixel_max=config.hot_pixel_max,
        cell_size=grid.cell_size,
        grid_w=grid.grid_w,
        grid_h=grid.grid_h,
        min_events=grid.min_events,
        k=k,
        width=grid.width,
        height=grid.height,
        window=window,
        bins=bins,
        interpret=interpret,
    )
    rows = {f: cl[..., r, :k] for r, f in enumerate(_wp.CL_FIELDS)}
    fc = FixedClusters(
        cq_x=rows["cq_x"], cq_y=rows["cq_y"], cq_t=rows["cq_t"],
        count=rows["count"], cell_x=rows["cell_x"], cell_y=rows["cell_y"],
        x0=rows["x0"], y0=rows["y0"], valid=rows["valid"] != 0,
    )
    norm = rows["norm"][..., :1]  # (W, 1); every lane carries the value
    hist = surf[..., :bins]
    s1, s2, s_g, s_e2, edges = (
        surf[..., bins + i] for i in range(len(_wp.SURF_FIELDS))
    )
    epi = jax.vmap(_partial(fixed_metric_epilogue, n=window * window))
    for _ in range(stacked.x.ndim - 1):
        epi = jax.vmap(epi)
    mets = epi(
        hist, s1, s2, s_g, s_e2, edges, fc.count, fc.valid,
        jnp.broadcast_to(norm, fc.count.shape),
    )
    return fc, mets


@partial(jax.jit, static_argnames=("window", "bins", "interpret"))
def window_entropy(
    frame: jax.Array,
    cx: jax.Array,
    cy: jax.Array,
    *,
    window: int = 48,
    bins: int = 32,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-cluster (3, K) [shannon, renyi, contrast] window metrics."""
    interpret = _default_interpret() if interpret is None else interpret
    return _we.window_entropy(
        frame, cx, cy, window=window, bins=bins, interpret=interpret
    )
