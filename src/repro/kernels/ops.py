"""Public jit'd wrappers around the Pallas kernels.

Handles stream padding/layout so callers pass natural 1-D event arrays,
and selects interpret mode automatically: compiled on TPU, interpreted
(kernel body executed in Python by the Pallas interpreter) on CPU so the
same code path is testable everywhere.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import cluster_accum as _ca
from repro.kernels import grid_quantize as _gq
from repro.kernels import window_entropy as _we


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(a: jax.Array, n: int, fill=0) -> jax.Array:
    pad = n - a.shape[0]
    if pad == 0:
        return a
    return jnp.concatenate([a, jnp.full((pad,), fill, a.dtype)])


@partial(jax.jit, static_argnames=("cell_size", "interpret"))
def grid_quantize_packed(
    words: jax.Array, cell_size: int = 16, interpret: bool | None = None
) -> jax.Array:
    """Quantize a 1-D stream of packed 32-bit event words (paper IP core).

    Pads to the kernel's (8, 128) tile, runs the Pallas kernel, and returns
    the first N packed cell words.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n = words.shape[0]
    tile = _gq.BLOCK_ROWS * _gq.BLOCK_COLS
    n_pad = -(-n // tile) * tile
    padded = _pad_to(words.astype(jnp.uint32), n_pad)
    out = _gq.grid_quantize_packed(
        padded.reshape(-1, _gq.BLOCK_COLS), cell_size, interpret=interpret
    )
    return out.reshape(-1)[:n]


def cluster_accum_call(
    x: jax.Array,
    y: jax.Array,
    t: jax.Array,
    valid: jax.Array,
    *,
    cell_size: int,
    grid_w: int,
    grid_h: int,
    interpret: bool | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Trace-time fused quantize + per-cell count/centroid accumulation.

    No jit wrapper: all shapes (event count, pad amount, grid) are static
    at trace time, so this is safe to call inside an enclosing ``jax.jit``
    or a ``lax.scan`` body (the scanned pipeline path) without nesting a
    dispatch boundary per window.
    """
    interpret = _default_interpret() if interpret is None else interpret
    n = x.shape[0]
    n_pad = -(-n // _ca.EVENT_TILE) * _ca.EVENT_TILE
    return _ca.cluster_accum(
        _pad_to(x.astype(jnp.int32), n_pad),
        _pad_to(y.astype(jnp.int32), n_pad),
        _pad_to(t.astype(jnp.float32), n_pad),
        _pad_to(valid.astype(jnp.float32), n_pad),
        cell_size=cell_size,
        grid_w=grid_w,
        grid_h=grid_h,
        interpret=interpret,
    )


cluster_accum = jax.jit(
    cluster_accum_call,
    static_argnames=("cell_size", "grid_w", "grid_h", "interpret"),
)
cluster_accum.__doc__ = (
    "Jit'd entry point for host callers; see :func:`cluster_accum_call`."
)


@partial(jax.jit, static_argnames=("window", "bins", "interpret"))
def window_entropy(
    frame: jax.Array,
    cx: jax.Array,
    cy: jax.Array,
    *,
    window: int = 48,
    bins: int = 32,
    interpret: bool | None = None,
) -> jax.Array:
    """Per-cluster (3, K) [shannon, renyi, contrast] window metrics."""
    interpret = _default_interpret() if interpret is None else interpret
    return _we.window_entropy(
        frame, cx, cy, window=window, bins=bins, interpret=interpret
    )
