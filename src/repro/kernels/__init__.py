"""Pallas TPU kernels for the paper's compute hot-spots.

- grid_quantize: the FPGA IP core (spatial quantization), VPU tiles.
- cluster_accum: beyond-paper fused quantize+aggregate (paper Sec. VI).
- window_entropy: per-cluster metric windows, frame VMEM-resident.

ops.py holds jit'd public wrappers; ref.py the pure-jnp oracles.
"""
