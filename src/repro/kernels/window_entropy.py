"""Pallas kernel for per-cluster window entropy metrics (paper Sec. III-E).

For each detected cluster the paper computes intensity-histogram statistics
over a 48x48 window of the reconstructed frame. With hundreds of clusters
per second this is the metric hot-spot; here one kernel invocation scans
all K cluster windows with the frame resident in VMEM (a 640x480 f32 frame
is 1.2 MB — comfortably VMEM-resident), computing:

  row 0: Shannon entropy  H  = -sum p log2 p
  row 1: Renyi entropy    H2 = -log2 sum p^2
  row 2: local contrast   std(window)

Histogramming is one-hot bin assignment followed by a reduction — the same
MXU-friendly scatter-as-matmul trick as ``cluster_accum``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

WINDOW = 48
HIST_BINS = 32


def _kernel(cx_ref, cy_ref, frame_ref, out_ref, *, window: int, bins: int):
    k = pl.program_id(0)
    cx = cx_ref[0, k]
    cy = cy_ref[0, k]
    h, w = frame_ref.shape
    x0 = jnp.clip(cx - window // 2, 0, w - window)
    y0 = jnp.clip(cy - window // 2, 0, h - window)
    patch = jax.lax.dynamic_slice(frame_ref[...], (y0, x0), (window, window))

    flat = patch.reshape(1, window * window)
    idx = jnp.clip((flat * bins).astype(jnp.int32), 0, bins - 1)
    iota = jax.lax.broadcasted_iota(jnp.int32, (window * window, bins), 1)
    onehot = (idx.reshape(window * window, 1) == iota).astype(jnp.float32)
    counts = jnp.sum(onehot, axis=0)  # (bins,)
    p = counts / jnp.maximum(jnp.sum(counts), 1.0)
    shannon = -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-12)), 0.0))
    renyi = -jnp.log2(jnp.maximum(jnp.sum(p * p), 1e-12))
    contrast = jnp.std(flat)
    out_ref[0, 0] = shannon
    out_ref[1, 0] = renyi
    out_ref[2, 0] = contrast


def window_entropy(
    frame: jax.Array,
    cx: jax.Array,
    cy: jax.Array,
    *,
    window: int = WINDOW,
    bins: int = HIST_BINS,
    interpret: bool = False,
) -> jax.Array:
    """Compute (3, K) [shannon, renyi, contrast] for K cluster windows.

    ``frame``: (H, W) float32 in [0, 1]; ``cx``/``cy``: (K,) int32 centers.
    """
    k = cx.shape[0]
    h, w = frame.shape
    return pl.pallas_call(
        lambda cxr, cyr, fr, o: _kernel(cxr, cyr, fr, o, window=window, bins=bins),
        grid=(k,),
        in_specs=[
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),
            pl.BlockSpec((h, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((3, 1), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((3, k), jnp.float32),
        interpret=interpret,
    )(
        cx.astype(jnp.int32).reshape(1, k),
        cy.astype(jnp.int32).reshape(1, k),
        frame.astype(jnp.float32),
    )
