"""Pallas TPU kernel for the paper's grid-quantization IP core (Fig. 4).

The FPGA core is a 3-stage II=1 stream pipeline at 200 MHz: unpack a
32-bit AXI word (x = bits 15:0, y = bits 31:16), divide both coordinates
by ``cell_size``, repack. TPU adaptation (DESIGN.md Sec. 2): the stream
becomes VMEM tiles of packed words processed 8x128 lanes at a time on the
VPU; the DSP48 division becomes a logical shift for power-of-two cell
sizes (the shipped configuration: 16) and an integer division otherwise.

Wire format is bit-identical to the paper's.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-native tile: 8 sublanes x 128 lanes of 32-bit words.
BLOCK_ROWS = 8
BLOCK_COLS = 128


def _quantize_block(words: jax.Array, cell_size: int) -> jax.Array:
    w = words.astype(jnp.uint32)
    x = w & jnp.uint32(0xFFFF)
    y = w >> jnp.uint32(16)
    if cell_size & (cell_size - 1) == 0:
        shift = jnp.uint32(cell_size.bit_length() - 1)
        cx = x >> shift
        cy = y >> shift
    else:
        cx = (x // jnp.uint32(cell_size)).astype(jnp.uint32)
        cy = (y // jnp.uint32(cell_size)).astype(jnp.uint32)
    return (cy << jnp.uint32(16)) | cx


def _kernel(words_ref, out_ref, *, cell_size: int):
    out_ref[...] = _quantize_block(words_ref[...], cell_size)


def grid_quantize_packed(
    words: jax.Array,
    cell_size: int = 16,
    *,
    interpret: bool = False,
) -> jax.Array:
    """Quantize a 2D array of packed 32-bit event words.

    ``words``: (R, 128) uint32 with R a multiple of 8 (``ops.py`` pads
    arbitrary 1-D streams into this layout). Returns packed cell words of
    the same shape/dtype.
    """
    if words.ndim != 2 or words.shape[1] != BLOCK_COLS:
        raise ValueError(f"expected (R, {BLOCK_COLS}) layout, got {words.shape}")
    rows = words.shape[0]
    if rows % BLOCK_ROWS:
        raise ValueError(f"rows ({rows}) must be a multiple of {BLOCK_ROWS}")
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        lambda w, o: _kernel(w, o, cell_size=cell_size),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, BLOCK_COLS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(words.shape, jnp.uint32),
        interpret=interpret,
    )(words)
