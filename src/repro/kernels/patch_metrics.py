"""Fused event->patch + six-metric Pallas kernel (beyond-paper).

The paper's Discussion (Sec. VI) proposes pushing aggregation *and* the
quality metrics into the fabric so the client only receives final
statistics. This kernel realizes that for the metrics stage, the way
``cluster_accum`` does for clustering (DESIGN.md Sec. 6): one program per
cluster slot scatters the window's events into the cluster's 48x48
centroid-relative count patch (one-hot compare + MXU matmul — the TPU
idiom for the FPGA's BRAM scatter), builds the intensity histogram from
per-event coincidence counts, runs the Sobel stencil, and emits all six
quality metrics. No sensor-sized buffer exists anywhere: VMEM holds the
event tile and one patch.

The metric math is the shared exactly-replayable core
(``repro.core.metrics._exact_cluster_metrics``), so kernel outputs match
the jnp event-space path to float precision (interpret mode is exercised
in CI; on TPU the one-hot matmuls land on the MXU).

Inputs are per-event arrays padded to a lane multiple plus per-cluster
patch origins; ``ops.patch_metrics_call`` handles layout and the
event-space preprocessing (coincidence counts, leaders, normalizer).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import metrics as M

LANE = 128
N_METRICS = len(M.METRIC_NAMES)


def _kernel(
    x_ref, y_ref, w_ref, c_ref, lead_ref, x0_ref, y0_ref, count_ref,
    cvalid_ref, norm_ref, out_ref, *, window: int, bins: int
):
    e = x_ref.shape[-1]
    npix = window * window
    x = x_ref[...].astype(jnp.int32)  # (1, E)
    y = y_ref[...].astype(jnp.int32)
    w = w_ref[...]  # (1, E) f32 validity
    c = c_ref[...]  # (1, E) f32 coincidence counts
    lead = lead_ref[...]
    norm = norm_ref[0, 0]
    x0 = x0_ref[0, 0]
    y0 = y0_ref[0, 0]

    rx = x - x0
    ry = y - y0
    inp = w * (
        (rx >= 0) & (rx < window) & (ry >= 0) & (ry < window)
    ).astype(jnp.float32)  # (1, E)
    flat = jnp.clip(ry, 0, window - 1) * window + jnp.clip(rx, 0, window - 1)

    # Event -> patch scatter as a one-hot (E, npix) matmul.
    cells = jax.lax.broadcasted_iota(jnp.int32, (e, npix), 1)
    onehot = (flat.reshape(e, 1) == cells).astype(jnp.float32)
    cnt_flat = jnp.dot(inp, onehot, preferred_element_type=jnp.float32)
    cnt_patch = cnt_flat.reshape(window, window)

    # Histogram straight from events: leaders carry their pixel's count.
    bin_idx = jnp.clip((c / norm * bins).astype(jnp.int32), 0, bins - 1)
    bins_iota = jax.lax.broadcasted_iota(jnp.int32, (e, bins), 1)
    bins_onehot = (bin_idx.reshape(e, 1) == bins_iota).astype(jnp.float32)
    lead_inp = inp * lead
    hist = jnp.dot(lead_inp, bins_onehot, preferred_element_type=jnp.float32)
    occ = jnp.sum(lead_inp)
    hist = (hist + (jax.lax.broadcasted_iota(jnp.int32, (1, bins), 1) == 0)
            * (npix - occ)).reshape(bins)

    mets = M._exact_cluster_metrics(
        cnt_patch, hist, norm, count_ref[0, 0], cvalid_ref[0, 0] > 0
    )
    row = jnp.stack([mets[name] for name in M.METRIC_NAMES])
    out_ref[...] = jnp.pad(row, (0, LANE - N_METRICS)).reshape(1, LANE)


def patch_metrics(
    x: jax.Array,
    y: jax.Array,
    w: jax.Array,
    c: jax.Array,
    leader: jax.Array,
    x0: jax.Array,
    y0: jax.Array,
    count: jax.Array,
    cvalid: jax.Array,
    norm: jax.Array,
    *,
    window: int = M.WINDOW,
    bins: int = M.HIST_BINS,
    interpret: bool = False,
) -> jax.Array:
    """Six metrics for K cluster slots from one event window.

    Event arrays are (E,) with E a LANE multiple (ops.py pads, weight 0);
    per-cluster arrays are (K,). Returns (K, N_METRICS) float32 in
    ``METRIC_NAMES`` order. One grid step per cluster slot; the (E, 48^2)
    one-hot block bounds VMEM use (~2.3 MB at E=256).
    """
    e = x.shape[0]
    if e % LANE:
        raise ValueError(f"E ({e}) must be a multiple of {LANE}")
    k = x0.shape[0]

    def ev(a, dtype):
        return a.astype(dtype).reshape(1, e)

    def per_cluster(a, dtype):
        return a.astype(dtype).reshape(1, k)

    ev_spec = pl.BlockSpec((1, e), lambda i: (0, 0))
    k_spec = pl.BlockSpec((1, 1), lambda i: (0, i))
    out = pl.pallas_call(
        lambda *refs: _kernel(*refs, window=window, bins=bins),
        grid=(k,),
        in_specs=[ev_spec] * 5 + [k_spec] * 4 + [pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((k, LANE), jnp.float32),
        interpret=interpret,
    )(
        ev(x, jnp.int32),
        ev(y, jnp.int32),
        ev(w, jnp.float32),
        ev(c, jnp.float32),
        ev(leader, jnp.float32),
        per_cluster(x0, jnp.int32),
        per_cluster(y0, jnp.int32),
        per_cluster(count, jnp.float32),
        per_cluster(cvalid, jnp.float32),
        norm.astype(jnp.float32).reshape(1, 1),
    )
    return out[:, :N_METRICS]
