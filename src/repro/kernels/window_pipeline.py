"""Fused per-window Pallas megakernel for the fixed-point datapath.

One kernel launch per window *batch* — each grid step runs the entire
integer per-window stage chain that ``repro.core.fixed_point`` stages
through separate jnp ops (and that the float path spreads over multiple
kernel launches when ``use_kernels``/``metrics_impl="kernel"`` are on):

    ROI filter -> hot-pixel filter -> coincidence counts/leaders ->
    grid quantization -> 4-stat cell histogram -> top-K cell selection ->
    UQ10.8 centroids + exact patch origins -> per-cluster patch scatter,
    intensity histogram, Sobel, edge count, integer moment sums.

The kernel emits ONLY integer surfaces (cluster fields + per-cluster
metric sufficient statistics); the small float metric epilogue
(``fixed_point.fixed_metric_epilogue`` — log2/sqrt over exact integers,
the FPGA's LUT/CORDIC stage) runs as vmapped jnp in the caller's jit.
Keeping transcendentals out of the kernel is what makes fused-vs-staged
bit-identity robust: both paths feed the *identical* integers through the
*identical* epilogue code, so there is no float op whose lowering could
differ between the Pallas program and the staged program.

The TPU idioms follow ``patch_metrics.py``: event scatters become one-hot
compares + MXU matmuls, the pairwise (E, E) same-pixel block replaces the
sensor-sized histogram (exactly the event-space trick
``core.events.persistent_event_filter`` uses), and top-K is K unrolled
(max, first-index, mask) passes — the same selection contract as
``grid_clustering._top_k_cells``. Every one-hot f32 matmul produces the
same exact integers the staged int32 scatters do (all sums stay below
2^24). ``tests/test_fixed_point.py`` pins the identity over randomized
and adversarial windows.

Layout: inputs are (W, E) int32 event arrays (E a LANE multiple,
wrapper-padded); outputs are one (W, CL_ROWS, LANE) int32 block of
cluster fields (cluster slot k in lane k; row ``CL_FIELDS.index(f)`` =
field f; row 9 carries the per-window frame normalizer) and one
(W, K, LANE) int32 block of per-cluster surfaces (row k = cluster k:
lanes [0, bins) histogram counts, then s1, s2, s_g, s_e2, edges).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import fixed_point as FX
from repro.core import metrics as M

LANE = 128
CL_ROWS = 16
CL_FIELDS = (
    "count", "cell_x", "cell_y", "cq_x", "cq_y", "cq_t", "x0", "y0",
    "valid", "norm",
)
SURF_FIELDS = ("s1", "s2", "s_g", "s_e2", "edges")  # lanes bins..bins+4
# Pairwise (E, E) blocks bound the supported window capacity, exactly as
# events._PAIRWISE_MAX_EVENTS bounds the jnp pairwise branch.
MAX_EVENTS = 1024


def _kernel(
    x_ref, y_ref, t_ref, v_ref, cl_ref, surf_ref, *,
    roi: tuple[int, int, int, int],
    hot_pixel_max: int,
    cell_size: int,
    grid_w: int,
    grid_h: int,
    min_events: int,
    k: int,
    width: int,
    height: int,
    window: int,
    bins: int,
):
    e = x_ref.shape[-1]
    npix = window * window
    n_cells = grid_w * grid_h
    c_pad = -(-n_cells // LANE) * LANE
    x = x_ref[...]  # (1, E) int32
    y = y_ref[...]
    t = t_ref[...]
    v = v_ref[...] != 0

    # --- conditioning: ROI + hot-pixel filter (pairwise same-pixel) -------
    rx0, ry0, rx1, ry1 = roi
    v = v & (x >= rx0) & (x < rx1) & (y >= ry0) & (y < ry1)
    xi, xj = x.reshape(e, 1), x.reshape(1, e)
    yi, yj = y.reshape(e, 1), y.reshape(1, e)
    same = (xi == xj) & (yi == yj)  # (E, E) same-pixel
    hot = jnp.sum(same & v.reshape(1, e), axis=1, dtype=jnp.int32)
    v = v & (hot <= hot_pixel_max).reshape(1, e)

    # --- coincidence counts, leaders, frame normalizer --------------------
    inb = (x >= 0) & (x < width) & (y >= 0) & (y < height)
    w = v & inb  # (1, E)
    wj = w.reshape(1, e)
    c = jnp.sum(same & wj, axis=1, dtype=jnp.int32).reshape(1, e)
    row_i = jax.lax.broadcasted_iota(jnp.int32, (e, e), 0)
    col_j = jax.lax.broadcasted_iota(jnp.int32, (e, e), 1)
    earlier = same & wj & (col_j < row_i)
    leader = w & ~jnp.any(earlier, axis=1).reshape(1, e)
    norm_i = jnp.maximum(jnp.max(jnp.where(w, c, 0)), 1)

    # --- grid quantization + 4-stat cell histogram (one-hot matmul) -------
    if cell_size & (cell_size - 1) == 0:
        shift = cell_size.bit_length() - 1
        cx, cy = x >> shift, y >> shift
    else:
        cx, cy = x // cell_size, y // cell_size
    flat = jnp.clip(cy * grid_w + cx, 0, n_cells - 1)
    cell_iota = jax.lax.broadcasted_iota(jnp.int32, (e, c_pad), 1)
    cell_onehot = (flat.reshape(e, 1) == cell_iota).astype(jnp.float32)
    wf = w.astype(jnp.float32)
    xf = x.astype(jnp.float32)
    yf = y.astype(jnp.float32)
    tf = t.astype(jnp.float32)
    stats = jnp.concatenate([wf, wf * xf, wf * yf, wf * tf], axis=0)  # (4, E)
    # Exact: every per-cell sum is an integer below 2^24 (count <= E,
    # sum_x < E * width, sum_t < E * time_threshold).
    cell_stats = jnp.dot(
        stats, cell_onehot, preferred_element_type=jnp.float32
    ).astype(jnp.int32)  # (4, C_pad)
    counts = cell_stats[0:1, :]  # padded cells hold count 0

    # --- top-K cells + fixed-point cluster fields -------------------------
    lane1 = jax.lax.broadcasted_iota(jnp.int32, (1, LANE), 1)
    flat_iota = jax.lax.broadcasted_iota(jnp.int32, (1, c_pad), 1)
    cl = jnp.zeros((CL_ROWS, LANE), jnp.int32)
    remaining = counts
    for kk in range(k):
        top = jnp.max(remaining)
        # First maximum (lowest index) — lax.top_k's stable tie order,
        # matching grid_clustering._top_k_cells.
        idx = jnp.min(jnp.where(remaining == top, flat_iota, c_pad))
        remaining = jnp.where(
            flat_iota == idx, jnp.iinfo(jnp.int32).min, remaining
        )
        sel = flat_iota == idx
        cnt = top
        sx = jnp.sum(jnp.where(sel, cell_stats[1:2, :], 0))
        sy = jnp.sum(jnp.where(sel, cell_stats[2:3, :], 0))
        st = jnp.sum(jnp.where(sel, cell_stats[3:4, :], 0))
        validk = cnt >= min_events
        den = jnp.maximum(cnt, 1)

        def q8(s):
            q = s // den
            r = s - q * den
            return q * FX.CENTROID_ONE + FX.round_div_half_even(
                r * FX.CENTROID_ONE, den
            )

        neg = jnp.int32(-FX.CENTROID_ONE)
        ox = jnp.where(validk, FX.round_div_half_even(sx, den), -1)
        oy = jnp.where(validk, FX.round_div_half_even(sy, den), -1)
        col = jnp.stack([
            jnp.where(validk, cnt, 0),
            jnp.where(validk, idx % grid_w, -1),
            jnp.where(validk, idx // grid_w, -1),
            jnp.where(validk, q8(sx), neg),
            jnp.where(validk, q8(sy), neg),
            jnp.where(validk, q8(st), neg),
            jnp.clip(ox - window // 2, 0, width - window),
            jnp.clip(oy - window // 2, 0, height - window),
            validk.astype(jnp.int32),
            norm_i,
        ] + [jnp.int32(0)] * (CL_ROWS - 10)).reshape(CL_ROWS, 1)
        cl = cl + jnp.where(lane1 == kk, col, 0)

    # --- per-cluster integer metric surfaces ------------------------------
    cf = c.astype(jnp.float32)
    bin_idx = jnp.clip((c * bins) // norm_i, 0, bins - 1)
    bins_iota = jax.lax.broadcasted_iota(jnp.int32, (e, bins), 1)
    bins_onehot = (bin_idx.reshape(e, 1) == bins_iota).astype(jnp.float32)
    pix_iota = jax.lax.broadcasted_iota(jnp.int32, (e, npix), 1)
    leadf = leader.astype(jnp.float32)
    rowk = jax.lax.broadcasted_iota(jnp.int32, (k, 1), 0)

    def per_cluster(kk, surf):
        sel = lane1 == kk

        def field(r):
            return jnp.sum(jnp.where(sel, cl[r:r + 1, :], 0))

        x0k, y0k = field(6), field(7)
        rx = x - x0k
        ry = y - y0k
        inp = (
            (rx >= 0) & (rx < window) & (ry >= 0) & (ry < window) & w
        ).astype(jnp.float32)  # (1, E)
        pflat = (
            jnp.clip(ry, 0, window - 1) * window + jnp.clip(rx, 0, window - 1)
        )
        pix_onehot = (pflat.reshape(e, 1) == pix_iota).astype(jnp.float32)
        cnt_flat = jnp.dot(inp, pix_onehot, preferred_element_type=jnp.float32)
        patch = cnt_flat.reshape(window, window).astype(jnp.int32)

        lead_inp = inp * leadf
        hist = jnp.dot(
            lead_inp, bins_onehot, preferred_element_type=jnp.float32
        )  # (1, bins)
        occ = jnp.sum(lead_inp)
        hist = hist + (
            jax.lax.broadcasted_iota(jnp.int32, (1, bins), 1) == 0
        ) * (npix - occ)
        s1 = jnp.sum(inp).astype(jnp.int32)
        s2 = jnp.sum(lead_inp * (cf * cf)).astype(jnp.int32)

        gx, gy = FX.sobel_int(patch)
        g2 = gx * gx + gy * gy
        g2max = jnp.max(g2)
        edges = jnp.sum(16 * g2 > g2max, dtype=jnp.int32)
        s_g = jnp.sum(FX.isqrt(g2), dtype=jnp.int32)
        s_e2 = jnp.sum(g2, dtype=jnp.int32)

        row = jnp.concatenate([
            hist.astype(jnp.int32),
            jnp.stack([s1, s2, s_g, s_e2, edges]).reshape(1, 5),
            jnp.zeros((1, LANE - bins - 5), jnp.int32),
        ], axis=1)  # (1, LANE)
        return surf + jnp.where(rowk == kk, row, 0)

    surf = jax.lax.fori_loop(
        0, k, per_cluster, jnp.zeros((k, LANE), jnp.int32)
    )

    cl_ref[...] = cl.reshape(1, CL_ROWS, LANE)
    surf_ref[...] = surf.reshape(1, k, LANE)


def window_pipeline(
    x: jax.Array,
    y: jax.Array,
    t: jax.Array,
    valid: jax.Array,
    *,
    roi: tuple[int, int, int, int],
    hot_pixel_max: int,
    cell_size: int,
    grid_w: int,
    grid_h: int,
    min_events: int,
    k: int,
    width: int,
    height: int,
    window: int = M.WINDOW,
    bins: int = M.HIST_BINS,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Run the fused per-window integer pipeline over a (W, E) batch.

    Returns ``(cl, surf)``: (W, CL_ROWS, LANE) int32 cluster fields in
    ``CL_FIELDS`` row order (slot k in lane k) and (W, K, LANE) int32
    per-cluster metric surfaces (histogram counts in lanes [0, bins),
    then ``SURF_FIELDS``). ``ops.window_pipeline_call`` unpacks both and
    applies the shared float epilogue.
    """
    n_windows, e = x.shape
    if e % LANE:
        raise ValueError(f"E ({e}) must be a multiple of {LANE}")
    if e > MAX_EVENTS:
        raise ValueError(
            f"E ({e}) exceeds the pairwise block bound ({MAX_EVENTS})"
        )
    if k > LANE:
        raise ValueError(f"max_clusters ({k}) must be <= {LANE}")
    if bins + len(SURF_FIELDS) > LANE:
        raise ValueError(f"bins ({bins}) too large for the surface row")

    ev_spec = pl.BlockSpec((1, e), lambda i: (i, 0))
    kernel = lambda *refs: _kernel(  # noqa: E731
        *refs,
        roi=roi, hot_pixel_max=hot_pixel_max, cell_size=cell_size,
        grid_w=grid_w, grid_h=grid_h, min_events=min_events, k=k,
        width=width, height=height, window=window, bins=bins,
    )
    return pl.pallas_call(
        kernel,
        grid=(n_windows,),
        in_specs=[ev_spec] * 4,
        out_specs=[
            pl.BlockSpec((1, CL_ROWS, LANE), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, k, LANE), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_windows, CL_ROWS, LANE), jnp.int32),
            jax.ShapeDtypeStruct((n_windows, k, LANE), jnp.int32),
        ],
        interpret=interpret,
    )(
        x.astype(jnp.int32),
        y.astype(jnp.int32),
        t.astype(jnp.int32),
        valid.astype(jnp.int32),
    )
