"""Synthetic token pipeline for LM training examples/tests.

A deterministic Zipf-ish Markov stream: learnable structure (so a ~100M
model's loss visibly drops within a few hundred steps) without external
data. Sharding-aware: ``sharded_batches`` device_puts each batch with the
requested NamedSharding (the host->device path a real loader uses).
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


class MarkovTokens:
    """Order-1 Markov chain over the vocab with Zipf marginals."""

    def __init__(self, vocab: int, seed: int = 0, branch: int = 16):
        rng = np.random.default_rng(seed)
        self.vocab = vocab
        # Each token transitions to `branch` successors with Zipf weights.
        self.succ = rng.integers(0, vocab, size=(vocab, branch))
        w = 1.0 / np.arange(1, branch + 1)
        self.w = w / w.sum()
        self.rng = rng

    def sample(self, batch: int, seq: int) -> np.ndarray:
        out = np.empty((batch, seq + 1), np.int32)
        cur = self.rng.integers(0, self.vocab, size=batch)
        out[:, 0] = cur
        for t in range(1, seq + 1):
            choice = self.rng.choice(len(self.w), size=batch, p=self.w)
            cur = self.succ[cur, choice]
            out[:, t] = cur
        return out


def batches(
    vocab: int, batch: int, seq: int, n_steps: int, seed: int = 0
) -> Iterator[dict[str, jnp.ndarray]]:
    gen = MarkovTokens(vocab, seed)
    for _ in range(n_steps):
        toks = gen.sample(batch, seq)
        yield {
            "tokens": jnp.asarray(toks[:, :-1]),
            "labels": jnp.asarray(toks[:, 1:]),
        }


def sharded_batches(
    vocab: int, batch: int, seq: int, n_steps: int, sharding, seed: int = 0
) -> Iterator[dict[str, jnp.ndarray]]:
    for b in batches(vocab, batch, seq, n_steps, seed):
        yield jax.tree.map(lambda x: jax.device_put(x, sharding), b)
