from repro.data.synthetic import (  # noqa: F401
    LENS_CONFIGS,
    Recording,
    make_recording,
    make_validation_suite,
)
