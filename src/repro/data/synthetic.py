"""Synthetic EVAS-like night-sky event recordings with ground truth.

The EVAS dataset (Valdivia et al. 2025) is hosted on Kaggle and not
available offline, so validation uses a physically-motivated simulator
that reproduces the statistical regime the paper reports:

* a static star field — stars scintillate at a low event rate and drift
  slowly (apparent sidereal motion), producing small clusters (the paper's
  Fig. 6 notes sub-5-event clusters are overwhelmingly noise/stars),
* 1-3 RSOs crossing the field of view on linear trajectories at up to
  0.6 rad/s apparent angular velocity, producing dense event streaks
  (5-20 events per 20 ms window, Fig. 6),
* uniform background shot noise.

Six recordings x three lens configurations mirror the paper's validation
set. Every event carries a ground-truth kind (0 noise / 1 star / 2 RSO)
and object id so detector accuracy can be scored exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.events import SENSOR_HEIGHT, SENSOR_WIDTH

KIND_NOISE, KIND_STAR, KIND_RSO = 0, 1, 2

# Lens configurations: focal scale multiplies apparent velocities and
# divides the star density (narrower field of view sees fewer stars).
LENS_CONFIGS = {
    "standard": dict(scale=1.0, n_stars=36),
    "telephoto": dict(scale=2.2, n_stars=14),
    "wide": dict(scale=0.55, n_stars=60),
}


@dataclasses.dataclass
class Recording:
    """Time-sorted event stream with per-event ground truth."""

    x: np.ndarray  # (N,) int32
    y: np.ndarray  # (N,) int32
    t: np.ndarray  # (N,) int64 microseconds
    p: np.ndarray  # (N,) int32 polarity
    kind: np.ndarray  # (N,) int32 in {0 noise, 1 star, 2 rso}
    obj: np.ndarray  # (N,) int32 object index (-1 for noise)
    rso_tracks: np.ndarray  # (R, 4) [x0, y0, vx_px_per_s, vy_px_per_s]
    duration_us: int
    name: str = "synthetic"

    def __len__(self) -> int:
        return len(self.t)

    def rso_position(self, rso: int, t_us: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        x0, y0, vx, vy = self.rso_tracks[rso]
        ts = np.asarray(t_us, np.float64) * 1e-6
        return x0 + vx * ts, y0 + vy * ts


def _poisson_times(rng: np.random.Generator, rate_hz: float, duration_us: int) -> np.ndarray:
    n = rng.poisson(rate_hz * duration_us * 1e-6)
    return np.sort(rng.uniform(0, duration_us, size=n)).astype(np.int64)


def make_recording(
    seed: int = 0,
    duration_s: float = 2.0,
    n_rsos: int = 2,
    lens: str = "standard",
    noise_rate_hz: float = 3_500.0,
    star_rate_hz: tuple[float, float] = (15.0, 60.0),
    rso_rate_hz: tuple[float, float] = (380.0, 700.0),
    rso_speed_px_s: tuple[float, float] = (40.0, 150.0),
    psf_sigma: float = 0.8,
    width: int = SENSOR_WIDTH,
    height: int = SENSOR_HEIGHT,
    name: str | None = None,
) -> Recording:
    """Generate one labeled recording.

    Star rates put most star clusters below 5 events / 20 ms window; RSO
    rates put almost all RSO clusters at >= 5 — the regime in which the
    paper's min_events = 5 threshold is optimal (Fig. 10b).
    """
    rng = np.random.default_rng(seed)
    cfg = LENS_CONFIGS[lens]
    scale = cfg["scale"]
    n_stars = cfg["n_stars"]
    duration_us = int(duration_s * 1e6)

    xs, ys, ts, ps, kinds, objs = [], [], [], [], [], []

    # --- background shot noise -------------------------------------------
    t_noise = _poisson_times(rng, noise_rate_hz, duration_us)
    n = len(t_noise)
    xs.append(rng.integers(0, width, n))
    ys.append(rng.integers(0, height, n))
    ts.append(t_noise)
    ps.append(rng.integers(0, 2, n))
    kinds.append(np.full(n, KIND_NOISE))
    objs.append(np.full(n, -1))

    # --- star field -------------------------------------------------------
    star_x = rng.uniform(30, width - 30, n_stars)
    star_y = rng.uniform(30, height - 30, n_stars)
    # Apparent sidereal drift, px/s (scaled by lens focal length).
    drift = rng.normal(0.0, 0.6, (n_stars, 2)) * scale
    for s in range(n_stars):
        rate = rng.uniform(*star_rate_hz)
        t_s = _poisson_times(rng, rate, duration_us)
        n = len(t_s)
        if n == 0:
            continue
        tt = t_s * 1e-6
        xs.append(star_x[s] + drift[s, 0] * tt + rng.normal(0, psf_sigma, n))
        ys.append(star_y[s] + drift[s, 1] * tt + rng.normal(0, psf_sigma, n))
        ts.append(t_s)
        ps.append(rng.integers(0, 2, n))
        kinds.append(np.full(n, KIND_STAR))
        objs.append(np.full(n, s))

    # --- RSOs --------------------------------------------------------------
    # (n_rsos, 4): a zero-RSO recording gets an empty (0, 4) track table so
    # accuracy scoring sees no phantom object at the origin.
    tracks = np.zeros((n_rsos, 4), np.float64)
    for r in range(n_rsos):
        speed = rng.uniform(*rso_speed_px_s) * scale  # px/s apparent
        angle = rng.uniform(0, 2 * np.pi)
        vx, vy = speed * np.cos(angle), speed * np.sin(angle)
        # Start so the trajectory stays mostly inside the ROI.
        x0 = rng.uniform(0.25 * width, 0.75 * width) - vx * duration_s / 2
        y0 = rng.uniform(0.25 * height, 0.75 * height) - vy * duration_s / 2
        tracks[r] = (x0, y0, vx, vy)
        rate = rng.uniform(*rso_rate_hz)
        t_r = _poisson_times(rng, rate, duration_us)
        n = len(t_r)
        tt = t_r * 1e-6
        px = x0 + vx * tt + rng.normal(0, psf_sigma, n)
        py = y0 + vy * tt + rng.normal(0, psf_sigma, n)
        inside = (px >= 0) & (px < width) & (py >= 0) & (py < height)
        xs.append(px[inside])
        ys.append(py[inside])
        ts.append(t_r[inside])
        ps.append(rng.integers(0, 2, int(inside.sum())))
        kinds.append(np.full(int(inside.sum()), KIND_RSO))
        objs.append(np.full(int(inside.sum()), r))

    x = np.clip(np.concatenate(xs), 0, width - 1).astype(np.int32)
    y = np.clip(np.concatenate(ys), 0, height - 1).astype(np.int32)
    t = np.concatenate(ts).astype(np.int64)
    p = np.concatenate(ps).astype(np.int32)
    kind = np.concatenate(kinds).astype(np.int32)
    obj = np.concatenate(objs).astype(np.int32)
    order = np.argsort(t, kind="stable")
    return Recording(
        x[order], y[order], t[order], p[order], kind[order], obj[order],
        rso_tracks=tracks,
        duration_us=duration_us,
        name=name or f"synthetic-{lens}-seed{seed}",
    )


def make_validation_suite(
    n_recordings: int = 6, duration_s: float = 2.0, seed0: int = 100
) -> list[Recording]:
    """Six recordings x three lens types, mirroring the paper's Sec. V-A."""
    suite = []
    for i in range(n_recordings):
        for li, lens in enumerate(LENS_CONFIGS):
            suite.append(
                make_recording(
                    seed=seed0 + 17 * i + 251 * li,
                    duration_s=duration_s,
                    n_rsos=1 + (i % 3),
                    lens=lens,
                    name=f"rec{i}-{lens}",
                )
            )
    return suite
