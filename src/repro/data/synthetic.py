"""Synthetic EVAS-like night-sky event recordings with ground truth.

The EVAS dataset (Valdivia et al. 2025) is hosted on Kaggle and not
available offline, so validation uses a physically-motivated simulator
that reproduces the statistical regime the paper reports:

* a static star field — stars scintillate at a low event rate and drift
  slowly (apparent sidereal motion), producing small clusters (the paper's
  Fig. 6 notes sub-5-event clusters are overwhelmingly noise/stars),
* 1-3 RSOs crossing the field of view on linear trajectories at up to
  0.6 rad/s apparent angular velocity, producing dense event streaks
  (5-20 events per 20 ms window, Fig. 6),
* uniform background shot noise.

Six recordings x three lens configurations mirror the paper's validation
set. Every event carries a ground-truth kind (0 noise / 1 star / 2 RSO)
and object id so detector accuracy can be scored exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.events import SENSOR_HEIGHT, SENSOR_WIDTH

KIND_NOISE, KIND_STAR, KIND_RSO = 0, 1, 2

# Lens configurations: focal scale multiplies apparent velocities and
# divides the star density (narrower field of view sees fewer stars).
LENS_CONFIGS = {
    "standard": dict(scale=1.0, n_stars=36),
    "telephoto": dict(scale=2.2, n_stars=14),
    "wide": dict(scale=0.55, n_stars=60),
}


@dataclasses.dataclass
class Recording:
    """Time-sorted event stream with per-event ground truth.

    ``rso_tracks`` rows are ``[x0, y0, vx_px_per_s, vy_px_per_s]``
    (legacy constant-velocity, (R, 4)) or additionally
    ``[..., ax_px_per_s2, ay_px_per_s2]`` ((R, 6)) for the scenario
    simulator's ballistic family; every consumer normalizes via
    :func:`repro.core.pipeline.evaluate.track_table`.
    """

    x: np.ndarray  # (N,) int32
    y: np.ndarray  # (N,) int32
    t: np.ndarray  # (N,) int64 microseconds
    p: np.ndarray  # (N,) int32 polarity
    kind: np.ndarray  # (N,) int32 in {0 noise, 1 star, 2 rso}
    obj: np.ndarray  # (N,) int32 object index (-1 for noise)
    rso_tracks: np.ndarray  # (R, 4) or (R, 6) trajectory table
    duration_us: int
    name: str = "synthetic"

    def __len__(self) -> int:
        return len(self.t)

    def rso_position(self, rso: int, t_us: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        tr = np.asarray(self.rso_tracks[rso], np.float64)
        x0, y0, vx, vy = tr[:4]
        ax, ay = (tr[4], tr[5]) if tr.shape[0] >= 6 else (0.0, 0.0)
        ts = np.asarray(t_us, np.float64) * 1e-6
        return (
            x0 + vx * ts + 0.5 * ax * ts * ts,
            y0 + vy * ts + 0.5 * ay * ts * ts,
        )


def _poisson_times(rng: np.random.Generator, rate_hz: float, duration_us: int) -> np.ndarray:
    n = rng.poisson(rate_hz * duration_us * 1e-6)
    return np.sort(rng.uniform(0, duration_us, size=n)).astype(np.int64)


def make_recording(
    seed: int = 0,
    duration_s: float = 2.0,
    n_rsos: int = 2,
    lens: str = "standard",
    noise_rate_hz: float = 3_500.0,
    star_rate_hz: tuple[float, float] = (15.0, 60.0),
    rso_rate_hz: tuple[float, float] = (380.0, 700.0),
    rso_speed_px_s: tuple[float, float] = (40.0, 150.0),
    psf_sigma: float = 0.8,
    width: int = SENSOR_WIDTH,
    height: int = SENSOR_HEIGHT,
    name: str | None = None,
) -> Recording:
    """Generate one labeled recording.

    Star rates put most star clusters below 5 events / 20 ms window; RSO
    rates put almost all RSO clusters at >= 5 — the regime in which the
    paper's min_events = 5 threshold is optimal (Fig. 10b).
    """
    rng = np.random.default_rng(seed)
    cfg = LENS_CONFIGS[lens]
    scale = cfg["scale"]
    n_stars = cfg["n_stars"]
    duration_us = int(duration_s * 1e6)

    xs, ys, ts, ps, kinds, objs = [], [], [], [], [], []

    # --- background shot noise -------------------------------------------
    t_noise = _poisson_times(rng, noise_rate_hz, duration_us)
    n = len(t_noise)
    xs.append(rng.integers(0, width, n))
    ys.append(rng.integers(0, height, n))
    ts.append(t_noise)
    ps.append(rng.integers(0, 2, n))
    kinds.append(np.full(n, KIND_NOISE))
    objs.append(np.full(n, -1))

    # --- star field -------------------------------------------------------
    star_x = rng.uniform(30, width - 30, n_stars)
    star_y = rng.uniform(30, height - 30, n_stars)
    # Apparent sidereal drift, px/s (scaled by lens focal length).
    drift = rng.normal(0.0, 0.6, (n_stars, 2)) * scale
    for s in range(n_stars):
        rate = rng.uniform(*star_rate_hz)
        t_s = _poisson_times(rng, rate, duration_us)
        n = len(t_s)
        if n == 0:
            continue
        tt = t_s * 1e-6
        xs.append(star_x[s] + drift[s, 0] * tt + rng.normal(0, psf_sigma, n))
        ys.append(star_y[s] + drift[s, 1] * tt + rng.normal(0, psf_sigma, n))
        ts.append(t_s)
        ps.append(rng.integers(0, 2, n))
        kinds.append(np.full(n, KIND_STAR))
        objs.append(np.full(n, s))

    # --- RSOs --------------------------------------------------------------
    # (n_rsos, 4): a zero-RSO recording gets an empty (0, 4) track table so
    # accuracy scoring sees no phantom object at the origin.
    tracks = np.zeros((n_rsos, 4), np.float64)
    for r in range(n_rsos):
        speed = rng.uniform(*rso_speed_px_s) * scale  # px/s apparent
        angle = rng.uniform(0, 2 * np.pi)
        vx, vy = speed * np.cos(angle), speed * np.sin(angle)
        # Start so the trajectory stays mostly inside the ROI.
        x0 = rng.uniform(0.25 * width, 0.75 * width) - vx * duration_s / 2
        y0 = rng.uniform(0.25 * height, 0.75 * height) - vy * duration_s / 2
        tracks[r] = (x0, y0, vx, vy)
        rate = rng.uniform(*rso_rate_hz)
        t_r = _poisson_times(rng, rate, duration_us)
        n = len(t_r)
        tt = t_r * 1e-6
        px = x0 + vx * tt + rng.normal(0, psf_sigma, n)
        py = y0 + vy * tt + rng.normal(0, psf_sigma, n)
        inside = (px >= 0) & (px < width) & (py >= 0) & (py < height)
        xs.append(px[inside])
        ys.append(py[inside])
        ts.append(t_r[inside])
        ps.append(rng.integers(0, 2, int(inside.sum())))
        kinds.append(np.full(int(inside.sum()), KIND_RSO))
        objs.append(np.full(int(inside.sum()), r))

    x = np.clip(np.concatenate(xs), 0, width - 1).astype(np.int32)
    y = np.clip(np.concatenate(ys), 0, height - 1).astype(np.int32)
    t = np.concatenate(ts).astype(np.int64)
    p = np.concatenate(ps).astype(np.int32)
    kind = np.concatenate(kinds).astype(np.int32)
    obj = np.concatenate(objs).astype(np.int32)
    order = np.argsort(t, kind="stable")
    return Recording(
        x[order], y[order], t[order], p[order], kind[order], obj[order],
        rso_tracks=tracks,
        duration_us=duration_us,
        name=name or f"synthetic-{lens}-seed{seed}",
    )


def make_validation_suite(
    n_recordings: int = 6, duration_s: float = 2.0, seed0: int = 100
) -> list[Recording]:
    """Six recordings x three lens types, mirroring the paper's Sec. V-A."""
    suite = []
    for i in range(n_recordings):
        for li, lens in enumerate(LENS_CONFIGS):
            suite.append(
                make_recording(
                    seed=seed0 + 17 * i + 251 * li,
                    duration_s=duration_s,
                    n_rsos=1 + (i % 3),
                    lens=lens,
                    name=f"rec{i}-{lens}",
                )
            )
    return suite


# ---------------------------------------------------------------------------
# Scenario layer: composable sky scenarios beyond the three lens configs.
#
# The paper validates on three lens configurations of the same regime
# (linear crossers + static stars + uniform shot noise). Real SSA
# scenes are messier — Afshar et al. (1911.08730) and Ussa et al.
# (2007.11404) both stress heterogeneous scene statistics — so the
# scenario layer composes orthogonal stressors into labeled recordings:
# GEO slow-movers, tumbling RSOs (periodic brightness), ballistic
# (curved) crossings, hot-pixel columns, temporally localized noise
# bursts, and platform pointing jitter. Every event still carries
# (kind, obj) ground truth, and trajectory tables extend to (R, 6)
# [x0, y0, vx, vy, ax, ay] so the evaluators gate curved paths exactly.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RSOSpec:
    """One resident space object: kinematics + photometric behaviour.

    ``speed_px_s`` / ``accel_px_s2`` / ``rate_hz`` are (lo, hi) ranges
    sampled per recording. ``tumble_hz > 0`` modulates the event rate
    sinusoidally (a tumbling body's periodic glint): instantaneous rate
    = peak * ((1 - depth) + depth * (1 + sin) / 2), so ``depth=1`` goes
    fully dark at the trough.
    """

    speed_px_s: tuple[float, float] = (40.0, 150.0)
    accel_px_s2: tuple[float, float] = (0.0, 0.0)
    rate_hz: tuple[float, float] = (380.0, 700.0)
    tumble_hz: float = 0.0
    tumble_depth: float = 0.9


@dataclasses.dataclass(frozen=True)
class Scenario:
    """Composable recording spec: any mix of stressors in one sky.

    Fields compose freely — e.g. tumbling RSOs *plus* hot columns
    *plus* jitter is a valid scenario; :data:`SCENARIO_FAMILIES` holds
    the canonical single-stressor presets.
    """

    name: str
    rsos: tuple[RSOSpec, ...] = ()
    lens: str = "standard"
    noise_rate_hz: float = 3_500.0
    star_rate_hz: tuple[float, float] = (15.0, 60.0)
    # Hot-pixel columns: stuck sensor columns carrying clusters of
    # persistently firing pixels (exercises the conditioning stage).
    hot_columns: int = 0
    hot_pixels_per_column: int = 24
    hot_pixel_rate_hz: float = 800.0
    # Noise bursts: short intervals of elevated background rate.
    n_bursts: int = 0
    burst_rate_hz: float = 60_000.0
    burst_ms: float = 30.0
    # Platform pointing jitter: sinusoidal whole-frame wobble.
    jitter_px: float = 0.0
    jitter_hz: float = 4.0
    duration_s: float = 2.0


SCENARIO_FAMILIES: dict[str, Scenario] = {
    # The paper's regime: fast linear crossers (baseline family).
    "crossing": Scenario(name="crossing", rsos=(RSOSpec(), RSOSpec())),
    # Near-stationary GEO objects: drift speeds comparable to the star
    # field's sidereal motion — separability must come from density, not
    # streak length.
    "geo_slow": Scenario(
        name="geo_slow",
        rsos=(
            RSOSpec(speed_px_s=(0.5, 3.0), rate_hz=(420.0, 650.0)),
            RSOSpec(speed_px_s=(1.0, 5.0), rate_hz=(420.0, 650.0)),
        ),
    ),
    # Tumbling bodies: the event rate collapses periodically, so windows
    # near the glint trough look like sub-threshold star clusters.
    "tumbling": Scenario(
        name="tumbling",
        rsos=(
            RSOSpec(tumble_hz=5.0, rate_hz=(500.0, 800.0)),
            RSOSpec(tumble_hz=2.5, tumble_depth=1.0, rate_hz=(500.0, 800.0)),
        ),
    ),
    # Curved / ballistic crossings: constant-acceleration trajectories
    # ((R, 6) ground-truth rows) that a linear gate would lose.
    "ballistic": Scenario(
        name="ballistic",
        rsos=(
            RSOSpec(speed_px_s=(30.0, 90.0), accel_px_s2=(40.0, 120.0)),
            RSOSpec(speed_px_s=(40.0, 110.0), accel_px_s2=(30.0, 90.0)),
        ),
    ),
    # Defective sensor columns full of persistently firing pixels.
    "hot_columns": Scenario(
        name="hot_columns", rsos=(RSOSpec(),), hot_columns=3
    ),
    # Temporally localized background storms (e.g. stray light).
    "noise_burst": Scenario(
        name="noise_burst", rsos=(RSOSpec(),), n_bursts=5
    ),
    # Platform wobble: every apparent position oscillates a few px.
    "jitter": Scenario(
        name="jitter", rsos=(RSOSpec(), RSOSpec()), jitter_px=2.5,
        jitter_hz=6.0,
    ),
}


def _tumble_thin(
    rng: np.random.Generator, t_us: np.ndarray, spec: RSOSpec
) -> np.ndarray:
    """Thin Poisson arrivals to a sinusoidally modulated rate (keep mask)."""
    if spec.tumble_hz <= 0.0 or len(t_us) == 0:
        return np.ones(len(t_us), bool)
    phase = rng.uniform(0, 2 * np.pi)
    ts = t_us * 1e-6
    m = (1.0 - spec.tumble_depth) + spec.tumble_depth * 0.5 * (
        1.0 + np.sin(2 * np.pi * spec.tumble_hz * ts + phase)
    )
    return rng.uniform(size=len(t_us)) < m


def make_scenario(
    scenario: Scenario,
    seed: int = 0,
    psf_sigma: float = 0.8,
    width: int = SENSOR_WIDTH,
    height: int = SENSOR_HEIGHT,
    name: str | None = None,
) -> Recording:
    """Generate one labeled recording from a composable scenario spec."""
    rng = np.random.default_rng(seed)
    cfg = LENS_CONFIGS[scenario.lens]
    scale = cfg["scale"]
    n_stars = cfg["n_stars"]
    duration_s = scenario.duration_s
    duration_us = int(duration_s * 1e6)

    xs, ys, ts, ps, kinds, objs = [], [], [], [], [], []

    def add(x, y, t, kind, obj):
        n = len(t)
        xs.append(np.asarray(x, np.float64))
        ys.append(np.asarray(y, np.float64))
        ts.append(np.asarray(t, np.int64))
        ps.append(rng.integers(0, 2, n))
        kinds.append(np.full(n, kind))
        objs.append(np.full(n, obj))

    # --- background shot noise -------------------------------------------
    t_noise = _poisson_times(rng, scenario.noise_rate_hz, duration_us)
    n = len(t_noise)
    add(rng.integers(0, width, n), rng.integers(0, height, n), t_noise,
        KIND_NOISE, -1)

    # --- noise bursts -----------------------------------------------------
    for _ in range(scenario.n_bursts):
        b_us = int(scenario.burst_ms * 1e3)
        t0 = int(rng.uniform(0, max(duration_us - b_us, 1)))
        t_b = _poisson_times(rng, scenario.burst_rate_hz, b_us) + t0
        n = len(t_b)
        add(rng.integers(0, width, n), rng.integers(0, height, n), t_b,
            KIND_NOISE, -1)

    # --- hot-pixel columns ------------------------------------------------
    for _ in range(scenario.hot_columns):
        col = int(rng.integers(0, width))
        rows = rng.choice(height, size=scenario.hot_pixels_per_column,
                          replace=False)
        for r in rows:
            t_h = _poisson_times(rng, scenario.hot_pixel_rate_hz, duration_us)
            add(np.full(len(t_h), col), np.full(len(t_h), r), t_h,
                KIND_NOISE, -1)

    # --- star field -------------------------------------------------------
    star_x = rng.uniform(30, width - 30, n_stars)
    star_y = rng.uniform(30, height - 30, n_stars)
    drift = rng.normal(0.0, 0.6, (n_stars, 2)) * scale
    for s in range(n_stars):
        rate = rng.uniform(*scenario.star_rate_hz)
        t_s = _poisson_times(rng, rate, duration_us)
        n = len(t_s)
        if n == 0:
            continue
        tt = t_s * 1e-6
        add(
            star_x[s] + drift[s, 0] * tt + rng.normal(0, psf_sigma, n),
            star_y[s] + drift[s, 1] * tt + rng.normal(0, psf_sigma, n),
            t_s, KIND_STAR, s,
        )

    # --- RSOs -------------------------------------------------------------
    n_rsos = len(scenario.rsos)
    tracks = np.zeros((n_rsos, 6), np.float64)
    for r, spec in enumerate(scenario.rsos):
        speed = rng.uniform(*spec.speed_px_s) * scale
        angle = rng.uniform(0, 2 * np.pi)
        vx, vy = speed * np.cos(angle), speed * np.sin(angle)
        a_mag = rng.uniform(*spec.accel_px_s2) * scale
        a_angle = rng.uniform(0, 2 * np.pi)
        ax, ay = a_mag * np.cos(a_angle), a_mag * np.sin(a_angle)
        # Center the trajectory's midpoint so it stays mostly in view.
        half = duration_s / 2
        x0 = rng.uniform(0.25 * width, 0.75 * width) - vx * half - 0.5 * ax * half * half
        y0 = rng.uniform(0.25 * height, 0.75 * height) - vy * half - 0.5 * ay * half * half
        tracks[r] = (x0, y0, vx, vy, ax, ay)
        rate = rng.uniform(*spec.rate_hz)
        t_r = _poisson_times(rng, rate, duration_us)
        t_r = t_r[_tumble_thin(rng, t_r, spec)]
        n = len(t_r)
        tt = t_r * 1e-6
        px = x0 + vx * tt + 0.5 * ax * tt * tt + rng.normal(0, psf_sigma, n)
        py = y0 + vy * tt + 0.5 * ay * tt * tt + rng.normal(0, psf_sigma, n)
        inside = (px >= 0) & (px < width) & (py >= 0) & (py < height)
        add(px[inside], py[inside], t_r[inside], KIND_RSO, r)

    x = np.concatenate(xs)
    y = np.concatenate(ys)
    t = np.concatenate(ts).astype(np.int64)
    p = np.concatenate(ps).astype(np.int32)
    kind = np.concatenate(kinds).astype(np.int32)
    obj = np.concatenate(objs).astype(np.int32)

    # --- pointing jitter (applies to the whole frame) ---------------------
    if scenario.jitter_px > 0.0:
        phx, phy = rng.uniform(0, 2 * np.pi, 2)
        w = 2 * np.pi * scenario.jitter_hz
        tt = t * 1e-6
        x = x + scenario.jitter_px * np.sin(w * tt + phx)
        y = y + scenario.jitter_px * np.sin(w * tt + phy)

    x = np.clip(x, 0, width - 1).astype(np.int32)
    y = np.clip(y, 0, height - 1).astype(np.int32)
    order = np.argsort(t, kind="stable")
    return Recording(
        x[order], y[order], t[order], p[order], kind[order], obj[order],
        rso_tracks=tracks,
        duration_us=duration_us,
        name=name or f"{scenario.name}-seed{seed}",
    )


def make_scenario_suite(
    families: tuple[str, ...] | None = None,
    seed0: int = 0,
    duration_s: float | None = None,
    n_per_family: int = 1,
) -> list[Recording]:
    """One labeled recording per scenario family (x ``n_per_family``).

    The stress-test counterpart of :func:`make_validation_suite`:
    feeds the same evaluators (``threshold_sweep``,
    ``collect_candidates*``) but sweeps scene *statistics* instead of
    lens configs.
    """
    names = tuple(SCENARIO_FAMILIES) if families is None else families
    suite = []
    for i in range(n_per_family):
        for fi, fam in enumerate(names):
            sc = SCENARIO_FAMILIES[fam]
            if duration_s is not None:
                sc = dataclasses.replace(sc, duration_s=duration_s)
            suite.append(
                make_scenario(
                    sc, seed=seed0 + 31 * i + 7 * fi,
                    name=f"{fam}-{i}",
                )
            )
    return suite


def make_fleet_recordings(
    n_sensors: int,
    scenario: Scenario | None = None,
    seed0: int = 0,
    duration_s: float | None = None,
    jitter_px: float = 1.5,
    jitter_hz: float = 6.0,
) -> list[Recording]:
    """Per-sensor recordings for a fleet: scenario-diverse by default
    (cycling the family presets), each sensor with independent pointing
    jitter (own amplitude phase/seed) — no two sensors see the same
    platform wobble, which is exactly what the fleet engine's per-sensor
    carries must keep isolated.
    """
    names = tuple(SCENARIO_FAMILIES)
    recs = []
    for s in range(n_sensors):
        sc = SCENARIO_FAMILIES[names[s % len(names)]] if scenario is None else scenario
        sc = dataclasses.replace(
            sc,
            jitter_px=max(sc.jitter_px, jitter_px),
            jitter_hz=jitter_hz,
            **({"duration_s": duration_s} if duration_s is not None else {}),
        )
        recs.append(
            make_scenario(sc, seed=seed0 + 101 * s, name=f"sensor{s}-{sc.name}")
        )
    return recs
