"""EVAS dataset interface (Valdivia et al. 2025, kaggle.com/ds/5688319).

The dataset is hosted on Kaggle and unavailable offline, so this module
defines the on-disk interchange format the pipeline consumes and a
loader that falls back to the calibrated synthetic generator. A real
EVAS download converted to this .npz layout drops in without code
changes:

  arrays: x (N,) int32, y (N,) int32, t (N,) int64 microseconds,
          p (N,) int32 polarity; optional: kind, obj, rso_tracks
  attrs (0-d arrays): duration_us, name
"""
from __future__ import annotations

from pathlib import Path
from typing import Iterator

import numpy as np

from repro.data.synthetic import Recording, make_validation_suite


def save_recording(rec: Recording, path: str | Path) -> None:
    np.savez_compressed(
        path,
        x=rec.x, y=rec.y, t=rec.t, p=rec.p,
        kind=rec.kind, obj=rec.obj, rso_tracks=rec.rso_tracks,
        duration_us=np.int64(rec.duration_us),
        name=np.str_(rec.name),
    )


def load_recording(path: str | Path) -> Recording:
    with np.load(path, allow_pickle=False) as z:
        n = len(z["t"])
        return Recording(
            x=z["x"].astype(np.int32),
            y=z["y"].astype(np.int32),
            t=z["t"].astype(np.int64),
            p=z["p"].astype(np.int32),
            kind=z["kind"].astype(np.int32) if "kind" in z else np.zeros(n, np.int32),
            obj=z["obj"].astype(np.int32) if "obj" in z else np.full(n, -1, np.int32),
            rso_tracks=z["rso_tracks"] if "rso_tracks" in z else np.zeros((0, 4)),
            duration_us=int(z["duration_us"]),
            name=str(z["name"]) if "name" in z else Path(path).stem,
        )


def load_validation_suite(directory: str | Path | None = None) -> list[Recording]:
    """Load real EVAS recordings if present, else the synthetic suite
    calibrated to the paper's statistics (DESIGN.md §6).

    Files are ordered by *name*, never by directory enumeration order —
    ``glob`` reflects filesystem insertion order on some platforms, and
    suite ordering decides sweep-output ordering, which must be stable
    across machines (regression-tested in tests/test_data_io.py).
    """
    if directory is not None:
        files = sorted(Path(directory).glob("*.npz"), key=lambda f: f.name)
        if files:
            return [load_recording(f) for f in files]
    return make_validation_suite()


def iter_chunks(
    rec: Recording, chunk_us: int = 20_000
) -> Iterator[tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]]:
    """Chunked replay over a recording — the shape a live EBC client feeds.

    Yields ``(x, y, t, p)`` slices covering fixed ``chunk_us`` spans of
    *event time*, anchored at the first event (the cadence a live sensor
    delivers to :class:`repro.serve.service.DetectionService` or a
    streaming/fleet pipeline). Chunks partition the stream exactly:
    concatenating every chunk reproduces the recording's arrays verbatim,
    and a span containing no events yields empty arrays (a live client's
    heartbeat) rather than being skipped, so chunk index x ``chunk_us``
    stays aligned with wall time.
    """
    if chunk_us < 1:
        raise ValueError(f"chunk_us must be >= 1, got {chunk_us}")
    from repro.core.events import stride_bounds  # data<->core: import lazily

    for lo, hi, _ in stride_bounds(rec.t, chunk_us):
        yield rec.x[lo:hi], rec.y[lo:hi], rec.t[lo:hi], rec.p[lo:hi]
