"""EVAS dataset interface (Valdivia et al. 2025, kaggle.com/ds/5688319).

The dataset is hosted on Kaggle and unavailable offline, so this module
defines the on-disk interchange format the pipeline consumes and a
loader that falls back to the calibrated synthetic generator. A real
EVAS download converted to this .npz layout drops in without code
changes:

  arrays: x (N,) int32, y (N,) int32, t (N,) int64 microseconds,
          p (N,) int32 polarity; optional: kind, obj, rso_tracks
  attrs (0-d arrays): duration_us, name
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.data.synthetic import Recording, make_validation_suite


def save_recording(rec: Recording, path: str | Path) -> None:
    np.savez_compressed(
        path,
        x=rec.x, y=rec.y, t=rec.t, p=rec.p,
        kind=rec.kind, obj=rec.obj, rso_tracks=rec.rso_tracks,
        duration_us=np.int64(rec.duration_us),
        name=np.str_(rec.name),
    )


def load_recording(path: str | Path) -> Recording:
    with np.load(path, allow_pickle=False) as z:
        n = len(z["t"])
        return Recording(
            x=z["x"].astype(np.int32),
            y=z["y"].astype(np.int32),
            t=z["t"].astype(np.int64),
            p=z["p"].astype(np.int32),
            kind=z["kind"].astype(np.int32) if "kind" in z else np.zeros(n, np.int32),
            obj=z["obj"].astype(np.int32) if "obj" in z else np.full(n, -1, np.int32),
            rso_tracks=z["rso_tracks"] if "rso_tracks" in z else np.zeros((0, 4)),
            duration_us=int(z["duration_us"]),
            name=str(z["name"]) if "name" in z else Path(path).stem,
        )


def load_validation_suite(directory: str | Path | None = None) -> list[Recording]:
    """Load real EVAS recordings if present, else the synthetic suite
    calibrated to the paper's statistics (DESIGN.md §6)."""
    if directory is not None:
        files = sorted(Path(directory).glob("*.npz"))
        if files:
            return [load_recording(f) for f in files]
    return make_validation_suite()
