"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 16 transformer cycles reports 1/16th of the real FLOPs,
and collectives inside the loop body vanish from the totals. This module
parses the optimized (post-SPMD) HLO text and aggregates:

* flops — dot ops: 2 * |result| * contracted-dim product,
* bytes — per top-level instruction: result + operand bytes, with
  slice-aware fusion accounting (a fusion parameter consumed only by a
  (dynamic-)slice counts the slice, not the whole buffer — this is the
  scan param-slice pattern),
* collective payload bytes per kind (ring-cost approximations:
  all-gather/all-reduce count gathered/2x bytes, others operand bytes),

each multiplied by the enclosing ``while`` trip count, which XLA exposes
as ``backend_config={"known_trip_count":{"n":...}}``.

Shapes in post-SPMD HLO are per-device, so all totals are PER-DEVICE.
"""
from __future__ import annotations

import dataclasses
import json
import re
from functools import lru_cache

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->")
_INST_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*((?:\([^)]*\))|(?:[\w\[\],{}]+))\s*"
    r"([\w\-]+)\("
)
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)


def _parse_shape(s: str) -> list[tuple[str, list[int]]]:
    """'(f32[2,3]{1,0}, s32[])' or 'f32[16,128]{1,0}' -> [(dtype, dims)]."""
    return [
        (dt, [int(d) for d in dims.split(",") if d])
        for dt, dims in _SHAPE_RE.findall(s)
    ]


def _shape_bytes(shapes: list[tuple[str, list[int]]]) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(shapes: list[tuple[str, list[int]]]) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instruction:
    name: str
    shape: list  # [(dtype, dims)]
    opcode: str
    line: str
    operands: list[str]


@dataclasses.dataclass
class Computation:
    name: str
    instructions: list
    is_entry: bool = False

    def __post_init__(self):
        self.by_name = {i.name: i for i in self.instructions}


def parse_module(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEADER_RE.match(line)
            if m and line.endswith("{"):
                cur = Computation(m.group(2), [], is_entry=bool(m.group(1)))
            continue
        if line.startswith("}"):
            comps[cur.name] = Computation(cur.name, cur.instructions, cur.is_entry)
            cur = None
            continue
        m = _INST_RE.match(line)
        if not m:
            continue
        name, shape_str, opcode = m.group(1), m.group(2), m.group(3)
        paren = line[m.end() - 1:]
        # operands = refs inside the first (...) group
        depth = 0
        args = ""
        for ch in paren:
            if ch == "(":
                depth += 1
                if depth == 1:
                    continue
            if ch == ")":
                depth -= 1
                if depth == 0:
                    break
            if depth >= 1:
                args += ch
        cur.instructions.append(
            Instruction(name, _parse_shape(shape_str), opcode, line,
                        _OPERAND_RE.findall(args))
        )
    return comps


def _trip_count(line: str) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', line)
    return int(m.group(1)) if m else 1


def _attr_comp(line: str, attr: str) -> str | None:
    m = re.search(rf"{attr}=%?([\w\.\-]+)", line)
    return m.group(1) if m else None


_FLOAT_EMUL = {"f32", "bf16", "f16"}


def _is_free_convert(inst: Instruction, comp: "Computation") -> bool:
    """True for float<->float converts XLA:CPU inserts to emulate bf16
    (its float-normalization pass). These do not exist on the TPU target
    (native bf16), so they are costed at zero; see module docstring."""
    if inst.opcode != "convert" or not inst.shape:
        return False
    out_dt, out_dims = inst.shape[0]
    if out_dt not in _FLOAT_EMUL:
        return False
    src = comp.by_name.get(inst.operands[0]) if inst.operands else None
    if src is None or not src.shape:
        return False
    in_dt, in_dims = src.shape[0]
    return in_dt in _FLOAT_EMUL and in_dims == out_dims


def _resolve_through_converts(comp: "Computation", inst: Instruction) -> Instruction:
    """Follow a chain of same-shape float converts back to its source."""
    seen = 0
    while inst.opcode == "convert" and inst.operands and seen < 8:
        src = comp.by_name.get(inst.operands[0])
        if src is None:
            break
        inst = src
        seen += 1
    return inst


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict | None = None

    def __post_init__(self):
        if self.coll is None:
            self.coll = {}

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult


class Analyzer:
    def __init__(self, hlo: str):
        self.comps = parse_module(hlo)
        self._memo: dict[str, Cost] = {}

    # -- shape resolution ---------------------------------------------------

    def _operand_shapes(self, comp: Computation, inst: Instruction):
        out = []
        for op in inst.operands:
            d = comp.by_name.get(op)
            if d is not None:
                out.append(d.shape)
        return out

    # -- per-instruction costs ----------------------------------------------

    def _dot_flops(self, comp: Computation, inst: Instruction) -> float:
        result_elems = _shape_elems(inst.shape)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", inst.line)
        cdims = [int(x) for x in m.group(1).split(",") if x] if m else []
        lhs = comp.by_name.get(inst.operands[0]) if inst.operands else None
        k = 1
        if lhs is not None and lhs.shape:
            dims = lhs.shape[0][1]
            for c in cdims:
                if c < len(dims):
                    k *= dims[c]
        return 2.0 * result_elems * k

    def _conv_flops(self, comp: Computation, inst: Instruction) -> float:
        # flops = 2 * |result| * (kernel spatial x in_features)
        result_elems = _shape_elems(inst.shape)
        rhs = comp.by_name.get(inst.operands[1]) if len(inst.operands) > 1 else None
        k = 1
        if rhs is not None and rhs.shape:
            for d in rhs.shape[0][1][:-1]:  # all but output-feature dim
                k *= d
        return 2.0 * result_elems * k

    def _fusion_operand_bytes(self, comp: Computation, inst: Instruction) -> float:
        """Slice-aware: params only consumed by (dynamic-)slice count the
        slice result size, not the whole buffer."""
        callee_name = _attr_comp(inst.line, "calls")
        callee = self.comps.get(callee_name) if callee_name else None
        total = 0.0
        op_shapes = []
        for op in inst.operands:
            d = comp.by_name.get(op)
            op_shapes.append(d.shape if d else None)
        if callee is None:
            for s in op_shapes:
                if s:
                    total += _shape_bytes(s)
            return total
        # map param index -> param instruction name
        params: dict[int, Instruction] = {}
        for i in callee.instructions:
            if i.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", i.line)
                if m:
                    params[int(m.group(1))] = i
        for idx, op in enumerate(inst.operands):
            shape = op_shapes[idx]
            if shape is None:
                continue
            pinst = params.get(idx)
            eff = _shape_bytes(shape)
            if pinst is not None:
                # Look through emulation converts to the real consumers.
                frontier = [pinst.name]
                uses: list[Instruction] = []
                for _ in range(8):
                    new_frontier = []
                    for u in callee.instructions:
                        if u.opcode == "parameter" or not u.operands:
                            continue
                        if any(f in u.operands for f in frontier):
                            if _is_free_convert(u, callee):
                                new_frontier.append(u.name)
                            else:
                                uses.append(u)
                    if not new_frontier:
                        break
                    frontier = new_frontier
                if uses and all(
                    u.opcode in ("dynamic-slice", "slice")
                    for u in uses
                ):
                    eff = sum(_shape_bytes(u.shape) for u in uses)
                elif uses and all(
                    u.opcode == "dynamic-update-slice" for u in uses
                ):
                    # In-place update: traffic = the written region only.
                    eff = 0.0
                    for u in uses:
                        upd = callee.by_name.get(u.operands[1]) if len(u.operands) > 1 else None
                        if upd is not None:
                            upd = _resolve_through_converts(callee, upd)
                        eff += _shape_bytes(upd.shape) if upd else _shape_bytes(u.shape)
            total += eff
        return total

    def _fusion_result_bytes(self, inst: Instruction) -> float:
        """If the fusion root is a dynamic-update-slice (in-place buffer
        write), effective output traffic is the update region, not the
        whole buffer. Emulation converts around the root are skipped."""
        callee_name = _attr_comp(inst.line, "calls")
        callee = self.comps.get(callee_name) if callee_name else None
        if callee is not None and callee.instructions:
            root = _resolve_through_converts(callee, callee.instructions[-1])
            if root.opcode == "dynamic-update-slice" and len(root.operands) > 1:
                upd = callee.by_name.get(root.operands[1])
                if upd is not None:
                    upd = _resolve_through_converts(callee, upd)
                    return _shape_bytes(upd.shape)
                return _shape_bytes(root.shape)
        return _shape_bytes(inst.shape)

    # -- computation cost ----------------------------------------------------

    def cost(self, comp_name: str) -> Cost:
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        total = Cost()
        self._memo[comp_name] = total  # break cycles defensively
        if comp is None:
            return total
        for inst in comp.instructions:
            op = inst.opcode
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all"):
                continue
            base_kind = op[:-6] if op.endswith("-start") else op
            if base_kind.endswith("-done"):
                continue
            if base_kind in COLLECTIVES:
                if base_kind == "all-gather":
                    payload = _shape_bytes(inst.shape)
                elif base_kind == "all-reduce":
                    payload = 2.0 * _shape_bytes(inst.shape)
                else:
                    ops = self._operand_shapes(comp, inst)
                    payload = sum(_shape_bytes(s) for s in ops) or _shape_bytes(inst.shape)
                total.coll[base_kind] = total.coll.get(base_kind, 0.0) + payload
                total.bytes += _shape_bytes(inst.shape)
                continue
            if op == "dot":
                total.flops += self._dot_flops(comp, inst)
                total.bytes += _shape_bytes(inst.shape) + sum(
                    _shape_bytes(s) for s in self._operand_shapes(comp, inst)
                )
                continue
            if op == "convolution":
                total.flops += self._conv_flops(comp, inst)
                total.bytes += _shape_bytes(inst.shape) + sum(
                    _shape_bytes(s) for s in self._operand_shapes(comp, inst)
                )
                continue
            if op == "convert" and _is_free_convert(inst, comp):
                continue  # XLA:CPU bf16-emulation artifact, free on TPU
            if op in ("dynamic-slice", "slice"):
                total.bytes += 2.0 * _shape_bytes(inst.shape)
                continue
            if op == "dynamic-update-slice":
                upd = comp.by_name.get(inst.operands[1]) if len(inst.operands) > 1 else None
                total.bytes += 2.0 * (_shape_bytes(upd.shape) if upd else _shape_bytes(inst.shape))
                continue
            if op == "fusion":
                callee = _attr_comp(inst.line, "calls")
                if callee:
                    child = self.cost(callee)
                    total.flops += child.flops  # dots inside fusions
                    for k, v in child.coll.items():
                        total.coll[k] = total.coll.get(k, 0.0) + v
                total.bytes += self._fusion_result_bytes(inst) + self._fusion_operand_bytes(comp, inst)
                continue
            if op == "while":
                body = _attr_comp(inst.line, "body")
                cond = _attr_comp(inst.line, "condition")
                trip = _trip_count(inst.line)
                if body:
                    total.add(self.cost(body), trip)
                if cond:
                    total.add(self.cost(cond), trip)
                continue
            if op in ("call", "async-start"):
                callee = _attr_comp(inst.line, "to_apply") or _attr_comp(inst.line, "calls")
                if callee:
                    total.add(self.cost(callee), 1.0)
                continue
            if op == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|branch_computations=\{)([^,}]+)", inst.line):
                    total.add(self.cost(m.group(1).strip("% ")), 1.0)
                continue
            # generic elementwise / data movement: bytes only
            total.bytes += _shape_bytes(inst.shape) + sum(
                _shape_bytes(s) for s in self._operand_shapes(comp, inst)
            )
        self._memo[comp_name] = total
        return total

    def entry_cost(self) -> Cost:
        for name, comp in self.comps.items():
            if comp.is_entry:
                return self.cost(name)
        raise ValueError("no ENTRY computation found")


def analyze(hlo_text: str) -> dict:
    """Per-device {flops, bytes, coll_bytes, coll_breakdown} for a module."""
    c = Analyzer(hlo_text).entry_cost()
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "coll_bytes": float(sum(c.coll.values())),
        "coll_breakdown": {k: float(v) for k, v in sorted(c.coll.items())},
    }


def top_collectives(hlo_text: str, n: int = 20) -> list[dict]:
    """The N largest collective ops (payload x trips), with op metadata —
    the profiler view for collective-bound hillclimbing."""
    an = Analyzer(hlo_text)
    entry = next(c for c in an.comps.values() if c.is_entry)

    # trip multiplier per computation (map comp -> product of enclosing trips)
    mult: dict[str, float] = {entry.name: 1.0}
    stack = [entry.name]
    while stack:
        name = stack.pop()
        comp = an.comps.get(name)
        if comp is None:
            continue
        for inst in comp.instructions:
            for attr, factor in (("calls", 1.0), ("body", None), ("condition", None), ("to_apply", 1.0)):
                callee = _attr_comp(inst.line, attr)
                if not callee or callee not in an.comps:
                    continue
                f = _trip_count(inst.line) if factor is None else factor
                m = mult.get(name, 1.0) * f
                if mult.get(callee, 0.0) < m:
                    mult[callee] = m
                    stack.append(callee)

    out = []
    for cname, comp in an.comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        for inst in comp.instructions:
            kind = inst.opcode[:-6] if inst.opcode.endswith("-start") else inst.opcode
            if kind not in COLLECTIVES:
                continue
            payload = _shape_bytes(inst.shape)
            meta = re.search(r'op_name="([^"]*)"', inst.line)
            out.append({
                "kind": kind,
                "bytes": payload * m,
                "trips": m,
                "shape": inst.line.split(" ", 3)[2] if len(inst.line.split(" ", 3)) > 2 else "",
                "op_name": meta.group(1) if meta else "",
            })
    out.sort(key=lambda d: -d["bytes"])
    return out[:n]


def _mult_map(an: "Analyzer") -> dict[str, float]:
    entry = next(c for c in an.comps.values() if c.is_entry)
    mult: dict[str, float] = {entry.name: 1.0}
    stack = [entry.name]
    while stack:
        name = stack.pop()
        comp = an.comps.get(name)
        if comp is None:
            continue
        for inst in comp.instructions:
            for attr in ("calls", "body", "condition", "to_apply"):
                callee = _attr_comp(inst.line, attr)
                if not callee or callee not in an.comps:
                    continue
                f = _trip_count(inst.line) if attr in ("body", "condition") else 1.0
                m = mult.get(name, 1.0) * f
                if mult.get(callee, 0.0) < m:
                    mult[callee] = m
                    stack.append(callee)
    return mult


def top_bytes(hlo_text: str, n: int = 20) -> list[dict]:
    """The N largest byte-moving instructions (bytes x trips) — the
    profiler view for memory-bound hillclimbing."""
    an = Analyzer(hlo_text)
    mult = _mult_map(an)
    out = []
    for cname, comp in an.comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        for inst in comp.instructions:
            op = inst.opcode
            if op in ("parameter", "constant", "get-tuple-element", "tuple",
                      "bitcast", "after-all", "while", "call"):
                continue
            if op == "convert" and _is_free_convert(inst, comp):
                continue
            if op == "fusion":
                b = an._fusion_result_bytes(inst) + an._fusion_operand_bytes(comp, inst)
            elif op in ("dynamic-slice", "slice"):
                b = 2.0 * _shape_bytes(inst.shape)
            elif op == "dynamic-update-slice":
                upd = comp.by_name.get(inst.operands[1]) if len(inst.operands) > 1 else None
                b = 2.0 * (_shape_bytes(upd.shape) if upd else _shape_bytes(inst.shape))
            else:
                b = _shape_bytes(inst.shape) + sum(
                    _shape_bytes(s) for s in an._operand_shapes(comp, inst)
                )
            meta = re.search(r'op_name="([^"]*)"', inst.line)
            out.append({
                "bytes": b * m,
                "trips": m,
                "opcode": op,
                "op_name": meta.group(1) if meta else inst.name,
            })
    out.sort(key=lambda d: -d["bytes"])
    return out[:n]


def top_dots(hlo_text: str, n: int = 20) -> list[dict]:
    """The N largest matmuls (flops x trips) with metadata."""
    an = Analyzer(hlo_text)
    entry = next(c for c in an.comps.values() if c.is_entry)
    mult: dict[str, float] = {entry.name: 1.0}
    stack = [entry.name]
    while stack:
        name = stack.pop()
        comp = an.comps.get(name)
        if comp is None:
            continue
        for inst in comp.instructions:
            for attr in ("calls", "body", "condition", "to_apply"):
                callee = _attr_comp(inst.line, attr)
                if not callee or callee not in an.comps:
                    continue
                f = _trip_count(inst.line) if attr in ("body", "condition") else 1.0
                m = mult.get(name, 1.0) * f
                if mult.get(callee, 0.0) < m:
                    mult[callee] = m
                    stack.append(callee)
    out = []
    for cname, comp in an.comps.items():
        m = mult.get(cname)
        if m is None:
            continue
        for inst in comp.instructions:
            if inst.opcode != "dot":
                continue
            fl = an._dot_flops(comp, inst)
            meta = re.search(r'op_name="([^"]*)"', inst.line)
            out.append({
                "flops": fl * m,
                "trips": m,
                "op_name": meta.group(1) if meta else "",
            })
    out.sort(key=lambda d: -d["flops"])
    return out[:n]
