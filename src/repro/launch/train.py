"""End-to-end training driver.

Usage (CPU-scale example; the production path is the same code under the
dry-run meshes):

  python -m repro.launch.train --arch llama3.2-1b --preset tiny \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.data import lm_data
from repro.models.transformer import init_params
from repro.train.checkpoint import CheckpointManager
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step

PRESETS = {
    # ~100M-param class config used by examples and the e2e test.
    "small100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
                      d_ff=3072, vocab=32000),
    "tiny": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                 d_ff=256, vocab=1024),
}


def reduced_config(arch: str, preset: str | None) -> ModelConfig:
    cfg = get_config(arch)
    if preset is None:
        return cfg
    over = dict(PRESETS[preset])
    if cfg.n_kv_heads == 1:
        over["n_kv_heads"] = 1
    if cfg.n_experts:
        over.update(n_experts=4, top_k=2, d_ff=over["d_ff"] // 4)
    if cfg.use_mla:
        over.update(q_lora_rank=256, kv_lora_rank=128, qk_nope_dim=32,
                    qk_rope_dim=16, v_head_dim=32, head_dim=48)
    if cfg.lru_width:
        over["lru_width"] = over["d_model"]
    if cfg.mrope_sections:
        hd = over["d_model"] // over["n_heads"]
        over["head_dim"] = hd
        over["mrope_sections"] = (hd // 8, hd // 4 - hd // 8 - hd // 16, hd // 16)
        # keep sections summing to hd//2
        s = over["mrope_sections"]
        over["mrope_sections"] = (s[0], s[1], hd // 2 - s[0] - s[1])
    return dataclasses.replace(cfg, dtype="float32", **over)


def train(
    arch: str = "llama3.2-1b",
    preset: str | None = "tiny",
    steps: int = 50,
    batch: int = 8,
    seq: int = 128,
    lr: float = 3e-3,
    ckpt_dir: str | None = None,
    log_every: int = 10,
    seed: int = 0,
):
    cfg = reduced_config(arch, preset)
    tcfg = TrainConfig(
        opt=OptConfig(lr=lr, warmup_steps=max(steps // 10, 1), total_steps=steps),
        remat=False,
    )
    params = init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = init_opt_state(params)
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    ckpt = CheckpointManager(ckpt_dir) if ckpt_dir else None
    log: list[dict] = []
    t0 = time.time()
    for i, b in enumerate(lm_data.batches(cfg.vocab, batch, seq, steps, seed)):
        params, opt_state, metrics = step_fn(params, opt_state, b)
        if i % log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m.update(step=i, wall_s=round(time.time() - t0, 1))
            log.append(m)
            print(
                f"step {i:5d} loss {m['loss']:.4f} acc {m['accuracy']:.3f} "
                f"lr {m['lr']:.2e} gnorm {m['grad_norm']:.2f} ({m['wall_s']}s)"
            )
        if ckpt is not None and (i + 1) % 20 == 0:
            ckpt.save_async(i, {"params": params, "opt": opt_state})
    if ckpt is not None:
        ckpt.wait()
    return params, log


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--preset", default="tiny", choices=[*PRESETS, "full"])
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    preset = None if args.preset == "full" else args.preset
    train(args.arch, preset, args.steps, args.batch, args.seq, args.lr, args.ckpt_dir)


if __name__ == "__main__":
    main()
