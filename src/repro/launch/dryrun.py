import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# --- everything below may import jax -------------------------------------
"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory/cost/roofline artifacts.

This file proves the distribution config is coherent without hardware:
``.lower().compile()`` must succeed for the 16x16 single-pod mesh AND the
2x16x16 multi-pod mesh for every assigned architecture x input shape.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out benchmarks/dryrun_results
"""
import argparse
import dataclasses
import json
import time
import traceback
from functools import partial
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import LM_SHAPES, ModelConfig, applicable_shapes, get_config, list_archs
from repro.distributed import sharding as S
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh, use_mesh
from repro.models.transformer import decode_step, init_cache, init_params, prefill
from repro.train.optimizer import init_opt_state
from repro.train.train_step import TrainConfig, make_train_step

DEFAULT_OUT = Path("benchmarks/dryrun_results")


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    spec = LM_SHAPES[shape_name]
    b, s = spec.global_batch, spec.seq_len
    if spec.kind == "decode":
        s_in = 1
    else:
        s_in = s
    inputs: dict = {}
    if cfg.frontend is not None:
        inputs["embeds"] = sds((b, s_in, cfg.d_model), jnp.bfloat16)
    else:
        inputs["tokens"] = sds((b, s_in), jnp.int32)
    if cfg.pos_kind == "mrope" and spec.kind != "decode":
        inputs["mrope_positions"] = sds((3, b, s_in), jnp.int32)
    return inputs


def _rules(mesh, kind: str, features: frozenset = frozenset()) -> S.ShardingRules:
    multi = "pod" in mesh.axis_names
    if kind == "train":
        return S.MULTIPOD_TRAIN_RULES if multi else S.TRAIN_RULES
    if "tp2d" in features:
        return S.MULTIPOD_SERVE_2D_RULES if multi else S.SERVE_2D_RULES
    return S.MULTIPOD_SERVE_RULES if multi else S.SERVE_RULES


def _batch_sharding(mesh, rules, tree):
    """NamedShardings for an input dict (batch-dim over dp)."""

    def leaf(path, x):
        name = path[-1].key if path else ""
        if name == "mrope_positions":
            spec = P(None, rules.dp if len(rules.dp) > 1 else rules.dp[0], None)
        else:
            spec = S.batch_spec(rules, extra_dims=x.ndim - 1)
        # divisibility fallback
        dp_size = 1
        for a in rules.dp:
            dp_size *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
        bdim = 1 if name == "mrope_positions" else 0
        if x.shape[bdim] % dp_size != 0:
            spec = P(*([None] * x.ndim))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, tree)


# Cache sharding rules by leaf name (right-aligned, divisibility-checked).
_CACHE_ROLES = {
    "k_page": ("dp", None, None, None),
    "v_page": ("dp", None, None, None),
    "page_pos": (None,),
    "k": ("dp", None, "tp", None),
    "v": ("dp", None, "tp", None),
    "c_kv": ("dp", None, "tp"),
    "k_rope": ("dp", None, None),
    "pos": (None,),
    "h": ("dp", "tp"),
    "conv": ("dp", None, "tp"),
    "c": ("dp", None, None, None),
    "n": ("dp", None, None),
    "m": ("dp", None),
}

# Hillclimb variant: shard the cache SEQUENCE dim over the model axis
# (context parallelism for decode). The head-count dim of GQA caches is
# rarely divisible by 16; the 32k sequence always is.
_CACHE_ROLES_SEQ = dict(
    _CACHE_ROLES,
    k=("dp", "tp", None, None),
    v=("dp", "tp", None, None),
    c_kv=("dp", "tp", None),
    k_rope=("dp", "tp", None),
)


def _cache_sharding(mesh, rules, cache_tree, roles_table=None):
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    table = roles_table or _CACHE_ROLES

    def role_axes(role):
        if role == "dp":
            return tuple(a for a in rules.dp if a in axis_sizes)
        if role == "tp":
            parts = rules.tp if isinstance(rules.tp, tuple) else (rules.tp,)
            return tuple(a for a in parts if a in axis_sizes)
        return ()

    def leaf(path, x):
        name = path[-1].key if path and isinstance(path[-1], jax.tree_util.DictKey) else ""
        roles = table.get(name)
        if roles is None:
            return NamedSharding(mesh, P())
        nd = x.ndim
        spec: list = [None] * nd
        for i, role in enumerate(roles):
            dim = nd - len(roles) + i
            if dim < 0 or role is None:
                continue
            axes = role_axes(role)
            total = 1
            for a in axes:
                total *= axis_sizes[a]
            if axes and x.shape[dim] % total == 0:
                spec[dim] = axes if len(axes) > 1 else axes[0]
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(leaf, cache_tree)


def build_cell(cfg: ModelConfig, shape_name: str, mesh, variant: str = ""):
    """Returns (fn, args, in_shardings, out_shardings, donate) for a cell.

    ``variant`` is a comma-separated optimization feature list recorded in
    EXPERIMENTS.md SPerf: cache_seq (sequence-parallel decode cache),
    serve_bf16 (bf16 weights for inference), tp2d (2D tensor parallelism
    for tiny-batch serving), moe_hint (MoE dispatch sharding constraints).
    """
    features = frozenset(f for f in variant.split(",") if f)
    import repro.models.moe as _moe
    _moe.USE_SHARDING_HINTS = "moe_hint" in features
    import repro.models.attention as _attn
    _attn.CACHE_DTYPE_DOTS = "bf16_dots" in features
    import repro.models.transformer as _tf
    _tf.PAGED_DECODE = 256 if "paged" in features else 0
    _attn.Q_CHUNK = 1024 if "flash_chunks" in features else 512
    _attn.KV_CHUNK = 4096 if "flash_chunks" in features else 1024
    spec = LM_SHAPES[shape_name]
    kind = spec.kind
    rules = _rules(mesh, kind, features)
    if "moe_ep_only" in features:
        rules = dataclasses.replace(rules, moe_ep_only=True)
    if "moe_hint" in features or "moe_ep_only" in features:
        pass  # hints flag handled above via USE_SHARDING_HINTS
    params_sds = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))
    if "serve_bf16" in features and kind != "train":
        params_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            params_sds,
        )
    cache_roles = _CACHE_ROLES_SEQ if "cache_seq" in features else None
    pspecs = S.partition_params(params_sds, rules, mesh)
    pshard = jax.tree.map(lambda sp: NamedSharding(mesh, sp), pspecs)
    inputs = input_specs(cfg, shape_name)
    in_batch_shard = _batch_sharding(mesh, rules, inputs)

    if kind == "train":
        batch = dict(inputs)
        b, s = spec.global_batch, spec.seq_len
        batch["labels"] = sds((b, s), jnp.int32)
        bshard = _batch_sharding(mesh, rules, batch)
        opt_sds = jax.eval_shape(partial(init_opt_state), params_sds)
        oshard = {
            "step": NamedSharding(mesh, P()),
            "mu": pshard,
            "nu": pshard,
        }
        fn = make_train_step(cfg, TrainConfig())
        metrics_sds = jax.eval_shape(fn, params_sds, opt_sds, batch)[2]
        mshard = jax.tree.map(lambda _: NamedSharding(mesh, P()), metrics_sds)
        return (
            fn,
            (params_sds, opt_sds, batch),
            (pshard, oshard, bshard),
            (pshard, oshard, mshard),
            (0, 1),
        )

    if kind == "prefill":
        fn = partial(prefill, cfg=cfg, cache_len=spec.seq_len)
        logits_sds, cache_sds = jax.eval_shape(fn, params_sds, inputs)
        cshard = _cache_sharding(mesh, rules, cache_sds, cache_roles)
        lshard = NamedSharding(
            mesh, S.batch_spec(rules, extra_dims=1)
            if logits_sds.shape[0] % _dp_size(mesh, rules) == 0 else P()
        )
        return (
            fn,
            (params_sds, inputs),
            (pshard, in_batch_shard),
            (lshard, cshard),
            (),
        )

    # decode
    cache_sds = jax.eval_shape(
        lambda: init_cache(cfg, spec.global_batch, spec.seq_len,
                           stacked="flat_cache" not in features)
    )
    if "serve_bf16" in features:
        cache_sds = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, jnp.bfloat16)
            if x.dtype == jnp.dtype("float32") and x.ndim >= 3 else x,
            cache_sds,
        )
    cshard = _cache_sharding(mesh, rules, cache_sds, cache_roles)
    pos_sds = sds((), jnp.int32)
    unroll_mode = "carry" if "cache_carry" in features else ("unroll" in features)
    fn = partial(decode_step, cfg=cfg, unroll=unroll_mode)
    lshard = NamedSharding(
        mesh, S.batch_spec(rules, extra_dims=1)
        if spec.global_batch % _dp_size(mesh, rules) == 0 else P()
    )
    return (
        fn,
        (params_sds, inputs, cache_sds, pos_sds),
        (pshard, in_batch_shard, cshard, NamedSharding(mesh, P())),
        (lshard, cshard),
        (2,),
    )


def _dp_size(mesh, rules) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    total = 1
    for a in rules.dp:
        total *= sizes.get(a, 1)
    return total


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             variant: str = "") -> dict:
    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_dev = mesh.devices.size
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "variant": variant, "n_devices": int(n_dev), "ok": False,
    }
    t0 = time.time()
    try:
        fn, args, in_shardings, out_shardings, donate = build_cell(cfg, shape_name, mesh, variant)
        with use_mesh(mesh):
            jitted = jax.jit(
                fn,
                in_shardings=in_shardings,
                out_shardings=out_shardings,
                donate_argnums=donate,
            )
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        print(mem)
        cost = compiled.cost_analysis()
        raw_cost = {
            k: v for k, v in (cost or {}).items() if k in ("flops", "bytes accessed")
        }
        print(raw_cost)
        terms = R.extract_terms(compiled, n_dev)
        spec = LM_SHAPES[shape_name]
        tokens = spec.global_batch * (spec.seq_len if spec.kind != "decode" else 1)
        mf = R.model_flops(
            cfg.param_count(), tokens,
            cfg.active_param_count() if cfg.n_experts else None,
            kind=spec.kind,
        )
        rec.update(
            ok=True,
            t_lower_s=round(t_lower, 1),
            t_compile_s=round(t_compile, 1),
            memory={
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "peak_memory_in_bytes",
                )
                if hasattr(mem, k)
            },
            roofline=terms.as_dict(),
            raw_cost_analysis=raw_cost,
            model_flops=mf,
            useful_flops_ratio=(
                (mf / (terms.flops * n_dev)) if terms.flops else None
            ),
        )
    except Exception as e:  # noqa: BLE001 - record and continue
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["wall_s"] = round(time.time() - t0, 1)
    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{variant.replace(',', '+')}" if variant else ""
    fname = f"{arch.replace('/', '_')}__{shape_name}__{mesh_kind}{suffix}.json"
    (out_dir / fname).write_text(json.dumps(rec, indent=2))
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '')[:120]})"
    print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: {status} "
          f"({rec['wall_s']}s)")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(DEFAULT_OUT))
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="")
    args = ap.parse_args()
    out_dir = Path(args.out)

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_ok = n_fail = 0
    for arch in archs:
        cfg = get_config(arch)
        shapes = (
            applicable_shapes(cfg) if (args.all or args.shape is None) else [args.shape]
        )
        for shape_name in shapes:
            for mesh_kind in meshes:
                suffix = f"__{args.variant.replace(',', '+')}" if args.variant else ""
                fname = out_dir / f"{arch.replace('/', '_')}__{shape_name}__{mesh_kind}{suffix}.json"
                if args.skip_existing and fname.exists():
                    prev = json.loads(fname.read_text())
                    if prev.get("ok"):
                        print(f"[dryrun] skip existing OK: {fname.name}")
                        n_ok += 1
                        continue
                rec = run_cell(arch, shape_name, mesh_kind, out_dir, args.variant)
                n_ok += rec["ok"]
                n_fail += not rec["ok"]
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
