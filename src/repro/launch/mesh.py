"""Production mesh construction (TPU v5e-like pods).

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh helper for tests/examples (e.g. (8,) 'node' arrays)."""
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(shape))


# TPU v5e-like hardware constants for the roofline model.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per-direction approximation)
