"""Production mesh construction (TPU v5e-like pods).

Defined as functions (never module-level constants) so importing this
module never touches jax device state.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.4.35-ish; older releases have no explicit axis types
    from jax.sharding import AxisType

    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
except ImportError:  # pragma: no cover - depends on installed jax
    _AXIS_KW = lambda n: {}  # noqa: E731

try:  # jax >= 0.6 exposes shard_map at the top level
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map  # noqa: F401


def use_mesh(mesh):
    """Context manager activating ``mesh``: jax.set_mesh on new jax,
    the Mesh object's own context manager on older releases."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(shape)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh helper for tests/examples (e.g. (8,) 'node' arrays)."""
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(shape)))


# TPU v5e-like hardware constants for the roofline model.
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (per-direction approximation)
