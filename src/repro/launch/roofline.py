"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = collective_bytes_per_device / link_bw

FLOPs/bytes/collective-bytes come from ``launch.hlo_analysis`` — a
loop-aware analysis of the optimized post-SPMD HLO (XLA's own
``cost_analysis()`` counts while bodies once, so a scanned 95-layer model
would be undercounted ~95x; see hlo_analysis docstring). Post-SPMD shapes
are per-device, so terms are per-chip directly. Raw ``cost_analysis()``
numbers are retained in the dry-run JSON for reference.
"""
from __future__ import annotations

import dataclasses

from repro.launch.hlo_analysis import analyze
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


@dataclasses.dataclass
class RooflineTerms:
    flops: float  # per-device HLO FLOPs
    hbm_bytes: float  # per-device bytes moved
    coll_bytes: float  # per-device collective payload bytes
    n_devices: int
    coll_breakdown: dict = dataclasses.field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "coll_bytes_per_device": self.coll_bytes,
            "n_devices": self.n_devices,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "coll_breakdown": self.coll_breakdown,
        }


def extract_terms(compiled, n_devices: int) -> RooflineTerms:
    """Pull per-device roofline terms from a compiled artifact's HLO."""
    stats = analyze(compiled.as_text())
    return RooflineTerms(
        flops=stats["flops"],
        hbm_bytes=stats["bytes"],
        coll_bytes=stats["coll_bytes"],
        n_devices=n_devices,
        coll_breakdown=stats["coll_breakdown"],
    )


def model_flops(
    param_count: int,
    tokens: int,
    active_param_count: int | None = None,
    kind: str = "train",
) -> float:
    """MODEL_FLOPS: 6*N*D for training (fwd+bwd), 2*N*D for inference.
    MoE uses N_active."""
    n = active_param_count if active_param_count is not None else param_count
    factor = 6.0 if kind == "train" else 2.0
    return factor * n * tokens
