"""Batched-serving driver using the paper's dual-threshold batcher.

  python -m repro.launch.serve --arch llama3.2-1b --requests 24
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.launch.train import reduced_config
from repro.models.transformer import init_params
from repro.serve.lm import EngineConfig, Request, ServingEngine


def serve_demo(
    arch: str = "llama3.2-1b",
    n_requests: int = 24,
    prompt_len: int = 16,
    max_new: int = 8,
    max_batch: int = 8,
    max_delay_s: float = 0.02,
    seed: int = 0,
) -> dict:
    cfg = reduced_config(arch, "tiny")
    params = init_params(jax.random.PRNGKey(seed), cfg)
    engine = ServingEngine(
        params, cfg,
        EngineConfig(max_delay_s=max_delay_s, max_batch=max_batch,
                     max_seq=prompt_len + max_new + 1),
    )
    rng = np.random.default_rng(seed)
    t0 = time.monotonic()
    for i in range(n_requests):
        engine.submit(Request(
            rid=i,
            tokens=list(rng.integers(0, cfg.vocab, prompt_len)),
            max_new_tokens=max_new,
        ))
    done = engine.run_until_drained()
    wall = time.monotonic() - t0
    tokens_out = sum(len(r.output) for r in done)
    stats = {
        "requests": len(done),
        "tokens_generated": tokens_out,
        "wall_s": round(wall, 3),
        "tokens_per_s": round(tokens_out / wall, 1),
        "mean_batch_latency_s": round(
            float(np.mean([r.batch_latency_s for r in done])), 4
        ),
    }
    return stats


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--max-delay-ms", type=float, default=20.0)
    args = ap.parse_args()
    stats = serve_demo(
        args.arch, args.requests, max_batch=args.max_batch,
        max_delay_s=args.max_delay_ms / 1e3,
    )
    for k, v in stats.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
