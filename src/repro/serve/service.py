"""DetectionService: dynamic sensor sessions over the slot-pooled fleet.

The serving-shaped top of the detection stack (DESIGN.md Secs. 11, 13).
Sensors attach and detach at will; every attached session feeds raw
event chunks at its own cadence; the service micro-batches the queued
chunks under the paper's dual-threshold admission policy
(:mod:`repro.serve.batcher`) and drives the whole set through ONE
slot-pooled :class:`~repro.core.pipeline.fleet.FleetPipeline` step.

Contracts:

* **Bit-identity.** Every session's results — concatenated over its
  lifetime, including the detach tail — are bit-identical to a
  dedicated :class:`~repro.core.pipeline.stream.StreamingPipeline` fed
  the same chunks (and hence to the offline scan driver), for ANY
  interleaving of attach / feed / idle / detach across sessions,
  including slot recycling and capacity-tier promotion mid-stream.
  Pinned by tests/test_serve_service.py.
* **Fault isolation.** Faults on one sensor never perturb another:
  with :class:`~repro.serve.faults.FaultConfig` degraded modes enabled,
  a corrupt chunk quarantines only the offending session, a silent
  sensor is evicted by heartbeat deadline (slot flushed + recycled), an
  overloaded session sheds by its own queue budget, and a failed fleet
  step retries with backoff before the round is marked degraded with
  every taken chunk restored — healthy sessions' outputs stay
  bit-identical to a fault-free run throughout (the chaos harness in
  :mod:`repro.serve.chaos` pins this).
* **Compile discipline.** Slot occupancy never appears in a compiled
  shape: the fleet step is compiled per (pool capacity, windows-per-feed)
  only, so attach/detach churn costs zero compiles and a churn workload
  cycling 1 -> max sessions compiles at most one fleet step per
  capacity tier (the service pins ``uniform_fast_path=False`` so the
  static uniform variant cannot double that).
* **Atomic validation.** A chunk that is out of order — within itself
  or against its session's stream — or carries int32-unsafe garbage
  coordinates is refused at the ``feed`` call, before it is queued: no
  other session's state is touched. Under the strict default it raises
  ``ValueError`` (not even the offending session's state changes);
  under ``on_validation_error="quarantine"`` the offending session —
  and only it — is quarantined with a structured error record and its
  slot recycled.
"""
from __future__ import annotations

import bisect
import copy
import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.fleet import (
    DEFAULT_TIERS,
    FleetPipeline,
    PendingRound,
    SlotCarry,
    tier_capacity,
)
from repro.core.pipeline.scan import ScanResult
from repro.serve.batcher import AdmissionConfig, DualThresholdAdmitter
from repro.serve.faults import FaultConfig, SessionHealth
from repro.serve.sessions import (
    DETACHED,
    EVICTED,
    LIVE,
    MIGRATED,
    QUARANTINED,
    SensorSession,
    SessionError,
    SessionStats,
)


@dataclasses.dataclass
class ServedFeed:
    """One session's share of one fleet step.

    ``result`` is lazy: the fleet round behind it was dispatched
    asynchronously, and the per-sensor :class:`ScanResult` materializes
    (synchronizing with the device if needed) the first time it is read.
    Consuming several feeds from several in-flight rounds together costs
    one sync, not one per round — the pipelined-ingest contract
    (DESIGN.md Sec. 14). Everything else (``sid``, ``latency_ms``,
    ``num_windows``) is host data, readable without blocking.
    """

    sid: int
    latency_ms: float  # oldest queued chunk's arrival -> round dispatched
    _round: PendingRound = dataclasses.field(repr=False)
    _slot: int = dataclasses.field(repr=False)
    _result: ScanResult | None = dataclasses.field(default=None, repr=False)

    @property
    def num_windows(self) -> int:
        """Windows this step closed for the session (never blocks)."""
        return int(self._round.n_windows[self._slot])

    @property
    def result(self) -> ScanResult:
        """The session's trimmed result (materializes on first read)."""
        if self._result is None:
            self._result = self._round.result().sensor(self._slot)
        return self._result


@dataclasses.dataclass
class SessionExport:
    """One session's complete portable state (cross-shard migration).

    Produced by :meth:`DetectionService.export_session`, consumed by
    :meth:`DetectionService.adopt_session` on any service sharing the
    same :class:`~repro.core.pipeline.config.PipelineConfig`. Carries
    the fleet slot carry (the entire device-side stream state), the
    unstepped ingest queue with original arrival stamps, the monotone
    watermark, and the session's accumulated stats/error records — so
    the adopted stream resumes bit-identically and the operator-facing
    accounting survives the hop.
    """

    name: str
    carry: SlotCarry
    queue: list  # [(chunk, arrival_s)] in arrival order
    last_t: int | None
    stats: SessionStats
    errors: list[SessionError]

    @property
    def queued_events(self) -> int:
        return sum(len(c[2]) for c, _ in self.queue)


class DetectionService:
    """Micro-batched detection serving over a slot pool of sensor sessions.

    >>> svc = DetectionService(PipelineConfig(), tiers=(4, 8))
    >>> sid = svc.attach("station-7")
    >>> done = svc.feed(sid, x, y, t, p)   # [] until admission fires
    >>> done = svc.pump(force=True)        # or step the fleet explicitly
    >>> tail = svc.detach(sid)             # flush + recycle the slot

    ``feed`` queues the (validated) chunk and steps the fleet only when
    the admission policy fires — oldest queued chunk ``max_delay_s`` old
    OR ``max_items`` events queued fleet-wide — so concurrent sessions
    share one vmapped dispatch instead of paying one each. The returned
    list carries every session's results from that step, not just the
    caller's. ``pump(force=True)`` steps unconditionally (deterministic
    drivers, tests, drain-before-shutdown).

    ``faults`` selects the degraded modes (DESIGN.md Sec. 13): the
    default :class:`FaultConfig` is the strict contract above; a
    fault-tolerant deployment passes quarantine / queue budgets /
    heartbeat eviction / step-retry policies explicitly. ``sleep`` is
    the retry-backoff sleeper (injectable so tests and the chaos
    harness never really sleep).

    ``max_inflight_rounds`` is the ingest pipeline depth (DESIGN.md
    Sec. 14). The default 1 is the synchronous path: every round is
    awaited before ``_step`` returns, exactly the pre-pipelining
    behaviour. Depth N > 1 keeps up to N dispatched rounds in flight —
    host packing of the next round overlaps device compute of the
    previous ones — and an admission-triggered round arriving while the
    pipeline is full is *deferred* (queues intact, admission state
    untouched, per-session ``deferred_rounds`` incremented) rather than
    blocking the feed caller; ``pump(force=True)`` and detach/evict
    flushes instead apply backpressure by retiring the oldest round.
    Outputs are bit-identical at every depth for any chunking/churn
    schedule.

    ``wire`` selects the host->device ingest format (``"ragged"`` — the
    compressed event wire, the default — or ``"dense"``); outputs are
    bit-identical either way and per-round transfer sizes accumulate in
    :attr:`wire_stats`. See DESIGN.md Sec. 16.
    """

    def __init__(
        self,
        config: PipelineConfig = PipelineConfig(),
        tiers: tuple[int, ...] = DEFAULT_TIERS,
        admission: AdmissionConfig = AdmissionConfig(),
        faults: FaultConfig = FaultConfig(),
        with_tracking: bool = True,
        mesh=None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        max_inflight_rounds: int = 1,
        wire: str = "ragged",
    ):
        if not tiers or list(tiers) != sorted(set(tiers)):
            raise ValueError(f"tiers must be strictly increasing, got {tiers}")
        if max_inflight_rounds < 1:
            raise ValueError(
                f"max_inflight_rounds must be >= 1, got {max_inflight_rounds}"
            )
        self.config = config
        self.tiers = tuple(int(t) for t in tiers)
        self.faults = faults
        self.clock = clock
        self._sleep = sleep
        self.max_inflight_rounds = max_inflight_rounds
        self._admit: DualThresholdAdmitter[int] = DualThresholdAdmitter(
            admission, clock
        )
        self._health = SessionHealth(faults, clock)
        self._fleet = FleetPipeline(
            config,
            n_sensors=self.tiers[0],
            with_tracking=with_tracking,
            mesh=mesh,
            uniform_fast_path=False,  # compile discipline (module docstring)
            # One spare staging set beyond the deepest in-flight window,
            # so packing round N never waits on a buffer still borrowed
            # by an unretired round.
            staging_depth=max(2, max_inflight_rounds),
            wire=wire,
        )
        self._sessions: dict[int, SensorSession] = {}  # all states
        self._by_slot: dict[int, int] = {}  # slot -> sid, live only
        self._free: list[int] = list(range(self.tiers[0]))  # sorted
        self._inflight: list[PendingRound] = []  # dispatched, unretired
        self._next_sid = 0
        self.promotions = 0  # capacity-tier promotions performed
        self.demotions = 0  # capacity-tier demotions performed
        self.quarantines = 0  # sessions quarantined (validation faults)
        self.evictions = 0  # sessions evicted (heartbeat deadline)
        self.degraded_rounds = 0  # fleet rounds failed + restored
        self.step_retries = 0  # fleet step retries performed
        self.deferred_rounds = 0  # admission rounds deferred, pipeline full
        self.errors: list[SessionError] = []  # service-wide fault log
        # Most recently dispatched fleet round (monitoring / cross-shard
        # exchange taps; never consumed by the service itself).
        self.last_round: PendingRound | None = None

    # ------------------------------------------------------------------
    # Introspection.
    # ------------------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Current slot-pool capacity (the active tier)."""
        return self._fleet.n_sensors

    @property
    def wire_stats(self):
        """Ingest transfer accounting (``WireStats``): bytes shipped per
        round on the active wire mode vs the dense-equivalent cost."""
        return self._fleet.wire_stats

    @property
    def n_sessions(self) -> int:
        """Live (attached) sessions."""
        return len(self._by_slot)

    def session(self, sid: int) -> SensorSession:
        """Session record (any state) — stats, slot, errors."""
        return self._sessions[sid]

    def backlog(self, sid: int) -> int:
        """Events accepted for ``sid`` but not yet windowed: the service
        queue plus the slot's batcher remainder inside the fleet carry."""
        sess = self._sessions[sid]
        queued = sess.queued_events
        if sess.state == LIVE:
            queued += self._fleet.state.cursors[sess.slot].pending_count
        return queued

    def _sids_in(self, state: str) -> list[int]:
        return [sid for sid, s in self._sessions.items() if s.state == state]

    @property
    def detached_sessions(self) -> list[int]:
        """Sids of retained detached-session records (see :meth:`forget`)."""
        return self._sids_in(DETACHED)

    @property
    def migrated_sessions(self) -> list[int]:
        """Sids exported to another service (records retained)."""
        return self._sids_in(MIGRATED)

    @property
    def quarantined_sessions(self) -> list[int]:
        """Sids quarantined by validation faults (records retained)."""
        return self._sids_in(QUARANTINED)

    @property
    def evicted_sessions(self) -> list[int]:
        """Sids evicted by heartbeat deadline (records retained)."""
        return self._sids_in(EVICTED)

    def stragglers(self) -> list[int]:
        """Live sids whose service-latency EMA exceeds the straggler
        threshold (flagged, not evicted — see FaultConfig)."""
        return [s for s in self._health.stragglers() if s in self._by_slot.values()]

    # ------------------------------------------------------------------
    # Lifecycle.
    # ------------------------------------------------------------------

    def attach(self, name: str | None = None) -> int:
        """Admit a new sensor; returns its session id.

        Takes the lowest free slot; with no slot free, promotes the pool
        to the next capacity tier first (carry migration — live sessions
        are unaffected, their results stay bit-identical across the
        promotion).
        """
        if not self._free:
            new_cap = tier_capacity(self.capacity + 1, self.tiers)
            old_cap = self.capacity
            self._fleet.grow(new_cap)
            self._free.extend(range(old_cap, new_cap))
            self.promotions += 1
        slot = self._free.pop(0)
        sid = self._next_sid
        self._next_sid += 1
        self._sessions[sid] = SensorSession(
            sid=sid,
            slot=slot,
            name=name or f"session-{sid}",
            clock=self.clock,
            queue_budget=self.faults.queue_budget_events,
            shed_policy=self.faults.shed_policy,
        )
        self._by_slot[slot] = sid
        self._health.register(sid)
        return sid

    def feed(self, sid: int, x, y, t, p) -> list[ServedFeed]:
        """Queue one raw event chunk for ``sid``; step the fleet if the
        admission policy fires. Returns the feeds completed by this call
        (every admitted session's, not just ``sid``'s) — ``[]`` while
        the micro-batch is still filling.

        Any feed — including an empty chunk — is a heartbeat. A chunk
        failing validation raises ``ValueError`` under the strict
        default, or quarantines ``sid`` (only) under
        ``on_validation_error="quarantine"``.
        """
        sess = self._live(sid)
        self._health.beat(sid)
        shed_before = sess.stats.shed_events
        try:
            n = sess.accept(x, y, t, p)
        except ValueError as e:
            if self.faults.on_validation_error == "raise":
                raise
            self._quarantine(sess, str(e))
            return []
        if sess.stats.shed_events != shed_before:
            # The budget shed events (possibly previously submitted ones);
            # re-state this session's admitter weight exactly.
            self._admit.restate(sid, sess.queued_events)
        elif n:
            self._admit.submit(sid, weight=n)
        self._sweep_liveness()
        if sess.state == LIVE and self._admit.ready():
            return self.pump()
        return []

    def pump(self, force: bool = False) -> list[ServedFeed]:
        """Run one fleet step over every queued chunk (if admission fired
        or ``force``). Results are delivered per session, slot-ordered.
        Sweeps heartbeat eviction first; a degraded round (step failed
        after retries) returns ``[]`` with every chunk restored.

        With ``max_inflight_rounds > 1`` an admission-triggered round
        that arrives while the pipeline is full (every in-flight slot
        taken, oldest still executing) is deferred: nothing is taken
        from any queue, the admitter keeps its state so the next pump
        retries, and the deferral is accounted per queued session.
        ``force=True`` never defers — it applies backpressure by
        retiring the oldest round instead (drain semantics)."""
        self._sweep_liveness()
        if not force and not self._admit.ready():
            return []
        if not force and not self._dispatch_ready():
            self.deferred_rounds += 1
            for sid in self._by_slot.values():
                sess = self._sessions[sid]
                if sess.queued_events:
                    sess.stats.deferred_rounds += 1
            return []
        self._admit.pop_all()
        dirty = [
            (slot, sid)
            for slot, sid in sorted(self._by_slot.items())
            if self._sessions[sid].queued_events
        ]
        if not dirty:
            return []
        out = self._step({slot: sid for slot, sid in dirty}, final_slots=())
        return [] if out is None else out

    @property
    def inflight_rounds(self) -> int:
        """Dispatched fleet rounds not yet retired (<= max_inflight_rounds)."""
        return len(self._inflight)

    def drain(self) -> None:
        """Retire every in-flight round (block until the device is idle).

        Deferred micro-batches are NOT stepped — call ``pump(force=True)``
        first to flush queues; ``drain`` only empties the pipeline."""
        self._retire(0)

    def detach(self, sid: int) -> ScanResult:
        """Close a session: its queued chunks and trailing partial window
        are processed in one final fleet step (other sessions' queues are
        untouched), the slot carry is zeroed and recycled, and the tail
        result is returned. The session object stays readable for stats.

        If the final step degrades (fails past its retries), the chunks
        are restored and ``RuntimeError`` is raised — the session stays
        live and the detach can be retried."""
        sess = self._live(sid)
        out = self._step({sess.slot: sid}, final_slots=(sess.slot,))
        if out is None:
            raise RuntimeError(
                f"detach of session {sid} degraded (fleet step failed after "
                f"{self.faults.max_step_retries} retries); chunks restored, "
                "retry the detach"
            )
        self._release_slot(sess, DETACHED)
        return out[0].result

    def export_session(self, sid: int) -> SessionExport:
        """Lift a live session out of this service for re-migration to
        another shard (DESIGN.md Sec. 15).

        The complete state crosses: the fleet slot carry (cursor +
        atlas slice + tracker slice — the entire stream state, so the
        destination resumes bit-identically), the unstepped ingest queue
        with original arrival stamps, the monotone watermark, and the
        accumulated stats/errors. Locally this is a detach-shaped exit
        *without* the flushing step: the slot is zeroed and recycled,
        the admitter entries dropped, and the record retained as
        ``"migrated"``. Works with rounds in flight — the export blocks
        only on the slot's own carry buffers; results already served
        stay valid (outputs are never donated).
        """
        sess = self._live(sid)
        carry = self._fleet.export_slot(sess.slot)
        queue = sess.export_queue()
        self._release_slot(sess, MIGRATED)
        self._maybe_demote()
        export = SessionExport(
            name=sess.name,
            carry=carry,
            queue=queue,
            last_t=sess.last_t,
            stats=sess.stats,
            errors=sess.errors,
        )
        # The live stats/error objects travel WITH the stream; the local
        # migrated record keeps a frozen snapshot (no aliasing with the
        # destination's continued accounting).
        sess.stats = copy.deepcopy(sess.stats)
        sess.errors = list(sess.errors)
        return export

    def adopt_session(self, export: SessionExport, name: str | None = None) -> int:
        """Admit a migrated session: a fresh slot (tier promotion if
        needed, like any attach), the exported carry installed into it,
        and the exported queue/stats/watermark restored. Returns the new
        (local) session id — the constellation layer keeps the global
        identity. The adopted stream is bit-identical to one that never
        migrated, for any interleaving of feeds around the hop."""
        sid = self.attach(name or export.name)
        sess = self._sessions[sid]
        try:
            self._fleet.import_slot(sess.slot, export.carry)
        except (ValueError, IndexError):
            # Shape-incompatible carry (different PipelineConfig): undo
            # the attach so the refusal is atomic on this service.
            self._release_slot(sess, DETACHED)
            del self._sessions[sid]
            raise
        sess.last_t = export.last_t
        sess.stats = export.stats
        sess.errors = export.errors
        for chunk, arrival in export.queue:
            sess.requeue(chunk, arrival)
        if sess.queued_events:
            self._admit.restate(sid, sess.queued_events)
        return sid

    def forget(self, sid: int) -> None:
        """Drop a *closed* (detached / quarantined / evicted) session's
        record. Closed sessions are retained for inspection, not forever
        by obligation — a long-lived churny deployment calls this (or
        periodically sweeps the ``*_sessions`` lists) to bound host
        memory."""
        sess = self._sessions.get(sid)
        if sess is None:
            return
        if sess.state == LIVE:
            raise RuntimeError(f"session {sid} is {sess.state}; detach first")
        del self._sessions[sid]

    # ------------------------------------------------------------------
    # Fault paths (DESIGN.md Sec. 13).
    # ------------------------------------------------------------------

    def _quarantine(self, sess: SensorSession, message: str) -> None:
        """Validation fault: record, drop the suspect queue + slot
        remainder, recycle the slot. Only this session is touched."""
        err = sess.record_error("validation", message)
        sess.stats.validation_failures += 1
        self.errors.append(err)
        self.quarantines += 1
        sess.drop_queue()
        self._release_slot(sess, QUARANTINED)

    def _sweep_liveness(self) -> None:
        """Evict every live session past its heartbeat deadline: flush
        its queue + trailing window in its own single-slot step, recycle
        the slot, and demote the pool tier if the tail emptied."""
        for sid in self._health.expired():
            self._evict(sid)

    def _evict(self, sid: int) -> None:
        sess = self._sessions[sid]
        out = self._step({sess.slot: sid}, final_slots=(sess.slot,))
        if out is None:
            return  # flush degraded; chunks restored, retry next sweep
        err = sess.record_error(
            "evicted",
            f"no heartbeat for > {self.faults.heartbeat_timeout_s} s; "
            "slot flushed and recycled",
        )
        self.errors.append(err)
        self.evictions += 1
        sess.tail_result = out[0].result
        self._release_slot(sess, EVICTED)
        self._maybe_demote()

    def _release_slot(self, sess: SensorSession, state: str) -> None:
        """Common slot-recycle path for every exit (detach / quarantine /
        evict): admitter purged by the caller, carry zeroed, slot freed."""
        self._health.forget(sess.sid)
        self._admit.discard(sess.sid)
        del self._by_slot[sess.slot]
        bisect.insort(self._free, sess.slot)
        self._fleet.reset_slots([sess.slot])
        sess.state = state
        sess.slot = -1

    def _maybe_demote(self) -> None:
        """Shrink the pool back a tier when the tail slots all freed up
        (carry sliced + re-sharded; surviving slots keep state verbatim)."""
        if not self.faults.demote_tiers:
            return
        while True:
            cap = self.capacity
            if cap > self.tiers[-1]:
                lower = cap // 2  # doubling schedule past the last tier
            else:
                lower = max((t for t in self.tiers if t < cap), default=None)
            if lower is None or (self._by_slot and max(self._by_slot) >= lower):
                return
            self._fleet.shrink(lower, occupied=list(self._by_slot))
            self._free = [s for s in self._free if s < lower]
            self.demotions += 1

    # ------------------------------------------------------------------
    # Internals.
    # ------------------------------------------------------------------

    def _live(self, sid: int) -> SensorSession:
        sess = self._sessions.get(sid)
        if sess is None:
            raise KeyError(f"unknown session id {sid}")
        if sess.state != LIVE:
            raise RuntimeError(f"session {sid} is {sess.state}")
        return sess

    def _dispatch_ready(self) -> bool:
        """Can a new round be dispatched without blocking on the device?"""
        return (
            len(self._inflight) < self.max_inflight_rounds
            or self._inflight[0].ready()
        )

    def _retire(self, keep: int) -> None:
        """Await the oldest in-flight rounds until at most ``keep`` remain."""
        while len(self._inflight) > keep:
            self._inflight.pop(0).wait()

    def _step(
        self, by_slot: dict[int, int], final_slots: tuple[int, ...]
    ) -> list[ServedFeed] | None:
        """One fleet step over the named slots' merged queues, dispatched
        asynchronously into the in-flight window.

        A dispatch that raises is retried up to ``max_step_retries``
        times with exponential backoff (the fleet validates before
        mutating — phase A — so a failed dispatch leaves the carry
        untouched and the same chunks re-feed exactly; this is the
        boundary where chunk-induced faults surface even with rounds
        already in flight, since earlier rounds' outputs are never
        donated). When retries are exhausted: with
        ``degrade_on_step_failure`` every taken chunk is restored to its
        session queue (original arrival stamps — nothing lost, latency
        clocks intact), the round is recorded degraded, and ``None`` is
        returned; otherwise the last error propagates (strict default).

        Before dispatching, the oldest in-flight rounds are retired down
        to ``max_inflight_rounds - 1`` (backpressure); at depth 1 the
        new round is also awaited before returning — the synchronous
        path. Per-session accounting (steps, windows, latency, health)
        happens at dispatch from host-side window counts, so counters
        are exact regardless of when results are consumed.
        """
        chunks: list = [None] * self.capacity
        arrivals: dict[int, float | None] = {}
        for slot, sid in by_slot.items():
            chunks[slot], arrivals[sid] = self._sessions[sid].take()
        final = np.zeros(self.capacity, bool)
        if final_slots:
            final[list(final_slots)] = True
        self._retire(self.max_inflight_rounds - 1)
        pending = None
        for attempt in range(self.faults.max_step_retries + 1):
            try:
                pending = self._fleet.feed_async(chunks, final=final)
                break
            except Exception as e:  # noqa: BLE001 — device-step failure
                last_err = e
                if attempt == self.faults.max_step_retries:
                    if not self.faults.degrade_on_step_failure:
                        raise
                    break
                self.step_retries += 1
                backoff = self.faults.retry_backoff_s * (2**attempt)
                if backoff:
                    self._sleep(backoff)
        if pending is None:
            self.degraded_rounds += 1
            for slot, sid in by_slot.items():
                sess = self._sessions[sid]
                if chunks[slot] is not None:
                    sess.restore(chunks[slot], arrivals[sid])
                    self._admit.restate(sid, sess.queued_events)
                sess.stats.degraded_rounds += 1
                self.errors.append(
                    sess.record_error(
                        "degraded_round",
                        f"fleet step failed after {self.faults.max_step_retries}"
                        f" retries ({type(last_err).__name__}: {last_err}); "
                        "chunks restored",
                    )
                )
            return None
        self._inflight.append(pending)
        self.last_round = pending
        now = self.clock()
        served: list[ServedFeed] = []
        for slot in sorted(by_slot):
            sid = by_slot[slot]
            sess = self._sessions[sid]
            arrival = arrivals[sid]
            latency_ms = None if arrival is None else (now - arrival) * 1e3
            sess.record_step(int(pending.n_windows[slot]), latency_ms)
            if latency_ms is not None:
                self._health.note_latency(sid, latency_ms)
            served.append(
                ServedFeed(
                    sid=sid, latency_ms=latency_ms or 0.0,
                    _round=pending, _slot=slot,
                )
            )
        if self.max_inflight_rounds == 1:
            self._retire(0)  # synchronous path: round awaited before return
        return served
