"""DetectionService: dynamic sensor sessions over the slot-pooled fleet.

The serving-shaped top of the detection stack (DESIGN.md Sec. 11).
Sensors attach and detach at will; every attached session feeds raw
event chunks at its own cadence; the service micro-batches the queued
chunks under the paper's dual-threshold admission policy
(:mod:`repro.serve.batcher`) and drives the whole set through ONE
slot-pooled :class:`~repro.core.pipeline.fleet.FleetPipeline` step.

Contracts:

* **Bit-identity.** Every session's results — concatenated over its
  lifetime, including the detach tail — are bit-identical to a
  dedicated :class:`~repro.core.pipeline.stream.StreamingPipeline` fed
  the same chunks (and hence to the offline scan driver), for ANY
  interleaving of attach / feed / idle / detach across sessions,
  including slot recycling and capacity-tier promotion mid-stream.
  Pinned by tests/test_serve_service.py.
* **Compile discipline.** Slot occupancy never appears in a compiled
  shape: the fleet step is compiled per (pool capacity, windows-per-feed)
  only, so attach/detach churn costs zero compiles and a churn workload
  cycling 1 -> max sessions compiles at most one fleet step per
  capacity tier (the service pins ``uniform_fast_path=False`` so the
  static uniform variant cannot double that).
* **Atomic validation.** A chunk that is out of order — within itself
  or against its session's stream — raises ``ValueError`` at the
  ``feed`` call, before it is queued: no other session's state, and not
  even the offending session's state, is touched.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Callable

import numpy as np

from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.fleet import DEFAULT_TIERS, FleetPipeline, tier_capacity
from repro.core.pipeline.scan import ScanResult
from repro.serve.batcher import AdmissionConfig, DualThresholdAdmitter
from repro.serve.sessions import DETACHED, LIVE, SensorSession


@dataclasses.dataclass
class ServedFeed:
    """One session's share of one fleet step."""

    sid: int
    result: ScanResult
    latency_ms: float  # oldest queued chunk's arrival -> results ready


class DetectionService:
    """Micro-batched detection serving over a slot pool of sensor sessions.

    >>> svc = DetectionService(PipelineConfig(), tiers=(4, 8))
    >>> sid = svc.attach("station-7")
    >>> done = svc.feed(sid, x, y, t, p)   # [] until admission fires
    >>> done = svc.pump(force=True)        # or step the fleet explicitly
    >>> tail = svc.detach(sid)             # flush + recycle the slot

    ``feed`` queues the (validated) chunk and steps the fleet only when
    the admission policy fires — oldest queued chunk ``max_delay_s`` old
    OR ``max_items`` events queued fleet-wide — so concurrent sessions
    share one vmapped dispatch instead of paying one each. The returned
    list carries every session's results from that step, not just the
    caller's. ``pump(force=True)`` steps unconditionally (deterministic
    drivers, tests, drain-before-shutdown).
    """

    def __init__(
        self,
        config: PipelineConfig = PipelineConfig(),
        tiers: tuple[int, ...] = DEFAULT_TIERS,
        admission: AdmissionConfig = AdmissionConfig(),
        with_tracking: bool = True,
        mesh=None,
        clock: Callable[[], float] = time.monotonic,
    ):
        if not tiers or list(tiers) != sorted(set(tiers)):
            raise ValueError(f"tiers must be strictly increasing, got {tiers}")
        self.config = config
        self.tiers = tuple(int(t) for t in tiers)
        self.clock = clock
        self._admit: DualThresholdAdmitter[int] = DualThresholdAdmitter(
            admission, clock
        )
        self._fleet = FleetPipeline(
            config,
            n_sensors=self.tiers[0],
            with_tracking=with_tracking,
            mesh=mesh,
            uniform_fast_path=False,  # compile discipline (module docstring)
        )
        self._sessions: dict[int, SensorSession] = {}  # all, live + detached
        self._by_slot: dict[int, int] = {}  # slot -> sid, live only
        self._free: list[int] = list(range(self.tiers[0]))  # sorted
        self._next_sid = 0
        self.promotions = 0  # capacity-tier promotions performed

    # ------------------------------------------------------------------
    # Introspection.
    # ------------------------------------------------------------------

    @property
    def capacity(self) -> int:
        """Current slot-pool capacity (the active tier)."""
        return self._fleet.n_sensors

    @property
    def n_sessions(self) -> int:
        """Live (attached) sessions."""
        return len(self._by_slot)

    def session(self, sid: int) -> SensorSession:
        """Session record (live or detached) — stats, slot, state."""
        return self._sessions[sid]

    def backlog(self, sid: int) -> int:
        """Events accepted for ``sid`` but not yet windowed: the service
        queue plus the slot's batcher remainder inside the fleet carry."""
        sess = self._sessions[sid]
        queued = sess.queued_events
        if sess.state == LIVE:
            queued += self._fleet.state.cursors[sess.slot].pending_count
        return queued

    # ------------------------------------------------------------------
    # Lifecycle.
    # ------------------------------------------------------------------

    def attach(self, name: str | None = None) -> int:
        """Admit a new sensor; returns its session id.

        Takes the lowest free slot; with no slot free, promotes the pool
        to the next capacity tier first (carry migration — live sessions
        are unaffected, their results stay bit-identical across the
        promotion).
        """
        if not self._free:
            new_cap = tier_capacity(self.capacity + 1, self.tiers)
            old_cap = self.capacity
            self._fleet.grow(new_cap)
            self._free.extend(range(old_cap, new_cap))
            self.promotions += 1
        slot = self._free.pop(0)
        sid = self._next_sid
        self._next_sid += 1
        self._sessions[sid] = SensorSession(
            sid=sid,
            slot=slot,
            name=name or f"session-{sid}",
            clock=self.clock,
        )
        self._by_slot[slot] = sid
        return sid

    def feed(self, sid: int, x, y, t, p) -> list[ServedFeed]:
        """Queue one raw event chunk for ``sid``; step the fleet if the
        admission policy fires. Returns the feeds completed by this call
        (every admitted session's, not just ``sid``'s) — ``[]`` while
        the micro-batch is still filling."""
        sess = self._live(sid)
        n = sess.accept(x, y, t, p)
        if n:
            self._admit.submit(sid, weight=n)
        if self._admit.ready():
            return self.pump(force=True)
        return []

    def pump(self, force: bool = False) -> list[ServedFeed]:
        """Run one fleet step over every queued chunk (if admission fired
        or ``force``). Results are delivered per session, slot-ordered."""
        if not force and not self._admit.ready():
            return []
        self._admit.pop_all()
        dirty = [
            (slot, sid)
            for slot, sid in sorted(self._by_slot.items())
            if self._sessions[sid].queued_events
        ]
        if not dirty:
            return []
        return self._step({slot: sid for slot, sid in dirty}, final_slots=())

    def detach(self, sid: int) -> ScanResult:
        """Close a session: its queued chunks and trailing partial window
        are processed in one final fleet step (other sessions' queues are
        untouched), the slot carry is zeroed and recycled, and the tail
        result is returned. The session object stays readable for stats."""
        sess = self._live(sid)
        out = self._step({sess.slot: sid}, final_slots=(sess.slot,))
        self._admit.discard(sid)  # consumed out of band: stop its entries
        sess.state = DETACHED     # aging toward the next admission
        del self._by_slot[sess.slot]
        bisect.insort(self._free, sess.slot)
        self._fleet.reset_slots([sess.slot])
        sess.slot = -1
        return out[0].result

    def forget(self, sid: int) -> None:
        """Drop a *detached* session's stats record. Detached sessions are
        retained for inspection, not forever by obligation — a long-lived
        churny deployment calls this (or periodically sweeps
        ``detached_sessions``) to bound host memory."""
        sess = self._sessions.get(sid)
        if sess is None:
            return
        if sess.state != DETACHED:
            raise RuntimeError(f"session {sid} is {sess.state}; detach first")
        del self._sessions[sid]

    @property
    def detached_sessions(self) -> list[int]:
        """Sids of retained detached-session records (see :meth:`forget`)."""
        return [
            sid for sid, s in self._sessions.items() if s.state == DETACHED
        ]

    # ------------------------------------------------------------------
    # Internals.
    # ------------------------------------------------------------------

    def _live(self, sid: int) -> SensorSession:
        sess = self._sessions.get(sid)
        if sess is None:
            raise KeyError(f"unknown session id {sid}")
        if sess.state != LIVE:
            raise RuntimeError(f"session {sid} is {sess.state}")
        return sess

    def _step(
        self, by_slot: dict[int, int], final_slots: tuple[int, ...]
    ) -> list[ServedFeed]:
        """One fleet step over the named slots' merged queues."""
        chunks: list = [None] * self.capacity
        arrivals: dict[int, float | None] = {}
        for slot, sid in by_slot.items():
            chunks[slot], arrivals[sid] = self._sessions[sid].take()
        final = np.zeros(self.capacity, bool)
        if final_slots:
            final[list(final_slots)] = True
        out = self._fleet.feed(chunks, final=final)
        now = self.clock()
        served: list[ServedFeed] = []
        for slot in sorted(by_slot):
            sid = by_slot[slot]
            sess = self._sessions[sid]
            result = out.sensor(slot)
            arrival = arrivals[sid]
            latency_ms = None if arrival is None else (now - arrival) * 1e3
            sess.record_step(result.num_windows, latency_ms)
            served.append(
                ServedFeed(sid=sid, result=result, latency_ms=latency_ms or 0.0)
            )
        return served
