"""Deprecated back-compat shim: the LM engine moved to :mod:`repro.serve.lm`.

The serving stack is now layered (DESIGN.md Sec. 11):

* :mod:`repro.serve.batcher`  — the paper's dual-threshold admission
  policy as a generic, fake-clock-testable primitive.
* :mod:`repro.serve.sessions` — per-sensor session lifecycle.
* :mod:`repro.serve.service`  — :class:`DetectionService`, micro-batched
  detection serving over the slot-pooled fleet engine.
* :mod:`repro.serve.lm`       — the batched LM engine, a thin client of
  the shared batcher.

Importing from ``repro.serve.engine`` keeps working for now but warns;
update imports to ``repro.serve.lm``.
"""
import warnings

from repro.serve.lm import (  # noqa: F401
    DualThresholdBatcher,
    EngineConfig,
    Request,
    ServingEngine,
)

warnings.warn(
    "repro.serve.engine is deprecated; import the LM engine from "
    "repro.serve.lm instead",
    DeprecationWarning,
    stacklevel=2,
)
