"""Deterministic chaos-injection harness for the detection service.

Real event-camera SSA deployments (Afshar et al., arXiv:1911.08730)
must keep observing through sensor dropouts, hot-pixel bursts, and
corrupted links. This module drives a fault-tolerant
:class:`~repro.serve.service.DetectionService` through a *seeded*
schedule of every fault in the taxonomy and checks the two invariants
the fault layer promises (DESIGN.md Sec. 13):

* **No crash**: no injected fault ever raises out of ``feed``/``pump``
  — faulty sessions are quarantined, evicted, shed, or retried, each
  leaving a structured :class:`~repro.serve.sessions.SessionError`.
* **Bit-identical degraded mode**: the outputs of every *healthy*
  session — windows, clusters, metrics, tracks, final tracker state —
  are bit-identical to a fault-free reference run of the same feeds.
  Faults on one sensor never perturb another, and degraded rounds
  (restored chunks re-fed later, i.e. re-chunked) are covered by the
  streaming engine's re-chunking invariance.

Everything is deterministic from ``ChaosConfig.seed``: the fault
schedule, every injected payload, and the fake clock (no wall time, no
real sleeps), so a chaos failure replays exactly.

    report = ChaosHarness(ChaosConfig(seed=7)).run()
    assert report.bit_identical and not report.escaped_errors

The CI soak gate lives in ``benchmarks/chaos_soak.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.core.pipeline.config import PipelineConfig
from repro.serve.batcher import AdmissionConfig
from repro.serve.faults import FaultConfig
from repro.serve.service import DetectionService
from repro.serve.sessions import LIVE, SessionError

# The fault taxonomy. Each entry is injected on *faulty* sensors only;
# healthy sensors feed clean chunks every round.
FAULT_TAXONOMY = (
    "non_monotone",    # timestamps shuffled inside a chunk -> quarantine
    "duplicate",       # previous chunk re-sent (stream regresses) -> quarantine
    "dropped",         # a chunk silently lost in transit (gap; survivable)
    "oob_coords",      # off-sensor but int32-safe coordinates (masked; survivable)
    "garbage_coords",  # int32-unsafe integer garbage -> quarantine
    "stall",           # sensor goes silent -> heartbeat eviction
    "burst",           # overload flood past the queue budget -> shed
    "churn",           # detach + immediate re-attach (slot recycle)
    "step_exception",  # simulated device-step failure -> retry / degraded round
)


@dataclasses.dataclass(frozen=True)
class ChaosConfig:
    """Seeded chaos schedule over a sensor fleet.

    The first ``n_faulty`` sensors are the fault targets; the remaining
    ``n_sensors - n_faulty`` stay healthy and are the bit-identity
    comparison set. Faults fire on a deterministic schedule that cycles
    through ``faults`` (every entry at least once when the round budget
    allows) and then keeps injecting at random from the same seed.
    """

    n_sensors: int = 6
    n_faulty: int = 2
    n_rounds: int = 48
    seed: int = 0
    faults: tuple[str, ...] = FAULT_TAXONOMY
    chunk_events: int = 100  # per-round clean chunk size
    burst_events: int = 1500  # overload chunk size (>> queue budget share)
    round_dt_s: float = 0.02  # fake-clock advance per round (live cadence)
    queue_budget_events: int = 800  # per-session ingest bound
    shed_policy: str = "drop_oldest"
    heartbeat_rounds: int = 4  # silence threshold, in rounds
    stall_rounds: int = 6  # how long a stalled sensor stays silent
    max_step_retries: int = 2
    tiers: tuple[int, ...] = (4, 8, 16)

    def __post_init__(self):
        if not 0 < self.n_faulty < self.n_sensors:
            raise ValueError(
                f"need 0 < n_faulty < n_sensors, got {self.n_faulty} of "
                f"{self.n_sensors}"
            )
        unknown = set(self.faults) - set(FAULT_TAXONOMY)
        if unknown:
            raise ValueError(f"unknown faults {sorted(unknown)}")
        if self.stall_rounds <= self.heartbeat_rounds + 1:
            raise ValueError(
                "stall_rounds must exceed heartbeat_rounds + 1 so a stalled "
                "sensor is reliably evicted before it could resume"
            )
        if self.chunk_events > self.queue_budget_events:
            raise ValueError(
                "chunk_events must fit the queue budget or healthy feeds "
                "would shed (breaking the bit-identity comparison)"
            )


@dataclasses.dataclass
class ChaosReport:
    """Outcome of one chaos run; every field is deterministic per seed."""

    rounds: int
    fired: dict  # fault kind -> injection count (every kind >= 1)
    quarantines: int
    evictions: int
    degraded_rounds: int
    step_retries: int
    demotions: int
    healthy_windows: int  # windows served to healthy sessions
    shed: dict  # {"offered": int, "accepted": int, "shed": int, "exact": bool}
    errors: list[SessionError]  # structured records, service-wide order
    escaped_errors: list[str]  # exceptions that escaped feed/pump (must be [])
    bit_identical: bool  # healthy outputs == fault-free reference
    mismatches: list[str]  # per-leaf mismatch descriptions when not
    round_times_ms: list[float]  # wall time per faulted round (bench input)


class _FakeClock:
    def __init__(self):
        self.now = 0.0

    def __call__(self) -> float:
        return self.now


class _Stream:
    """Deterministic per-sensor event stream: strictly increasing
    timestamps (100 us apart), rng coordinates. Both chaos runs consume
    a healthy stream with the same seed and the same slice sizes, so
    the fed chunks are identical arrays."""

    def __init__(self, seed: int, dt_us: int = 100):
        self._rng = np.random.default_rng(seed)
        self._pos = 0
        self.dt_us = dt_us

    def next(self, n: int):
        x = self._rng.integers(40, 560, n).astype(np.int64)
        y = self._rng.integers(40, 400, n).astype(np.int64)
        p = self._rng.integers(0, 2, n).astype(np.int64)
        t = (np.arange(n, dtype=np.int64) + self._pos + 1) * self.dt_us
        self._pos += n
        return x, y, t, p


class _FlakyFleet:
    """Transparent fleet wrapper whose ``feed`` / ``feed_async`` raise
    the next ``fail_next`` times — the chaos stand-in for a device-step
    failure at the dispatch boundary (before any fleet mutation, which
    is where a failed XLA dispatch surfaces; the service's retry loop
    wraps ``feed_async``)."""

    def __init__(self, fleet):
        self._fleet = fleet
        self.fail_next = 0
        self.raised = 0

    def __getattr__(self, name):
        return getattr(self._fleet, name)

    def _maybe_fail(self):
        if self.fail_next > 0:
            self.fail_next -= 1
            self.raised += 1
            raise RuntimeError("chaos: injected device-step failure")

    def feed(self, *args, **kwargs):
        self._maybe_fail()
        return self._fleet.feed(*args, **kwargs)

    def feed_async(self, *args, **kwargs):
        self._maybe_fail()
        return self._fleet.feed_async(*args, **kwargs)


def _result_arrays(res) -> list[np.ndarray]:
    """A ScanResult's comparable surfaces as host arrays, leading dim =
    windows (so concatenation over parts is chunking-invariant)."""
    out = [np.asarray(res.t_start_us)]
    if res.num_windows:
        for leaf in jax.tree.leaves((res.clusters, res.metrics)):
            out.append(np.asarray(leaf))
        if res.tracks is not None:
            out.extend(np.asarray(a) for a in jax.tree.leaves(res.tracks))
    return out


def concat_outputs(parts) -> list[np.ndarray]:
    """Concatenate one session's per-step results into window-indexed
    surfaces, plus the final tracker state of the last (detach) part."""
    cols = [_result_arrays(r) for r in parts if r.num_windows]
    out = [np.concatenate(c) for c in zip(*cols)] if cols else []
    for r in reversed(parts):
        if r.final_tracks is not None:
            out.extend(np.asarray(a) for a in jax.tree.leaves(r.final_tracks))
            break
    return out


def compare_outputs(got, want, label: str) -> list[str]:
    """Bitwise comparison of two concat_outputs lists."""
    bad = []
    if len(got) != len(want):
        return [f"{label}: {len(got)} surfaces vs {len(want)}"]
    for i, (g, w) in enumerate(zip(got, want)):
        if g.shape != w.shape:
            bad.append(f"{label}[{i}]: shape {g.shape} vs {w.shape}")
        elif not np.array_equal(g, w):
            bad.append(
                f"{label}[{i}]: {int((g != w).sum())}/{g.size} elements differ"
            )
    return bad


class ChaosHarness:
    """Run the seeded fault schedule against a fault-tolerant service,
    then a fault-free reference over the same healthy feeds, and diff.

    ``config`` here is the chaos schedule; ``pipeline`` the detection
    pipeline config shared by both runs.
    """

    def __init__(
        self,
        config: ChaosConfig = ChaosConfig(),
        pipeline: PipelineConfig = PipelineConfig(),
    ):
        self.config = config
        self.pipeline = pipeline

    # -- schedule ------------------------------------------------------

    def schedule(self) -> list[tuple[int, int, str]]:
        """The deterministic fault schedule: (round, faulty_sensor, kind).

        A guarantee pass spreads every configured kind over the run —
        each fires at least once — then extra (sensor, kind) pairs are
        drawn at random from the same seed. Stalled sensors carry a busy
        horizon so they are evicted and re-attached before their next
        fault."""
        cfg = self.config
        rng = np.random.default_rng(cfg.seed)
        kinds = list(cfg.faults)
        first = 3  # duplicates need history; give every sensor some
        last = cfg.n_rounds - 3
        busy = [0] * cfg.n_faulty  # per-sensor stall horizon
        out: list[tuple[int, int, str]] = []

        def place(r: int, f: int, kind: str) -> None:
            out.append((r, f, kind))
            if kind == "stall":
                busy[f] = r + cfg.stall_rounds + 2

        span = max(1, last - first)
        for i, kind in enumerate(kinds):  # guarantee pass
            r = first + (i * span) // len(kinds)
            free = [f for f in range(cfg.n_faulty) if r >= busy[f]]
            if not free:
                r = min(busy)
                free = [f for f in range(cfg.n_faulty) if r >= busy[f]]
            place(min(r, last), free[i % len(free)], kind)
        r = first  # extra random injections
        while True:
            r += int(rng.integers(2, 6))
            if r >= last:
                break
            f = int(rng.integers(cfg.n_faulty))
            if r >= busy[f]:
                place(r, f, str(rng.choice(kinds)))
        out.sort(key=lambda e: e[0])
        return out

    # -- runs ----------------------------------------------------------

    def run(self) -> ChaosReport:
        cfg = self.config
        faulted = self._run_faulted()
        reference = self._run_reference()
        mismatches: list[str] = []
        for k, hid in enumerate(sorted(faulted["healthy_parts"])):
            got = concat_outputs(faulted["healthy_parts"][hid])
            want = concat_outputs(reference[k])
            mismatches.extend(compare_outputs(got, want, f"healthy[{k}]"))
        svc = faulted["svc"]
        stats = [s.stats for s in faulted["all_sessions"]]
        offered = sum(s.offered_events for s in stats)
        accepted = sum(s.events for s in stats)
        shed = sum(s.shed_events for s in stats)
        return ChaosReport(
            rounds=cfg.n_rounds,
            fired=faulted["fired"],
            quarantines=svc.quarantines,
            evictions=svc.evictions,
            degraded_rounds=svc.degraded_rounds,
            step_retries=svc.step_retries,
            demotions=svc.demotions,
            healthy_windows=sum(
                r.num_windows
                for parts in faulted["healthy_parts"].values()
                for r in parts
            ),
            shed={
                "offered": offered,
                "accepted": accepted,
                "shed": shed,
                "exact": offered == accepted + shed,
            },
            errors=list(svc.errors),
            escaped_errors=faulted["escaped"],
            bit_identical=not mismatches,
            mismatches=mismatches,
            round_times_ms=faulted["round_times_ms"],
        )

    def _fault_config(self) -> FaultConfig:
        cfg = self.config
        return FaultConfig(
            on_validation_error="quarantine",
            queue_budget_events=cfg.queue_budget_events,
            shed_policy=cfg.shed_policy,
            heartbeat_timeout_s=(cfg.heartbeat_rounds - 0.5) * cfg.round_dt_s,
            demote_tiers=True,
            max_step_retries=cfg.max_step_retries,
            retry_backoff_s=0.001,  # fake sleep: advances the fake clock
            degrade_on_step_failure=True,
        )

    def _service(self, clock, faults: FaultConfig) -> DetectionService:
        cfg = self.config

        def fake_sleep(s: float) -> None:
            clock.now += s

        return DetectionService(
            self.pipeline,
            tiers=cfg.tiers,
            admission=AdmissionConfig(
                max_delay_s=cfg.round_dt_s,
                max_items=cfg.chunk_events * cfg.n_sensors,
            ),
            faults=faults,
            clock=clock,
            sleep=fake_sleep,
        )

    def _run_faulted(self) -> dict:
        cfg = self.config
        clock = _FakeClock()
        svc = self._service(clock, self._fault_config())
        flaky = _FlakyFleet(svc._fleet)
        svc._fleet = flaky
        schedule = {}
        for r, f, kind in self.schedule():
            schedule.setdefault(r, []).append((f, kind))
        rng = np.random.default_rng(cfg.seed + 1)  # payload corruption rng
        streams: dict[int, _Stream] = {}
        next_stream_seed = [0]

        def fresh_stream(sensor: int) -> _Stream:
            # Healthy sensors must consume the SAME seed sequence as the
            # reference run; faulty re-attaches draw private seeds.
            if sensor >= cfg.n_faulty:
                seed = cfg.seed * 1000 + sensor
            else:
                seed = cfg.seed * 1000 + 500 + next_stream_seed[0]
                next_stream_seed[0] += 1
            return _Stream(seed)

        sids = {}
        all_sessions = []
        for sensor in range(cfg.n_sensors):
            sids[sensor] = svc.attach(f"sensor-{sensor}")
            all_sessions.append(svc.session(sids[sensor]))
            streams[sensor] = fresh_stream(sensor)
        healthy_sids = {sids[s] for s in range(cfg.n_faulty, cfg.n_sensors)}
        healthy_parts: dict[int, list] = {h: [] for h in healthy_sids}
        last_chunk: dict[int, tuple] = {}
        stalled_until = [0] * cfg.n_faulty
        fired: dict[str, int] = {k: 0 for k in cfg.faults}
        step_exc_count = [0]
        escaped: list[str] = []
        round_times_ms: list[float] = []

        def collect(served):
            for fd in served:
                if fd.sid in healthy_sids:
                    healthy_parts[fd.sid].append(fd.result)

        def guard(fn, *args):
            try:
                collect(fn(*args))
            except Exception as e:  # noqa: BLE001 — the no-crash invariant
                escaped.append(f"{type(e).__name__}: {e}")

        def inject(sensor: int, kind: str) -> None:
            """One fault on one faulty sensor. Never touches healthy state."""
            sid = sids[sensor]
            stream = streams[sensor]
            if kind == "stall":
                stalled_until[sensor] = rnd + cfg.stall_rounds
                fired[kind] += 1
                return
            if kind == "step_exception":
                # Alternate: heal-within-retries, then a degraded round.
                step_exc_count[0] += 1
                flaky.fail_next = (
                    1 if step_exc_count[0] % 2 else cfg.max_step_retries + 1
                )
                fired[kind] += 1
                return
            if kind == "churn":
                if svc.session(sid).state == LIVE:
                    try:
                        svc.detach(sid)
                    except RuntimeError:  # degraded detach: session stays
                        fired[kind] += 1  # live, chunks restored — retryable
                        return
                sids[sensor] = svc.attach(f"sensor-{sensor}-churned")
                all_sessions.append(svc.session(sids[sensor]))
                streams[sensor] = fresh_stream(sensor)
                last_chunk.pop(sensor, None)
                fired[kind] += 1
                return
            if kind == "dropped":
                stream.next(cfg.chunk_events)  # lost in transit
                fired[kind] += 1
                return
            if kind == "burst":
                chunk = stream.next(cfg.burst_events)
                guard(svc.feed, sid, *chunk)
                fired[kind] += 1
                return
            if kind == "duplicate":
                chunk = last_chunk.get(sensor)
                if chunk is None:  # no history yet: synthesize a regression
                    chunk = stream.next(cfg.chunk_events)
                    guard(svc.feed, sid, *chunk)
                guard(svc.feed, sid, *chunk)
                fired[kind] += 1
                return
            x, y, t, p = stream.next(cfg.chunk_events)
            if kind == "non_monotone":
                t = t[::-1].copy()
            elif kind == "oob_coords":
                x = x + 5000  # off-sensor, int32-safe: masked, survivable
                y = y + 5000
            elif kind == "garbage_coords":
                x = x + (np.int64(1) << 31)  # int32-unsafe garbage
            guard(svc.feed, sid, x, y, t, p)
            fired[kind] += 1

        for rnd in range(cfg.n_rounds):
            t0 = time.perf_counter()
            clock.now += cfg.round_dt_s
            for sensor, kind in schedule.get(rnd, ()):
                inject(sensor, kind)
            for sensor in range(cfg.n_sensors):
                faulty = sensor < cfg.n_faulty
                if faulty and rnd < stalled_until[sensor]:
                    continue  # silent: heartbeat eviction territory
                sid = sids[sensor]
                if svc.session(sid).state != LIVE:
                    if faulty:  # re-attach after quarantine/eviction
                        sids[sensor] = svc.attach(f"sensor-{sensor}-r{rnd}")
                        all_sessions.append(svc.session(sids[sensor]))
                        streams[sensor] = fresh_stream(sensor)
                        last_chunk.pop(sensor, None)
                        sid = sids[sensor]
                    else:  # a healthy session left LIVE = isolation broken
                        escaped.append(
                            f"healthy sensor {sensor} left live state: "
                            f"{svc.session(sid).state}"
                        )
                        continue
                chunk = streams[sensor].next(cfg.chunk_events)
                if faulty:
                    last_chunk[sensor] = chunk
                guard(svc.feed, sid, *chunk)
            guard(svc.pump, True)
            round_times_ms.append((time.perf_counter() - t0) * 1e3)

        for h in sorted(healthy_sids):
            try:
                healthy_parts[h].append(svc.detach(h))
            except Exception as e:  # noqa: BLE001
                escaped.append(f"detach({h}): {type(e).__name__}: {e}")
        return {
            "svc": svc,
            "healthy_parts": healthy_parts,
            "all_sessions": all_sessions,
            "fired": fired,
            "escaped": escaped,
            "round_times_ms": round_times_ms,
        }

    def _run_reference(self) -> list[list]:
        """Fault-free run of the healthy feeds only (strict FaultConfig,
        same cadence, same stream seeds) — the bit-identity baseline."""
        cfg = self.config
        clock = _FakeClock()
        svc = self._service(clock, FaultConfig())
        sensors = list(range(cfg.n_faulty, cfg.n_sensors))
        sids = [svc.attach(f"ref-{s}") for s in sensors]
        streams = [_Stream(cfg.seed * 1000 + s) for s in sensors]
        parts: list[list] = [[] for _ in sensors]
        by_sid = {sid: i for i, sid in enumerate(sids)}

        def collect(served):
            for fd in served:
                parts[by_sid[fd.sid]].append(fd.result)

        for _ in range(cfg.n_rounds):
            clock.now += cfg.round_dt_s
            for i, sid in enumerate(sids):
                collect(svc.feed(sid, *streams[i].next(cfg.chunk_events)))
            collect(svc.pump(force=True))
        for i, sid in enumerate(sids):
            parts[i].append(svc.detach(sid))
        return parts
