"""Dual-threshold admission policy as a generic, workload-agnostic primitive.

The paper's client buffers camera events and closes a batch when EITHER
``time_threshold_us`` (20,000 us) elapses OR ``size_threshold`` (250
events) accumulates — Sec. III-A — bounding both latency (time cut) and
work granularity (size cut). The same policy governs every admission
point in this repo's serving stack:

* the **detection service** admits a fleet step when the oldest queued
  sensor chunk is ``max_delay_s`` old or ``max_items`` events are queued
  fleet-wide (:mod:`repro.serve.service`),
* the **LM engine** admits a request batch when the oldest request is
  ``max_delay_s`` old or ``max_items`` requests queue up
  (:mod:`repro.serve.lm`).

:class:`DualThresholdAdmitter` is the one implementation both ride on.
It holds no threads and never sleeps: callers inject ``clock`` (any
``() -> float`` in seconds, ``time.monotonic`` by default), poll
:meth:`DualThresholdAdmitter.ready`, and drain with
:meth:`DualThresholdAdmitter.pop` — so the policy is exactly testable
with a fake clock and composes with any event loop.
"""
from __future__ import annotations

import bisect
import dataclasses
import time
from typing import Any, Callable, Generic, TypeVar

T = TypeVar("T")


@dataclasses.dataclass(frozen=True)
class AdmissionConfig:
    """Dual-threshold close rule: oldest item age OR total queued weight.

    ``max_items`` counts *weight*, not entries: each submit carries a
    weight (1 by default), so the same config expresses "250 events"
    (detection chunks weighted by event count) and "8 requests" (LM
    requests at unit weight).
    """

    max_delay_s: float = 0.020  # paper: 20 ms window
    max_items: int = 250  # paper: 250 events

    def __post_init__(self):
        if self.max_delay_s < 0:
            raise ValueError(f"max_delay_s must be >= 0, got {self.max_delay_s}")
        if self.max_items < 1:
            raise ValueError(f"max_items must be >= 1, got {self.max_items}")


@dataclasses.dataclass
class _Entry(Generic[T]):
    arrival_s: float
    item: T
    weight: int


class DualThresholdAdmitter(Generic[T]):
    """Close a batch at ``max_delay_s`` OR ``max_items`` — whichever first.

    >>> clock = lambda: now[0]
    >>> adm = DualThresholdAdmitter(AdmissionConfig(0.02, 4), clock)
    >>> adm.submit("a"); adm.ready()
    False
    >>> now[0] += 0.025; adm.ready()
    True
    >>> adm.pop()
    ['a']
    """

    def __init__(
        self,
        config: AdmissionConfig = AdmissionConfig(),
        clock: Callable[[], float] = time.monotonic,
    ):
        self.config = config
        self.clock = clock
        self._queue: list[_Entry[T]] = []
        self._weight = 0

    def __len__(self) -> int:
        return len(self._queue)

    @property
    def pending_weight(self) -> int:
        return self._weight

    @property
    def items(self) -> list[T]:
        """Queued items in arrival order (read-only view)."""
        return [e.item for e in self._queue]

    def oldest_age_s(self) -> float:
        """Seconds since the oldest queued item arrived (0 when empty)."""
        if not self._queue:
            return 0.0
        return self.clock() - self._queue[0].arrival_s

    def submit(self, item: T, weight: int = 1) -> None:
        """Queue an item, stamped with the injected clock's now."""
        if weight < 0:
            raise ValueError(f"weight must be >= 0, got {weight}")
        self._queue.append(_Entry(self.clock(), item, weight))
        self._weight += weight

    def discard(self, item: T) -> int:
        """Drop every queued entry equal to ``item`` (returns the count).

        For producers that leave the queue out of band — e.g. a detached
        detection session whose chunks were consumed by its final step:
        its stale entries must not keep aging (or weighing) toward the
        next admission, which would fire the time cut spuriously for
        everyone else.
        """
        keep = [e for e in self._queue if e.item != item]
        dropped = len(self._queue) - len(keep)
        if dropped:
            self._weight -= sum(
                e.weight for e in self._queue if e.item == item
            )
            self._queue = keep
        return dropped

    def restate(self, item: T, weight: int) -> None:
        """Replace every queued entry for ``item`` with ONE entry of the
        given weight, keeping the oldest of their arrival stamps.

        For producers whose queued weight changed out of band — e.g. a
        detection session whose queue budget shed events: the stale
        entries would keep firing the size threshold for weight that no
        longer exists. ``weight == 0`` just clears the item's entries
        (:meth:`discard`); with no prior entries the new one is stamped
        now. The replacement entry is inserted in arrival order, so the
        prefix-pop rule and ``oldest_age_s`` stay exact.
        """
        if weight < 0:
            raise ValueError(f"weight must be >= 0, got {weight}")
        arrivals = [e.arrival_s for e in self._queue if e.item == item]
        self.discard(item)
        if weight == 0:
            return
        arrival = min(arrivals) if arrivals else self.clock()
        entry = _Entry(arrival, item, weight)
        ix = bisect.bisect_right(
            [e.arrival_s for e in self._queue], arrival
        )
        self._queue.insert(ix, entry)
        self._weight += weight

    def ready(self) -> bool:
        if not self._queue:
            return False
        if self._weight >= self.config.max_items:
            return True
        return self.oldest_age_s() >= self.config.max_delay_s

    def pop(self) -> list[T]:
        """Drain one admitted batch: the longest arrival-order prefix whose
        cumulative weight fits ``max_items`` (always at least one item, so
        an over-weight head entry cannot wedge the queue)."""
        out: list[T] = []
        acc = 0
        while self._queue:
            head = self._queue[0]
            if out and acc + head.weight > self.config.max_items:
                break
            out.append(head.item)
            acc += head.weight
            self._weight -= head.weight
            self._queue.pop(0)
        return out

    def pop_all(self) -> list[T]:
        """Drain the whole queue regardless of weight (micro-batch
        consumers that can absorb arbitrarily many items per step)."""
        out = [e.item for e in self._queue]
        self._queue.clear()
        self._weight = 0
        return out


def drain(admitter: DualThresholdAdmitter[Any], force: bool = False) -> list[Any]:
    """``pop_all`` if the admitter is ready (or ``force``), else ``[]``."""
    if force or admitter.ready():
        return admitter.pop_all()
    return []
