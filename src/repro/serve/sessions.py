"""Per-sensor session lifecycle for the detection service.

A :class:`SensorSession` is the service-side identity of one live event
camera: it owns the sensor's slot in the fleet pool, validates the
monotone-timestamp contract at *accept* time (a bad chunk is refused
before it is ever queued, so the micro-batch a session rides in can
never be poisoned by it), buffers accepted chunks until the admission
policy releases a fleet step, and keeps the per-session accounting the
operator reads: feeds, events, windows, backlog, and service-latency
samples.

Sessions are plain host objects — all device state lives in the fleet
carry, keyed by ``slot``. The lifecycle is strictly::

    attach (service assigns a zeroed slot)
      -> feed* (validate -> queue -> fleet step on admission)
      -> detach (flush trailing window, slot zeroed + recycled)

after which the session object survives as a read-only stats record
(``state == "detached"``).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.events import validate_monotone

Chunk = tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]

LIVE = "live"
DETACHED = "detached"


# Latency samples retained per session (a sliding window, so a long-lived
# session's stats stay O(1) in memory; counters stay exact forever).
MAX_LATENCY_SAMPLES = 1024


@dataclasses.dataclass
class SessionStats:
    """Monotone per-session counters plus service-latency samples.

    ``latency_ms`` keeps only the most recent :data:`MAX_LATENCY_SAMPLES`
    samples — percentiles describe recent behaviour, and a session
    feeding at live cadence for days cannot grow host memory unboundedly.
    """

    feeds: int = 0  # chunks accepted (empty chunks are no-ops, not counted)
    events: int = 0  # events accepted
    steps: int = 0  # fleet steps this session's chunks rode in
    windows: int = 0  # windows closed and returned to the session
    latency_ms: list[float] = dataclasses.field(default_factory=list)

    def record_latency(self, latency_ms: float) -> None:
        self.latency_ms.append(latency_ms)
        if len(self.latency_ms) > MAX_LATENCY_SAMPLES:
            del self.latency_ms[: len(self.latency_ms) - MAX_LATENCY_SAMPLES]

    def latency_percentile(self, q: float) -> float:
        """q-th percentile of the retained latency samples (0 when none)."""
        if not self.latency_ms:
            return 0.0
        return float(np.percentile(np.asarray(self.latency_ms), q))


@dataclasses.dataclass
class SensorSession:
    """One attached sensor: slot ownership, validation, chunk queue, stats."""

    sid: int
    slot: int
    name: str
    clock: Callable[[], float]
    state: str = LIVE
    last_t: int | None = None  # newest accepted timestamp
    stats: SessionStats = dataclasses.field(default_factory=SessionStats)
    # Chunks accepted but not yet absorbed by a fleet step, plus the
    # arrival stamp of the oldest one (service-latency measurement
    # origin; None while the queue is empty).
    _queue: list[Chunk] = dataclasses.field(default_factory=list)
    _queued_events: int = 0
    _oldest_arrival_s: float | None = None

    @property
    def queued_events(self) -> int:
        """Events accepted but not yet handed to the fleet step."""
        return self._queued_events

    def accept(self, x, y, t, p) -> int:
        """Validate and queue one raw chunk; returns its event count.

        Raises ``ValueError`` (chunk not absorbed, session unharmed) when
        the chunk is out of order within itself or against this session's
        stream — the same contract :class:`StreamingPipeline` enforces,
        applied here so the error surfaces at the offending ``feed`` call
        rather than inside a later micro-batched fleet step.
        """
        if self.state != LIVE:
            raise RuntimeError(f"session {self.sid} is {self.state}")
        t = np.asarray(t, np.int64)
        validate_monotone(t, self.last_t, label=f"session {self.sid}")
        n = len(t)
        if n == 0:
            return 0  # heartbeat: nothing to queue
        self._queue.append(
            (np.asarray(x), np.asarray(y), t, np.asarray(p))
        )
        if self._oldest_arrival_s is None:
            self._oldest_arrival_s = self.clock()
        self._queued_events += n
        self.last_t = int(t[-1])
        self.stats.feeds += 1
        self.stats.events += n
        return n

    def take(self) -> tuple[Chunk | None, float | None]:
        """Drain the queue as one merged chunk for a fleet step.

        Returns ``(chunk, oldest_arrival_s)`` — ``(None, None)`` when
        nothing is queued. Merging is safe: chunks were validated in
        accept order, and the streaming engine is bit-identical under
        any re-chunking, so one merged feed returns exactly the windows
        the individual feeds would have.
        """
        if not self._queue:
            return None, None
        if len(self._queue) == 1:
            chunk = self._queue[0]
        else:
            chunk = tuple(
                np.concatenate([c[i] for c in self._queue]) for i in range(4)
            )
        arrival = self._oldest_arrival_s
        self._queue.clear()
        self._queued_events = 0
        self._oldest_arrival_s = None
        return chunk, arrival

    def record_step(self, n_windows: int, latency_ms: float | None) -> None:
        """Account one fleet step; ``latency_ms`` is None when the step
        carried no queued chunk for this session (a bare detach flush),
        which is not a service-latency sample."""
        self.stats.steps += 1
        self.stats.windows += n_windows
        if latency_ms is not None:
            self.stats.record_latency(latency_ms)
