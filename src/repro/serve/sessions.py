"""Per-sensor session lifecycle for the detection service.

A :class:`SensorSession` is the service-side identity of one live event
camera: it owns the sensor's slot in the fleet pool, validates the
monotone-timestamp contract at *accept* time (a bad chunk is refused
before it is ever queued, so the micro-batch a session rides in can
never be poisoned by it), buffers accepted chunks until the admission
policy releases a fleet step — under an optional queue budget with
exact shed accounting — and keeps the per-session accounting the
operator reads: feeds, events, windows, backlog, shed counts, and
service-latency samples.

Sessions are plain host objects — all device state lives in the fleet
carry, keyed by ``slot``. The lifecycle is::

    attach (service assigns a zeroed slot)
      -> feed* (validate -> queue -> fleet step on admission)
      -> detach (flush trailing window, slot zeroed + recycled)

after which the session object survives as a read-only stats record
(``state == "detached"``). Two fault exits leave the same read-only
record (DESIGN.md Sec. 13): ``"quarantined"`` (an accept-time
validation failure under ``on_validation_error="quarantine"`` — queued
chunks and the slot remainder are discarded, the slot recycled) and
``"evicted"`` (heartbeat deadline missed — queued chunks and the
trailing window are flushed into ``tail_result``, then the slot is
recycled). Every fault transition appends a structured
:class:`SessionError` to ``errors``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.core.events import validate_monotone

Chunk = tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]

LIVE = "live"
DETACHED = "detached"
QUARANTINED = "quarantined"
EVICTED = "evicted"
# Exported to another shard's service (constellation re-migration): the
# local record survives read-only, like a detach, but the stream itself
# continues bit-identically under a new sid on the destination shard.
MIGRATED = "migrated"

# Shed policies for a budget-bounded session queue (DESIGN.md Sec. 13).
SHED_REJECT = "reject"          # refuse the whole over-budget chunk
SHED_DROP_OLDEST = "drop_oldest"  # admit the new chunk, drop oldest queued
SHED_POLICIES = (SHED_REJECT, SHED_DROP_OLDEST)


# Latency samples retained per session (a sliding window, so a long-lived
# session's stats stay O(1) in memory; counters stay exact forever).
MAX_LATENCY_SAMPLES = 1024


@dataclasses.dataclass(frozen=True)
class SessionError:
    """One structured fault record on a session (or service) timeline.

    ``kind`` is one of ``"validation"`` (bad chunk refused at accept),
    ``"evicted"`` (heartbeat deadline missed), ``"degraded_round"``
    (a fleet step exhausted its retries; the round's chunks were
    restored, nothing was lost).
    """

    kind: str
    sid: int
    time_s: float  # service clock at the fault
    message: str


@dataclasses.dataclass
class SessionStats:
    """Monotone per-session counters plus service-latency samples.

    ``latency_ms`` keeps only the most recent :data:`MAX_LATENCY_SAMPLES`
    samples — percentiles describe recent behaviour, and a session
    feeding at live cadence for days cannot grow host memory unboundedly.

    Shed accounting is exact by construction: every event offered to
    :meth:`SensorSession.accept` on a live session is either accepted
    or shed, so ``offered_events == events + shed_events`` always
    (validation-refused chunks are counted in neither — they were never
    admitted into the accounting stream; they increment
    ``validation_failures`` instead).
    """

    feeds: int = 0  # chunks accepted (empty chunks are no-ops, not counted)
    events: int = 0  # events accepted
    offered_events: int = 0  # events offered past validation (accepted + shed)
    shed_events: int = 0  # events shed by the queue budget
    shed_chunks: int = 0  # whole chunks shed (reject) or dropped (drop_oldest)
    validation_failures: int = 0  # chunks refused by validate/range checks
    degraded_rounds: int = 0  # fleet rounds that failed + restored this queue
    # Admission rounds deferred while this session had queued data because
    # the ingest pipeline was full (max_inflight_rounds reached, oldest
    # round still executing). Deferral is backpressure, not loss: the
    # queue and the admitter state are untouched, so the events ride the
    # next dispatched round and offered == events + shed stays exact.
    deferred_rounds: int = 0
    steps: int = 0  # fleet steps this session's chunks rode in
    windows: int = 0  # windows closed and returned to the session
    latency_ms: list[float] = dataclasses.field(default_factory=list)

    def record_latency(self, latency_ms: float) -> None:
        self.latency_ms.append(latency_ms)
        if len(self.latency_ms) > MAX_LATENCY_SAMPLES:
            del self.latency_ms[: len(self.latency_ms) - MAX_LATENCY_SAMPLES]

    def latency_percentile(self, q: float) -> float:
        """q-th percentile of the retained latency samples (0 when none)."""
        if not self.latency_ms:
            return 0.0
        return float(np.percentile(np.asarray(self.latency_ms), q))


@dataclasses.dataclass
class _Queued:
    """One accepted-but-unstepped chunk with its arrival stamp."""

    chunk: Chunk
    n: int
    arrival_s: float


# Coordinate sanity bound: anything outside this range cannot be a pixel
# address on any supported sensor and would wrap when packed into the
# int32 transfer planes — treat it as corruption, not as an off-sensor
# event (which the pipeline masks fine). Polarity gets the same net.
COORD_LIMIT = 1 << 30


@dataclasses.dataclass
class SensorSession:
    """One attached sensor: slot ownership, validation, bounded chunk
    queue, shed accounting, stats."""

    sid: int
    slot: int
    name: str
    clock: Callable[[], float]
    state: str = LIVE
    queue_budget: int | None = None  # max queued events (None = unbounded)
    shed_policy: str = SHED_REJECT
    last_t: int | None = None  # newest accepted timestamp
    stats: SessionStats = dataclasses.field(default_factory=SessionStats)
    errors: list[SessionError] = dataclasses.field(default_factory=list)
    tail_result: object | None = None  # eviction flush tail (ScanResult)
    # Chunks accepted but not yet absorbed by a fleet step, each with its
    # arrival stamp (service-latency measurement origin; the oldest
    # surviving stamp rides through drop_oldest shedding exactly).
    _queue: list[_Queued] = dataclasses.field(default_factory=list)
    _queued_events: int = 0

    def __post_init__(self):
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"shed_policy must be one of {SHED_POLICIES}, "
                f"got {self.shed_policy!r}"
            )
        if self.queue_budget is not None and self.queue_budget < 1:
            raise ValueError(
                f"queue_budget must be >= 1 events, got {self.queue_budget}"
            )

    @property
    def queued_events(self) -> int:
        """Events accepted but not yet handed to the fleet step."""
        return self._queued_events

    def accept(self, x, y, t, p) -> int:
        """Validate and queue one raw chunk; returns the number of its
        events actually queued (less than ``len(t)`` when the queue
        budget shed).

        Raises ``ValueError`` (chunk not absorbed, session unharmed) when
        the chunk is out of order within itself or against this session's
        stream — the same contract :class:`StreamingPipeline` enforces —
        or when coordinates/polarities are corrupt (outside
        ``±COORD_LIMIT``: garbage that would wrap in the int32 transfer
        planes, as opposed to merely off-sensor events, which the
        pipeline masks). The error surfaces at the offending ``feed``
        call rather than inside a later micro-batched fleet step.
        """
        if self.state != LIVE:
            raise RuntimeError(f"session {self.sid} is {self.state}")
        t = np.asarray(t, np.int64)
        validate_monotone(t, self.last_t, label=f"session {self.sid}")
        x, y, p = (np.asarray(a, np.int64) for a in (x, y, p))
        for label, a in (("x", x), ("y", y), ("p", p)):
            if len(a) and (
                int(a.min()) <= -COORD_LIMIT or int(a.max()) >= COORD_LIMIT
            ):
                raise ValueError(
                    f"session {self.sid}: corrupt {label} values outside "
                    f"+-{COORD_LIMIT} (int32-unsafe garbage, not off-sensor "
                    "coordinates)"
                )
        n = len(t)
        if n == 0:
            return 0  # heartbeat: nothing to queue
        self.stats.offered_events += n
        budget = self.queue_budget
        if budget is not None and self._queued_events + n > budget:
            accepted = self._shed(x, y, t, p, n, budget)
        else:
            self._push((x, y, t, p), n)
            accepted = n
        # Exact accounting invariant: offered == accepted(events) + shed.
        self.last_t = int(t[-1])
        return accepted

    def _push(self, chunk: Chunk, n: int) -> None:
        self._queue.append(_Queued(chunk, n, self.clock()))
        self._queued_events += n
        self.stats.feeds += 1
        self.stats.events += n

    def _shed(self, x, y, t, p, n: int, budget: int) -> int:
        """Apply the shed policy to an over-budget chunk; returns the
        number of the chunk's events queued."""
        if self.shed_policy == SHED_REJECT:
            # Refuse the whole chunk; queued data is older and keeps its
            # service-latency clock. The stream simply has a gap (the
            # pipeline is gap-tolerant; last_t still advances so later
            # chunks validate against the true newest timestamp).
            self.stats.shed_events += n
            self.stats.shed_chunks += 1
            return 0
        # drop_oldest: the freshest data wins. Shed the oldest queued
        # chunks until the new one fits; an oversized chunk keeps only
        # its newest `budget` events (a prefix drop preserves the
        # time-sorted contract).
        keep_n = min(n, budget)
        if keep_n < n:
            cut = n - keep_n
            x, y, t, p = x[cut:], y[cut:], t[cut:], p[cut:]
            self.stats.shed_events += cut
        while self._queue and self._queued_events + keep_n > budget:
            old = self._queue.pop(0)
            self._queued_events -= old.n
            self.stats.shed_events += old.n
            self.stats.shed_chunks += 1
            # The shed chunk was counted accepted at its own accept();
            # un-count it so `events` tracks what the fleet will see.
            self.stats.events -= old.n
            self.stats.feeds -= 1
        self._push((x, y, t, p), keep_n)
        return keep_n

    def take(self) -> tuple[Chunk | None, float | None]:
        """Drain the queue as one merged chunk for a fleet step.

        Returns ``(chunk, oldest_arrival_s)`` — ``(None, None)`` when
        nothing is queued. Merging is safe: chunks were validated in
        accept order, and the streaming engine is bit-identical under
        any re-chunking, so one merged feed returns exactly the windows
        the individual feeds would have.
        """
        if not self._queue:
            return None, None
        if len(self._queue) == 1:
            chunk = self._queue[0].chunk
        else:
            chunk = tuple(
                np.concatenate([q.chunk[i] for q in self._queue])
                for i in range(4)
            )
        arrival = self._queue[0].arrival_s
        self._queue.clear()
        self._queued_events = 0
        return chunk, arrival

    def restore(self, chunk: Chunk, arrival_s: float | None) -> None:
        """Put back a chunk handed out by :meth:`take` after a fleet step
        failed (degraded round): the data re-queues at the head with its
        original arrival stamp, so nothing is lost and the latency clock
        keeps measuring from the true oldest arrival."""
        n = len(chunk[2])
        self._queue.insert(
            0, _Queued(chunk, n, self.clock() if arrival_s is None else arrival_s)
        )
        self._queued_events += n

    def export_queue(self) -> list[tuple[Chunk, float]]:
        """Drain the queue as ``(chunk, arrival_s)`` pairs in arrival
        order — the migration counterpart of :meth:`take`. Unlike
        ``take`` the chunks stay separate with their own stamps, so the
        adopting session (:meth:`requeue`) reconstructs the queue
        exactly: latency clocks and shed bookkeeping carry over."""
        out = [(q.chunk, q.arrival_s) for q in self._queue]
        self._queue.clear()
        self._queued_events = 0
        return out

    def requeue(self, chunk: Chunk, arrival_s: float) -> None:
        """Append one exported chunk with its original arrival stamp
        (adopt path). No stats are touched: the exported
        :class:`SessionStats` already counted these events at their
        original ``accept``."""
        self._queue.append(_Queued(chunk, len(chunk[2]), arrival_s))
        self._queued_events += len(chunk[2])

    def drop_queue(self) -> int:
        """Discard every queued chunk (quarantine path); returns the
        number of events discarded."""
        dropped = self._queued_events
        self._queue.clear()
        self._queued_events = 0
        return dropped

    def record_step(self, n_windows: int, latency_ms: float | None) -> None:
        """Account one fleet step; ``latency_ms`` is None when the step
        carried no queued chunk for this session (a bare detach flush),
        which is not a service-latency sample."""
        self.stats.steps += 1
        self.stats.windows += n_windows
        if latency_ms is not None:
            self.stats.record_latency(latency_ms)

    def record_error(self, kind: str, message: str) -> SessionError:
        err = SessionError(
            kind=kind, sid=self.sid, time_s=self.clock(), message=message
        )
        self.errors.append(err)
        return err
