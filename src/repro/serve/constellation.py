"""ConstellationService: many DetectionService shards over a device mesh.

The paper positions the detection stack as a building block for
*distributed space surveillance networks*; this module is that scale-out
layer (DESIGN.md Sec. 15). A :class:`ConstellationService` partitions
sensor sessions across N :class:`~repro.serve.service.DetectionService`
shards. Each shard owns a slice of the available devices as its own
``sensor``-axis mesh (real accelerators when present, the
``jax.devices()``-backed simulated multi-host otherwise) and runs its
own pipelined rounds — shards at different capacity tiers keep rounds
in flight concurrently instead of the single lock-step compiled step a
lone service dispatches.

Layered on top of the per-shard services:

* **Placement / rebalance planner.** ``attach`` routes a new sensor to
  the least-loaded up shard. Fault exits that free capacity (heartbeat
  eviction, tier demotion) trigger a rebalance sweep that re-migrates
  sessions from the most- to the least-loaded shard via the carry
  export/adopt path, which itself rides ``grow_fleet_carry`` /
  ``shrink_fleet_carry`` tier moves on either end. Migration preserves
  bit-identity: the slot carry IS the entire stream state.
* **Whole-shard rescue.** A shard whose fleet rounds keep failing
  (``rescue_after_degraded_rounds`` consecutive degraded rounds) is
  marked down and every session on it is re-migrated to the surviving
  shards — sessions are moved, not lost, because a degraded round
  restores its chunks to the session queues and the export carries
  queue + carry + stats across. ``revive_shard`` re-admits a repaired
  shard for new placements.
* **Compressed cross-shard exchange.** Every shard publishes a compact
  per-round summary plane (windows + valid clusters + per-metric sums
  per slot) through :class:`CrossShardExchange`, which quantizes the
  plane to int8 with an error-feedback buffer
  (:mod:`repro.distributed.compression`) so the cross-shard wire cost
  is ~4x below fp32 while the running per-shard sums stay exact up to
  the final residual (the EF telescoping bound, pinned by tests).

Healthy-session outputs stay bit-identical to dedicated
:class:`~repro.core.pipeline.stream.StreamingPipeline` runs under any
multi-shard churn — attach/feed/detach interleavings, explicit
migrations, rebalances, and whole-shard rescue (pinned by
tests/test_constellation.py and the shard chaos harness in
:mod:`repro.serve.chaos_shards`).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.fleet import DEFAULT_TIERS, PendingRound
from repro.core.pipeline.scan import ScanResult
from repro.distributed.compression import dequantize_int8, quantize_int8
from repro.serve.batcher import AdmissionConfig
from repro.serve.faults import FaultConfig
from repro.serve.service import DetectionService, ServedFeed
from repro.serve.sessions import LIVE, SensorSession

SENSOR_AXIS = "sensor"

EXCHANGE_MODES = ("int8_ef", "exact", "off")


def partition_devices(devices, n_shards: int) -> list[tuple]:
    """Split ``devices`` into ``n_shards`` per-shard groups.

    With at least one device per shard the split is contiguous and
    balanced (first ``len % n`` shards get the extra device) so each
    shard's mesh is a compact slice of the device order. With fewer
    devices than shards, shards share devices round-robin — the
    simulated multi-host shape on small hosts.
    """
    devices = list(devices)
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if not devices:
        raise ValueError("need at least one device")
    if len(devices) < n_shards:
        return [(devices[i % len(devices)],) for i in range(n_shards)]
    base, extra = divmod(len(devices), n_shards)
    groups, at = [], 0
    for i in range(n_shards):
        n = base + (1 if i < extra else 0)
        groups.append(tuple(devices[at : at + n]))
        at += n
    return groups


@functools.lru_cache(maxsize=None)
def _summary_fn(n_metrics: int):
    """Jit'd per-round summary plane: (S, 2 + n_metrics) float32.

    Column 0 is each slot's real window count this round, column 1 its
    valid-cluster count, and the rest the per-metric sums over valid
    clusters in real windows — the compact per-slot digest a fusion /
    catalog consumer wants from every remote shard each round. Padded
    windows and invalid cluster rows contribute exactly zero.
    """

    def summary(valid, n_valid, *mets):
        wmask = jnp.arange(valid.shape[1])[None, :] < n_valid[:, None]
        cmask = valid & wmask[:, :, None]
        cols = [
            n_valid.astype(jnp.float32),
            jnp.sum(cmask, axis=(1, 2)).astype(jnp.float32),
        ]
        for m in mets:
            cols.append(
                jnp.sum(
                    jnp.where(cmask, m.astype(jnp.float32), 0.0), axis=(1, 2)
                )
            )
        return jnp.stack(cols, axis=1)

    return jax.jit(summary)


@functools.lru_cache(maxsize=None)
def _compress_fn():
    """Jit'd EF-int8 round trip for one plane: (q, scale, deq, ef')."""

    def compress(plane, ef):
        corrected = plane + ef
        q, scale = quantize_int8(corrected)
        deq = dequantize_int8(q, scale)
        return q, scale, deq, corrected - deq

    return jax.jit(compress)


class CrossShardExchange:
    """Compressed per-round result-plane exchange between shards.

    Each shard pushes its round's summary plane
    (:func:`_summary_fn`); peers read the latest published plane per
    shard via :meth:`latest`. In ``"int8_ef"`` mode the plane crosses
    the (simulated) wire as int8 + one fp32 scale — ~4x fewer bytes
    than fp32 — with a per-shard error-feedback buffer carrying the
    quantization residual into the next round, so:

    * per round: ``|deq - (plane + ef_prev)| <= scale / 2`` elementwise
      (symmetric int8 round-to-nearest, unsaturated by construction
      since the scale is the per-tensor absmax / 127), and
    * telescoping: the sum of published planes equals the sum of exact
      planes minus the final EF residual — running cross-shard
      accumulations are exact up to one round's quantization error.

    ``"exact"`` publishes fp32 planes (the oracle the tests compare
    against); ``"off"`` publishes nothing. Pushing never synchronizes
    with the device — planes stay lazy jax arrays until read — so the
    exchange cannot serialize the shards' interleaved rounds.
    """

    def __init__(self, n_shards: int, mode: str = "int8_ef"):
        if mode not in EXCHANGE_MODES:
            raise ValueError(
                f"exchange mode must be one of {EXCHANGE_MODES}, got {mode!r}"
            )
        self.n_shards = n_shards
        self.mode = mode
        self.columns: tuple[str, ...] | None = None  # set at first push
        self.rounds = 0
        self.wire_bytes = 0  # bytes a compressed link would carry
        self.exact_bytes = 0  # bytes the fp32 link would carry
        self._latest: list = [None] * n_shards  # published plane (lazy)
        self._ef: list = [None] * n_shards  # error-feedback carry (lazy)
        self._scale: list = [None] * n_shards  # last round's quant scale

    @staticmethod
    def summary_plane(round_: PendingRound) -> jax.Array | None:
        """The exact (uncompressed) summary plane for one fleet round —
        ``None`` when the round closed no window. Public so tests and
        consumers can compare published planes against the oracle."""
        res = round_.result()
        if res.clusters is None:
            return None
        keys = tuple(sorted(res.metrics))
        return _summary_fn(len(keys))(
            res.clusters.valid,
            jnp.asarray(res.n_windows),
            *[res.metrics[k] for k in keys],
        )

    def push_round(self, shard: int, round_: PendingRound) -> None:
        """Publish one shard's round. No-op in ``"off"`` mode or when
        the round closed no window (nothing to exchange)."""
        if self.mode == "off":
            return
        res = round_.result()
        if res.clusters is None:
            return
        if self.columns is None:
            self.columns = ("windows", "clusters") + tuple(sorted(res.metrics))
        plane = self.summary_plane(round_)
        self.rounds += 1
        self.exact_bytes += plane.size * 4
        if self.mode == "exact":
            self.wire_bytes += plane.size * 4
            self._latest[shard] = plane
            return
        ef = self._ef[shard]
        if ef is None or ef.shape != plane.shape:
            # Tier promotion/demotion resized the slot pool: grow appends
            # slots and shrink drops the free tail, so surviving rows
            # keep their residual and new rows start clean.
            fresh = jnp.zeros(plane.shape, jnp.float32)
            if ef is not None:
                keep = min(ef.shape[0], plane.shape[0])
                fresh = fresh.at[:keep].set(ef[:keep])
            ef = fresh
        q, scale, deq, ef = _compress_fn()(plane, ef)
        self.wire_bytes += q.size + 4  # int8 payload + one fp32 scale
        self._latest[shard] = deq
        self._ef[shard] = ef
        self._scale[shard] = scale

    def latest(self, shard: int) -> np.ndarray | None:
        """Most recently published plane for ``shard`` (host fp32), as a
        peer would decode it — dequantized in ``"int8_ef"`` mode."""
        p = self._latest[shard]
        return None if p is None else np.asarray(p)

    def error_feedback(self, shard: int) -> np.ndarray | None:
        """Current EF residual for ``shard`` (None before any push)."""
        e = self._ef[shard]
        return None if e is None else np.asarray(e)

    def last_scale(self, shard: int) -> float | None:
        """Quantization scale of ``shard``'s last published round."""
        s = self._scale[shard]
        return None if s is None else float(s)

    def view(self) -> dict[int, np.ndarray]:
        """All published planes, keyed by shard index."""
        out = {}
        for i in range(self.n_shards):
            p = self.latest(i)
            if p is not None:
                out[i] = p
        return out

    @property
    def stats(self) -> dict:
        return {
            "mode": self.mode,
            "rounds": self.rounds,
            "wire_bytes": self.wire_bytes,
            "exact_bytes": self.exact_bytes,
            "compression_ratio": (
                self.exact_bytes / self.wire_bytes if self.wire_bytes else 0.0
            ),
        }


@dataclasses.dataclass
class ConstellationFeed:
    """One session's share of one shard's fleet round, globally keyed."""

    gid: int  # constellation-global session id
    shard: int  # shard that served it
    feed: ServedFeed

    @property
    def num_windows(self) -> int:
        return self.feed.num_windows

    @property
    def latency_ms(self) -> float:
        return self.feed.latency_ms

    @property
    def result(self) -> ScanResult:
        return self.feed.result


@dataclasses.dataclass
class _Shard:
    """One shard's runtime record: the service, its device slice, and
    the constellation-side bookkeeping layered on it."""

    index: int
    service: DetectionService
    devices: tuple
    mesh: object | None
    down: bool = False
    # Local sid -> global id for constellation-live sessions only;
    # entries leave when the session migrates or a local fault closes it.
    local_to_global: dict[int, int] = dataclasses.field(default_factory=dict)
    # Fault-counter checkpoints (deltas drive rebalance/rescue triggers).
    degraded_seen: int = 0
    evictions_seen: int = 0
    demotions_seen: int = 0
    consecutive_degraded: int = 0
    pushed_round: object | None = None  # last round handed to the exchange

    @property
    def load(self) -> int:
        return self.service.n_sessions


class ConstellationService:
    """Sharded detection serving: sessions partitioned over N shards.

    >>> cs = ConstellationService(PipelineConfig(), n_shards=2)
    >>> gid = cs.attach("station-7")     # routed to the least-loaded shard
    >>> done = cs.feed(gid, x, y, t, p)  # [] until that shard admits
    >>> done = cs.pump(force=True)       # one round on EVERY up shard
    >>> tail = cs.detach(gid)

    Every shard is a full :class:`DetectionService` over its own fleet
    (own admitter, own slot pool, own capacity tier, own device mesh
    slice), so a constellation ``pump`` dispatches up to N rounds that
    execute concurrently — each shard's ``max_inflight_rounds`` depth
    (default 2 here) lets its next round's host packing overlap its
    previous round's device compute, and nothing in the constellation
    layer synchronizes between shard dispatches.

    Global session ids (``gid``) are stable across migration: the
    constellation owns the gid -> (shard, local sid) routing table and
    re-points it when a session moves, so callers never see the hop
    (beyond their stream continuing bit-identically on a new shard).

    ``rescue_after_degraded_rounds=None`` (default) disables whole-shard
    rescue; deployments with ``faults.degrade_on_step_failure`` set it
    to bound how long a stalled shard can hold its sessions hostage.
    """

    def __init__(
        self,
        config: PipelineConfig = PipelineConfig(),
        n_shards: int = 2,
        tiers: tuple[int, ...] = DEFAULT_TIERS,
        admission: AdmissionConfig = AdmissionConfig(),
        faults: FaultConfig = FaultConfig(),
        with_tracking: bool = True,
        devices=None,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        max_inflight_rounds: int = 2,
        exchange: str = "int8_ef",
        rebalance_margin: int = 2,
        auto_rebalance: bool = True,
        rescue_after_degraded_rounds: int | None = None,
        wire: str = "ragged",
    ):
        if rebalance_margin < 1:
            raise ValueError(
                f"rebalance_margin must be >= 1, got {rebalance_margin}"
            )
        self.config = config
        self.clock = clock
        self.rebalance_margin = rebalance_margin
        self.auto_rebalance = auto_rebalance
        self.rescue_after_degraded_rounds = rescue_after_degraded_rounds
        groups = partition_devices(
            jax.devices() if devices is None else devices, n_shards
        )
        single_device = len({id(d) for g in groups for d in g}) == 1
        self._shards: list[_Shard] = []
        for i, group in enumerate(groups):
            if single_device:
                # One physical device total: a mesh would only add
                # context overhead; every shard runs the unsharded path.
                mesh = None
            else:
                mesh = jax.sharding.Mesh(np.array(group), (SENSOR_AXIS,))
            self._shards.append(
                _Shard(
                    index=i,
                    service=DetectionService(
                        config,
                        tiers=tiers,
                        admission=admission,
                        faults=faults,
                        with_tracking=with_tracking,
                        mesh=mesh,
                        clock=clock,
                        sleep=sleep,
                        max_inflight_rounds=max_inflight_rounds,
                        wire=wire,
                    ),
                    devices=group,
                    mesh=mesh,
                )
            )
        self.exchange = CrossShardExchange(n_shards, exchange)
        self._routes: dict[int, tuple[int, int]] = {}  # gid -> (shard, lsid)
        self._closed: dict[int, tuple[int, int]] = {}  # gid -> last home
        self._next_gid = 0
        self.migrations = 0  # sessions moved between shards
        self.rebalances = 0  # rebalance sweeps that moved >= 1 session
        self.rescues = 0  # whole-shard rescues performed
        self._want_rebalance = False

    # ------------------------------------------------------------------
    # Introspection.
    # ------------------------------------------------------------------

    @property
    def n_shards(self) -> int:
        return len(self._shards)

    @property
    def n_sessions(self) -> int:
        """Constellation-live sessions across all shards."""
        return len(self._routes)

    @property
    def wire_stats(self):
        """Aggregate ingest transfer accounting over every shard's fleet
        (``WireStats`` — see :class:`~repro.serve.service.DetectionService`)."""
        from repro.core.pipeline.fleet import WireStats

        total = WireStats()
        for shard in self._shards:
            total.add(shard.service.wire_stats)
        return total

    @property
    def capacity(self) -> int:
        """Total slot-pool capacity across shards (sum of active tiers)."""
        return sum(sh.service.capacity for sh in self._shards)

    @property
    def loads(self) -> list[int]:
        """Live sessions per shard (placement-planner view)."""
        return [sh.load for sh in self._shards]

    @property
    def down_shards(self) -> list[int]:
        return [sh.index for sh in self._shards if sh.down]

    def shard(self, i: int) -> _Shard:
        """Shard runtime record (service, devices, mesh, fault deltas)."""
        return self._shards[i]

    def shard_of(self, gid: int) -> int:
        """Which shard currently (or last) hosts ``gid``."""
        home = self._routes.get(gid) or self._closed.get(gid)
        if home is None:
            raise KeyError(f"unknown session id {gid}")
        return home[0]

    def session(self, gid: int) -> SensorSession:
        """The session record (any state), wherever it lives now."""
        home = self._routes.get(gid) or self._closed.get(gid)
        if home is None:
            raise KeyError(f"unknown session id {gid}")
        return self._shards[home[0]].service.session(home[1])

    def backlog(self, gid: int) -> int:
        shard_i, lsid = self._route(gid)
        return self._shards[shard_i].service.backlog(lsid)

    def stats(self) -> dict:
        """Operator snapshot: planner counters, per-shard state, exchange."""
        return {
            "n_sessions": self.n_sessions,
            "capacity": self.capacity,
            "migrations": self.migrations,
            "rebalances": self.rebalances,
            "rescues": self.rescues,
            "shards": [
                {
                    "index": sh.index,
                    "down": sh.down,
                    "sessions": sh.load,
                    "capacity": sh.service.capacity,
                    "devices": [str(d) for d in sh.devices],
                    "degraded_rounds": sh.service.degraded_rounds,
                    "evictions": sh.service.evictions,
                    "quarantines": sh.service.quarantines,
                    "inflight_rounds": sh.service.inflight_rounds,
                }
                for sh in self._shards
            ],
            "exchange": self.exchange.stats,
        }

    # ------------------------------------------------------------------
    # Lifecycle.
    # ------------------------------------------------------------------

    def attach(self, name: str | None = None) -> int:
        """Admit a new sensor on the least-loaded up shard; returns its
        constellation-global session id."""
        shard = self._pick_shard()
        gid = self._next_gid
        self._next_gid += 1
        lsid = shard.service.attach(name or f"sensor-{gid}")
        self._routes[gid] = (shard.index, lsid)
        shard.local_to_global[lsid] = gid
        return gid

    def feed(self, gid: int, x, y, t, p) -> list[ConstellationFeed]:
        """Queue one chunk for ``gid`` on its shard; that shard steps if
        its admission fires. Returns the feeds completed by this call
        (the owning shard's round only — other shards step on their own
        admission clocks or on :meth:`pump`)."""
        shard_i, lsid = self._route(gid)
        shard = self._shards[shard_i]
        feeds = shard.service.feed(lsid, x, y, t, p)
        out = self._wrap(shard, feeds)
        self._after_round(shard, bool(feeds))
        self._maybe_rescue()
        self._flush_rebalance()
        return out

    def pump(self, force: bool = False) -> list[ConstellationFeed]:
        """One round on every up shard (admission-gated unless ``force``).

        Shards dispatch in index order without synchronizing between
        dispatches: with pipeline depth > 1 every shard's round is in
        flight before the first one's results are consumed, which is
        the constellation's concurrency model on one host. Follows up
        with fault reconciliation, whole-shard rescue, and any pending
        fault-triggered rebalance."""
        out: list[ConstellationFeed] = []
        for shard in self._shards:
            if shard.down:
                continue
            feeds = shard.service.pump(force=force)
            out.extend(self._wrap(shard, feeds))
            self._after_round(shard, bool(feeds))
        self._maybe_rescue()
        self._flush_rebalance()
        return out

    def drain(self) -> None:
        """Retire every in-flight round on every up shard."""
        for shard in self._shards:
            if not shard.down:
                shard.service.drain()

    def detach(self, gid: int) -> ScanResult:
        """Close ``gid`` wherever it lives: flush + recycle on its shard,
        return the tail result."""
        shard_i, lsid = self._route(gid)
        shard = self._shards[shard_i]
        out = shard.service.detach(lsid)
        del shard.local_to_global[lsid]
        del self._routes[gid]
        self._closed[gid] = (shard_i, lsid)
        return out

    def forget(self, gid: int) -> None:
        """Drop a closed session's record (here and on its last shard)."""
        home = self._closed.pop(gid, None)
        if home is None:
            if gid in self._routes:
                raise RuntimeError(f"session {gid} is live; detach first")
            return
        self._shards[home[0]].service.forget(home[1])

    # ------------------------------------------------------------------
    # Placement / rebalance planner (DESIGN.md Sec. 15).
    # ------------------------------------------------------------------

    def migrate(self, gid: int, dst: int) -> None:
        """Move one live session to shard ``dst`` via carry export/adopt.

        The stream resumes bit-identically on the destination (the slot
        carry is the entire stream state); queued chunks, the latency
        clock, and the stats record travel with it. The gid is stable —
        only the routing table changes."""
        shard_i, lsid = self._route(gid)
        src = self._shards[shard_i]
        dst_shard = self._shards[dst]
        if dst_shard.down:
            raise RuntimeError(f"shard {dst} is down")
        if dst_shard is src:
            return
        export = src.service.export_session(lsid)
        del src.local_to_global[lsid]
        new_lsid = dst_shard.service.adopt_session(export)
        self._routes[gid] = (dst, new_lsid)
        dst_shard.local_to_global[new_lsid] = gid
        self.migrations += 1

    def rebalance(self, max_moves: int | None = None) -> int:
        """Re-migrate sessions from the most- to the least-loaded up
        shard until the spread is within ``rebalance_margin`` (or
        ``max_moves`` moves were made). Returns the number of moves."""
        moves = 0
        while max_moves is None or moves < max_moves:
            up = [sh for sh in self._shards if not sh.down]
            if len(up) < 2:
                break
            hi = max(up, key=lambda s: (s.load, -s.index))
            lo = min(up, key=lambda s: (s.load, s.index))
            if hi.load - lo.load <= self.rebalance_margin:
                break
            # Youngest local session moves: oldest streams keep their
            # warm placement, and the youngest has the least state.
            lsid = max(hi.local_to_global)
            self.migrate(hi.local_to_global[lsid], lo.index)
            moves += 1
        if moves:
            self.rebalances += 1
        return moves

    def rescue_shard(self, i: int) -> int:
        """Mark shard ``i`` down and re-migrate every session it holds
        to the surviving shards (least-loaded first). Returns the number
        of sessions moved. Raises when no other shard is up — there is
        nowhere to move the streams, and marking the only shard down
        would strand them."""
        shard = self._shards[i]
        others = [s for s in self._shards if s is not shard and not s.down]
        if not others:
            raise RuntimeError(
                f"cannot rescue shard {i}: no other shard is up"
            )
        moved = 0
        for lsid in sorted(shard.local_to_global):
            gid = shard.local_to_global[lsid]
            dst = min(others, key=lambda s: (s.load, s.index))
            self.migrate(gid, dst.index)
            moved += 1
        shard.down = True
        self.rescues += 1
        return moved

    def revive_shard(self, i: int) -> None:
        """Re-admit a repaired shard for new placements (existing
        sessions stay where the rescue put them)."""
        shard = self._shards[i]
        shard.down = False
        shard.consecutive_degraded = 0
        shard.degraded_seen = shard.service.degraded_rounds

    # ------------------------------------------------------------------
    # Internals.
    # ------------------------------------------------------------------

    def _route(self, gid: int) -> tuple[int, int]:
        home = self._routes.get(gid)
        if home is None:
            if gid in self._closed:
                state = self.session(gid).state
                raise RuntimeError(f"session {gid} is {state}")
            raise KeyError(f"unknown session id {gid}")
        return home

    def _pick_shard(self) -> _Shard:
        up = [sh for sh in self._shards if not sh.down]
        if not up:
            raise RuntimeError("every shard is down; revive one first")
        return min(up, key=lambda s: (s.load, s.index))

    def _wrap(
        self, shard: _Shard, feeds: list[ServedFeed]
    ) -> list[ConstellationFeed]:
        return [
            ConstellationFeed(
                gid=shard.local_to_global[f.sid], shard=shard.index, feed=f
            )
            for f in feeds
        ]

    def _after_round(self, shard: _Shard, served: bool) -> None:
        """Post-round bookkeeping for one shard: reconcile local fault
        exits into the routing table, track degraded streaks, schedule
        fault-triggered rebalances, publish to the exchange."""
        svc = shard.service
        # Local faults (quarantine / heartbeat eviction) close sessions
        # inside the shard; re-point their global routes to "closed".
        for lsid, gid in list(shard.local_to_global.items()):
            if svc.session(lsid).state != LIVE:
                del shard.local_to_global[lsid]
                del self._routes[gid]
                self._closed[gid] = (shard.index, lsid)
        delta = svc.degraded_rounds - shard.degraded_seen
        if delta > 0:
            shard.degraded_seen = svc.degraded_rounds
            shard.consecutive_degraded += delta
        elif served:
            shard.consecutive_degraded = 0
        # Fault exits that freed capacity re-trigger the planner.
        if (
            svc.evictions != shard.evictions_seen
            or svc.demotions != shard.demotions_seen
        ):
            shard.evictions_seen = svc.evictions
            shard.demotions_seen = svc.demotions
            self._want_rebalance = True
        rnd = svc.last_round
        if rnd is not None and rnd is not shard.pushed_round:
            self.exchange.push_round(shard.index, rnd)
            shard.pushed_round = rnd

    def _maybe_rescue(self) -> None:
        if self.rescue_after_degraded_rounds is None:
            return
        for shard in self._shards:
            if (
                not shard.down
                and shard.consecutive_degraded
                >= self.rescue_after_degraded_rounds
                and any(
                    s is not shard and not s.down for s in self._shards
                )
            ):
                self.rescue_shard(shard.index)

    def _flush_rebalance(self) -> None:
        if self._want_rebalance and self.auto_rebalance:
            self._want_rebalance = False
            self.rebalance()
