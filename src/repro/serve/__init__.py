"""Layered serving stack (DESIGN.md Secs. 11, 13).

* ``batcher``  — the paper's dual-threshold admission policy as a
  generic, fake-clock-testable primitive.
* ``sessions`` — per-sensor session lifecycle (attach / feed / detach,
  monotone-timestamp enforcement, bounded queues with shed accounting,
  latency + backlog accounting, structured fault records).
* ``faults``   — :class:`FaultConfig` degraded-mode policy + the
  session-keyed heartbeat/straggler adapter.
* ``service``  — :class:`DetectionService`: micro-batched detection
  serving over the slot-pooled fleet engine, with per-session fault
  isolation (quarantine, heartbeat eviction, degraded rounds).
* ``chaos``    — deterministic seeded fault-injection harness pinning
  the isolation and bit-identity guarantees.
* ``constellation`` — :class:`ConstellationService`: sensor sessions
  partitioned over N service shards on a device mesh, with the
  placement/rebalance planner, whole-shard rescue, and the compressed
  cross-shard exchange (DESIGN.md Sec. 15).
* ``chaos_shards`` — the shard-level chaos harness (whole-shard stalls,
  forced migrations/rebalances on top of the per-sensor taxonomy).
* ``lm``       — the batched LM engine, a thin client of the shared
  batcher. Lazy here: importing ``repro.serve`` does not pull the LM
  client; ``repro.serve.engine`` remains as a deprecated shim.
"""
from repro.serve.batcher import (  # noqa: F401
    AdmissionConfig,
    DualThresholdAdmitter,
)
from repro.serve.chaos import (  # noqa: F401
    FAULT_TAXONOMY,
    ChaosConfig,
    ChaosHarness,
    ChaosReport,
)
from repro.serve.chaos_shards import (  # noqa: F401
    SHARD_FAULT_TAXONOMY,
    ShardChaosConfig,
    ShardChaosHarness,
    ShardChaosReport,
)
from repro.serve.constellation import (  # noqa: F401
    ConstellationFeed,
    ConstellationService,
    CrossShardExchange,
    partition_devices,
)
from repro.serve.faults import (  # noqa: F401
    FaultConfig,
    SessionHealth,
)
from repro.serve.sessions import (  # noqa: F401
    SensorSession,
    SessionError,
    SessionStats,
)
from repro.serve.service import (  # noqa: F401
    DetectionService,
    ServedFeed,
)

# LM engine names resolve lazily so the detection-serving surface does
# not import the LM client (or anything it drags in) eagerly.
_LM_NAMES = ("DualThresholdBatcher", "EngineConfig", "Request", "ServingEngine")


def __getattr__(name: str):
    if name in _LM_NAMES:
        from repro.serve import lm

        return getattr(lm, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_LM_NAMES))
