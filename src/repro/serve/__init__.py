from repro.serve.engine import (  # noqa: F401
    DualThresholdBatcher,
    EngineConfig,
    Request,
    ServingEngine,
)
