"""Layered serving stack (DESIGN.md Secs. 11, 13).

* ``batcher``  — the paper's dual-threshold admission policy as a
  generic, fake-clock-testable primitive.
* ``sessions`` — per-sensor session lifecycle (attach / feed / detach,
  monotone-timestamp enforcement, bounded queues with shed accounting,
  latency + backlog accounting, structured fault records).
* ``faults``   — :class:`FaultConfig` degraded-mode policy + the
  session-keyed heartbeat/straggler adapter.
* ``service``  — :class:`DetectionService`: micro-batched detection
  serving over the slot-pooled fleet engine, with per-session fault
  isolation (quarantine, heartbeat eviction, degraded rounds).
* ``chaos``    — deterministic seeded fault-injection harness pinning
  the isolation and bit-identity guarantees.
* ``lm``       — the batched LM engine, a thin client of the shared
  batcher (``repro.serve.engine`` remains as a shim).
"""
from repro.serve.batcher import (  # noqa: F401
    AdmissionConfig,
    DualThresholdAdmitter,
)
from repro.serve.chaos import (  # noqa: F401
    FAULT_TAXONOMY,
    ChaosConfig,
    ChaosHarness,
    ChaosReport,
)
from repro.serve.faults import (  # noqa: F401
    FaultConfig,
    SessionHealth,
)
from repro.serve.lm import (  # noqa: F401
    DualThresholdBatcher,
    EngineConfig,
    Request,
    ServingEngine,
)
from repro.serve.sessions import (  # noqa: F401
    SensorSession,
    SessionError,
    SessionStats,
)
from repro.serve.service import (  # noqa: F401
    DetectionService,
    ServedFeed,
)
