"""Layered serving stack (DESIGN.md Sec. 11).

* ``batcher``  — the paper's dual-threshold admission policy as a
  generic, fake-clock-testable primitive.
* ``sessions`` — per-sensor session lifecycle (attach / feed / detach,
  monotone-timestamp enforcement, latency + backlog accounting).
* ``service``  — :class:`DetectionService`: micro-batched detection
  serving over the slot-pooled fleet engine.
* ``lm``       — the batched LM engine, a thin client of the shared
  batcher (``repro.serve.engine`` remains as a shim).
"""
from repro.serve.batcher import (  # noqa: F401
    AdmissionConfig,
    DualThresholdAdmitter,
)
from repro.serve.lm import (  # noqa: F401
    DualThresholdBatcher,
    EngineConfig,
    Request,
    ServingEngine,
)
from repro.serve.sessions import (  # noqa: F401
    SensorSession,
    SessionStats,
)
from repro.serve.service import (  # noqa: F401
    DetectionService,
    ServedFeed,
)
