"""Fault-tolerance policy for the detection service (DESIGN.md Sec. 13).

:class:`FaultConfig` is the one knob block: what happens on a
validation failure (raise, the strict default, or quarantine the
offending session), how big a session's ingest queue may grow and which
shed policy bounds it, how long a silent sensor lives before heartbeat
eviction, and how many times a failed fleet step retries before the
round is marked degraded.

:class:`SessionHealth` adapts the generic cluster-liveness primitives —
:class:`~repro.distributed.fault_tolerance.HeartbeatMonitor` and
:class:`~repro.distributed.fault_tolerance.StragglerTracker`, built for
1000-node training jobs — to sensor sessions: node ids are session ids,
a heartbeat is any ``feed`` call (an empty chunk counts — that is what
a live but quiet sensor sends), and the straggler EMA runs over
per-session service latencies so persistently slow feeds are flagged
relative to the fleet median. Everything is clock-injected; nothing
here sleeps or threads.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.distributed.fault_tolerance import HeartbeatMonitor, StragglerTracker
from repro.serve.sessions import SHED_POLICIES, SHED_REJECT

ON_VALIDATION = ("raise", "quarantine")


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Fault handling policy for :class:`~repro.serve.service.DetectionService`.

    The default is the strict PR-5 contract — validation errors raise at
    the ``feed`` call, queues are unbounded, nothing is evicted and a
    step failure propagates. A fault-tolerant deployment turns each
    degraded-mode behaviour on explicitly; the bit-identity guarantee
    (healthy sessions' outputs never change, faults on or off) holds for
    every combination.
    """

    # Accept-time validation failure: "raise" (strict, default) or
    # "quarantine" (record the error, recycle the slot, keep serving).
    on_validation_error: str = "raise"
    # Per-session ingest bound: max queued events (None = unbounded) and
    # the shed policy applied when a chunk would exceed it.
    queue_budget_events: int | None = None
    shed_policy: str = SHED_REJECT
    # A live session whose last feed (any feed — empty chunks are
    # heartbeats) is older than this is evicted: flushed, slot recycled.
    # None disables eviction.
    heartbeat_timeout_s: float | None = None
    # Capacity-tier demotion after evictions empty the pool's tail.
    demote_tiers: bool = True
    # Straggler flagging: per-session service-latency EMA more than
    # `straggler_factor` x the fleet median marks the session slow.
    straggler_factor: float = 4.0
    straggler_alpha: float = 0.2
    # A fleet step that raises is retried with exponential backoff
    # (base * 2^attempt). With `degrade_on_step_failure`, exhausting the
    # retries marks the round degraded — every taken chunk is restored
    # to its session queue and the service returns [] instead of
    # raising; the strict default propagates the last error.
    max_step_retries: int = 2
    retry_backoff_s: float = 0.0
    degrade_on_step_failure: bool = False

    def __post_init__(self):
        if self.on_validation_error not in ON_VALIDATION:
            raise ValueError(
                f"on_validation_error must be one of {ON_VALIDATION}, "
                f"got {self.on_validation_error!r}"
            )
        if self.shed_policy not in SHED_POLICIES:
            raise ValueError(
                f"shed_policy must be one of {SHED_POLICIES}, "
                f"got {self.shed_policy!r}"
            )
        if self.queue_budget_events is not None and self.queue_budget_events < 1:
            raise ValueError(
                f"queue_budget_events must be >= 1, got {self.queue_budget_events}"
            )
        if self.heartbeat_timeout_s is not None and self.heartbeat_timeout_s <= 0:
            raise ValueError(
                f"heartbeat_timeout_s must be > 0, got {self.heartbeat_timeout_s}"
            )
        if self.max_step_retries < 0:
            raise ValueError(
                f"max_step_retries must be >= 0, got {self.max_step_retries}"
            )
        if self.retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {self.retry_backoff_s}"
            )
        if self.straggler_factor <= 1.0:
            raise ValueError(
                f"straggler_factor must be > 1, got {self.straggler_factor}"
            )


class SessionHealth:
    """Liveness + slowness tracking for live sessions, keyed by sid."""

    def __init__(self, config: FaultConfig, clock: Callable[[], float]):
        self.config = config
        self._monitor = (
            None
            if config.heartbeat_timeout_s is None
            else HeartbeatMonitor(
                timeout_s=config.heartbeat_timeout_s, clock=clock
            )
        )
        self._straggler = StragglerTracker(
            factor=config.straggler_factor, alpha=config.straggler_alpha
        )

    def register(self, sid: int) -> None:
        if self._monitor is not None:
            self._monitor.register(sid)

    def forget(self, sid: int) -> None:
        if self._monitor is not None and sid in self._monitor:
            self._monitor.forget(sid)
        self._straggler.forget(sid)

    def beat(self, sid: int) -> None:
        if self._monitor is not None:
            self._monitor.beat(sid)

    def expired(self) -> list[int]:
        """Live sids whose heartbeat deadline has passed (eviction set)."""
        if self._monitor is None:
            return []
        return self._monitor.failed_nodes()

    def note_latency(self, sid: int, latency_ms: float) -> None:
        self._straggler.record(sid, latency_ms)

    def stragglers(self) -> list[int]:
        """Sids whose service-latency EMA exceeds ``straggler_factor`` x
        the fleet median — persistently slow feeds, flagged not evicted."""
        return self._straggler.stragglers()
