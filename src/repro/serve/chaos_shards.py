"""Multi-shard chaos harness for the constellation layer.

Extends the single-service chaos harness (:mod:`repro.serve.chaos`,
DESIGN.md Sec. 13) to shard-level faults on a
:class:`~repro.serve.constellation.ConstellationService`: on top of the
full per-sensor taxonomy, sessions are migrated between shards
mid-stream (explicitly and via forced rebalances), and whole shards
stall — every fleet round on them fails — until the constellation's
rescue path re-migrates their sessions to the surviving shards. The two
invariants under test (DESIGN.md Sec. 15):

* **No crash, no loss**: no injected fault escapes ``feed``/``pump``,
  and a whole-shard stall moves its sessions — healthy ones included —
  rather than losing them (a degraded round restores its chunks to the
  session queues, and the queues travel with the carry export).
* **Bit-identity against dedicated pipelines**: every healthy session's
  concatenated outputs are bit-identical to a dedicated
  :class:`~repro.core.pipeline.stream.StreamingPipeline` fed the same
  chunks — a *stronger* reference than the single-service harness's
  fault-free service twin, since it crosses the fleet, service, AND
  constellation layers in one comparison.

Deterministic from ``ShardChaosConfig.seed`` exactly like the
single-service harness: fake clock, seeded schedule, seeded payloads.

    report = ShardChaosHarness(ShardChaosConfig(seed=7)).run()
    assert report.bit_identical and not report.escaped_errors
    assert report.rescues >= 1 and report.lost_sessions == 0
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from repro.core.pipeline.config import PipelineConfig
from repro.core.pipeline.stream import StreamingPipeline
from repro.serve.batcher import AdmissionConfig
from repro.serve.chaos import (
    FAULT_TAXONOMY,
    _FakeClock,
    _FlakyFleet,
    _Stream,
    compare_outputs,
    concat_outputs,
)
from repro.serve.constellation import ConstellationService
from repro.serve.faults import FaultConfig
from repro.serve.sessions import LIVE, SessionError

# Shard-level faults layered on the per-sensor taxonomy. ``migrate``
# moves a random live session (healthy ones included — the point) to
# another shard; ``rebalance`` forces a planner sweep; ``shard_stall``
# makes every fleet round on one shard fail until the rescue path
# evacuates it.
SHARD_FAULT_TAXONOMY = FAULT_TAXONOMY + ("migrate", "rebalance", "shard_stall")


@dataclasses.dataclass(frozen=True)
class ShardChaosConfig:
    """Seeded chaos schedule over a sharded constellation.

    Sensors ``0 .. n_faulty-1`` are the per-sensor fault targets; the
    rest stay healthy and form the bit-identity comparison set (healthy
    sessions still migrate and ride shard stalls — those must be
    invisible in their outputs).
    """

    n_shards: int = 2
    n_sensors: int = 6
    n_faulty: int = 2
    n_rounds: int = 48
    seed: int = 0
    faults: tuple[str, ...] = SHARD_FAULT_TAXONOMY
    chunk_events: int = 100
    burst_events: int = 1500
    round_dt_s: float = 0.02
    queue_budget_events: int = 800
    shed_policy: str = "drop_oldest"
    heartbeat_rounds: int = 4
    stall_rounds: int = 6  # per-sensor stall length (heartbeat eviction)
    shard_stall_rounds: int = 5  # whole-shard stall length (repair horizon)
    rescue_after_degraded_rounds: int = 2
    max_step_retries: int = 1
    tiers: tuple[int, ...] = (2, 4, 8, 16)
    exchange: str = "int8_ef"

    def __post_init__(self):
        if self.n_shards < 2:
            raise ValueError("shard chaos needs >= 2 shards to migrate between")
        if not 0 < self.n_faulty < self.n_sensors:
            raise ValueError(
                f"need 0 < n_faulty < n_sensors, got {self.n_faulty} of "
                f"{self.n_sensors}"
            )
        unknown = set(self.faults) - set(SHARD_FAULT_TAXONOMY)
        if unknown:
            raise ValueError(f"unknown faults {sorted(unknown)}")
        if self.stall_rounds <= self.heartbeat_rounds + 1:
            raise ValueError(
                "stall_rounds must exceed heartbeat_rounds + 1 so a stalled "
                "sensor is reliably evicted before it could resume"
            )
        if self.chunk_events > self.queue_budget_events:
            raise ValueError(
                "chunk_events must fit the queue budget or healthy feeds "
                "would shed (breaking the bit-identity comparison)"
            )
        if self.shard_stall_rounds <= self.rescue_after_degraded_rounds:
            raise ValueError(
                "shard_stall_rounds must exceed rescue_after_degraded_rounds "
                "so the rescue reliably fires before the shard heals"
            )


@dataclasses.dataclass
class ShardChaosReport:
    """Outcome of one shard-chaos run; deterministic per seed."""

    rounds: int
    fired: dict  # fault kind -> injection count (every kind >= 1)
    migrations: int  # sessions moved between shards (all causes)
    rebalances: int
    rescues: int  # whole-shard rescues performed
    lost_sessions: int  # healthy sessions not live at the end (must be 0)
    quarantines: int
    evictions: int
    degraded_rounds: int
    healthy_windows: int
    errors: list[SessionError]
    escaped_errors: list[str]  # exceptions escaping feed/pump (must be [])
    bit_identical: bool  # healthy outputs == dedicated pipeline runs
    mismatches: list[str]
    round_times_ms: list[float]
    exchange: dict  # CrossShardExchange.stats snapshot


class ShardChaosHarness:
    """Seeded shard-level fault schedule against a constellation, diffed
    healthy-session-by-healthy-session against dedicated
    :class:`StreamingPipeline` runs of the identical chunk streams."""

    def __init__(
        self,
        config: ShardChaosConfig = ShardChaosConfig(),
        pipeline: PipelineConfig = PipelineConfig(),
    ):
        self.config = config
        self.pipeline = pipeline

    # -- schedule ------------------------------------------------------

    def schedule(self) -> list[tuple[int, int, str]]:
        """Deterministic (round, faulty_sensor, kind) schedule: one
        guarantee pass spreading every kind over the run, then random
        extras from the same seed. Shard-level kinds ignore the sensor
        column. Stalled sensors and stalled shards carry busy horizons
        so overlapping stalls cannot mask each other."""
        cfg = self.config
        rng = np.random.default_rng(cfg.seed)
        kinds = list(cfg.faults)
        first, last = 3, cfg.n_rounds - 4
        busy = [0] * cfg.n_faulty
        shard_busy = [0]  # global: one shard stall at a time
        out: list[tuple[int, int, str]] = []

        def place(r: int, f: int, kind: str) -> None:
            out.append((r, f, kind))
            if kind == "stall":
                busy[f] = r + cfg.stall_rounds + 2
            elif kind == "shard_stall":
                shard_busy[0] = r + cfg.shard_stall_rounds + 2

        span = max(1, last - first)
        for i, kind in enumerate(kinds):  # guarantee pass
            r = first + (i * span) // len(kinds)
            if kind == "shard_stall":
                r = max(r, shard_busy[0])
            free = [f for f in range(cfg.n_faulty) if r >= busy[f]]
            if not free:
                r = min(busy)
                free = [f for f in range(cfg.n_faulty) if r >= busy[f]]
            place(min(r, last), free[i % len(free)], kind)
        r = first
        while True:  # extra random injections
            r += int(rng.integers(2, 6))
            if r >= last:
                break
            f = int(rng.integers(cfg.n_faulty))
            kind = str(rng.choice(kinds))
            if kind == "shard_stall" and r < shard_busy[0]:
                continue
            if r >= busy[f]:
                place(r, f, kind)
        out.sort(key=lambda e: e[0])
        return out

    # -- runs ----------------------------------------------------------

    def run(self) -> ShardChaosReport:
        cfg = self.config
        faulted = self._run_faulted()
        mismatches: list[str] = []
        for k, sensor in enumerate(sorted(faulted["healthy_chunks"])):
            got = concat_outputs(faulted["healthy_parts"][sensor])
            want = concat_outputs(
                self._run_dedicated(faulted["healthy_chunks"][sensor])
            )
            mismatches.extend(compare_outputs(got, want, f"healthy[{k}]"))
        cs = faulted["cs"]
        return ShardChaosReport(
            rounds=cfg.n_rounds,
            fired=faulted["fired"],
            migrations=cs.migrations,
            rebalances=cs.rebalances,
            rescues=cs.rescues,
            lost_sessions=faulted["lost_sessions"],
            quarantines=sum(s.service.quarantines for s in cs._shards),
            evictions=sum(s.service.evictions for s in cs._shards),
            degraded_rounds=sum(s.service.degraded_rounds for s in cs._shards),
            healthy_windows=sum(
                r.num_windows
                for parts in faulted["healthy_parts"].values()
                for r in parts
            ),
            errors=[e for s in cs._shards for e in s.service.errors],
            escaped_errors=faulted["escaped"],
            bit_identical=not mismatches,
            mismatches=mismatches,
            round_times_ms=faulted["round_times_ms"],
            exchange=cs.exchange.stats,
        )

    def _constellation(self, clock) -> ConstellationService:
        cfg = self.config

        def fake_sleep(s: float) -> None:
            clock.now += s

        return ConstellationService(
            self.pipeline,
            n_shards=cfg.n_shards,
            tiers=cfg.tiers,
            admission=AdmissionConfig(
                max_delay_s=cfg.round_dt_s,
                max_items=cfg.chunk_events * cfg.n_sensors,
            ),
            faults=FaultConfig(
                on_validation_error="quarantine",
                queue_budget_events=cfg.queue_budget_events,
                shed_policy=cfg.shed_policy,
                heartbeat_timeout_s=(cfg.heartbeat_rounds - 0.5)
                * cfg.round_dt_s,
                demote_tiers=True,
                max_step_retries=cfg.max_step_retries,
                retry_backoff_s=0.001,
                degrade_on_step_failure=True,
            ),
            clock=clock,
            sleep=fake_sleep,
            exchange=cfg.exchange,
            rescue_after_degraded_rounds=cfg.rescue_after_degraded_rounds,
        )

    def _run_dedicated(self, chunks: list) -> list:
        """One healthy sensor's chunk stream through a dedicated
        single-sensor StreamingPipeline — the bit-identity reference."""
        pipe = StreamingPipeline(self.pipeline)
        parts = [pipe.feed(*chunk) for chunk in chunks]
        parts.append(pipe.flush())
        return parts

    def _run_faulted(self) -> dict:
        cfg = self.config
        clock = _FakeClock()
        cs = self._constellation(clock)
        # Every shard's fleet gets the flaky wrapper so both per-sensor
        # step faults and whole-shard stalls inject at the same boundary.
        flaky: list[_FlakyFleet] = []
        for sh in cs._shards:
            wrapper = _FlakyFleet(sh.service._fleet)
            sh.service._fleet = wrapper
            flaky.append(wrapper)
        schedule: dict[int, list] = {}
        for r, f, kind in self.schedule():
            schedule.setdefault(r, []).append((f, kind))
        rng = np.random.default_rng(cfg.seed + 1)
        streams: dict[int, _Stream] = {}
        next_stream_seed = [0]

        def fresh_stream(sensor: int) -> _Stream:
            if sensor >= cfg.n_faulty:  # healthy: shared seed sequence
                seed = cfg.seed * 1000 + sensor
            else:  # faulty re-attaches draw private seeds
                seed = cfg.seed * 1000 + 500 + next_stream_seed[0]
                next_stream_seed[0] += 1
            return _Stream(seed)

        gids: dict[int, int] = {}
        for sensor in range(cfg.n_sensors):
            gids[sensor] = cs.attach(f"sensor-{sensor}")
            streams[sensor] = fresh_stream(sensor)
        healthy = list(range(cfg.n_faulty, cfg.n_sensors))
        healthy_parts: dict[int, list] = {s: [] for s in healthy}
        healthy_chunks: dict[int, list] = {s: [] for s in healthy}
        healthy_gids = {gids[s]: s for s in healthy}
        last_chunk: dict[int, tuple] = {}
        stalled_until = [0] * cfg.n_faulty
        stalled_shard: list[tuple[int, int] | None] = [None]  # (shard, heal_round)
        fired: dict[str, int] = {k: 0 for k in cfg.faults}
        step_exc_count = [0]
        escaped: list[str] = []
        round_times_ms: list[float] = []

        def collect(served):
            for fd in served:
                sensor = healthy_gids.get(fd.gid)
                if sensor is not None:
                    healthy_parts[sensor].append(fd.result)

        def guard(fn, *args):
            try:
                collect(fn(*args))
            except Exception as e:  # noqa: BLE001 — the no-crash invariant
                escaped.append(f"{type(e).__name__}: {e}")

        def inject(sensor: int, kind: str) -> None:
            gid = gids[sensor]
            stream = streams[sensor]
            if kind == "shard_stall":
                up = [s.index for s in cs._shards if not s.down]
                # The busiest up shard: a stall that holds no sessions
                # hostage would exercise nothing.
                target = max(up, key=lambda i: (cs._shards[i].load, -i))
                flaky[target].fail_next = 10**9  # every dispatch fails
                stalled_shard[0] = (target, rnd + cfg.shard_stall_rounds)
                fired[kind] += 1
                return
            if kind == "migrate":
                live = sorted(cs._routes)
                if live:
                    g = int(live[rng.integers(len(live))])
                    src = cs.shard_of(g)
                    up = [
                        s.index
                        for s in cs._shards
                        if not s.down and s.index != src
                    ]
                    if up:
                        guard_migrate(g, int(up[rng.integers(len(up))]))
                fired[kind] += 1
                return
            if kind == "rebalance":
                try:
                    cs.rebalance()
                except Exception as e:  # noqa: BLE001
                    escaped.append(f"rebalance: {type(e).__name__}: {e}")
                fired[kind] += 1
                return
            if kind == "stall":
                stalled_until[sensor] = rnd + cfg.stall_rounds
                fired[kind] += 1
                return
            if kind == "step_exception":
                # Alternate heal-within-retries / degraded on the
                # sensor's own shard.
                step_exc_count[0] += 1
                shard_i = cs.shard_of(gid)
                flaky[shard_i].fail_next = (
                    1 if step_exc_count[0] % 2 else cfg.max_step_retries + 1
                )
                fired[kind] += 1
                return
            if kind == "churn":
                if cs.session(gid).state == LIVE:
                    try:
                        cs.detach(gid)
                    except RuntimeError:  # degraded detach: retryable
                        fired[kind] += 1
                        return
                gids[sensor] = cs.attach(f"sensor-{sensor}-churned")
                streams[sensor] = fresh_stream(sensor)
                last_chunk.pop(sensor, None)
                fired[kind] += 1
                return
            if kind == "dropped":
                stream.next(cfg.chunk_events)
                fired[kind] += 1
                return
            if kind == "burst":
                chunk = stream.next(cfg.burst_events)
                guard(cs.feed, gid, *chunk)
                fired[kind] += 1
                return
            if kind == "duplicate":
                chunk = last_chunk.get(sensor)
                if chunk is None:
                    chunk = stream.next(cfg.chunk_events)
                    guard(cs.feed, gid, *chunk)
                guard(cs.feed, gid, *chunk)
                fired[kind] += 1
                return
            x, y, t, p = stream.next(cfg.chunk_events)
            if kind == "non_monotone":
                t = t[::-1].copy()
            elif kind == "oob_coords":
                x = x + 5000
                y = y + 5000
            elif kind == "garbage_coords":
                x = x + (np.int64(1) << 31)
            guard(cs.feed, gid, x, y, t, p)
            fired[kind] += 1

        def guard_migrate(g: int, dst: int) -> None:
            try:
                cs.migrate(g, dst)
            except Exception as e:  # noqa: BLE001
                escaped.append(f"migrate: {type(e).__name__}: {e}")

        for rnd in range(cfg.n_rounds):
            t0 = time.perf_counter()
            clock.now += cfg.round_dt_s
            # Heal a stalled shard once its repair horizon passes.
            if stalled_shard[0] is not None and rnd >= stalled_shard[0][1]:
                shard_i = stalled_shard[0][0]
                flaky[shard_i].fail_next = 0
                if cs._shards[shard_i].down:
                    cs.revive_shard(shard_i)
                stalled_shard[0] = None
            for sensor, kind in schedule.get(rnd, ()):
                inject(sensor, kind)
            for sensor in range(cfg.n_sensors):
                faulty = sensor < cfg.n_faulty
                if faulty and rnd < stalled_until[sensor]:
                    continue
                gid = gids[sensor]
                if cs.session(gid).state != LIVE:
                    if faulty:
                        gids[sensor] = cs.attach(f"sensor-{sensor}-r{rnd}")
                        streams[sensor] = fresh_stream(sensor)
                        last_chunk.pop(sensor, None)
                        gid = gids[sensor]
                    else:  # healthy session closed by a fault = isolation broken
                        escaped.append(
                            f"healthy sensor {sensor} left live state: "
                            f"{cs.session(gid).state}"
                        )
                        continue
                chunk = streams[sensor].next(cfg.chunk_events)
                if faulty:
                    last_chunk[sensor] = chunk
                else:
                    healthy_chunks[sensor].append(chunk)
                guard(cs.feed, gid, *chunk)
            guard(cs.pump, True)
            round_times_ms.append((time.perf_counter() - t0) * 1e3)

        # A stall still pending at the end: heal so the detach flush runs.
        if stalled_shard[0] is not None:
            flaky[stalled_shard[0][0]].fail_next = 0
        lost = 0
        for sensor in healthy:
            gid = gids[sensor]
            try:
                if cs.session(gid).state != LIVE:
                    lost += 1
                    continue
                healthy_parts[sensor].append(cs.detach(gid))
            except Exception as e:  # noqa: BLE001
                escaped.append(f"detach({sensor}): {type(e).__name__}: {e}")
                lost += 1
        return {
            "cs": cs,
            "healthy_parts": healthy_parts,
            "healthy_chunks": healthy_chunks,
            "fired": fired,
            "escaped": escaped,
            "lost_sessions": lost,
            "round_times_ms": round_times_ms,
        }
