"""Batched LM serving engine — a thin client of the shared admission batcher.

The dual-threshold policy itself lives in :mod:`repro.serve.batcher`
(one implementation for every admission point in the serving stack);
this module keeps only what is LM-specific: request bookkeeping, padded
prefill, and the shared-position decode loop. The engine runs static
batches: queued prompts are right-padded to a common length, prefilled
together, then decoded together with one shared position counter and
per-request stop bookkeeping.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, prefill
from repro.serve.batcher import AdmissionConfig, DualThresholdAdmitter


@dataclasses.dataclass
class Request:
    rid: int
    tokens: list[int]
    max_new_tokens: int = 16
    arrival_s: float = 0.0
    # filled by the engine:
    output: list[int] = dataclasses.field(default_factory=list)
    batch_latency_s: float = 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    max_delay_s: float = 0.020  # paper: 20 ms window
    max_batch: int = 8  # paper: 250 events; scaled to LM requests
    max_seq: int = 256
    eos_token: int = -1  # disabled by default


class DualThresholdBatcher:
    """LM-request admission: the generic admitter at unit weight.

    Kept as a named class (rather than an alias) for the historical API:
    ``submit`` stamps ``Request.arrival_s`` and ``queue`` exposes the
    pending requests, both of which the engine and its tests rely on.
    """

    def __init__(self, cfg: EngineConfig, clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self._admit: DualThresholdAdmitter[Request] = DualThresholdAdmitter(
            AdmissionConfig(max_delay_s=cfg.max_delay_s, max_items=cfg.max_batch),
            clock,
        )

    @property
    def queue(self) -> list[Request]:
        return self._admit.items

    def submit(self, req: Request) -> None:
        req.arrival_s = self.clock()
        self._admit.submit(req)

    def ready(self) -> bool:
        return self._admit.ready()

    def pop_batch(self) -> list[Request]:
        return self._admit.pop()


class ServingEngine:
    def __init__(
        self,
        params,
        cfg: ModelConfig,
        engine_cfg: EngineConfig = EngineConfig(),
        clock: Callable[[], float] = time.monotonic,
    ):
        self.params = params
        self.cfg = cfg
        self.ecfg = engine_cfg
        self.clock = clock
        self.batcher = DualThresholdBatcher(engine_cfg, clock)
        self._prefill = jax.jit(
            partial(prefill, cfg=cfg, cache_len=engine_cfg.max_seq)
        )
        self._decode = jax.jit(partial(decode_step, cfg=cfg))

    def submit(self, req: Request) -> None:
        self.batcher.submit(req)

    def step(self) -> list[Request]:
        """Serve one ready batch (or nothing). Returns completed requests."""
        if not self.batcher.ready():
            return []
        batch = self.batcher.pop_batch()
        t0 = self.clock()
        b = len(batch)
        lens = [len(r.tokens) for r in batch]
        max_len = max(lens)
        toks = np.zeros((b, max_len), np.int32)
        for i, r in enumerate(batch):
            toks[i, max_len - lens[i]:] = r.tokens  # left-pad to align ends
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        max_new = max(r.max_new_tokens for r in batch)
        cur = jnp.argmax(logits, -1)
        done = np.zeros(b, bool)
        for step in range(max_new):
            for i, r in enumerate(batch):
                if not done[i] and step < r.max_new_tokens:
                    tok = int(cur[i])
                    r.output.append(tok)
                    if tok == self.ecfg.eos_token:
                        done[i] = True
                if len(r.output) >= r.max_new_tokens:
                    done[i] = True
            if done.all():
                break
            logits, cache = self._decode(
                self.params, {"tokens": cur[:, None]}, cache,
                jnp.int32(max_len + step),
            )
            cur = jnp.argmax(logits, -1)
        dt = self.clock() - t0
        for r in batch:
            r.batch_latency_s = dt
        return batch

    def run_until_drained(self, budget_s: float = 60.0) -> list[Request]:
        out: list[Request] = []
        t0 = self.clock()
        while self.batcher.queue and (self.clock() - t0) < budget_s:
            out.extend(self.step())
            if not self.batcher.ready() and self.batcher.queue:
                # force the time threshold for the tail batch
                time.sleep(min(self.ecfg.max_delay_s, 0.02))
        return out
