"""xLSTM blocks (Beck et al. 2024): mLSTM and sLSTM.

mLSTM: matrix-memory LSTM with exponential gating. Training/prefill uses
the parallel (attention-like) stabilized form; decode carries the
(C, n, m) recurrent state — C is a (dk x dv) matrix memory per head.

sLSTM: scalar-memory LSTM with exponential gating and head-wise recurrent
mixing; inherently sequential, evaluated with ``lax.scan`` over time.

Block wiring follows the xLSTM paper: mLSTM blocks use pre-up-projection
(factor 2) with a short causal conv feeding q/k; sLSTM blocks use
post-up-projection (factor 4/3) like a transformer FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init

MLSTM_PROJ_FACTOR = 2.0
SLSTM_PROJ_FACTOR = 4.0 / 3.0
CONV_WIDTH = 4


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, d_model: int, n_heads: int) -> Params:
    d_inner = int(MLSTM_PROJ_FACTOR * d_model)
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], d_model, d_inner),
        "w_up_gate": dense_init(ks[1], d_model, d_inner),
        "conv_w": 0.1 * jax.random.normal(ks[2], (CONV_WIDTH, d_inner)),
        "conv_b": jnp.zeros((d_inner,)),
        "wq": dense_init(ks[3], d_inner, d_inner),
        "wk": dense_init(ks[4], d_inner, d_inner),
        "wv": dense_init(ks[5], d_inner, d_inner),
        "w_igate": dense_init(ks[6], d_inner, n_heads),
        "w_fgate": dense_init(ks[7], d_inner, n_heads),
        "fgate_bias": 3.0 * jnp.ones((n_heads,)),  # init toward remembering
        "igate_bias": -1.0 * jnp.ones((n_heads,)),
        "skip_scale": jnp.ones((d_inner,)),
        "w_down": dense_init(ks[8], d_inner, d_model),
    }


def _mlstm_conv(params: Params, u: jax.Array, state: jax.Array | None):
    w = params["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], w - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)
    out = sum(
        full[:, i : i + u.shape[1]] * params["conv_w"][i].astype(u.dtype)
        for i in range(w)
    ) + params["conv_b"].astype(u.dtype)
    return jax.nn.silu(out), full[:, -(w - 1):]


def mlstm_parallel(
    q: jax.Array,  # (B, S, H, dh)
    k: jax.Array,
    v: jax.Array,
    log_f: jax.Array,  # (B, S, H) log sigmoid forget gates
    log_i: jax.Array,  # (B, S, H) log input gates (pre-exp)
    chunk: int = 256,
) -> jax.Array:
    """Stabilized parallel mLSTM (chunked over queries to bound memory).

    D[t,s] = exp(F[t] - F[s] + log_i[s] - m[t]), F = cumsum(log_f);
    h_t = (sum_s D[t,s] (q_t k_s / sqrt(d)) v_s) / max(|l_t|, exp(-m_t)).
    """
    b, s, h, dh = q.shape
    scale = dh ** -0.5
    f_cum = jnp.cumsum(log_f, axis=1)  # (B, S, H)

    chunk = min(chunk, s)
    s_pad = -(-s // chunk) * chunk
    if s_pad != s:
        padf = ((0, 0), (0, s_pad - s), (0, 0))
        q = jnp.pad(q, padf + ((0, 0),))
        f_cum_q = jnp.pad(f_cum, padf)
    else:
        f_cum_q = f_cum
    nq = s_pad // chunk
    qs = q.reshape(b, nq, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    fq = f_cum_q.reshape(b, nq, chunk, h).transpose(1, 0, 2, 3)
    pos_q = jnp.arange(s_pad).reshape(nq, chunk)
    pos_k = jnp.arange(s)

    def q_step(_, inp):
        qc, fqc, pq = inp  # (B, c, H, dh), (B, c, H), (c,)
        # scores over ALL keys (bounded: (B, H, c, S)).
        sc = jnp.einsum("bqhd,bkhd->bhqk", qc, k, preferred_element_type=jnp.float32) * scale
        logd = (
            fqc.transpose(0, 2, 1)[..., None]  # (B,H,c,1)
            - f_cum.transpose(0, 2, 1)[:, :, None, :]  # (B,H,1,S)
            + log_i.transpose(0, 2, 1)[:, :, None, :]
        )
        causal = pos_k[None, :] <= pq[:, None]  # (c, S)
        logd = jnp.where(causal[None, None], logd, -jnp.inf)
        m = jnp.max(logd, axis=-1, keepdims=True)  # (B,H,c,1)
        m = jnp.maximum(m, -1e30)
        d = jnp.exp(logd - m)
        wts = sc * d
        l = jnp.abs(wts.sum(-1, keepdims=True))
        denom = jnp.maximum(l, jnp.exp(-m))
        out = jnp.einsum(
            "bhqk,bkhd->bqhd", (wts / denom).astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
        return None, out

    _, outs = jax.lax.scan(q_step, None, (qs, fq, pos_q))  # (nq,B,c,H,dh)
    out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s_pad, h, dh)
    return out[:, :s].astype(v.dtype)


def mlstm_apply(
    params: Params,
    x: jax.Array,  # (B, S, d)
    *,
    n_heads: int,
    state: dict[str, jax.Array] | None = None,
    return_state: bool = False,
):
    b, s, d = x.shape
    dtype = x.dtype
    u = x @ params["w_up"].astype(dtype)  # (B, S, di)
    z = x @ params["w_up_gate"].astype(dtype)
    conv_state = None if state is None else state["conv"]
    c, new_conv = _mlstm_conv(params, u, conv_state)
    di = u.shape[-1]
    dh = di // n_heads
    q = (c @ params["wq"].astype(dtype)).reshape(b, s, n_heads, dh)
    k = (c @ params["wk"].astype(dtype)).reshape(b, s, n_heads, dh)
    v = (u @ params["wv"].astype(dtype)).reshape(b, s, n_heads, dh)
    log_f = jax.nn.log_sigmoid(
        (c @ params["w_fgate"].astype(dtype)).astype(jnp.float32)
        + params["fgate_bias"]
    )
    log_i = (
        (c @ params["w_igate"].astype(dtype)).astype(jnp.float32)
        + params["igate_bias"]
    )
    h = mlstm_parallel(q, k, v, log_f, log_i)  # (B, S, H, dh)
    h = h.reshape(b, s, di)
    h = h + params["skip_scale"].astype(dtype) * c  # learnable skip
    y = (h * jax.nn.silu(z)) @ params["w_down"].astype(dtype)
    if not return_state:
        return y
    # Build the recurrent state from the full sequence (for prefill).
    # C_S = sum_s exp(F_S - F_s + i_s - m_S) v_s k_s^T  (stabilized by m_S).
    f_cum = jnp.cumsum(log_f, axis=1)
    rel = f_cum[:, -1:, :] - f_cum + log_i  # (B, S, H)
    m_last = jnp.max(rel, axis=1)  # (B, H)
    w_s = jnp.exp(rel - m_last[:, None, :])  # (B, S, H)
    c_mat = jnp.einsum("bshk,bshv,bsh->bhkv", k.astype(jnp.float32), v.astype(jnp.float32), w_s)
    n_vec = jnp.einsum("bshk,bsh->bhk", k.astype(jnp.float32), w_s)
    new_state = {
        "c": c_mat, "n": n_vec, "m": m_last, "conv": new_conv.astype(jnp.float32),
    }
    return y, new_state


def mlstm_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    state: dict[str, jax.Array],
    *,
    n_heads: int,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    b = x.shape[0]
    dtype = x.dtype
    u = x @ params["w_up"].astype(dtype)
    z = x @ params["w_up_gate"].astype(dtype)
    c, new_conv = _mlstm_conv(params, u, state["conv"])
    di = u.shape[-1]
    dh = di // n_heads
    q = (c @ params["wq"].astype(dtype)).reshape(b, n_heads, dh)
    k = (c @ params["wk"].astype(dtype)).reshape(b, n_heads, dh)
    v = (u @ params["wv"].astype(dtype)).reshape(b, n_heads, dh)
    log_f = jax.nn.log_sigmoid(
        (c[:, 0] @ params["w_fgate"].astype(dtype)).astype(jnp.float32)
        + params["fgate_bias"]
    )  # (B, H)
    log_i = (
        (c[:, 0] @ params["w_igate"].astype(dtype)).astype(jnp.float32)
        + params["igate_bias"]
    )
    m_new = jnp.maximum(log_f + state["m"], log_i)
    f_s = jnp.exp(log_f + state["m"] - m_new)
    i_s = jnp.exp(log_i - m_new)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    c_mat = f_s[..., None, None] * state["c"] + i_s[..., None, None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n_vec = f_s[..., None] * state["n"] + i_s[..., None] * kf
    qf = q.astype(jnp.float32) * (dh ** -0.5)
    num = jnp.einsum("bhk,bhkv->bhv", qf, c_mat)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n_vec)), jnp.exp(-m_new)
    )
    h = (num / den[..., None]).reshape(b, 1, di).astype(dtype)
    h = h + params["skip_scale"].astype(dtype) * c
    y = (h * jax.nn.silu(z)) @ params["w_down"].astype(dtype)
    return y, {"c": c_mat, "n": n_vec, "m": m_new, "conv": new_conv.astype(jnp.float32)}


def init_mlstm_state(b: int, d_model: int, n_heads: int):
    di = int(MLSTM_PROJ_FACTOR * d_model)
    dh = di // n_heads
    return {
        "c": jnp.zeros((b, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((b, n_heads, dh), jnp.float32),
        "m": jnp.full((b, n_heads), 0.0, jnp.float32),
        "conv": jnp.zeros((b, CONV_WIDTH - 1, di), jnp.float32),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, d_model: int, n_heads: int) -> Params:
    dh = d_model // n_heads
    ks = jax.random.split(key, 7)
    d_up = int(SLSTM_PROJ_FACTOR * d_model)
    return {
        "w_gates": dense_init(ks[0], d_model, 4 * d_model),  # i, f, z, o
        "r_gates": 0.5 * jax.vmap(lambda k: dense_init(k, dh, 4 * dh))(
            jax.random.split(ks[1], n_heads)
        ),  # head-wise recurrent mixing (H, dh, 4*dh)
        "gate_bias": jnp.concatenate(
            [jnp.zeros((d_model,)), 3.0 * jnp.ones((d_model,)), jnp.zeros((2 * d_model,))]
        ),
        "w_up_gate": dense_init(ks[2], d_model, d_up),
        "w_up": dense_init(ks[3], d_model, d_up),
        "w_down": dense_init(ks[4], d_up, d_model),
    }


def _slstm_cell(params: Params, x_t: jax.Array, state, *, n_heads: int):
    """One sLSTM time step. x_t: (B, d). state: dict of (B, d)/(B, H...)"""
    b, d = x_t.shape
    dh = d // n_heads
    dtype = x_t.dtype
    h_prev = state["h"].astype(dtype)  # (B, d)
    # Recurrent head-wise contribution.
    hh = h_prev.reshape(b, n_heads, dh)
    rec = jnp.einsum("bhd,hde->bhe", hh, params["r_gates"].astype(dtype))
    # Reorder head-blocked (i,f,z,o) chunks to match w_gates' (i|f|z|o) layout.
    rec = rec.reshape(b, n_heads, 4, dh).transpose(0, 2, 1, 3).reshape(b, 4 * d)
    gates = (
        x_t @ params["w_gates"].astype(dtype)
        + rec
        + params["gate_bias"].astype(dtype)
    ).astype(jnp.float32)
    i_raw, f_raw, z_raw, o_raw = jnp.split(gates, 4, axis=-1)
    log_f = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(log_f + state["m"], i_raw)
    i_g = jnp.exp(i_raw - m_new)
    f_g = jnp.exp(log_f + state["m"] - m_new)
    c_new = f_g * state["c"] + i_g * jnp.tanh(z_raw)
    n_new = f_g * state["n"] + i_g
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1.0)
    return {"h": h_new, "c": c_new, "n": n_new, "m": m_new}


def slstm_apply(
    params: Params,
    x: jax.Array,  # (B, S, d)
    *,
    n_heads: int,
    state: dict[str, jax.Array] | None = None,
    return_state: bool = False,
):
    b, s, d = x.shape
    dtype = x.dtype
    st = init_slstm_state(b, d) if state is None else state

    def step(carry, x_t):
        new = _slstm_cell(params, x_t, carry, n_heads=n_heads)
        return new, new["h"]

    st, hs = jax.lax.scan(step, st, x.transpose(1, 0, 2))  # hs: (S, B, d)
    h = hs.transpose(1, 0, 2).astype(dtype)
    up = jax.nn.gelu(h @ params["w_up_gate"].astype(dtype)) * (
        h @ params["w_up"].astype(dtype)
    )
    y = up @ params["w_down"].astype(dtype)
    if return_state:
        return y, st
    return y


def slstm_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    state: dict[str, jax.Array],
    *,
    n_heads: int,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    new = _slstm_cell(params, x[:, 0], state, n_heads=n_heads)
    h = new["h"][:, None].astype(x.dtype)
    up = jax.nn.gelu(h @ params["w_up_gate"].astype(x.dtype)) * (
        h @ params["w_up"].astype(x.dtype)
    )
    y = up @ params["w_down"].astype(x.dtype)
    return y, new


def init_slstm_state(b: int, d_model: int):
    z = jnp.zeros((b, d_model), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}
