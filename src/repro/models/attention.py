"""GQA attention with chunked online-softmax (flash-style) computation.

XLA does not rewrite softmax(QK^T)V into a streaming kernel on its own; at
32k context a materialized score tensor is petabytes. ``flash_attention``
is the pure-JAX flash algorithm: an outer scan over query chunks and an
inner scan over KV chunks carrying (m, l, acc) online-softmax state. Peak
live memory per step is (B, KV, G, q_chunk, kv_chunk) — constants, not
O(S^2).

Supports: causal masking via absolute positions, sliding-window (local)
attention, GQA grouping (KV heads x group), dk != dv (for MLA), and cache
validity masks (position < 0 = empty slot).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.common import Params, apply_mrope, apply_rope, dense_init

NEG_INF = -1e30

# Default flash chunk sizes. The inner-scan (m, l, acc) carries cross HBM
# once per KV step, so accumulator traffic scales with S/kv_chunk; larger
# chunks trade VMEM-resident score-tile size for fewer carry round trips
# (EXPERIMENTS.md §Perf HC4). Overridable per dry-run variant.
Q_CHUNK = 512
KV_CHUNK = 1024


def flash_attention(
    q: jax.Array,  # (B, Sq, KV, G, dk)
    k: jax.Array,  # (B, Skv, KV, dk)
    v: jax.Array,  # (B, Skv, KV, dv)
    q_positions: jax.Array,  # (Sq,) int32 absolute positions
    kv_positions: jax.Array,  # (Skv,) int32; -1 marks invalid slots
    *,
    causal: bool = True,
    window: int | None = None,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
) -> jax.Array:
    q_chunk = Q_CHUNK if q_chunk is None else q_chunk
    kv_chunk = KV_CHUNK if kv_chunk is None else kv_chunk
    b, sq, kvh, g, dk = q.shape
    skv, dv = k.shape[1], v.shape[-1]
    scale = dk ** -0.5

    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    # Pad sequence axes to chunk multiples.
    sq_p = -(-sq // q_chunk) * q_chunk
    skv_p = -(-skv // kv_chunk) * kv_chunk
    if sq_p != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_p - sq), (0, 0), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, sq_p - sq), constant_values=0)
    if skv_p != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_p - skv), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, skv_p - skv), constant_values=-1)

    nq, nkv = sq_p // q_chunk, skv_p // kv_chunk
    # (nq, B, qc, KV, G, dk) so scan slices are contiguous.
    qs = q.reshape(b, nq, q_chunk, kvh, g, dk).transpose(1, 0, 2, 3, 4, 5)
    qpos = q_positions.reshape(nq, q_chunk)

    def q_step(_, q_in):
        qc, qp = q_in  # (B, qc, KV, G, dk), (qc,)

        def kv_step(carry, j):
            m, l, acc = carry
            ks = jax.lax.dynamic_slice_in_dim(k, j * kv_chunk, kv_chunk, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, j * kv_chunk, kv_chunk, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(kv_positions, j * kv_chunk, kv_chunk)
            # scores: (B, KV, G, qc, kc)
            s = jnp.einsum(
                "bqkgd,btkd->bkgqt", qc, ks, preferred_element_type=jnp.float32
            ) * scale
            mask = kp[None, :] >= 0  # valid slots
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window is not None:
                mask &= kp[None, :] > qp[:, None] - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            p = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + p.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqt,btkd->bkgqd", p.astype(vs.dtype), vs,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((b, kvh, g, q_chunk), NEG_INF, jnp.float32),
            jnp.zeros((b, kvh, g, q_chunk), jnp.float32),
            jnp.zeros((b, kvh, g, q_chunk, dv), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(nkv))
        out = acc / jnp.maximum(l, 1e-30)[..., None]  # (B, KV, G, qc, dv)
        return None, out.transpose(0, 3, 1, 2, 4)  # (B, qc, KV, G, dv)

    _, outs = jax.lax.scan(q_step, None, (qs, qpos))  # (nq, B, qc, KV, G, dv)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, sq_p, kvh, g, dv)
    return out[:, :sq].astype(q.dtype)


# When True, decode QK/PV dots run in the cache dtype and upcast AFTER the
# dot. ``preferred_element_type=f32`` on a bf16 cache makes XLA hoist an
# f32 COPY of the whole cache into the decode loop carry (measured ~900
# GB/step on deepseek-67b — EXPERIMENTS.md §Perf HC1). On TPU the MXU
# accumulates bf16 dots in f32 internally either way.
CACHE_DTYPE_DOTS = False


def decode_attention(
    q: jax.Array,  # (B, 1, KV, G, dk)
    k: jax.Array,  # (B, Skv, KV, dk)
    v: jax.Array,  # (B, Skv, KV, dv)
    position: jax.Array,  # scalar int32: absolute position of the new token
    kv_positions: jax.Array,  # (Skv,)
    *,
    window: int | None = None,
) -> jax.Array:
    """Single-token attention over a cache — no chunking needed (Sq = 1)."""
    dk = q.shape[-1]
    if CACHE_DTYPE_DOTS:
        s = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(k.dtype), k)
        s = s.astype(jnp.float32) * (dk ** -0.5)
    else:
        s = jnp.einsum(
            "bqkgd,btkd->bkgqt", q, k, preferred_element_type=jnp.float32
        ) * (dk ** -0.5)
    mask = (kv_positions >= 0) & (kv_positions <= position)
    if window is not None:
        mask &= kv_positions > position - window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    if CACHE_DTYPE_DOTS:
        out = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    else:
        out = jnp.einsum(
            "bkgqt,btkd->bqkgd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention layer (init/apply for train, prefill, decode).
# ---------------------------------------------------------------------------

def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_heads * head_dim),
        "wk": dense_init(k2, d_model, n_kv_heads * head_dim),
        "wv": dense_init(k3, d_model, n_kv_heads * head_dim),
        "wo": dense_init(k4, n_heads * head_dim, d_model),
    }


def _project_qkv(params: Params, x: jax.Array, n_heads: int, n_kv_heads: int, head_dim: int):
    b, s, _ = x.shape
    dtype = x.dtype
    q = (x @ params["wq"].astype(dtype)).reshape(b, s, n_heads, head_dim)
    k = (x @ params["wk"].astype(dtype)).reshape(b, s, n_kv_heads, head_dim)
    v = (x @ params["wv"].astype(dtype)).reshape(b, s, n_kv_heads, head_dim)
    return q, k, v


def _apply_positional(q, k, positions, cfg_pos: dict[str, Any]):
    kind = cfg_pos.get("kind", "rope")
    if kind == "rope":
        theta = cfg_pos.get("theta", 10000.0)
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    elif kind == "mrope":
        q = apply_mrope(q, cfg_pos["mrope_positions"], cfg_pos["sections"], cfg_pos.get("theta", 10000.0))
        k = apply_mrope(k, cfg_pos["mrope_positions"], cfg_pos["sections"], cfg_pos.get("theta", 10000.0))
    elif kind == "none":
        pass
    else:
        raise ValueError(kind)
    return q, k


def attention_apply(
    params: Params,
    x: jax.Array,  # (B, S, d)
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    positions: jax.Array,  # (B, S) absolute
    pos_cfg: dict[str, Any],
    window: int | None = None,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
) -> jax.Array:
    """Full causal (optionally banded) attention for train/prefill."""
    b, s, _ = x.shape
    g = n_heads // n_kv_heads
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    q, k = _apply_positional(q, k, positions, pos_cfg)
    qg = q.reshape(b, s, n_kv_heads, g, head_dim)
    out = flash_attention(
        qg, k, v,
        q_positions=positions[0],
        kv_positions=positions[0],
        causal=True,
        window=window,
        q_chunk=q_chunk,
        kv_chunk=kv_chunk,
    )
    out = out.reshape(b, s, n_heads * head_dim)
    return out @ params["wo"].astype(x.dtype)


def attention_prefill(
    params: Params,
    x: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    positions: jax.Array,
    pos_cfg: dict[str, Any],
    window: int | None = None,
    cache_len: int | None = None,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Forward + build the decode cache.

    For full attention the cache holds all S (padded to cache_len) keys;
    for local attention only the trailing ``window`` ring buffer.
    """
    b, s, _ = x.shape
    g = n_heads // n_kv_heads
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    q, k = _apply_positional(q, k, positions, pos_cfg)
    qg = q.reshape(b, s, n_kv_heads, g, head_dim)
    out = flash_attention(
        qg, k, v,
        q_positions=positions[0],
        kv_positions=positions[0],
        causal=True,
        window=window,
        q_chunk=q_chunk,
        kv_chunk=kv_chunk,
    )
    out = out.reshape(b, s, n_heads * head_dim) @ params["wo"].astype(x.dtype)

    if window is None:
        clen = cache_len if cache_len is not None else s
        pad = clen - s
        ck = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cv = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cpos = jnp.pad(positions[0], (0, pad), constant_values=-1)
        cache = {"k": ck, "v": cv, "pos": cpos}
    else:
        w = window
        # Ring buffer holding the last `w` tokens at slot = pos % w.
        take = min(s, w)
        k_last = k[:, s - take:]
        v_last = v[:, s - take:]
        p_last = positions[0, s - take:]
        slots = p_last % w
        ck = jnp.zeros((b, w, n_kv_heads, head_dim), k.dtype).at[:, slots].set(k_last)
        cv = jnp.zeros((b, w, n_kv_heads, head_dim), v.dtype).at[:, slots].set(v_last)
        cpos = jnp.full((w,), -1, jnp.int32).at[slots].set(p_last)
        cache = {"k": ck, "v": cv, "pos": cpos}
    return out, cache


def attention_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    cache: dict[str, jax.Array],
    position: jax.Array,  # scalar int32
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    pos_cfg: dict[str, Any],
    window: int | None = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    b = x.shape[0]
    g = n_heads // n_kv_heads
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos_b = jnp.broadcast_to(position[None], (b, 1)).astype(jnp.int32)
    q, k = _apply_positional(q, k, pos_b, pos_cfg)
    slot = position % cache["k"].shape[1] if window is not None else position
    ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], position[None].astype(jnp.int32), slot, axis=0
    )
    qg = q.reshape(b, 1, n_kv_heads, g, head_dim)
    out = decode_attention(qg, ck, cv, position, cpos, window=window)
    out = out.reshape(b, 1, n_heads * head_dim) @ params["wo"].astype(x.dtype)
    return out, {"k": ck, "v": cv, "pos": cpos}


def init_attn_cache(
    b: int, cache_len: int, n_kv_heads: int, head_dim: int, dtype,
    window: int | None = None, page: int = 0,
) -> dict[str, jax.Array]:
    clen = min(cache_len, window) if window is not None else cache_len
    out = {
        "k": jnp.zeros((b, clen, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((b, clen, n_kv_heads, head_dim), dtype),
        "pos": jnp.full((clen,), -1, jnp.int32),
    }
    if page:
        out["k_page"] = jnp.zeros((b, page, n_kv_heads, head_dim), dtype)
        out["v_page"] = jnp.zeros((b, page, n_kv_heads, head_dim), dtype)
        out["page_pos"] = jnp.full((page,), -1, jnp.int32)
    return out


# ---------------------------------------------------------------------------
# Paged decode: hot-page writes + two-source online-softmax merge.
#
# With the main cache sequence-sharded (context parallelism), a one-token
# dynamic update lowers under SPMD to a masked select that rewrites the
# whole local cache shard every step (~83 GB/step on deepseek-67b,
# EXPERIMENTS.md §Perf HC1). Instead, new tokens land in a small
# batch-sharded ring page (local, single-token write); attention runs
# over frozen-cache and page separately and merges the softmax partials;
# the page is flushed into the main cache every `page` steps, amortizing
# the select-rewrite by 1/page.
# ---------------------------------------------------------------------------

def decode_attention_partial(
    q: jax.Array,  # (B, 1, KV, G, dk)
    k: jax.Array,  # (B, Skv, KV, dk)
    v: jax.Array,  # (B, Skv, KV, dv)
    position: jax.Array,
    kv_positions: jax.Array,
    *,
    window: int | None = None,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Unnormalized single-token attention: returns (acc, m, l) with
    out = acc / l after cross-source merging."""
    dk = q.shape[-1]
    if CACHE_DTYPE_DOTS:
        s = jnp.einsum("bqkgd,btkd->bkgqt", q.astype(k.dtype), k)
        s = s.astype(jnp.float32) * (dk ** -0.5)
    else:
        s = jnp.einsum(
            "bqkgd,btkd->bkgqt", q, k, preferred_element_type=jnp.float32
        ) * (dk ** -0.5)
    mask = (kv_positions >= 0) & (kv_positions <= position)
    if window is not None:
        mask &= kv_positions > position - window
    s = jnp.where(mask[None, None, None, None, :], s, NEG_INF)
    m = s.max(-1)  # (B, KV, G, 1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(-1)
    if CACHE_DTYPE_DOTS:
        acc = jnp.einsum("bkgqt,btkd->bkgqd", p.astype(v.dtype), v).astype(jnp.float32)
    else:
        acc = jnp.einsum(
            "bkgqt,btkd->bkgqd", p.astype(v.dtype), v,
            preferred_element_type=jnp.float32,
        )
    return acc, m, l


def merge_attention_partials(
    parts: list[tuple[jax.Array, jax.Array, jax.Array]]
) -> jax.Array:
    """Combine (acc, m, l) online-softmax partials from disjoint KV sets."""
    m_star = parts[0][1]
    for _, m, _ in parts[1:]:
        m_star = jnp.maximum(m_star, m)
    acc_tot = 0.0
    l_tot = 0.0
    for acc, m, l in parts:
        scale = jnp.exp(m - m_star)
        acc_tot = acc_tot + acc * scale[..., None]
        l_tot = l_tot + l * scale
    return acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]


def attention_decode_paged(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    cache: dict[str, jax.Array],
    position: jax.Array,
    *,
    n_heads: int,
    n_kv_heads: int,
    head_dim: int,
    pos_cfg: dict[str, Any],
    window: int | None = None,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    b = x.shape[0]
    g = n_heads // n_kv_heads
    page = cache["k_page"].shape[1]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos_b = jnp.broadcast_to(position[None], (b, 1)).astype(jnp.int32)
    q, k = _apply_positional(q, k, pos_b, pos_cfg)
    slot = position % page
    kp = jax.lax.dynamic_update_slice_in_dim(cache["k_page"], k, slot, axis=1)
    vp = jax.lax.dynamic_update_slice_in_dim(cache["v_page"], v, slot, axis=1)
    ppos = jax.lax.dynamic_update_slice_in_dim(
        cache["page_pos"], position[None].astype(jnp.int32), slot, axis=0
    )
    qg = q.reshape(b, 1, n_kv_heads, g, head_dim)
    parts = [
        decode_attention_partial(qg, cache["k"], cache["v"], position,
                                 cache["pos"], window=window),
        decode_attention_partial(qg, kp, vp, position, ppos, window=window),
    ]
    out = merge_attention_partials(parts)  # (B, KV, G, 1, dv)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, 1, n_heads * head_dim)
    out = out.astype(x.dtype) @ params["wo"].astype(x.dtype)
    new_cache = dict(cache, k_page=kp, v_page=vp, page_pos=ppos)
    return out, new_cache


def flush_page(cache: dict[str, jax.Array]) -> dict[str, jax.Array]:
    """Merge the hot page into the main cache (run every `page` steps).

    This is the amortized select-rewrite: full-shard cost once per page
    of tokens instead of every token."""
    if "k_page" not in cache:
        return cache
    page = cache["k_page"].shape[1]
    ppos = cache["page_pos"]
    valid = ppos >= 0
    # Scatter page entries into the main cache at their absolute positions.
    idx = jnp.where(valid, ppos, 0)
    k = cache["k"].at[:, idx].set(
        jnp.where(valid[None, :, None, None], cache["k_page"], cache["k"][:, idx])
    )
    v = cache["v"].at[:, idx].set(
        jnp.where(valid[None, :, None, None], cache["v_page"], cache["v"][:, idx])
    )
    pos = cache["pos"].at[idx].set(jnp.where(valid, ppos, cache["pos"][idx]))
    return dict(
        cache, k=k, v=v, pos=pos,
        k_page=jnp.zeros_like(cache["k_page"]),
        v_page=jnp.zeros_like(cache["v_page"]),
        page_pos=jnp.full_like(cache["page_pos"], -1),
    )
