"""Decoder-only model assembly for all assigned architectures.

Layers are grouped into *cycles* (one repetition of ``block_pattern``) and
scanned with ``jax.lax.scan`` over stacked cycle parameters — HLO size and
compile time stay O(pattern), not O(n_layers), which matters for the
95-layer deepseek-67b dry-run. Leftover layers (n_layers % pattern) run
unrolled ("rem").

Three entry points, matching the shape kinds:
  forward_train  — full causal forward, logits + MoE aux loss
  prefill        — forward + decode-cache construction
  decode_step    — one token against the cache/recurrent state

Inputs are a dict: {"tokens": (B, S) int32} or, for stubbed-frontend
archs (audio/vlm), {"embeds": (B, S, d)}; VLM adds "mrope_positions"
(3, B, S). Decode takes (inputs, cache, position).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import hint
from repro.models import attention as A
from repro.models import mla as MLA
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import xlstm as XL
from repro.models.common import (
    Params,
    dense_init,
    ffn_apply,
    ffn_init,
    rmsnorm,
    rmsnorm_init,
    sinusoidal_positions,
    truncated_normal_init,
)

BATCH_AXES = ("pod", "data")

# When > 0, full-attention layer caches get a hot ring page of this many
# slots and decode uses the paged path (attention.attention_decode_paged).
# Set by launch.dryrun variants; see EXPERIMENTS.md §Perf HC1.
PAGED_DECODE = 0


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _split_layers(cfg: ModelConfig) -> tuple[int, tuple[str, ...]]:
    """(n_cycles, remainder_types)."""
    plen = len(cfg.block_pattern)
    return cfg.n_layers // plen, cfg.layer_types[(cfg.n_layers // plen) * plen:]


# ---------------------------------------------------------------------------
# Layer init / apply (single layer; block type static).
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, bt: str) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p: Params = {"norm1": rmsnorm_init(d)}
    if bt in ("attn", "local"):
        if cfg.use_mla:
            p["inner"] = MLA.mla_init(
                k1, d, cfg.n_heads, cfg.q_lora_rank, cfg.kv_lora_rank,
                cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim,
            )
        else:
            p["inner"] = A.attn_init(
                k1, d, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
            )
    elif bt == "rglru":
        p["inner"] = RG.rglru_init(k1, d, cfg.lru_width or d, cfg.conv_width)
    elif bt == "mlstm":
        p["inner"] = XL.mlstm_init(k1, d, cfg.n_heads)
    elif bt == "slstm":
        p["inner"] = XL.slstm_init(k1, d, cfg.n_heads)
    else:
        raise ValueError(bt)
    if bt in ("attn", "local", "rglru") and cfg.d_ff:
        p["norm2"] = rmsnorm_init(d)
        if cfg.n_experts:
            p["moe"] = MOE.moe_init(k2, d, cfg.d_ff, cfg.n_experts)
        else:
            p["ffn"] = ffn_init(k2, d, cfg.d_ff)
    return p


def _mla_dims(cfg: ModelConfig) -> dict[str, int]:
    return dict(
        n_heads=cfg.n_heads,
        qk_nope_dim=cfg.qk_nope_dim,
        qk_rope_dim=cfg.qk_rope_dim,
        v_head_dim=cfg.v_head_dim,
        kv_lora_rank=cfg.kv_lora_rank,
    )


def _pos_cfg(cfg: ModelConfig, mrope_positions=None) -> dict[str, Any]:
    if cfg.pos_kind == "mrope":
        return {
            "kind": "mrope",
            "theta": cfg.rope_theta,
            "sections": cfg.mrope_sections,
            "mrope_positions": mrope_positions,
        }
    if cfg.pos_kind == "rope":
        return {"kind": "rope", "theta": cfg.rope_theta}
    return {"kind": "none"}


def _ffn_part(lp: Params, x: jax.Array, cfg: ModelConfig):
    aux = jnp.float32(0.0)
    if "moe" in lp:
        h = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        out = MOE.moe_apply(
            lp["moe"], h,
            n_experts=cfg.n_experts, top_k=cfg.top_k,
            capacity_factor=cfg.capacity_factor, act=cfg.act,
        )
        x = x + out.y
        aux = out.aux_loss
    elif "ffn" in lp:
        h = rmsnorm(x, lp["norm2"], cfg.norm_eps)
        x = x + ffn_apply(lp["ffn"], h, cfg.act)
    return x, aux


def apply_layer_train(
    lp: Params, x: jax.Array, *, cfg: ModelConfig, bt: str,
    positions: jax.Array, pos_cfg: dict[str, Any],
) -> tuple[jax.Array, jax.Array]:
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if bt in ("attn", "local"):
        window = cfg.local_window if bt == "local" else None
        if cfg.use_mla:
            y = MLA.mla_apply(
                lp["inner"], h, dims=_mla_dims(cfg), positions=positions,
                theta=cfg.rope_theta,
            )
        else:
            y = A.attention_apply(
                lp["inner"], h,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, positions=positions,
                pos_cfg=pos_cfg, window=window,
            )
    elif bt == "rglru":
        y = RG.rglru_apply(lp["inner"], h)
    elif bt == "mlstm":
        y = XL.mlstm_apply(lp["inner"], h, n_heads=cfg.n_heads)
    elif bt == "slstm":
        y = XL.slstm_apply(lp["inner"], h, n_heads=cfg.n_heads)
    else:
        raise ValueError(bt)
    x = x + y
    x, aux = _ffn_part(lp, x, cfg)
    return hint(x, BATCH_AXES, None, None), aux


def apply_layer_prefill(
    lp: Params, x: jax.Array, *, cfg: ModelConfig, bt: str,
    positions: jax.Array, pos_cfg: dict[str, Any], cache_len: int,
) -> tuple[jax.Array, jax.Array, Any]:
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if bt in ("attn", "local"):
        window = cfg.local_window if bt == "local" else None
        if cfg.use_mla:
            y, cache = MLA.mla_prefill(
                lp["inner"], h, dims=_mla_dims(cfg), positions=positions,
                theta=cfg.rope_theta, cache_len=cache_len,
            )
        else:
            y, cache = A.attention_prefill(
                lp["inner"], h,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, positions=positions,
                pos_cfg=pos_cfg, window=window, cache_len=cache_len,
            )
    elif bt == "rglru":
        y, cache = RG.rglru_apply(lp["inner"], h, return_state=True)
    elif bt == "mlstm":
        y, cache = XL.mlstm_apply(lp["inner"], h, n_heads=cfg.n_heads, return_state=True)
    elif bt == "slstm":
        y, cache = XL.slstm_apply(lp["inner"], h, n_heads=cfg.n_heads, return_state=True)
    else:
        raise ValueError(bt)
    x = x + y
    x, aux = _ffn_part(lp, x, cfg)
    return hint(x, BATCH_AXES, None, None), aux, cache


def apply_layer_decode(
    lp: Params, x: jax.Array, cache: Any, position: jax.Array, *,
    cfg: ModelConfig, bt: str, pos_cfg: dict[str, Any],
) -> tuple[jax.Array, Any]:
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if bt in ("attn", "local"):
        window = cfg.local_window if bt == "local" else None
        if cfg.use_mla:
            y, cache = MLA.mla_decode(
                lp["inner"], h, cache, position, dims=_mla_dims(cfg),
                theta=cfg.rope_theta,
            )
        elif "k_page" in cache:
            y, cache = A.attention_decode_paged(
                lp["inner"], h, cache, position,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, pos_cfg=pos_cfg, window=window,
            )
        else:
            y, cache = A.attention_decode(
                lp["inner"], h, cache, position,
                n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, pos_cfg=pos_cfg, window=window,
            )
    elif bt == "rglru":
        y, cache = RG.rglru_decode(lp["inner"], h, cache)
    elif bt == "mlstm":
        y, cache = XL.mlstm_decode(lp["inner"], h, cache, n_heads=cfg.n_heads)
    elif bt == "slstm":
        y, cache = XL.slstm_decode(lp["inner"], h, cache, n_heads=cfg.n_heads)
    else:
        raise ValueError(bt)
    x = x + y
    x, _ = _ffn_part(lp, x, cfg)
    return x, cache


# ---------------------------------------------------------------------------
# Model init.
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig) -> Params:
    n_cycles, rem = _split_layers(cfg)
    keys = jax.random.split(key, 4 + len(rem))
    d, v = cfg.d_model, cfg.vocab
    params: Params = {
        # d^-0.5 keeps tied-embedding logits O(1) at init.
        "embed": truncated_normal_init(keys[0], (v, d), d ** -0.5),
        "final_norm": rmsnorm_init(d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(keys[1], d, v)

    if n_cycles > 0:
        cycle_keys = jax.random.split(keys[2], n_cycles)

        def init_cycle(k):
            lkeys = jax.random.split(k, len(cfg.block_pattern))
            return {
                f"blk{j}": init_layer(lk, cfg, bt)
                for j, (bt, lk) in enumerate(zip(cfg.block_pattern, lkeys))
            }

        params["cycles"] = jax.vmap(init_cycle)(cycle_keys)
    for i, bt in enumerate(rem):
        params[f"rem{i}"] = init_layer(keys[4 + i], cfg, bt)
    return params


# ---------------------------------------------------------------------------
# Forward passes.
# ---------------------------------------------------------------------------

def _embed_inputs(params: Params, inputs: dict[str, jax.Array], cfg: ModelConfig):
    dt = _dtype(cfg)
    if cfg.frontend is not None:
        x = inputs["embeds"].astype(dt)
    else:
        x = params["embed"].astype(dt)[inputs["tokens"]]
    b, s = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
    if cfg.pos_kind == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(dt)
    return hint(x, BATCH_AXES, None, None), positions


def _logits(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].astype(x.dtype).T
    else:
        logits = x @ params["lm_head"].astype(x.dtype)
    return logits.astype(jnp.float32)


def forward_train(
    params: Params, inputs: dict[str, jax.Array], cfg: ModelConfig,
    *, remat: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Full causal forward. Returns (logits fp32 (B,S,V), moe_aux scalar)."""
    n_cycles, rem = _split_layers(cfg)
    x, positions = _embed_inputs(params, inputs, cfg)
    pos_cfg = _pos_cfg(cfg, inputs.get("mrope_positions"))
    aux0 = jnp.float32(0.0)

    def cycle_body(carry, cycle_params):
        x, aux = carry
        for j, bt in enumerate(cfg.block_pattern):
            x, a = apply_layer_train(
                cycle_params[f"blk{j}"], x, cfg=cfg, bt=bt,
                positions=positions, pos_cfg=pos_cfg,
            )
            aux = aux + a
        return (x, aux), None

    body = jax.checkpoint(cycle_body) if remat else cycle_body
    if n_cycles > 0:
        (x, aux), _ = jax.lax.scan(body, (x, aux0), params["cycles"])
    else:
        aux = aux0
    for i, bt in enumerate(rem):
        x, a = apply_layer_train(
            params[f"rem{i}"], x, cfg=cfg, bt=bt,
            positions=positions, pos_cfg=pos_cfg,
        )
        aux = aux + a
    return _logits(params, x, cfg), aux


def prefill(
    params: Params, inputs: dict[str, jax.Array], cfg: ModelConfig,
    *, cache_len: int | None = None,
) -> tuple[jax.Array, Any]:
    """Forward + cache. Returns (last-position logits (B, V), cache)."""
    n_cycles, rem = _split_layers(cfg)
    x, positions = _embed_inputs(params, inputs, cfg)
    pos_cfg = _pos_cfg(cfg, inputs.get("mrope_positions"))
    clen = cache_len if cache_len is not None else x.shape[1]

    def cycle_body(x, cycle_params):
        caches = {}
        for j, bt in enumerate(cfg.block_pattern):
            x, _, cache = apply_layer_prefill(
                cycle_params[f"blk{j}"], x, cfg=cfg, bt=bt,
                positions=positions, pos_cfg=pos_cfg, cache_len=clen,
            )
            caches[f"blk{j}"] = cache
        return x, caches

    cache_out: dict[str, Any] = {}
    if n_cycles > 0:
        x, cycle_caches = jax.lax.scan(cycle_body, x, params["cycles"])
        cache_out["cycles"] = cycle_caches
    for i, bt in enumerate(rem):
        x, _, cache = apply_layer_prefill(
            params[f"rem{i}"], x, cfg=cfg, bt=bt,
            positions=positions, pos_cfg=pos_cfg, cache_len=clen,
        )
        cache_out[f"rem{i}"] = cache
    logits = _logits(params, x[:, -1:], cfg)[:, 0]
    return logits, cache_out


def decode_step(
    params: Params,
    inputs: dict[str, jax.Array],  # token (B,1) or embeds (B,1,d)
    cache: Any,
    position: jax.Array,  # scalar int32
    cfg: ModelConfig,
    unroll: bool = False,
) -> tuple[jax.Array, Any]:
    """One decode step. Returns (logits (B, V), new cache).

    ``unroll=True`` replaces the scan-over-cycles with a Python loop:
    each layer's cache slice is read/written individually instead of
    through the scan's stacked ys buffer. XLA's scan output-stacking
    round-trips the whole stacked cache through a dtype-converted copy
    every iteration (measured ~900 GB/step on deepseek-67b decode);
    unrolling removes it — see EXPERIMENTS.md §Perf HC1.
    """
    n_cycles, rem = _split_layers(cfg)
    dt = _dtype(cfg)
    if cfg.frontend is not None and "embeds" in inputs:
        x = inputs["embeds"].astype(dt)
    else:
        x = params["embed"].astype(dt)[inputs["tokens"]]
    b = x.shape[0]
    if cfg.pos_kind == "sinusoidal":
        pos_b = jnp.broadcast_to(position[None], (b, 1)).astype(jnp.int32)
        x = x + sinusoidal_positions(pos_b, cfg.d_model).astype(dt)
    mrope = None
    if cfg.pos_kind == "mrope":
        # Text continuation: t = h = w = position.
        mrope = jnp.broadcast_to(position[None, None, None], (3, b, 1)).astype(jnp.int32)
    pos_cfg = _pos_cfg(cfg, mrope)

    new_cache: dict[str, Any] = {}

    def cycle_body(x, xs):
        cycle_params, cycle_cache = xs
        new_caches = {}
        for j, bt in enumerate(cfg.block_pattern):
            x, c = apply_layer_decode(
                cycle_params[f"blk{j}"], x, cycle_cache[f"blk{j}"], position,
                cfg=cfg, bt=bt, pos_cfg=pos_cfg,
            )
            new_caches[f"blk{j}"] = c
        return x, new_caches

    if n_cycles > 0 and "cycles_list" in cache:
        # Flat (unstacked) cache: unrolled layers, per-layer buffers,
        # single-token in-place updates.
        new_list = []
        for i in range(n_cycles):
            cp = jax.tree.map(lambda a: a[i], params["cycles"])
            x, nc = cycle_body(x, (cp, cache["cycles_list"][i]))
            new_list.append(nc)
        new_cache["cycles_list"] = new_list
    elif n_cycles > 0 and unroll == "carry":
        # Cache rides the scan CARRY with per-layer dynamic-index update.
        # The default path returns caches as scan ys; XLA's ys stacking
        # round-trips the whole stacked buffer through a converted copy
        # each iteration (HC1 in EXPERIMENTS.md §Perf). Carry + DUS keeps
        # per-step traffic at slice granularity and aliases the donated
        # input buffer.
        def carry_body(carry, xs_i):
            x, stacked = carry
            i, cycle_params = xs_i
            cc = jax.tree.map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, keepdims=False),
                stacked,
            )
            x, nc = cycle_body(x, (cycle_params, cc))
            stacked = jax.tree.map(
                lambda a, n: jax.lax.dynamic_update_index_in_dim(a, n, i, 0),
                stacked, nc,
            )
            return (x, stacked), None

        (x, cycles_new), _ = jax.lax.scan(
            carry_body, (x, cache["cycles"]),
            (jnp.arange(n_cycles), params["cycles"]),
        )
        new_cache["cycles"] = cycles_new
    elif n_cycles > 0 and unroll:
        per_cycle = []
        for i in range(n_cycles):
            cp = jax.tree.map(lambda a: a[i], params["cycles"])
            cc = jax.tree.map(lambda a: a[i], cache["cycles"])
            x, nc = cycle_body(x, (cp, cc))
            per_cycle.append(nc)
        new_cache["cycles"] = jax.tree.map(
            lambda *xs: jnp.stack(xs), *per_cycle
        )
    elif n_cycles > 0:
        x, cycles_new = jax.lax.scan(
            cycle_body, x, (params["cycles"], cache["cycles"])
        )
        new_cache["cycles"] = cycles_new
    for i, bt in enumerate(rem):
        x, c = apply_layer_decode(
            params[f"rem{i}"], x, cache[f"rem{i}"], position,
            cfg=cfg, bt=bt, pos_cfg=pos_cfg,
        )
        new_cache[f"rem{i}"] = c
    logits = _logits(params, x, cfg)[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Cache init.
# ---------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, bt: str, b: int, cache_len: int, dt) -> Any:
    if bt in ("attn", "local"):
        if cfg.use_mla:
            return MLA.init_mla_cache(b, cache_len, cfg.kv_lora_rank, cfg.qk_rope_dim, dt)
        window = cfg.local_window if bt == "local" else None
        page = PAGED_DECODE if (bt == "attn" and PAGED_DECODE) else 0
        return A.init_attn_cache(
            b, cache_len, cfg.n_kv_heads, cfg.resolved_head_dim, dt,
            window=window, page=page,
        )
    if bt == "rglru":
        return RG.init_rglru_state(b, cfg.lru_width or cfg.d_model, cfg.conv_width)
    if bt == "mlstm":
        return XL.init_mlstm_state(b, cfg.d_model, cfg.n_heads)
    if bt == "slstm":
        return XL.init_slstm_state(b, cfg.d_model)
    raise ValueError(bt)


def init_cache(
    cfg: ModelConfig, batch: int, cache_len: int, stacked: bool = True
) -> Any:
    """Decode cache. ``stacked=True`` packs per-cycle caches into scanned
    (n_cycles, ...) arrays; ``stacked=False`` keeps one buffer per layer
    ("flat" layout) so decode updates are single-token DUS with perfect
    donation aliasing — the scan ys-restacking rewrites the entire
    per-layer cache every step (EXPERIMENTS.md §Perf HC1)."""
    dt = _dtype(cfg)
    n_cycles, rem = _split_layers(cfg)
    out: dict[str, Any] = {}
    if n_cycles > 0:
        def cycle():
            return {
                f"blk{j}": _layer_cache(cfg, bt, batch, cache_len, dt)
                for j, bt in enumerate(cfg.block_pattern)
            }

        if stacked:
            out["cycles"] = jax.tree.map(
                lambda a: jnp.tile(a[None], (n_cycles,) + (1,) * a.ndim), cycle()
            )
        else:
            out["cycles_list"] = [cycle() for _ in range(n_cycles)]
    for i, bt in enumerate(rem):
        out[f"rem{i}"] = _layer_cache(cfg, bt, batch, cache_len, dt)
    return out
