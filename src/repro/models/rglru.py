"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)  with
input-dependent gates a_t = exp(-c * softplus(Lambda) * sigma(W_a x_t)) is
a diagonal linear recurrence, so train/prefill evaluates it with
``jax.lax.associative_scan`` (O(log S) depth — the TPU-native form of the
sequential loop) and decode carries a single (B, D) state.

Block structure (Griffin recurrent block):
  x -> [gate branch: linear -> GeLU]
    -> [main branch: linear -> short conv1d(w=4) -> RG-LRU]
  y = gate * rglru_out -> linear out
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import Params, dense_init

C_SCALE = 8.0  # the paper's fixed `c` constant


def rglru_init(key, d_model: int, lru_width: int, conv_width: int = 4) -> Params:
    ks = jax.random.split(key, 7)
    # Lambda init so a^c in [0.9, 0.999] at sigma=0.5 (Griffin appendix).
    u = jax.random.uniform(ks[0], (lru_width,), minval=0.9, maxval=0.999)
    log_lambda = jnp.log(jnp.expm1(-jnp.log(u) / C_SCALE))  # softplus^-1
    return {
        "w_gate_branch": dense_init(ks[1], d_model, lru_width),
        "w_main": dense_init(ks[2], d_model, lru_width),
        "conv_w": 0.1 * jax.random.normal(ks[3], (conv_width, lru_width)),
        "conv_b": jnp.zeros((lru_width,)),
        "w_input_gate": dense_init(ks[4], lru_width, lru_width),
        "w_rec_gate": dense_init(ks[5], lru_width, lru_width),
        "log_lambda": log_lambda,
        "w_out": dense_init(ks[6], lru_width, d_model),
    }


def _gates(params: Params, u: jax.Array):
    """Input gate i_t and log recurrence gate log(a_t) from conv output."""
    dtype = u.dtype
    i_gate = jax.nn.sigmoid(u @ params["w_input_gate"].astype(dtype))
    r = jax.nn.sigmoid(u @ params["w_rec_gate"].astype(dtype))
    log_a = (
        -C_SCALE
        * jax.nn.softplus(params["log_lambda"]).astype(jnp.float32)
        * r.astype(jnp.float32)
    )
    return i_gate, log_a


def _causal_conv(params: Params, u: jax.Array, state: jax.Array | None = None):
    """Short causal conv along time. u: (B, S, D). state: (B, W-1, D)."""
    w = params["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], w - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    full = jnp.concatenate([pad, u], axis=1)  # (B, S+W-1, D)
    out = sum(
        full[:, i : i + u.shape[1]] * params["conv_w"][i].astype(u.dtype)
        for i in range(w)
    ) + params["conv_b"].astype(u.dtype)
    new_state = full[:, -(w - 1):] if w > 1 else pad
    return out, new_state


def rglru_scan(log_a: jax.Array, b_in: jax.Array) -> jax.Array:
    """Associative scan of h_t = a_t h_{t-1} + b_t over axis 1 (time)."""

    def combine(lhs, rhs):
        la1, b1 = lhs
        la2, b2 = rhs
        return la1 + la2, jnp.exp(la2) * b1 + b2

    _, h = jax.lax.associative_scan(combine, (log_a, b_in), axis=1)
    return h


def rglru_apply(
    params: Params,
    x: jax.Array,  # (B, S, d)
    *,
    state: dict[str, jax.Array] | None = None,
    return_state: bool = False,
):
    """Train/prefill path. Returns y (and final state when requested)."""
    dtype = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate_branch"].astype(dtype))
    u = x @ params["w_main"].astype(dtype)
    conv_state = None if state is None else state["conv"]
    u, new_conv = _causal_conv(params, u, conv_state)
    i_gate, log_a = _gates(params, u)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    b_in = beta * (i_gate.astype(jnp.float32) * u.astype(jnp.float32))
    if state is not None:
        # Seed the scan with the carried hidden state via the first step.
        h0 = state["h"].astype(jnp.float32)
        b_first = b_in[:, :1] + jnp.exp(log_a[:, :1]) * h0[:, None]
        b_in = jnp.concatenate([b_first, b_in[:, 1:]], axis=1)
    h = rglru_scan(log_a, b_in)  # (B, S, D) fp32
    y = (gate * h.astype(dtype)) @ params["w_out"].astype(dtype)
    if return_state:
        return y, {"h": h[:, -1], "conv": new_conv.astype(jnp.float32)}
    return y


def rglru_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    state: dict[str, jax.Array],
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Single-step recurrence with carried (h, conv) state."""
    dtype = x.dtype
    gate = jax.nn.gelu(x @ params["w_gate_branch"].astype(dtype))
    u = x @ params["w_main"].astype(dtype)
    u, new_conv = _causal_conv(params, u, state["conv"])
    i_gate, log_a = _gates(params, u)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-9))
    h = (
        jnp.exp(log_a[:, 0]) * state["h"].astype(jnp.float32)
        + beta[:, 0] * (i_gate[:, 0] * u[:, 0]).astype(jnp.float32)
    )
    y = (gate[:, 0] * h.astype(dtype)) @ params["w_out"].astype(dtype)
    return y[:, None], {"h": h, "conv": new_conv.astype(jnp.float32)}


def init_rglru_state(b: int, lru_width: int, conv_width: int = 4):
    return {
        "h": jnp.zeros((b, lru_width), jnp.float32),
        "conv": jnp.zeros((b, conv_width - 1, lru_width), jnp.float32),
    }
