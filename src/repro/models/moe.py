"""Token-choice top-k Mixture-of-Experts with capacity-based dispatch.

Dispatch is the TPU-friendly grouped-matmul formulation: assignments are
ranked within their expert (one-hot cumsum — no sort), tokens are
scattered into an (E, C, d) buffer, experts run as one batched einsum
(E-sharded over the ``model`` axis = expert parallelism), and results
gather back weighted by router gates. Tokens beyond an expert's capacity
are dropped (standard Switch/GShard semantics); the router aux loss keeps
loads balanced so drops are rare.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import hint
from repro.models.common import Params, dense_init

# Constrain dispatch/return buffers to (experts->model, capacity->data)
# instead of letting the SPMD partitioner guess. Toggled by dry-run
# variants to measure the delta (EXPERIMENTS.md §Perf HC2).
USE_SHARDING_HINTS = False


class MoEOutput(NamedTuple):
    y: jax.Array  # (B, S, d)
    aux_loss: jax.Array  # scalar load-balancing loss
    router_entropy: jax.Array  # scalar diagnostics


def moe_init(key, d_model: int, d_ff: int, n_experts: int) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, d_model, n_experts),
        "wi_gate": jax.vmap(lambda k: dense_init(k, d_model, d_ff))(
            jax.random.split(k2, n_experts)
        ),
        "wi_up": jax.vmap(lambda k: dense_init(k, d_model, d_ff))(
            jax.random.split(k3, n_experts)
        ),
        "wo": jax.vmap(lambda k: dense_init(k, d_ff, d_model))(
            jax.random.split(k4, n_experts)
        ),
    }


def moe_apply(
    params: Params,
    x: jax.Array,  # (B, S, d)
    *,
    n_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    act: str = "silu",
) -> MoEOutput:
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    dtype = x.dtype
    e = n_experts

    router_logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(router_logits, axis=-1)  # (T, E) fp32
    gate_vals, expert_idx = jax.lax.top_k(probs, top_k)  # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = int(max(top_k, t * top_k / e * capacity_factor))

    # Rank each assignment within its expert: one-hot cumsum, no sort.
    flat_e = expert_idx.reshape(-1)  # (T*k,) expert of each assignment
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)  # (T*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)  # (T*k, E)
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    keep = pos < capacity
    flat_t = jnp.repeat(jnp.arange(t), top_k)

    # Scatter tokens into the (E*C, d) dispatch buffer. Dropped tokens are
    # value-masked into row 0 (a +1 pad row would make the buffer length
    # E*C+1 — indivisible by any mesh axis, which forces the partitioner
    # to replicate the scatter; EXPERIMENTS.md §Perf HC2).
    slot = jnp.where(keep, flat_e * capacity + pos, 0)
    contrib = xt[flat_t] * keep.astype(dtype)[:, None]
    buf = jnp.zeros((e * capacity, d), dtype).at[slot].add(contrib)
    buf = buf.reshape(e, capacity, d)
    if USE_SHARDING_HINTS:
        buf = hint(buf, "model", ("pod", "data"), None)  # E->ep, C->dp

    # Batched expert FFN (E-parallel einsums; E shards over the model axis).
    actfn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    gate_h = jnp.einsum(
        "ecd,edf->ecf", buf, params["wi_gate"].astype(dtype)
    )
    up_h = jnp.einsum("ecd,edf->ecf", buf, params["wi_up"].astype(dtype))
    h = actfn(gate_h) * up_h
    out_e = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dtype))  # (E,C,d)
    if USE_SHARDING_HINTS:
        out_e = hint(out_e, "model", ("pod", "data"), None)

    # Gather back, weighted by gates (row-0 reads are gate-masked).
    flat_gate = gate_vals.reshape(-1).astype(dtype) * keep.astype(dtype)
    picked = out_e.reshape(e * capacity, d)[slot]
    yt = jnp.zeros((t, d), dtype).at[flat_t].add(picked * flat_gate[:, None])

    # Switch-style load-balancing loss: E * sum_e f_e * P_e.
    f_e = jnp.mean(
        jax.nn.one_hot(expert_idx, e, dtype=jnp.float32).sum(1), axis=0
    ) / top_k  # fraction of tokens routed to e
    p_e = probs.mean(0)
    aux = e * jnp.sum(f_e * p_e)
    entropy = -jnp.mean(jnp.sum(probs * jnp.log(probs + 1e-9), axis=-1))
    return MoEOutput(yt.reshape(b, s, d), aux, entropy)
