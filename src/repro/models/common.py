"""Shared building blocks: norms, rotary embeddings, FFNs, init helpers."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Params = dict[str, Any]


def truncated_normal_init(key, shape, scale: float, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    return truncated_normal_init(key, (d_in, d_out), d_in ** -0.5, dtype)


def rmsnorm_init(dim: int) -> jax.Array:
    return jnp.ones((dim,), jnp.float32)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * scale
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE for Qwen2-VL).
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (
        theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)
    )


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """Rotate (B, S, H, D) by per-token positions (B, S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: tuple[int, int, int],
    theta: float = 10000.0,
) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): the head dim is split into (t, h, w)
    frequency sections, each rotated by its own position stream.

    ``x``: (B, S, H, D); ``positions``: (3, B, S) int32 (t/h/w indices).
    ``sections``: half-dim sizes per section, sum = D/2.
    """
    d = x.shape[-1]
    assert sum(sections) == d // 2, (sections, d)
    inv = rope_freqs(d, theta)  # (D/2,)
    # Build per-frequency position selector: frequency i belongs to section j.
    sec_id = jnp.concatenate(
        [jnp.full((s,), j, jnp.int32) for j, s in enumerate(sections)]
    )  # (D/2,)
    # pos_per_freq[b, s, i] = positions[sec_id[i], b, s]
    pos = positions[sec_id].transpose(1, 2, 0).astype(jnp.float32)  # (B, S, D/2)
    ang = pos * inv  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, dim: int) -> jax.Array:
    """(B, S) -> (B, S, dim) sinusoidal embedding (MusicGen-style)."""
    half = dim // 2
    freq = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Gated FFN (SwiGLU / GeGLU).
# ---------------------------------------------------------------------------

def ffn_init(key, d_model: int, d_ff: int) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, d_model, d_ff),
        "wi_up": dense_init(k2, d_model, d_ff),
        "wo": dense_init(k3, d_ff, d_model),
    }


def ffn_apply(params: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    actfn = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[act]
    dtype = x.dtype
    gate = actfn(x @ params["wi_gate"].astype(dtype))
    up = x @ params["wi_up"].astype(dtype)
    return (gate * up) @ params["wo"].astype(dtype)
