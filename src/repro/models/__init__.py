"""Assigned-architecture model zoo: decoder-only LM families in pure JAX.

Families: dense GQA transformers, MLA (MiniCPM3), MoE (token-choice top-k
with capacity), audio/VLM backbones with stubbed modality frontends,
RG-LRU hybrid (RecurrentGemma), and xLSTM (mLSTM/sLSTM).
"""
from repro.models.transformer import (  # noqa: F401
    init_params,
    forward_train,
    prefill,
    decode_step,
    init_cache,
)
