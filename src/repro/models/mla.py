"""Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style).

Queries and keys/values are produced through low-rank latents:

  q = W_uq * norm(W_dq * x)              (q_lora_rank)
  c_kv = norm(W_dkv * x)                 (kv_lora_rank)  <- cached
  k_nope, v = W_uk * c_kv, W_uv * c_kv
  k_rope = RoPE(W_kr * x)                (single shared rope head) <- cached

Train/prefill assemble full per-head K = [k_nope ; k_rope] and run the
shared flash attention. Decode uses the *absorbed* formulation: W_uk is
folded into the query so attention runs directly against the cached
latents — the cache is (kv_lora_rank + rope_dim) per token instead of
2 * H * head_dim, which is the entire point of MLA.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.attention import decode_attention, flash_attention
from repro.models.common import Params, apply_rope, dense_init, rmsnorm, rmsnorm_init


def mla_init(
    key,
    d_model: int,
    n_heads: int,
    q_lora_rank: int,
    kv_lora_rank: int,
    qk_nope_dim: int,
    qk_rope_dim: int,
    v_head_dim: int,
) -> Params:
    ks = jax.random.split(key, 7)
    qk_dim = qk_nope_dim + qk_rope_dim
    return {
        "w_dq": dense_init(ks[0], d_model, q_lora_rank),
        "q_norm": rmsnorm_init(q_lora_rank),
        "w_uq": dense_init(ks[1], q_lora_rank, n_heads * qk_dim),
        "w_dkv": dense_init(ks[2], d_model, kv_lora_rank),
        "kv_norm": rmsnorm_init(kv_lora_rank),
        "w_uk": dense_init(ks[3], kv_lora_rank, n_heads * qk_nope_dim),
        "w_uv": dense_init(ks[4], kv_lora_rank, n_heads * v_head_dim),
        "w_kr": dense_init(ks[5], d_model, qk_rope_dim),
        "wo": dense_init(ks[6], n_heads * v_head_dim, d_model),
    }


def _latents(params: Params, x: jax.Array, dims: dict[str, int]):
    b, s, _ = x.shape
    h = dims["n_heads"]
    nope, rope = dims["qk_nope_dim"], dims["qk_rope_dim"]
    dtype = x.dtype
    cq = rmsnorm(x @ params["w_dq"].astype(dtype), params["q_norm"])
    q = (cq @ params["w_uq"].astype(dtype)).reshape(b, s, h, nope + rope)
    c_kv = rmsnorm(x @ params["w_dkv"].astype(dtype), params["kv_norm"])
    k_rope = (x @ params["w_kr"].astype(dtype)).reshape(b, s, 1, rope)
    return q, c_kv, k_rope


def mla_apply(
    params: Params,
    x: jax.Array,
    *,
    dims: dict[str, int],
    positions: jax.Array,
    theta: float = 10000.0,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
) -> jax.Array:
    """Full causal MLA for training (no cache)."""
    out, _ = mla_prefill(
        params, x, dims=dims, positions=positions, theta=theta,
        cache_len=None, q_chunk=q_chunk, kv_chunk=kv_chunk,
    )
    return out


def mla_prefill(
    params: Params,
    x: jax.Array,
    *,
    dims: dict[str, int],
    positions: jax.Array,
    theta: float = 10000.0,
    cache_len: int | None = None,
    q_chunk: int | None = None,
    kv_chunk: int | None = None,
) -> tuple[jax.Array, dict[str, jax.Array] | None]:
    b, s, _ = x.shape
    h = dims["n_heads"]
    nope, rope, vdim = dims["qk_nope_dim"], dims["qk_rope_dim"], dims["v_head_dim"]
    rank = dims["kv_lora_rank"]
    dtype = x.dtype

    q, c_kv, k_rope = _latents(params, x, dims)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, theta)
    k_rope = apply_rope(k_rope, positions, theta)

    k_nope = (c_kv @ params["w_uk"].astype(dtype)).reshape(b, s, h, nope)
    v = (c_kv @ params["w_uv"].astype(dtype)).reshape(b, s, h, vdim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, rope))], axis=-1
    )
    qg = jnp.concatenate([q_nope, q_rope], axis=-1)[:, :, :, None, :]  # KV=H, G=1
    out = flash_attention(
        qg, k, v,
        q_positions=positions[0], kv_positions=positions[0],
        causal=True, q_chunk=q_chunk, kv_chunk=kv_chunk,
    ).reshape(b, s, h * vdim)
    out = out @ params["wo"].astype(dtype)

    cache = None
    if cache_len is not None:
        pad = cache_len - s
        cache = {
            "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
            "k_rope": jnp.pad(k_rope[:, :, 0, :], ((0, 0), (0, pad), (0, 0))),
            "pos": jnp.pad(positions[0], (0, pad), constant_values=-1),
        }
    return out, cache


def mla_decode(
    params: Params,
    x: jax.Array,  # (B, 1, d)
    cache: dict[str, jax.Array],
    position: jax.Array,
    *,
    dims: dict[str, int],
    theta: float = 10000.0,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Absorbed-matrix MLA decode over the latent cache."""
    b = x.shape[0]
    h = dims["n_heads"]
    nope, rope, vdim = dims["qk_nope_dim"], dims["qk_rope_dim"], dims["v_head_dim"]
    rank = dims["kv_lora_rank"]
    dtype = x.dtype

    q, c_kv_new, k_rope_new = _latents(params, x, dims)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    pos_b = jnp.broadcast_to(position[None], (b, 1)).astype(jnp.int32)
    q_rope = apply_rope(q_rope, pos_b, theta)
    k_rope_new = apply_rope(k_rope_new, pos_b, theta)

    # Update latent cache.
    c_kv = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv_new, position, axis=1)
    k_rope = jax.lax.dynamic_update_slice_in_dim(
        cache["k_rope"], k_rope_new[:, :, 0, :], position, axis=1
    )
    cpos = jax.lax.dynamic_update_slice_in_dim(
        cache["pos"], position[None].astype(jnp.int32), position, axis=0
    )

    # Absorb W_uk into the query: q_lat[b,1,h,r] = sum_n q_nope * w_uk[r,h,n].
    w_uk = params["w_uk"].astype(dtype).reshape(rank, h, nope)
    q_lat = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)
    # Attention against SHARED latents: GQA with KV=1 latent head, G=H query
    # heads. K_lat = [c_kv ; k_rope], Q_lat = [q_lat ; q_rope], V = c_kv.
    q_full = jnp.concatenate([q_lat, q_rope], axis=-1)  # (B,1,H,rank+rope)
    q_full = q_full * ((rank + rope) ** 0.5) * ((nope + rope) ** -0.5)  # rescale
    q_full = q_full.reshape(b, 1, 1, h, rank + rope)
    k_full = jnp.concatenate([c_kv, k_rope], axis=-1)[:, :, None, :]  # (B,S,1,·)
    out_lat = decode_attention(
        q_full, k_full, c_kv[:, :, None, :], position, cpos,
    ).reshape(b, 1, h, rank)
    # Un-absorb W_uv: out[b,1,h,v] = sum_r out_lat * w_uv[r,h,v].
    w_uv = params["w_uv"].astype(dtype).reshape(rank, h, vdim)
    out = jnp.einsum("bqhr,rhv->bqhv", out_lat, w_uv).reshape(b, 1, h * vdim)
    out = out @ params["wo"].astype(dtype)
    return out, {"c_kv": c_kv, "k_rope": k_rope, "pos": cpos}


def init_mla_cache(b: int, cache_len: int, kv_lora_rank: int, qk_rope_dim: int, dtype):
    return {
        "c_kv": jnp.zeros((b, cache_len, kv_lora_rank), dtype),
        "k_rope": jnp.zeros((b, cache_len, qk_rope_dim), dtype),
        "pos": jnp.full((cache_len,), -1, jnp.int32),
    }
