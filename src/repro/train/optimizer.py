"""AdamW (+ cosine schedule, global-norm clipping) from scratch.

Optimizer state is a pytree congruent with params, so it inherits the
parameter PartitionSpecs (FSDP shards optimizer moments — ZeRO style).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    clip_norm: float = 1.0


def schedule(step: jax.Array, cfg: OptConfig) -> jax.Array:
    """Linear warmup + cosine decay to min_lr_ratio * lr."""
    step_f = step.astype(jnp.float32)
    warm = step_f / jnp.maximum(cfg.warmup_steps, 1)
    progress = jnp.clip(
        (step_f - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
    return cfg.lr * jnp.where(step_f < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> dict[str, Any]:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": zeros,
        "nu": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


def adamw_update(
    grads: Any, opt_state: dict[str, Any], params: Any, cfg: OptConfig
) -> tuple[Any, dict[str, Any], dict[str, jax.Array]]:
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    lr = schedule(step, cfg)
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.clip_norm:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    else:
        gnorm = global_norm(grads)
    b1, b2 = cfg.b1, cfg.b2
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt_state["mu"], grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt_state["nu"], grads)
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        u = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return (
        new_params,
        {"step": step, "mu": mu, "nu": nu},
        {"lr": lr, "grad_norm": gnorm},
    )
