"""Training step: loss, grads, microbatch accumulation, optimizer update.

Cross-entropy is computed over vocab-sharded logits (the lm_head keeps the
vocab dim on the tensor axis, so the softmax reductions become small
all-reduces instead of gathering (B, S, V) logits). Optional int8
error-feedback gradient compression quantizes gradients before the
optimizer (the EF buffer lives in the step state), cutting DP-sync bytes
when the synchronization is expressed explicitly (see
``distributed.compression``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import hint
from repro.models.transformer import BATCH_AXES, forward_train
from repro.train.optimizer import OptConfig, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    moe_aux_weight: float = 0.01
    z_loss_weight: float = 1e-4
    num_microbatches: int = 1
    remat: bool = True
    compression: str | None = None  # None | "int8_ef"


def cross_entropy(
    logits: jax.Array,  # (B, S, V) fp32, vocab possibly sharded
    labels: jax.Array,  # (B, S) int32
    z_loss_weight: float = 0.0,
) -> tuple[jax.Array, jax.Array]:
    """Mean token xent (+ z-loss), plus accuracy for metrics."""
    logits_max = jax.lax.stop_gradient(logits.max(-1, keepdims=True))
    shifted = logits - logits_max
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
    gold = jnp.take_along_axis(shifted, labels[..., None], axis=-1)[..., 0]
    xent = jnp.mean(lse - gold)
    if z_loss_weight:
        xent = xent + z_loss_weight * jnp.mean(jnp.square(lse + logits_max[..., 0]))
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return xent, acc


def loss_fn(
    params: Any,
    batch: dict[str, jax.Array],
    cfg: ModelConfig,
    tcfg: TrainConfig,
) -> tuple[jax.Array, dict[str, jax.Array]]:
    logits, aux = forward_train(params, batch, cfg, remat=tcfg.remat)
    logits = hint(logits, BATCH_AXES, None, "model")
    xent, acc = cross_entropy(logits, batch["labels"], tcfg.z_loss_weight)
    loss = xent + tcfg.moe_aux_weight * aux
    return loss, {"xent": xent, "accuracy": acc, "moe_aux": aux}


def _split_microbatches(batch: dict[str, jax.Array], m: int) -> dict[str, jax.Array]:
    def split(x):
        if x.ndim >= 2 and x.shape[0] % m == 0:
            return x.reshape(m, x.shape[0] // m, *x.shape[1:])
        return jnp.broadcast_to(x[None], (m,) + x.shape)

    return jax.tree.map(split, batch)


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig = TrainConfig()):
    """Build the jit-able train_step(params, opt_state, batch) function."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch, cfg, tcfg
        )
        return loss, metrics, grads

    def train_step(params, opt_state, batch):
        if tcfg.num_microbatches > 1:
            m = tcfg.num_microbatches
            micro = _split_microbatches(batch, m)

            def acc_fn(carry, mb):
                g_acc, l_acc = carry
                loss, metrics, grads = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / m, g_acc, grads
                )
                return (g_acc, l_acc + loss / m), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss), metrics_seq = jax.lax.scan(acc_fn, (g0, 0.0), micro)
            metrics = jax.tree.map(lambda x: x.mean(), metrics_seq)
        else:
            loss, metrics, grads = grads_of(params, batch)

        if tcfg.compression == "int8_ef":
            from repro.distributed.compression import ef_int8_roundtrip

            grads, opt_state = ef_int8_roundtrip(grads, opt_state)

        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, tcfg.opt
        )
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step
