from repro.train.optimizer import OptConfig, init_opt_state, adamw_update  # noqa: F401
from repro.train.train_step import TrainConfig, make_train_step, loss_fn  # noqa: F401
