"""Sharded, mesh-agnostic checkpointing with async save and elastic restore.

Design for 1000+ node runs:

* **Mesh-agnostic layout** — leaves are written as full (unsharded) numpy
  arrays keyed by pytree path, so a checkpoint written on a (16,16) mesh
  restores onto (2,16,16), (8,), or a single CPU: elastic scaling is a
  restore-time re-shard, not a format conversion.
* **Atomicity** — writes go to ``<dir>.tmp`` then ``os.replace`` onto the
  final name; a crash mid-save never corrupts the latest checkpoint.
* **Async** — ``save_async`` snapshots device arrays to host then hands
  the file I/O to a worker thread; training continues.
* **Retention** — ``keep_n`` newest checkpoints survive garbage collection.

On a real multi-host deployment each host writes only the shards it owns
(``jax.experimental.multihost_utils``); on this single-process runtime the
full-array path is exercised, which is the superset code path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat: dict[str, np.ndarray] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def _unflatten_into(template: Any, flat: dict[str, np.ndarray]) -> Any:
    leaves = []
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    for path, leaf in paths:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            for p in path
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs model {leaf.shape}"
            )
        leaves.append(arr.astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str | Path, keep_n: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_n = keep_n
        self._thread: threading.Thread | None = None

    # -- save ---------------------------------------------------------------

    def _write(self, step: int, flat: dict[str, np.ndarray], meta: dict) -> None:
        final = self.dir / f"step_{step:08d}"
        tmp = self.dir / f"step_{step:08d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        (tmp / "meta.json").write_text(json.dumps(dict(meta, step=step)))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def save(self, step: int, state: Any, meta: dict | None = None) -> None:
        """Blocking save (atomic)."""
        self.wait()
        self._write(step, _flatten(state), meta or {})

    def save_async(self, step: int, state: Any, meta: dict | None = None) -> None:
        """Snapshot to host, then write on a background thread."""
        self.wait()
        flat = _flatten(jax.tree.map(lambda x: x, state))  # host snapshot
        self._thread = threading.Thread(
            target=self._write, args=(step, flat, meta or {}), daemon=True
        )
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore --------------------------------------------------------------

    def latest_step(self) -> int | None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp")
        )
        return steps[-1] if steps else None

    def restore(
        self,
        template: Any,
        step: int | None = None,
        shardings: Any = None,
    ) -> tuple[int, Any]:
        """Restore into ``template``'s structure. With ``shardings`` given
        (a pytree of NamedShardings for a possibly different mesh), leaves
        are device_put with the new layout — the elastic re-shard path.
        """
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        with np.load(path / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings
            )
        return step, state

    def _gc(self) -> None:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
            if not p.name.endswith(".tmp")
        )
        for s in steps[: -self.keep_n]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
