"""Architecture registry: one config module per assigned architecture."""
from repro.configs.base import (  # noqa: F401
    LM_SHAPES,
    ModelConfig,
    ShapeSpec,
    applicable_shapes,
    get_config,
    list_archs,
    register,
)
