"""Model + shape configuration dataclasses and the arch registry."""
from __future__ import annotations

import dataclasses
from typing import Literal


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: Literal["dense", "moe", "audio", "vlm", "hybrid", "ssm"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # Block pattern cycled over layers: attn | local | rglru | mlstm | slstm.
    block_pattern: tuple[str, ...] = ("attn",)
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # MLA (use_mla => attention blocks are MLA)
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0
    # Recurrent / local
    local_window: int = 2048
    lru_width: int = 0  # 0 -> d_model
    conv_width: int = 4
    # Positions
    pos_kind: Literal["rope", "mrope", "sinusoidal", "none"] = "rope"
    rope_theta: float = 10_000.0
    mrope_sections: tuple[int, int, int] | None = None
    # Modality frontend stub: None = token ids; 'audio'/'vision' = the input
    # is precomputed frame/patch embeddings (B, S, d_model) per instructions.
    frontend: str | None = None
    tie_embeddings: bool = False
    act: str = "silu"
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # True when every block is attention-free or windowed => O(1)-state
    # decode, eligible for the long_500k shape.
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def layer_types(self) -> tuple[str, ...]:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        total = v * d * (1 if self.tie_embeddings else 2)
        for t in self.layer_types:
            if t in ("attn", "local"):
                if self.use_mla:
                    qk = self.qk_nope_dim + self.qk_rope_dim
                    total += d * self.q_lora_rank + self.q_lora_rank * self.n_heads * qk
                    total += d * self.kv_lora_rank
                    total += self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    total += d * self.qk_rope_dim + self.n_heads * self.v_head_dim * d
                else:
                    total += d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
                if self.n_experts:
                    total += d * self.n_experts + 3 * self.n_experts * d * ff
                elif ff:
                    total += 3 * d * ff
            elif t == "rglru":
                w = self.lru_width or d
                total += 2 * d * w + 2 * w * w + w * d + (self.conv_width + 3) * w
                if ff:
                    total += 3 * d * ff
            elif t == "mlstm":
                di = 2 * d
                total += 2 * d * di + 3 * di * di + di * d
            elif t == "slstm":
                total += 4 * d * d + 4 * d * d // self.n_heads + int(4 / 3 * d) * 3 * d
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.n_experts:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        dense_total = self.param_count()
        moe_layers = sum(1 for t in self.layer_types if t in ("attn", "local"))
        all_experts = 3 * self.n_experts * d * ff * moe_layers
        active = 3 * self.top_k * d * ff * moe_layers
        return dense_total - all_experts + active


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: Literal["train", "prefill", "decode"]
    seq_len: int
    global_batch: int


LM_SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Shape cells for an arch; long_500k only for sub-quadratic archs."""
    out = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        out.append("long_500k")
    return out


def _load_all() -> None:
    import importlib

    for mod in (
        "stablelm_3b",
        "llama3_2_1b",
        "minicpm3_4b",
        "deepseek_67b",
        "moonshot_v1_16b_a3b",
        "phi3_5_moe",
        "musicgen_large",
        "qwen2_vl_2b",
        "recurrentgemma_9b",
        "xlstm_350m",
    ):
        importlib.import_module(f"repro.configs.{mod}")
