"""RecurrentGemma-9B (Griffin): RG-LRU + local attention, 2:1 pattern.

[arXiv:2402.19427; unverified] per assignment:
38L d_model=4096 16H (GQA kv=1, MQA) d_ff=12288 vocab=256000; block
pattern (rglru, rglru, local) with 2048-token attention window.
Sub-quadratic: bounded decode state => eligible for long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="recurrentgemma-9b",
        family="hybrid",
        n_layers=38,
        d_model=4096,
        n_heads=16,
        n_kv_heads=1,
        d_ff=12288,
        vocab=256000,
        head_dim=256,
        block_pattern=("rglru", "rglru", "local"),
        local_window=2048,
        lru_width=4096,
        act="gelu",
        subquadratic=True,
    )
)
