"""MusicGen-large decoder over EnCodec tokens (backbone only).

[arXiv:2306.05284; hf] per assignment:
48L d_model=2048 32H (kv=32) d_ff=8192 vocab=2048. The EnCodec
modality frontend is a STUB per instructions: input_specs() provides
precomputed frame embeddings (B, S, d_model).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab=2048,
        pos_kind="sinusoidal",
        frontend="audio",
        act="gelu",
    )
)
