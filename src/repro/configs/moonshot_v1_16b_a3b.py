"""Moonlight-16B-A3B (Kimi/Moonshot) MoE transformer.

[hf:moonshotai/Moonlight-16B-A3B; hf] per assignment:
48L d_model=2048 16H (GQA kv=16) expert d_ff=1408 vocab=163840,
MoE 64 experts top-6.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="moonshot-v1-16b-a3b",
        family="moe",
        n_layers=48,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1408,
        vocab=163840,
        n_experts=64,
        top_k=6,
        rope_theta=50_000.0,
    )
)
