"""DeepSeek-67B dense (llama-arch) transformer.

[arXiv:2401.02954; hf] per assignment:
95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab=102400,
        rope_theta=10_000.0,
    )
)
