"""xLSTM-350M: mLSTM + sLSTM blocks at 7:1 (xLSTM[7:1]).

[arXiv:2405.04517; unverified] per assignment:
24L d_model=1024 4H d_ff=0 (blocks carry their own projections)
vocab=50304. Pure recurrent state => eligible for long_500k.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab=50304,
        block_pattern=(
            "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "mlstm", "slstm",
        ),
        pos_kind="none",
        subquadratic=True,
    )
)
