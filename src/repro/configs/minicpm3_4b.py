"""MiniCPM3-4B: dense transformer with Multi-head Latent Attention.

[hf:openbmb/MiniCPM3-4B; hf] per assignment:
62L d_model=2560 40H d_ff=6400 vocab=73448; MLA with q_lora=768,
kv_lora=256, qk_nope=64, qk_rope=32, v_head=64 (HF config values).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="minicpm3-4b",
        family="dense",
        n_layers=62,
        d_model=2560,
        n_heads=40,
        n_kv_heads=40,
        d_ff=6400,
        vocab=73448,
        use_mla=True,
        q_lora_rank=768,
        kv_lora_rank=256,
        qk_nope_dim=64,
        qk_rope_dim=32,
        v_head_dim=64,
        head_dim=96,  # qk_nope + qk_rope
        rope_theta=10_000.0,
    )
)
