"""Qwen2-VL-2B backbone with M-RoPE (vision frontend stubbed).

[arXiv:2409.12191; hf] per assignment:
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936; M-RoPE splits
the 128-dim rotary space into (t, h, w) = (16, 24, 24) half-dim
sections. Patch embeddings arrive pre-merged via input_specs().
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(
    ModelConfig(
        arch_id="qwen2-vl-2b",
        family="vlm",
        n_layers=28,
        d_model=1536,
        n_heads=12,
        n_kv_heads=2,
        d_ff=8960,
        vocab=151936,
        head_dim=128,
        pos_kind="mrope",
        mrope_sections=(16, 24, 24),
        rope_theta=1_000_000.0,
        frontend="vision",
        tie_embeddings=True,
    )
)
