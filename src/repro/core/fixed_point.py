"""Hardware-faithful fixed-point window datapath (``numerics="fixed"``).

The paper's 62 ms / 8.5 W numbers come from fixed-point programmable
logic; the float pipeline reproduces the *algorithm* but not the
*datapath*. This module is the integer datapath: every accumulation in
the per-window stage chain — grid quantization, cell histogram,
coincidence/persistence filtering, patch scatter, intensity histogram,
Sobel, moment sums, edge counting — runs in integer arithmetic (int8/
int16-ranged inputs, int32 accumulators, the FPGA's DSP48/BRAM regime),
and only a small per-cluster scalar epilogue (log2/sqrt of exact
integers — a LUT/CORDIC stage in fabric) touches float32.

Number formats (DESIGN.md Sec. 12):

* coordinates: 10-bit sensor range carried as int16 (int8 once
  patch-relative), cells int16;
* all accumulators int32: per-cell ``count <= capacity`` (9 bits),
  ``sum_x < capacity * width`` (18 bits), ``sum_t < capacity *
  time_threshold_us`` (23 bits);
* centroids: UQ10.8 (int32, ``CENTROID_FRAC`` fractional bits), rounded
  half-to-even to match ``jnp.round``;
* patch origins: exact integer round-half-even division of the raw
  sums — NOT a re-rounding of the Q10.8 centroid, which would double-
  round — so origins are bit-identical to the float golden model;
* Sobel gradients: ``|g| <= 4 * capacity`` (int32), squared magnitude
  ``g2 <= 32 * capacity^2`` and its patch sum ``<= 64 * capacity^2``
  (int32-safe for capacity <= 4096).

Float-golden-model relationship (pinned by ``tests/test_fixed_point.py``):

* bit-identical: conditioning masks, cluster counts/cells/validity,
  window origins, count patches, histogram counts, and the
  shannon/renyi/local-contrast/event-count metrics (identical integers
  feed the identical float epilogue expressions);
* bounded: centroids within ``2**-8`` px (Q10.8 quantization),
  ``differential_entropy`` and ``edge_density`` within the analytic
  bounds documented in DESIGN.md Sec. 12 (the fixed path defines the
  gradient mean through an exact integer sqrt and the edge threshold
  through the exact integer compare ``16 * g2 > max(g2)``).

The fused Pallas megakernel (``repro.kernels.window_pipeline``) executes
this same datapath in one kernel launch per window batch and shares
:func:`fixed_metric_epilogue`, so staged-vs-fused bit-identity is
structural.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import metrics as M
from repro.core.events import EventBatch, coincidence_counts
from repro.core.grid_clustering import Clusters, GridConfig, _top_k_cells, quantize
from repro.core.pipeline.config import PipelineConfig
from repro.core.tracking import TrackState, tracker_step

CENTROID_FRAC = 8  # UQ10.8 centroid format (1/256 px resolution)
CENTROID_ONE = 1 << CENTROID_FRAC


class FixedClusters(NamedTuple):
    """Integer cluster set for one window (K slots), Q10.8 centroids.

    ``x0``/``y0`` are the 48x48 metric-patch origins, computed by exact
    integer division of the raw coordinate sums (bit-identical to the
    float path's ``round(centroid)`` origin — see module doc).
    """

    cq_x: jax.Array  # (K,) int32, UQ10.8 centroid column
    cq_y: jax.Array  # (K,) int32, UQ10.8 centroid row
    cq_t: jax.Array  # (K,) int32, UQ23.8 mean event time (us, window-rel)
    count: jax.Array  # (K,) int32
    cell_x: jax.Array  # (K,) int32
    cell_y: jax.Array  # (K,) int32
    x0: jax.Array  # (K,) int32 patch origin column
    y0: jax.Array  # (K,) int32 patch origin row
    valid: jax.Array  # (K,) bool

    def to_clusters(self) -> Clusters:
        """Dequantize to the standard float cluster struct (|error| <=
        2**-(CENTROID_FRAC+1) px vs the float path; invalid slots keep
        the float path's -1 sentinels)."""
        scale = jnp.float32(1.0 / CENTROID_ONE)

        def dq(cq):
            return jnp.where(self.valid, cq.astype(jnp.float32) * scale, -1.0)

        return Clusters(
            centroid_x=dq(self.cq_x),
            centroid_y=dq(self.cq_y),
            centroid_t=dq(self.cq_t),
            count=self.count,
            cell_x=self.cell_x,
            cell_y=self.cell_y,
            valid=self.valid,
        )


def round_div_half_even(num: jax.Array, den: jax.Array) -> jax.Array:
    """Exact round-half-to-even integer division (non-negative operands).

    Matches ``jnp.round(num / den)`` for every ratio the pipeline
    produces (num < 2**26, den <= capacity): the f32 quotient is within
    ulp of the rational, the rational is either exactly on a .5 boundary
    (then the f32 division is exact — the quotient fits 24 bits) or at
    least ``1/(2*den)`` away, and ``1/(2*den)`` dwarfs the division
    rounding error. This is the fabric-side divider the megakernel and
    the staged path share for patch origins.
    """
    q = num // den
    r = num - q * den
    two_r = 2 * r
    round_up = (two_r > den) | ((two_r == den) & ((q & 1) == 1))
    return q + round_up.astype(num.dtype)


def isqrt(v: jax.Array) -> jax.Array:
    """Exact integer floor-sqrt for int32 values (the LUT/CORDIC stage).

    f32 sqrt of an int <= 2**26 has error well below 1/2, so one
    correction step in each direction pins the exact floor.
    """
    r = jnp.floor(jnp.sqrt(v.astype(jnp.float32))).astype(jnp.int32)
    r = r - (r * r > v).astype(jnp.int32)
    r = r + ((r + 1) * (r + 1) <= v).astype(jnp.int32)
    return r


# ---------------------------------------------------------------------------
# Stage 1-2: grid quantization + integer cell histogram.
# ---------------------------------------------------------------------------

def cell_stats_fixed(
    batch: EventBatch, grid: GridConfig
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Integer scatter of (count, sum_x, sum_y, sum_t) per grid cell.

    Same masking/clipping as :func:`repro.core.grid_clustering.cell_histogram`
    but with int32 accumulators — the sums are exact integers below 2**24
    either way, so count/sum surfaces are bit-identical across numerics.
    """
    cx, cy = quantize(batch.x, batch.y, grid.cell_size)
    inb = (
        (batch.x >= 0)
        & (batch.x < grid.width)
        & (batch.y >= 0)
        & (batch.y < grid.height)
    )
    w = (batch.valid & inb).astype(jnp.int32)
    flat = jnp.clip(cy * grid.grid_w + cx, 0, grid.n_cells - 1)
    stats = jnp.stack([w, w * batch.x, w * batch.y, w * batch.t], axis=-1)
    acc = jnp.zeros((grid.n_cells, 4), jnp.int32).at[flat].add(stats)
    return acc[:, 0], acc[:, 1], acc[:, 2], acc[:, 3]


def clusters_fixed_from_stats(
    count: jax.Array,
    sum_x: jax.Array,
    sum_y: jax.Array,
    sum_t: jax.Array,
    grid: GridConfig,
    width: int | None = None,
    height: int | None = None,
    window: int = M.WINDOW,
) -> FixedClusters:
    """Top-K threshold + UQ10.8 centroids + exact integer patch origins.

    Cell selection reuses ``_top_k_cells`` on the identical int32 counts,
    so slot order / counts / cells / validity are bit-identical to the
    float path; only the centroid representation is quantized.
    """
    width = grid.width if width is None else width
    height = grid.height if height is None else height
    k = grid.max_clusters
    top_count, top_idx = _top_k_cells(count, k)
    valid = top_count >= grid.min_events
    den = jnp.maximum(top_count, 1)
    sx, sy, st = sum_x[top_idx], sum_y[top_idx], sum_t[top_idx]

    def q8(s):
        # Split form q*2^f + rdiv(r*2^f, den): never overflows int32 for
        # any sum below 2^31 (s * CENTROID_ONE would, for large time
        # sums), and rounds identically — the integer part q*2^f is
        # even, so the half-even parity check only needs the low word.
        q = s // den
        r = s - q * den
        return q * CENTROID_ONE + round_div_half_even(r * CENTROID_ONE, den)

    # Patch origin: round(centroid) from the RAW sums (single rounding),
    # then the same -window//2 + clip geometry as metrics.window_origin.
    # Invalid slots mirror the float path's -1.0 sentinel centroid.
    ox = jnp.where(valid, round_div_half_even(sx, den), -1)
    oy = jnp.where(valid, round_div_half_even(sy, den), -1)
    x0 = jnp.clip(ox - window // 2, 0, width - window)
    y0 = jnp.clip(oy - window // 2, 0, height - window)
    neg = jnp.int32(-CENTROID_ONE)  # dequantizes to the -1.0 sentinel
    return FixedClusters(
        cq_x=jnp.where(valid, q8(sx), neg),
        cq_y=jnp.where(valid, q8(sy), neg),
        cq_t=jnp.where(valid, q8(st), neg),
        count=jnp.where(valid, top_count, 0),
        cell_x=jnp.where(valid, (top_idx % grid.grid_w).astype(jnp.int32), -1),
        cell_y=jnp.where(valid, (top_idx // grid.grid_w).astype(jnp.int32), -1),
        x0=x0,
        y0=y0,
        valid=valid,
    )


# ---------------------------------------------------------------------------
# Stage 3-4: integer metric surfaces + shared float epilogue.
# ---------------------------------------------------------------------------

def sobel_int(patch: jax.Array) -> tuple[jax.Array, jax.Array]:
    """3x3 Sobel on an integer count patch — pure int32 shift-and-add."""
    h, w = patch.shape
    padded = jnp.pad(patch, 1)

    def shift(dy: int, dx: int) -> jax.Array:
        return jax.lax.dynamic_slice(padded, (dy, dx), (h, w))

    left, right = shift(1, 0), shift(1, 2)
    up, down = shift(0, 1), shift(2, 1)
    ul, ur = shift(0, 0), shift(0, 2)
    dl, dr = shift(2, 0), shift(2, 2)
    gx = (ur - ul) + 2 * (right - left) + (dr - dl)
    gy = (dl - ul) + 2 * (down - up) + (dr - ur)
    return gx, gy


def fixed_metric_epilogue(
    hist_i: jax.Array,  # (bins,) int32 histogram counts
    s1: jax.Array,  # scalar int32: sum of patch counts
    s2: jax.Array,  # scalar int32: sum of squared patch counts
    s_g: jax.Array,  # scalar int32: sum of floor-sqrt gradient magnitudes
    s_e2: jax.Array,  # scalar int32: sum of squared gradient magnitudes
    edges: jax.Array,  # scalar int32: exact integer edge count
    count: jax.Array,  # scalar int32 cluster event count
    valid: jax.Array,  # scalar bool
    norm_i: jax.Array,  # scalar int32 frame normalizer (max coincidence)
    n: int,  # patch pixel count (window**2)
) -> dict[str, jax.Array]:
    """The one float stage of the fixed datapath: per-cluster scalar
    transcendentals over exact integers (a LUT stage in fabric).

    Shared verbatim by the staged jnp path and the Pallas megakernel, so
    their bit-identity is structural; shannon/renyi/contrast evaluate the
    same expressions as ``metrics._exact_cluster_metrics`` over the same
    integers and stay bit-identical to the float golden model too.
    """
    histf = hist_i.astype(jnp.float32)
    p = histf / jnp.maximum(histf.sum(), 1.0)
    norm = norm_i.astype(jnp.float32)

    mean = s1.astype(jnp.float32) / n
    var_c = jnp.maximum(s2.astype(jnp.float32) / n - mean * mean, 0.0)
    contrast = jnp.sqrt(var_c) / norm

    # Fixed-point differential entropy: the gradient first moment uses
    # the exact integer floor-sqrt (|Δ| < 1/norm per pixel vs the float
    # path's sqrt); the second moment is exact. DESIGN.md Sec. 12 bounds
    # the resulting shift.
    m1 = (s_g.astype(jnp.float32) / n) / norm
    m2 = (s_e2.astype(jnp.float32) / n) / (norm * norm)
    var_g = jnp.maximum(m2 - m1 * m1, 1e-12)
    diff_entropy = 0.5 * jnp.log2(2.0 * jnp.pi * jnp.e * var_g)

    m = {
        "shannon_entropy": M._shannon_from_hist(p),
        "renyi_entropy": M._renyi_from_hist(p),
        "differential_entropy": diff_entropy,
        "local_contrast": contrast,
        "edge_density": edges.astype(jnp.float32) / n,
        "event_count": count.astype(jnp.float32),
    }
    return {k: jnp.where(valid, v, 0.0) for k, v in m.items()}


def fixed_metric_surfaces(
    batch: EventBatch,
    x0: jax.Array,
    y0: jax.Array,
    width: int,
    height: int,
    window: int = M.WINDOW,
    bins: int = M.HIST_BINS,
) -> dict[str, jax.Array]:
    """Every integer surface the metric epilogue consumes, for K clusters.

    Pure int32 arithmetic: coincidence counts, histogram bin indices via
    integer division (``(c * bins) // norm`` — provably equal to the
    float path's truncation, DESIGN.md Sec. 12), patch scatter, Sobel,
    exact edge compare ``16 * g2 > max(g2)``, integer floor-sqrt sums.
    """
    inb = (
        (batch.x >= 0) & (batch.x < width) & (batch.y >= 0) & (batch.y < height)
    )
    w = batch.valid & inb
    c, leader = coincidence_counts(batch.x, batch.y, w)
    c = c.astype(jnp.int32)
    norm_i = jnp.maximum(jnp.max(jnp.where(w, c, 0)), 1)

    bin_idx = jnp.clip((c * bins) // norm_i, 0, bins - 1)
    bins_onehot = (
        (bin_idx[:, None] == jnp.arange(bins, dtype=jnp.int32)[None, :])
        & leader[:, None]
    ).astype(jnp.int32)  # (E, bins)

    rx = batch.x[None, :] - x0[:, None]  # (K, E)
    ry = batch.y[None, :] - y0[:, None]
    inp = (rx >= 0) & (rx < window) & (ry >= 0) & (ry < window) & w[None, :]
    inp_i = inp.astype(jnp.int32)
    lead_inp = (inp & leader[None, :]).astype(jnp.int32)

    hist = lead_inp @ bins_onehot  # (K, bins) int32
    occ = lead_inp.sum(axis=-1)
    npix = window * window
    hist = hist.at[:, 0].add(npix - occ)
    s1 = inp_i.sum(axis=-1)
    s2 = (lead_inp * (c * c)[None, :]).sum(axis=-1)

    def per_patch(x0k, y0k):
        rxk = batch.x - x0k
        ryk = batch.y - y0k
        ink = (rxk >= 0) & (rxk < window) & (ryk >= 0) & (ryk < window) & w
        return (
            jnp.zeros((window, window), jnp.int32)
            .at[jnp.clip(ryk, 0, window - 1), jnp.clip(rxk, 0, window - 1)]
            .add(ink.astype(jnp.int32))
        )

    patches = jax.vmap(per_patch)(x0, y0)  # (K, window, window) int32
    gx, gy = jax.vmap(sobel_int)(patches)
    g2 = gx * gx + gy * gy
    g2max = jnp.max(g2, axis=(1, 2))
    edges = jnp.sum(
        16 * g2 > g2max[:, None, None], axis=(1, 2), dtype=jnp.int32
    )
    s_g = jnp.sum(isqrt(g2), axis=(1, 2), dtype=jnp.int32)
    s_e2 = jnp.sum(g2, axis=(1, 2), dtype=jnp.int32)
    return {
        "hist": hist, "s1": s1, "s2": s2, "s_g": s_g, "s_e2": s_e2,
        "edges": edges, "norm_i": norm_i, "patches": patches,
    }


def fixed_cluster_metrics(
    batch: EventBatch,
    fc: FixedClusters,
    width: int,
    height: int,
    window: int = M.WINDOW,
    bins: int = M.HIST_BINS,
) -> dict[str, jax.Array]:
    """Six metrics for K cluster slots, integer datapath end to end."""
    s = fixed_metric_surfaces(batch, fc.x0, fc.y0, width, height, window, bins)
    k = fc.x0.shape[0]
    return jax.vmap(
        functools.partial(fixed_metric_epilogue, n=window * window)
    )(
        s["hist"], s["s1"], s["s2"], s["s_g"], s["s_e2"], s["edges"],
        fc.count, fc.valid, jnp.broadcast_to(s["norm_i"], (k,)),
    )


# ---------------------------------------------------------------------------
# Per-window stage + scan-driver cores (the numerics="fixed" routing).
# ---------------------------------------------------------------------------

def _check_fixed_config(config: PipelineConfig) -> None:
    if config.merge_neighbors:
        raise ValueError(
            "numerics='fixed' does not support merge_neighbors (the merge "
            "weight-averages float centroids); run the float path instead"
        )
    if config.use_kernels:
        raise ValueError(
            "numerics='fixed' ignores use_kernels: the staged fixed path is "
            "integer jnp, and metrics_impl='megakernel' is the fused Pallas "
            "route — set use_kernels=False"
        )
    if config.metrics_impl not in ("event", "staged", "megakernel"):
        raise ValueError(
            "numerics='fixed' supports metrics_impl 'event'/'staged' (the "
            "staged integer path) or 'megakernel' (fused Pallas); got "
            f"{config.metrics_impl!r}"
        )


def fixed_window_stage(
    config: PipelineConfig, batch: EventBatch
) -> tuple[FixedClusters, dict[str, jax.Array]]:
    """Conditioning -> integer clustering -> integer metrics, one window.

    The staged golden reference for the megakernel: identical math, one
    jnp stage at a time.
    """
    from repro.core.pipeline.window_core import _condition

    batch = _condition(config, batch)
    fc = clusters_fixed_from_stats(
        *cell_stats_fixed(batch, config.grid), config.grid
    )
    mets = fixed_cluster_metrics(
        batch, fc, config.grid.width, config.grid.height
    )
    return fc, mets


def make_fixed_process_window(config: PipelineConfig):
    """Jit'd per-window fixed stage returning the standard float cluster
    struct (drop-in for ``make_process_window``)."""
    _check_fixed_config(config)
    if config.metrics_impl == "megakernel":
        from repro.kernels import ops as kops

        @jax.jit
        def process_window(batch: EventBatch):
            stacked = jax.tree.map(lambda a: a[None], batch)
            fc, mets = kops.window_pipeline_call(stacked, config)
            one = jax.tree.map(lambda a: a[0], fc)
            return one.to_clusters(), {k: v[0] for k, v in mets.items()}

        return process_window

    @jax.jit
    def process_window(batch: EventBatch):
        fc, mets = fixed_window_stage(config, batch)
        return fc.to_clusters(), mets

    return process_window


def _make_fixed_core(config: PipelineConfig, with_tracking: bool):
    """Step core for ``numerics="fixed"`` with the standard carry
    signature (atlas threaded through untouched).

    ``metrics_impl='event'/'staged'`` scans the staged integer stage one
    window at a time; ``'megakernel'`` runs the whole window batch
    through ONE Pallas launch and only the tracker scans.
    """
    _check_fixed_config(config)
    fused = config.metrics_impl == "megakernel"
    if fused:
        from repro.kernels import ops as kops

    def tracker_scan(state: TrackState, clusters, shannon):
        def step(carry, inp):
            cl, sh = inp
            carry, _ = tracker_step(carry, cl, sh, config.tracker)
            return carry, carry

        return jax.lax.scan(step, state, (clusters, shannon))

    def core(stacked: EventBatch, state: TrackState, atlas: jax.Array, tag0):
        del tag0  # only the event-space atlas needs window tags
        if fused:
            fc, mets = kops.window_pipeline_call(stacked, config)
            clusters = fc.to_clusters()
        else:
            def step(carry, batch):
                fc, m = fixed_window_stage(config, batch)
                return carry, (fc.to_clusters(), m)

            _, (clusters, mets) = jax.lax.scan(step, 0, stacked)
        if with_tracking:
            final, states = tracker_scan(
                state, clusters, mets["shannon_entropy"]
            )
        else:
            final, states = state, state
        return final, clusters, mets, states, atlas

    return core
