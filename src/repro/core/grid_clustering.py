"""Grid clustering (Schikuta 1996) adapted for streaming event data.

This is the paper's core algorithm, split exactly as the paper splits it:

* :func:`quantize` — the *stateless* spatial quantization stage (the FPGA IP
  core): ``cell = coord // cell_size``. The production path runs this (and
  the fused variant) as a Pallas TPU kernel (``repro.kernels``); this module
  is the composable pure-JAX implementation used as reference and on hosts.
* :func:`form_clusters` — the *stateful* cluster-formation stage (the
  paper's software client): aggregate events by cell, apply the
  ``min_events`` threshold (paper optimum: 5), emit centroids.

Everything is fixed-shape and jit/vmap/shard_map friendly.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.events import EventBatch, pack_words, unpack_words

DEFAULT_CELL_SIZE = 16  # paper: "grid size is fixed to 16x16"
DEFAULT_MIN_EVENTS = 5  # paper Table IV
DEFAULT_MAX_CLUSTERS = 32


@dataclasses.dataclass(frozen=True)
class GridConfig:
    width: int = 640
    height: int = 480
    cell_size: int = DEFAULT_CELL_SIZE
    min_events: int = DEFAULT_MIN_EVENTS
    max_clusters: int = DEFAULT_MAX_CLUSTERS

    @property
    def grid_w(self) -> int:
        return -(-self.width // self.cell_size)

    @property
    def grid_h(self) -> int:
        return -(-self.height // self.cell_size)

    @property
    def n_cells(self) -> int:
        return self.grid_w * self.grid_h


class Clusters(NamedTuple):
    """Fixed-capacity cluster set for one window (K = max_clusters slots)."""

    centroid_x: jax.Array  # (K,) float32
    centroid_y: jax.Array  # (K,) float32
    centroid_t: jax.Array  # (K,) float32 mean event time (us, window-rel)
    count: jax.Array  # (K,) int32 events contributing
    cell_x: jax.Array  # (K,) int32 grid cell column
    cell_y: jax.Array  # (K,) int32 grid cell row
    valid: jax.Array  # (K,) bool — count >= min_events

    def num_valid(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32), axis=-1)


def quantize(
    x: jax.Array, y: jax.Array, cell_size: int = DEFAULT_CELL_SIZE
) -> tuple[jax.Array, jax.Array]:
    """Stateless spatial quantization — the FPGA IP core's arithmetic.

    Power-of-two cell sizes lower to a shift (TPU VPU has no int division);
    this mirrors the DSP48 division in the paper's HLS core.
    """
    if cell_size & (cell_size - 1) == 0:
        shift = cell_size.bit_length() - 1
        return (x >> shift).astype(jnp.int32), (y >> shift).astype(jnp.int32)
    return (x // cell_size).astype(jnp.int32), (y // cell_size).astype(jnp.int32)


def quantize_packed(words: jax.Array, cell_size: int = DEFAULT_CELL_SIZE) -> jax.Array:
    """Wire-format-faithful quantization: 32-bit packed in, packed out.

    Matches the IP core end to end: unpack (bit slice) -> divide -> repack.
    """
    x, y = unpack_words(words)
    cx, cy = quantize(x, y, cell_size)
    return pack_words(cx, cy)


def cell_histogram(
    batch: EventBatch, config: GridConfig
) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """Scatter-accumulate per-cell statistics: count, sum_x, sum_y, sum_t.

    Events outside the sensor are masked out of the weights rather than
    clipped into a neighbouring cell (a clipped flat index would silently
    wrap ``x >= width`` onto the next row). The four statistics ride one
    scatter of (E, 4) rows instead of four separate scatters — XLA's CPU
    scatter loop is per-update, so packing cuts its iteration count 4x.
    """
    cx, cy = quantize(batch.x, batch.y, config.cell_size)
    inb = (
        (batch.x >= 0)
        & (batch.x < config.width)
        & (batch.y >= 0)
        & (batch.y < config.height)
    )
    w = (batch.valid & inb).astype(jnp.float32)
    flat = jnp.clip(cy * config.grid_w + cx, 0, config.n_cells - 1)
    stats = jnp.stack(
        [w, w * batch.x, w * batch.y, w * batch.t], axis=-1
    )  # (E, 4)
    acc = jnp.zeros((config.n_cells, 4), jnp.float32).at[flat].add(stats)
    count = acc[:, 0].astype(jnp.int32)
    return count, acc[:, 1], acc[:, 2], acc[:, 3]


def _top_k_cells(count: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """``lax.top_k`` with the identical contract, fast on CPU.

    XLA's CPU ``top_k`` lowers to a full variadic sort of all cells —
    ~2.7 ms for a vmapped (8, 1200) batch, which dominates the whole
    fleet step. K iterations of (argmax, mask) need only K linear passes
    and vectorize cleanly. The selection is exactly equivalent: values
    descend, and ties break to the lowest index (``argmax`` returns the
    first maximum, matching ``top_k``'s stable tie order), so every
    driver stays bit-identical whichever branch runs. Non-CPU backends
    keep the native ``top_k`` (their sort is fast and fused).
    """
    if jax.default_backend() != "cpu" or k > count.shape[-1]:
        return jax.lax.top_k(count, k)
    vals, idxs = [], []
    remaining = count
    for _ in range(k):
        i = jnp.argmax(remaining, axis=-1)
        v = jnp.take_along_axis(remaining, i[..., None], axis=-1)[..., 0]
        vals.append(v)
        idxs.append(i.astype(jnp.int32))
        remaining = jnp.where(
            jax.nn.one_hot(i, count.shape[-1], dtype=bool),
            jnp.iinfo(count.dtype).min,
            remaining,
        )
    return jnp.stack(vals, axis=-1), jnp.stack(idxs, axis=-1)


def clusters_from_histogram(
    count: jax.Array,
    sum_x: jax.Array,
    sum_y: jax.Array,
    sum_t: jax.Array,
    config: GridConfig,
) -> Clusters:
    """Threshold cells and emit the top-K clusters by event count."""
    k = config.max_clusters
    # top-k cells by count; invalid slots get count 0
    top_count, top_idx = _top_k_cells(count, k)
    valid = top_count >= config.min_events
    denom = jnp.maximum(top_count.astype(jnp.float32), 1.0)
    centroid_x = sum_x[top_idx] / denom
    centroid_y = sum_y[top_idx] / denom
    centroid_t = sum_t[top_idx] / denom
    cell_x = (top_idx % config.grid_w).astype(jnp.int32)
    cell_y = (top_idx // config.grid_w).astype(jnp.int32)
    return Clusters(
        centroid_x=jnp.where(valid, centroid_x, -1.0),
        centroid_y=jnp.where(valid, centroid_y, -1.0),
        centroid_t=jnp.where(valid, centroid_t, -1.0),
        count=jnp.where(valid, top_count, 0),
        cell_x=jnp.where(valid, cell_x, -1),
        cell_y=jnp.where(valid, cell_y, -1),
        valid=valid,
    )


def form_clusters(batch: EventBatch, config: GridConfig) -> Clusters:
    """The paper's client-side cluster formation, single pass, O(n)."""
    return clusters_from_histogram(*cell_histogram(batch, config), config)


def grid_cluster(batch: EventBatch, config: GridConfig = GridConfig()) -> Clusters:
    """End-to-end grid clustering for one event window (quantize + form)."""
    return form_clusters(batch, config)


# ---------------------------------------------------------------------------
# Neighbour merge (optional refinement; Schikuta's hierarchical step).
# ---------------------------------------------------------------------------

def merge_adjacent(clusters: Clusters, config: GridConfig) -> Clusters:
    """Merge clusters in 8-adjacent cells into the heaviest member.

    The paper's pipeline reports per-cell clusters; objects spanning a cell
    boundary appear as two adjacent clusters. This single sweep merges each
    cluster into its heaviest 8-neighbour (transitively dominated by the
    local maximum), weight-averaging centroids. Fixed shape, O(K^2).
    """
    k = clusters.count.shape[-1]
    dx = jnp.abs(clusters.cell_x[:, None] - clusters.cell_x[None, :])
    dy = jnp.abs(clusters.cell_y[:, None] - clusters.cell_y[None, :])
    adjacent = (dx <= 1) & (dy <= 1) & clusters.valid[:, None] & clusters.valid[None, :]
    counts = clusters.count.astype(jnp.float32)
    # Parent = heaviest adjacent cluster (ties broken by index).
    score = jnp.where(adjacent, counts[None, :], -1.0)
    parent = jnp.argmax(score - 1e-6 * jnp.arange(k)[None, :], axis=-1)
    parent = jnp.where(clusters.valid, parent, jnp.arange(k))
    # A root is its own parent.
    is_root = parent == jnp.arange(k)
    onehot = jax.nn.one_hot(parent, k, dtype=jnp.float32)  # (child, root)
    w = counts * clusters.valid
    merged_count = (w @ onehot).astype(jnp.int32)
    merged_x = (w * clusters.centroid_x) @ onehot
    merged_y = (w * clusters.centroid_y) @ onehot
    merged_t = (w * clusters.centroid_t) @ onehot
    denom = jnp.maximum(merged_count.astype(jnp.float32), 1.0)
    valid = is_root & clusters.valid & (merged_count >= 1)
    return Clusters(
        centroid_x=jnp.where(valid, merged_x / denom, -1.0),
        centroid_y=jnp.where(valid, merged_y / denom, -1.0),
        centroid_t=jnp.where(valid, merged_t / denom, -1.0),
        count=jnp.where(valid, merged_count, 0),
        cell_x=jnp.where(valid, clusters.cell_x, -1),
        cell_y=jnp.where(valid, clusters.cell_y, -1),
        valid=valid,
    )
