"""Track formation across event windows (paper Sec. III-D, Fig. 8).

The paper's second detection stage enforces *spatial coherence*: clusters
must form "continuous patterns consistent with expected orbital motion".
We implement that as a fixed-capacity constant-velocity (alpha-beta)
multi-target tracker:

* greedy nearest-neighbour association with a gating radius,
* alpha-beta state update (position + velocity),
* hit/miss bookkeeping; a track is *confirmed* after ``confirm_hits``
  consecutive associations and killed after ``max_misses`` misses.

Everything is fixed shape: MAX_TRACKS slots, jit/scan friendly, so a whole
recording is processed with one ``lax.scan`` over windows.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.grid_clustering import Clusters

MAX_TRACKS = 16


@dataclasses.dataclass(frozen=True)
class TrackerConfig:
    gate: float = 24.0  # px association gate (1.5 cells)
    alpha: float = 0.6  # position gain
    beta: float = 0.25  # velocity gain
    confirm_hits: int = 3
    max_misses: int = 2
    max_tracks: int = MAX_TRACKS


class TrackState(NamedTuple):
    x: jax.Array  # (T,) float32
    y: jax.Array  # (T,)
    vx: jax.Array  # (T,) px / window
    vy: jax.Array  # (T,)
    hits: jax.Array  # (T,) int32
    misses: jax.Array  # (T,) int32
    age: jax.Array  # (T,) int32
    active: jax.Array  # (T,) bool
    entropy: jax.Array  # (T,) float32 EMA of cluster Shannon entropy (Fig. 8)


def init_tracks(config: TrackerConfig = TrackerConfig()) -> TrackState:
    t = config.max_tracks
    zf = jnp.zeros((t,), jnp.float32)
    zi = jnp.zeros((t,), jnp.int32)
    return TrackState(zf, zf, zf, zf, zi, zi, zi, jnp.zeros((t,), bool), zf)


def _greedy_assign(cost: jax.Array, gate: float) -> jax.Array:
    """Greedy min-cost assignment. cost: (T, K). Returns (T,) index into K
    or -1. Each detection is used at most once."""
    t, k = cost.shape

    def body(carry, ti):
        assigned_det, out = carry
        row = jnp.where(assigned_det, jnp.inf, cost[ti])
        j = jnp.argmin(row)
        ok = row[j] <= gate
        assigned_det = assigned_det.at[j].set(assigned_det[j] | ok)
        out = out.at[ti].set(jnp.where(ok, j, -1))
        return (assigned_det, out), None

    (_, out), _ = jax.lax.scan(
        body, (jnp.zeros((k,), bool), jnp.full((t,), -1, jnp.int32)), jnp.arange(t)
    )
    return out


def tracker_step(
    state: TrackState,
    clusters: Clusters,
    cluster_entropy: jax.Array,
    config: TrackerConfig = TrackerConfig(),
) -> tuple[TrackState, jax.Array]:
    """One tracker update. Returns (new_state, assignment (T,) det index)."""
    t = config.max_tracks
    # Predict.
    px = state.x + state.vx
    py = state.y + state.vy
    # Cost = distance, inf for inactive tracks / invalid detections.
    dx = px[:, None] - clusters.centroid_x[None, :]
    dy = py[:, None] - clusters.centroid_y[None, :]
    dist = jnp.sqrt(dx * dx + dy * dy)
    cost = jnp.where(
        state.active[:, None] & clusters.valid[None, :], dist, jnp.inf
    )
    assign = _greedy_assign(cost, config.gate)
    matched = assign >= 0
    ai = jnp.clip(assign, 0, clusters.centroid_x.shape[0] - 1)
    mx = clusters.centroid_x[ai]
    my = clusters.centroid_y[ai]
    me = cluster_entropy[ai]

    # Alpha-beta update for matched, coast for unmatched-active.
    rx = mx - px
    ry = my - py
    nx = jnp.where(matched, px + config.alpha * rx, px)
    ny = jnp.where(matched, py + config.alpha * ry, py)
    nvx = jnp.where(matched, state.vx + config.beta * rx, state.vx)
    nvy = jnp.where(matched, state.vy + config.beta * ry, state.vy)
    hits = jnp.where(matched, state.hits + 1, state.hits)
    misses = jnp.where(matched, 0, state.misses + state.active.astype(jnp.int32))
    ent = jnp.where(matched, 0.7 * state.entropy + 0.3 * me, state.entropy)
    active = state.active & (misses <= config.max_misses)

    # Spawn new tracks from unassigned detections into inactive slots.
    det_used = jnp.zeros((clusters.valid.shape[0],), bool).at[ai].set(
        matched, mode="drop"
    )
    det_free = clusters.valid & ~det_used
    slot_free = ~active
    # Rank free slots and free detections; pair them by rank.
    slot_rank = jnp.cumsum(slot_free.astype(jnp.int32)) - 1  # (T,)
    det_rank = jnp.cumsum(det_free.astype(jnp.int32)) - 1  # (K,)
    k = clusters.valid.shape[0]
    # det index for each rank r: scatter rank -> det id
    det_for_rank = jnp.full((t + k,), -1, jnp.int32).at[
        jnp.where(det_free, det_rank, t + k - 1)
    ].set(jnp.arange(k), mode="drop")
    spawn_det = jnp.where(slot_free, det_for_rank[jnp.clip(slot_rank, 0, t + k - 1)], -1)
    do_spawn = slot_free & (spawn_det >= 0)
    si = jnp.clip(spawn_det, 0, k - 1)
    nx = jnp.where(do_spawn, clusters.centroid_x[si], nx)
    ny = jnp.where(do_spawn, clusters.centroid_y[si], ny)
    nvx = jnp.where(do_spawn, 0.0, nvx)
    nvy = jnp.where(do_spawn, 0.0, nvy)
    hits = jnp.where(do_spawn, 1, hits)
    misses = jnp.where(do_spawn, 0, misses)
    ent = jnp.where(do_spawn, cluster_entropy[si], ent)
    age = jnp.where(do_spawn, 0, state.age + active.astype(jnp.int32))
    active = active | do_spawn

    new = TrackState(nx, ny, nvx, nvy, hits, misses, age, active, ent)
    return new, assign


def confirmed(state: TrackState, config: TrackerConfig = TrackerConfig()) -> jax.Array:
    """(T,) bool — tracks that passed the spatial-coherence stage."""
    return state.active & (state.hits >= config.confirm_hits)


def track_recording(
    clusters_seq: Clusters,
    entropy_seq: jax.Array,
    config: TrackerConfig = TrackerConfig(),
    init: TrackState | None = None,
) -> tuple[TrackState, TrackState]:
    """Scan the tracker over a stacked sequence of per-window clusters.

    ``clusters_seq`` leaves have shape (W, K); ``entropy_seq`` is (W, K).
    ``init`` seeds the carry (defaults to empty tracks) so scans can be
    chained across recording segments. Returns (final_state, per-window
    stacked states). ``TrackState`` is a flat pytree of (T,) leaves, so it
    is a valid ``lax.scan`` carry as-is — ``run_recording_scan`` threads it
    through the full conditioning -> clustering -> metrics scan body.
    """

    def step(state, inp):
        cl, ent = inp
        new, _ = tracker_step(state, cl, ent, config)
        return new, new

    return jax.lax.scan(
        step, init_tracks(config) if init is None else init,
        (clusters_seq, entropy_seq),
    )
