"""Core library: the paper's grid-clustering RSO detection pipeline."""
from repro.core.events import (  # noqa: F401
    EventBatch,
    BatcherConfig,
    WindowedEvents,
    dual_threshold_batches,
    pad_windows,
    pack_words,
    unpack_words,
    roi_filter,
    persistent_event_filter,
    persistent_event_filter_hist,
    coincidence_counts,
)
from repro.core.grid_clustering import (  # noqa: F401
    Clusters,
    GridConfig,
    grid_cluster,
    quantize,
    quantize_packed,
    form_clusters,
)
from repro.core.pipeline import (  # noqa: F401
    Candidates,
    DetectionScore,
    PipelineConfig,
    ScanResult,
    collect_candidates,
    evaluate_detection,
    make_process_window,
    make_scan_fn,
    merge_candidates,
    run_many_scan,
    run_recording,
    run_recording_scan,
    score_threshold,
    threshold_sweep,
)
from repro.core.tracking import (  # noqa: F401
    TrackerConfig,
    TrackState,
    tracker_step,
    track_recording,
    confirmed,
)
