"""Core library: the paper's grid-clustering RSO detection pipeline."""
from repro.core.events import (  # noqa: F401
    EventBatch,
    BatcherConfig,
    dual_threshold_batches,
    pack_words,
    unpack_words,
    roi_filter,
    persistent_event_filter,
)
from repro.core.grid_clustering import (  # noqa: F401
    Clusters,
    GridConfig,
    grid_cluster,
    quantize,
    quantize_packed,
    form_clusters,
)
from repro.core.pipeline import (  # noqa: F401
    PipelineConfig,
    make_process_window,
    run_recording,
    evaluate_detection,
    threshold_sweep,
)
from repro.core.tracking import (  # noqa: F401
    TrackerConfig,
    TrackState,
    tracker_step,
    track_recording,
    confirmed,
)
