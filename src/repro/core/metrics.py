"""Cluster quality metrics (paper Sec. III-E).

For every detected cluster the paper extracts a 48x48 pixel window around
the centroid from a *reconstructed frame* (event accumulation image) and
computes six statistics used to pick the ``min_events`` operating point:

* Shannon entropy of the intensity histogram,
* Renyi entropy of order 2,
* differential entropy from the gradient-magnitude standard deviation,
* local contrast (intensity std),
* edge density (paper: Canny; here: Sobel magnitude + non-maximum-style
  threshold — Canny's hysteresis is a host-side heuristic that does not
  change the ranking the paper uses, noted in DESIGN.md),
* event count (carried through from clustering).

All functions are fixed-shape, jit- and vmap-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.events import EventBatch
from repro.core.grid_clustering import Clusters

WINDOW = 48  # paper: 48x48 pixel window
HIST_BINS = 32


def reconstruct_frame(
    batch: EventBatch, width: int = 640, height: int = 480
) -> jax.Array:
    """Accumulate events into an intensity frame, normalized to [0, 1]."""
    flat = jnp.clip(batch.y * width + batch.x, 0, width * height - 1)
    img = jnp.zeros((height * width,), jnp.float32).at[flat].add(
        batch.valid.astype(jnp.float32)
    )
    img = img.reshape(height, width)
    return img / jnp.maximum(img.max(), 1.0)


def extract_window(
    frame: jax.Array, cx: jax.Array, cy: jax.Array, window: int = WINDOW
) -> jax.Array:
    """Extract a (window, window) patch centered at (cx, cy), edge-clamped."""
    h, w = frame.shape
    x0 = jnp.clip(jnp.round(cx).astype(jnp.int32) - window // 2, 0, w - window)
    y0 = jnp.clip(jnp.round(cy).astype(jnp.int32) - window // 2, 0, h - window)
    return jax.lax.dynamic_slice(frame, (y0, x0), (window, window))


def _histogram(patch: jax.Array, bins: int = HIST_BINS) -> jax.Array:
    """Normalized intensity histogram (differentiable-ish, fixed shape).

    Implemented as a one-hot compare-and-sum rather than a scatter-add:
    counts are exact small integers either way (bit-identical result), but
    the dense reduction vectorizes where vmapped scatters serialize —
    ~5x faster on CPU and the layout the scanned pipeline wants.
    """
    flat = patch.reshape(-1)
    idx = jnp.clip((flat * bins).astype(jnp.int32), 0, bins - 1)
    # int8 compares vectorize best on CPU; only valid while every bin
    # index fits in int8.
    cmp_dtype = jnp.int8 if bins <= 127 else jnp.int32
    onehot = idx.astype(cmp_dtype)[None, :] == jnp.arange(bins, dtype=cmp_dtype)[:, None]
    counts = onehot.sum(axis=1, dtype=jnp.int32).astype(jnp.float32)
    return counts / jnp.maximum(counts.sum(), 1.0)


def _shannon_from_hist(p: jax.Array) -> jax.Array:
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-12)), 0.0))


def _renyi_from_hist(p: jax.Array) -> jax.Array:
    return -jnp.log2(jnp.maximum(jnp.sum(p * p), 1e-12))


def shannon_entropy(patch: jax.Array, bins: int = HIST_BINS) -> jax.Array:
    """H = -sum p_i log2 p_i over the intensity histogram."""
    return _shannon_from_hist(_histogram(patch, bins))


def renyi_entropy(patch: jax.Array, bins: int = HIST_BINS) -> jax.Array:
    """H2 = -log2 sum p_i^2 (collision entropy)."""
    return _renyi_from_hist(_histogram(patch, bins))


def _sobel(patch: jax.Array) -> tuple[jax.Array, jax.Array]:
    """3x3 Sobel cross-correlation via shift-and-add.

    Zero-padded shifts match conv_general_dilated's SAME behaviour but
    lower to six adds per axis — far cheaper than a general convolution on
    CPU/VPU for a fixed 3x3 stencil, and fully fusable inside scan bodies.
    """
    h, w = patch.shape
    padded = jnp.pad(patch, 1)

    def shift(dy: int, dx: int) -> jax.Array:
        return jax.lax.dynamic_slice(padded, (dy, dx), (h, w))

    left = shift(1, 0)
    right = shift(1, 2)
    up = shift(0, 1)
    down = shift(2, 1)
    ul, ur = shift(0, 0), shift(0, 2)
    dl, dr = shift(2, 0), shift(2, 2)
    gx = (ur - ul) + 2.0 * (right - left) + (dr - dl)
    gy = (dl - ul) + 2.0 * (down - up) + (dr - ur)
    return gx, gy


def gradient_magnitude(patch: jax.Array) -> jax.Array:
    gx, gy = _sobel(patch)
    return jnp.sqrt(gx * gx + gy * gy + 1e-12)


def _diff_entropy_from_g(g: jax.Array) -> jax.Array:
    var = jnp.maximum(jnp.var(g), 1e-12)
    return 0.5 * jnp.log2(2.0 * jnp.pi * jnp.e * var)


def _edge_density_from_g(g: jax.Array, threshold: float = 0.25) -> jax.Array:
    g = g / jnp.maximum(g.max(), 1e-3)
    return jnp.mean((g > threshold).astype(jnp.float32))


def differential_entropy(patch: jax.Array) -> jax.Array:
    """Gaussian-model differential entropy of gradient magnitudes:
    h = 0.5 * log2(2 pi e sigma^2)."""
    return _diff_entropy_from_g(gradient_magnitude(patch))


def local_contrast(patch: jax.Array) -> jax.Array:
    """Standard deviation of pixel intensities within the window."""
    return jnp.std(patch)


def edge_density(patch: jax.Array, threshold: float = 0.25) -> jax.Array:
    """Ratio of edge pixels to total pixels (Sobel-magnitude detector).

    The 1e-3 normalization floor keeps flat patches edge-free (frames are
    normalized to [0, 1], so real edges have O(1) gradients).
    """
    return _edge_density_from_g(gradient_magnitude(patch), threshold)


def cluster_metrics(frame: jax.Array, clusters: Clusters) -> dict[str, jax.Array]:
    """Vectorized metric computation for every cluster slot. Invalid slots
    get zeros. Returns a dict of (K,) arrays keyed by metric name.

    The intensity histogram and gradient magnitude are computed once per
    patch and shared across the metrics that consume them — this stage
    dominates per-window latency, so the sharing matters for the scanned
    pipeline's throughput.
    """

    def per_cluster(cx, cy, count, valid):
        patch = extract_window(frame, cx, cy)
        p = _histogram(patch)
        g = gradient_magnitude(patch)
        m = {
            "shannon_entropy": _shannon_from_hist(p),
            "renyi_entropy": _renyi_from_hist(p),
            "differential_entropy": _diff_entropy_from_g(g),
            "local_contrast": local_contrast(patch),
            "edge_density": _edge_density_from_g(g),
            "event_count": count.astype(jnp.float32),
        }
        return {k: jnp.where(valid, v, 0.0) for k, v in m.items()}

    return jax.vmap(per_cluster)(
        clusters.centroid_x, clusters.centroid_y, clusters.count, clusters.valid
    )


METRIC_NAMES = (
    "shannon_entropy",
    "renyi_entropy",
    "differential_entropy",
    "local_contrast",
    "edge_density",
    "event_count",
)


def metric_matrix(metrics: dict[str, jax.Array]) -> jax.Array:
    """Stack the metric dict into a (K, 6) matrix in METRIC_NAMES order."""
    return jnp.stack([metrics[name] for name in METRIC_NAMES], axis=-1)


def correlation_matrix(samples: jax.Array) -> jax.Array:
    """Pearson correlation matrix across metric columns (paper Fig. 7).

    ``samples``: (N, M) matrix of N cluster observations x M metrics.
    """
    x = samples - samples.mean(axis=0, keepdims=True)
    cov = (x.T @ x) / jnp.maximum(samples.shape[0] - 1, 1)
    std = jnp.sqrt(jnp.clip(jnp.diag(cov), 1e-12))
    return cov / (std[:, None] * std[None, :])
