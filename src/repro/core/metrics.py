"""Cluster quality metrics (paper Sec. III-E).

For every detected cluster the paper extracts a 48x48 pixel window around
the centroid from a *reconstructed frame* (event accumulation image) and
computes six statistics used to pick the ``min_events`` operating point:

* Shannon entropy of the intensity histogram,
* Renyi entropy of order 2,
* differential entropy from the gradient-magnitude standard deviation,
* local contrast (intensity std),
* edge density (paper: Canny; here: Sobel magnitude + non-maximum-style
  threshold — Canny's hysteresis is a host-side heuristic that does not
  change the ranking the paper uses, noted in DESIGN.md Sec. 3),
* event count (carried through from clustering).

Two equivalent paths produce the six metrics (DESIGN.md Sec. 4):

* the **frame-based oracle** (:func:`cluster_metrics_frame`) scatters the
  window into a sensor-sized accumulation image and slices patches out of
  it — O(sensor area) per window, kept as the bit-exactness reference;
* the **event-space path** (:func:`cluster_metrics_events`) accumulates
  each cluster's 48x48 count patch directly from events via
  centroid-relative coordinates and recovers the frame's global-max
  normalizer from per-pixel coincidence counts — O(E + K * patch^2) per
  window, bit-identical to the oracle.

Bit-identity holds because every cross-path quantity is an exact small
integer (pixel counts, histogram counts, edge counts, integer moment
sums): float sums of exact integers below 2^24 are order-independent,
and both paths share :func:`_exact_cluster_metrics` for everything
downstream of those integers.

All functions are fixed-shape, jit- and vmap-friendly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.events import EventBatch, coincidence_counts
from repro.core.grid_clustering import Clusters

WINDOW = 48  # paper: 48x48 pixel window
HIST_BINS = 32
EDGE_THRESHOLD = 0.25


def accumulate_image(
    batch: EventBatch, width: int = 640, height: int = 480
) -> jax.Array:
    """Dense per-pixel event-count image (the un-normalized accumulation
    frame). Events outside the sensor are masked out of the weights, not
    clipped into a neighbouring pixel."""
    inb = (
        (batch.x >= 0) & (batch.x < width) & (batch.y >= 0) & (batch.y < height)
    )
    w = (batch.valid & inb).astype(jnp.float32)
    flat = jnp.clip(batch.y * width + batch.x, 0, width * height - 1)
    img = jnp.zeros((height * width,), jnp.float32).at[flat].add(w)
    return img.reshape(height, width)


def reconstruct_frame(
    batch: EventBatch, width: int = 640, height: int = 480
) -> jax.Array:
    """Accumulate events into an intensity frame, normalized to [0, 1]."""
    img = accumulate_image(batch, width, height)
    return img / jnp.maximum(img.max(), 1.0)


def window_origin(
    cx: jax.Array, cy: jax.Array, width: int, height: int, window: int = WINDOW
) -> tuple[jax.Array, jax.Array]:
    """Top-left corner of the edge-clamped (window, window) patch around a
    centroid — the one geometry shared by every metrics path."""
    x0 = jnp.clip(jnp.round(cx).astype(jnp.int32) - window // 2, 0, width - window)
    y0 = jnp.clip(jnp.round(cy).astype(jnp.int32) - window // 2, 0, height - window)
    return x0, y0


def extract_window(
    frame: jax.Array, cx: jax.Array, cy: jax.Array, window: int = WINDOW
) -> jax.Array:
    """Extract a (window, window) patch centered at (cx, cy), edge-clamped."""
    h, w = frame.shape
    x0, y0 = window_origin(cx, cy, w, h, window)
    return jax.lax.dynamic_slice(frame, (y0, x0), (window, window))


def _histogram_counts(patch: jax.Array, bins: int = HIST_BINS) -> jax.Array:
    """Integer intensity-histogram counts of a [0, 1] patch, as float32.

    Implemented as a one-hot compare-and-sum rather than a scatter-add:
    counts are exact small integers either way (bit-identical result), but
    the dense reduction vectorizes where vmapped scatters serialize —
    ~5x faster on CPU and the layout the scanned pipeline wants.
    """
    flat = patch.reshape(-1)
    idx = jnp.clip((flat * bins).astype(jnp.int32), 0, bins - 1)
    # int8 compares vectorize best on CPU; only valid while every bin
    # index fits in int8.
    cmp_dtype = jnp.int8 if bins <= 127 else jnp.int32
    onehot = idx.astype(cmp_dtype)[None, :] == jnp.arange(bins, dtype=cmp_dtype)[:, None]
    return onehot.sum(axis=1, dtype=jnp.int32).astype(jnp.float32)


def _histogram(patch: jax.Array, bins: int = HIST_BINS) -> jax.Array:
    """Normalized intensity histogram (differentiable-ish, fixed shape)."""
    counts = _histogram_counts(patch, bins)
    return counts / jnp.maximum(counts.sum(), 1.0)


def _shannon_from_hist(p: jax.Array) -> jax.Array:
    return -jnp.sum(jnp.where(p > 0, p * jnp.log2(jnp.maximum(p, 1e-12)), 0.0))


def _renyi_from_hist(p: jax.Array) -> jax.Array:
    return -jnp.log2(jnp.maximum(jnp.sum(p * p), 1e-12))


def shannon_entropy(patch: jax.Array, bins: int = HIST_BINS) -> jax.Array:
    """H = -sum p_i log2 p_i over the intensity histogram."""
    return _shannon_from_hist(_histogram(patch, bins))


def renyi_entropy(patch: jax.Array, bins: int = HIST_BINS) -> jax.Array:
    """H2 = -log2 sum p_i^2 (collision entropy)."""
    return _renyi_from_hist(_histogram(patch, bins))


def _sobel(patch: jax.Array) -> tuple[jax.Array, jax.Array]:
    """3x3 Sobel cross-correlation via shift-and-add.

    Zero-padded shifts match conv_general_dilated's SAME behaviour but
    lower to six adds per axis — far cheaper than a general convolution on
    CPU/VPU for a fixed 3x3 stencil, and fully fusable inside scan bodies.
    """
    h, w = patch.shape
    padded = jnp.pad(patch, 1)

    def shift(dy: int, dx: int) -> jax.Array:
        return jax.lax.dynamic_slice(padded, (dy, dx), (h, w))

    left = shift(1, 0)
    right = shift(1, 2)
    up = shift(0, 1)
    down = shift(2, 1)
    ul, ur = shift(0, 0), shift(0, 2)
    dl, dr = shift(2, 0), shift(2, 2)
    gx = (ur - ul) + 2.0 * (right - left) + (dr - dl)
    gy = (dl - ul) + 2.0 * (down - up) + (dr - ur)
    return gx, gy


def gradient_magnitude(patch: jax.Array) -> jax.Array:
    gx, gy = _sobel(patch)
    return jnp.sqrt(gx * gx + gy * gy + 1e-12)


def _diff_entropy_from_g(g: jax.Array) -> jax.Array:
    var = jnp.maximum(jnp.var(g), 1e-12)
    return 0.5 * jnp.log2(2.0 * jnp.pi * jnp.e * var)


def _edge_density_from_g(g: jax.Array, threshold: float = 0.25) -> jax.Array:
    g = g / jnp.maximum(g.max(), 1e-3)
    return jnp.mean((g > threshold).astype(jnp.float32))


def differential_entropy(patch: jax.Array) -> jax.Array:
    """Gaussian-model differential entropy of gradient magnitudes:
    h = 0.5 * log2(2 pi e sigma^2)."""
    return _diff_entropy_from_g(gradient_magnitude(patch))


def local_contrast(patch: jax.Array) -> jax.Array:
    """Standard deviation of pixel intensities within the window."""
    return jnp.std(patch)


def edge_density(patch: jax.Array, threshold: float = 0.25) -> jax.Array:
    """Ratio of edge pixels to total pixels (Sobel-magnitude detector).

    The 1e-3 normalization floor keeps flat patches edge-free (frames are
    normalized to [0, 1], so real edges have O(1) gradients).
    """
    return _edge_density_from_g(gradient_magnitude(patch), threshold)


def cluster_metrics(frame: jax.Array, clusters: Clusters) -> dict[str, jax.Array]:
    """Vectorized metric computation for every cluster slot. Invalid slots
    get zeros. Returns a dict of (K,) arrays keyed by metric name.

    Legacy reference operating on a pre-normalized frame; the pipeline
    routes through :func:`cluster_metrics_frame` /
    :func:`cluster_metrics_events` instead, which share the
    exactly-replayable metric core (values agree with this function to
    float tolerance, not bit-for-bit — see DESIGN.md Sec. 4).
    """

    def per_cluster(cx, cy, count, valid):
        patch = extract_window(frame, cx, cy)
        p = _histogram(patch)
        g = gradient_magnitude(patch)
        m = {
            "shannon_entropy": _shannon_from_hist(p),
            "renyi_entropy": _renyi_from_hist(p),
            "differential_entropy": _diff_entropy_from_g(g),
            "local_contrast": local_contrast(patch),
            "edge_density": _edge_density_from_g(g),
            "event_count": count.astype(jnp.float32),
        }
        return {k: jnp.where(valid, v, 0.0) for k, v in m.items()}

    return jax.vmap(per_cluster)(
        clusters.centroid_x, clusters.centroid_y, clusters.count, clusters.valid
    )


# ---------------------------------------------------------------------------
# Exactly-replayable metric core, shared by the frame-based oracle and the
# frame-free event-space path (DESIGN.md Sec. 4). Every quantity entering a
# float reduction is either an exact small integer (order-independent sum)
# or computed densely from identical integer inputs in both paths.
# ---------------------------------------------------------------------------

def _exact_cluster_metrics(
    cnt_patch: jax.Array,  # (window, window) integer event counts, as f32
    hist_counts: jax.Array,  # (bins,) integer histogram counts, as f32
    norm: jax.Array,  # scalar frame normalizer: max(global max count, 1)
    count: jax.Array,  # scalar cluster event count
    valid: jax.Array,  # scalar cluster validity
    moments: tuple[jax.Array, jax.Array] | None = None,  # (sum c, sum c^2)
) -> dict[str, jax.Array]:
    """Six metrics for one cluster from its integer count patch.

    ``local_contrast`` uses integer moment sums (sum c, sum c^2 <= 2^24,
    exact in f32) and ``edge_density`` compares squared gradient
    magnitudes against a squared threshold, so both are computable from
    sparse events without replaying a dense reduction order — callers
    with event-side moments pass them via ``moments`` and skip two dense
    passes; the sums are exact integers either way, so the result is
    bit-identical. The gradient-magnitude statistics run densely on the
    count patch, which both paths materialize bit-identically.
    """
    n = cnt_patch.size
    p = hist_counts / jnp.maximum(hist_counts.sum(), 1.0)

    # Local contrast: std of normalized intensities via integer moments.
    if moments is None:
        s1 = jnp.sum(cnt_patch)
        s2 = jnp.sum(cnt_patch * cnt_patch)
    else:
        s1, s2 = moments
    mean = s1 / n
    var_c = jnp.maximum(s2 / n - mean * mean, 0.0)
    contrast = jnp.sqrt(var_c) / norm

    # Gradient field of the integer counts (Sobel outputs stay integer).
    gx, gy = _sobel(cnt_patch)
    e2 = (gx * gx + gy * gy) / (norm * norm) + 1e-12  # squared magnitude
    g = jnp.sqrt(e2)
    # One variadic reduce for sum(g) / sum(e2) / max(e2): three separate
    # jnp reductions each force the whole e2/g field to materialize and
    # be re-read, which costs more than the Sobel itself on CPU; a
    # single fused reduce streams the field once. (Float summation
    # order is unspecified either way; every metrics path shares this
    # function, so cross-driver bit-identity is structural.)
    s_g, s_e2, mx_e2 = jax.lax.reduce(
        (g, e2, e2),
        (jnp.float32(0.0), jnp.float32(0.0), jnp.float32(-jnp.inf)),
        lambda a, b: (a[0] + b[0], a[1] + b[1], jnp.maximum(a[2], b[2])),
        (0, 1),
    )
    m1 = s_g / n
    var_g = jnp.maximum(s_e2 / n - m1 * m1, 1e-12)
    diff_entropy = 0.5 * jnp.log2(2.0 * jnp.pi * jnp.e * var_g)

    # Edge density: g / max(g.max(), 1e-3) > t, evaluated in squared
    # magnitude space (sqrt is monotone, so max commutes; the count of
    # edge pixels is an exact integer sum).
    den = jnp.maximum(jnp.sqrt(mx_e2), 1e-3)
    thr = (EDGE_THRESHOLD * den) * (EDGE_THRESHOLD * den)
    edges = jnp.sum((e2 > thr).astype(jnp.float32))
    edge_density_v = edges / n

    m = {
        "shannon_entropy": _shannon_from_hist(p),
        "renyi_entropy": _renyi_from_hist(p),
        "differential_entropy": diff_entropy,
        "local_contrast": contrast,
        "edge_density": edge_density_v,
        "event_count": count.astype(jnp.float32),
    }
    return {k: jnp.where(valid, v, 0.0) for k, v in m.items()}


def cluster_metrics_frame(
    batch: EventBatch,
    clusters: Clusters,
    width: int = 640,
    height: int = 480,
) -> dict[str, jax.Array]:
    """Frame-based oracle: metrics via a dense sensor-sized count image.

    Scatters the window into an O(sensor-area) accumulation image, takes
    the global max as the normalizer, and slices each cluster's count
    patch out with :func:`extract_window` — the paper's original data
    flow. Kept as the bit-exactness reference for
    :func:`cluster_metrics_events` (identical integer count patches and
    histogram counts feed the shared core).
    """
    img = accumulate_image(batch, width, height)
    norm = jnp.maximum(jnp.max(img), 1.0)

    def per_cluster(cx, cy, count, valid):
        cnt = extract_window(img, cx, cy)
        hist = _histogram_counts(cnt / norm)
        return _exact_cluster_metrics(cnt, hist, norm, count, valid)

    return jax.vmap(per_cluster)(
        clusters.centroid_x, clusters.centroid_y, clusters.count, clusters.valid
    )


def event_normalizer(batch: EventBatch, width: int, height: int):
    """Per-event coincidence counts, leaders, and the frame normalizer —
    everything :func:`reconstruct_frame` provides, recovered in event
    space. Returns (counts, leader, weight, norm)."""
    inb = (
        (batch.x >= 0) & (batch.x < width) & (batch.y >= 0) & (batch.y < height)
    )
    w = batch.valid & inb
    c, leader = coincidence_counts(batch.x, batch.y, w)
    norm = jnp.maximum(jnp.max(jnp.where(w, c, 0)).astype(jnp.float32), 1.0)
    return c, leader, w, norm


def event_histogram_counts(
    batch: EventBatch,
    c: jax.Array,
    leader: jax.Array,
    w: jax.Array,
    norm: jax.Array,
    x0: jax.Array,  # (K,) patch origins
    y0: jax.Array,
    window: int = WINDOW,
    bins: int = HIST_BINS,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Patch intensity-histogram counts straight from events: (K, bins).

    Every occupied pixel contributes through its leader event (whose bin
    index is the same expression the dense path evaluates per pixel);
    unoccupied pixels land in bin 0. Also returns the per-cluster
    integer moment sums ``(sum c, sum c^2)`` over the patch (exact in
    f32) for the contrast metric.
    """
    val = c.astype(jnp.float32) / norm
    bin_idx = jnp.clip((val * bins).astype(jnp.int32), 0, bins - 1)
    bins_onehot = (
        (bin_idx[:, None] == jnp.arange(bins)[None, :]) & leader[:, None]
    ).astype(jnp.float32)  # (E, bins)

    rx = batch.x[None, :] - x0[:, None]  # (K, E)
    ry = batch.y[None, :] - y0[:, None]
    inp = (
        (rx >= 0) & (rx < window) & (ry >= 0) & (ry < window) & w[None, :]
    ).astype(jnp.float32)

    lead_inp = inp * leader.astype(jnp.float32)[None, :]
    hist = lead_inp @ bins_onehot  # (K, bins) exact integer counts
    occ = jnp.sum(lead_inp, axis=-1)
    hist = hist.at[:, 0].add(window * window - occ)
    # Moments: sum of pixel counts == events in patch; sum of squared
    # pixel counts through leaders. Exact integers below 2^24.
    s1 = jnp.sum(inp, axis=-1)
    c2 = (c * c).astype(jnp.float32)
    s2 = jnp.sum(lead_inp * c2[None, :], axis=-1)
    return hist, (s1, s2)


def cluster_count_patches(
    batch: EventBatch,
    clusters: Clusters,
    width: int = 640,
    height: int = 480,
    window: int = WINDOW,
) -> jax.Array:
    """(K, window, window) integer count patches accumulated directly from
    events via centroid-relative coordinates — no sensor-sized buffer."""
    inb = (
        (batch.x >= 0) & (batch.x < width) & (batch.y >= 0) & (batch.y < height)
    )
    w = batch.valid & inb
    x0, y0 = window_origin(
        clusters.centroid_x, clusters.centroid_y, width, height, window
    )

    def per_cluster(x0k, y0k):
        rx = batch.x - x0k
        ry = batch.y - y0k
        inp = (rx >= 0) & (rx < window) & (ry >= 0) & (ry < window) & w
        return (
            jnp.zeros((window, window), jnp.float32)
            .at[jnp.clip(ry, 0, window - 1), jnp.clip(rx, 0, window - 1)]
            .add(inp.astype(jnp.float32))
        )

    return jax.vmap(per_cluster)(x0, y0)


def cluster_metrics_events(
    batch: EventBatch,
    clusters: Clusters,
    width: int = 640,
    height: int = 480,
) -> dict[str, jax.Array]:
    """Frame-free metrics: O(E + K * patch^2) per window, bit-identical to
    :func:`cluster_metrics_frame`.

    The normalizer comes from per-pixel coincidence counts, histogram
    counts from leader events, and each cluster's count patch is
    accumulated directly from events — ``reconstruct_frame`` and the
    sensor-sized scatter never run.
    """
    c, leader, w, norm = event_normalizer(batch, width, height)
    x0, y0 = window_origin(
        clusters.centroid_x, clusters.centroid_y, width, height
    )
    hist, moments = event_histogram_counts(batch, c, leader, w, norm, x0, y0)
    patches = cluster_count_patches(batch, clusters, width, height)
    return jax.vmap(_exact_cluster_metrics)(
        patches, hist, jnp.broadcast_to(norm, x0.shape), clusters.count,
        clusters.valid, moments,
    )


METRIC_NAMES = (
    "shannon_entropy",
    "renyi_entropy",
    "differential_entropy",
    "local_contrast",
    "edge_density",
    "event_count",
)


def metric_matrix(metrics: dict[str, jax.Array]) -> jax.Array:
    """Stack the metric dict into a (K, 6) matrix in METRIC_NAMES order."""
    return jnp.stack([metrics[name] for name in METRIC_NAMES], axis=-1)


def correlation_matrix(samples: jax.Array) -> jax.Array:
    """Pearson correlation matrix across metric columns (paper Fig. 7).

    ``samples``: (N, M) matrix of N cluster observations x M metrics.
    """
    x = samples - samples.mean(axis=0, keepdims=True)
    cov = (x.T @ x) / jnp.maximum(samples.shape[0] - 1, 1)
    std = jnp.sqrt(jnp.clip(jnp.diag(cov), 1e-12))
    return cov / (std[:, None] * std[None, :])
