"""Event-stream representation and client-side conditioning.

Faithful to the paper's client subsystem (Sec. III-A):

* events are (x, y, t, polarity) tuples from a 640x480 event-based camera,
* the wire format to the accelerator is a 32-bit packed word with
  ``x = bits[15:0]`` and ``y = bits[31:16]`` (Sec. IV-B),
* conditioning = spatial ROI filter (default ``[20, 20, 580, 420]``) plus
  persistent-event (hot pixel) removal,
* batching uses the dual-threshold policy: a buffer closes after
  ``time_threshold_us`` (20,000 us) OR ``size_threshold`` (250 events),
  whichever comes first.

XLA needs static shapes, so a closed buffer becomes a fixed-capacity
:class:`EventBatch` padded with a validity mask (capacity defaults to 256,
the paper's 250-event threshold rounded to the VPU-friendly multiple of 128
... of 8; kernels pad further to lane multiples as needed).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

SENSOR_WIDTH = 640
SENSOR_HEIGHT = 480
DEFAULT_ROI = (20, 20, 580, 420)  # x0, y0, x1, y1 (paper Sec. III-A)
DEFAULT_TIME_THRESHOLD_US = 20_000
DEFAULT_SIZE_THRESHOLD = 250
DEFAULT_CAPACITY = 256


class EventBatch(NamedTuple):
    """Fixed-capacity struct-of-arrays event buffer (one closed window)."""

    x: jax.Array  # (E,) int32 pixel column
    y: jax.Array  # (E,) int32 pixel row
    t: jax.Array  # (E,) int64-ish microsecond timestamps, stored int32 rel.
    p: jax.Array  # (E,) int32 polarity in {0, 1}
    valid: jax.Array  # (E,) bool validity mask

    @property
    def capacity(self) -> int:
        return self.x.shape[-1]

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32), axis=-1)


def make_empty_batch(capacity: int = DEFAULT_CAPACITY) -> EventBatch:
    z = jnp.zeros((capacity,), jnp.int32)
    return EventBatch(z, z, z, z, jnp.zeros((capacity,), bool))


def batch_from_arrays(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    capacity: int = DEFAULT_CAPACITY,
) -> EventBatch:
    """Pad/truncate host arrays into a fixed-capacity EventBatch."""
    n = min(len(x), capacity)
    pad = capacity - n

    def prep(a):
        a = np.asarray(a[:n], np.int32)
        return jnp.asarray(np.pad(a, (0, pad)))

    valid = jnp.asarray(np.pad(np.ones(n, bool), (0, pad)))
    return EventBatch(prep(x), prep(y), prep(t), prep(p), valid)


# ---------------------------------------------------------------------------
# 32-bit wire format (paper Sec. IV-B): x in bits 15:0, y in bits 31:16.
# ---------------------------------------------------------------------------

def pack_words(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pack coordinate pairs into the AXI4-Stream 32-bit word format."""
    xi = x.astype(jnp.uint32) & jnp.uint32(0xFFFF)
    yi = y.astype(jnp.uint32) & jnp.uint32(0xFFFF)
    return (yi << jnp.uint32(16)) | xi


def unpack_words(words: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Inverse of :func:`pack_words` (bit-slicing, Sec. IV-B step 2)."""
    w = words.astype(jnp.uint32)
    x = (w & jnp.uint32(0xFFFF)).astype(jnp.int32)
    y = (w >> jnp.uint32(16)).astype(jnp.int32)
    return x, y


# ---------------------------------------------------------------------------
# Conditioning: ROI filter + persistent-event removal (Sec. III-A).
# ---------------------------------------------------------------------------

def roi_filter(batch: EventBatch, roi: Sequence[int] = DEFAULT_ROI) -> EventBatch:
    """Invalidate events outside the rectangular region of interest."""
    x0, y0, x1, y1 = roi
    keep = (
        (batch.x >= x0) & (batch.x < x1) & (batch.y >= y0) & (batch.y < y1)
    )
    return batch._replace(valid=batch.valid & keep)


def persistent_event_filter(
    batch: EventBatch,
    max_repeats: int = 8,
    width: int = SENSOR_WIDTH,
    height: int = SENSOR_HEIGHT,
) -> EventBatch:
    """Remove events from pixels firing more than ``max_repeats`` times in
    the window (hot pixels / persistent background activity)."""
    flat = batch.y * width + batch.x
    counts = jnp.zeros((height * width,), jnp.int32).at[flat].add(
        batch.valid.astype(jnp.int32)
    )
    keep = counts[flat] <= max_repeats
    return batch._replace(valid=batch.valid & keep)


# ---------------------------------------------------------------------------
# Dual-threshold batcher (host side; the paper's client event buffer).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    time_threshold_us: int = DEFAULT_TIME_THRESHOLD_US
    size_threshold: int = DEFAULT_SIZE_THRESHOLD
    capacity: int = DEFAULT_CAPACITY


def dual_threshold_batches(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    config: BatcherConfig = BatcherConfig(),
) -> Iterator[tuple[EventBatch, slice]]:
    """Iterate fixed-capacity EventBatches over a time-sorted recording.

    A buffer closes when ``size_threshold`` events accumulate OR the time
    span reaches ``time_threshold_us`` — the paper's 250-event / 20 ms
    client policy. Yields ``(batch, slice_into_recording)`` so callers can
    recover per-event ground-truth labels.
    """
    n = len(t)
    start = 0
    while start < n:
        t0 = t[start]
        # size cut
        end_size = min(start + config.size_threshold, n)
        # time cut: first index with t >= t0 + threshold
        end_time = int(np.searchsorted(t, t0 + config.time_threshold_us, side="left"))
        end = max(start + 1, min(end_size, end_time if end_time > start else end_size))
        sl = slice(start, end)
        yield (
            batch_from_arrays(x[sl], y[sl], t[sl] - t0, p[sl], config.capacity),
            sl,
        )
        start = end


def window_batches(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    window_us: int = DEFAULT_TIME_THRESHOLD_US,
    capacity: int = DEFAULT_CAPACITY,
) -> Iterator[tuple[EventBatch, slice]]:
    """Fixed-stride temporal windows (used by frame reconstruction/tracking)."""
    if len(t) == 0:
        return
    t_end = int(t[-1])
    w0 = int(t[0])
    while w0 <= t_end:
        lo = int(np.searchsorted(t, w0, side="left"))
        hi = int(np.searchsorted(t, w0 + window_us, side="left"))
        sl = slice(lo, hi)
        yield (
            batch_from_arrays(x[sl], y[sl], t[sl] - w0, p[sl], capacity),
            sl,
        )
        w0 += window_us
