"""Event-stream representation and client-side conditioning.

Faithful to the paper's client subsystem (Sec. III-A):

* events are (x, y, t, polarity) tuples from a 640x480 event-based camera,
* the wire format to the accelerator is a 32-bit packed word with
  ``x = bits[15:0]`` and ``y = bits[31:16]`` (Sec. IV-B),
* conditioning = spatial ROI filter (default ``[20, 20, 580, 420]``) plus
  persistent-event (hot pixel) removal,
* batching uses the dual-threshold policy: a buffer closes after
  ``time_threshold_us`` (20,000 us) OR ``size_threshold`` (250 events),
  whichever comes first.

XLA needs static shapes, so a closed buffer becomes a fixed-capacity
:class:`EventBatch` padded with a validity mask (capacity defaults to 256,
the paper's 250-event threshold rounded to the VPU-friendly multiple of 128
... of 8; kernels pad further to lane multiples as needed).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

SENSOR_WIDTH = 640
SENSOR_HEIGHT = 480
DEFAULT_ROI = (20, 20, 580, 420)  # x0, y0, x1, y1 (paper Sec. III-A)
DEFAULT_TIME_THRESHOLD_US = 20_000
DEFAULT_SIZE_THRESHOLD = 250
DEFAULT_CAPACITY = 256


class EventBatch(NamedTuple):
    """Fixed-capacity struct-of-arrays event buffer (one closed window)."""

    x: jax.Array  # (E,) int32 pixel column
    y: jax.Array  # (E,) int32 pixel row
    t: jax.Array  # (E,) int32 WINDOW-RELATIVE microseconds: t_abs - t_start
    #   of the window (absolute int64 stamps never reach the device; the
    #   packers subtract each window's origin, and the int64 -> int32
    #   cast wraps — dual-threshold windows span < time_threshold_us so
    #   in-contract deltas always fit exactly)
    p: jax.Array  # (E,) int32 polarity in {0, 1}
    valid: jax.Array  # (E,) bool validity mask

    @property
    def capacity(self) -> int:
        return self.x.shape[-1]

    def count(self) -> jax.Array:
        return jnp.sum(self.valid.astype(jnp.int32), axis=-1)


def make_empty_batch(capacity: int = DEFAULT_CAPACITY) -> EventBatch:
    z = jnp.zeros((capacity,), jnp.int32)
    return EventBatch(z, z, z, z, jnp.zeros((capacity,), bool))


def batch_from_arrays(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    capacity: int = DEFAULT_CAPACITY,
) -> EventBatch:
    """Pad/truncate host arrays into a fixed-capacity EventBatch.

    Truncation drops the ``len(x) - capacity`` trailing events; the
    stacked path (:func:`pack_bounds` / :func:`pad_windows`) records that
    count per window in ``WindowedEvents.overflow`` rather than losing it.
    Iterator callers can recover it as ``max(0, (sl.stop - sl.start) -
    capacity)`` from the yielded slice. Dual-threshold windows never
    truncate while ``size_threshold <= capacity`` (the default).
    """
    n = min(len(x), capacity)
    pad = capacity - n

    def prep(a):
        a = np.asarray(a[:n], np.int32)
        return jnp.asarray(np.pad(a, (0, pad)))

    valid = jnp.asarray(np.pad(np.ones(n, bool), (0, pad)))
    return EventBatch(prep(x), prep(y), prep(t), prep(p), valid)


# ---------------------------------------------------------------------------
# 32-bit wire format (paper Sec. IV-B): x in bits 15:0, y in bits 31:16.
# ---------------------------------------------------------------------------

def pack_words(x: jax.Array, y: jax.Array) -> jax.Array:
    """Pack coordinate pairs into the AXI4-Stream 32-bit word format."""
    xi = x.astype(jnp.uint32) & jnp.uint32(0xFFFF)
    yi = y.astype(jnp.uint32) & jnp.uint32(0xFFFF)
    return (yi << jnp.uint32(16)) | xi


def unpack_words(words: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Inverse of :func:`pack_words` (bit-slicing, Sec. IV-B step 2)."""
    w = words.astype(jnp.uint32)
    x = (w & jnp.uint32(0xFFFF)).astype(jnp.int32)
    y = (w >> jnp.uint32(16)).astype(jnp.int32)
    return x, y


# ---------------------------------------------------------------------------
# Ragged event wire: the compressed host->device ingest layout.
#
# The dense staging block ships four int32 planes plus a bool mask —
# 17 bytes per event SLOT, padding included. The ragged wire ships only
# real events (DESIGN.md Sec. 16):
#
#   words    (N,)      uint32  pack_words(x, y) — coords in one word
#   dt       (N,)      uint16  t - window t_start (window-relative delta)
#   pol      (N/32,)   uint32  polarity bitplane, little-endian bit order
#   offsets  (S, W+1)  int32   CSR row offsets per (sensor, window)
#   spill    (5, M)    int32   exact lane for out-of-range events:
#                              rows are (position, x, y, dt, p)
#
# ~6.125 bytes per real event plus small offset/spill sidecars. Events
# whose coords/delta/polarity do not fit the packed lanes ([0, 0xFFFF]
# coords and deltas, {0, 1} polarity — everything a real sensor emits)
# are ALSO written to the spill lane as the exact int32 values the dense
# path would have shipped; the device overlay restores them, so decoding
# is bit-identical to the dense planes for arbitrary inputs. N is padded
# to WIRE_QUANTUM so the decoder compiles per occupancy bucket, not per
# event count.
# ---------------------------------------------------------------------------

WIRE_QUANTUM = 512  # wire length bucket (multiple of 32 for the bitplane)
SPILL_QUANTUM = 8  # spill lane length bucket
# Padding entries in the spill lane point past any possible wire length,
# so the decoder's mode="drop" scatter discards them.
SPILL_SENTINEL = np.int32(2**31 - 1)

_DT_MAX = 0xFFFF  # widest window-relative delta the packed lane holds


def wire_pad(n: int) -> int:
    """Events ``n`` rounded up to the wire-length bucket (minimum one)."""
    return max(WIRE_QUANTUM, -(-n // WIRE_QUANTUM) * WIRE_QUANTUM)


def spill_pad(m: int) -> int:
    """Spill entries ``m`` rounded up to the spill bucket (0 stays 0)."""
    return -(-m // SPILL_QUANTUM) * SPILL_QUANTUM


def dense_wire_bytes(s: int, w: int, cap: int) -> int:
    """Host->device bytes for one dense round: four int32 planes, the
    bool validity mask, and the (2, S) int32 meta rows."""
    return 17 * s * w * cap + 8 * s


def ragged_wire_bytes(n_pad: int, s: int, w: int, m_pad: int) -> int:
    """Host->device bytes for one ragged round: words + dt + bitplane
    (6.125 B/slot over the padded wire length), CSR offsets, spill lane,
    and the same (2, S) meta rows as the dense path."""
    return (
        4 * n_pad + 2 * n_pad + 4 * (n_pad // 32)  # words, dt, pol
        + 4 * s * (w + 1)  # offsets
        + 4 * 5 * m_pad  # spill
        + 8 * s  # meta
    )


def _pack_bounds_ragged(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    bounds: list[tuple[int, int, int]],
    out: tuple[np.ndarray, ...],
    *,
    base: int,
    capacity: int,
    spill: bool,
) -> tuple[np.ndarray, ...]:
    """Ragged-mode core of :func:`pack_bounds_into` (one sensor's rows).

    ``out`` is ``(words, dt, pbits, offsets_row)``: the shared 1-D wire
    arrays (written from ``base``) plus this sensor's (>= W+1,) offsets
    row. ``pbits`` is the per-event polarity byte scratch — the caller
    packs it into the 32-bit bitplane once per round
    (``np.packbits(..., bitorder="little")``), since bit packing does
    not compose across unaligned per-sensor segments. Windows longer
    than ``capacity`` truncate exactly like the dense planes do (the
    drop count lands in ``overflow``). Returns
    ``(starts, stops, t_start, overflow, new_base, spill_entries)`` with
    ``spill_entries`` a (5, k) int32 block of (position, x, y, dt, p)
    rows holding the exact int32 values the dense path would ship.
    With ``spill=False`` an out-of-range event raises ``ValueError``
    instead of wrapping into the packed lanes.
    """
    words, dt16, pbits, offsets_row = out
    w = len(bounds)
    starts = np.fromiter((b[0] for b in bounds), np.int64, count=w)
    stops = np.fromiter((b[1] for b in bounds), np.int64, count=w)
    t_start = np.fromiter((b[2] for b in bounds), np.int64, count=w)
    n = np.minimum(stops - starts, np.int64(capacity))  # per-window rows
    overflow = stops - starts - n
    total = int(n.sum())
    offsets_row[0] = base
    offsets_row[1 : w + 1] = base + np.cumsum(n)
    offsets_row[w + 1 :] = base + total  # padding windows: zero count
    if not total:
        return starts, stops, t_start, overflow, base, np.zeros((5, 0), np.int32)
    if w == 1:
        # Single-window fast path (the steady live-feed case): one slice
        # copy per lane, mirroring the dense fast path.
        s0 = int(starts[0])
        xv = x[s0 : s0 + total]
        yv = y[s0 : s0 + total]
        tv = t[s0 : s0 + total] - t_start[0]
        pv = p[s0 : s0 + total]
    else:
        cols = np.arange(total) - np.repeat(np.cumsum(n) - n, n)
        src = np.repeat(starts, n) + cols
        xv, yv, pv = x[src], y[src], p[src]
        tv = t[src] - np.repeat(t_start, n)
    dst = slice(base, base + total)
    words[dst] = (
        (yv.astype(np.uint32) & np.uint32(0xFFFF)) << np.uint32(16)
    ) | (xv.astype(np.uint32) & np.uint32(0xFFFF))
    dt16[dst] = tv.astype(np.uint16)
    pbits[dst] = (pv & 1).astype(np.uint8)
    wide = (
        (xv < 0) | (xv > 0xFFFF) | (yv < 0) | (yv > 0xFFFF)
        | (tv < 0) | (tv > _DT_MAX) | (pv < 0) | (pv > 1)
    )
    if not wide.any():
        return starts, stops, t_start, overflow, base + total, np.zeros(
            (5, 0), np.int32
        )
    if not spill:
        k = int(np.argmax(wide))
        raise ValueError(
            f"event (x={int(xv[k])}, y={int(yv[k])}, dt={int(tv[k])}, "
            f"p={int(pv[k])}) does not fit the packed wire lanes "
            "(coords/deltas in [0, 65535], polarity in {0, 1}) and the "
            "spill lane is disabled; enable spill or pre-filter the stream"
        )
    k = np.flatnonzero(wide)
    # Exact int32 values, wrapping exactly like the dense path's
    # int64 -> int32 plane assignment.
    entries = np.stack([
        (base + k).astype(np.int64),
        xv[k], yv[k], tv[k], pv[k],
    ]).astype(np.int32)
    return starts, stops, t_start, overflow, base + total, entries


def pack_wire(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    bounds: list[tuple[int, int, int]],
    capacity: int,
    *,
    spill: bool = True,
) -> tuple[tuple[np.ndarray, ...], np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Allocate-and-pack one sensor's windows into ragged wire arrays.

    Convenience wrapper over ``pack_bounds_into(layout="ragged")`` for
    single-sensor callers (the streaming engine, tests): returns
    ``(wire, starts, stops, t_start, overflow)`` where ``wire`` is the
    ``(words, dt, pol, offsets, spill)`` tuple :func:`unpack_wire`
    consumes, with ``offsets`` shaped (1, W+1) and the wire length
    padded to :data:`WIRE_QUANTUM`. Rows longer than ``capacity`` are
    truncated exactly like :func:`pack_bounds`.
    """
    w = len(bounds)
    total = sum(min(e - s, capacity) for s, e, _ in bounds)
    n_pad = wire_pad(total)
    words = np.zeros(n_pad, np.uint32)
    dt16 = np.zeros(n_pad, np.uint16)
    pbits = np.zeros(n_pad, np.uint8)
    offsets = np.zeros((1, w + 1), np.int32)
    starts, stops, t_start, overflow, _, entries = pack_bounds_into(
        x, y, t, p, bounds,
        out=(words, dt16, pbits, offsets[0]),
        layout="ragged", base=0, capacity=capacity, spill=spill,
    )
    pol = np.zeros(n_pad // 32, np.uint32)
    if total:
        packed_bits = np.packbits(pbits[:total], bitorder="little")
        pol.view(np.uint8)[: len(packed_bits)] = packed_bits
    m = entries.shape[1]
    m_pad = spill_pad(m)
    spill_lane = np.full((5, m_pad), SPILL_SENTINEL, np.int32)
    spill_lane[:, :m] = entries
    return (words, dt16, pol, offsets, spill_lane), starts, stops, t_start, overflow


def unpack_wire(
    words: jax.Array,
    dt16: jax.Array,
    pol: jax.Array,
    offsets: jax.Array,
    spill: jax.Array,
    capacity: int,
    unpack_impl=None,
) -> tuple[jax.Array, jax.Array]:
    """Device-side ragged-wire decoder (trace-time jnp; DESIGN.md Sec. 16).

    Reconstructs the dense staging planes bit-for-bit: returns
    ``(packed, valid)`` with ``packed`` the (4, S, W, capacity) int32
    x/y/t/p block and ``valid`` the (S, W, capacity) bool mask — exactly
    what the fleet step consumes, so the compiled step is shared between
    the dense and ragged ingest paths. ``unpack_impl`` overrides the
    word unpack route (the Pallas ``event_unpack`` kernel when
    ``config.use_kernels``; the jnp shift/mask path otherwise). Safe
    inside an enclosing jit: every shape is static at trace time.

    Bit-identity argument: packed lanes reconstruct exactly over their
    ranges (coords/deltas in [0, 0xFFFF] zero-extend to the same
    non-negative int32; polarity bits are the values); everything wider
    was also written to the spill lane as the exact int32 the dense path
    ships, and the overlay scatter restores it before the gather. Slots
    past each window's count are forced to zero — the dense planes are
    zero-filled — so even garbage in the padded wire tail is
    unobservable.
    """
    n = words.shape[0]
    dt16, pol, spill = (jnp.asarray(dt16), jnp.asarray(pol), jnp.asarray(spill))
    xs, ys = (unpack_impl or unpack_words)(words)
    ts = dt16.astype(jnp.int32)  # zero-extend: exact over [0, 0xFFFF]
    bits = (
        pol[:, None] >> jnp.arange(32, dtype=jnp.uint32)[None, :]
    ) & jnp.uint32(1)
    ps = bits.reshape(-1).astype(jnp.int32)
    pos = spill[0]
    xs = xs.at[pos].set(spill[1], mode="drop")
    ys = ys.at[pos].set(spill[2], mode="drop")
    ts = ts.at[pos].set(spill[3], mode="drop")
    ps = ps.at[pos].set(spill[4], mode="drop")
    counts = offsets[:, 1:] - offsets[:, :-1]  # (S, W)
    slot = jnp.arange(capacity, dtype=jnp.int32)
    src = offsets[:, :-1, None] + slot[None, None, :]  # (S, W, cap)
    valid = slot[None, None, :] < counts[..., None]
    take = jnp.clip(src, 0, n - 1)
    gather = lambda a: jnp.where(valid, a[take], 0)
    packed = jnp.stack([gather(xs), gather(ys), gather(ts), gather(ps)])
    return packed, valid


# ---------------------------------------------------------------------------
# Conditioning: ROI filter + persistent-event removal (Sec. III-A).
# ---------------------------------------------------------------------------

def roi_filter(batch: EventBatch, roi: Sequence[int] = DEFAULT_ROI) -> EventBatch:
    """Invalidate events outside the rectangular region of interest."""
    x0, y0, x1, y1 = roi
    keep = (
        (batch.x >= x0) & (batch.x < x1) & (batch.y >= y0) & (batch.y < y1)
    )
    return batch._replace(valid=batch.valid & keep)


# Above this capacity the pairwise (E x E) coincidence count costs more
# than the O(E log E) sort-based one; below it, the compare matrix
# vectorizes better on CPU/VPU.
_PAIRWISE_MAX_EVENTS = 1024


def persistent_event_filter(
    batch: EventBatch,
    max_repeats: int = 8,
    width: int = SENSOR_WIDTH,
    height: int = SENSOR_HEIGHT,
) -> EventBatch:
    """Remove events from pixels firing more than ``max_repeats`` times in
    the window (hot pixels / persistent background activity).

    Event-space implementation: the per-pixel rate is a pairwise
    coincidence count over the window's own events (E x E compares for
    E <= 256, which vectorizes better than a sort at the paper's window
    sizes) instead of a scatter into a sensor-sized ``height * width``
    histogram — the window only ever touches O(E^2) values, not
    O(sensor area), and the ``keep`` mask is bit-identical to the
    histogram formulation (kept below as
    :func:`persistent_event_filter_hist`, the test oracle). Large
    capacities fall back to the O(E log E) :func:`coincidence_counts`
    sort so the cost never goes quadratic. ``width``/``height`` are
    accepted for signature compatibility with the oracle; neither form
    needs them.
    """
    del width, height  # event-space forms never materialize the sensor grid
    if batch.x.shape[-1] > _PAIRWISE_MAX_EVENTS:
        fn = coincidence_counts
        for _ in range(batch.x.ndim - 1):
            fn = jax.vmap(fn)
        counts, _ = fn(batch.x, batch.y, batch.valid)
    else:
        same = (batch.x[..., :, None] == batch.x[..., None, :]) & (
            batch.y[..., :, None] == batch.y[..., None, :]
        )
        counts = jnp.sum(same & batch.valid[..., None, :], axis=-1)
    keep = counts <= max_repeats
    return batch._replace(valid=batch.valid & keep)


def persistent_event_filter_hist(
    batch: EventBatch,
    max_repeats: int = 8,
    width: int = SENSOR_WIDTH,
    height: int = SENSOR_HEIGHT,
) -> EventBatch:
    """Histogram-based oracle for :func:`persistent_event_filter`.

    Scatters the window into a sensor-sized per-pixel histogram — the
    original O(sensor-area) formulation, kept as the bit-exactness
    reference for the pairwise path.
    """
    flat = batch.y * width + batch.x
    counts = jnp.zeros((height * width,), jnp.int32).at[flat].add(
        batch.valid.astype(jnp.int32)
    )
    keep = counts[flat] <= max_repeats
    return batch._replace(valid=batch.valid & keep)


def coincidence_counts(
    x: jax.Array, y: jax.Array, weight: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Per-event pixel coincidence counts and run leaders, O(E log E).

    For each event ``i``, ``counts[i]`` is the number of weighted events
    sharing pixel ``(x[i], y[i])`` (including itself), and ``leader[i]``
    marks exactly one weighted event per occupied pixel. Implemented by
    sorting packed pixel keys and measuring run lengths with prefix
    scans — no sensor-sized buffer, no O(E^2) compare matrix. Counts are
    exact integers, so downstream float math is bit-reproducible
    regardless of event order.

    Events with ``weight`` False get an arbitrary count and are never
    leaders. 1-D inputs only (vmap over a window axis for batches).

    At window capacities (E <= ``_PAIRWISE_MAX_EVENTS``) on CPU the
    same contract is served by one (E, E) pairwise compare block —
    cache-resident, no sort. XLA's
    CPU sort is the single most expensive op in the vmapped fleet step,
    so the pairwise route is worth a branch; both produce the identical
    exact integers and the identical lowest-index-per-pixel leader, so
    every driver stays bit-identical whichever branch compiles.
    """
    e = x.shape[-1]
    if e <= _PAIRWISE_MAX_EVENTS and jax.default_backend() == "cpu":
        key = pack_words(x, y)
        same = (key[:, None] == key[None, :]) & weight[None, :]  # (i, j)
        counts = jnp.sum(same, axis=-1, dtype=jnp.int32)
        earlier = jnp.tril(same, k=-1)  # weighted same-pixel j < i
        leader = weight & ~jnp.any(earlier, axis=-1)
        return counts, leader
    sentinel = jnp.uint32(0xFFFFFFFF)
    key = jnp.where(weight, pack_words(x, y), sentinel)
    perm = jnp.argsort(key)
    sk = key[perm]
    idx = jnp.arange(e, dtype=jnp.int32)
    start = jnp.concatenate([jnp.ones((1,), bool), sk[1:] != sk[:-1]])
    end = jnp.concatenate([sk[1:] != sk[:-1], jnp.ones((1,), bool)])
    first = jax.lax.cummax(jnp.where(start, idx, 0))
    last = jnp.flip(jax.lax.cummin(jnp.flip(jnp.where(end, idx, e))))
    counts_s = last - first + 1
    leader_s = start & (sk != sentinel)
    inv = jnp.zeros((e,), jnp.int32).at[perm].set(idx, unique_indices=True)
    return counts_s[inv], leader_s[inv]


# ---------------------------------------------------------------------------
# Dual-threshold batcher (host side; the paper's client event buffer).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BatcherConfig:
    time_threshold_us: int = DEFAULT_TIME_THRESHOLD_US
    size_threshold: int = DEFAULT_SIZE_THRESHOLD
    capacity: int = DEFAULT_CAPACITY


def validate_monotone(
    t: np.ndarray, last_t: int | None = None, label: str = "feed"
) -> None:
    """Reject a chunk whose timestamps would mis-window the stream.

    Timestamps must be non-decreasing *within* the chunk and must not
    precede ``last_t``, the newest timestamp the stream has already
    absorbed (which may belong to an already-processed window, not just
    the remainder). Raises ``ValueError`` on violation; shared by
    :func:`monotone_merge` (the fleet/stream merge point) and the
    session layer (:mod:`repro.serve.sessions`), which validates at
    accept time so a bad chunk is refused before it is ever queued.
    """
    t = np.asarray(t, np.int64)
    if not len(t):
        return
    if len(t) > 1 and np.any(t[1:] < t[:-1]):
        bad = int(np.argmax(t[1:] < t[:-1]))
        raise ValueError(
            f"{label}: chunk timestamps are not non-decreasing "
            f"(t[{bad + 1}]={int(t[bad + 1])} < t[{bad}]={int(t[bad])}); "
            "events must be time-sorted"
        )
    if last_t is not None and int(t[0]) < last_t:
        raise ValueError(
            f"{label}: chunk starts at t={int(t[0])} us, before the "
            f"stream's newest absorbed timestamp {last_t} us; feeds "
            "must be monotonically non-decreasing across boundaries"
        )


def monotone_merge(
    pending: tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray],
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    last_t: int | None = None,
    label: str = "feed",
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Validate + append a raw chunk onto the batcher remainder.

    The dual-threshold batcher requires time-sorted input; an
    out-of-order chunk would silently land events in the wrong window
    (the window boundaries are computed from ``searchsorted`` over the
    merged buffer). This is the one merge point every streaming driver
    goes through, so :func:`validate_monotone` is enforced here: a bad
    chunk raises ``ValueError`` before any state is touched — the
    caller's carry stays valid and the chunk is not absorbed.
    """
    px, py, pt, pp = pending
    t = np.asarray(t, np.int64)
    validate_monotone(t, last_t, label)
    return (
        np.concatenate([px, np.asarray(x, np.int64)]),
        np.concatenate([py, np.asarray(y, np.int64)]),
        np.concatenate([pt, t]),
        np.concatenate([pp, np.asarray(p, np.int64)]),
    )


def dual_threshold_bounds(
    t: np.ndarray, config: BatcherConfig = BatcherConfig()
) -> list[tuple[int, int]]:
    """Window boundaries (start, stop) under the dual-threshold policy.

    Shared by the streaming batcher and :func:`pad_windows` so the host
    loop, the device-resident scan, and the streaming engine see
    identical windows. Derived from
    :func:`dual_threshold_closed_bounds` — the one implementation of the
    size/time cuts — plus the end-of-stream rule: the trailing remainder
    (which by construction neither cut can close, so it is a single
    window shorter than ``size_threshold``) is force-closed at the last
    event.
    """
    bounds, start = dual_threshold_closed_bounds(t, config)
    if start < len(t):
        bounds.append((start, len(t)))
    return bounds


def dual_threshold_closed_bounds(
    t: np.ndarray, config: BatcherConfig = BatcherConfig()
) -> tuple[list[tuple[int, int]], int]:
    """Provably-final window bounds for a stream that may still continue.

    Same semantics as :func:`dual_threshold_bounds`, restricted to windows
    whose boundaries no future event can change: either an event at or past
    ``t0 + time_threshold_us`` is already buffered (time cut lands inside
    the buffer) or ``size_threshold`` events have accumulated (size cut
    binds regardless of later timestamps). The trailing partial window
    stays pending. Returns ``(bounds, consumed)`` where ``consumed`` is the
    prefix length covered by the closed windows; for any split of a
    recording into chunks, concatenating the closed bounds of successive
    buffers (plus a final :func:`dual_threshold_bounds` pass over the last
    remainder) reproduces the whole-recording bounds exactly — the
    invariant the streaming engine's bit-identity rests on.
    """
    n = len(t)
    bounds: list[tuple[int, int]] = []
    start = 0
    while start < n:
        t0 = t[start]
        end_size = start + config.size_threshold
        end_time = int(np.searchsorted(t, t0 + config.time_threshold_us, side="left"))
        if end_time > start:
            if end_time >= n and end_size > n:
                break  # neither cut provably lands inside the buffer yet
            end = min(end_size, end_time)
        else:  # degenerate time threshold: only the size cut can close
            if end_size > n:
                break
            end = end_size
        end = max(start + 1, min(end, n))
        bounds.append((start, end))
        start = end
    return bounds, start


def dual_threshold_batches(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    config: BatcherConfig = BatcherConfig(),
) -> Iterator[tuple[EventBatch, slice]]:
    """Iterate fixed-capacity EventBatches over a time-sorted recording.

    A buffer closes when ``size_threshold`` events accumulate OR the time
    span reaches ``time_threshold_us`` — the paper's 250-event / 20 ms
    client policy. Yields ``(batch, slice_into_recording)`` so callers can
    recover per-event ground-truth labels.
    """
    for start, end in dual_threshold_bounds(t, config):
        sl = slice(start, end)
        yield (
            batch_from_arrays(x[sl], y[sl], t[sl] - t[start], p[sl], config.capacity),
            sl,
        )


def stride_bounds(
    t: np.ndarray, window_us: int = DEFAULT_TIME_THRESHOLD_US
) -> list[tuple[int, int, int]]:
    """Fixed-stride window boundaries ``(start, stop, window_t0_us)``.

    Unlike the dual-threshold policy, stride windows are anchored to wall
    time: a window may be empty and its origin is the stride start, not
    the first event's timestamp.
    """
    if len(t) == 0:
        return []
    bounds: list[tuple[int, int, int]] = []
    t_end = int(t[-1])
    w0 = int(t[0])
    while w0 <= t_end:
        lo = int(np.searchsorted(t, w0, side="left"))
        hi = int(np.searchsorted(t, w0 + window_us, side="left"))
        bounds.append((lo, hi, w0))
        w0 += window_us
    return bounds


def window_batches(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    window_us: int = DEFAULT_TIME_THRESHOLD_US,
    capacity: int = DEFAULT_CAPACITY,
) -> Iterator[tuple[EventBatch, slice]]:
    """Fixed-stride temporal windows (used by frame reconstruction/tracking)."""
    for lo, hi, w0 in stride_bounds(t, window_us):
        sl = slice(lo, hi)
        yield (
            batch_from_arrays(x[sl], y[sl], t[sl] - w0, p[sl], capacity),
            sl,
        )


# ---------------------------------------------------------------------------
# Device-resident windowing: the whole recording as one stacked pytree.
# ---------------------------------------------------------------------------

class WindowedEvents(NamedTuple):
    """A full recording pre-windowed into a stacked, fixed-shape pytree.

    ``batch`` leaves have shape (W, capacity) — one row per closed window,
    padded with the validity mask — so the entire recording can be pushed
    through a ``jax.lax.scan`` (or vmapped across recordings) with a single
    device dispatch. Host-side bookkeeping (window start times and slice
    boundaries into the original stream) rides along as numpy arrays for
    ground-truth matching.

    ``overflow`` records per-window event loss: windows longer than
    ``capacity`` are truncated to fit the fixed shape, and the number of
    dropped events lands here instead of vanishing silently. Under the
    dual-threshold policy every window closes at ``<= size_threshold``
    events, so overflow is all-zero whenever ``size_threshold <=
    capacity``; ``policy="stride"`` windows are unbounded and can
    genuinely truncate.
    """

    batch: EventBatch  # leaves (W, capacity)
    t_start_us: np.ndarray  # (W,) int64 absolute window origin
    starts: np.ndarray  # (W,) int64 slice start into the recording
    stops: np.ndarray  # (W,) int64 slice stop (exclusive)
    overflow: np.ndarray | None = None  # (W,) int64 events dropped past capacity

    @property
    def num_windows(self) -> int:
        return self.batch.x.shape[0]

    @property
    def capacity(self) -> int:
        return self.batch.x.shape[-1]


def pack_bounds_into(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    bounds: list[tuple[int, int, int]],
    bx: np.ndarray | None = None,
    by: np.ndarray | None = None,
    bt: np.ndarray | None = None,
    bp: np.ndarray | None = None,
    bv: np.ndarray | None = None,
    *,
    out: tuple[np.ndarray, ...] | None = None,
    layout: str = "dense",
    base: int = 0,
    capacity: int | None = None,
    spill: bool = True,
) -> tuple[np.ndarray, ...]:
    """Numpy core of :func:`pack_bounds`: scatter windows into preallocated
    (>= W, capacity) arrays (rows past ``len(bounds)`` are left untouched).

    Shared by the single-recording packer and the fleet engine, which
    packs every sensor into one (S, W_max, capacity) block so the whole
    fleet transfers to device as five arrays, not five per sensor. The
    destination planes are either five positional arrays or one
    ``out=(bx, by, bt, bp, bv)`` tuple — the form the fleet's reusable
    staging buffers hand over, so a pipelined round packs in place with
    zero per-round allocation. Returns ``(starts, stops, t_start,
    overflow)``.

    ``layout="ragged"`` writes the compressed event wire instead:
    ``out`` becomes ``(words, dt, pbits, offsets_row)`` (see
    :func:`_pack_bounds_ragged` — packed coordinate words from ``base``,
    16-bit deltas, polarity bytes, this sensor's CSR offsets row) and
    ``capacity`` bounds the per-window row length exactly like the dense
    planes' trailing dim. The return grows to ``(starts, stops, t_start,
    overflow, new_base, spill_entries)``; ``spill=False`` raises on any
    event the packed lanes cannot hold exactly.
    """
    if layout == "ragged":
        if out is None or bx is not None:
            raise TypeError("layout='ragged' requires the out= wire tuple")
        if capacity is None:
            raise TypeError("layout='ragged' requires capacity=")
        return _pack_bounds_ragged(
            x, y, t, p, bounds, out, base=base, capacity=capacity, spill=spill
        )
    if layout != "dense":
        raise ValueError(f"unknown pack layout: {layout!r}")
    if out is not None:
        if bx is not None:
            raise TypeError("pass destination planes positionally OR as out=")
        bx, by, bt, bp, bv = out
    if bx is None or by is None or bt is None or bp is None or bv is None:
        raise TypeError("five destination planes required (positional or out=)")
    w = len(bounds)
    cap = bx.shape[-1]
    if w == 1:
        # Single-window fast path — the steady live-feed case (one
        # window closes per 20 ms chunk), hit once per sensor per fleet
        # round: plain slice assignments, no scatter-index build.
        s0, e0, t0 = bounds[0]
        n0 = min(e0 - s0, cap)
        bx[0, :n0] = x[s0:s0 + n0]
        by[0, :n0] = y[s0:s0 + n0]
        bt[0, :n0] = t[s0:s0 + n0] - t0
        bp[0, :n0] = p[s0:s0 + n0]
        bv[0, :n0] = True
        return (
            np.array([s0], np.int64), np.array([e0], np.int64),
            np.array([t0], np.int64), np.array([e0 - s0 - n0], np.int64),
        )
    starts = np.fromiter((b[0] for b in bounds), np.int64, count=w)
    stops = np.fromiter((b[1] for b in bounds), np.int64, count=w)
    t_start = np.fromiter((b[2] for b in bounds), np.int64, count=w)
    n = np.minimum(stops - starts, cap)
    overflow = stops - starts - n
    total = int(n.sum())
    if total:
        rows = np.repeat(np.arange(w), n)
        cols = np.arange(total) - np.repeat(np.cumsum(n) - n, n)
        src = np.repeat(starts, n) + cols
        bx[rows, cols] = x[src]
        by[rows, cols] = y[src]
        bt[rows, cols] = t[src] - np.repeat(t_start, n)
        bp[rows, cols] = p[src]
        bv[rows, cols] = True
    return starts, stops, t_start, overflow


def pack_bounds(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    bounds: list[tuple[int, int, int]],
    capacity: int,
) -> WindowedEvents:
    """Pack ``(start, stop, t0_us)`` bounds into a stacked WindowedEvents.

    One bulk scatter per field over (window-row, column) index arrays —
    no per-window Python slice loop — so host packing scales with total
    events, not windows. Rows longer than ``capacity`` are truncated and
    the per-window drop count recorded in ``overflow``.
    """
    w = len(bounds)
    bx = np.zeros((w, capacity), np.int32)
    by = np.zeros((w, capacity), np.int32)
    bt = np.zeros((w, capacity), np.int32)
    bp = np.zeros((w, capacity), np.int32)
    bv = np.zeros((w, capacity), bool)
    starts, stops, t_start, overflow = pack_bounds_into(
        x, y, t, p, bounds, bx, by, bt, bp, bv
    )
    batch = EventBatch(
        jnp.asarray(bx), jnp.asarray(by), jnp.asarray(bt), jnp.asarray(bp),
        jnp.asarray(bv),
    )
    return WindowedEvents(batch, t_start, starts, stops, overflow)


def pad_windows(
    x: np.ndarray,
    y: np.ndarray,
    t: np.ndarray,
    p: np.ndarray,
    config: BatcherConfig = BatcherConfig(),
    policy: str = "dual",
    window_us: int | None = None,
) -> WindowedEvents:
    """Slice a time-sorted recording into a (W, capacity) stacked EventBatch.

    ``policy="dual"`` reproduces :func:`dual_threshold_batches` windows
    bit-for-bit (same boundaries, same relative timestamps, same
    capacity truncation); ``policy="stride"`` reproduces
    :func:`window_batches`. The result feeds ``run_recording_scan``:
    one device transfer in, one compiled scan over the W axis, one
    transfer out. Events dropped by capacity truncation are counted in
    the result's ``overflow`` field.
    """
    x = np.asarray(x)
    y = np.asarray(y)
    t = np.asarray(t)
    p = np.asarray(p)
    if policy == "dual":
        bounds = [(s, e, int(t[s])) for s, e in dual_threshold_bounds(t, config)]
    elif policy == "stride":
        bounds = stride_bounds(t, window_us or config.time_threshold_us)
    else:
        raise ValueError(f"unknown windowing policy: {policy!r}")
    return pack_bounds(x, y, t, p, bounds, config.capacity)
