"""Device-resident scanned drivers (one dispatch per recording / batch).

The central object is the **step core** built by :func:`_make_core`:

    core(stacked, state, atlas, tag0) ->
        (final_state, clusters, mets, states, atlas_out)

It processes a block of pre-windowed events (leaves ``(W, capacity)``)
through conditioning -> clustering -> metrics -> tracking, threading two
carries: the tracker state and (for the event-space metrics path) the
persistent window-tagged event atlas, whose tags start at ``tag0``.
Everything else is a wrapper:

* ``run_recording_scan`` — one core call over all of a recording's
  windows with a fresh carry (``tag0 = 0``, zero atlas): the streaming
  engine's single-feed special case.
* ``run_many_scan`` — ``vmap`` of the same core over a batch of
  recordings (multi-sensor throughput).
* ``StreamingPipeline`` (``stream.py``) — repeated core calls over
  incrementally closed windows, carrying state/atlas/tag between feeds.

Because window ``w`` only ever reads atlas pixels tagged ``tag0 + w``
(stale pixels fail the tag check), results are bit-identical no matter
how the window sequence is split across core calls.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.events import EventBatch, WindowedEvents, pad_windows
from repro.core.grid_clustering import Clusters
from repro.core.pipeline.config import PipelineConfig, _histogram_fn, _metrics_fn
from repro.core.pipeline.window_core import WindowResult, _window_core
from repro.core.tracking import TrackState, init_tracks, tracker_step

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # avoid circular import (data.synthetic uses core.events)
    from repro.data.synthetic import Recording


@dataclasses.dataclass
class ScanResult:
    """Stacked outputs of the scanned (or streaming) pipeline.

    ``clusters`` leaves and ``metrics`` values have shape (W, K);
    ``tracks`` leaves (when tracking is on) have shape (W, T) — the
    tracker state *after* each window. Everything stays on device until
    the caller converts it; ``window_results()`` materializes the legacy
    per-window list for drop-in comparisons.
    """

    t_start_us: np.ndarray  # (W,) int64
    clusters: Clusters  # leaves (W, K)
    metrics: dict[str, jax.Array]  # (W, K)
    tracks: TrackState | None  # leaves (W, T)
    final_tracks: TrackState | None
    windows: WindowedEvents

    @property
    def num_windows(self) -> int:
        return int(self.t_start_us.shape[0])

    def window_results(self) -> list[WindowResult]:
        mets_np = {k: np.asarray(v) for k, v in self.metrics.items()}
        out: list[WindowResult] = []
        for w in range(self.num_windows):
            out.append(
                WindowResult(
                    t_start_us=int(self.t_start_us[w]),
                    clusters=jax.tree.map(lambda a: a[w], self.clusters),
                    metrics={k: v[w] for k, v in mets_np.items()},
                    tracks=(
                        jax.tree.map(lambda a: a[w], self.tracks)
                        if self.tracks is not None
                        else None
                    ),
                )
            )
        return out


def atlas_shape(config: PipelineConfig, capacity: int | None = None) -> tuple[int, int]:
    """Shape of the persistent tagged event surface for this config."""
    cap = config.batcher.capacity if capacity is None else capacity
    return (config.grid.height + 1, max(config.grid.width, cap))


def make_atlas(config: PipelineConfig, capacity: int | None = None) -> jax.Array:
    """Fresh (all-stale) tagged event atlas; rides the scan/stream carry."""
    return jnp.zeros(atlas_shape(config, capacity), jnp.int32)


def _make_core(config: PipelineConfig, with_tracking: bool):
    """Build the (un-jitted) step core; jit/vmap wrappers layer on top.

    ``numerics="fixed"`` routes to the integer datapath core
    (:func:`repro.core.fixed_point._make_fixed_core`, staged or fused
    megakernel); ``metrics_impl="event"`` routes to the phased
    event-space driver (:func:`_make_event_core`); "frame" and "kernel"
    keep the straight per-window scan (the atlas is threaded through
    untouched so every impl exposes the same carry signature).
    """
    if config.numerics == "fixed":
        from repro.core.fixed_point import _make_fixed_core

        return _make_fixed_core(config, with_tracking)
    if config.numerics != "float":
        raise ValueError(f"unknown numerics: {config.numerics!r}")
    if config.metrics_impl == "event":
        from repro.core.pipeline.event_core import _make_event_core

        return _make_event_core(config, with_tracking)
    hist_fn = _histogram_fn(config)
    metrics_fn = _metrics_fn(config)

    def core(stacked: EventBatch, state: TrackState, atlas: jax.Array, tag0):
        del tag0  # only the event-space atlas needs window tags

        def step(carry, batch):
            clusters, mets = _window_core(config, hist_fn, metrics_fn, batch)
            if with_tracking:
                carry, _ = tracker_step(
                    carry, clusters, mets["shannon_entropy"], config.tracker
                )
            return carry, (clusters, mets, carry)

        final, (clusters, mets, states) = jax.lax.scan(step, state, stacked)
        return final, clusters, mets, states, atlas

    return core


def _fresh_carry_core(config: PipelineConfig, with_tracking: bool):
    """Core specialized to a fresh carry (zero atlas, tags from 0)."""
    core = _make_core(config, with_tracking)

    def scan_core(stacked: EventBatch, state: TrackState):
        atlas = make_atlas(config, stacked.x.shape[-1])
        final, clusters, mets, states, _ = core(stacked, state, atlas, 0)
        return final, clusters, mets, states

    return scan_core


@functools.lru_cache(maxsize=None)
def make_scan_fn(config: PipelineConfig = PipelineConfig(), with_tracking: bool = True):
    """Jit'd whole-recording scan: (stacked EventBatch, init TrackState) ->
    (final TrackState, stacked Clusters, stacked metrics, stacked TrackState).

    Compiled once per (config, window count, capacity); cached per config.
    """
    return jax.jit(_fresh_carry_core(config, with_tracking))


@functools.lru_cache(maxsize=None)
def make_stream_fn(config: PipelineConfig = PipelineConfig(), with_tracking: bool = True):
    """Jit'd streaming step with donated carry:

        (stacked, state, atlas, tag0) ->
            (final_state, clusters, mets, states, atlas_out)

    The atlas is donated — XLA reuses its buffer for the updated carry, so
    the steady-state feed loop allocates only the per-feed outputs. The
    tracker state is NOT donated: the previous feed handed it to the
    caller as ``final_tracks``, and donating it would invalidate that
    result behind the caller's back (it is (T,)-tiny anyway). Compiled
    once per (config, windows-per-feed count); cached per config.
    """
    return jax.jit(_make_core(config, with_tracking), donate_argnums=(2,))


@functools.lru_cache(maxsize=None)
def _make_many_scan_fn(config: PipelineConfig, with_tracking: bool):
    core = _fresh_carry_core(config, with_tracking)
    # Map over the recording axis; broadcast the (fresh) tracker state.
    return jax.jit(jax.vmap(core, in_axes=(0, None)))


def run_recording_scan(
    recording: Recording,
    config: PipelineConfig = PipelineConfig(),
    with_tracking: bool = True,
    windows: WindowedEvents | None = None,
) -> ScanResult:
    """Device-resident driver: the whole recording in one core call.

    Windows are identical to ``run_recording``'s dual-threshold batches
    (same boundaries, same padding), but the per-window stage and the
    tracker run inside a single compiled scan — one host->device transfer
    in, one device->host sync out, no per-window dispatch. This is the
    streaming engine's single-feed special case: one step over all
    windows with a fresh carry. Pass a precomputed ``windows`` (from
    :func:`repro.core.events.pad_windows`) to skip the host windowing
    pass, e.g. when sweeping configs over one recording.
    """
    if windows is None:
        windows = pad_windows(
            recording.x, recording.y, recording.t, recording.p, config.batcher
        )
    scan_fn = make_scan_fn(config, with_tracking)
    final, clusters, mets, states = scan_fn(windows.batch, init_tracks(config.tracker))
    return ScanResult(
        t_start_us=windows.t_start_us,
        clusters=clusters,
        metrics=mets,
        tracks=states if with_tracking else None,
        final_tracks=final if with_tracking else None,
        windows=windows,
    )


def _many_scan_raw(
    recordings: list[Recording],
    config: PipelineConfig,
    with_tracking: bool,
) -> tuple[list[WindowedEvents], tuple]:
    """Window + stack a batch of recordings and run the vmapped core once.

    Returns the per-recording host windowing plus the *untrimmed* stacked
    device outputs (leaves (R, W_max, ...)) — the device-resident
    evaluation path consumes these directly so the whole batch stays at
    O(1) dispatches.
    """
    windowed = [
        pad_windows(r.x, r.y, r.t, r.p, config.batcher) for r in recordings
    ]
    w_max = max(w.num_windows for w in windowed)

    def pad_leaf(a: jax.Array) -> jax.Array:
        pad = w_max - a.shape[0]
        if pad == 0:
            return a
        return jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0
        )

    stacked = EventBatch(
        *[
            jnp.stack([pad_leaf(getattr(w.batch, f)) for w in windowed])
            for f in EventBatch._fields
        ]
    )
    many_fn = _make_many_scan_fn(config, with_tracking)
    return windowed, many_fn(stacked, init_tracks(config.tracker))


def run_many_scan(
    recordings: list[Recording],
    config: PipelineConfig = PipelineConfig(),
    with_tracking: bool = True,
) -> list[ScanResult]:
    """Vmapped scan over a batch of recordings (multi-sensor throughput).

    Recordings are windowed on host, right-padded with empty (all-invalid)
    windows to a common window count, stacked to (R, W, capacity) leaves,
    and pushed through ``vmap(core)`` in a single dispatch. Results are
    split back per recording and trimmed to each one's true window count.
    """
    if not recordings:
        return []
    windowed, (_, clusters, mets, states) = _many_scan_raw(
        recordings, config, with_tracking
    )
    results: list[ScanResult] = []
    for r, w in enumerate(windowed):
        n = w.num_windows
        if not with_tracking:
            final_r = None
        elif n == 0:
            final_r = init_tracks(config.tracker)
        else:
            # The scan carry after w_max windows has coasted through this
            # recording's padded (all-invalid) tail; the true final state
            # is the per-window state at its last real window.
            final_r = jax.tree.map(lambda a: a[r, n - 1], states)
        results.append(
            ScanResult(
                t_start_us=w.t_start_us,
                clusters=jax.tree.map(lambda a: a[r, :n], clusters),
                metrics={k: v[r, :n] for k, v in mets.items()},
                tracks=(
                    jax.tree.map(lambda a: a[r, :n], states)
                    if with_tracking
                    else None
                ),
                final_tracks=final_r,
                windows=w,
            )
        )
    return results
